"""Layer-2 optimizer step graphs — one lowered artifact per weight shape.

Implements MoFaSGD (paper Algorithm 1) plus every baseline the paper
evaluates against. Each function is pure, static-shape, and LAPACK-free so
it lowers to HLO text runnable from the Rust PJRT runtime.

Conventions:
  * momentum factors: U (m×r), s (r,), V (n×r) with M̂ = U diag(s) Vᵀ
  * all hyperparameters (η, β, t, …) are runtime scalars, so one artifact
    serves a whole hyperparameter sweep
  * `*_step_from_buf` variants consume the fused low-rank accumulation
    buffers of §5.5 and never touch the full-rank gradient
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.tangent import lowrank_accum, rank_r_update, tangent_project
from .linalg_jnp import cgs2_qr, jacobi_svd, newton_schulz, rand_range, svd_lowrank

_ADAM_EPS = 1e-8


# ---------------------------------------------------------------------------
# MoFaSGD (Algorithm 1)
# ---------------------------------------------------------------------------

def umf_core(w, u, s, v, gv, utg, utgv, eta, beta):
    """Update-Momentum-Factors core given the tangent projections.

    Implements Alg. 1 lines 3–12 + the Eq. 9 spectral update:
      QR([U  GV]), QR([V  GᵀU]),
      S = R_U [[βΣ − UᵀGV, I], [I, 0]] R_Vᵀ,
      SVD_r(S) → rotate factors, W ← W − η U' V'ᵀ.

    Cost: O((m+n)r²) for the QRs + O(r³) for the 2r×2r SVD — no pass over
    G beyond the projections already in (gv, utg, utgv).
    """
    r = s.shape[0]
    uq, ru = cgs2_qr(jnp.concatenate([u, gv], axis=1))          # m×2r
    vq, rv = cgs2_qr(jnp.concatenate([v, utg.T], axis=1))       # n×2r
    eye = jnp.eye(r, dtype=w.dtype)
    zero = jnp.zeros((r, r), dtype=w.dtype)
    core = jnp.concatenate(
        [
            jnp.concatenate([beta * jnp.diag(s) - utgv, eye], axis=1),
            jnp.concatenate([eye, zero], axis=1),
        ],
        axis=0,
    )
    s_mat = ru @ core @ rv.T                                     # 2r×2r
    us, ss, vs = jacobi_svd(s_mat)
    u2 = uq @ us[:, :r]
    v2 = vq @ vs[:, :r]
    s2 = ss[:r]
    w2 = rank_r_update(w, u2, v2, eta)
    return w2, u2, s2, v2


def mofasgd_step(w, u, s, v, g, eta, beta):
    """One full MoFaSGD step from a full-rank gradient (Alg. 1)."""
    gv, utg, utgv = tangent_project(g, u, v)
    return umf_core(w, u, s, v, gv, utg, utgv, eta, beta)


def mofasgd_accum(g, u, v, b_gv, b_utg, b_utgv):
    """Fused low-rank gradient accumulation across micro-batches (§5.5)."""
    return lowrank_accum(g, u, v, b_gv, b_utg, b_utgv)


def mofasgd_step_from_buf(w, u, s, v, b_gv, b_utg, b_utgv, eta, beta, scale):
    """MoFaSGD step from accumulated low-rank buffers; G is never formed.

    `scale` is 1/num_microbatches so buffers hold the mean gradient's
    projections (projection is linear in G with U, V frozen in-window).
    """
    return umf_core(w, u, s, v, scale * b_gv, scale * b_utg, scale * b_utgv,
                    eta, beta)


def mofasgd_init(g, omega):
    """Momentum-factor initialization: SVD_r of the first gradient (§5.5)."""
    return svd_lowrank(g, omega, iters=2)


def mofasgd_step_naive(w, u, s, v, g, eta, beta, omega):
    """Ablation baseline: M̂_t = SVD_r(β M̂_{t-1} + Ĝ_t) via a fresh
    randomized SVD of the densified momentum — the expensive update UMF
    avoids (paper §4.1 "a naive update"). Used by bench_umf.
    """
    gv, utg, utgv = tangent_project(g, u, v)
    g_hat = u @ utg + gv @ v.T - u @ (utgv @ v.T)
    m_dense = beta * (u @ (s[:, None] * v.T)) + g_hat
    u2, s2, v2 = svd_lowrank(m_dense, omega, iters=2)
    w2 = rank_r_update(w, u2, v2, eta)
    return w2, u2, s2, v2


# ---------------------------------------------------------------------------
# GaLore (Zhao et al. 2024a) — subspace projection + Adam-in-subspace
# ---------------------------------------------------------------------------

def galore_step(w, q, m, vv, g, eta, t, b1, b2):
    """GaLore update: project, Adam moments in the subspace, project back.

    q: (m×r) left-subspace; m, vv: (r×n) subspace moments; t: step (f32,
    1-based) for bias correction.
    """
    gr = q.T @ g
    m2 = b1 * m + (1.0 - b1) * gr
    v2 = b2 * vv + (1.0 - b2) * gr * gr
    mhat = m2 / (1.0 - b1 ** t)
    vhat = v2 / (1.0 - b2 ** t)
    w2 = w - eta * (q @ (mhat / (jnp.sqrt(vhat) + _ADAM_EPS)))
    return w2, m2, v2


def galore_accum(g, q, buf):
    """Fused low-rank gradient accumulation for GaLore (§5.5): only QᵀG is
    needed by the subspace moments, so the buffer is r×n."""
    return buf + q.T @ g


def galore_step_from_buf(w, q, m, vv, buf, eta, t, b1, b2, scale):
    gr = scale * buf
    m2 = b1 * m + (1.0 - b1) * gr
    v2 = b2 * vv + (1.0 - b2) * gr * gr
    mhat = m2 / (1.0 - b1 ** t)
    vhat = v2 / (1.0 - b2 ** t)
    w2 = w - eta * (q @ (mhat / (jnp.sqrt(vhat) + _ADAM_EPS)))
    return w2, m2, v2


def galore_resample(g, omega):
    """Offline subspace refresh: Q ← top-r left singular vectors of G.

    The paper's full SVD is replaced by randomized subspace iteration
    (2 power iterations) — same O(mnr) asymptotics as GaLore's cost model
    once r ≪ min(m,n), same subspace up to noise the paper's τ-ablation
    already tolerates.
    """
    return rand_range(g, omega, iters=2)


# ---------------------------------------------------------------------------
# Full-rank baselines
# ---------------------------------------------------------------------------

def adamw_step(w, m, vv, g, eta, t, b1, b2, wd):
    """AdamW (decoupled weight decay), any parameter shape."""
    m2 = b1 * m + (1.0 - b1) * g
    v2 = b2 * vv + (1.0 - b2) * g * g
    mhat = m2 / (1.0 - b1 ** t)
    vhat = v2 / (1.0 - b2 ** t)
    w2 = w - eta * (mhat / (jnp.sqrt(vhat) + _ADAM_EPS) + wd * w)
    return w2, m2, v2


def muon_step(w, m, g, eta, beta):
    """Muon: full-rank momentum + Newton-Schulz orthogonalization.

    The full-rank counterpart MoFaSGD factorizes (paper §1: "a low-rank
    variant of Muon"); O(mn) state.
    """
    m2 = beta * m + g
    o = newton_schulz(m2, steps=5)
    return w - eta * o, m2


def lion_step(w, m, g, eta, b1, b2, wd):
    """Lion (Chen et al. 2024): sign of interpolated momentum."""
    upd = jnp.sign(b1 * m + (1.0 - b1) * g)
    m2 = b2 * m + (1.0 - b2) * g
    return w - eta * (upd + wd * w), m2


def sgdm_step(w, m, g, eta, beta):
    m2 = beta * m + g
    return w - eta * m2, m2


def signsgd_step(w, g, eta):
    """signSGD (Bernstein et al. 2018): stateless sign descent."""
    return w - eta * jnp.sign(g)


def adafactor_step(w, r_acc, c_acc, g, eta, b2):
    """Adafactor-style factored second moment (O(m+n) state), matrices only.

    r_acc: (m,), c_acc: (n,) running row/col second-moment factors.
    """
    g2 = g * g + 1e-30
    r2 = b2 * r_acc + (1.0 - b2) * jnp.mean(g2, axis=1)
    c2 = b2 * c_acc + (1.0 - b2) * jnp.mean(g2, axis=0)
    denom = jnp.sqrt(jnp.outer(r2, c2) / (jnp.mean(r2) + 1e-30)) + _ADAM_EPS
    return w - eta * g / denom, r2, c2

"""AOT lowering driver: JAX/Pallas graphs → artifacts/*.hlo.txt + manifest.

Run once at build time (`make artifacts`); Python never appears on the
request path. Every entry point is lowered to **HLO text** — never
``lowered.compile()`` / proto ``.serialize()`` — because jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that the Rust side's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

`artifacts/manifest.json` is the contract with the Rust runtime: for every
artifact it records the ordered input/output descriptors (name, shape,
dtype) plus semantic tags (kind, shape key, rank, config), and for every
model config the full parameter spec in canonical order.

Usage: python -m compile.aot [--out-dir ../artifacts] [--force]
                             [--configs gpt_tiny,gpt_small,enc_glue]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import optim_jnp as O
from .configs import (CONFIGS, LORA_RANKS, RANKS, lora_spec, matrix_shapes,
                      n_params, nonmatrix_shapes, param_spec)

F32, I32 = jnp.float32, jnp.int32

DEFAULT_CONFIGS = ["gpt_tiny", "gpt_small", "enc_glue"]


def sds(shape, dt=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dt)


def _dtype_str(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(dt).name]


class Entry:
    """One artifact: a callable plus its example-argument signature."""

    def __init__(self, name, fn, args, input_names, output_names, tags):
        self.name = name
        self.fn = fn
        self.args = args
        self.input_names = input_names
        self.output_names = output_names
        self.tags = tags

    def describe(self) -> dict:
        outs = jax.eval_shape(self.fn, *self.args)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        assert len(outs) == len(self.output_names), self.name
        return {
            "name": self.name,
            "file": f"{self.name}.hlo.txt",
            "inputs": [
                {"name": n, "shape": list(a.shape), "dtype": _dtype_str(a.dtype)}
                for n, a in zip(self.input_names, self.args)
            ],
            "outputs": [
                {"name": n, "shape": list(o.shape), "dtype": _dtype_str(o.dtype)}
                for n, o in zip(self.output_names, outs)
            ],
            "tags": self.tags,
        }

    def lower_to_text(self) -> str:
        lowered = jax.jit(self.fn).lower(*self.args)
        mlir_mod = lowered.compiler_ir("stablehlo")
        comp = xc._xla.mlir.mlir_module_to_xla_computation(
            str(mlir_mod), use_tuple_args=False, return_tuple=True
        )
        return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Entry builders
# ---------------------------------------------------------------------------

def _shape_key(m: int, n: int) -> str:
    return f"{m}x{n}"


def optimizer_entries(shape_rank_pairs, matrix_only_shapes, all_shapes,
                      naive_pairs) -> list[Entry]:
    """Per-weight-shape optimizer step artifacts (shared across configs)."""
    es: list[Entry] = []
    for (m, n), r in shape_rank_pairs:
        key = f"{_shape_key(m, n)}_r{r}"
        w, g = sds((m, n)), sds((m, n))
        u, s, v = sds((m, r)), sds((r,)), sds((n, r))
        gv, utg, utgv = sds((m, r)), sds((r, n)), sds((r, r))
        sc = sds(())
        tags = {"m": m, "n": n, "r": r}
        es.append(Entry(
            f"mofasgd_step_{key}", O.mofasgd_step,
            [w, u, s, v, g, sc, sc],
            ["w", "u", "s", "v", "g", "eta", "beta"],
            ["w", "u", "s", "v"], {"kind": "mofasgd_step", **tags}))
        es.append(Entry(
            f"mofasgd_accum_{key}", O.mofasgd_accum,
            [g, u, v, gv, utg, utgv],
            ["g", "u", "v", "b_gv", "b_utg", "b_utgv"],
            ["b_gv", "b_utg", "b_utgv"], {"kind": "mofasgd_accum", **tags}))
        es.append(Entry(
            f"mofasgd_step_from_buf_{key}", O.mofasgd_step_from_buf,
            [w, u, s, v, gv, utg, utgv, sc, sc, sc],
            ["w", "u", "s", "v", "b_gv", "b_utg", "b_utgv", "eta", "beta",
             "scale"],
            ["w", "u", "s", "v"], {"kind": "mofasgd_step_from_buf", **tags}))
        es.append(Entry(
            f"mofasgd_init_{key}", O.mofasgd_init,
            [g, sds((n, r))], ["g", "omega"],
            ["u", "s", "v"], {"kind": "mofasgd_init", **tags}))
        mr, vr, buf = sds((r, n)), sds((r, n)), sds((r, n))
        q = sds((m, r))
        es.append(Entry(
            f"galore_step_{key}", O.galore_step,
            [w, q, mr, vr, g, sc, sc, sc, sc],
            ["w", "q", "m", "v", "g", "eta", "t", "b1", "b2"],
            ["w", "m", "v"], {"kind": "galore_step", **tags}))
        es.append(Entry(
            f"galore_accum_{key}", O.galore_accum,
            [g, q, buf], ["g", "q", "buf"],
            ["buf"], {"kind": "galore_accum", **tags}))
        es.append(Entry(
            f"galore_step_from_buf_{key}", O.galore_step_from_buf,
            [w, q, mr, vr, buf, sc, sc, sc, sc, sc],
            ["w", "q", "m", "v", "buf", "eta", "t", "b1", "b2", "scale"],
            ["w", "m", "v"], {"kind": "galore_step_from_buf", **tags}))
        es.append(Entry(
            f"galore_resample_{key}", O.galore_resample,
            [g, sds((n, r))], ["g", "omega"],
            ["q"], {"kind": "galore_resample", **tags}))
    for (m, n), r in naive_pairs:
        key = f"{_shape_key(m, n)}_r{r}"
        w, g = sds((m, n)), sds((m, n))
        u, s, v = sds((m, r)), sds((r,)), sds((n, r))
        sc = sds(())
        es.append(Entry(
            f"mofasgd_step_naive_{key}", O.mofasgd_step_naive,
            [w, u, s, v, g, sc, sc, sds((n, r))],
            ["w", "u", "s", "v", "g", "eta", "beta", "omega"],
            ["w", "u", "s", "v"],
            {"kind": "mofasgd_step_naive", "m": m, "n": n, "r": r}))
    for m, n in matrix_only_shapes:
        key = _shape_key(m, n)
        w, g, mm = sds((m, n)), sds((m, n)), sds((m, n))
        sc = sds(())
        tags = {"m": m, "n": n}
        es.append(Entry(
            f"muon_step_{key}", O.muon_step,
            [w, mm, g, sc, sc], ["w", "m", "g", "eta", "beta"],
            ["w", "m"], {"kind": "muon_step", **tags}))
        es.append(Entry(
            f"lion_step_{key}", O.lion_step,
            [w, mm, g, sc, sc, sc, sc],
            ["w", "m", "g", "eta", "b1", "b2", "wd"],
            ["w", "m"], {"kind": "lion_step", **tags}))
        es.append(Entry(
            f"sgdm_step_{key}", O.sgdm_step,
            [w, mm, g, sc, sc], ["w", "m", "g", "eta", "beta"],
            ["w", "m"], {"kind": "sgdm_step", **tags}))
        es.append(Entry(
            f"signsgd_step_{key}", O.signsgd_step,
            [w, g, sc], ["w", "g", "eta"],
            ["w"], {"kind": "signsgd_step", **tags}))
        es.append(Entry(
            f"adafactor_step_{key}", O.adafactor_step,
            [w, sds((m,)), sds((n,)), g, sc, sc],
            ["w", "r_acc", "c_acc", "g", "eta", "b2"],
            ["w", "r_acc", "c_acc"], {"kind": "adafactor_step", **tags}))
    for shape in all_shapes:
        key = "x".join(str(d) for d in shape)
        w, g, mm, vv = sds(shape), sds(shape), sds(shape), sds(shape)
        sc = sds(())
        es.append(Entry(
            f"adamw_step_{key}", O.adamw_step,
            [w, mm, vv, g, sc, sc, sc, sc, sc],
            ["w", "m", "v", "g", "eta", "t", "b1", "b2", "wd"],
            ["w", "m", "v"],
            {"kind": "adamw_step", "shape": list(shape)}))
    return es


def model_entries(cfg_name: str) -> list[Entry]:
    cfg = CONFIGS[cfg_name]
    spec = param_spec(cfg)
    b, t = cfg["batch"], cfg["seq"]
    params = [sds(shape) for _, shape in spec]
    pnames = [name for name, _ in spec]
    tokens = sds((b, t), I32)
    if cfg["kind"] == "lm":
        labels = sds((b, t), I32)
        lbl_name = "targets"
    else:
        labels = sds((b,), I32)
        lbl_name = "labels"
    es = [
        Entry(f"{cfg_name}_loss_and_grads", M.loss_and_grads(cfg),
              params + [tokens, labels],
              pnames + ["tokens", lbl_name],
              ["loss"] + [f"g:{n}" for n in pnames],
              {"kind": "loss_and_grads", "config": cfg_name}),
        Entry(f"{cfg_name}_eval_loss", M.eval_loss(cfg),
              params + [tokens, labels],
              pnames + ["tokens", lbl_name],
              ["loss"], {"kind": "eval_loss", "config": cfg_name}),
    ]
    if cfg["kind"] == "lm":
        es.append(Entry(
            f"{cfg_name}_last_logits", M.last_logits(cfg),
            params + [tokens], pnames + ["tokens"],
            ["logits"], {"kind": "last_logits", "config": cfg_name}))
        es.append(Entry(
            f"{cfg_name}_token_correct", M.token_correct(cfg),
            params + [tokens, labels], pnames + ["tokens", lbl_name],
            ["correct"], {"kind": "token_correct", "config": cfg_name}))
    else:
        es.append(Entry(
            f"{cfg_name}_cls_logits", M.cls_logits(cfg),
            params + [tokens], pnames + ["tokens"],
            ["logits"], {"kind": "cls_logits", "config": cfg_name}))
    for r in LORA_RANKS.get(cfg_name, []):
        alpha = 2.0 * r  # paper Table 7: alpha = 16 at r = 8
        aspec = lora_spec(cfg, r)
        adapters = [sds(shape) for _, shape in aspec]
        anames = [name for name, _ in aspec]
        es.append(Entry(
            f"{cfg_name}_lora_r{r}_loss_and_grads",
            M.lora_loss_and_grads(cfg, r, alpha),
            adapters + params + [tokens, labels],
            anames + pnames + ["tokens", lbl_name],
            ["loss"] + [f"g:{n}" for n in anames],
            {"kind": "lora_loss_and_grads", "config": cfg_name, "r": r,
             "alpha": alpha}))
        es.append(Entry(
            f"{cfg_name}_lora_r{r}_eval_loss",
            M.lora_eval_loss(cfg, r, alpha),
            adapters + params + [tokens, labels],
            anames + pnames + ["tokens", lbl_name],
            ["loss"],
            {"kind": "lora_eval_loss", "config": cfg_name, "r": r,
             "alpha": alpha}))
    return es


def build_entries(config_names) -> list[Entry]:
    pairs: list[tuple[tuple[int, int], int]] = []
    mat_shapes: list[tuple[int, int]] = []
    all_shapes: list[tuple[int, ...]] = []
    for cn in config_names:
        cfg = CONFIGS[cn]
        for shp in matrix_shapes(cfg):
            if shp not in mat_shapes:
                mat_shapes.append(shp)
            for r in RANKS[cn]:
                if (shp, r) not in pairs:
                    pairs.append((shp, r))
        for shp in param_spec(cfg):
            if tuple(shp[1]) not in all_shapes:
                all_shapes.append(tuple(shp[1]))
    # UMF-vs-naive ablation artifacts (bench_umf): one tall shape, two ranks.
    naive_pairs = [p for p in pairs
                   if p[0] == (256, 1024) and p[1] in (8, 32)]
    es = optimizer_entries(pairs, mat_shapes, all_shapes, naive_pairs)
    for cn in config_names:
        es += model_entries(cn)
    return es


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--configs", default=",".join(DEFAULT_CONFIGS))
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the .hlo.txt already exists")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    config_names = [c for c in args.configs.split(",") if c]

    entries = build_entries(config_names)
    t0 = time.time()
    manifest = {
        "version": 1,
        "configs": {
            cn: {
                **{k: v for k, v in CONFIGS[cn].items()},
                "params": [
                    {"name": n, "shape": list(s)}
                    for n, s in param_spec(CONFIGS[cn])
                ],
                "n_params": n_params(CONFIGS[cn]),
                "ranks": RANKS[cn],
                "lora_ranks": LORA_RANKS.get(cn, []),
                "matrix_shapes": [list(s) for s in matrix_shapes(CONFIGS[cn])],
                "nonmatrix_shapes": [
                    list(s) for s in nonmatrix_shapes(CONFIGS[cn])],
            }
            for cn in config_names
        },
        "artifacts": [],
    }
    n_lowered = 0
    for i, e in enumerate(entries):
        manifest["artifacts"].append(e.describe())
        path = os.path.join(out_dir, f"{e.name}.hlo.txt")
        if os.path.exists(path) and not args.force:
            continue
        text = e.lower_to_text()
        with open(path, "w") as f:
            f.write(text)
        n_lowered += 1
        if n_lowered % 20 == 0:
            print(f"[aot] {i + 1}/{len(entries)} lowered "
                  f"({time.time() - t0:.0f}s)", flush=True)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(entries)} artifact descriptors "
          f"({n_lowered} lowered, {len(entries) - n_lowered} cached) "
          f"to {out_dir} in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()

"""Pallas kernels for MoFaSGD's per-step hot spot.

Two O(mnr) operations dominate Algorithm 1 — everything else is
O((m+n)r² + r³):

  * ``tangent_project``  — the tangent-space interactions (G·V, Uᵀ·G, Uᵀ·G·V)
    computed in a single fused pass over G (Alg. 1 line 1);
  * ``rank_r_update``    — the spectrally normalized weight update
    W ← W − η·U·Vᵀ (Eq. 9), fused so no full UVᵀ temporary survives the
    kernel.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles G into
(bm×bn) = (128×128) f32 VMEM blocks with the factor slabs (128×r) resident
alongside; each grid step issues three MXU-shaped contractions. Revisited
output blocks implement the k-dimension accumulation that CUDA kernels
would express with threadblock-local accumulators.

Kernels are executed with ``interpret=True`` everywhere in this repo: the
CPU PJRT runtime cannot run Mosaic custom-calls, and interpret-mode lowers
the identical schedule to plain HLO so it round-trips through HLO text.
Correctness oracle: ``kernels/ref.py`` (pytest + hypothesis).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_TILE = 128


def _block(dim: int) -> int:
    """VMEM tile size: 128 when the dim is tile-aligned, else one block."""
    return _TILE if dim % _TILE == 0 else dim


def _proj_kernel(g_ref, u_ref, v_ref, gv_ref, utg_ref, utgv_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init_gv():
        gv_ref[...] = jnp.zeros_like(gv_ref)

    @pl.when(i == 0)
    def _init_utg():
        utg_ref[...] = jnp.zeros_like(utg_ref)

    @pl.when((i == 0) & (j == 0))
    def _init_utgv():
        utgv_ref[...] = jnp.zeros_like(utgv_ref)

    g = g_ref[...]
    u = u_ref[...]
    v = v_ref[...]
    gv = g @ v                    # (bm, r)   MXU contraction over bn
    utg = u.T @ g                 # (r, bn)   MXU contraction over bm
    gv_ref[...] += gv
    utg_ref[...] += utg
    utgv_ref[...] += u.T @ gv     # (r, r)    reuses the gv block in-register


def tangent_project(g, u, v):
    """Fused (G·V, Uᵀ·G, Uᵀ·G·V) in one tiled pass over G.

    g: (m, n), u: (m, r), v: (n, r) -> ((m, r), (r, n), (r, r)).
    """
    m, n = g.shape
    r = u.shape[1]
    bm, bn = _block(m), _block(n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _proj_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),
            pl.BlockSpec((r, r), lambda i, j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, r), g.dtype),
            jax.ShapeDtypeStruct((r, n), g.dtype),
            jax.ShapeDtypeStruct((r, r), g.dtype),
        ],
        interpret=True,
    )(g, u, v)


def _update_kernel(w_ref, u_ref, v_ref, eta_ref, o_ref):
    o_ref[...] = w_ref[...] - eta_ref[0, 0] * (u_ref[...] @ v_ref[...].T)


def rank_r_update(w, u, v, eta):
    """Spectral update W − η·U·Vᵀ, tiled; η is a runtime scalar.

    w: (m, n), u: (m, r), v: (n, r), eta: scalar -> (m, n).
    """
    m, n = w.shape
    r = u.shape[1]
    bm, bn = _block(m), _block(n)
    eta_arr = jnp.reshape(eta.astype(w.dtype), (1, 1))
    return pl.pallas_call(
        _update_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), w.dtype),
        interpret=True,
    )(w, u, v, eta_arr)


def lowrank_accum(g, u, v, b_gv, b_utg, b_utgv):
    """Fused low-rank gradient accumulation (paper §5.5).

    Adds this micro-batch's tangent projections into the persistent
    low-rank buffers, so no full-rank gradient buffer survives across
    micro-batches. Linearity of the projection in G makes summing
    projections identical to projecting the summed gradient (U, V are
    frozen across the accumulation window).
    """
    gv, utg, utgv = tangent_project(g, u, v)
    return b_gv + gv, b_utg + utg, b_utgv + utgv

"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

pytest (python/tests/test_kernels.py) sweeps shapes/dtypes with hypothesis
and asserts allclose between kernels.tangent and these references.
"""

from __future__ import annotations

import jax.numpy as jnp


def tangent_project_ref(g, u, v):
    gv = g @ v
    utg = u.T @ g
    return gv, utg, utg @ v


def rank_r_update_ref(w, u, v, eta):
    return w - eta * (u @ v.T)


def lowrank_accum_ref(g, u, v, b_gv, b_utg, b_utgv):
    gv, utg, utgv = tangent_project_ref(g, u, v)
    return b_gv + gv, b_utg + utg, b_utgv + utgv


def tangent_space_projection_ref(g, u, v):
    """Full-rank Proj_T(G) = UUᵀG + GVVᵀ − UUᵀGVVᵀ (paper Eq. 6/7).

    Never materialized by the optimizer (that is the point of the paper);
    used in tests to check the factored update against the definition.
    """
    uug = u @ (u.T @ g)
    gvv = (g @ v) @ v.T
    return uug + gvv - u @ ((u.T @ g) @ v) @ v.T

"""Layer-1 Pallas kernels (build-time only; lowered with interpret=True)."""

from .tangent import lowrank_accum, rank_r_update, tangent_project  # noqa: F401

"""Model configurations and parameter-shape enumeration.

Single source of truth shared by the L2 model, the AOT lowering driver, and
(via artifacts/manifest.json) the Rust coordinator. Shapes here are chosen
so every transformer linear is a multiple of 128 (the Pallas VMEM tile),
mirroring how the paper applies MoFaSGD only to transformer linear layers
(paper §5.5) while embeddings / 1-D params are handled by AdamW.
"""

from __future__ import annotations

# kind: "lm" = causal decoder LM (NanoGPT-speedrun stand-in, paper §5.1)
#       "cls" = bidirectional encoder + classification head (GLUE stand-in,
#                paper §5.2 Table 3)
CONFIGS = {
    "gpt_tiny": dict(kind="lm", vocab=256, d=128, layers=2, heads=4, seq=128,
                     mlp=4, batch=8),
    "gpt_small": dict(kind="lm", vocab=512, d=256, layers=4, heads=8, seq=256,
                      mlp=4, batch=8),
    "gpt_med": dict(kind="lm", vocab=4096, d=512, layers=8, heads=8, seq=512,
                    mlp=4, batch=4),
    "enc_glue": dict(kind="cls", vocab=256, d=128, layers=2, heads=4, seq=64,
                     mlp=4, batch=16, ncls=4),
}

# Ranks for which low-rank optimizer artifacts are built, per config.
# Table 1 sweeps r ∈ {16,32,128}; Tables 3/4 use r ∈ {4,8}.
RANKS = {
    "gpt_tiny": [4, 8],
    "gpt_small": [8, 16, 32, 128],
    "gpt_med": [32],
    "enc_glue": [4, 8],
}

# LoRA adapter ranks (Table 3/4 baselines).
LORA_RANKS = {
    "gpt_tiny": [8],
    "gpt_small": [8],
    "enc_glue": [4, 8],
}


def param_spec(cfg: dict) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list — the canonical flat parameter order.

    The Rust side replicates this order from manifest.json; any change here
    is an artifact-format change.
    """
    d, v, s, L = cfg["d"], cfg["vocab"], cfg["seq"], cfg["layers"]
    h = cfg["mlp"] * d
    spec: list[tuple[str, tuple[int, ...]]] = [
        ("tok_emb", (v, d)),
        ("pos_emb", (s, d)),
    ]
    for i in range(L):
        spec += [
            (f"l{i}.ln1", (d,)),
            (f"l{i}.qkv", (d, 3 * d)),
            (f"l{i}.proj", (d, d)),
            (f"l{i}.ln2", (d,)),
            (f"l{i}.fc1", (d, h)),
            (f"l{i}.fc2", (h, d)),
        ]
    spec.append(("lnf", (d,)))
    if cfg["kind"] == "cls":
        spec.append(("head", (d, cfg["ncls"])))
    return spec


def matrix_params(cfg: dict) -> list[tuple[str, tuple[int, int]]]:
    """The 2-D transformer-block linears MoFaSGD/GaLore/Muon apply to.

    Embeddings, norms, and the classification head are excluded and routed
    to AdamW by the coordinator, following paper §5.5.
    """
    out = []
    for name, shape in param_spec(cfg):
        if len(shape) == 2 and name.startswith("l"):
            out.append((name, shape))
    return out


def matrix_shapes(cfg: dict) -> list[tuple[int, int]]:
    """Deduplicated matrix shapes (artifact granularity for optimizer steps)."""
    seen: list[tuple[int, int]] = []
    for _, shape in matrix_params(cfg):
        if shape not in seen:
            seen.append(shape)
    return seen


def nonmatrix_shapes(cfg: dict) -> list[tuple[int, ...]]:
    """Shapes routed to AdamW (embeddings, norm scales, heads)."""
    mats = {s for s in matrix_shapes(cfg)}
    seen: list[tuple[int, ...]] = []
    for name, shape in param_spec(cfg):
        is_matrix = len(shape) == 2 and name.startswith("l") and shape in mats
        if not is_matrix and shape not in seen:
            seen.append(shape)
    return seen


def lora_spec(cfg: dict, r: int) -> list[tuple[str, tuple[int, int]]]:
    """Ordered adapter (name, shape) list: A (m×r) then B (r×n) per linear."""
    out = []
    for name, (m, n) in matrix_params(cfg):
        out.append((f"{name}.A", (m, r)))
        out.append((f"{name}.B", (r, n)))
    return out


def n_params(cfg: dict) -> int:
    total = 0
    for _, shape in param_spec(cfg):
        k = 1
        for s in shape:
            k *= s
        total += k
    return total

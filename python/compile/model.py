"""Layer-2 model: GPT-style decoder LM + encoder classifier, in pure JAX.

The compute graphs lowered to HLO artifacts for the Rust coordinator:
  * ``loss_and_grads``       — fused fwd+bwd for full-parameter training
  * ``lora_loss_and_grads``  — fwd+bwd w.r.t. LoRA adapters only (Table 3/4
                               baseline; base weights are frozen inputs)
  * ``eval_loss``            — validation loss / perplexity
  * ``last_logits``          — final-position logits for greedy decoding
  * ``cls_logits``           — classifier logits (GLUE-proxy accuracy)

Architecture follows the Modded-NanoGPT speedrun family the paper
benchmarks on (§5.1): pre-RMSNorm, bias-free linears, GELU MLP, learned
positions, tied LM head. Parameter order is `configs.param_spec` — the
contract with artifacts/manifest.json and the Rust side.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import lora_spec, matrix_params, param_spec

_NORM_EPS = 1e-6


# ---------------------------------------------------------------------------
# Parameter packing
# ---------------------------------------------------------------------------

def unflatten(cfg: dict, flat) -> dict:
    spec = param_spec(cfg)
    assert len(flat) == len(spec), (len(flat), len(spec))
    return {name: arr for (name, _), arr in zip(spec, flat)}


def flatten(cfg: dict, params: dict) -> list:
    return [params[name] for name, _ in param_spec(cfg)]


def init_params(cfg: dict, seed: int = 0) -> list:
    """He-style init, matching the Rust coordinator's initializer layout."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            out.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0]
            std = 0.02 if "emb" in name else 1.0 / jnp.sqrt(fan_in)
            out.append(std * jax.random.normal(sub, shape, jnp.float32))
    return out


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _rmsnorm(x, scale):
    rms = jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + _NORM_EPS)
    return x / rms * scale


def _attention(x, w_qkv, w_proj, heads: int, causal: bool):
    b, t, d = x.shape
    hd = d // heads
    qkv = x @ w_qkv                                  # (b, t, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads_first(z):
        return z.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads_first(q), heads_first(k), heads_first(v)
    scores = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
    if causal:
        mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
        scores = jnp.where(mask[None, None], scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ w_proj


def _block(x, p: dict, i: int, heads: int, causal: bool):
    h = _rmsnorm(x, p[f"l{i}.ln1"])
    x = x + _attention(h, p[f"l{i}.qkv"], p[f"l{i}.proj"], heads, causal)
    h = _rmsnorm(x, p[f"l{i}.ln2"])
    h = jax.nn.gelu(h @ p[f"l{i}.fc1"], approximate=True) @ p[f"l{i}.fc2"]
    return x + h


def _trunk(cfg: dict, p: dict, tokens):
    """Embed + transformer blocks; returns final hidden states (b, t, d)."""
    t = tokens.shape[1]
    causal = cfg["kind"] == "lm"
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :t]
    for i in range(cfg["layers"]):
        x = _block(x, p, i, cfg["heads"], causal)
    return _rmsnorm(x, p["lnf"])


def lm_loss(cfg: dict, p: dict, tokens, targets):
    """Mean next-token cross-entropy; logits via tied embedding head."""
    h = _trunk(cfg, p, tokens)                        # (b, t, d)
    logits = h @ p["tok_emb"].T                       # (b, t, v)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def cls_loss(cfg: dict, p: dict, tokens, labels):
    h = jnp.mean(_trunk(cfg, p, tokens), axis=1)      # (b, d) mean-pool
    logits = h @ p["head"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def _loss(cfg, p, tokens, labels):
    return lm_loss(cfg, p, tokens, labels) if cfg["kind"] == "lm" \
        else cls_loss(cfg, p, tokens, labels)


# ---------------------------------------------------------------------------
# Flat-signature artifact entry points
# ---------------------------------------------------------------------------

def loss_and_grads(cfg: dict):
    """(param_0..param_k, tokens, labels) -> (loss, grad_0..grad_k)."""
    def fn(*args):
        flat, tokens, labels = list(args[:-2]), args[-2], args[-1]
        p = unflatten(cfg, flat)
        loss, grads = jax.value_and_grad(
            lambda pp: _loss(cfg, pp, tokens, labels))(p)
        return (loss, *flatten(cfg, grads))
    return fn


def eval_loss(cfg: dict):
    def fn(*args):
        flat, tokens, labels = list(args[:-2]), args[-2], args[-1]
        return (_loss(cfg, unflatten(cfg, flat), tokens, labels),)
    return fn


def last_logits(cfg: dict):
    """Final-position LM logits for greedy decoding (instruction-tune eval)."""
    def fn(*args):
        flat, tokens = list(args[:-1]), args[-1]
        p = unflatten(cfg, flat)
        h = _trunk(cfg, p, tokens)
        return (h[:, -1] @ p["tok_emb"].T,)
    return fn


def token_correct(cfg: dict):
    """Teacher-forced greedy correctness map: (params, tokens, targets) ->
    (B, T) float {0,1} whether argmax(logits) == target at each position.

    One forward pass scores a whole batch of instruction examples; the Rust
    side reduces answer spans to exact-match rates (Table 4 eval) without
    autoregressive decoding.
    """
    def fn(*args):
        flat, tokens, targets = list(args[:-2]), args[-2], args[-1]
        p = unflatten(cfg, flat)
        h = _trunk(cfg, p, tokens)
        logits = h @ p["tok_emb"].T
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return ((pred == targets).astype(jnp.float32),)
    return fn


def cls_logits(cfg: dict):
    def fn(*args):
        flat, tokens = list(args[:-1]), args[-1]
        p = unflatten(cfg, flat)
        h = jnp.mean(_trunk(cfg, p, tokens), axis=1)
        return (h @ p["head"],)
    return fn


# ---------------------------------------------------------------------------
# LoRA (Hu et al. 2021) — frozen base, rank-r adapters on every 2-D linear
# ---------------------------------------------------------------------------

def _merge_lora(cfg: dict, base: dict, adapters: list, r: int, alpha: float):
    merged = dict(base)
    names = [n for n, _ in lora_spec(cfg, r)]
    ad = {name: arr for name, arr in zip(names, adapters)}
    for name, _ in matrix_params(cfg):
        a, b = ad[f"{name}.A"], ad[f"{name}.B"]
        merged[name] = base[name] + (alpha / r) * (a @ b)
    return merged


def lora_loss_and_grads(cfg: dict, r: int, alpha: float):
    """(adapter_0.., base_0.., tokens, labels) -> (loss, adapter_grads..).

    Base weights are runtime inputs (not baked constants) so one artifact
    serves any checkpoint; only adapters receive gradients.
    """
    n_ad = len(lora_spec(cfg, r))
    n_base = len(param_spec(cfg))

    def fn(*args):
        adapters = list(args[:n_ad])
        base = unflatten(cfg, list(args[n_ad:n_ad + n_base]))
        tokens, labels = args[-2], args[-1]

        def f(ads):
            return _loss(cfg, _merge_lora(cfg, base, ads, r, alpha),
                         tokens, labels)

        loss, grads = jax.value_and_grad(f)(adapters)
        return (loss, *grads)
    return fn


def lora_eval_loss(cfg: dict, r: int, alpha: float):
    n_ad = len(lora_spec(cfg, r))
    n_base = len(param_spec(cfg))

    def fn(*args):
        adapters = list(args[:n_ad])
        base = unflatten(cfg, list(args[n_ad:n_ad + n_base]))
        tokens, labels = args[-2], args[-1]
        return (_loss(cfg, _merge_lora(cfg, base, adapters, r, alpha),
                      tokens, labels),)
    return fn

"""LAPACK-free linear-algebra building blocks for lowered artifacts.

The xla_extension 0.5.1 CPU runtime used by the Rust `xla` crate cannot
resolve jaxlib's `lapack_*_ffi` custom-calls, so `jnp.linalg.{qr,svd}` must
never appear inside an artifact. Everything here lowers to plain HLO
(dots, loops, elementwise) and therefore round-trips through HLO text.

Provided:
  * cgs2_qr          — classical Gram-Schmidt with reorthogonalization
                       (tall-skinny QR; the paper's QR([U GV]) step)
  * jacobi_svd       — one-sided Jacobi SVD (the 2r×2r core SVD of Alg. 1,
                       also used rectangularly for randomized SVD)
  * rand_range       — randomized subspace iteration (top-r range of G;
                       the SVD_r(G0) initialization and GaLore resampling)
  * svd_lowrank      — rank-r randomized SVD built from the two above
  * newton_schulz    — Muon's odd-polynomial orthogonalization
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def cgs2_qr(a):
    """QR of a (m×k) with k small, via classical Gram-Schmidt applied twice.

    CGS2 ("twice is enough") restores orthogonality to machine precision for
    the well-conditioned tall-skinny panels MoFaSGD produces. Rank-deficient
    columns yield a zero q-column and a ~0 diagonal R entry, which keeps the
    reconstruction A = Q R exact and is benign downstream (the Jacobi SVD
    sees a correspondingly tiny singular value).

    Returns (Q m×k, R k×k upper-triangular).
    """
    m, k = a.shape

    def body(j, state):
        q_mat, r_mat = state
        v = jax.lax.dynamic_slice(a, (0, j), (m, 1))
        # First CGS pass (columns >= j of q_mat are still zero).
        h1 = q_mat.T @ v
        v1 = v - q_mat @ h1
        # Reorthogonalization pass.
        h2 = q_mat.T @ v1
        v2 = v1 - q_mat @ h2
        h = h1 + h2
        nrm = jnp.sqrt(jnp.sum(v2 * v2))
        q_col = v2 / jnp.maximum(nrm, _EPS)
        # Zero the column entirely when numerically rank deficient.
        q_col = jnp.where(nrm > 1e-10, q_col, jnp.zeros_like(q_col))
        q_mat = jax.lax.dynamic_update_slice(q_mat, q_col, (0, j))
        r_mat = jax.lax.dynamic_update_slice(r_mat, h, (0, j))
        r_mat = r_mat.at[j, j].set(nrm)
        return q_mat, r_mat

    q0 = jnp.zeros((m, k), a.dtype)
    r0 = jnp.zeros((k, k), a.dtype)
    return jax.lax.fori_loop(0, k, body, (q0, r0))


def _round_robin_schedule(k: int):
    """Tournament pairings: k-1 rounds of k/2 disjoint pairs covering all
    (i, j) pairs once per sweep (circle method, element 0 fixed)."""
    assert k % 2 == 0
    players = list(range(k))
    rounds = []
    for _ in range(k - 1):
        left = [players[0]] + players[1:k // 2]
        right = players[k // 2:][::-1]
        rounds.append((left, right))
        players = [players[0], players[-1]] + players[1:-1]
    return rounds


def jacobi_svd(a, sweeps: int = 12):
    """One-sided Jacobi SVD of a (m×k), m >= k assumed, k small.

    Applies plane rotations V from the right until the columns of A·V are
    orthogonal; then A = U diag(s) Vᵀ with s the column norms.

    Parallel-ordering formulation: each round-robin round rotates k/2
    *disjoint* column pairs at once (vectorized gather → 2×2 rotate →
    scatter), so a sweep is k−1 fused steps instead of k(k−1)/2 sequential
    rotations — the difference between ~3k and ~460k loop iterations for
    the 2r×2r core at r = 128. A fixed sweep count keeps shapes static for
    AOT lowering.

    Returns (U m×k, s (k,) descending, V k×k).
    """
    m, k0 = a.shape
    if k0 == 1:
        s = jnp.sqrt(jnp.sum(a * a, axis=0))
        u = a / jnp.maximum(s, _EPS)[None, :]
        return u, s, jnp.ones((1, 1), a.dtype)
    # Pad to an even column count (zero column ⇒ zero singular value,
    # sorted last and trimmed below).
    k = k0 + (k0 % 2)
    b = a.astype(jnp.float32)
    if k != k0:
        b = jnp.concatenate([b, jnp.zeros((m, 1), jnp.float32)], axis=1)
    rounds = _round_robin_schedule(k)
    # Static schedule tensor: (rounds, 2, k/2).
    sched = jnp.array(
        [[l, r] for (l, r) in rounds], dtype=jnp.int32
    )  # (k-1, 2, k/2)
    n_rounds = sched.shape[0]

    def one_round(t, carry):
        b, v = carry
        rr = t % n_rounds
        pq = jax.lax.dynamic_slice(sched, (rr, 0, 0), (1, 2, k // 2))[0]
        p, q = pq[0], pq[1]
        bp = jnp.take(b, p, axis=1)        # (m, k/2)
        bq = jnp.take(b, q, axis=1)
        alpha = jnp.sum(bp * bp, axis=0)   # (k/2,)
        beta = jnp.sum(bq * bq, axis=0)
        gamma = jnp.sum(bp * bq, axis=0)
        denom = jnp.where(jnp.abs(gamma) < _EPS, 1.0, 2.0 * gamma)
        zeta = (beta - alpha) / denom
        sgn = jnp.where(zeta >= 0.0, 1.0, -1.0)
        tt = sgn / (jnp.abs(zeta) + jnp.sqrt(1.0 + zeta * zeta))
        c = 1.0 / jnp.sqrt(1.0 + tt * tt)
        s = c * tt
        # Identity rotation where the pair is already orthogonal.
        small = jnp.abs(gamma) <= 1e-9 * jnp.sqrt(alpha * beta) + _EPS
        c = jnp.where(small, 1.0, c)
        s = jnp.where(small, 0.0, s)
        new_bp = c[None, :] * bp - s[None, :] * bq
        new_bq = s[None, :] * bp + c[None, :] * bq
        b = b.at[:, p].set(new_bp).at[:, q].set(new_bq)
        vp = jnp.take(v, p, axis=1)
        vq = jnp.take(v, q, axis=1)
        v = v.at[:, p].set(c[None, :] * vp - s[None, :] * vq)
        v = v.at[:, q].set(s[None, :] * vp + c[None, :] * vq)
        return b, v

    v = jnp.eye(k, dtype=jnp.float32)
    b, v = jax.lax.fori_loop(0, sweeps * n_rounds, one_round, (b, v))
    s = jnp.sqrt(jnp.sum(b * b, axis=0))
    order = jnp.argsort(-s)
    s_sorted = s[order][:k0]
    b = b[:, order][:, :k0]
    v = v[:, order][:k0, :k0]
    u = b / jnp.maximum(s_sorted, _EPS)[None, :]
    u = jnp.where(s_sorted[None, :] > 1e-10, u, jnp.zeros_like(u))
    return u, s_sorted, v


def rand_range(g, omega, iters: int = 2):
    """Randomized range finder: orthonormal Q (m×r) ≈ top-r range of g.

    `omega` is an (n×r) Gaussian sketch supplied by the caller (the Rust
    coordinator for GaLore resampling artifacts) so no PRNG state is baked
    into the artifact. `iters` power iterations sharpen the spectrum.
    """
    y = g @ omega
    q, _ = cgs2_qr(y)
    for _ in range(iters):
        z, _ = cgs2_qr(g.T @ q)
        q, _ = cgs2_qr(g @ z)
    return q


def svd_lowrank(g, omega, iters: int = 2):
    """Rank-r randomized SVD of g (m×n): returns (U m×r, s (r,), V n×r).

    Used for the paper's SVD_r(G0) momentum-factor initialization (§5.5)
    and the momentum spectral analysis (Fig. 6a).
    """
    q = rand_range(g, omega, iters)
    b = q.T @ g                       # r×n
    ub, s, vb = jacobi_svd(b.T)       # bᵀ = ub s vbᵀ  =>  b = vb s ubᵀ
    u = q @ vb                        # m×r
    return u, s, ub


def newton_schulz(m, steps: int = 5):
    """Muon's quintic Newton-Schulz orthogonalization: m -> ≈ U_m V_mᵀ.

    Coefficients from Jordan et al. (2024b). Operates on the smaller Gram
    side for wide matrices.
    """
    a, b, c = 3.4445, -4.7750, 2.0315
    transpose = m.shape[0] > m.shape[1]
    x = m.T if transpose else m
    x = x / (jnp.sqrt(jnp.sum(x * x)) + 1e-7)
    for _ in range(steps):
        g = x @ x.T
        x = a * x + (b * g + c * (g @ g)) @ x
    return x.T if transpose else x

"""L2 model graphs: shapes, gradient flow, trainability, LoRA semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import (CONFIGS, lora_spec, matrix_params, n_params,
                             param_spec)

RNG = np.random.default_rng(11)
TINY = CONFIGS["gpt_tiny"]
ENC = CONFIGS["enc_glue"]


def _batch(cfg):
    b, t, v = cfg["batch"], cfg["seq"], cfg["vocab"]
    tokens = jnp.asarray(RNG.integers(0, v, (b, t)), jnp.int32)
    if cfg["kind"] == "lm":
        labels = jnp.asarray(RNG.integers(0, v, (b, t)), jnp.int32)
    else:
        labels = jnp.asarray(RNG.integers(0, cfg["ncls"], (b,)), jnp.int32)
    return tokens, labels


def test_param_spec_counts():
    # gpt_tiny: emb 256·128 + pos 128·128 + 2 blocks + lnf
    blk = 128 * 384 + 128 * 128 + 128 * 512 + 512 * 128 + 2 * 128
    want = 256 * 128 + 128 * 128 + 2 * blk + 128
    assert n_params(TINY) == want


def test_matrix_params_excludes_embeddings_and_norms():
    names = [n for n, _ in matrix_params(TINY)]
    assert all(n.startswith("l") for n in names)
    assert len(names) == 4 * TINY["layers"]


def test_loss_and_grads_shapes_and_finiteness():
    params = M.init_params(TINY, seed=0)
    tokens, labels = _batch(TINY)
    out = M.loss_and_grads(TINY)(*params, tokens, labels)
    loss, grads = out[0], out[1:]
    assert np.isfinite(float(loss))
    # random init ⇒ loss ≈ ln(vocab)
    assert abs(float(loss) - np.log(TINY["vocab"])) < 1.0
    assert len(grads) == len(param_spec(TINY))
    for g, (name, shape) in zip(grads, param_spec(TINY)):
        assert g.shape == shape, name
        assert np.isfinite(np.asarray(g)).all(), name
        assert float(jnp.abs(g).max()) > 0, f"dead gradient: {name}"


def test_eval_loss_matches_loss_and_grads():
    params = M.init_params(TINY, seed=1)
    tokens, labels = _batch(TINY)
    l1 = M.eval_loss(TINY)(*params, tokens, labels)[0]
    l2 = M.loss_and_grads(TINY)(*params, tokens, labels)[0]
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_causality():
    """Future tokens must not affect earlier-position logits."""
    cfg = dict(TINY, batch=1)
    params = M.init_params(cfg, seed=2)
    p = M.unflatten(cfg, params)
    t = cfg["seq"]
    tok1 = jnp.asarray(RNG.integers(0, cfg["vocab"], (1, t)), jnp.int32)
    tok2 = tok1.at[0, -1].set((tok1[0, -1] + 1) % cfg["vocab"])
    h1 = M._trunk(cfg, p, tok1)
    h2 = M._trunk(cfg, p, tok2)
    np.testing.assert_allclose(np.asarray(h1[0, :-1]), np.asarray(h2[0, :-1]),
                               atol=1e-5)
    assert np.abs(np.asarray(h1[0, -1] - h2[0, -1])).max() > 1e-4


def test_adam_training_reduces_loss():
    """Full-parameter training on a repetitive sequence must learn fast."""
    params = M.init_params(TINY, seed=3)
    seq = np.tile(np.arange(8), TINY["seq"] // 8 + 1)[:TINY["seq"] + 1]
    tokens = jnp.asarray(np.tile(seq[:-1], (TINY["batch"], 1)), jnp.int32)
    labels = jnp.asarray(np.tile(seq[1:], (TINY["batch"], 1)), jnp.int32)
    fn = jax.jit(lambda *a: M.loss_and_grads(TINY)(*a))
    mm = [jnp.zeros_like(p) for p in params]
    vv = [jnp.zeros_like(p) for p in params]
    first = None
    for i in range(25):
        out = fn(*params, tokens, labels)
        loss, grads = out[0], out[1:]
        if first is None:
            first = float(loss)
        t = i + 1
        mm = [0.9 * a + 0.1 * g for a, g in zip(mm, grads)]
        vv = [0.999 * a + 0.001 * g * g for a, g in zip(vv, grads)]
        params = [
            p - 0.02 * (a / (1 - 0.9 ** t)) /
            (jnp.sqrt(b / (1 - 0.999 ** t)) + 1e-8)
            for p, a, b in zip(params, mm, vv)
        ]
    assert float(loss) < 0.3 * first, (first, float(loss))


def test_last_logits_matches_trunk():
    params = M.init_params(TINY, seed=4)
    tokens, _ = _batch(TINY)
    logits = M.last_logits(TINY)(*params, tokens)[0]
    assert logits.shape == (TINY["batch"], TINY["vocab"])
    p = M.unflatten(TINY, params)
    h = M._trunk(TINY, p, tokens)
    np.testing.assert_allclose(np.asarray(h[:, -1] @ p["tok_emb"].T),
                               np.asarray(logits), atol=1e-5)


class TestClassifier:
    def test_cls_loss_and_logits(self):
        params = M.init_params(ENC, seed=5)
        tokens, labels = _batch(ENC)
        loss = M.eval_loss(ENC)(*params, tokens, labels)[0]
        assert abs(float(loss) - np.log(ENC["ncls"])) < 0.5
        logits = M.cls_logits(ENC)(*params, tokens)[0]
        assert logits.shape == (ENC["batch"], ENC["ncls"])

    def test_encoder_is_bidirectional(self):
        params = M.init_params(ENC, seed=6)
        p = M.unflatten(ENC, params)
        t = ENC["seq"]
        tok1 = jnp.asarray(RNG.integers(0, ENC["vocab"], (1, t)), jnp.int32)
        tok2 = tok1.at[0, -1].set((tok1[0, -1] + 1) % ENC["vocab"])
        h1, h2 = M._trunk(ENC, p, tok1), M._trunk(ENC, p, tok2)
        # changing the last token perturbs *earlier* positions (no mask)
        assert np.abs(np.asarray(h1[0, 0] - h2[0, 0])).max() > 1e-6


class TestLoRA:
    R = 8

    def test_zero_b_adapter_is_identity(self):
        params = M.init_params(TINY, seed=7)
        tokens, labels = _batch(TINY)
        ads = []
        for name, shape in lora_spec(TINY, self.R):
            if name.endswith(".A"):
                ads.append(jnp.asarray(
                    RNG.standard_normal(shape).astype(np.float32)))
            else:
                ads.append(jnp.zeros(shape, jnp.float32))
        l_lora = M.lora_eval_loss(TINY, self.R, 16.0)(
            *ads, *params, tokens, labels)[0]
        l_base = M.eval_loss(TINY)(*params, tokens, labels)[0]
        np.testing.assert_allclose(float(l_lora), float(l_base), rtol=1e-5)

    def test_grads_only_for_adapters(self):
        params = M.init_params(TINY, seed=8)
        tokens, labels = _batch(TINY)
        spec = lora_spec(TINY, self.R)
        ads = [0.01 * jnp.asarray(RNG.standard_normal(s).astype(np.float32))
               for _, s in spec]
        out = M.lora_loss_and_grads(TINY, self.R, 16.0)(
            *ads, *params, tokens, labels)
        loss, grads = out[0], out[1:]
        assert np.isfinite(float(loss))
        assert len(grads) == len(spec)
        for g, (name, shape) in zip(grads, spec):
            assert g.shape == shape, name

    def test_lora_training_reduces_loss(self):
        params = M.init_params(TINY, seed=9)
        seq = np.tile(np.arange(4), TINY["seq"] // 4 + 1)[:TINY["seq"] + 1]
        tokens = jnp.asarray(np.tile(seq[:-1], (TINY["batch"], 1)), jnp.int32)
        labels = jnp.asarray(np.tile(seq[1:], (TINY["batch"], 1)), jnp.int32)
        spec = lora_spec(TINY, self.R)
        ads = []
        for name, shape in spec:
            if name.endswith(".A"):
                ads.append(0.02 * jnp.asarray(
                    RNG.standard_normal(shape).astype(np.float32)))
            else:
                ads.append(jnp.zeros(shape, jnp.float32))
        fn = jax.jit(lambda *a: M.lora_loss_and_grads(TINY, self.R, 16.0)(*a))
        mm = [jnp.zeros_like(a) for a in ads]
        vv = [jnp.zeros_like(a) for a in ads]
        first = None
        for i in range(30):
            out = fn(*ads, *params, tokens, labels)
            loss, grads = out[0], out[1:]
            if first is None:
                first = float(loss)
            t = i + 1
            mm = [0.9 * a + 0.1 * g for a, g in zip(mm, grads)]
            vv = [0.999 * a + 0.001 * g * g for a, g in zip(vv, grads)]
            ads = [
                a - 0.02 * (x / (1 - 0.9 ** t)) /
                (jnp.sqrt(b / (1 - 0.999 ** t)) + 1e-8)
                for a, x, b in zip(ads, mm, vv)
            ]
        # adapters alone have limited capacity (frozen base, tied head) —
        # require a solid but not full reduction
        assert float(loss) < 0.75 * first, (first, float(loss))

"""LAPACK-free linalg blocks vs numpy.linalg ground truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.linalg_jnp import (cgs2_qr, jacobi_svd, newton_schulz,
                                rand_range, svd_lowrank)

RNG = np.random.default_rng(7)


def _rand(shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


@pytest.mark.parametrize("m,k", [(64, 8), (256, 16), (128, 128), (512, 256),
                                 (33, 5), (16, 1)])
def test_cgs2_qr_reconstruction_and_orthogonality(m, k):
    a = _rand((m, k))
    q, r = jax.jit(cgs2_qr)(a)
    np.testing.assert_allclose(np.asarray(q @ r), np.asarray(a), atol=2e-3)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(k), atol=2e-4)
    # R upper-triangular
    rr = np.asarray(r)
    assert np.abs(np.tril(rr, -1)).max() < 1e-5


def test_cgs2_qr_rank_deficient():
    """Duplicate columns must not poison Q; reconstruction still holds."""
    m, k = 96, 8
    a = np.array(_rand((m, k)), copy=True)
    a[:, 3] = a[:, 1]  # exact rank deficiency
    q, r = jax.jit(cgs2_qr)(jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(q @ r), a, atol=2e-3)
    assert not np.isnan(np.asarray(q)).any()


@pytest.mark.parametrize("m,k", [(16, 16), (64, 64), (256, 256), (100, 37),
                                 (50, 8), (7, 7), (10, 1)])
def test_jacobi_svd_vs_numpy(m, k):
    a = _rand((m, k))
    u, s, v = jax.jit(jacobi_svd)(a)
    s_np = np.linalg.svd(np.asarray(a), compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s_np, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(u * s @ v.T), np.asarray(a),
                               atol=6e-3)
    np.testing.assert_allclose(np.asarray(u.T @ u), np.eye(k), atol=2e-3)
    np.testing.assert_allclose(np.asarray(v.T @ v), np.eye(k), atol=2e-3)


def test_jacobi_svd_descending_and_nonnegative():
    a = _rand((40, 24))
    _, s, _ = jax.jit(jacobi_svd)(a)
    s = np.asarray(s)
    assert (s >= 0).all()
    assert (np.diff(s) <= 1e-5).all()


@settings(max_examples=20, deadline=None)
@given(m=st.integers(2, 80), k=st.integers(2, 32))
def test_jacobi_svd_hypothesis(m, k):
    k = min(m, k)
    a = _rand((m, k))
    u, s, v = jacobi_svd(a)
    np.testing.assert_allclose(np.asarray(u * s @ v.T), np.asarray(a),
                               atol=1e-2)


def test_rand_range_captures_dominant_subspace():
    m, n, r = 200, 150, 10
    low = np.asarray(_rand((m, r))) @ np.asarray(_rand((r, n)))
    g = jnp.asarray(low + 1e-3 * np.asarray(_rand((m, n))))
    omega = _rand((n, r))
    q = jax.jit(rand_range)(g, omega)
    resid = np.asarray(g - q @ (q.T @ g))
    assert np.linalg.norm(resid) / np.linalg.norm(np.asarray(g)) < 1e-2


def test_svd_lowrank_exact_on_lowrank_input():
    m, n, r = 160, 120, 6
    g = jnp.asarray(
        np.asarray(_rand((m, r))) @ np.asarray(_rand((r, n))))
    u, s, v = jax.jit(svd_lowrank)(g, _rand((n, r)))
    np.testing.assert_allclose(np.asarray(u * s @ v.T), np.asarray(g),
                               atol=1e-2, rtol=1e-2)
    s_np = np.linalg.svd(np.asarray(g), compute_uv=False)[:r]
    np.testing.assert_allclose(np.asarray(s), s_np, rtol=1e-3, atol=1e-2)


@pytest.mark.parametrize("m,n", [(64, 64), (128, 96), (96, 128)])
def test_newton_schulz_orthogonalizes(m, n):
    # own fixed-seed stream: the shared module RNG is perturbed by the
    # hypothesis sweeps above, and NS5's tail-singular-value bound is
    # sensitive to near-singular draws
    rng = np.random.default_rng(1000 + m + n)
    x = jax.jit(newton_schulz)(
        jnp.asarray(rng.standard_normal((m, n)).astype(np.float32)))
    sv = np.linalg.svd(np.asarray(x), compute_uv=False)
    assert sv.max() < 1.35 and sv.min() > 0.3


def test_newton_schulz_preserves_singular_vectors():
    """NS(M) ≈ U Vᵀ: left/right subspaces must match M's."""
    m, n = 96, 64
    rng = np.random.default_rng(77)
    a = jnp.asarray(rng.standard_normal((m, n)).astype(np.float32))
    x = jax.jit(newton_schulz)(a)
    u, _, vt = np.linalg.svd(np.asarray(a), full_matrices=False)
    np.testing.assert_allclose(np.asarray(x), u @ vt, atol=0.2)

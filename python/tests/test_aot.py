"""AOT manifest consistency + HLO-text portability invariants."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.configs import CONFIGS, RANKS, matrix_shapes, param_spec

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                   "artifacts"))
MANIFEST = os.path.join(ART, "manifest.json")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first")


def _manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_entry_descriptors_match_eval_shape():
    es = aot.build_entries(["gpt_tiny"])
    by_kind = {}
    for e in es:
        by_kind.setdefault(e.tags["kind"], e)
    # one representative per kind is enough (describe() eval_shapes all)
    for kind, e in by_kind.items():
        d = e.describe()
        assert len(d["inputs"]) == len(e.args), kind
        assert d["outputs"], kind


def test_lowered_text_has_no_lapack_custom_calls():
    """The load-bearing portability invariant: artifacts must not contain
    custom-calls the Rust-side XLA 0.5.1 CPU runtime cannot resolve."""
    es = aot.build_entries(["gpt_tiny"])
    reps = {}
    for e in es:
        reps.setdefault(e.tags["kind"], e)
    for kind in ("mofasgd_step", "mofasgd_init", "galore_resample",
                 "loss_and_grads"):
        text = reps[kind].lower_to_text()
        assert "custom-call" not in text.lower(), kind
        assert "lapack" not in text.lower(), kind


@needs_artifacts
def test_manifest_covers_all_config_shape_rank_artifacts():
    man = _manifest()
    names = {a["name"] for a in man["artifacts"]}
    for cn, mc in man["configs"].items():
        cfg = CONFIGS[cn]
        assert mc["n_params"] > 0
        for name, shape in param_spec(cfg):
            pass  # spec parses
        for (m, n) in matrix_shapes(cfg):
            for r in RANKS[cn]:
                for kind in ("mofasgd_step", "mofasgd_accum",
                             "mofasgd_step_from_buf", "mofasgd_init",
                             "galore_step", "galore_resample"):
                    assert f"{kind}_{m}x{n}_r{r}" in names, (cn, m, n, r)
            assert f"muon_step_{m}x{n}" in names
        assert f"{cn}_loss_and_grads" in names
        assert f"{cn}_eval_loss" in names


@needs_artifacts
def test_artifact_files_exist_and_are_hlo_text():
    man = _manifest()
    missing = []
    for a in man["artifacts"]:
        path = os.path.join(ART, a["file"])
        if not os.path.exists(path):
            missing.append(a["file"])
    assert not missing, missing[:10]
    with open(os.path.join(ART, man["artifacts"][0]["file"])) as f:
        head = f.read(200)
    assert "HloModule" in head


@needs_artifacts
def test_manifest_io_descriptors_are_well_formed():
    man = _manifest()
    for a in man["artifacts"]:
        assert a["inputs"] and a["outputs"], a["name"]
        for d in a["inputs"] + a["outputs"]:
            assert d["dtype"] in ("f32", "i32"), a["name"]
            assert all(isinstance(x, int) and x > 0 for x in d["shape"]) \
                or d["shape"] == [], a["name"]


@needs_artifacts
def test_loss_and_grads_descriptor_mirrors_param_spec():
    man = _manifest()
    art = {a["name"]: a for a in man["artifacts"]}
    for cn, mc in man["configs"].items():
        cfg = CONFIGS[cn]
        a = art[f"{cn}_loss_and_grads"]
        spec = param_spec(cfg)
        assert len(a["inputs"]) == len(spec) + 2
        for d, (name, shape) in zip(a["inputs"], spec):
            assert d["name"] == name and tuple(d["shape"]) == shape
        assert a["outputs"][0]["name"] == "loss"
        assert len(a["outputs"]) == len(spec) + 1

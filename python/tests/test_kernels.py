"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.tangent import (lowrank_accum, rank_r_update,
                                     tangent_project)

RNG = np.random.default_rng(0)


def _rand(shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


def _assert_close(a, b, scale=1.0):
    np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=2e-4, atol=5e-4 * max(scale, 1.0))


TILED = [(128, 128, 8), (256, 384, 16), (256, 1024, 32), (512, 128, 4),
         (256, 256, 128)]
RAGGED = [(37, 53, 4), (129, 64, 8), (200, 100, 16), (1, 1, 1)]


@pytest.mark.parametrize("m,n,r", TILED + RAGGED)
def test_tangent_project_matches_ref(m, n, r):
    g, u, v = _rand((m, n)), _rand((m, r)), _rand((n, r))
    got = tangent_project(g, u, v)
    want = ref.tangent_project_ref(g, u, v)
    # accumulation magnitude grows with contraction length
    for a, b, k in zip(got, want, (n, m, m * n)):
        _assert_close(a, b, scale=np.sqrt(k) * np.sqrt(r))


@pytest.mark.parametrize("m,n,r", TILED + RAGGED)
def test_rank_r_update_matches_ref(m, n, r):
    w, u, v = _rand((m, n)), _rand((m, r)), _rand((n, r))
    eta = jnp.float32(0.37)
    _assert_close(rank_r_update(w, u, v, eta),
                  ref.rank_r_update_ref(w, u, v, eta), scale=np.sqrt(r))


@pytest.mark.parametrize("m,n,r", [(128, 256, 8), (64, 64, 4)])
def test_lowrank_accum_matches_ref(m, n, r):
    g, u, v = _rand((m, n)), _rand((m, r)), _rand((n, r))
    bufs = (_rand((m, r)), _rand((r, n)), _rand((r, r)))
    got = lowrank_accum(g, u, v, *bufs)
    want = ref.lowrank_accum_ref(g, u, v, *bufs)
    for a, b in zip(got, want):
        _assert_close(a, b, scale=np.sqrt(max(m, n)))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 160),
    n=st.integers(1, 160),
    r=st.integers(1, 16),
)
def test_tangent_project_hypothesis(m, n, r):
    r = min(r, m, n)
    g, u, v = _rand((m, n)), _rand((m, r)), _rand((n, r))
    got = tangent_project(g, u, v)
    want = ref.tangent_project_ref(g, u, v)
    for a, b in zip(got, want):
        _assert_close(a, b, scale=np.sqrt(max(m, n) * r))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 160),
    n=st.integers(1, 160),
    r=st.integers(1, 16),
    eta=st.floats(0.0, 1.0),
)
def test_rank_r_update_hypothesis(m, n, r, eta):
    r = min(r, m, n)
    w, u, v = _rand((m, n)), _rand((m, r)), _rand((n, r))
    _assert_close(rank_r_update(w, u, v, jnp.float32(eta)),
                  ref.rank_r_update_ref(w, u, v, jnp.float32(eta)),
                  scale=np.sqrt(r))


def test_accum_equals_projection_of_sum():
    """Linearity: summing per-microbatch projections == projecting the sum
    (the §5.5 fused-accumulation correctness condition)."""
    m, n, r = 128, 256, 8
    u, v = _rand((m, r)), _rand((n, r))
    gs = [_rand((m, n)) for _ in range(4)]
    bufs = (jnp.zeros((m, r)), jnp.zeros((r, n)), jnp.zeros((r, r)))
    for g in gs:
        bufs = lowrank_accum(g, u, v, *bufs)
    want = ref.tangent_project_ref(sum(gs), u, v)
    for a, b in zip(bufs, want):
        _assert_close(a, b, scale=np.sqrt(max(m, n)) * 4)


def test_zero_rank_direction_is_noop():
    m, n, r = 64, 96, 4
    w = _rand((m, n))
    z = jnp.zeros((m, r))
    out = rank_r_update(w, z, jnp.zeros((n, r)), jnp.float32(1.0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(w))

"""L2 optimizer graphs: MoFaSGD vs dense references, baselines vs manual math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim_jnp as O
from compile.kernels import ref

RNG = np.random.default_rng(3)


def _rand(shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


def _init_factors(m, n, r):
    g0 = np.asarray(_rand((m, r))) @ np.asarray(_rand((r, n)))
    u, s, v = O.mofasgd_init(jnp.asarray(g0), _rand((n, r)))
    return u, s, v, g0


class TestMoFaSGD:
    def test_init_reconstructs_lowrank_gradient(self):
        m, n, r = 128, 96, 8
        u, s, v, g0 = _init_factors(m, n, r)
        np.testing.assert_allclose(np.asarray(u * s @ v.T), g0, atol=2e-2)

    def test_factors_stay_orthonormal(self):
        m, n, r = 128, 160, 8
        u, s, v, _ = _init_factors(m, n, r)
        w = _rand((m, n))
        step = jax.jit(O.mofasgd_step)
        for _ in range(6):
            w, u, s, v = step(w, u, s, v, _rand((m, n)),
                              jnp.float32(0.01), jnp.float32(0.9))
        np.testing.assert_allclose(np.asarray(u.T @ u), np.eye(r), atol=2e-3)
        np.testing.assert_allclose(np.asarray(v.T @ v), np.eye(r), atol=2e-3)
        assert (np.diff(np.asarray(s)) <= 1e-4).all()

    def test_matches_dense_truncated_svd_recursion(self):
        """UMF ≡ SVD_r(β·M̂ + Proj_T(G)) — Alg. 1 vs its dense definition."""
        m, n, r = 96, 128, 6
        u, s, v, g0 = _init_factors(m, n, r)
        w = _rand((m, n))
        beta, eta = 0.9, 0.02
        m_ref = np.asarray(u * s @ v.T)
        step = jax.jit(O.mofasgd_step)
        for _ in range(4):
            g = _rand((m, n))
            ghat = np.asarray(ref.tangent_space_projection_ref(
                g, u, v))
            dense = beta * m_ref + ghat
            ud, sd, vtd = np.linalg.svd(dense)
            w, u, s, v = step(w, u, s, v, g, jnp.float32(eta),
                              jnp.float32(beta))
            got = np.asarray(u * s @ v.T)
            want = ud[:, :r] * sd[:r] @ vtd[:r]
            assert np.linalg.norm(got - want) / np.linalg.norm(want) < 1e-3
            m_ref = want

    def test_update_is_spectrally_normalized(self):
        """W_{t+1} − W_t = −η U_{t+1} V_{t+1}ᵀ with orthonormal factors."""
        m, n, r = 64, 80, 4
        u, s, v, _ = _init_factors(m, n, r)
        w = _rand((m, n))
        eta = 0.05
        w2, u2, s2, v2 = jax.jit(O.mofasgd_step)(
            w, u, s, v, _rand((m, n)), jnp.float32(eta), jnp.float32(0.9))
        delta = np.asarray(w - w2) / eta
        sv = np.linalg.svd(delta, compute_uv=False)
        np.testing.assert_allclose(sv[:r], np.ones(r), atol=1e-3)
        assert np.abs(sv[r:]).max() < 1e-3

    def test_step_from_buf_equals_step_on_mean_gradient(self):
        """Fused §5.5 accumulation path == plain step on the mean gradient."""
        m, n, r, k = 96, 64, 8, 4
        u, s, v, _ = _init_factors(m, n, r)
        w = _rand((m, n))
        gs = [_rand((m, n)) for _ in range(k)]
        bufs = (jnp.zeros((m, r)), jnp.zeros((r, n)), jnp.zeros((r, r)))
        for g in gs:
            bufs = O.mofasgd_accum(g, u, v, *bufs)
        got = O.mofasgd_step_from_buf(
            w, u, s, v, *bufs, jnp.float32(0.01), jnp.float32(0.9),
            jnp.float32(1.0 / k))
        mean_g = sum(gs) / k
        want = O.mofasgd_step(w, u, s, v, mean_g, jnp.float32(0.01),
                              jnp.float32(0.9))
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-4, rtol=1e-3)

    def test_naive_step_agrees_with_umf_on_lowrank_momentum(self):
        m, n, r = 96, 128, 8
        u, s, v, _ = _init_factors(m, n, r)
        w = _rand((m, n))
        g = _rand((m, n))
        fast = O.mofasgd_step(w, u, s, v, g, jnp.float32(0.01),
                              jnp.float32(0.9))
        slow = O.mofasgd_step_naive(w, u, s, v, g, jnp.float32(0.01),
                                    jnp.float32(0.9), _rand((n, r)))
        # same momentum spectrum; singular vectors may differ by sign
        np.testing.assert_allclose(np.asarray(fast[2]), np.asarray(slow[2]),
                                   rtol=5e-3, atol=5e-3)
        np.testing.assert_allclose(np.asarray(fast[0]), np.asarray(slow[0]),
                                   atol=5e-2)


class TestGaLore:
    def test_step_matches_manual_adam_in_subspace(self):
        m, n, r = 64, 48, 4
        w, g, q = _rand((m, n)), _rand((m, n)), _rand((m, r))
        q, _ = np.linalg.qr(np.asarray(q)), None
        q = jnp.asarray(q[0] if isinstance(q, tuple) else q)
        mm, vv = jnp.zeros((r, n)), jnp.zeros((r, n))
        b1, b2, eta, t = 0.9, 0.999, 0.01, 1.0
        w2, m2, v2 = O.galore_step(
            w, q, mm, vv, g, jnp.float32(eta), jnp.float32(t),
            jnp.float32(b1), jnp.float32(b2))
        gr = np.asarray(q).T @ np.asarray(g)
        m_ref = (1 - b1) * gr
        v_ref = (1 - b2) * gr * gr
        mh, vh = m_ref / (1 - b1), v_ref / (1 - b2)
        w_ref = np.asarray(w) - eta * np.asarray(q) @ (
            mh / (np.sqrt(vh) + 1e-8))
        np.testing.assert_allclose(np.asarray(w2), w_ref, atol=1e-5)
        np.testing.assert_allclose(np.asarray(m2), m_ref, atol=1e-6)
        np.testing.assert_allclose(np.asarray(v2), v_ref, atol=1e-6)

    def test_step_from_buf_equals_step_on_mean(self):
        m, n, r, k = 64, 48, 4, 3
        w = _rand((m, n))
        q = jnp.asarray(np.linalg.qr(np.asarray(_rand((m, r))))[0])
        mm, vv = _rand((r, n)) * 0.1, jnp.abs(_rand((r, n))) * 0.1
        gs = [_rand((m, n)) for _ in range(k)]
        buf = jnp.zeros((r, n))
        for g in gs:
            buf = O.galore_accum(g, q, buf)
        args = (jnp.float32(0.01), jnp.float32(5.0), jnp.float32(0.9),
                jnp.float32(0.999))
        got = O.galore_step_from_buf(w, q, mm, vv, buf, *args,
                                     jnp.float32(1.0 / k))
        want = O.galore_step(w, q, mm, vv, sum(gs) / k, *args)
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-4)

    def test_resample_finds_left_subspace(self):
        m, n, r = 120, 80, 6
        g = jnp.asarray(np.asarray(_rand((m, r))) @ np.asarray(_rand((r, n))))
        q = O.galore_resample(g, _rand((n, r)))
        resid = np.asarray(g - q @ (q.T @ g))
        assert np.linalg.norm(resid) / np.linalg.norm(np.asarray(g)) < 1e-3


class TestFullRankBaselines:
    def test_adamw_matches_manual(self):
        shape = (32, 24)
        w, g = _rand(shape), _rand(shape)
        mm, vv = jnp.zeros(shape), jnp.zeros(shape)
        eta, t, b1, b2, wd = 0.01, 1.0, 0.9, 0.999, 0.1
        w2, m2, v2 = O.adamw_step(
            w, mm, vv, g, jnp.float32(eta), jnp.float32(t), jnp.float32(b1),
            jnp.float32(b2), jnp.float32(wd))
        m_ref = (1 - b1) * np.asarray(g)
        v_ref = (1 - b2) * np.asarray(g) ** 2
        mh, vh = m_ref / (1 - b1), v_ref / (1 - b2)
        w_ref = np.asarray(w) - eta * (
            mh / (np.sqrt(vh) + 1e-8) + wd * np.asarray(w))
        np.testing.assert_allclose(np.asarray(w2), w_ref, atol=1e-6)

    def test_muon_update_is_orthogonal(self):
        m, n = 96, 64
        w, mm, g = _rand((m, n)), jnp.zeros((m, n)), _rand((m, n))
        w2, m2 = O.muon_step(w, mm, g, jnp.float32(0.1), jnp.float32(0.95))
        np.testing.assert_allclose(np.asarray(m2), np.asarray(g), atol=1e-6)
        delta = np.asarray(w - w2) / 0.1
        sv = np.linalg.svd(delta, compute_uv=False)
        assert sv.max() < 1.35 and sv.min() > 0.3

    def test_lion_sign_update(self):
        shape = (16, 16)
        w, g = _rand(shape), _rand(shape)
        mm = jnp.zeros(shape)
        w2, m2 = O.lion_step(w, mm, g, jnp.float32(0.01), jnp.float32(0.9),
                             jnp.float32(0.99), jnp.float32(0.0))
        np.testing.assert_allclose(
            np.asarray(w2), np.asarray(w) - 0.01 * np.sign(0.1 * np.asarray(g)),
            atol=1e-6)

    def test_signsgd(self):
        w, g = _rand((8, 8)), _rand((8, 8))
        w2 = O.signsgd_step(w, g, jnp.float32(0.5))
        np.testing.assert_allclose(
            np.asarray(w2), np.asarray(w) - 0.5 * np.sign(np.asarray(g)),
            atol=1e-6)

    def test_sgdm(self):
        w, g, mm = _rand((8, 4)), _rand((8, 4)), _rand((8, 4))
        w2, m2 = O.sgdm_step(w, mm, g, jnp.float32(0.1), jnp.float32(0.9))
        m_ref = 0.9 * np.asarray(mm) + np.asarray(g)
        np.testing.assert_allclose(np.asarray(m2), m_ref, atol=1e-6)
        np.testing.assert_allclose(np.asarray(w2),
                                   np.asarray(w) - 0.1 * m_ref, atol=1e-6)

    def test_adafactor_state_is_factored(self):
        m, n = 24, 16
        w, g = _rand((m, n)), _rand((m, n))
        r_acc, c_acc = jnp.zeros((m,)), jnp.zeros((n,))
        w2, r2, c2 = O.adafactor_step(w, r_acc, c_acc, g, jnp.float32(0.01),
                                      jnp.float32(0.999))
        assert r2.shape == (m,) and c2.shape == (n,)
        g2 = np.asarray(g) ** 2 + 1e-30
        np.testing.assert_allclose(np.asarray(r2),
                                   (1 - 0.999) * g2.mean(1), rtol=1e-4)


@pytest.mark.parametrize("opt_rosenbrock", ["mofasgd", "galore", "adamw",
                                            "muon"])
def test_optimizers_descend_on_quadratic(opt_rosenbrock):
    """Closed-loop sanity: each optimizer reduces ||W − W*||² on a matrix
    quadratic with stochastic gradients."""
    m, n, r = 48, 32, 8
    steps = 150
    w_star = np.asarray(_rand((m, n)))
    # Modest initial offset: spectrally normalized optimizers move a fixed
    # η·√r (or η·√min(m,n)) Frobenius distance per step.
    w = jnp.asarray(w_star + 0.3 * np.asarray(_rand((m, n))))

    def grad(w):
        noise = 0.01 * np.asarray(RNG.standard_normal((m, n)), np.float32)
        return jnp.asarray(np.asarray(w) - w_star + noise)

    loss0 = float(np.linalg.norm(np.asarray(w) - w_star))
    if opt_rosenbrock == "mofasgd":
        u, s, v = O.mofasgd_init(grad(w), _rand((n, r)))
        step = jax.jit(O.mofasgd_step)
        for _ in range(steps):
            w, u, s, v = step(w, u, s, v, grad(w), jnp.float32(0.05),
                              jnp.float32(0.9))
    elif opt_rosenbrock == "galore":
        # GaLore needs periodic subspace resampling on a full-rank error
        # (rank-r fixed Q can only correct r of min(m,n) directions).
        q = O.galore_resample(grad(w), _rand((n, r)))
        mm = jnp.zeros((r, n))
        vv = jnp.zeros((r, n))
        step = jax.jit(O.galore_step)
        for t in range(steps):
            if t > 0 and t % 10 == 0:
                q = O.galore_resample(grad(w), _rand((n, r)))
            w, mm, vv = step(w, q, mm, vv, grad(w), jnp.float32(0.05),
                             jnp.float32(t + 1.0), jnp.float32(0.9),
                             jnp.float32(0.999))
    elif opt_rosenbrock == "adamw":
        mm = jnp.zeros((m, n))
        vv = jnp.zeros((m, n))
        step = jax.jit(O.adamw_step)
        for t in range(steps):
            w, mm, vv = step(w, mm, vv, grad(w), jnp.float32(0.05),
                             jnp.float32(t + 1.0), jnp.float32(0.9),
                             jnp.float32(0.999), jnp.float32(0.0))
    else:
        mm = jnp.zeros((m, n))
        step = jax.jit(O.muon_step)
        for _ in range(steps):
            w, mm = step(w, mm, grad(w), jnp.float32(0.02), jnp.float32(0.9))
    loss1 = float(np.linalg.norm(np.asarray(w) - w_star))
    assert loss1 < 0.5 * loss0, (loss0, loss1)

//! Integration tests: the full artifact path (PJRT runtime + coordinator).
//!
//! These require `make artifacts`; each test skips gracefully when the
//! manifest is missing so `cargo test` stays green on a fresh checkout.

use mofasgd::coordinator::{Hyper, OptimizerChoice, Schedule, Trainer,
                           TrainerOptions};
use mofasgd::data::corpus::LmDataset;
use mofasgd::data::glue::{GlueDataset, GLUE_TASKS};
use mofasgd::data::instruct::{InstructDataset, Task};
use mofasgd::runtime::Registry;

fn registry() -> Option<Registry> {
    let dir = Registry::default_dir();
    if dir.join("manifest.json").exists() {
        Some(Registry::open(dir).unwrap())
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn trainer<'r>(reg: &'r Registry, config: &str, opt: &str, lr: f64,
               accum: usize, fused: bool) -> Trainer<'r> {
    Trainer::new(reg, TrainerOptions {
        config: config.into(),
        choice: OptimizerChoice::parse(opt).unwrap(),
        hyper: Hyper {
            lr,
            emb_lr: lr,
            accum,
            fused,
            schedule: Schedule::Constant,
            ..Hyper::default()
        },
        seed: 7,
        run_name: format!("it-{opt}"),
    })
    .unwrap()
}

#[test]
fn mofasgd_training_reduces_lm_loss() {
    let Some(reg) = registry() else { return };
    let mut t = trainer(&reg, "gpt_tiny", "mofasgd:r=8,beta=0.9", 0.01, 1,
                        true);
    let mut data = LmDataset::new(t.cfg.vocab, t.cfg.batch, t.cfg.seq, 1);
    let val = data.val_batches(2);
    let before = t.eval_lm(&val).unwrap();
    for _ in 0..25 {
        t.step_lm(&[data.next_train()]).unwrap();
    }
    let after = t.eval_lm(&val).unwrap();
    assert!(after < before - 0.3, "{before} -> {after}");
}

#[test]
fn fused_and_dense_accumulation_agree() {
    // The §5.5 fused path must be numerically equivalent to dense
    // accumulation: identical seeds, 3 steps of accum=2, same final loss.
    let Some(reg) = registry() else { return };
    let run = |fused: bool| -> Vec<f32> {
        let mut t = trainer(&reg, "gpt_tiny", "mofasgd:r=4,beta=0.9", 0.005,
                            2, fused);
        let mut data =
            LmDataset::new(t.cfg.vocab, t.cfg.batch, t.cfg.seq, 3);
        let mut losses = Vec::new();
        for _ in 0..3 {
            let micro = vec![data.next_train(), data.next_train()];
            losses.push(t.step_lm(&micro).unwrap());
        }
        losses
    };
    let fused = run(true);
    let dense = run(false);
    for (a, b) in fused.iter().zip(&dense) {
        assert!((a - b).abs() < 2e-3, "fused {a} vs dense {b}");
    }
}

#[test]
fn galore_fused_matches_dense() {
    let Some(reg) = registry() else { return };
    let run = |fused: bool| -> f32 {
        let mut t = trainer(&reg, "gpt_tiny", "galore:r=4,tau=100", 0.005,
                            2, fused);
        let mut data =
            LmDataset::new(t.cfg.vocab, t.cfg.batch, t.cfg.seq, 4);
        let mut last = 0.0;
        for _ in 0..3 {
            let micro = vec![data.next_train(), data.next_train()];
            last = t.step_lm(&micro).unwrap();
        }
        last
    };
    let (f, d) = (run(true), run(false));
    assert!((f - d).abs() < 2e-3, "fused {f} vs dense {d}");
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let Some(reg) = registry() else { return };
    let path = std::env::temp_dir().join("mofa_it_ckpt.bin");
    let path = path.to_str().unwrap();
    let mut t = trainer(&reg, "gpt_tiny", "mofasgd:r=4", 0.01, 1, true);
    let mut data = LmDataset::new(t.cfg.vocab, t.cfg.batch, t.cfg.seq, 5);
    let val = data.val_batches(1);
    for _ in 0..3 {
        t.step_lm(&[data.next_train()]).unwrap();
    }
    let loss = t.eval_lm(&val).unwrap();
    t.save_checkpoint(path).unwrap();
    let mut t2 = trainer(&reg, "gpt_tiny", "adamw", 0.01, 1, false);
    t2.load_checkpoint(path).unwrap();
    let loss2 = t2.eval_lm(&val).unwrap();
    assert!((loss - loss2).abs() < 1e-4, "{loss} vs {loss2}");
}

#[test]
fn lora_training_reduces_loss_and_keeps_base_frozen() {
    let Some(reg) = registry() else { return };
    let path = std::env::temp_dir().join("mofa_it_lora.bin");
    let path = path.to_str().unwrap();
    let mut t = trainer(&reg, "gpt_tiny", "lora:r=8", 0.01, 1, true);
    let mut data = LmDataset::new(t.cfg.vocab, t.cfg.batch, t.cfg.seq, 9);
    let val = data.val_batches(2);
    t.save_checkpoint(path).unwrap();
    let before = t.eval_lm(&val).unwrap();
    for _ in 0..15 {
        t.step_lm(&[data.next_train()]).unwrap();
    }
    let after = t.eval_lm(&val).unwrap();
    assert!(after < before - 0.05, "{before} -> {after}");
    // Base weights untouched by adapter training.
    let ck_before = mofasgd::coordinator::checkpoint::Checkpoint::load(path)
        .unwrap();
    let path2 = std::env::temp_dir().join("mofa_it_lora2.bin");
    t.save_checkpoint(path2.to_str().unwrap()).unwrap();
    let ck_after = mofasgd::coordinator::checkpoint::Checkpoint::load(
        path2.to_str().unwrap()).unwrap();
    for (a, b) in ck_before.tensors.iter().zip(&ck_after.tensors) {
        assert_eq!(a.2, b.2, "base weight {} changed under LoRA", a.0);
    }
}

#[test]
fn cls_training_beats_chance() {
    let Some(reg) = registry() else { return };
    let task = GLUE_TASKS[2]; // SST-2 proxy (easiest)
    let mut t = trainer(&reg, "enc_glue", "mofasgd:r=4,beta=0.9", 0.01, 1,
                        true);
    let mut data = GlueDataset::new(task, t.cfg.vocab, t.cfg.batch,
                                    t.cfg.seq, 11);
    let val = data.val_batches(4);
    for _ in 0..40 {
        t.step_cls(&[data.next_train()]).unwrap();
    }
    let acc = t.eval_cls_accuracy(&val).unwrap();
    assert!(acc > 0.6, "accuracy {acc} not above chance");
}

#[test]
fn exact_match_eval_runs_and_is_bounded() {
    let Some(reg) = registry() else { return };
    let t = trainer(&reg, "gpt_tiny", "mofasgd:r=4", 0.01, 1, true);
    let ds = InstructDataset::new(t.cfg.vocab, t.cfg.batch, t.cfg.seq, 13);
    let examples = ds.eval_examples(Task::Copy, 12);
    let score = t.answer_exact_match(&examples).unwrap();
    assert!((0.0..=1.0).contains(&score.exact));
    assert!((0.0..=1.0).contains(&score.token));
    // untrained model should be near zero on exact match
    assert!(score.exact < 0.5,
            "untrained exact-match suspiciously high: {}", score.exact);
}

#[test]
fn optimizer_state_accounting_matches_table2_formulas() {
    let Some(reg) = registry() else { return };
    let t = trainer(&reg, "gpt_tiny", "mofasgd:r=8", 0.01, 1, true);
    let cfg = reg.config("gpt_tiny").unwrap();
    let want_mat: usize = cfg
        .matrix_params()
        .iter()
        .map(|(_, (m, n))| (m + n + 1) * 8)
        .sum();
    let want_vec: usize = cfg
        .params
        .iter()
        .filter(|(n, s)| !(s.len() == 2 && n.starts_with('l')))
        .map(|(_, s)| 2 * s.iter().product::<usize>().max(1))
        .sum();
    assert_eq!(t.optimizer_state_floats(), want_mat + want_vec);
    // fused gradient buffers are far below full-rank
    let full: usize = cfg
        .matrix_params()
        .iter()
        .map(|(_, (m, n))| m * n)
        .sum();
    assert!(t.gradient_buffer_floats() < full);
}

#[test]
fn schedule_decays_lr_late_in_training() {
    let Some(reg) = registry() else { return };
    // Indirect but end-to-end: with a cooldown schedule, late steps move
    // weights less than early steps under a constant gradient scale.
    let mut t = Trainer::new(&reg, TrainerOptions {
        config: "gpt_tiny".into(),
        choice: OptimizerChoice::parse("mofasgd:r=4").unwrap(),
        hyper: Hyper {
            lr: 0.01,
            emb_lr: 0.01,
            accum: 1,
            fused: true,
            schedule: Schedule::StableDecay {
                total_steps: 10,
                cooldown_frac: 0.8,
            },
            ..Hyper::default()
        },
        seed: 17,
        run_name: "sched".into(),
    })
    .unwrap();
    let mut data = LmDataset::new(t.cfg.vocab, t.cfg.batch, t.cfg.seq, 17);
    let mut drops = Vec::new();
    let mut prev = f64::NAN;
    for _ in 0..10 {
        let loss = t.step_lm(&[data.next_train()]).unwrap() as f64;
        if !prev.is_nan() {
            drops.push(prev - loss);
        }
        prev = loss;
    }
    assert!(drops.len() == 9);
}

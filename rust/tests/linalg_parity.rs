//! Linalg parity / property suite (ISSUE 2).
//!
//! Pins the parallel round-robin Jacobi SVD to the retained sequential
//! cyclic Jacobi, and the blocked compact-WY QR to the unblocked
//! reflector-at-a-time baseline, across tall / square / odd-k shapes,
//! rank-deficient and duplicated-column inputs, graded spectra, all-zero
//! matrices, and NaN/Inf poison. Includes Prop-based randomized-shape
//! checks and a fixed-seed determinism check: the same input must produce
//! *bit-identical* factors at every pool worker count (pairs within a
//! round-robin round are disjoint, so scheduling cannot reorder math).
//!
//! `rust/run_checks.sh` runs this suite under `RUST_TEST_THREADS=1` and
//! again with the pool pinned to 2 and 8 workers via `MOFA_WORKERS`.

use mofasgd::fusion;
use mofasgd::linalg::{
    householder_qr, householder_qr_unblocked, jacobi_svd, jacobi_svd_seq,
    Mat, Svd,
};
use mofasgd::optim::{MatrixOptimizer, MoFaSgd};
use mofasgd::util::prop::{dim, Prop};
use mofasgd::util::rng::Rng;
use std::sync::Mutex;

/// The determinism tests pin `fusion::set_workers`, which is process
/// global — serialize them against each other so each one's 1/2/8-worker
/// passes actually run at the advertised counts under the default
/// parallel test harness.
static WORKER_LOCK: Mutex<()> = Mutex::new(());

fn reconstruct(svd: &Svd) -> Mat {
    let k = svd.s.len();
    let mut us = svd.u.clone();
    for j in 0..k {
        for i in 0..us.rows {
            us[(i, j)] *= svd.s[j];
        }
    }
    us.matmul_t(&svd.v)
}

fn orth_err(q: &Mat) -> f32 {
    q.t_matmul(q).rel_err(&Mat::eye(q.cols))
}

/// Parallel Jacobi ≡ sequential Jacobi: singular values to 1e-5 (relative
/// to σ₀), reconstruction of the input by both, and — for full-rank
/// inputs — |UᵀU−I| / |VᵀV−I| at the same tolerance class.
fn check_svd_parity(a: &Mat, full_rank: bool) {
    let par = jacobi_svd(a);
    let seq = jacobi_svd_seq(a);
    assert_eq!(par.s.len(), a.cols);
    assert_eq!(seq.s.len(), a.cols);
    let scale = seq.s.first().copied().unwrap_or(0.0).max(1.0);
    for (i, (sp, ss)) in par.s.iter().zip(&seq.s).enumerate() {
        assert!(
            (sp - ss).abs() <= 1e-5 * scale,
            "σ_{i} mismatch: par {sp} vs seq {ss} ({}x{})",
            a.rows, a.cols
        );
        assert!(*sp >= -1e-6, "negative singular value");
    }
    for w in par.s.windows(2) {
        assert!(w[0] >= w[1] - 1e-5 * scale, "not sorted descending");
    }
    let frob = a.frob_norm();
    if frob > 1e-6 {
        assert!(reconstruct(&par).rel_err(a) < 1e-4, "par reconstruction");
        assert!(reconstruct(&seq).rel_err(a) < 1e-4, "seq reconstruction");
    } else {
        // All-zero input: both must return zero spectra, zero U — and V
        // must stay orthonormal (the index tie-break keeps the odd-k
        // padding column from displacing a real zero column's unit
        // vector).
        assert!(par.s.iter().all(|x| *x == 0.0));
        assert!(par.u.data.iter().all(|x| *x == 0.0));
        assert!(orth_err(&par.v) < 1e-6, "zero-input V not orthonormal");
        assert!(orth_err(&seq.v) < 1e-6);
    }
    if full_rank {
        assert!(orth_err(&par.u) < 1e-4, "par |UᵀU−I|");
        assert!(orth_err(&par.v) < 1e-4, "par |VᵀV−I|");
        assert!(
            (orth_err(&par.u) - orth_err(&seq.u)).abs() < 1e-4,
            "orthogonality quality diverged"
        );
    }
}

#[test]
fn svd_parity_fixed_shapes() {
    // Tall, square, odd-k (exercises the zero-column padding), k = 1.
    let mut rng = Rng::new(11);
    for (m, k) in [
        (8, 8), (40, 16), (33, 5), (21, 7), (13, 13), (64, 64), (64, 63),
        (9, 1), (5, 4),
    ] {
        let a = Mat::randn(&mut rng, m, k, 1.0);
        check_svd_parity(&a, true);
    }
}

#[test]
fn svd_parity_rank_deficient_and_duplicates() {
    let mut rng = Rng::new(12);
    // Duplicated columns (rank 3 in 5 columns).
    let base = Mat::randn(&mut rng, 30, 3, 1.0);
    let dup = base.hcat(&base.slice_cols(0, 2));
    check_svd_parity(&dup, false);
    // Exact low-rank outer product (rank 2 in 6 columns).
    let lowrank = Mat::randn(&mut rng, 24, 2, 1.0)
        .matmul(&Mat::randn(&mut rng, 2, 6, 1.0));
    check_svd_parity(&lowrank, false);
    // Tail singular values of the rank-2 input must be ≈ 0 in both paths.
    let par = jacobi_svd(&lowrank);
    let s0 = par.s[0].max(1.0);
    for s in &par.s[2..] {
        assert!(s.abs() < 1e-4 * s0, "rank-2 tail σ = {s}");
    }
}

#[test]
fn svd_parity_graded_spectrum() {
    // σ_i = 10^−i: ill-graded spectra are where one-sided Jacobi shines;
    // both orderings must agree on the small tail, not just the head.
    let mut rng = Rng::new(13);
    let (m, k) = (32, 8);
    let q1 = householder_qr(&Mat::randn(&mut rng, m, k, 1.0)).q;
    let q2 = householder_qr(&Mat::randn(&mut rng, k, k, 1.0)).q;
    let mut graded = Mat::zeros(m, k);
    for j in 0..k {
        let sigma = 10f32.powi(-(j as i32));
        for i in 0..m {
            graded[(i, j)] = q1[(i, j)] * sigma;
        }
    }
    let a = graded.matmul_t(&q2);
    check_svd_parity(&a, true);
    let par = jacobi_svd(&a);
    for (i, s) in par.s.iter().enumerate().take(5) {
        let want = 10f32.powi(-(i as i32));
        // 1% relative + f32-construction-noise floor.
        assert!(
            (s - want).abs() < 1e-2 * want + 1e-5,
            "graded σ_{i}: got {s}, want {want}"
        );
    }
}

#[test]
fn svd_parity_all_zero() {
    check_svd_parity(&Mat::zeros(12, 5), false);
    check_svd_parity(&Mat::zeros(6, 6), false);
}

#[test]
fn svd_nan_inf_regression_no_panic() {
    // The old sort (`partial_cmp(..).unwrap()`) aborted on NaN singular
    // values; `total_cmp` must sort them deterministically instead, and
    // the poison must propagate into the spectrum (Mat zero-skip rule).
    let mut a = Mat::zeros(8, 5);
    a[(0, 0)] = f32::NAN;
    a[(1, 1)] = f32::INFINITY;
    a[(2, 2)] = -3.0;
    a[(3, 3)] = f32::NEG_INFINITY;
    for svd in [jacobi_svd(&a), jacobi_svd_seq(&a)] {
        assert_eq!(svd.s.len(), 5);
        assert!(svd.s.iter().any(|x| !x.is_finite()),
                "NaN/Inf must reach the spectrum");
    }
}

#[test]
fn svd_property_random_shapes() {
    Prop::new(16).check("jacobi-par-vs-seq", |rng| {
        let k = dim(rng, 24);
        let m = k + rng.below(20);
        let a = Mat::randn(rng, m, k, 1.0);
        check_svd_parity(&a, true);
    });
}

#[test]
fn svd_determinism_across_worker_counts() {
    // Same seed ⇒ bit-identical factors at 1, 2, and 8 pool workers:
    // round-robin rounds rotate disjoint pairs, so the worker split can
    // never reorder arithmetic.
    let _guard = WORKER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Rng::new(42);
    // `even` is sized past the small-problem cutoff (half·(10m+4k) >
    // MIN_PAR_FLOPS) so the 2/8-worker runs genuinely take the parallel
    // round path with multi-way pair splits; `odd` covers the padding
    // logic (small is fine there).
    let even = Mat::randn(&mut rng, 512, 96, 1.0);
    let odd = Mat::randn(&mut rng, 33, 15, 1.0);
    let runs: Vec<(Svd, Svd)> = [1usize, 2, 8]
        .iter()
        .map(|&wkrs| {
            fusion::set_workers(wkrs);
            let out = (jacobi_svd(&even), jacobi_svd(&odd));
            fusion::set_workers(0);
            out
        })
        .collect();
    for (re, ro) in &runs[1..] {
        assert_eq!(re.u.data, runs[0].0.u.data, "U diverged (even k)");
        assert_eq!(re.s, runs[0].0.s, "σ diverged (even k)");
        assert_eq!(re.v.data, runs[0].0.v.data, "V diverged (even k)");
        assert_eq!(ro.u.data, runs[0].1.u.data, "U diverged (odd k)");
        assert_eq!(ro.s, runs[0].1.s, "σ diverged (odd k)");
        assert_eq!(ro.v.data, runs[0].1.v.data, "V diverged (odd k)");
    }
}

/// Blocked QR ≡ unblocked QR: both reconstruct, both orthonormal, both
/// canonical (upper-triangular R, non-negative diagonal) — and for
/// full-rank inputs the canonical form is unique, so the factors must
/// agree elementwise to f32 rounding.
fn check_qr_parity(a: &Mat, full_rank: bool) {
    let blk = householder_qr(a);
    let old = householder_qr_unblocked(a);
    for (name, f) in [("blocked", &blk), ("unblocked", &old)] {
        assert!(f.q.matmul(&f.r).rel_err(a) < 1e-4, "{name} reconstruction");
        for i in 0..a.cols {
            for j in 0..i {
                assert!(f.r[(i, j)].abs() < 1e-5, "{name} R not triangular");
            }
            assert!(f.r[(i, i)] >= 0.0, "{name} R diagonal sign");
        }
        assert!(!f.q.data.iter().any(|x| x.is_nan()), "{name} NaN in Q");
    }
    if full_rank {
        assert!(orth_err(&blk.q) < 1e-4, "blocked |QᵀQ−I|");
        assert!(blk.q.rel_err(&old.q) < 5e-4, "Q factors diverged");
        assert!(blk.r.rel_err(&old.r) < 5e-4, "R factors diverged");
    }
}

#[test]
fn qr_parity_fixed_shapes() {
    // Includes widths straddling the QR_PANEL=32 boundary so the compact
    // WY trailing update and multi-panel Q backsolve are exercised.
    let mut rng = Rng::new(21);
    for (m, k) in [
        (8, 8), (64, 16), (96, 48), (130, 65), (200, 40), (64, 33),
        (33, 5), (40, 1), (256, 96),
    ] {
        let a = Mat::randn(&mut rng, m, k, 1.0);
        check_qr_parity(&a, true);
    }
}

#[test]
fn qr_parity_rank_deficient() {
    let mut rng = Rng::new(22);
    let base = Mat::randn(&mut rng, 50, 4, 1.0);
    let dup = base.hcat(&base.slice_cols(1, 3));
    check_qr_parity(&dup, false);
    // A fully zero panel column mid-matrix.
    let mut with_zero = Mat::randn(&mut rng, 40, 10, 1.0);
    for i in 0..40 {
        with_zero[(i, 6)] = 0.0;
    }
    check_qr_parity(&with_zero, false);
}

#[test]
fn qr_property_random_shapes() {
    Prop::new(24).check("qr-blocked-vs-unblocked", |rng| {
        let k = dim(rng, 40); // crosses the panel width
        let m = k + rng.below(60);
        let a = Mat::randn(rng, m, k, 1.0);
        check_qr_parity(&a, true);
    });
}

#[test]
fn qr_determinism_across_worker_counts() {
    let _guard = WORKER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Rng::new(23);
    let a = Mat::randn(&mut rng, 120, 40, 1.0);
    let runs: Vec<_> = [1usize, 2, 8]
        .iter()
        .map(|&wkrs| {
            fusion::set_workers(wkrs);
            let f = householder_qr(&a);
            fusion::set_workers(0);
            f
        })
        .collect();
    for f in &runs[1..] {
        assert_eq!(f.q.data, runs[0].q.data, "Q diverged across workers");
        assert_eq!(f.r.data, runs[0].r.data, "R diverged across workers");
    }
}

#[test]
fn mofasgd_step_matches_frozen_reference() {
    // End-to-end guard: the workspace step (blocked QR + parallel Jacobi)
    // must track the frozen sequential reference trajectory. Factor signs
    // may flip pairwise, so compare weights and the reconstructed
    // momentum, which are sign-invariant.
    let mut rng = Rng::new(31);
    let (m, n, r) = (48, 40, 6);
    let mut opt_new = MoFaSgd::new(m, n, r, 0.9);
    let mut opt_ref = MoFaSgd::new(m, n, r, 0.9);
    let mut w_new = Mat::randn(&mut rng, m, n, 1.0);
    let mut w_ref = w_new.clone();
    for step in 0..4 {
        let g = Mat::randn(&mut rng, m, n, 1.0);
        opt_new.step(&mut w_new, &g, 0.05);
        opt_ref.step_reference(&mut w_ref, &g, 0.05);
        assert!(
            w_new.rel_err(&w_ref) < 2e-3,
            "weights diverged at step {step}: {}", w_new.rel_err(&w_ref)
        );
        assert!(
            opt_new.momentum_dense().rel_err(&opt_ref.momentum_dense())
                < 5e-3,
            "momentum diverged at step {step}"
        );
    }
}

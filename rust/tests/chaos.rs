//! Chaos lane (ISSUE 10): deterministic fault injection against the
//! serve stack. The contracts proven here:
//!
//! - **Survivor parity.** Panic session S at tick T in a 4-session run:
//!   the survivors' loss streams and final checkpoints are bit-identical
//!   to a run where S was never admitted — at workers ∈ {1, 2, 8}.
//! - **Crash-safe recovery.** A torn (injected) checkpoint write never
//!   poisons the store: recovery warn-skips it, falls back to the
//!   last-good snapshot, and the re-admitted session finishes
//!   bit-identical to a run that never crashed.
//! - **Slow is not wrong.** Injected stage delays reorder thread timing
//!   but never change a bit.
//! - **Determinism.** The same fault spec produces the same outcome,
//!   run after run.
//!
//! Fault specs are process-global (`util::faultinject`), so every test
//! here serializes on one gate; the check lanes additionally run this
//! binary with `RUST_TEST_THREADS=1`.

use mofasgd::coordinator::checkpoint::Checkpoint;
use mofasgd::serve::{CheckpointStore, LayerKind, LayerSpec,
                     SessionManager, SessionSpec, SessionState,
                     TickEvent, VecSpec};
use mofasgd::util::faultinject;

const WORKER_COUNTS: [usize; 3] = [1, 2, 8];

static GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|p| p.into_inner())
}

/// Small but representative tenant: three matrix optimizer kinds plus a
/// vec layer, inline noise.
fn chaos_spec(name: &str, seed: u64, steps: usize) -> SessionSpec {
    SessionSpec {
        name: name.to_string(),
        seed,
        steps,
        accum: 2,
        eta: 0.01,
        noise: 0.3,
        prefetch: 0,
        layers: vec![
            LayerSpec { kind: LayerKind::MoFaSgd, m: 16, n: 12, rank: 4,
                        beta: 0.9 },
            LayerSpec { kind: LayerKind::SgdM, m: 12, n: 16, rank: 4,
                        beta: 0.9 },
            LayerSpec { kind: LayerKind::SignSgd, m: 8, n: 8, rank: 4,
                        beta: 0.9 },
        ],
        vecs: vec![VecSpec { len: 32 }],
    }
}

/// All-restorable variant (no AdamW matrices, no vec layers) for the
/// crash-recovery round trip.
fn restorable_chaos_spec(seed: u64, steps: usize) -> SessionSpec {
    SessionSpec {
        name: "phoenix".to_string(),
        seed,
        steps,
        accum: 2,
        eta: 0.01,
        noise: 0.3,
        prefetch: 0,
        layers: vec![
            LayerSpec { kind: LayerKind::MoFaSgd, m: 24, n: 20, rank: 4,
                        beta: 0.9 },
            LayerSpec { kind: LayerKind::SgdM, m: 16, n: 16, rank: 4,
                        beta: 0.9 },
            LayerSpec { kind: LayerKind::SignSgd, m: 12, n: 12, rank: 4,
                        beta: 0.9 },
        ],
        vecs: vec![],
    }
}

/// Tick until nothing is Running; returns each session's loss bit
/// stream, in `ids` order (a session that fails mid-tick simply stops
/// producing metrics).
fn drive(mgr: &mut SessionManager, ids: &[u32], workers: usize)
         -> Vec<Vec<u64>> {
    let mut losses = vec![Vec::new(); ids.len()];
    let mut events = Vec::new();
    let mut guard = 0;
    while mgr.n_running() > 0 {
        events.clear();
        mgr.tick(workers, &mut events);
        for e in &events {
            if let TickEvent::Metrics { session, loss, .. } = e {
                let i =
                    ids.iter().position(|id| id == session).unwrap();
                losses[i].push(loss.to_bits());
            }
        }
        guard += 1;
        assert!(guard < 200, "ticks runaway");
    }
    losses
}

/// Bitwise view of a checkpoint.
fn ck_bits(ck: &Checkpoint) -> Vec<(String, Vec<usize>, Vec<u32>)> {
    ck.tensors
        .iter()
        .map(|(name, dims, data)| {
            (name.clone(), dims.clone(),
             data.iter().map(|x| x.to_bits()).collect())
        })
        .collect()
}

#[test]
fn survivors_bit_identical_to_never_admitted_baseline() {
    let _g = gate();
    let specs = [
        chaos_spec("alpha", 21, 7),
        chaos_spec("doomed", 22, 8),
        chaos_spec("gamma", 23, 6),
        chaos_spec("delta", 24, 9),
    ];
    for workers in WORKER_COUNTS {
        // Chaos run: all four tenants; the second admit (session id 2)
        // takes an injected stage panic on tick 5.
        faultinject::set_spec("panic@session:2/tick:5").unwrap();
        let mut mgr = SessionManager::new();
        let ids: Vec<u32> =
            specs.iter().map(|s| mgr.admit(s).unwrap()).collect();
        assert_eq!(ids[1], 2);
        let losses = drive(&mut mgr, &ids, workers);
        faultinject::clear();

        let doomed = mgr.get(ids[1]).unwrap();
        assert_eq!(doomed.state, SessionState::Failed, "w={workers}");
        let reason = doomed.fail_reason().unwrap();
        assert!(reason.contains("injected fault"), "{reason}");
        // Four clean ticks of metrics, then death on tick 5 — at every
        // worker count.
        assert_eq!(losses[1].len(), 4, "w={workers}");
        // Its buffers are quarantined: no checkpoint.
        assert!(mgr.checkpoint(ids[1]).is_err());

        // Baseline: the three survivors in a daemon that never admitted
        // the doomed tenant at all.
        faultinject::clear();
        let mut base = SessionManager::new();
        let survivors = [0usize, 2, 3];
        let bids: Vec<u32> = survivors
            .iter()
            .map(|&i| base.admit(&specs[i]).unwrap())
            .collect();
        let blosses = drive(&mut base, &bids, workers);
        for (bi, &si) in survivors.iter().enumerate() {
            assert_eq!(losses[si], blosses[bi],
                       "w={workers} survivor {}", specs[si].name);
            let ck = mgr.checkpoint(ids[si]).unwrap().1;
            let bck = base.checkpoint(bids[bi]).unwrap().1;
            assert_eq!(ck_bits(&ck), ck_bits(&bck),
                       "w={workers} survivor {}", specs[si].name);
        }
    }
}

#[test]
fn torn_checkpoint_write_recovers_to_last_good() {
    let _g = gate();
    faultinject::clear();
    let spec = restorable_chaos_spec(55, 6);

    // Uninterrupted reference run.
    let mut reference = SessionManager::new();
    let rid = reference.admit(&spec).unwrap();
    let rlosses = drive(&mut reference, &[rid], 2);
    let (rstep, rck) = reference.checkpoint(rid).unwrap();

    // Interrupted run: auto-checkpoint cadence of 2 ticks into a store;
    // the second store write (tick 4) is torn by an injected fault —
    // the crash-mid-save case `atomic_write_crc` exists for.
    let root = std::env::temp_dir()
        .join(format!("mofa-chaos-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let store = CheckpointStore::new(&root);
    let mut mgr = SessionManager::new();
    let id = mgr.admit(&spec).unwrap();
    let mut events = Vec::new();
    faultinject::set_spec("torn_write@ckpt:2").unwrap();
    for t in 1u64..=4 {
        events.clear();
        mgr.tick(2, &mut events);
        if t % 2 == 0 {
            let (step, ck) = mgr.checkpoint(id).unwrap();
            store.save(&spec, step, &ck).unwrap();
        }
    }
    faultinject::clear();
    drop(mgr); // the "crash": daemon state is gone, only the store is left

    // Recovery skips the torn newest snapshot, lands on last-good.
    let rec = store.recover_all();
    assert_eq!(rec.len(), 1);
    assert_eq!(rec[0].step, 2);
    assert_eq!(rec[0].spec.name, spec.name);

    // Re-admit and run out: bit-identical to never having crashed.
    let mut back = SessionManager::new();
    let bid = back.restore(&rec[0].spec, rec[0].step, &rec[0].ck).unwrap();
    let blosses = drive(&mut back, &[bid], 2);
    assert_eq!(blosses[0][..], rlosses[0][rec[0].step..]);
    let (bstep, bck) = back.checkpoint(bid).unwrap();
    assert_eq!(bstep, rstep);
    assert_eq!(ck_bits(&bck), ck_bits(&rck));
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn slow_stage_injection_does_not_perturb_parity() {
    let _g = gate();
    let specs = [chaos_spec("s0", 31, 5), chaos_spec("s1", 32, 5)];

    faultinject::clear();
    let mut clean = SessionManager::new();
    let cids: Vec<u32> =
        specs.iter().map(|s| clean.admit(s).unwrap()).collect();
    let clean_losses = drive(&mut clean, &cids, 8);

    // Session 1's first stage sleeps 3 ms every time it runs: maximal
    // thread-timing skew, zero numerical effect.
    faultinject::set_spec("slow@session:1/stage:0/ms:3").unwrap();
    let mut slow = SessionManager::new();
    let sids: Vec<u32> =
        specs.iter().map(|s| slow.admit(s).unwrap()).collect();
    let slow_losses = drive(&mut slow, &sids, 8);
    faultinject::clear();

    assert_eq!(slow_losses, clean_losses);
    for (ci, si) in cids.iter().zip(&sids) {
        assert_eq!(ck_bits(&clean.checkpoint(*ci).unwrap().1),
                   ck_bits(&slow.checkpoint(*si).unwrap().1));
    }
}

#[test]
fn chaos_outcome_is_deterministic_across_runs() {
    let _g = gate();
    let specs = [chaos_spec("d0", 41, 6), chaos_spec("d1", 42, 6)];
    let mut runs = Vec::new();
    for _ in 0..2 {
        faultinject::set_spec("panic@session:1/tick:3").unwrap();
        let mut mgr = SessionManager::new();
        let ids: Vec<u32> =
            specs.iter().map(|s| mgr.admit(s).unwrap()).collect();
        let losses = drive(&mut mgr, &ids, 8);
        faultinject::clear();
        let doomed = mgr.get(ids[0]).unwrap();
        assert_eq!(doomed.state, SessionState::Failed);
        // Died on tick 3 — exactly two clean ticks of metrics.
        assert_eq!(losses[0].len(), 2);
        let survivor_ck = ck_bits(&mgr.checkpoint(ids[1]).unwrap().1);
        runs.push((losses, survivor_ck));
    }
    assert_eq!(runs[0], runs[1]);
}

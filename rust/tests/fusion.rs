//! Fusion subsystem integration tests: random-graph property check
//! (fused planner vs naive `Mat` reference), fusion-structure assertions,
//! and fused-vs-reference parity for the rewired optimizer hot paths.

use mofasgd::fusion::{self, Graph, MatKind, SVal};
use mofasgd::linalg::Mat;
use mofasgd::optim::galore::GaLore;
use mofasgd::optim::mofasgd::MoFaSgd;
use mofasgd::optim::muon::newton_schulz;
use mofasgd::util::prop::{dim, Prop};
use mofasgd::util::rng::Rng;

fn f_tanh(x: f32) -> f32 {
    x.tanh()
}

fn f_sq(x: f32) -> f32 {
    x * x
}

fn f_relu(x: f32) -> f32 {
    x.max(0.0)
}

fn z_mix(a: f32, b: f32) -> f32 {
    0.5 * a + 0.25 * b
}

fn z_safe_div(a: f32, b: f32) -> f32 {
    a / (b.abs() + 1.0)
}

fn z_max(a: f32, b: f32) -> f32 {
    a.max(b)
}

/// One random straight-line graph over a fixed buffer skeleton; executed
/// through the fused planner and compared to the naive interpreter.
fn random_graph_case(rng: &mut Rng) {
    let m = dim(rng, 10);
    let k = dim(rng, 10);
    let n = dim(rng, 10);
    let mut g = Graph::new();
    let ia = g.input(m, k);
    let ib = g.input(k, n);
    let ic = g.input(m, n);
    let ibt = g.input(n, k);
    let iat = g.input(k, m);
    let e1 = g.ext(m, n);
    let e2 = g.ext(m, n);
    let p0 = g.param();
    let p1 = g.param();

    let maps: [fn(f32) -> f32; 3] = [f_tanh, f_sq, f_relu];
    let zips: [fn(f32, f32) -> f32; 3] = [z_mix, z_safe_div, z_max];

    // readable (m,n)-shaped buffers; writable excludes the input `ic`.
    let mut readable = vec![ic, e1, e2];
    let mut writable = vec![e1, e2];

    let pick_sval = |rng: &mut Rng| -> SVal {
        match rng.below(4) {
            0 => SVal::Lit(1.0),
            1 => SVal::Lit(-0.5),
            2 => p0,
            _ => p1,
        }
    };

    let n_ops = 2 + rng.below(6);
    for _ in 0..n_ops {
        match rng.below(8) {
            0 => {
                let out = g.temp(m, n);
                let al = pick_sval(rng);
                g.matmul(MatKind::NN, ia, ib, out, al, SVal::Lit(0.0));
                readable.push(out);
                writable.push(out);
            }
            1 => {
                let be = pick_sval(rng);
                g.matmul(MatKind::NN, ia, ib, e1, SVal::Lit(1.0), be);
            }
            2 => {
                let out = g.temp(m, n);
                let al = pick_sval(rng);
                g.matmul(MatKind::NT, ia, ibt, out, al, SVal::Lit(0.0));
                readable.push(out);
                writable.push(out);
            }
            3 => {
                let out = g.temp(m, n);
                g.matmul(MatKind::TN, iat, ib, out, SVal::Lit(1.0),
                         SVal::Lit(0.0));
                readable.push(out);
                writable.push(out);
            }
            4 => {
                let x = readable[rng.below(readable.len())];
                let y = readable[rng.below(readable.len())];
                let out = writable[rng.below(writable.len())];
                let (a, b) = (pick_sval(rng), pick_sval(rng));
                g.axpy(out, a, x, b, y);
            }
            5 => {
                let x = readable[rng.below(readable.len())];
                let out = writable[rng.below(writable.len())];
                g.scale(out, pick_sval(rng), x);
            }
            6 => {
                let x = readable[rng.below(readable.len())];
                let out = writable[rng.below(writable.len())];
                g.map(out, x, maps[rng.below(maps.len())]);
            }
            _ => {
                let x = readable[rng.below(readable.len())];
                let y = readable[rng.below(readable.len())];
                let out = writable[rng.below(writable.len())];
                if rng.below(2) == 0 {
                    g.mul(out, x, y);
                } else {
                    g.zip(out, x, y, zips[rng.below(zips.len())]);
                }
            }
        }
    }
    // Make sure both observable buffers depend on the run.
    let x = readable[rng.below(readable.len())];
    g.axpy(e1, SVal::Lit(1.0), e1, pick_sval(rng), x);
    let y = readable[rng.below(readable.len())];
    g.axpy(e2, SVal::Lit(0.5), e2, SVal::Lit(0.5), y);

    // Data.
    let a_m = Mat::randn(rng, m, k, 1.0);
    let b_m = Mat::randn(rng, k, n, 1.0);
    let c_m = Mat::randn(rng, m, n, 1.0);
    let bt_m = Mat::randn(rng, n, k, 1.0);
    let at_m = Mat::randn(rng, k, m, 1.0);
    let e1_0 = Mat::randn(rng, m, n, 1.0);
    let e2_0 = Mat::randn(rng, m, n, 1.0);
    let params = [0.7f32, -1.3f32];

    let mut want = [e1_0.clone(), e2_0.clone()];
    g.eval_naive(&[&a_m, &b_m, &c_m, &bt_m, &at_m], &mut want, &params);

    let plan = fusion::compile(&g);
    let mut ws = plan.workspace();
    let mut got1 = e1_0.clone();
    let mut got2 = e2_0.clone();
    {
        let ins = [&a_m.data[..], &b_m.data[..], &c_m.data[..],
                   &bt_m.data[..], &at_m.data[..]];
        let mut exts = [&mut got1.data[..], &mut got2.data[..]];
        let workers = 1 + rng.below(3);
        plan.execute(&mut ws, &ins, &mut exts, &params, workers);
    }
    let err1 = got1.rel_err(&want[0]);
    let err2 = got2.rel_err(&want[1]);
    assert!(err1 < 1e-5 && err2 < 1e-5,
            "fused vs naive divergence: e1 {err1} e2 {err2} \
             ({} ops, {} nodes)", n_ops + 2, plan.n_nodes());
}

#[test]
fn property_random_graphs_fused_matches_naive() {
    Prop::new(64).check("fusion-vs-naive", random_graph_case);
}

#[test]
fn gemm_axpy_fuses_into_single_node() {
    // The canonical W ← W − η·U·Vᵀ pattern must compile to ONE GEMM node
    // with the accumulate folded into alpha/beta, and no surviving temp.
    let (m, n, r) = (12, 9, 3);
    let mut g = Graph::new();
    let u = g.input(m, r);
    let v = g.input(n, r);
    let w = g.ext(m, n);
    let eta = g.param();
    let t = g.temp(m, n);
    g.matmul(MatKind::NT, u, v, t, SVal::Lit(1.0), SVal::Lit(0.0));
    g.axpy(w, SVal::Lit(1.0), w, eta, t);
    let plan = fusion::compile(&g);
    assert_eq!(plan.n_nodes(), 1, "axpy should fuse into the gemm");
    assert_eq!(plan.n_gemm_nodes(), 1);
    assert_eq!(plan.n_temps(), 0, "uvt temp should be fused away");

    let mut rng = Rng::new(5);
    let um = Mat::randn(&mut rng, m, r, 1.0);
    let vm = Mat::randn(&mut rng, n, r, 1.0);
    let w0 = Mat::randn(&mut rng, m, n, 1.0);
    let mut got = w0.clone();
    let mut ws = plan.workspace();
    {
        let ins = [&um.data[..], &vm.data[..]];
        let mut exts = [&mut got.data[..]];
        plan.execute(&mut ws, &ins, &mut exts, &[-0.1], 2);
    }
    let want = w0.sub(&um.matmul_t(&vm).scale(0.1));
    assert!(got.rel_err(&want) < 1e-5);
}

#[test]
fn adam_style_chain_fuses() {
    // The GaLore-shaped step graph: 8 ops should collapse to ≤ 5 nodes
    // (two moment chains, two bias-corrected ratio passes, one GEMM) and
    // exactly two surviving r×n temps.
    let (m, n, r) = (16, 12, 4);
    let mut g = Graph::new();
    let gr = g.input(r, n);
    let q = g.input(m, r);
    let m1 = g.ext(r, n);
    let m2 = g.ext(r, n);
    let w = g.ext(m, n);
    let p_b1 = g.param();
    let p_omb1 = g.param();
    let p_b2 = g.param();
    let p_omb2 = g.param();
    let p_i1 = g.param();
    let p_i2 = g.param();
    let p_ne = g.param();
    let t_gr2 = g.temp(r, n);
    let t_m1h = g.temp(r, n);
    let t_m2h = g.temp(r, n);
    let t_upd = g.temp(r, n);
    let t_full = g.temp(m, n);
    g.axpy(m1, p_b1, m1, p_omb1, gr);
    g.mul(t_gr2, gr, gr);
    g.axpy(m2, p_b2, m2, p_omb2, t_gr2);
    g.scale(t_m1h, p_i1, m1);
    g.scale(t_m2h, p_i2, m2);
    g.zip(t_upd, t_m1h, t_m2h, z_safe_div);
    g.matmul(MatKind::NN, q, t_upd, t_full, SVal::Lit(1.0), SVal::Lit(0.0));
    g.axpy(w, SVal::Lit(1.0), w, p_ne, t_full);

    let plan = fusion::compile(&g);
    assert!(plan.n_nodes() <= 5, "expected ≤5 fused nodes, got {}",
            plan.n_nodes());
    assert_eq!(plan.n_gemm_nodes(), 1);
    assert_eq!(plan.n_temps(), 2, "only m1h and upd staging should survive");
}

#[test]
fn chain_retarget_keeps_own_reads_bound_to_old_buffer() {
    // Regression: a chain step recorded as "read my own output" (the
    // in-place zip on t) must keep reading t after a later op retargets
    // the chain's output to u — not follow the output to u.
    let (m, k, n) = (6, 5, 7);
    let mut g = Graph::new();
    let a = g.input(m, k);
    let b = g.input(k, n);
    let c = g.input(m, n);
    let u = g.ext(m, n);
    let s = g.param();
    let t = g.temp(m, n);
    g.matmul(MatKind::NN, a, b, t, SVal::Lit(1.0), SVal::Lit(0.0));
    g.zip(t, t, c, z_mix); // in-place: reads t (the product), writes t
    g.scale(u, s, t); // retargets the chain's out from t to u

    let mut rng = Rng::new(29);
    let am = Mat::randn(&mut rng, m, k, 1.0);
    let bm = Mat::randn(&mut rng, k, n, 1.0);
    let cm = Mat::randn(&mut rng, m, n, 1.0);
    let u0 = Mat::randn(&mut rng, m, n, 1.0);
    let params = [1.7f32];

    let mut want = [u0.clone()];
    g.eval_naive(&[&am, &bm, &cm], &mut want, &params);

    let plan = fusion::compile(&g);
    let mut ws = plan.workspace();
    let mut got = u0.clone();
    {
        let ins = [&am.data[..], &bm.data[..], &cm.data[..]];
        let mut exts = [&mut got.data[..]];
        plan.execute(&mut ws, &ins, &mut exts, &params, 1);
    }
    assert!(got.rel_err(&want[0]) < 1e-5,
            "own-read rebinding broke: {}", got.rel_err(&want[0]));
    // Sanity on the expected value itself.
    let prod = am.matmul(&bm);
    let expect = prod
        .zip(&cm, z_mix)
        .scale(1.7);
    assert!(got.rel_err(&expect) < 1e-5);
}

#[test]
#[should_panic(expected = "ext binding 0 size")]
fn execute_rejects_undersized_bindings() {
    let mut g = Graph::new();
    let a = g.input(4, 4);
    let w = g.ext(4, 4);
    g.axpy(w, SVal::Lit(1.0), w, SVal::Lit(1.0), a);
    let plan = fusion::compile(&g);
    let mut ws = plan.workspace();
    let a_data = vec![0.0f32; 16];
    let mut short = vec![0.0f32; 15]; // one element short
    let ins = [&a_data[..]];
    let mut exts = [&mut short[..]];
    plan.execute(&mut ws, &ins, &mut exts, &[], 1);
}

#[test]
fn mofasgd_fused_matches_reference_trajectory() {
    // The rewired (fused, parallel) step must track the frozen
    // pre-refactor sequential reference over a multi-step trajectory.
    let mut rng = Rng::new(11);
    let (m, n, r) = (48, 40, 6);
    let mut fused = MoFaSgd::new(m, n, r, 0.9);
    let mut reference = MoFaSgd::new(m, n, r, 0.9);
    let mut w_f = Mat::randn(&mut rng, m, n, 1.0);
    let mut w_r = w_f.clone();
    for step in 0..5 {
        let g = Mat::randn(&mut rng, m, n, 1.0);
        fused.step(&mut w_f, &g, 0.02);
        reference.step_reference(&mut w_r, &g, 0.02);
        let werr = w_f.rel_err(&w_r);
        let merr = fused.momentum_dense().rel_err(&reference.momentum_dense());
        assert!(werr < 1e-3, "step {step}: weight divergence {werr}");
        assert!(merr < 1e-3, "step {step}: momentum divergence {merr}");
    }
}

#[test]
fn mofasgd_fused_accumulate_matches_projection_sums() {
    let mut rng = Rng::new(13);
    let (m, n, r, micro) = (32, 24, 4, 3);
    let mut opt = MoFaSgd::new(m, n, r, 0.9);
    let g0 = Mat::randn(&mut rng, m, n, 1.0);
    let mut w = Mat::randn(&mut rng, m, n, 1.0);
    opt.step(&mut w, &g0, 0.01); // init factors
    let gs: Vec<Mat> =
        (0..micro).map(|_| Mat::randn(&mut rng, m, n, 1.0)).collect();
    let mut buf = mofasgd::optim::mofasgd::LowRankBuffers::zeros(m, n, r);
    for g in &gs {
        opt.accumulate(g, &mut buf);
    }
    // Reference sums through plain Mat ops.
    let (mut gv, mut utg, mut utgv) =
        (Mat::zeros(m, r), Mat::zeros(r, n), Mat::zeros(r, r));
    for g in &gs {
        gv.axpy_inplace(1.0, 1.0, &g.matmul(&opt.v));
        let pu = opt.u.t_matmul(g);
        utg.axpy_inplace(1.0, 1.0, &pu);
        utgv.axpy_inplace(1.0, 1.0, &pu.matmul(&opt.v));
    }
    assert!(buf.gv.rel_err(&gv) < 1e-5);
    assert!(buf.utg.rel_err(&utg) < 1e-5);
    assert!(buf.utgv.rel_err(&utgv) < 1e-5);
    assert_eq!(buf.count, micro);
}

#[test]
fn galore_fused_step_matches_naive_formulas() {
    let mut rng = Rng::new(17);
    let (m, n, r) = (28, 20, 4);
    let mut opt = GaLore::new(m, n, r, 1000, 0.9, 0.999, 3);
    let g0 = Mat::randn(&mut rng, m, n, 1.0);
    opt.resample(&g0);
    let mut w = Mat::randn(&mut rng, m, n, 1.0);
    for t in 1..=3 {
        let gr = Mat::randn(&mut rng, r, n, 1.0);
        // Naive reference of one Adam-in-subspace step (old code path).
        let eps = 1e-8f32;
        let mut m1 = opt.m1.clone();
        let mut m2 = opt.m2.clone();
        m1.axpy_inplace(0.9, 0.1, &gr);
        let gr2 = gr.zip(&gr, |a, b| a * b);
        m2.axpy_inplace(0.999, 0.001, &gr2);
        let bc1 = 1.0 - 0.9f32.powi(t);
        let bc2 = 1.0 - 0.999f32.powi(t);
        let upd = m1.zip(&m2, |mv, vv| {
            (mv / bc1) / ((vv / bc2).max(0.0).sqrt() + eps)
        });
        let want_w = w.sub(&opt.q.matmul(&upd).scale(0.01));
        opt.step_from_subspace_grad(&mut w, &gr, 0.01);
        assert!(opt.m1.rel_err(&m1) < 1e-5, "t={t} m1");
        assert!(opt.m2.rel_err(&m2) < 1e-5, "t={t} m2");
        assert!(w.rel_err(&want_w) < 1e-5, "t={t} w {}", w.rel_err(&want_w));
    }
}

#[test]
fn muon_newton_schulz_matches_naive_reference() {
    let mut rng = Rng::new(19);
    for (m, n) in [(24, 24), (40, 16), (16, 40)] {
        let a = Mat::randn(&mut rng, m, n, 1.0);
        let got = newton_schulz(&a, 5);
        // Frozen naive reference of the quintic iteration.
        let (ca, cb, cc) = (3.4445f32, -4.7750f32, 2.0315f32);
        let transpose = m > n;
        let mut x = if transpose { a.t() } else { a.clone() };
        let nrm = x.frob_norm() + 1e-7;
        x = x.scale(1.0 / nrm);
        for _ in 0..5 {
            let g = x.matmul_t(&x);
            let gg = g.matmul(&g);
            let poly = g.scale(cb).add(&gg.scale(cc));
            x = x.scale(ca).add(&poly.matmul(&x));
        }
        let want = if transpose { x.t() } else { x };
        assert!(got.rel_err(&want) < 1e-4, "{m}x{n}: {}", got.rel_err(&want));
    }
}

#[test]
fn workspace_reuse_is_deterministic() {
    let (m, n, r) = (20, 14, 3);
    let mut g = Graph::new();
    let grad = g.input(m, n);
    let v = g.input(n, r);
    let gv = g.ext(m, r);
    let t = g.temp(m, r);
    g.matmul(MatKind::NN, grad, v, t, SVal::Lit(2.0), SVal::Lit(0.0));
    g.map(gv, t, f_tanh);
    let plan = fusion::compile(&g);
    let mut ws = plan.workspace();
    let size0 = ws.floats();

    let mut rng = Rng::new(23);
    let gm = Mat::randn(&mut rng, m, n, 1.0);
    let vm = Mat::randn(&mut rng, n, r, 1.0);
    let mut first: Option<Mat> = None;
    for _ in 0..4 {
        let mut out = Mat::zeros(m, r);
        {
            let ins = [&gm.data[..], &vm.data[..]];
            let mut exts = [&mut out.data[..]];
            plan.execute(&mut ws, &ins, &mut exts, &[], 2);
        }
        assert_eq!(ws.floats(), size0, "arena grew across executions");
        match &first {
            None => first = Some(out),
            Some(f) => assert_eq!(f.data, out.data,
                                  "re-execution not deterministic"),
        }
    }
}

//! Steady-state zero-allocation proof for the fused plan executor AND the
//! full native MoFaSGD step.
//!
//! A counting global allocator wraps `System`; after a warm-up execution,
//! steady-state executions of (a) a compiled optimizer-step plan and (b) a
//! complete `MoFaSgd::step` — projections, blocked QR, parallel-Jacobi
//! core SVD, spectral update — must not allocate at all (workers = 1 —
//! with more workers the only allocations are the OS thread spawns inside
//! `std::thread::scope`).
//!
//! This file intentionally contains a single test: allocation counts are
//! process-global and other tests running concurrently would pollute them.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use mofasgd::fusion::reduce::{LanePtr, TreeSchedule, TREE_WIDTH};
use mofasgd::fusion::{self, FleetUnit, Graph, MatKind, ReplicaSet, SVal};
use mofasgd::linalg::Mat;
use mofasgd::optim::adamw::AdamWVec;
use mofasgd::optim::{AdamW, GaLore, GradAccumUnit, MatOpt, MatUnit,
                     MatrixOptimizer, MoFaSgd, SgdM, TreeReduceUnit,
                     VecUnit};
use mofasgd::serve::{LayerKind, LayerSpec, SessionManager, SessionSpec,
                     TickEvent, VecSpec};
use mofasgd::util::rng::Rng;

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_plan_execution_is_allocation_free() {
    // GaLore-shaped fused step: two moment chains, a ratio chain, and a
    // back-projection GEMM with the W accumulate in its epilogue.
    let (m, n, r) = (96, 64, 8);
    let mut g = Graph::new();
    let gr = g.input(r, n);
    let q = g.input(m, r);
    let m1 = g.ext(r, n);
    let m2 = g.ext(r, n);
    let w = g.ext(m, n);
    let p_b1 = g.param();
    let p_omb1 = g.param();
    let p_neg_eta = g.param();
    let t_gr2 = g.temp(r, n);
    let t_upd = g.temp(r, n);
    let t_full = g.temp(m, n);
    fn ratio(a: f32, b: f32) -> f32 {
        a / (b.abs().sqrt() + 1e-8)
    }
    g.axpy(m1, p_b1, m1, p_omb1, gr);
    g.mul(t_gr2, gr, gr);
    g.axpy(m2, p_b1, m2, p_omb1, t_gr2);
    g.zip(t_upd, m1, m2, ratio);
    g.matmul(MatKind::NN, q, t_upd, t_full, SVal::Lit(1.0), SVal::Lit(0.0));
    g.axpy(w, SVal::Lit(1.0), w, p_neg_eta, t_full);

    let plan = fusion::compile(&g);
    let mut ws = plan.workspace();
    let arena = ws.floats();

    let mut rng = Rng::new(1);
    let gr_m = Mat::randn(&mut rng, r, n, 1.0);
    let q_m = Mat::randn(&mut rng, m, r, 1.0);
    let mut m1_m = Mat::zeros(r, n);
    let mut m2_m = Mat::zeros(r, n);
    let mut w_m = Mat::randn(&mut rng, m, n, 1.0);
    let params = [0.9f32, 0.1, -0.01];

    // Warm-up execution (fills moments; everything is preallocated).
    {
        let ins = [&gr_m.data[..], &q_m.data[..]];
        let mut exts = [&mut m1_m.data[..], &mut m2_m.data[..],
                        &mut w_m.data[..]];
        plan.execute(&mut ws, &ins, &mut exts, &params, 1);
    }

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..10 {
        let ins = [&gr_m.data[..], &q_m.data[..]];
        let mut exts = [&mut m1_m.data[..], &mut m2_m.data[..],
                        &mut w_m.data[..]];
        plan.execute(&mut ws, &ins, &mut exts, &params, 1);
    }
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(delta, 0,
               "steady-state fused step allocated {delta} times");
    assert_eq!(ws.floats(), arena, "arena changed size");
    assert!(w_m.data.iter().all(|v| v.is_finite()));

    // -- full MoFaSgd::step: tangent projections + blocked QR + 2r×2r
    //    parallel-Jacobi SVD + spectral update, all on the persistent
    //    workspace — zero allocations after one warm-up step.
    fusion::set_workers(1);
    let (sm, sn) = (96, 80);
    for umf_r in [4usize, 32] {
        let mut opt = MoFaSgd::new(sm, sn, umf_r, 0.9);
        let mut wmat = Mat::randn(&mut rng, sm, sn, 1.0);
        let g1 = Mat::randn(&mut rng, sm, sn, 1.0);
        let g2 = Mat::randn(&mut rng, sm, sn, 1.0);
        opt.step(&mut wmat, &g1, 1e-3); // SVD_r init
        opt.step(&mut wmat, &g2, 1e-3); // warm-up: sizes all scratch
        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..5 {
            opt.step(&mut wmat, &g1, 1e-3);
            opt.step(&mut wmat, &g2, 1e-3);
        }
        let delta = ALLOCS.load(Ordering::SeqCst) - before;
        assert_eq!(
            delta, 0,
            "steady-state MoFaSgd::step r={umf_r} allocated {delta} times"
        );
        assert!(wmat.data.iter().all(|v| v.is_finite()));
    }

    // -- full multi-layer fleet step: MoFaSgd r∈{4,32} + GaLore + dense
    //    AdamW/SGD-M matrix layers + a flat vec-AdamW layer, executed as
    //    one dispatch through fusion::Fleet. Adapters and the Fleet's
    //    scheduling storage are built once and reused; after one warm-up
    //    step (SVD_r init, subspace init, scratch sizing) steady-state
    //    fleet steps must not allocate at all at workers = 1.
    {
        let mut mofa4 = MoFaSgd::new(64, 48, 4, 0.9);
        let mut mofa32 = MoFaSgd::new(96, 80, 32, 0.9);
        // resample_every beyond the step count: the offline resample's
        // randomized range finder is an allocating (and rare) event by
        // design, so it stays out of the steady-state window.
        let mut gal = GaLore::new(48, 40, 8, 1000, 0.9, 0.999, 3);
        let mut adw = AdamW::new(56, 24, 0.9, 0.999, 0.0);
        let mut sgdm = SgdM::new(32, 64, 0.9);
        let mut vadw = AdamWVec::new(512, 0.9, 0.999, 0.0);
        let mut w4 = Mat::randn(&mut rng, 64, 48, 1.0);
        let mut w32 = Mat::randn(&mut rng, 96, 80, 1.0);
        let mut wg = Mat::randn(&mut rng, 48, 40, 1.0);
        let mut wa = Mat::randn(&mut rng, 56, 24, 1.0);
        let mut wsg = Mat::randn(&mut rng, 32, 64, 1.0);
        let mut wv: Vec<f32> = rng.normal_vec(512, 1.0);
        let g4 = Mat::randn(&mut rng, 64, 48, 1.0);
        let g32 = Mat::randn(&mut rng, 96, 80, 1.0);
        let gg = Mat::randn(&mut rng, 48, 40, 1.0);
        let ga = Mat::randn(&mut rng, 56, 24, 1.0);
        let gsg = Mat::randn(&mut rng, 32, 64, 1.0);
        let gv: Vec<f32> = rng.normal_vec(512, 1.0);

        {
            let mut u0 = MatUnit::new(MatOpt::MoFaSgd(&mut mofa4), &mut w4,
                                      &g4, 1e-3);
            let mut u1 = MatUnit::new(MatOpt::MoFaSgd(&mut mofa32),
                                      &mut w32, &g32, 1e-3);
            let mut u2 = MatUnit::new(MatOpt::GaLore(&mut gal), &mut wg,
                                      &gg, 1e-3);
            let mut u3 = MatUnit::new(MatOpt::AdamW(&mut adw), &mut wa,
                                      &ga, 1e-3);
            let mut u4 = MatUnit::new(MatOpt::SgdM(&mut sgdm), &mut wsg,
                                      &gsg, 1e-3);
            let mut u5 = VecUnit::new(&mut vadw, &mut wv, &gv, 1e-3);
            let mut fleet = fusion::Fleet::new();
            let mut refs: [&mut dyn FleetUnit; 6] =
                [&mut u0, &mut u1, &mut u2, &mut u3, &mut u4, &mut u5];
            // Warm-up: init paths + scratch sizing, then one full
            // steady-shape step.
            fleet.run(&mut refs, 1);
            fleet.run(&mut refs, 1);
            let before = ALLOCS.load(Ordering::SeqCst);
            for _ in 0..5 {
                fleet.run(&mut refs, 1);
            }
            let delta = ALLOCS.load(Ordering::SeqCst) - before;
            assert_eq!(
                delta, 0,
                "steady-state multi-layer fleet step allocated {delta} times"
            );
        }
        assert!(w4.data.iter().all(|v| v.is_finite()));
        assert!(w32.data.iter().all(|v| v.is_finite()));
        assert!(wg.data.iter().all(|v| v.is_finite()));
        assert!(wv.iter().all(|v| v.is_finite()));
    }

    // -- replicated steady-state step (DESIGN.md §13): two replicas per
    //    layer sharding 3 micro-batches into the fixed lane tree, tree
    //    reduce, then the optimizer step — one `run_replicated` dispatch
    //    per step. Lane Mats and the schedule are built once; unit and
    //    `ReplicaSet` construction is allocation-free by design (stack
    //    arrays + borrowed lanes), so after warm-up a whole replicated
    //    step must not allocate at all at workers = 1.
    {
        let sched = TreeSchedule::new(3, TREE_WIDTH);
        let mut mofa = MoFaSgd::new(64, 48, 4, 0.9);
        let mut sgdm = SgdM::new(32, 64, 0.9);
        let mut vadw = AdamWVec::new(256, 0.9, 0.999, 0.0);
        let mut wm = Mat::randn(&mut rng, 64, 48, 1.0);
        let mut wsg = Mat::randn(&mut rng, 32, 64, 1.0);
        let mut wv: Vec<f32> = rng.normal_vec(256, 1.0);
        let gm: Vec<Mat> =
            (0..3).map(|_| Mat::randn(&mut rng, 64, 48, 1.0)).collect();
        let gs: Vec<Mat> =
            (0..3).map(|_| Mat::randn(&mut rng, 32, 64, 1.0)).collect();
        let gv: Vec<Mat> = (0..3)
            .map(|_| Mat::from_vec(1, 256, rng.normal_vec(256, 1.0)))
            .collect();
        let mut lanes_m: Vec<Mat> =
            (0..TREE_WIDTH).map(|_| Mat::zeros(64, 48)).collect();
        let mut lanes_s: Vec<Mat> =
            (0..TREE_WIDTH).map(|_| Mat::zeros(32, 64)).collect();
        let mut lanes_v: Vec<Mat> =
            (0..TREE_WIDTH).map(|_| Mat::zeros(1, 256)).collect();
        let lpm = LanePtr::new(&mut lanes_m);
        let lps = LanePtr::new(&mut lanes_s);
        let lpv = LanePtr::new(&mut lanes_v);
        let mut fleet = fusion::Fleet::new();
        let mut do_step = |fl: &mut fusion::Fleet| {
            let mut am0 = GradAccumUnit::new(lpm, &sched, &gm, 0, 2);
            let mut am1 = GradAccumUnit::new(lpm, &sched, &gm, 1, 2);
            let mut as0 = GradAccumUnit::new(lps, &sched, &gs, 0, 2);
            let mut as1 = GradAccumUnit::new(lps, &sched, &gs, 1, 2);
            let mut av0 = GradAccumUnit::new(lpv, &sched, &gv, 0, 2);
            let mut av1 = GradAccumUnit::new(lpv, &sched, &gv, 1, 2);
            let mut rm = TreeReduceUnit::new(lpm, &sched);
            let mut rs = TreeReduceUnit::new(lps, &sched);
            let mut rv = TreeReduceUnit::new(lpv, &sched);
            let mut sm = MatUnit::reduced(MatOpt::MoFaSgd(&mut mofa),
                                          &mut wm, lpm, 1e-3);
            let mut ss = MatUnit::reduced(MatOpt::SgdM(&mut sgdm),
                                          &mut wsg, lps, 1e-3);
            let mut sv = VecUnit::reduced(&mut vadw, &mut wv, lpv, 1e-3);
            let mut acc_m: [&mut dyn FleetUnit; 2] = [&mut am0, &mut am1];
            let mut acc_s: [&mut dyn FleetUnit; 2] = [&mut as0, &mut as1];
            let mut acc_v: [&mut dyn FleetUnit; 2] = [&mut av0, &mut av1];
            let mut sets = [
                ReplicaSet { accum: &mut acc_m, reduce: &mut rm,
                             step: &mut sm },
                ReplicaSet { accum: &mut acc_s, reduce: &mut rs,
                             step: &mut ss },
                ReplicaSet { accum: &mut acc_v, reduce: &mut rv,
                             step: &mut sv },
            ];
            fl.run_replicated(&mut sets, 1);
        };
        // Warm-up: MoFaSGD SVD_r init + scratch sizing, then one
        // steady-shape replicated step.
        do_step(&mut fleet);
        do_step(&mut fleet);
        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..5 {
            do_step(&mut fleet);
        }
        let delta = ALLOCS.load(Ordering::SeqCst) - before;
        assert_eq!(
            delta, 0,
            "steady-state replicated step allocated {delta} times"
        );
        assert!(wm.data.iter().all(|v| v.is_finite()));
        assert!(wsg.data.iter().all(|v| v.is_finite()));
        assert!(wv.iter().all(|v| v.is_finite()));
    }

    // -- serve daemon steady-state tick (DESIGN.md §14): two multiplexed
    //    sessions over every serve-eligible zero-alloc optimizer kind
    //    (no Muon — Newton–Schulz allocates its iterates per call) with
    //    inline noise (prefetch = 0). Session state, lanes, and micro
    //    buffers are built at admit; the caller owns the events Vec; at
    //    workers = 1 the tick drains every chain inline without building
    //    a dispatch table — so after warm-up (MoFaSGD SVD_r init +
    //    scratch sizing) a whole multi-tenant tick must not allocate.
    {
        let layer = |kind, m, n| LayerSpec { kind, m, n, rank: 4,
                                             beta: 0.9 };
        let spec = |name: &str, seed| SessionSpec {
            name: name.to_string(),
            seed,
            steps: 1000,
            accum: 3,
            eta: 0.01,
            noise: 0.5,
            prefetch: 0,
            layers: vec![
                layer(LayerKind::MoFaSgd, 48, 40),
                layer(LayerKind::AdamW, 32, 20),
                layer(LayerKind::SgdM, 20, 36),
                layer(LayerKind::SignSgd, 16, 16),
            ],
            vecs: vec![VecSpec { len: 128 }],
        };
        let mut mgr = SessionManager::new();
        mgr.admit(&spec("tenant-a", 5)).unwrap();
        mgr.admit(&spec("tenant-b", 6)).unwrap();
        let mut events: Vec<TickEvent> = Vec::with_capacity(8);
        // Warm-up: MoFaSGD init tick, then two steady-shape ticks.
        for _ in 0..3 {
            events.clear();
            mgr.tick(1, &mut events);
        }
        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..5 {
            events.clear();
            mgr.tick(1, &mut events);
        }
        let delta = ALLOCS.load(Ordering::SeqCst) - before;
        assert_eq!(
            delta, 0,
            "steady-state serve tick allocated {delta} times"
        );
        assert_eq!(events.len(), 2, "one metrics event per session");
        for e in &events {
            match e {
                TickEvent::Metrics { loss, .. } => {
                    assert!(loss.is_finite())
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
    }
    fusion::set_workers(0); // restore auto resolution
}

//! Autotuner suite: per-variant parity against the frozen naive
//! baselines, the per-variant determinism contract across worker
//! counts, and the persistent winner-table lifecycle.
//!
//! Layout discipline: the parity tests drive `kernels::gemm_v` directly
//! — no global mode/table/cache involvement — so they run in parallel.
//! Everything that touches process-global state (the mode atomic, the
//! winner table, the cache file, the obs recorder) lives in the single
//! `autotune_global_lifecycle` test, same pattern as the obs recorder's
//! `recorder_roundtrip`.

use mofasgd::fusion::autotune::{self, Mode};
use mofasgd::fusion::kernels::{gemm_v, static_variant, KernelVariant};
use mofasgd::fusion::{compile, Graph, MatKind, SVal};
use mofasgd::linalg::Mat;
use mofasgd::obs;
use mofasgd::util::json::Json;
use mofasgd::util::rng::Rng;

/// Frozen sequential reference: the naive `Mat` kernels the fused path
/// has been property-tested against since PR 1.
fn gemm_ref(kind: MatKind, a: &Mat, b: &Mat, alpha: f32, beta: f32,
            prior: &Mat) -> Mat {
    let prod = match kind {
        MatKind::NN => a.matmul(b),
        MatKind::TN => a.t_matmul(b),
        MatKind::NT => a.matmul_t(b),
    };
    prior.scale(beta).add(&prod.scale(alpha))
}

fn operands(rng: &mut Rng, kind: MatKind, m: usize, n: usize, k: usize)
            -> (Mat, Mat) {
    let (sa, sb) = match kind {
        MatKind::NN => ((m, k), (k, n)),
        MatKind::TN => ((k, m), (k, n)),
        MatKind::NT => ((m, k), (n, k)),
    };
    (Mat::randn(rng, sa.0, sa.1, 1.0), Mat::randn(rng, sb.0, sb.1, 1.0))
}

/// The UMF shape families the tuner exists for, plus awkward odd sizes:
/// thin m×r, its transpose-heavy r×n cousins, square r×r cores, and
/// shapes straddling the KC/NC and KC_THIN/NC_THIN panel boundaries.
const SHAPES: [(usize, usize, usize); 7] = [
    (64, 8, 48),    // thin m×r projection
    (8, 64, 8),     // r×n with tiny k
    (16, 16, 16),   // square r×r core
    (33, 17, 300),  // multi-KC k, odd dims
    (5, 600, 70),   // wide n crossing NC_THIN and lane tails
    (1, 3, 130),    // single row, tail-only columns
    (48, 9, 513),   // k just past the KC_THIN panel
];

#[test]
fn every_variant_matches_frozen_baseline() {
    let mut rng = Rng::new(11);
    for v in KernelVariant::ALL {
        for &(m, n, k) in &SHAPES {
            let (a, b) = operands(&mut rng, v.kind(), m, n, k);
            let prior = Mat::randn(&mut rng, m, n, 1.0);
            let want = gemm_ref(v.kind(), &a, &b, 0.7, 0.3, &prior);
            let mut out = prior.clone();
            gemm_v(v, m, n, k, &a.data, &b.data, 0.7, 0.3, &mut out.data,
                   &[], 1);
            assert!(out.rel_err(&want) < 1e-5,
                    "{v:?} {m}x{n}x{k}: rel err {}", out.rel_err(&want));
        }
    }
}

#[test]
fn every_variant_is_bit_identical_across_workers() {
    // The per-variant determinism contract: for a FIXED variant, the
    // per-element accumulation order depends only on the problem shape,
    // so MOFA_WORKERS ∈ {1, 2, 8} must not change a single bit.
    let mut rng = Rng::new(12);
    for v in KernelVariant::ALL {
        for &(m, n, k) in &SHAPES {
            let (a, b) = operands(&mut rng, v.kind(), m, n, k);
            let mut base = vec![0.0f32; m * n];
            gemm_v(v, m, n, k, &a.data, &b.data, 1.0, 0.0, &mut base,
                   &[], 1);
            for workers in [2, 8] {
                let mut out = vec![0.0f32; m * n];
                gemm_v(v, m, n, k, &a.data, &b.data, 1.0, 0.0, &mut out,
                       &[], workers);
                assert_eq!(out, base, "{v:?} {m}x{n}x{k} w={workers}");
            }
        }
    }
}

#[test]
fn family_bit_identity_matches_design_contract() {
    // DESIGN.md §12: the NN/TN blocked variants (any panel size, scalar
    // or 8-wide lanes) accumulate straight into the output element in
    // ascending-k order, so they are bit-identical to EACH OTHER — a
    // retuned panel size can never change NN/TN results. Likewise
    // NtWide8 shares NtTiled4's fold structure exactly. (NtUnrolled's
    // 4-way split sums legitimately differ — tolerance-checked above.)
    let families: [&[KernelVariant]; 3] = [
        &[KernelVariant::NnBlocked, KernelVariant::NnBlockedThin,
          KernelVariant::NnWide8],
        &[KernelVariant::TnBlocked, KernelVariant::TnBlockedThin,
          KernelVariant::TnWide8],
        &[KernelVariant::NtTiled4, KernelVariant::NtWide8],
    ];
    let mut rng = Rng::new(13);
    for family in families {
        for &(m, n, k) in &SHAPES {
            let kind = family[0].kind();
            let (a, b) = operands(&mut rng, kind, m, n, k);
            let mut base = vec![0.0f32; m * n];
            gemm_v(family[0], m, n, k, &a.data, &b.data, 1.0, 0.0,
                   &mut base, &[], 1);
            for &v in &family[1..] {
                let mut out = vec![0.0f32; m * n];
                gemm_v(v, m, n, k, &a.data, &b.data, 1.0, 0.0, &mut out,
                       &[], 1);
                assert_eq!(out, base,
                           "{v:?} vs {:?} {m}x{n}x{k}", family[0]);
            }
        }
    }
}

/// Unique per-process scratch path for the cache file under test.
fn scratch_cache_path() -> std::path::PathBuf {
    std::env::temp_dir()
        .join(format!("mofa_autotune_test_{}.json", std::process::id()))
}

// One test for every global-state scenario: mode atomic, winner table,
// cache file, and obs counters are process-wide, so scenarios run
// serialized in a fixed order with explicit resets between them.
#[test]
fn autotune_global_lifecycle() {
    let cache = scratch_cache_path();
    std::env::set_var("MOFA_AUTOTUNE_CACHE", &cache);
    let _ = std::fs::remove_file(&cache);
    let (m, n, k) = (48, 8, 96);

    // -- off: static dispatch, nothing tabled, nothing written --------------
    autotune::set_mode(Mode::Off);
    autotune::reset();
    for kind in [MatKind::NN, MatKind::TN, MatKind::NT] {
        assert_eq!(autotune::chosen(kind, m, n, k), static_variant(kind));
        assert_eq!(autotune::compile_choice(kind, m, n, k), None);
    }
    assert_eq!(autotune::table_len(), 0);
    assert!(!cache.exists(), "off mode must not touch the cache file");

    // -- on, cold cache: first touch tunes, persists, then table-serves ----
    autotune::set_mode(Mode::On);
    let w0 = autotune::chosen(MatKind::NT, m, n, k);
    assert_eq!(w0.kind(), MatKind::NT);
    assert_eq!(autotune::table_len(), 1);
    assert_eq!(autotune::lookup(MatKind::NT, m, n, k), Some(w0));
    // Same pow2 class ⇒ same winner, no new entry.
    assert_eq!(autotune::chosen(MatKind::NT, m - 7, n - 1, k - 30), w0);
    assert_eq!(autotune::table_len(), 1);
    assert!(cache.exists(), "winner must be persisted");
    let doc = Json::parse(&std::fs::read_to_string(&cache).unwrap())
        .expect("cache file is valid JSON");
    assert_eq!(doc.req("version").unwrap().as_f64().unwrap(), 1.0);
    let entries = doc.req("entries").unwrap().as_obj().unwrap();
    let key = autotune::key_string(MatKind::NT, m, n, k);
    assert_eq!(entries[&key].as_str().unwrap(), w0.name());

    // -- warm dispatch is a counted table lookup ----------------------------
    obs::set_enabled(true);
    let _ = obs::drain();
    for _ in 0..5 {
        autotune::chosen(MatKind::NT, m, n, k);
    }
    let trace = obs::drain();
    obs::set_enabled(false);
    assert!(trace.counter("sched_cache_hits") >= 5,
            "warm chosen() must count as cache hits, got {}",
            trace.counter("sched_cache_hits"));

    // -- cache round-trip: a fresh table loads the persisted winner ---------
    // Forge a deliberately non-static winner so a hit can only come from
    // the file, not from re-measurement happening to agree.
    let forged = KernelVariant::NtUnrolled;
    assert_ne!(forged, static_variant(MatKind::NT));
    std::fs::write(&cache, Json::obj(vec![
        ("version", Json::Num(1.0)),
        ("entries", Json::obj(vec![(key.as_str(),
                                    Json::Str(forged.name().into()))])),
    ]).emit(1)).unwrap();
    autotune::reset();
    assert_eq!(autotune::chosen(MatKind::NT, m, n, k), forged,
               "persisted winner must be loaded, not re-measured");

    // -- stale entries are dropped, valid ones kept -------------------------
    std::fs::write(&cache, Json::obj(vec![
        ("version", Json::Num(1.0)),
        ("entries", Json::obj(vec![
            (key.as_str(), Json::Str(forged.name().into())),
            ("nn:16x16x16", Json::Str("renamed_away_kernel".into())),
            ("nt:16x16x16", Json::Str("nn_blocked".into())), // anchor clash
            ("garbage-key", Json::Str("nn_blocked".into())),
        ])),
    ]).emit(1)).unwrap();
    autotune::reset();
    assert_eq!(autotune::chosen(MatKind::NT, m, n, k), forged);
    // The dropped classes re-tune to something real instead of erroring.
    let retuned = autotune::chosen(MatKind::NN, 16, 16, 16);
    assert_eq!(retuned.kind(), MatKind::NN);

    // -- corrupt file: warn, retune from scratch ----------------------------
    std::fs::write(&cache, "{not json at all").unwrap();
    autotune::reset();
    let w2 = autotune::chosen(MatKind::NT, m, n, k);
    assert_eq!(w2.kind(), MatKind::NT);
    assert_eq!(autotune::table_len(), 1);

    // -- wrong version: ignored gracefully ----------------------------------
    std::fs::write(&cache, Json::obj(vec![
        ("version", Json::Num(999.0)),
        ("entries", Json::obj(vec![(key.as_str(),
                                    Json::Str(forged.name().into()))])),
    ]).emit(1)).unwrap();
    autotune::reset();
    let w3 = autotune::chosen(MatKind::NT, m, n, k);
    assert_eq!(w3.kind(), MatKind::NT); // measured, forged entry ignored

    // -- refresh: measure fresh even with a forged cache present ------------
    std::fs::write(&cache, Json::obj(vec![
        ("version", Json::Num(1.0)),
        ("entries", Json::obj(vec![(key.as_str(),
                                    Json::Str(forged.name().into()))])),
    ]).emit(1)).unwrap();
    autotune::set_mode(Mode::Refresh);
    autotune::reset();
    let _wr = autotune::chosen(MatKind::NT, m, n, k);
    // Refresh rewrote the file from this process's measurements; every
    // entry must still validate against the live registry.
    let doc = Json::parse(&std::fs::read_to_string(&cache).unwrap())
        .unwrap();
    for (ks, vs) in doc.req("entries").unwrap().as_obj().unwrap() {
        let v = KernelVariant::from_name(vs.as_str().unwrap())
            .unwrap_or_else(|| panic!("{ks}: unknown variant {vs:?}"));
        assert!(ks.starts_with(&format!("{}:", match v.kind() {
            MatKind::NN => "nn",
            MatKind::TN => "tn",
            MatKind::NT => "nt",
        })), "{ks} anchor mismatch for {v:?}");
    }

    // -- tuned dispatch equals static dispatch numerically ------------------
    // Whatever the tuner picked, results must match the static kernel to
    // baseline tolerance (bit-identical for NN/TN and NtWide8 families,
    // 1e-5 for NtUnrolled — both covered by the rel_err bound).
    autotune::set_mode(Mode::On);
    let mut rng = Rng::new(14);
    for kind in [MatKind::NN, MatKind::TN, MatKind::NT] {
        let (a, b) = operands(&mut rng, kind, m, n, k);
        let tuned = autotune::chosen(kind, m, n, k);
        let mut t_out = vec![0.0f32; m * n];
        let mut s_out = vec![0.0f32; m * n];
        gemm_v(tuned, m, n, k, &a.data, &b.data, 1.0, 0.0, &mut t_out,
               &[], 2);
        gemm_v(static_variant(kind), m, n, k, &a.data, &b.data, 1.0, 0.0,
               &mut s_out, &[], 2);
        let t = Mat::from_vec(m, n, t_out);
        let s = Mat::from_vec(m, n, s_out);
        assert!(t.rel_err(&s) < 1e-5, "{kind:?}: tuned {tuned:?} diverges");
    }

    // -- plan-compile resolution: nodes dispatch without a table read -------
    // A compiled graph under mode=on resolves variants at compile time;
    // executing it bumps sched_cache_hits per GEMM node.
    let (pm, pn, pr) = (24, 18, 8);
    let mut g = Graph::new();
    let grad = g.input(pm, pn);
    let v = g.input(pn, pr);
    let gv = g.ext(pm, pr);
    g.matmul(MatKind::NN, grad, v, gv, SVal::Lit(1.0), SVal::Lit(0.0));
    let plan = compile(&g);
    let mut ws = plan.workspace();
    let gm = Mat::randn(&mut rng, pm, pn, 1.0);
    let vm = Mat::randn(&mut rng, pn, pr, 1.0);
    let mut e_gv = Mat::zeros(pm, pr);
    obs::set_enabled(true);
    let _ = obs::drain();
    {
        let ins = [&gm.data[..], &vm.data[..]];
        let mut exts = [&mut e_gv.data[..]];
        plan.execute(&mut ws, &ins, &mut exts, &[], 2);
    }
    let trace = obs::drain();
    obs::set_enabled(false);
    assert!(trace.counter("sched_cache_hits") >= 1,
            "plan-resolved GEMM node must count as tuned dispatch");
    assert!(e_gv.rel_err(&gm.matmul(&vm)) < 1e-5);

    // -- leave the process in the default state -----------------------------
    autotune::set_mode(Mode::Off);
    autotune::reset();
    std::env::remove_var("MOFA_AUTOTUNE_CACHE");
    let _ = std::fs::remove_file(&cache);
}

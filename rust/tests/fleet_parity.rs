//! Fleet-vs-serial parity suite (ISSUE 5).
//!
//! The fleet executor runs a whole mixed-optimizer stack — MoFaSGD at
//! r ∈ {4, 32}, GaLore (with mid-run subspace resampling), Muon, dense
//! AdamW/SGD-M/signSGD, plus flat vec-layer AdamW — as a single pool
//! dispatch. Every test here asserts *bit-identical* weights and
//! optimizer state against the frozen serial per-layer loop: per-layer
//! stage chains forbid the schedule from reordering math within a layer,
//! and the kernels guarantee per-element results independent of worker
//! count and row chunking, so equality is exact, not approximate.
//!
//! `rust/run_checks.sh` runs this suite under `RUST_TEST_THREADS=1` and
//! again with the pool pinned to 2 and 8 workers via `MOFA_WORKERS`,
//! which moves the *serial* baseline's kernel pool size — parity must
//! hold at every combination.

use mofasgd::fusion::{self, FleetUnit};
use mofasgd::linalg::Mat;
use mofasgd::optim::adamw::AdamWVec;
use mofasgd::optim::{AdamW, GaLore, MatOpt, MatUnit, MatrixOptimizer,
                     MoFaSgd, Muon, SgdM, SignSgd, VecOptimizer, VecUnit};
use mofasgd::util::rng::Rng;

const ETA: f32 = 0.01;
const STEPS: usize = 6;

/// Layer kinds of the mixed acceptance fleet (ISSUE 5: MoFaSGD
/// r ∈ {4, 32} + GaLore + dense layers).
#[derive(Clone, Copy)]
enum Kind {
    MofaR4,
    MofaR32,
    Galore,
    Muon,
    AdamW,
    SgdM,
    SignSgd,
}

/// ≥ 8 matrix layers, mixed kinds and shapes. GaLore resamples every 3
/// steps, so a 6-step run exercises the subspace refresh inside the
/// fleet too.
fn mixed_spec() -> Vec<(Kind, usize, usize)> {
    vec![
        (Kind::MofaR4, 48, 40),
        (Kind::MofaR32, 96, 80),
        (Kind::Galore, 64, 48),
        (Kind::AdamW, 56, 24),
        (Kind::MofaR32, 80, 96),
        (Kind::Muon, 40, 40),
        (Kind::SgdM, 32, 64),
        (Kind::MofaR4, 40, 56),
        (Kind::Galore, 48, 64),
        (Kind::SignSgd, 24, 24),
    ]
}

enum Opt {
    Mofa(MoFaSgd),
    Galore(GaLore),
    Muon(Muon),
    AdamW(AdamW),
    SgdM(SgdM),
    SignSgd(SignSgd),
}

impl Opt {
    fn build(kind: Kind, m: usize, n: usize, seed: u64) -> Opt {
        match kind {
            Kind::MofaR4 => Opt::Mofa(MoFaSgd::new(m, n, 4, 0.9)),
            Kind::MofaR32 => Opt::Mofa(MoFaSgd::new(m, n, 32, 0.9)),
            Kind::Galore => {
                Opt::Galore(GaLore::new(m, n, 8, 3, 0.9, 0.999, seed))
            }
            Kind::Muon => Opt::Muon(Muon::new(m, n, 0.9)),
            Kind::AdamW => Opt::AdamW(AdamW::new(m, n, 0.9, 0.999, 0.01)),
            Kind::SgdM => Opt::SgdM(SgdM::new(m, n, 0.9)),
            Kind::SignSgd => Opt::SignSgd(SignSgd::new()),
        }
    }

    /// The frozen serial per-layer baseline.
    fn step(&mut self, w: &mut Mat, g: &Mat, eta: f32) {
        match self {
            Opt::Mofa(o) => o.step(w, g, eta),
            Opt::Galore(o) => o.step(w, g, eta),
            Opt::Muon(o) => o.step(w, g, eta),
            Opt::AdamW(o) => o.step(w, g, eta),
            Opt::SgdM(o) => o.step(w, g, eta),
            Opt::SignSgd(o) => o.step(w, g, eta),
        }
    }

    fn unit<'a>(&'a mut self, w: &'a mut Mat, g: &'a Mat, eta: f32)
                -> MatUnit<'a> {
        let opt = match self {
            Opt::Mofa(o) => MatOpt::MoFaSgd(o),
            Opt::Galore(o) => MatOpt::GaLore(o),
            Opt::Muon(o) => MatOpt::Muon(o),
            Opt::AdamW(o) => MatOpt::AdamW(o),
            Opt::SgdM(o) => MatOpt::SgdM(o),
            Opt::SignSgd(o) => MatOpt::SignSgd(o),
        };
        MatUnit::new(opt, w, g, eta)
    }

    /// Bit-exact state comparison against another instance.
    fn assert_state_eq(&self, other: &Opt, li: usize) {
        match (self, other) {
            (Opt::Mofa(a), Opt::Mofa(b)) => {
                assert_eq!(a.u.data, b.u.data, "layer {li}: U");
                assert_eq!(a.s, b.s, "layer {li}: sigma");
                assert_eq!(a.v.data, b.v.data, "layer {li}: V");
            }
            (Opt::Galore(a), Opt::Galore(b)) => {
                assert_eq!(a.q.data, b.q.data, "layer {li}: Q");
                assert_eq!(a.m1.data, b.m1.data, "layer {li}: m1");
                assert_eq!(a.m2.data, b.m2.data, "layer {li}: m2");
            }
            (Opt::Muon(a), Opt::Muon(b)) => {
                assert_eq!(a.m.data, b.m.data, "layer {li}: momentum");
            }
            (Opt::AdamW(a), Opt::AdamW(b)) => {
                assert_eq!(a.m.data, b.m.data, "layer {li}: m");
                assert_eq!(a.v.data, b.v.data, "layer {li}: v");
            }
            (Opt::SgdM(a), Opt::SgdM(b)) => {
                assert_eq!(a.m.data, b.m.data, "layer {li}: momentum");
            }
            (Opt::SignSgd(_), Opt::SignSgd(_)) => {}
            _ => panic!("layer {li}: kind mismatch"),
        }
    }
}

struct Stack {
    opts: Vec<Opt>,
    ws: Vec<Mat>,
    vec_opts: Vec<AdamWVec>,
    vec_ws: Vec<Vec<f32>>,
}

const VEC_LENS: [usize; 2] = [100, 3000];

/// Two identical stacks are built from the same spec and seeds; grads
/// are shared, so any divergence is the executor's fault.
fn build_stack(seed: u64) -> Stack {
    let spec = mixed_spec();
    let mut rng = Rng::new(seed);
    let mut opts = Vec::new();
    let mut ws = Vec::new();
    for (li, &(kind, m, n)) in spec.iter().enumerate() {
        opts.push(Opt::build(kind, m, n, 1000 + li as u64));
        ws.push(Mat::randn(&mut rng, m, n, 1.0));
    }
    let vec_opts = VEC_LENS
        .iter()
        .map(|&l| AdamWVec::new(l, 0.9, 0.999, 0.01))
        .collect();
    let vec_ws = VEC_LENS.iter().map(|&l| rng.normal_vec(l, 1.0)).collect();
    Stack { opts, ws, vec_opts, vec_ws }
}

/// Per-step gradients, shared verbatim by both stacks.
fn grads(seed: u64) -> (Vec<Vec<Mat>>, Vec<Vec<Vec<f32>>>) {
    let spec = mixed_spec();
    let mut rng = Rng::new(seed);
    let mat: Vec<Vec<Mat>> = (0..STEPS)
        .map(|_| {
            spec.iter()
                .map(|&(_, m, n)| Mat::randn(&mut rng, m, n, 1.0))
                .collect()
        })
        .collect();
    let vec: Vec<Vec<Vec<f32>>> = (0..STEPS)
        .map(|_| VEC_LENS.iter().map(|&l| rng.normal_vec(l, 1.0)).collect())
        .collect();
    (mat, vec)
}

fn run_serial(stack: &mut Stack, mat_g: &[Vec<Mat>], vec_g: &[Vec<Vec<f32>>]) {
    for step in 0..STEPS {
        for (li, opt) in stack.opts.iter_mut().enumerate() {
            opt.step(&mut stack.ws[li], &mat_g[step][li], ETA);
        }
        for (vi, o) in stack.vec_opts.iter_mut().enumerate() {
            o.step(&mut stack.vec_ws[vi], &vec_g[step][vi], ETA);
        }
    }
}

fn run_fleet(stack: &mut Stack, mat_g: &[Vec<Mat>],
             vec_g: &[Vec<Vec<f32>>], workers: usize) {
    let mut fleet = fusion::Fleet::new();
    for step in 0..STEPS {
        let mut mat_units: Vec<MatUnit> = stack
            .opts
            .iter_mut()
            .zip(&mut stack.ws)
            .zip(&mat_g[step])
            .map(|((opt, w), g)| opt.unit(w, g, ETA))
            .collect();
        let mut vec_units: Vec<VecUnit> = stack
            .vec_opts
            .iter_mut()
            .zip(&mut stack.vec_ws)
            .zip(&vec_g[step])
            .map(|((o, w), g)| VecUnit::new(o, w, g, ETA))
            .collect();
        let mut refs: Vec<&mut dyn FleetUnit> = mat_units
            .iter_mut()
            .map(|u| u as &mut dyn FleetUnit)
            .chain(vec_units.iter_mut().map(|u| u as &mut dyn FleetUnit))
            .collect();
        fleet.run(&mut refs, workers);
    }
}

fn assert_stacks_eq(a: &Stack, b: &Stack) {
    for (li, (wa, wb)) in a.ws.iter().zip(&b.ws).enumerate() {
        assert!(wa.data.iter().all(|v| v.is_finite()), "layer {li} w");
        assert_eq!(wa.data, wb.data, "layer {li}: weights diverged");
    }
    for (li, (oa, ob)) in a.opts.iter().zip(&b.opts).enumerate() {
        oa.assert_state_eq(ob, li);
    }
    for (vi, (va, vb)) in a.vec_ws.iter().zip(&b.vec_ws).enumerate() {
        assert_eq!(va, vb, "vec layer {vi}: weights diverged");
    }
}

#[test]
fn mixed_fleet_matches_serial_bitwise() {
    let (mat_g, vec_g) = grads(7);
    let mut serial = build_stack(42);
    let mut fleet = build_stack(42);
    run_serial(&mut serial, &mat_g, &vec_g);
    // The fleet runs at the ambient pool size (MOFA_WORKERS lanes in
    // run_checks.sh move it); the serial baseline's kernels saw the same
    // ambient size — equality must be exact regardless.
    run_fleet(&mut fleet, &mat_g, &vec_g, fusion::workers());
    assert_stacks_eq(&serial, &fleet);
}

#[test]
fn fleet_bit_determinism_across_worker_counts() {
    let (mat_g, vec_g) = grads(8);
    let mut base = build_stack(43);
    run_fleet(&mut base, &mat_g, &vec_g, 1);
    for workers in [2usize, 8] {
        let mut other = build_stack(43);
        run_fleet(&mut other, &mat_g, &vec_g, workers);
        assert_stacks_eq(&base, &other);
    }
}

#[test]
fn buffered_mofasgd_step_unchanged_by_scale_fold() {
    // The §5.5 buffered step now folds 1/count into panel assembly and
    // the core block instead of allocating scaled copies — trajectory
    // must still match a plain step on the mean gradient.
    use mofasgd::optim::mofasgd::LowRankBuffers;
    let mut rng = Rng::new(9);
    let (m, n, r, k) = (40, 32, 4, 3);
    let mut a = MoFaSgd::new(m, n, r, 0.9);
    let mut b = MoFaSgd::new(m, n, r, 0.9);
    let mut wa = Mat::randn(&mut rng, m, n, 1.0);
    let mut wb = wa.clone();
    let g0 = Mat::randn(&mut rng, m, n, 1.0);
    a.step(&mut wa, &g0, ETA);
    b.step(&mut wb, &g0, ETA);
    let gs: Vec<Mat> =
        (0..k).map(|_| Mat::randn(&mut rng, m, n, 1.0)).collect();
    let mut buf = LowRankBuffers::zeros(m, n, r);
    for g in &gs {
        a.accumulate(g, &mut buf);
    }
    a.step_from_buffers(&mut wa, &buf, ETA);
    let mut mean = Mat::zeros(m, n);
    for g in &gs {
        mean.axpy_inplace(1.0, 1.0 / k as f32, g);
    }
    b.step(&mut wb, &mean, ETA);
    assert!(wa.rel_err(&wb) < 1e-4, "err {}", wa.rel_err(&wb));
}

//! Steady-state zero-allocation proof for tracing-enabled recording.
//!
//! Same counting-allocator discipline as `fusion_alloc.rs`, with the
//! recorder switched ON: after a warm-up (ring claim + optimizer scratch
//! sizing), steady-state `MoFaSgd::step`s — each emitting dozens of
//! plan/linalg spans and counter bumps — must not allocate at all at
//! workers = 1.
//!
//! Single test: allocation counts and the recorder enable flag are
//! process-global.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use mofasgd::fusion;
use mofasgd::linalg::Mat;
use mofasgd::obs;
use mofasgd::optim::{MatrixOptimizer, MoFaSgd};
use mofasgd::util::rng::Rng;

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn tracing_enabled_steady_state_is_allocation_free() {
    fusion::set_workers(1);
    obs::set_enabled(true);

    let mut rng = Rng::new(3);
    let mut opt = MoFaSgd::new(96, 80, 16, 0.9);
    let mut w = Mat::randn(&mut rng, 96, 80, 1.0);
    let g1 = Mat::randn(&mut rng, 96, 80, 1.0);
    let g2 = Mat::randn(&mut rng, 96, 80, 1.0);

    // Warm-up: SVD_r init + scratch sizing + this thread's ring claim.
    opt.step(&mut w, &g1, 1e-3);
    opt.step(&mut w, &g2, 1e-3);

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..5 {
        opt.step(&mut w, &g1, 1e-3);
        opt.step(&mut w, &g2, 1e-3);
    }
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(delta, 0,
               "tracing-enabled steady state allocated {delta} times");

    // The recording really was live while we measured.
    let trace = obs::drain();
    obs::set_enabled(false);
    assert!(trace.spans.len() > 50,
            "only {} spans recorded — instrumentation dead?",
            trace.spans.len());
    assert!(trace.counter("flops") > 0, "flops counter dead");
    assert!(w.data.iter().all(|v| v.is_finite()));
    fusion::set_workers(0); // restore auto resolution
}

//! Serve-daemon contracts (ISSUE 9): a multiplexed session is
//! bit-identical to running it alone, inline and prefetched noise are
//! the same stream, checkpoints survive the JSON wire round trip
//! bit-exactly, and the protocol layer never panics on hostile bytes.
//! ISSUE 10 adds the failure-model fixtures: every manager verb answers
//! a clean error naming the state on unknown/Failed/evicted ids, and
//! shutdown under load (mid-tick, hostile non-reading client) still
//! flushes the final ack and joins every thread within a bound.
//!
//! The anchor is a hand-written serial reference (raw optimizer steps +
//! the frozen `reduce_ref` tree fold — the same baseline style as
//! `replica_parity.rs`), which the solo serve path must match bitwise;
//! every multiplexed/prefetched/restored variant is then compared to
//! the solo run, at workers ∈ {1, 2, 8}.

use mofasgd::coordinator::checkpoint::Checkpoint;
use mofasgd::fusion::reduce::{self, TreeSchedule};
use mofasgd::linalg::Mat;
use mofasgd::optim::{AdamW, MatrixOptimizer, MoFaSgd, Muon, SgdM, SignSgd,
                     VecOptimizer};
use mofasgd::optim::adamw::AdamWVec;
use mofasgd::serve::{parse_request, LayerKind, LayerSpec, SessionManager,
                     SessionSpec, SessionState, TickEvent, VecSpec};
use mofasgd::util::json::Json;
use mofasgd::util::prop::{self, Prop};
use mofasgd::util::rng::Rng;

const WORKER_COUNTS: [usize; 3] = [1, 2, 8];

// ---- specs ---------------------------------------------------------------

/// Every optimizer kind the daemon serves, plus a vec layer.
fn mixed_spec(name: &str, seed: u64, steps: usize, prefetch: usize)
              -> SessionSpec {
    SessionSpec {
        name: name.to_string(),
        seed,
        steps,
        accum: 3,
        eta: 0.01,
        noise: 0.5,
        prefetch,
        layers: vec![
            LayerSpec { kind: LayerKind::MoFaSgd, m: 48, n: 40, rank: 4,
                        beta: 0.9 },
            LayerSpec { kind: LayerKind::Muon, m: 24, n: 24, rank: 4,
                        beta: 0.9 },
            LayerSpec { kind: LayerKind::AdamW, m: 32, n: 20, rank: 4,
                        beta: 0.9 },
            LayerSpec { kind: LayerKind::SgdM, m: 20, n: 36, rank: 4,
                        beta: 0.9 },
            LayerSpec { kind: LayerKind::SignSgd, m: 16, n: 16, rank: 4,
                        beta: 0.9 },
        ],
        vecs: vec![VecSpec { len: 64 }],
    }
}

/// Only kinds whose full state restores from checkpoint tensors
/// (AdamW keeps a private step counter; vec layers are AdamW).
fn restorable_spec(seed: u64, steps: usize) -> SessionSpec {
    SessionSpec {
        name: "restorable".to_string(),
        seed,
        steps,
        accum: 2,
        eta: 0.01,
        noise: 0.4,
        prefetch: 0,
        layers: vec![
            LayerSpec { kind: LayerKind::MoFaSgd, m: 48, n: 40, rank: 4,
                        beta: 0.9 },
            LayerSpec { kind: LayerKind::Muon, m: 40, n: 40, rank: 4,
                        beta: 0.9 },
            LayerSpec { kind: LayerKind::SgdM, m: 32, n: 64, rank: 4,
                        beta: 0.9 },
            LayerSpec { kind: LayerKind::SignSgd, m: 24, n: 24, rank: 4,
                        beta: 0.9 },
        ],
        vecs: vec![],
    }
}

// ---- serial reference ----------------------------------------------------

/// Test-side pin of the serve stream-derivation convention: layer tag
/// `4*li + role` (vec layers `(1<<32) + 4*vi + role`), role 0 = init
/// weights, 1 = target, 2 = noise. If `serve::session` drifts from
/// this, the parity assertions below fail.
fn layer_rng(seed: u64, tag: u64) -> Rng {
    Rng::new(seed).split(tag)
}

enum RefOpt {
    Mofa(MoFaSgd),
    Muon(Muon),
    AdamW(AdamW),
    SgdM(SgdM),
    Sign(SignSgd),
}

impl RefOpt {
    fn build(l: &LayerSpec) -> RefOpt {
        match l.kind {
            LayerKind::MoFaSgd => {
                RefOpt::Mofa(MoFaSgd::new(l.m, l.n, l.rank, l.beta))
            }
            LayerKind::Muon => RefOpt::Muon(Muon::new(l.m, l.n, l.beta)),
            LayerKind::AdamW => {
                RefOpt::AdamW(AdamW::new(l.m, l.n, l.beta, 0.999, 0.0))
            }
            LayerKind::SgdM => RefOpt::SgdM(SgdM::new(l.m, l.n, l.beta)),
            LayerKind::SignSgd => RefOpt::Sign(SignSgd::new()),
        }
    }

    fn step(&mut self, w: &mut Mat, g: &Mat, eta: f32) {
        match self {
            RefOpt::Mofa(o) => o.step(w, g, eta),
            RefOpt::Muon(o) => o.step(w, g, eta),
            RefOpt::AdamW(o) => o.step(w, g, eta),
            RefOpt::SgdM(o) => o.step(w, g, eta),
            RefOpt::Sign(o) => o.step(w, g, eta),
        }
    }
}

struct RefMatLayer {
    w: Mat,
    target: Mat,
    opt: RefOpt,
    rng_noise: Rng,
}

struct RefVecLayer {
    w: Vec<f32>,
    target: Vec<f32>,
    opt: AdamWVec,
    rng_noise: Rng,
}

struct RefStack {
    spec: SessionSpec,
    sched: TreeSchedule,
    mats: Vec<RefMatLayer>,
    vecs: Vec<RefVecLayer>,
}

fn build_ref(spec: &SessionSpec) -> RefStack {
    let mats = spec
        .layers
        .iter()
        .enumerate()
        .map(|(li, l)| RefMatLayer {
            w: Mat::randn(&mut layer_rng(spec.seed, 4 * li as u64),
                          l.m, l.n, 1.0),
            target: Mat::randn(
                &mut layer_rng(spec.seed, 4 * li as u64 + 1),
                l.m, l.n, 1.0),
            opt: RefOpt::build(l),
            rng_noise: layer_rng(spec.seed, 4 * li as u64 + 2),
        })
        .collect();
    let vecs = spec
        .vecs
        .iter()
        .enumerate()
        .map(|(vi, v)| {
            let tag = (1u64 << 32) + 4 * vi as u64;
            RefVecLayer {
                w: layer_rng(spec.seed, tag).normal_vec(v.len, 1.0),
                target: layer_rng(spec.seed, tag + 1)
                    .normal_vec(v.len, 1.0),
                opt: AdamWVec::new(v.len, 0.9, 0.999, 0.0),
                rng_noise: layer_rng(spec.seed, tag + 2),
            }
        })
        .collect();
    RefStack {
        spec: spec.clone(),
        sched: TreeSchedule::new(spec.accum, reduce::TREE_WIDTH),
        mats,
        vecs,
    }
}

/// One reference step: per layer, materialize the micro gradients
/// `(w − w*) + noise·z`, mean-reduce them through the frozen tree fold,
/// take the serial optimizer step. Returns the post-step loss.
fn ref_tick(stack: &mut RefStack, step: usize) -> f64 {
    let accum = stack.spec.accum;
    let noise = stack.spec.noise;
    let eta = stack.spec.eta;
    let inv = 1.0 / accum as f32;
    for l in &mut stack.mats {
        let grads: Vec<Mat> = (0..accum)
            .map(|k| {
                let mut r = l
                    .rng_noise
                    .shard_stream((step * accum + k) as u64);
                let mut g = Mat::zeros(l.w.rows, l.w.cols);
                for i in 0..g.data.len() {
                    g.data[i] = (l.w.data[i] - l.target.data[i])
                        + noise * r.normal_f32();
                }
                g
            })
            .collect();
        let refs: Vec<&[f32]> =
            grads.iter().map(|g| &g.data[..]).collect();
        let mut mean = reduce::reduce_ref(&stack.sched, &refs);
        for x in &mut mean {
            *x *= inv;
        }
        let gm = Mat::from_vec(l.w.rows, l.w.cols, mean);
        l.opt.step(&mut l.w, &gm, eta);
    }
    for v in &mut stack.vecs {
        let grads: Vec<Vec<f32>> = (0..accum)
            .map(|k| {
                let mut r = v
                    .rng_noise
                    .shard_stream((step * accum + k) as u64);
                (0..v.w.len())
                    .map(|i| {
                        (v.w[i] - v.target[i]) + noise * r.normal_f32()
                    })
                    .collect()
            })
            .collect();
        let refs: Vec<&[f32]> = grads.iter().map(|g| &g[..]).collect();
        let mut mean = reduce::reduce_ref(&stack.sched, &refs);
        for x in &mut mean {
            *x *= inv;
        }
        v.opt.step(&mut v.w, &mean, eta);
    }
    let mut loss = 0.0f64;
    for l in &stack.mats {
        let mut acc = 0.0f64;
        for (w, t) in l.w.data.iter().zip(&l.target.data) {
            let d = (w - t) as f64;
            acc += d * d;
        }
        loss += 0.5 * acc;
    }
    for v in &stack.vecs {
        let mut acc = 0.0f64;
        for (w, t) in v.w.iter().zip(&v.target) {
            let d = (w - t) as f64;
            acc += d * d;
        }
        loss += 0.5 * acc;
    }
    loss
}

// ---- helpers -------------------------------------------------------------

/// Bitwise view of a checkpoint (f32 payloads as u32 bit patterns).
fn ck_bits(ck: &Checkpoint) -> Vec<(String, Vec<usize>, Vec<u32>)> {
    ck.tensors
        .iter()
        .map(|(name, dims, data)| {
            (name.clone(), dims.clone(),
             data.iter().map(|x| x.to_bits()).collect())
        })
        .collect()
}

/// Run one session alone to completion; returns its per-tick loss bit
/// sequence and final checkpoint.
fn run_solo(spec: &SessionSpec, workers: usize)
            -> (Vec<u64>, Checkpoint) {
    let mut mgr = SessionManager::new();
    let id = mgr.admit(spec).unwrap();
    let mut events = Vec::new();
    let mut losses = Vec::new();
    for _ in 0..spec.steps {
        events.clear();
        mgr.tick(workers, &mut events);
        for e in &events {
            if let TickEvent::Metrics { session, loss, .. } = e {
                assert_eq!(*session, id);
                losses.push(loss.to_bits());
            }
        }
    }
    let s = mgr.get(id).unwrap();
    assert_eq!(s.state, SessionState::Done);
    assert_eq!(s.step, spec.steps);
    let (_, ck) = mgr.checkpoint(id).unwrap();
    (losses, ck)
}

// ---- tests ---------------------------------------------------------------

#[test]
fn solo_session_matches_serial_reference() {
    // The whole serve stack — session build, fused lane accumulation,
    // tree reduce, MatStager staging, tick loop — against raw serial
    // optimizer math, bitwise, at every worker count.
    let spec = mixed_spec("anchor", 11, 6, 0);
    let mut stack = build_ref(&spec);
    let ref_losses: Vec<u64> = (0..spec.steps)
        .map(|s| ref_tick(&mut stack, s).to_bits())
        .collect();
    for workers in WORKER_COUNTS {
        let (losses, ck) = run_solo(&spec, workers);
        assert_eq!(losses, ref_losses, "workers={workers}");
        // Final weights/state bitwise against the reference.
        for (name, _dims, bits) in ck_bits(&ck) {
            let want: Vec<u32> = match name.as_str() {
                "w0" => stack.mats[0].w.data.iter().map(|x| x.to_bits())
                    .collect(),
                "w4" => stack.mats[4].w.data.iter().map(|x| x.to_bits())
                    .collect(),
                "vw0" => stack.vecs[0].w.iter().map(|x| x.to_bits())
                    .collect(),
                _ => continue,
            };
            assert_eq!(bits, want, "workers={workers} tensor {name}");
        }
    }
}

#[test]
fn multiplexed_sessions_bit_identical_to_solo() {
    // sessions ∈ {2, 4} tenants (different seeds, different lengths so
    // they finish on different ticks) × workers ∈ {1, 2, 8}: every
    // tenant's loss stream and final checkpoint must equal its solo run.
    for n_sessions in [2usize, 4] {
        let specs: Vec<SessionSpec> = (0..n_sessions)
            .map(|i| mixed_spec(&format!("t{i}"), 100 + i as u64,
                                5 + i, 0))
            .collect();
        let solo: Vec<(Vec<u64>, Checkpoint)> =
            specs.iter().map(|s| run_solo(s, 1)).collect();
        for workers in WORKER_COUNTS {
            let mut mgr = SessionManager::new();
            let ids: Vec<u32> =
                specs.iter().map(|s| mgr.admit(s).unwrap()).collect();
            let mut events = Vec::new();
            let mut losses: Vec<Vec<u64>> =
                vec![Vec::new(); n_sessions];
            let mut guard = 0;
            while mgr.n_running() > 0 {
                events.clear();
                mgr.tick(workers, &mut events);
                for e in &events {
                    match e {
                        TickEvent::Metrics { session, loss, .. } => {
                            let i = ids.iter()
                                .position(|id| id == session).unwrap();
                            losses[i].push(loss.to_bits());
                        }
                        TickEvent::Done { .. } => {}
                        TickEvent::Failed { session, msg } => {
                            panic!("session {session} failed: {msg}");
                        }
                    }
                }
                guard += 1;
                assert!(guard < 100, "ticks runaway");
            }
            for (i, id) in ids.iter().enumerate() {
                assert_eq!(losses[i], solo[i].0,
                           "n={n_sessions} w={workers} tenant {i}");
                let (_, ck) = mgr.checkpoint(*id).unwrap();
                assert_eq!(ck_bits(&ck), ck_bits(&solo[i].1),
                           "n={n_sessions} w={workers} tenant {i}");
            }
        }
    }
}

#[test]
fn mid_run_admission_leaves_tenants_bit_identical() {
    // Admit B three ticks into A's run: lockstep multiplexing must not
    // couple them — both still match their solo trajectories.
    let spec_a = mixed_spec("early", 7, 8, 0);
    let spec_b = mixed_spec("late", 8, 5, 0);
    let (solo_a, ck_a) = run_solo(&spec_a, 1);
    let (solo_b, ck_b) = run_solo(&spec_b, 1);
    for workers in WORKER_COUNTS {
        let mut mgr = SessionManager::new();
        let a = mgr.admit(&spec_a).unwrap();
        let mut events = Vec::new();
        let mut la = Vec::new();
        let mut lb = Vec::new();
        for _ in 0..3 {
            events.clear();
            mgr.tick(workers, &mut events);
            for e in &events {
                if let TickEvent::Metrics { loss, .. } = e {
                    la.push(loss.to_bits());
                }
            }
        }
        let b = mgr.admit(&spec_b).unwrap();
        let mut guard = 0;
        while mgr.n_running() > 0 {
            events.clear();
            mgr.tick(workers, &mut events);
            for e in &events {
                if let TickEvent::Metrics { session, loss, .. } = e {
                    if *session == a {
                        la.push(loss.to_bits());
                    } else {
                        lb.push(loss.to_bits());
                    }
                }
            }
            guard += 1;
            assert!(guard < 100, "ticks runaway");
        }
        assert_eq!(la, solo_a, "w={workers} tenant A");
        assert_eq!(lb, solo_b, "w={workers} tenant B");
        assert_eq!(ck_bits(&mgr.checkpoint(a).unwrap().1), ck_bits(&ck_a));
        assert_eq!(ck_bits(&mgr.checkpoint(b).unwrap().1), ck_bits(&ck_b));
    }
}

#[test]
fn pause_resume_does_not_perturb_the_trajectory() {
    let spec = mixed_spec("pausy", 21, 6, 0);
    let (solo, ck_solo) = run_solo(&spec, 1);
    let mut mgr = SessionManager::new();
    let id = mgr.admit(&spec).unwrap();
    let mut events = Vec::new();
    let mut losses = Vec::new();
    let mut drain = |mgr: &mut SessionManager,
                     events: &mut Vec<TickEvent>,
                     losses: &mut Vec<u64>| {
        events.clear();
        mgr.tick(2, events);
        for e in events.iter() {
            if let TickEvent::Metrics { loss, .. } = e {
                losses.push(loss.to_bits());
            }
        }
    };
    drain(&mut mgr, &mut events, &mut losses);
    drain(&mut mgr, &mut events, &mut losses);
    mgr.pause(id).unwrap();
    // Ticks while paused are no-ops for this session.
    for _ in 0..3 {
        drain(&mut mgr, &mut events, &mut losses);
    }
    assert_eq!(losses.len(), 2, "paused session must not step");
    assert_eq!(mgr.get(id).unwrap().state, SessionState::Paused);
    mgr.resume(id).unwrap();
    while mgr.n_running() > 0 {
        drain(&mut mgr, &mut events, &mut losses);
    }
    assert_eq!(losses, solo);
    assert_eq!(ck_bits(&mgr.checkpoint(id).unwrap().1),
               ck_bits(&ck_solo));
}

#[test]
fn inline_and_prefetched_noise_are_the_same_stream() {
    // prefetch = 0 generates noise on the tick thread; prefetch = 3
    // streams it through the bounded-channel producer. Same bytes, same
    // trajectory, bit for bit.
    let inline_spec = mixed_spec("inline", 33, 6, 0);
    let prefetch_spec = mixed_spec("prefetch", 33, 6, 3);
    let (l0, ck0) = run_solo(&inline_spec, 2);
    let (l1, ck1) = run_solo(&prefetch_spec, 2);
    assert_eq!(l0, l1);
    assert_eq!(ck_bits(&ck0), ck_bits(&ck1));
}

#[test]
fn checkpoint_restores_bit_exact_through_the_json_wire_form() {
    // 5 ticks, checkpoint through emit∘parse (the daemon's socket
    // format), restore into a fresh manager, 5 more ticks — identical
    // to 10 uninterrupted ticks, at every worker count.
    let spec = restorable_spec(55, 10);
    let (solo_losses, ck_full) = run_solo(&spec, 1);
    for workers in WORKER_COUNTS {
        let mut mgr = SessionManager::new();
        let id = mgr.admit(&spec).unwrap();
        let mut events = Vec::new();
        for _ in 0..5 {
            events.clear();
            mgr.tick(workers, &mut events);
        }
        let (step, ck) = mgr.checkpoint(id).unwrap();
        assert_eq!(step, 5);
        let wire = ck.to_json().emit(0);
        let ck_back =
            Checkpoint::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(ck_bits(&ck), ck_bits(&ck_back), "wire round trip");
        let mut mgr2 = SessionManager::new();
        let id2 = mgr2.restore(&spec, step, &ck_back).unwrap();
        assert_eq!(mgr2.get(id2).unwrap().state, SessionState::Running);
        let mut losses = Vec::new();
        for _ in 0..5 {
            events.clear();
            mgr2.tick(workers, &mut events);
            for e in &events {
                if let TickEvent::Metrics { loss, .. } = e {
                    losses.push(loss.to_bits());
                }
            }
        }
        assert_eq!(mgr2.get(id2).unwrap().state, SessionState::Done);
        assert_eq!(losses[..], solo_losses[5..],
                   "w={workers} resumed loss stream");
        assert_eq!(ck_bits(&mgr2.checkpoint(id2).unwrap().1),
                   ck_bits(&ck_full), "w={workers} final state");
    }
}

#[test]
fn restore_rejects_non_restorable_and_mismatched_checkpoints() {
    let mut mgr = SessionManager::new();
    // AdamW / vec layers can't restore (private step counters).
    let spec = mixed_spec("norestore", 1, 5, 0);
    let id = mgr.admit(&spec).unwrap();
    let mut events = Vec::new();
    mgr.tick(1, &mut events);
    let (step, ck) = mgr.checkpoint(id).unwrap();
    assert!(mgr.restore(&spec, step, &ck).is_err());
    // Restorable spec, but tampered checkpoints must error, not panic.
    let rspec = restorable_spec(2, 5);
    let rid = mgr.admit(&rspec).unwrap();
    events.clear();
    mgr.tick(1, &mut events);
    let (rstep, rck) = mgr.checkpoint(rid).unwrap();
    let mut missing = Checkpoint { tensors: rck.tensors[1..].to_vec() };
    assert!(mgr.restore(&rspec, rstep, &missing).is_err(), "missing w0");
    missing = Checkpoint { tensors: rck.tensors.clone() };
    missing.tensors.push(("bogus".into(), vec![1], vec![0.0]));
    assert!(mgr.restore(&rspec, rstep, &missing).is_err(),
            "unconsumed tensor");
    let mut bad_dims = Checkpoint { tensors: rck.tensors.clone() };
    bad_dims.tensors[0].1 = vec![2, 2];
    bad_dims.tensors[0].2 = vec![0.0; 4];
    assert!(mgr.restore(&rspec, rstep, &bad_dims).is_err(), "bad dims");
    assert!(mgr.restore(&rspec, rspec.steps + 1, &rck).is_err(),
            "step beyond spec");
    // And a well-formed restore still works after all the rejects.
    assert!(mgr.restore(&rspec, rstep, &rck).is_ok());
}

#[test]
fn protocol_rejects_hostile_requests_without_panicking() {
    // Fixed fixtures: the daemon must answer every one of these with an
    // error, never a panic (resource ceilings included).
    for bad in [
        "",
        "not json at all",
        "[1,2,3]",
        r#"{"cmd":"admit","spec":{"name":"x","seed":0,"steps":5,
            "layers":[{"kind":"mofasgd","m":4096,"n":4096,"rank":4096}]}}"#,
        r#"{"cmd":"admit","spec":{"name":"x","seed":0,"steps":5,"accum":0,
            "layers":[{"kind":"sgdm","m":4,"n":4}]}}"#,
        r#"{"cmd":"admit","spec":{"name":"x","seed":0,"steps":5,
            "prefetch":9999,"layers":[{"kind":"sgdm","m":4,"n":4}]}}"#,
        r#"{"cmd":"admit","spec":{"name":"x","seed":-3,"steps":5,
            "layers":[{"kind":"sgdm","m":4,"n":4}]}}"#,
        r#"{"cmd":"restore","spec":{"name":"x","seed":0,"steps":5,
            "layers":[{"kind":"sgdm","m":4,"n":4}]},"step":1,
            "checkpoint":{"version":1,
                "tensors":[{"name":"w0","dims":[4,4],"bits":[1]}]}}"#,
        r#"{"cmd":"checkpoint"}"#,
        r#"{"cmd":"unknown-verb"}"#,
        // Hostile dims whose product overflows usize: clean reject,
        // not a debug-build multiply-overflow panic.
        r#"{"cmd":"restore","spec":{"name":"x","seed":0,"steps":5,
            "layers":[{"kind":"sgdm","m":4,"n":4}]},"step":1,
            "checkpoint":{"version":1,"tensors":[{"name":"w0",
                "dims":[4294967296,4294967296],"bits":[1]}]}}"#,
    ] {
        assert!(parse_request(bad).is_err(), "{bad}");
    }
    // Deep-nesting bombs: the random fuzz below cannot generate these
    // (matched brackets 100k deep), and without a parser depth cap they
    // overflow the stack — an abort, not an Err. Both the bare bomb and
    // one tucked inside an otherwise valid request must reject cleanly.
    let bomb = "[".repeat(100_000);
    assert!(parse_request(&bomb).is_err());
    let closed = format!("{}{}", bomb, "]".repeat(100_000));
    assert!(parse_request(&closed).is_err());
    let nested_spec = format!(
        r#"{{"cmd":"admit","spec":{}1{}}}"#,
        "{\"name\":".repeat(50_000), "}".repeat(50_000));
    assert!(parse_request(&nested_spec).is_err());
    // Property fuzz: random ASCII soup and single-byte mutations of a
    // valid admit line — parse_request returns Ok or Err, never panics
    // (Prop::check catches unwinds and reports the replay seed).
    let valid = format!(
        r#"{{"cmd":"admit","spec":{}}}"#,
        mixed_spec("fuzz", 3, 5, 0).to_json().emit(0)
    );
    assert!(parse_request(&valid).is_ok());
    let prop = Prop::new(300);
    prop.check("parse_request_fuzz", |rng| {
        let len = prop::dim(rng, 120);
        let soup: String = (0..len)
            .map(|_| (32 + rng.below(95)) as u8 as char)
            .collect();
        let _ = parse_request(&soup);
        // Mutate the valid line (it is pure ASCII): flip one byte and
        // truncate at a random point.
        let mut bytes = valid.clone().into_bytes();
        let i = rng.below(bytes.len());
        bytes[i] = (32 + rng.below(95)) as u8;
        let mutated = String::from_utf8(bytes).unwrap();
        let _ = parse_request(&mutated);
        let cut = rng.below(valid.len());
        let _ = parse_request(&valid[..cut]);
    });
}

/// Serializes the tests in this file that install a process-global
/// fault-injection spec (the check lanes run this binary with
/// `RUST_TEST_THREADS=1`, so the spec can never leak into a
/// concurrently running parity test there).
static FAULT_GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[test]
fn verbs_answer_clean_errors_for_unknown_failed_and_evicted() {
    use mofasgd::util::faultinject;
    let _g = FAULT_GATE.lock().unwrap_or_else(|p| p.into_inner());

    // Unknown ids: every verb is a clean "no session" error.
    let mut mgr = SessionManager::new();
    for e in [
        mgr.pause(99).unwrap_err().to_string(),
        mgr.resume(99).unwrap_err().to_string(),
        mgr.evict(99).unwrap_err().to_string(),
        mgr.checkpoint(99).unwrap_err().to_string(),
    ] {
        assert!(e.contains("no session 99"), "{e}");
    }

    // Fail one of two sessions mid-tick via a deterministic injected
    // stage panic; the other keeps running.
    let doomed = mixed_spec("doomed", 5, 6, 0);
    let bystander = mixed_spec("bystander", 6, 6, 0);
    let id = mgr.admit(&doomed).unwrap();
    let sid = mgr.admit(&bystander).unwrap();
    faultinject::set_spec(&format!("panic@session:{id}/stage:0"))
        .unwrap();
    let mut events = Vec::new();
    mgr.tick(2, &mut events);
    faultinject::clear();
    let s = mgr.get(id).unwrap();
    assert_eq!(s.state, SessionState::Failed);
    let reason = s.fail_reason().unwrap();
    assert!(reason.contains("injected fault"), "{reason}");
    assert!(events.iter().any(|e| matches!(
        e, TickEvent::Failed { session, .. } if *session == id)));
    assert_eq!(mgr.get(sid).unwrap().state, SessionState::Running);

    // Verbs on the Failed session: clean errors naming the state —
    // except evict, the documented cleanup path.
    let e = mgr.pause(id).unwrap_err().to_string();
    assert!(e.contains("failed"), "{e}");
    let e = mgr.resume(id).unwrap_err().to_string();
    assert!(e.contains("failed"), "{e}");
    let e = mgr.checkpoint(id).unwrap_err().to_string();
    assert!(e.contains("failed") && e.contains("quarantined"), "{e}");
    mgr.evict(id).unwrap();

    // Verbs on the evicted id: back to clean "no session".
    for e in [
        mgr.pause(id).unwrap_err().to_string(),
        mgr.resume(id).unwrap_err().to_string(),
        mgr.evict(id).unwrap_err().to_string(),
        mgr.checkpoint(id).unwrap_err().to_string(),
    ] {
        assert!(e.contains(&format!("no session {id}")), "{e}");
    }

    // A healthy evicted session answers identically.
    mgr.evict(sid).unwrap();
    let e = mgr.evict(sid).unwrap_err().to_string();
    assert!(e.contains(&format!("no session {sid}")), "{e}");
}

#[test]
fn shutdown_under_load_flushes_ack_and_joins_within_bound() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    // A long-running session (nowhere near done at shutdown) plus a
    // small finished one whose checkpoint responses are bulky enough to
    // overflow a non-reading client's socket buffer and writer queue.
    let load_spec = SessionSpec {
        name: "load".to_string(),
        seed: 9,
        steps: 1_000_000,
        accum: 4,
        eta: 0.001,
        noise: 0.1,
        prefetch: 0,
        layers: vec![LayerSpec { kind: LayerKind::SgdM, m: 96, n: 96,
                                 rank: 4, beta: 0.9 }],
        vecs: vec![],
    };
    let ck_spec = SessionSpec {
        name: "ckfodder".to_string(),
        seed: 10,
        steps: 2,
        accum: 1,
        eta: 0.01,
        noise: 0.1,
        prefetch: 0,
        layers: vec![LayerSpec { kind: LayerKind::SgdM, m: 64, n: 64,
                                 rank: 4, beta: 0.9 }],
        vecs: vec![],
    };
    let daemon = mofasgd::serve::Daemon::bind("127.0.0.1:0").unwrap();
    let addr = daemon.local_addr().to_string();
    let (done_tx, done_rx) = channel::<()>();
    std::thread::spawn(move || {
        daemon.run(2).unwrap();
        let _ = done_tx.send(());
    });

    let mut ctl = TcpStream::connect(&addr).unwrap();
    ctl.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(ctl.try_clone().unwrap());
    let send = |sock: &mut TcpStream, line: &str| {
        sock.write_all(line.as_bytes()).unwrap();
        sock.write_all(b"\n").unwrap();
        sock.flush().unwrap();
    };
    let mut next_response = |reader: &mut BufReader<TcpStream>| loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0,
                "daemon closed the stream early");
        let v = Json::parse(line.trim()).unwrap();
        if v.get("ok").is_some() {
            return v;
        }
    };
    send(&mut ctl, &format!(r#"{{"cmd":"admit","spec":{}}}"#,
                            ck_spec.to_json().emit(0)));
    let r = next_response(&mut reader);
    assert_eq!(r.req("ok").unwrap(), &Json::Bool(true));
    let ck_id = r.req("session").unwrap().as_usize().unwrap();
    send(&mut ctl, &format!(r#"{{"cmd":"admit","spec":{}}}"#,
                            load_spec.to_json().emit(0)));
    let r = next_response(&mut reader);
    assert_eq!(r.req("ok").unwrap(), &Json::Bool(true));

    // Hostile client: requests hundreds of full checkpoints and never
    // reads a byte. Its socket buffer fills, then its writer queue; the
    // daemon must shed it, not stall on it.
    let mut greedy = TcpStream::connect(&addr).unwrap();
    for _ in 0..400 {
        send(&mut greedy,
             &format!(r#"{{"cmd":"checkpoint","session":{ck_id}}}"#));
    }

    // Shutdown lands mid-tick for the load session (1M steps: it
    // cannot have finished). The final ack must still reach the
    // control client, and the daemon must join every thread it owns
    // within a bound — not wait on the greedy client.
    send(&mut ctl, r#"{"cmd":"shutdown"}"#);
    let bye = next_response(&mut reader);
    assert_eq!(bye.req("ok").unwrap(), &Json::Bool(true));
    done_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("daemon did not shut down within the bound");
    drop(greedy);
}

#[test]
fn daemon_smoke_two_sessions_stream_metrics_over_tcp() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::time::Duration;

    let smoke_spec = |name: &str, seed: u64| SessionSpec {
        name: name.to_string(),
        seed,
        steps: 5,
        accum: 1,
        eta: 0.05,
        noise: 0.1,
        prefetch: 1,
        layers: vec![
            LayerSpec { kind: LayerKind::SgdM, m: 8, n: 8, rank: 4,
                        beta: 0.9 },
            LayerSpec { kind: LayerKind::SignSgd, m: 6, n: 6, rank: 4,
                        beta: 0.9 },
        ],
        vecs: vec![],
    };
    let daemon = mofasgd::serve::Daemon::bind("127.0.0.1:0").unwrap();
    let addr = daemon.local_addr().to_string();
    let handle = std::thread::spawn(move || daemon.run(2).unwrap());

    let mut sock = TcpStream::connect(&addr).unwrap();
    sock.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    let mut events: Vec<Json> = Vec::new();
    let mut send = |sock: &mut TcpStream, line: &str| {
        sock.write_all(line.as_bytes()).unwrap();
        sock.write_all(b"\n").unwrap();
        sock.flush().unwrap();
    };
    // Responses have an "ok" key; unsolicited events have "event".
    // They interleave once ticks start, so buffer events while waiting.
    let mut next_response =
        |reader: &mut BufReader<TcpStream>, events: &mut Vec<Json>| {
            loop {
                let mut line = String::new();
                assert!(reader.read_line(&mut line).unwrap() > 0,
                        "daemon closed the stream early");
                let v = Json::parse(line.trim()).unwrap();
                if v.get("ok").is_some() {
                    return v;
                }
                assert!(v.get("event").is_some(), "{line}");
                events.push(v);
            }
        };

    send(&mut sock,
         &format!(r#"{{"cmd":"admit","spec":{}}}"#,
                  smoke_spec("a", 1).to_json().emit(0)));
    let ra = next_response(&mut reader, &mut events);
    assert_eq!(ra.req("ok").unwrap(), &Json::Bool(true));
    let ida = ra.req("session").unwrap().as_usize().unwrap();
    send(&mut sock,
         &format!(r#"{{"cmd":"admit","spec":{}}}"#,
                  smoke_spec("b", 2).to_json().emit(0)));
    let rb = next_response(&mut reader, &mut events);
    assert_eq!(rb.req("ok").unwrap(), &Json::Bool(true));
    let idb = rb.req("session").unwrap().as_usize().unwrap();
    assert_ne!(ida, idb);

    // A malformed line mid-run: the daemon answers with an error and
    // keeps ticking.
    send(&mut sock, "}}}garbage{{{");
    let rg = next_response(&mut reader, &mut events);
    assert_eq!(rg.req("ok").unwrap(), &Json::Bool(false));

    // Drain events until both sessions report done.
    let mut done = [false, false];
    let mut check = |events: &[Json], done: &mut [bool; 2]| {
        for v in events {
            if v.req("event").unwrap().as_str().unwrap() == "done" {
                let id = v.req("session").unwrap().as_usize().unwrap();
                if id == ida {
                    done[0] = true;
                } else if id == idb {
                    done[1] = true;
                }
            }
        }
    };
    check(&events, &mut done);
    while !(done[0] && done[1]) {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).unwrap() > 0,
                "daemon closed the stream early");
        let v = Json::parse(line.trim()).unwrap();
        assert!(v.get("event").is_some(), "{line}");
        events.push(v);
        check(&events[events.len() - 1..], &mut done);
    }
    // Both streamed per-tick metrics along the way.
    let n_metrics = |id: usize| {
        events.iter().filter(|v| {
            v.req("event").unwrap().as_str().unwrap() == "metrics"
                && v.req("session").unwrap().as_usize().unwrap() == id
        }).count()
    };
    assert_eq!(n_metrics(ida), 5);
    assert_eq!(n_metrics(idb), 5);

    send(&mut sock, r#"{"cmd":"status"}"#);
    let st = next_response(&mut reader, &mut events);
    let sessions = st.req("sessions").unwrap().as_arr().unwrap();
    assert_eq!(sessions.len(), 2);
    for s in sessions {
        assert_eq!(s.req("state").unwrap().as_str().unwrap(), "done");
        assert_eq!(s.req("step").unwrap().as_usize().unwrap(), 5);
    }
    send(&mut sock, r#"{"cmd":"shutdown"}"#);
    let bye = next_response(&mut reader, &mut events);
    assert_eq!(bye.req("ok").unwrap(), &Json::Bool(true));
    handle.join().unwrap();
}

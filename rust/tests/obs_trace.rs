//! Tracing transparency + export validity.
//!
//! The observability layer is pure observation: it reads clocks and
//! writes to per-thread rings, and never touches math, scheduling, or
//! worker resolution. This test proves it — an identical multi-layer
//! fleet run with tracing OFF and tracing ON must produce bit-identical
//! weights — and then checks the drained trace itself: spans from every
//! instrumented layer of the stack (task-graph, fleet stage, plan node,
//! linalg, engine phase), a valid Chrome trace-event JSON document, and
//! live counters.
//!
//! Single test: the recorder enable flag, rings, and counters are
//! process-global, so sibling tests would race them.

use std::collections::HashSet;

use mofasgd::coordinator::metrics::{Phase, PhaseTimer, TrainMetrics};
use mofasgd::fusion::{self, FleetUnit};
use mofasgd::linalg::Mat;
use mofasgd::obs;
use mofasgd::optim::adamw::AdamWVec;
use mofasgd::optim::{AdamW, GaLore, MatOpt, MatUnit, MoFaSgd, VecUnit};
use mofasgd::util::json::Json;
use mofasgd::util::rng::Rng;

struct Stack {
    mofa: MoFaSgd,
    gal: GaLore,
    adw: AdamW,
    vadw: AdamWVec,
    w_mofa: Mat,
    w_gal: Mat,
    w_adw: Mat,
    wv: Vec<f32>,
    g_mofa: Mat,
    g_gal: Mat,
    g_adw: Mat,
    gv: Vec<f32>,
}

fn build() -> Stack {
    let mut wr = Rng::new(11);
    let mut gr = Rng::new(12);
    Stack {
        mofa: MoFaSgd::new(64, 48, 16, 0.9),
        gal: GaLore::new(48, 40, 8, 1000, 0.9, 0.999, 3),
        adw: AdamW::new(56, 24, 0.9, 0.999, 0.0),
        vadw: AdamWVec::new(256, 0.9, 0.999, 0.0),
        w_mofa: Mat::randn(&mut wr, 64, 48, 1.0),
        w_gal: Mat::randn(&mut wr, 48, 40, 1.0),
        w_adw: Mat::randn(&mut wr, 56, 24, 1.0),
        wv: wr.normal_vec(256, 1.0),
        g_mofa: Mat::randn(&mut gr, 64, 48, 1.0),
        g_gal: Mat::randn(&mut gr, 48, 40, 1.0),
        g_adw: Mat::randn(&mut gr, 56, 24, 1.0),
        gv: gr.normal_vec(256, 1.0),
    }
}

fn run_steps(st: &mut Stack, steps: usize, workers: usize) {
    let mut fleet = fusion::Fleet::new();
    for _ in 0..steps {
        let mut u0 = MatUnit::new(MatOpt::MoFaSgd(&mut st.mofa),
                                  &mut st.w_mofa, &st.g_mofa, 1e-3);
        let mut u1 = MatUnit::new(MatOpt::GaLore(&mut st.gal),
                                  &mut st.w_gal, &st.g_gal, 1e-3);
        let mut u2 = MatUnit::new(MatOpt::AdamW(&mut st.adw),
                                  &mut st.w_adw, &st.g_adw, 1e-3);
        let mut u3 = VecUnit::new(&mut st.vadw, &mut st.wv, &st.gv, 1e-3);
        let mut refs: [&mut dyn FleetUnit; 4] =
            [&mut u0, &mut u1, &mut u2, &mut u3];
        fleet.run(&mut refs, workers);
    }
}

#[test]
fn tracing_is_transparent_and_exports_a_valid_trace() {
    // Baseline: tracing off.
    obs::set_enabled(false);
    let mut base = build();
    run_steps(&mut base, 4, 4);

    // Traced: identical stack, identical steps, recording on.
    obs::set_enabled(true);
    let _ = obs::drain(); // discard anything recorded before this test
    let mut traced = build();
    run_steps(&mut traced, 4, 4);
    // One engine phase through the metrics timer (Engine category).
    let mut metrics = TrainMetrics::new("obs_trace_test");
    let t = PhaseTimer::begin(Phase::Fwd);
    metrics.end_phase(t);

    let trace = obs::drain();
    obs::set_enabled(false);

    // -- bit parity: tracing changed nothing --------------------------------
    assert_eq!(base.w_mofa.data, traced.w_mofa.data, "MoFaSgd weights");
    assert_eq!(base.w_gal.data, traced.w_gal.data, "GaLore weights");
    assert_eq!(base.w_adw.data, traced.w_adw.data, "AdamW weights");
    assert_eq!(base.wv, traced.wv, "vec weights");
    assert!(metrics.fwd_s >= 0.0);

    // -- span coverage: every instrumented stack layer shows up ------------
    let cats: HashSet<&str> =
        trace.spans.iter().map(|s| s.cat.name()).collect();
    for want in ["task", "fleet", "plan", "linalg", "engine"] {
        assert!(cats.contains(want),
                "no `{want}` spans in trace (got {cats:?})");
    }
    for sp in &trace.spans {
        assert!(sp.end_ns >= sp.start_ns,
                "negative span {} [{}, {}]", sp.label, sp.start_ns,
                sp.end_ns);
    }
    assert!(trace.counter("flops") > 0, "flops counter dead");
    assert!(trace.counter("tasks_run") > 0, "tasks_run counter dead");
    assert!(trace.counter("fleet_stages") > 0, "fleet_stages counter dead");

    // -- Chrome trace export round-trips as valid JSON ----------------------
    let text = obs::export::chrome_trace(&trace).emit(1);
    let parsed = Json::parse(&text).expect("chrome trace is valid JSON");
    let events = parsed.req("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), trace.spans.len());
    let e0 = &events[0];
    for key in ["name", "cat", "ph", "ts", "dur", "pid", "tid", "args"] {
        assert!(e0.get(key).is_some(), "event missing `{key}`");
    }
    assert_eq!(e0.req("ph").unwrap().as_str().unwrap(), "X");

    // Summary/counter tables build without panicking and see every group.
    let summary = obs::export::summary_table(&trace);
    assert!(!summary.rows.is_empty());
    let counters = obs::export::counter_table(&trace);
    assert!(!counters.rows.is_empty());

    // The run_checks obs lane sets MOFA_TRACE: emit the file so the lane
    // can assert a trace artifact exists and contains traceEvents.
    if let Some(path) =
        std::env::var("MOFA_TRACE").ok().filter(|s| !s.is_empty())
    {
        std::fs::write(&path, &text).expect("write trace artifact");
    }
}

//! Replica-vs-serial parity suite (PR 8 tentpole; DESIGN.md §13).
//!
//! The replicated fleet shards a step's micro-batches across R in-process
//! replicas and folds their gradients through the fixed-topology lane
//! tree (`fusion::reduce`). The reduction's association is a pure
//! function of `(n_micro, TREE_WIDTH)` — never of R or the worker count —
//! so every `(R, workers)` combination must be *bit-identical* to the
//! R = 1 serial baseline. The frozen baseline here is
//! `reduce::reduce_ref` (the same lane tree, folded sequentially) feeding
//! the serial `MatrixOptimizer::step` loop.
//!
//! `rust/run_checks.sh` runs this suite under `RUST_TEST_THREADS=1` and
//! again with the kernel pool pinned to 2 and 8 workers via
//! `MOFA_WORKERS` — parity must hold at every combination.

use std::collections::HashMap;

use mofasgd::coordinator::checkpoint::Checkpoint;
use mofasgd::fusion::reduce::{self, LanePtr, TreeSchedule, TREE_WIDTH};
use mofasgd::fusion::{self, FleetUnit, ReplicaSet};
use mofasgd::linalg::Mat;
use mofasgd::optim::adamw::AdamWVec;
use mofasgd::optim::{AdamW, GaLore, GradAccumUnit, MatOpt, MatUnit,
                     MatrixOptimizer, MoFaSgd, Muon, SgdM, SignSgd,
                     TreeReduceUnit, VecOptimizer, VecUnit};
use mofasgd::util::rng::Rng;

const ETA: f32 = 0.01;
const STEPS: usize = 10;
const N_MICRO: usize = 5;

#[derive(Clone, Copy)]
enum Kind {
    MofaR4,
    MofaR32,
    Galore,
    Muon,
    AdamW,
    SgdM,
    SignSgd,
}

/// The mixed acceptance fleet: MoFaSGD at r ∈ {4, 32}, GaLore (which
/// resamples its subspace every 3 steps — a 10-step run refreshes it
/// three times mid-replication), Muon and the dense optimizers.
fn mixed_spec() -> Vec<(Kind, usize, usize)> {
    vec![
        (Kind::MofaR4, 48, 40),
        (Kind::MofaR32, 96, 80),
        (Kind::Galore, 64, 48),
        (Kind::AdamW, 56, 24),
        (Kind::MofaR32, 80, 96),
        (Kind::Muon, 40, 40),
        (Kind::SgdM, 32, 64),
        (Kind::MofaR4, 40, 56),
        (Kind::Galore, 48, 64),
        (Kind::SignSgd, 24, 24),
    ]
}

/// Layers whose full optimizer state is externally restorable — the
/// checkpoint round-trip needs to rebuild state bit-exactly, and
/// AdamW/GaLore keep a private step counter.
fn restorable_spec() -> Vec<(Kind, usize, usize)> {
    vec![
        (Kind::MofaR4, 48, 40),
        (Kind::Muon, 40, 40),
        (Kind::SgdM, 32, 64),
        (Kind::MofaR32, 40, 56),
        (Kind::SignSgd, 24, 24),
    ]
}

enum Opt {
    Mofa(MoFaSgd),
    Galore(GaLore),
    Muon(Muon),
    AdamW(AdamW),
    SgdM(SgdM),
    SignSgd(SignSgd),
}

impl Opt {
    fn build(kind: Kind, m: usize, n: usize, seed: u64) -> Opt {
        match kind {
            Kind::MofaR4 => Opt::Mofa(MoFaSgd::new(m, n, 4, 0.9)),
            Kind::MofaR32 => Opt::Mofa(MoFaSgd::new(m, n, 32, 0.9)),
            Kind::Galore => {
                Opt::Galore(GaLore::new(m, n, 8, 3, 0.9, 0.999, seed))
            }
            Kind::Muon => Opt::Muon(Muon::new(m, n, 0.9)),
            Kind::AdamW => Opt::AdamW(AdamW::new(m, n, 0.9, 0.999, 0.01)),
            Kind::SgdM => Opt::SgdM(SgdM::new(m, n, 0.9)),
            Kind::SignSgd => Opt::SignSgd(SignSgd::new()),
        }
    }

    /// The frozen serial per-layer baseline.
    fn step(&mut self, w: &mut Mat, g: &Mat, eta: f32) {
        match self {
            Opt::Mofa(o) => o.step(w, g, eta),
            Opt::Galore(o) => o.step(w, g, eta),
            Opt::Muon(o) => o.step(w, g, eta),
            Opt::AdamW(o) => o.step(w, g, eta),
            Opt::SgdM(o) => o.step(w, g, eta),
            Opt::SignSgd(o) => o.step(w, g, eta),
        }
    }

    /// Step unit reading the reduced mean gradient from lane 0.
    fn unit_reduced<'a>(&'a mut self, w: &'a mut Mat, lanes: LanePtr,
                        eta: f32) -> MatUnit<'a> {
        let opt = match self {
            Opt::Mofa(o) => MatOpt::MoFaSgd(o),
            Opt::Galore(o) => MatOpt::GaLore(o),
            Opt::Muon(o) => MatOpt::Muon(o),
            Opt::AdamW(o) => MatOpt::AdamW(o),
            Opt::SgdM(o) => MatOpt::SgdM(o),
            Opt::SignSgd(o) => MatOpt::SignSgd(o),
        };
        MatUnit::reduced(opt, w, lanes, eta)
    }

    /// Bit-exact state comparison against another instance.
    fn assert_state_eq(&self, other: &Opt, li: usize, tag: &str) {
        match (self, other) {
            (Opt::Mofa(a), Opt::Mofa(b)) => {
                assert_eq!(a.u.data, b.u.data, "{tag} layer {li}: U");
                assert_eq!(a.s, b.s, "{tag} layer {li}: sigma");
                assert_eq!(a.v.data, b.v.data, "{tag} layer {li}: V");
            }
            (Opt::Galore(a), Opt::Galore(b)) => {
                assert_eq!(a.q.data, b.q.data, "{tag} layer {li}: Q");
                assert_eq!(a.m1.data, b.m1.data, "{tag} layer {li}: m1");
                assert_eq!(a.m2.data, b.m2.data, "{tag} layer {li}: m2");
            }
            (Opt::Muon(a), Opt::Muon(b)) => {
                assert_eq!(a.m.data, b.m.data, "{tag} layer {li}: momentum");
            }
            (Opt::AdamW(a), Opt::AdamW(b)) => {
                assert_eq!(a.m.data, b.m.data, "{tag} layer {li}: m");
                assert_eq!(a.v.data, b.v.data, "{tag} layer {li}: v");
            }
            (Opt::SgdM(a), Opt::SgdM(b)) => {
                assert_eq!(a.m.data, b.m.data, "{tag} layer {li}: momentum");
            }
            (Opt::SignSgd(_), Opt::SignSgd(_)) => {}
            _ => panic!("{tag} layer {li}: kind mismatch"),
        }
    }
}

struct Stack {
    opts: Vec<Opt>,
    ws: Vec<Mat>,
    vec_opts: Vec<AdamWVec>,
    vec_ws: Vec<Vec<f32>>,
}

const VEC_LENS: [usize; 2] = [100, 3000];

fn build_stack(spec: &[(Kind, usize, usize)], with_vec: bool,
               seed: u64) -> Stack {
    let mut rng = Rng::new(seed);
    let mut opts = Vec::new();
    let mut ws = Vec::new();
    for (li, &(kind, m, n)) in spec.iter().enumerate() {
        opts.push(Opt::build(kind, m, n, 1000 + li as u64));
        ws.push(Mat::randn(&mut rng, m, n, 1.0));
    }
    let (vec_opts, vec_ws) = if with_vec {
        (VEC_LENS.iter()
             .map(|&l| AdamWVec::new(l, 0.9, 0.999, 0.01))
             .collect(),
         VEC_LENS.iter().map(|&l| rng.normal_vec(l, 1.0)).collect())
    } else {
        (Vec::new(), Vec::new())
    };
    Stack { opts, ws, vec_opts, vec_ws }
}

/// Per-(step, micro) gradients. Each micro-batch's data comes from
/// `Rng::shard_stream(step * N_MICRO + micro)` — derivation does not
/// advance the parent, so what a micro-batch sees is a pure function of
/// its global index, identical no matter which replica generates it or
/// how many replicas exist. Vec-layer gradients ride in 1×len Mats, the
/// lane representation the replicated fleet uses for flat params.
#[allow(clippy::type_complexity)]
fn micro_grads(spec: &[(Kind, usize, usize)], with_vec: bool, steps: usize,
               seed: u64) -> (Vec<Vec<Vec<Mat>>>, Vec<Vec<Vec<Mat>>>) {
    let base = Rng::new(seed);
    let mut mat = Vec::new();
    let mut vec = Vec::new();
    for step in 0..steps {
        let mut m_layers: Vec<Vec<Mat>> =
            spec.iter().map(|_| Vec::new()).collect();
        let mut v_layers: Vec<Vec<Mat>> = if with_vec {
            VEC_LENS.iter().map(|_| Vec::new()).collect()
        } else {
            Vec::new()
        };
        for micro in 0..N_MICRO {
            let mut s = base.shard_stream((step * N_MICRO + micro) as u64);
            for (li, &(_, m, n)) in spec.iter().enumerate() {
                m_layers[li].push(Mat::randn(&mut s, m, n, 0.5));
            }
            if with_vec {
                for (vi, &l) in VEC_LENS.iter().enumerate() {
                    v_layers[vi]
                        .push(Mat::from_vec(1, l, s.normal_vec(l, 0.5)));
                }
            }
        }
        mat.push(m_layers);
        vec.push(v_layers);
    }
    (mat, vec)
}

/// Frozen baseline: sequential lane-tree fold (`reduce_ref`), mean
/// scale, then the serial per-layer optimizer step.
fn run_serial_reference(stack: &mut Stack, mat_g: &[Vec<Vec<Mat>>],
                        vec_g: &[Vec<Vec<Mat>>], sched: &TreeSchedule) {
    let inv = 1.0 / sched.n_items() as f32;
    for step in 0..mat_g.len() {
        for (li, opt) in stack.opts.iter_mut().enumerate() {
            let micros: Vec<&[f32]> =
                mat_g[step][li].iter().map(|g| &g.data[..]).collect();
            let mut mean = reduce::reduce_ref(sched, &micros);
            for x in &mut mean {
                *x *= inv;
            }
            let (m, n) = (mat_g[step][li][0].rows, mat_g[step][li][0].cols);
            let gm = Mat::from_vec(m, n, mean);
            opt.step(&mut stack.ws[li], &gm, ETA);
        }
        if !vec_g.is_empty() {
            for (vi, o) in stack.vec_opts.iter_mut().enumerate() {
                let micros: Vec<&[f32]> =
                    vec_g[step][vi].iter().map(|g| &g.data[..]).collect();
                let mut mean = reduce::reduce_ref(sched, &micros);
                for x in &mut mean {
                    *x *= inv;
                }
                o.step(&mut stack.vec_ws[vi], &mean, ETA);
            }
        }
    }
}

/// The replicated path under test: per step, every layer contributes R
/// accumulation chains, a tree-reduce chain and a step chain, and the
/// whole stack runs as ONE `Fleet::run_replicated` dispatch.
fn run_replicated(stack: &mut Stack, mat_g: &[Vec<Vec<Mat>>],
                  vec_g: &[Vec<Vec<Mat>>], sched: &TreeSchedule,
                  r: usize, workers: usize) {
    let mut mat_lanes: Vec<Vec<Mat>> = stack
        .ws
        .iter()
        .map(|w| (0..TREE_WIDTH).map(|_| Mat::zeros(w.rows, w.cols))
            .collect())
        .collect();
    let mut vec_lanes: Vec<Vec<Mat>> = stack
        .vec_ws
        .iter()
        .map(|w| (0..TREE_WIDTH).map(|_| Mat::zeros(1, w.len())).collect())
        .collect();
    let mut fl = fusion::Fleet::new();
    for step in 0..mat_g.len() {
        let mat_lps: Vec<LanePtr> =
            mat_lanes.iter_mut().map(|l| LanePtr::new(l)).collect();
        let vec_lps: Vec<LanePtr> =
            vec_lanes.iter_mut().map(|l| LanePtr::new(l)).collect();
        let empty: Vec<Vec<Mat>> = Vec::new();
        let vg = if vec_g.is_empty() { &empty } else { &vec_g[step] };
        let mut accs: Vec<Vec<GradAccumUnit>> = Vec::new();
        for (lp, items) in mat_lps.iter().zip(&mat_g[step])
            .chain(vec_lps.iter().zip(vg))
        {
            accs.push((0..r)
                .map(|k| GradAccumUnit::new(*lp, sched, items, k, r))
                .collect());
        }
        let mut reds: Vec<TreeReduceUnit> = mat_lps
            .iter()
            .chain(vec_lps.iter())
            .map(|lp| TreeReduceUnit::new(*lp, sched))
            .collect();
        let mut mat_units: Vec<MatUnit> = stack
            .opts
            .iter_mut()
            .zip(&mut stack.ws)
            .zip(&mat_lps)
            .map(|((opt, w), lp)| opt.unit_reduced(w, *lp, ETA))
            .collect();
        let mut vec_units: Vec<VecUnit> = stack
            .vec_opts
            .iter_mut()
            .zip(&mut stack.vec_ws)
            .zip(&vec_lps)
            .map(|((o, w), lp)| VecUnit::reduced(o, w, *lp, ETA))
            .collect();
        let mut acc_refs: Vec<Vec<&mut dyn FleetUnit>> = accs
            .iter_mut()
            .map(|v| v.iter_mut().map(|u| u as &mut dyn FleetUnit).collect())
            .collect();
        let step_refs = mat_units
            .iter_mut()
            .map(|u| u as &mut dyn FleetUnit)
            .chain(vec_units.iter_mut().map(|u| u as &mut dyn FleetUnit));
        let mut sets: Vec<ReplicaSet> = acc_refs
            .iter_mut()
            .zip(reds.iter_mut())
            .zip(step_refs)
            .map(|((ar, red), st)| ReplicaSet {
                accum: ar.as_mut_slice(),
                reduce: red,
                step: st,
            })
            .collect();
        fl.run_replicated(&mut sets, workers);
    }
}

fn assert_stacks_eq(a: &Stack, b: &Stack, tag: &str) {
    for (li, (wa, wb)) in a.ws.iter().zip(&b.ws).enumerate() {
        assert!(wa.data.iter().all(|v| v.is_finite()),
                "{tag} layer {li}: non-finite weights");
        assert_eq!(wa.data, wb.data, "{tag} layer {li}: weights diverged");
    }
    for (li, (oa, ob)) in a.opts.iter().zip(&b.opts).enumerate() {
        oa.assert_state_eq(ob, li, tag);
    }
    for (vi, (va, vb)) in a.vec_ws.iter().zip(&b.vec_ws).enumerate() {
        assert_eq!(va, vb, "{tag} vec layer {vi}: weights diverged");
    }
}

/// ISSUE 8 acceptance: R ∈ {1, 2, 4} × workers ∈ {1, 2, 8}, ten steps of
/// the mixed fleet, every combination bit-identical to the serial
/// reference (which includes each MoFaSGD layer's SVD_r init step and
/// GaLore's mid-run subspace resamples).
#[test]
fn replicated_mixed_fleet_matches_serial_reference() {
    let spec = mixed_spec();
    let sched = TreeSchedule::new(N_MICRO, TREE_WIDTH);
    let (mat_g, vec_g) = micro_grads(&spec, true, STEPS, 17);
    let mut reference = build_stack(&spec, true, 42);
    run_serial_reference(&mut reference, &mat_g, &vec_g, &sched);
    for r in [1usize, 2, 4] {
        for workers in [1usize, 2, 8] {
            let mut stack = build_stack(&spec, true, 42);
            run_replicated(&mut stack, &mat_g, &vec_g, &sched, r, workers);
            assert_stacks_eq(&reference, &stack,
                             &format!("R={r} workers={workers}"));
        }
    }
}

/// Single-stage unit that keeps a `ReplicaSet` well-formed without
/// touching any state — lets the reduction be tested in isolation.
struct NoopStep;

impl FleetUnit for NoopStep {
    fn n_stages(&self) -> usize {
        1
    }

    fn run_stage(&mut self, _stage: usize) {}
}

/// Tree-order invariance fixtures: for micro counts that exercise empty
/// lanes (1), exact splits (2, 4, 8) and ragged splits (3, 5, 7), the
/// fleet-folded mean in lane 0 equals the frozen sequential baseline
/// bitwise at every (R, workers).
#[test]
fn tree_reduction_invariant_across_replicas_and_workers() {
    let mut rng = Rng::new(5);
    let (m, n) = (33, 17);
    for n_micro in [1usize, 2, 3, 4, 5, 7, 8] {
        let sched = TreeSchedule::new(n_micro, TREE_WIDTH);
        let items: Vec<Mat> =
            (0..n_micro).map(|_| Mat::randn(&mut rng, m, n, 1.0)).collect();
        let refs: Vec<&[f32]> = items.iter().map(|g| &g.data[..]).collect();
        let mut want = reduce::reduce_ref(&sched, &refs);
        let inv = 1.0 / n_micro as f32;
        for x in &mut want {
            *x *= inv;
        }
        for r in [1usize, 2, 4] {
            for workers in [1usize, 2, 8] {
                let mut lanes: Vec<Mat> =
                    (0..TREE_WIDTH).map(|_| Mat::zeros(m, n)).collect();
                {
                    let lp = LanePtr::new(&mut lanes);
                    let mut accs: Vec<GradAccumUnit> = (0..r)
                        .map(|k| GradAccumUnit::new(lp, &sched, &items, k, r))
                        .collect();
                    let mut red = TreeReduceUnit::new(lp, &sched);
                    let mut st = NoopStep;
                    let mut acc_refs: Vec<&mut dyn FleetUnit> = accs
                        .iter_mut()
                        .map(|u| u as &mut dyn FleetUnit)
                        .collect();
                    let mut sets = [ReplicaSet {
                        accum: &mut acc_refs,
                        reduce: &mut red,
                        step: &mut st,
                    }];
                    fusion::Fleet::new().run_replicated(&mut sets, workers);
                }
                assert_eq!(lanes[0].data, want,
                           "n={n_micro} R={r} workers={workers}");
            }
        }
    }
}

fn save_restorable(stack: &Stack, path: &std::path::Path) {
    let mut ck = Checkpoint { tensors: Vec::new() };
    for (li, w) in stack.ws.iter().enumerate() {
        ck.tensors.push((format!("w{li}"), vec![w.rows, w.cols],
                         w.data.clone()));
    }
    for (li, opt) in stack.opts.iter().enumerate() {
        match opt {
            Opt::Mofa(o) => {
                ck.tensors.push((format!("u{li}"), vec![o.u.rows, o.u.cols],
                                 o.u.data.clone()));
                ck.tensors.push((format!("s{li}"), vec![o.s.len()],
                                 o.s.clone()));
                ck.tensors.push((format!("v{li}"), vec![o.v.rows, o.v.cols],
                                 o.v.data.clone()));
            }
            Opt::Muon(o) => {
                ck.tensors.push((format!("m{li}"), vec![o.m.rows, o.m.cols],
                                 o.m.data.clone()));
            }
            Opt::SgdM(o) => {
                ck.tensors.push((format!("m{li}"), vec![o.m.rows, o.m.cols],
                                 o.m.data.clone()));
            }
            Opt::SignSgd(_) => {}
            _ => panic!("non-restorable optimizer in checkpoint spec"),
        }
    }
    ck.save(path).expect("checkpoint save");
}

fn load_restorable(spec: &[(Kind, usize, usize)],
                   path: &std::path::Path) -> Stack {
    let loaded = Checkpoint::load(path).expect("checkpoint load");
    let mut map: HashMap<String, (Vec<usize>, Vec<f32>)> = loaded
        .tensors
        .into_iter()
        .map(|(name, dims, data)| (name, (dims, data)))
        .collect();
    // Architecture comes from the spec (as in `Trainer::load_checkpoint`);
    // the checkpoint carries tensors only.
    let mut stack = build_stack(spec, false, 999);
    for (li, w) in stack.ws.iter_mut().enumerate() {
        let (dims, data) = map.remove(&format!("w{li}")).expect("weight");
        assert_eq!(dims, vec![w.rows, w.cols], "layer {li}: shape");
        w.data.copy_from_slice(&data);
    }
    for (li, opt) in stack.opts.iter_mut().enumerate() {
        match opt {
            Opt::Mofa(o) => {
                let (du, u) = map.remove(&format!("u{li}")).expect("U");
                let (_, s) = map.remove(&format!("s{li}")).expect("sigma");
                let (dv, v) = map.remove(&format!("v{li}")).expect("V");
                o.restore_factors(Mat::from_vec(du[0], du[1], u), s,
                                  Mat::from_vec(dv[0], dv[1], v));
            }
            Opt::Muon(o) => {
                let (dm, d) = map.remove(&format!("m{li}")).expect("muon m");
                o.m = Mat::from_vec(dm[0], dm[1], d);
            }
            Opt::SgdM(o) => {
                let (dm, d) = map.remove(&format!("m{li}")).expect("sgdm m");
                o.m = Mat::from_vec(dm[0], dm[1], d);
            }
            Opt::SignSgd(_) => {}
            _ => unreachable!("restorable_spec kinds only"),
        }
    }
    assert!(map.is_empty(), "unconsumed checkpoint tensors");
    stack
}

/// ISSUE 8 satellite: checkpoint round-trip under replication. Run the
/// replicated engine for 5 steps, serialize weights + optimizer state
/// through the real `Checkpoint` container, restore into a fresh stack,
/// continue 5 more steps — the result must be bit-identical to the
/// uninterrupted 10-step run at every (R, workers).
#[test]
fn checkpoint_roundtrip_under_replication() {
    let spec = restorable_spec();
    let sched = TreeSchedule::new(N_MICRO, TREE_WIDTH);
    let (mat_g, vec_g) = micro_grads(&spec, false, STEPS, 23);
    let mut baseline = build_stack(&spec, false, 42);
    run_serial_reference(&mut baseline, &mat_g, &vec_g, &sched);
    for r in [1usize, 2, 4] {
        for workers in [1usize, 2, 8] {
            let tag = format!("R={r} workers={workers}");
            let mut first = build_stack(&spec, false, 42);
            run_replicated(&mut first, &mat_g[..5], &vec_g, &sched, r,
                           workers);
            let path = std::env::temp_dir()
                .join(format!("mofa_replica_ckpt_r{r}_w{workers}.bin"));
            save_restorable(&first, &path);
            drop(first);
            let mut resumed = load_restorable(&spec, &path);
            std::fs::remove_file(&path).ok();
            run_replicated(&mut resumed, &mat_g[5..], &vec_g, &sched, r,
                           workers);
            assert_stacks_eq(&baseline, &resumed, &tag);
        }
    }
}

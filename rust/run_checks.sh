#!/usr/bin/env bash
# CI gate for the Rust coordinator.
#
#   rust/run_checks.sh                # build + test (+ fmt/clippy soft)
#   rust/run_checks.sh --bench-smoke  # also run the fusion bench smoke
#                                     # mode, emitting BENCH_fusion.json
#
# build + test are hard failures (the tier-1 gate). fmt/clippy are
# advisory: the container image may ship a toolchain without the rustfmt /
# clippy components, and their absence must not mask real build breaks.

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo fmt --check (advisory) =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check \
        || echo "WARN: rustfmt check failed (non-fatal)"
else
    echo "WARN: rustfmt component unavailable; skipping (non-fatal)"
fi

echo "== cargo clippy -- -D warnings (advisory) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy -- -D warnings \
        || echo "WARN: clippy failed (non-fatal)"
else
    echo "WARN: clippy component unavailable; skipping (non-fatal)"
fi

if [ "${1:-}" = "--bench-smoke" ]; then
    echo "== bench smoke (BENCH_fusion.json) =="
    BENCH_SMOKE=1 cargo bench --bench bench_umf
fi

echo "run_checks: OK"

#!/usr/bin/env bash
# CI gate for the Rust coordinator.
#
#   rust/run_checks.sh                # build + test (+ fmt/clippy soft)
#   rust/run_checks.sh --bench-smoke  # also run the fusion bench smoke
#                                     # mode, emitting BENCH_fusion.json
#
# build + test are hard failures (the tier-1 gate). fmt/clippy are
# advisory: the container image may ship a toolchain without the rustfmt /
# clippy components, and their absence must not mask real build breaks.

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Linalg lane: the parity suite must hold sequentially and at pinned pool
# sizes — the parallel Jacobi guarantees bit-identical factors at every
# worker count, so the same assertions must pass at 1, 2, and 8 workers.
# RUST_TEST_THREADS=1 everywhere: the determinism tests flip the global
# worker override, and serial execution keeps each MOFA_WORKERS lane
# actually running at its advertised pool size.
echo "== linalg parity lane (single-threaded) =="
RUST_TEST_THREADS=1 cargo test -q --test linalg_parity
for w in 2 8; do
    echo "== linalg parity lane (MOFA_WORKERS=$w) =="
    RUST_TEST_THREADS=1 MOFA_WORKERS=$w cargo test -q --test linalg_parity
done

# Fleet lane: fleet-vs-serial must be bit-identical with the ambient
# kernel pool pinned to 1, 2, and 8 workers (the serial baseline's
# kernels run at MOFA_WORKERS; fleet stages always pin themselves to 1
# thread and parallelize across layers instead).
echo "== fleet parity lane (single-threaded) =="
RUST_TEST_THREADS=1 cargo test -q --test fleet_parity
for w in 2 8; do
    echo "== fleet parity lane (MOFA_WORKERS=$w) =="
    RUST_TEST_THREADS=1 MOFA_WORKERS=$w cargo test -q --test fleet_parity
done

# Replica lane (ISSUE 8): the replicated engine shards micro-batches
# across R in-process replicas and folds gradients through the
# fixed-topology lane tree — every (R, workers) combination must be
# bit-identical to the R=1 serial baseline, including the checkpoint
# round-trip mid-run. The suite itself sweeps R ∈ {1,2,4} ×
# workers ∈ {1,2,8}; the MOFA_WORKERS loop additionally moves the
# ambient kernel pool the serial baseline runs at.
echo "== replica parity lane (single-threaded) =="
RUST_TEST_THREADS=1 cargo test -q --test replica_parity
for w in 2 8; do
    echo "== replica parity lane (MOFA_WORKERS=$w) =="
    RUST_TEST_THREADS=1 MOFA_WORKERS=$w cargo test -q --test replica_parity
done

# Serve lane (ISSUE 9): the multi-tenant daemon multiplexes sessions
# through one shared fleet dispatch per tick — every tenant must be
# bit-identical to running alone, the checkpoint wire round trip must be
# bit-exact, and the protocol layer must never panic on hostile bytes.
# The suite itself sweeps sessions ∈ {1,2,4} × workers ∈ {1,2,8}; the
# MOFA_WORKERS loop additionally moves the ambient kernel pool.
echo "== serve lane (single-threaded) =="
RUST_TEST_THREADS=1 cargo test -q --test serve_parity
for w in 2 8; do
    echo "== serve lane (MOFA_WORKERS=$w) =="
    RUST_TEST_THREADS=1 MOFA_WORKERS=$w cargo test -q --test serve_parity
done

# Chaos lane (ISSUE 10): deterministic fault injection. A session
# panicked mid-tick must fail alone — survivors bit-identical to a run
# where it was never admitted — a torn (injected) checkpoint write must
# recover to the last-good snapshot, and injected stage delays must not
# change a bit. The suite itself sweeps workers ∈ {1,2,8}; the
# MOFA_WORKERS loop additionally moves the ambient kernel pool.
# RUST_TEST_THREADS=1 is load-bearing here: fault specs are
# process-global.
echo "== chaos lane (single-threaded) =="
RUST_TEST_THREADS=1 cargo test -q --test chaos
for w in 2 8; do
    echo "== chaos lane (MOFA_WORKERS=$w) =="
    RUST_TEST_THREADS=1 MOFA_WORKERS=$w cargo test -q --test chaos
done

# Obs lane: tracing must be pure observation. Re-run the fleet parity
# suite with MOFA_TRACE set (the recorder auto-enables from the env, so
# every bit-parity assertion now runs with spans recording), then the
# dedicated obs tests: obs_trace emits a trace artifact the lane
# validates, obs_alloc proves recording is allocation-free after warmup.
echo "== obs lane: fleet parity with tracing enabled =="
rm -f obs_lane_trace.json
RUST_TEST_THREADS=1 MOFA_TRACE=obs_lane_trace.json MOFA_WORKERS=4 \
    cargo test -q --test fleet_parity
echo "== obs lane: trace emission + parity (obs_trace) =="
rm -f obs_lane_trace.json
RUST_TEST_THREADS=1 MOFA_TRACE=obs_lane_trace.json \
    cargo test -q --test obs_trace
[ -f obs_lane_trace.json ] \
    || { echo "FAIL: obs lane emitted no trace file"; exit 1; }
grep -q '"traceEvents"' obs_lane_trace.json \
    || { echo "FAIL: obs_lane_trace.json has no traceEvents"; exit 1; }
rm -f obs_lane_trace.json
echo "== obs lane: allocation-free recording (obs_alloc) =="
RUST_TEST_THREADS=1 cargo test -q --test obs_alloc

# Autotune lane: the tuner must be a pure dispatch layer — every
# bit-parity assertion of the fleet suite must hold with tuning on (warm
# and cold cache, and with refresh forcing fresh measurement), and a
# corrupt cache file must degrade to retuning, never to a failure. The
# dedicated autotune suite then covers per-variant parity and the table
# lifecycle. MOFA_AUTOTUNE_CACHE points at a lane-local file so the lane
# neither reads nor pollutes the per-host table.
echo "== autotune lane: fleet parity with tuning on (cold cache) =="
rm -f autotune_lane_cache.json
RUST_TEST_THREADS=1 MOFA_AUTOTUNE=on \
    MOFA_AUTOTUNE_CACHE=autotune_lane_cache.json \
    cargo test -q --test fleet_parity
[ -f autotune_lane_cache.json ] \
    || { echo "FAIL: autotune lane wrote no cache file"; exit 1; }
echo "== autotune lane: fleet parity with tuning on (warm cache) =="
RUST_TEST_THREADS=1 MOFA_AUTOTUNE=on \
    MOFA_AUTOTUNE_CACHE=autotune_lane_cache.json \
    cargo test -q --test fleet_parity
echo "== autotune lane: fleet parity with refresh =="
RUST_TEST_THREADS=1 MOFA_AUTOTUNE=refresh \
    MOFA_AUTOTUNE_CACHE=autotune_lane_cache.json \
    cargo test -q --test fleet_parity
echo "== autotune lane: corrupt-cache recovery =="
echo '{broken json' > autotune_lane_cache.json
RUST_TEST_THREADS=1 MOFA_AUTOTUNE=on \
    MOFA_AUTOTUNE_CACHE=autotune_lane_cache.json \
    cargo test -q --test fleet_parity
rm -f autotune_lane_cache.json
echo "== autotune lane: variant parity + table lifecycle (autotune) =="
RUST_TEST_THREADS=1 cargo test -q --test autotune

echo "== cargo fmt --check (advisory) =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check \
        || echo "WARN: rustfmt check failed (non-fatal)"
else
    echo "WARN: rustfmt component unavailable; skipping (non-fatal)"
fi

echo "== cargo clippy -- -D warnings (advisory) =="
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy -- -D warnings \
        || echo "WARN: clippy failed (non-fatal)"
else
    echo "WARN: clippy component unavailable; skipping (non-fatal)"
fi

if [ "${1:-}" = "--bench-smoke" ]; then
    echo "== bench smoke (BENCH_fusion.json / BENCH_svd.json) =="
    BENCH_SMOKE=1 cargo bench --bench bench_umf
    echo "== BENCH_svd.json completeness =="
    [ -f BENCH_svd.json ] \
        || { echo "FAIL: BENCH_svd.json was not written"; exit 1; }
    for key in bench workers cases seq_svd_ms par_svd_ms svd_speedup \
               qr_old_ms qr_blocked_ms qr_speedup; do
        grep -q "\"$key\"" BENCH_svd.json \
            || { echo "FAIL: BENCH_svd.json missing key \"$key\""; exit 1; }
    done
    echo "== bench smoke (BENCH_fleet.json) =="
    BENCH_SMOKE=1 cargo bench --bench bench_e2e
    echo "== BENCH_fleet.json completeness =="
    [ -f BENCH_fleet.json ] \
        || { echo "FAIL: BENCH_fleet.json was not written"; exit 1; }
    for key in bench cases layers rank workers serial_ms fleet_ms \
               speedup bit_identical; do
        grep -q "\"$key\"" BENCH_fleet.json \
            || { echo "FAIL: BENCH_fleet.json missing key \"$key\""; exit 1; }
    done
    echo "== BENCH_replica.json completeness =="
    [ -f BENCH_replica.json ] \
        || { echo "FAIL: BENCH_replica.json was not written"; exit 1; }
    for key in bench cases layers mn rank micro replicas workers \
               serial_ms replica_ms speedup bit_identical; do
        grep -q "\"$key\"" BENCH_replica.json \
            || { echo "FAIL: BENCH_replica.json missing key \"$key\""; \
                 exit 1; }
    done
    echo "== bench smoke (BENCH_obs.json) =="
    BENCH_SMOKE=1 cargo bench --bench bench_obs
    echo "== BENCH_obs.json completeness =="
    [ -f BENCH_obs.json ] \
        || { echo "FAIL: BENCH_obs.json was not written"; exit 1; }
    for key in bench cases workers gate_pct pass disabled_ms enabled_ms \
               overhead_pct spans; do
        grep -q "\"$key\"" BENCH_obs.json \
            || { echo "FAIL: BENCH_obs.json missing key \"$key\""; exit 1; }
    done
    grep -q '"pass": true' BENCH_obs.json \
        || { echo "FAIL: tracing overhead gate failed"; exit 1; }
    echo "== bench smoke (BENCH_autotune.json) =="
    BENCH_SMOKE=1 cargo bench --bench bench_autotune
    echo "== BENCH_autotune.json completeness =="
    [ -f BENCH_autotune.json ] \
        || { echo "FAIL: BENCH_autotune.json was not written"; exit 1; }
    for key in bench cases family class static_variant tuned_variant \
               static_ms tuned_ms speedup tuned_classes pass; do
        grep -q "\"$key\"" BENCH_autotune.json \
            || { echo "FAIL: BENCH_autotune.json missing key \"$key\""; \
                 exit 1; }
    done
    grep -q '"pass": true' BENCH_autotune.json \
        || { echo "FAIL: autotuned path slower than static"; exit 1; }
    echo "== bench smoke (BENCH_serve.json) =="
    BENCH_SMOKE=1 cargo bench --bench bench_serve
    echo "== BENCH_serve.json completeness =="
    [ -f BENCH_serve.json ] \
        || { echo "FAIL: BENCH_serve.json was not written"; exit 1; }
    for key in bench cases sessions layers workers tick_ms ticks_per_s \
               pass; do
        grep -q "\"$key\"" BENCH_serve.json \
            || { echo "FAIL: BENCH_serve.json missing key \"$key\""; \
                 exit 1; }
    done
    grep -q '"pass": true' BENCH_serve.json \
        || { echo "FAIL: serve tick produced non-finite loss"; exit 1; }
fi

echo "run_checks: OK"

//! Stub of the `xla` (xla_extension / PJRT C API) bindings.
//!
//! The real bindings need the ~1 GB xla_extension shared library, which is
//! not vendored in this container. This stub keeps the whole coordinator
//! compiling and partially functional:
//!
//! * [`Literal`] is a **real** host-side tensor container — `scalar`,
//!   `vec1`, `reshape`, `to_vec`, `decompose_tuple` and `array_shape` all
//!   work, so literal marshaling code and its tests run unchanged.
//! * [`PjRtClient::cpu`] returns a clean error. Everything downstream
//!   (`Registry::open`, artifact execution) therefore degrades exactly the
//!   way a checkout without `make artifacts` does: integration tests skip,
//!   benches report "native-only run", the CLI prints the error.
//!
//! Swapping the real bindings back in is a Cargo.toml-only change; no
//! source edits are required as long as this API surface is kept in sync.

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring `xla::Error`'s role (Display + std::error::Error).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (built against the stub `xla` \
         crate; xla_extension is not vendored in this container)"
    ))
}

type Result<T> = std::result::Result<T, Error>;

// ---------------------------------------------------------------------------
// Literal — functional host-side tensor container
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host tensor: element data plus dims (empty dims ⇒ scalar).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

/// Element types storable in a [`Literal`].
pub trait Element: Copy {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<&[Self]>;
}

impl Element for f32 {
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<&[f32]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl Element for i32 {
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<&[i32]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl Literal {
    pub fn scalar<T: Element>(v: T) -> Literal {
        Literal { dims: Vec::new(), data: T::wrap(vec![v]) }
    }

    pub fn vec1<T: Element>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { dims: Vec::new(), data: Data::Tuple(elems) }
    }

    fn numel(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(_) => 0,
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.numel().max(1) && !dims.is_empty() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.numel()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("to_vec: element type mismatch".into()))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match std::mem::replace(&mut self.data, Data::Tuple(Vec::new())) {
            Data::Tuple(elems) => Ok(elems),
            other => {
                self.data = other;
                Err(Error("decompose_tuple: literal is not a tuple".into()))
            }
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.data {
            Data::Tuple(_) => {
                Err(Error("array_shape: literal is a tuple".into()))
            }
            _ => Ok(ArrayShape { dims: self.dims.clone() }),
        }
    }
}

/// Shape of a non-tuple literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

// ---------------------------------------------------------------------------
// PJRT client / executable / buffer — inert stubs
// ---------------------------------------------------------------------------

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn literal_scalar_i32() {
        let l = Literal::scalar(7i32);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7]);
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn tuple_decompose() {
        let mut t = Literal::tuple(vec![Literal::scalar(1.0f32)]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 1);
        let mut nt = Literal::scalar(1.0f32);
        assert!(nt.decompose_tuple().is_err());
        // non-tuple literal survives a failed decompose
        assert_eq!(nt.to_vec::<f32>().unwrap(), vec![1.0]);
    }

    #[test]
    fn client_is_inert() {
        assert!(PjRtClient::cpu().is_err());
    }
}

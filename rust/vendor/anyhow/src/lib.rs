//! Vendored, dependency-free subset of the `anyhow` 1.x API.
//!
//! The container this repo builds in has no crates.io access, so the small
//! slice of anyhow the codebase uses is vendored here: `Error`, `Result`,
//! the `anyhow!` / `bail!` macros, and the `Context` extension trait.
//! Error values keep a flat context chain (outermost first) and render the
//! same "Caused by:" Debug output the real crate produces.

use std::error::Error as StdError;
use std::fmt;

/// Error type: a chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    fn wrap(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like the real anyhow, `Error` deliberately does NOT implement
// std::error::Error — that keeps the blanket `From` below coherent.
impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Create an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad {}", 42);
        assert_eq!(format!("{e}"), "bad 42");
    }

    #[test]
    fn bail_returns() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative {x}");
            }
            Ok(x)
        }
        assert!(f(1).is_ok());
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative -1");
    }

    #[test]
    fn context_chains_and_debug() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "opening file").unwrap_err();
        assert_eq!(format!("{e}"), "opening file");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("gone"), "{dbg}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<u32> {
            let n: u32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(f().unwrap(), 12);
    }

    #[test]
    fn option_context() {
        let o: Option<u8> = None;
        let e = o.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
    }
}

//! End-to-end step throughput: the native fleet-vs-serial section
//! (ISSUE 5 acceptance numbers, emitted to `BENCH_fleet.json` in smoke
//! mode), the replicated-engine R×workers sweep (ISSUE 8, emitted to
//! `BENCH_replica.json` in smoke mode) plus, when artifacts are built,
//! the per-optimizer gpt_tiny throughput table (Table 1) and the §5.5
//! fused-vs-dense ablation.

mod common;

use common::{report, time_it};
use mofasgd::coordinator::{Hyper, OptimizerChoice, Schedule, Trainer,
                           TrainerOptions};
use mofasgd::data::corpus::LmDataset;
use mofasgd::fusion::reduce::{self, LanePtr, TreeSchedule, TREE_WIDTH};
use mofasgd::fusion::{self, Fleet, FleetUnit, ReplicaSet};
use mofasgd::linalg::Mat;
use mofasgd::optim::{AdamW, GaLore, GradAccumUnit, MatOpt, MatUnit,
                     MatrixOptimizer, MoFaSgd, TreeReduceUnit};
use mofasgd::runtime::Registry;
use mofasgd::util::json::Json;
use mofasgd::util::rng::Rng;

// ---------------------------------------------------------------------------
// Native fleet-vs-serial section (no artifacts required)
// ---------------------------------------------------------------------------

/// Bench mix: layer i cycles MoFaSGD, MoFaSGD, GaLore, dense AdamW —
/// the ISSUE 5 "mixed fleet" shape with the MoFaSGD/GaLore rank swept.
enum BenchOpt {
    Mofa(MoFaSgd),
    Gal(GaLore),
    Adam(AdamW),
}

impl BenchOpt {
    fn build(i: usize, mn: usize, r: usize) -> BenchOpt {
        match i % 4 {
            0 | 1 => BenchOpt::Mofa(MoFaSgd::new(mn, mn, r, 0.9)),
            // resample_every beyond the bench horizon keeps per-step
            // work uniform across timed iterations.
            2 => BenchOpt::Gal(GaLore::new(mn, mn, r, 1_000_000, 0.9,
                                           0.999, 17 + i as u64)),
            _ => BenchOpt::Adam(AdamW::new(mn, mn, 0.9, 0.999, 0.0)),
        }
    }

    fn step(&mut self, w: &mut Mat, g: &Mat, eta: f32) {
        match self {
            BenchOpt::Mofa(o) => o.step(w, g, eta),
            BenchOpt::Gal(o) => o.step(w, g, eta),
            BenchOpt::Adam(o) => o.step(w, g, eta),
        }
    }

    fn unit<'a>(&'a mut self, w: &'a mut Mat, g: &'a Mat, eta: f32)
                -> MatUnit<'a> {
        let opt = match self {
            BenchOpt::Mofa(o) => MatOpt::MoFaSgd(o),
            BenchOpt::Gal(o) => MatOpt::GaLore(o),
            BenchOpt::Adam(o) => MatOpt::AdamW(o),
        };
        MatUnit::new(opt, w, g, eta)
    }

    fn unit_reduced<'a>(&'a mut self, w: &'a mut Mat, lanes: LanePtr,
                        eta: f32) -> MatUnit<'a> {
        let opt = match self {
            BenchOpt::Mofa(o) => MatOpt::MoFaSgd(o),
            BenchOpt::Gal(o) => MatOpt::GaLore(o),
            BenchOpt::Adam(o) => MatOpt::AdamW(o),
        };
        MatUnit::reduced(opt, w, lanes, eta)
    }
}

struct BenchStack {
    opts: Vec<BenchOpt>,
    ws: Vec<Mat>,
    gs: Vec<Mat>,
}

fn build_stack(layers: usize, mn: usize, r: usize, seed: u64) -> BenchStack {
    let mut rng = Rng::new(seed);
    let mut opts = Vec::new();
    let mut ws = Vec::new();
    let mut gs = Vec::new();
    for i in 0..layers {
        opts.push(BenchOpt::build(i, mn, r));
        ws.push(Mat::randn(&mut rng, mn, mn, 1.0));
        gs.push(Mat::randn(&mut rng, mn, mn, 1.0));
    }
    BenchStack { opts, ws, gs }
}

fn step_serial(stack: &mut BenchStack, eta: f32) {
    for (li, opt) in stack.opts.iter_mut().enumerate() {
        opt.step(&mut stack.ws[li], &stack.gs[li], eta);
    }
}

fn step_fleet(fleet: &mut Fleet, stack: &mut BenchStack, eta: f32,
              workers: usize) {
    let mut units: Vec<MatUnit> = stack
        .opts
        .iter_mut()
        .zip(&mut stack.ws)
        .zip(&stack.gs)
        .map(|((opt, w), g)| opt.unit(w, g, eta))
        .collect();
    let mut refs: Vec<&mut dyn FleetUnit> = units
        .iter_mut()
        .map(|u| u as &mut dyn FleetUnit)
        .collect();
    fleet.run(&mut refs, workers);
}

/// Fleet-vs-serial must also be *bit-identical*, at the specific worker
/// count being measured — verified per (case, workers) row before that
/// row is timed, so the `bit_identical` field in `BENCH_fleet.json`
/// reports evidence that was actually gathered.
fn verify_case(layers: usize, mn: usize, r: usize, workers: usize) -> bool {
    let mut serial = build_stack(layers, mn, r, 5);
    let mut fleet_s = build_stack(layers, mn, r, 5);
    let mut fleet = Fleet::new();
    for _ in 0..2 {
        step_serial(&mut serial, 1e-3);
        step_fleet(&mut fleet, &mut fleet_s, 1e-3, workers);
    }
    serial
        .ws
        .iter()
        .zip(&fleet_s.ws)
        .all(|(a, b)| a.data == b.data)
}

fn fleet_section(smoke: bool) {
    println!("== fleet executor vs serial per-layer loop ==\n");
    let (mn, sweep): (usize, &[(usize, usize)]) = if smoke {
        (256, &[(8, 4), (8, 32), (12, 8)])
    } else {
        (512, &[(8, 4), (8, 32), (12, 8), (16, 32)])
    };
    let worker_counts = [2usize, 4, 8];
    let (wu, iu) = if smoke { (1, 2) } else { (1, 4) };
    let mut cases = Vec::new();
    for &(layers, r) in sweep {
        for &w in &worker_counts {
            fusion::set_workers(w);
            let bit_identical = verify_case(layers, mn, r, w);
            assert!(
                bit_identical,
                "fleet-vs-serial outputs diverged at {layers}x{mn} r={r} w={w}"
            );
            let mut s_stack = build_stack(layers, mn, r, 9);
            step_serial(&mut s_stack, 1e-3); // init paths outside timing
            let serial_ms = time_it(wu, iu, || {
                step_serial(&mut s_stack, 1e-3);
            }) * 1e3;
            let mut f_stack = build_stack(layers, mn, r, 9);
            let mut fleet = Fleet::new();
            step_fleet(&mut fleet, &mut f_stack, 1e-3, w);
            let fleet_ms = time_it(wu, iu, || {
                step_fleet(&mut fleet, &mut f_stack, 1e-3, w);
            }) * 1e3;
            fusion::set_workers(0);
            let speedup = serial_ms / fleet_ms.max(1e-9);
            println!(
                "fleet {layers} layers {mn}x{mn} r={r:<3} w={w}   serial \
                 {serial_ms:9.2} ms   fleet {fleet_ms:9.2} ms   speedup \
                 {speedup:5.2}x"
            );
            cases.push(Json::obj(vec![
                ("layers", Json::Num(layers as f64)),
                ("rank", Json::Num(r as f64)),
                ("mn", Json::Num(mn as f64)),
                ("workers", Json::Num(w as f64)),
                ("serial_ms", Json::Num(serial_ms)),
                ("fleet_ms", Json::Num(fleet_ms)),
                ("speedup", Json::Num(speedup)),
                ("bit_identical",
                 Json::Num(if bit_identical { 1.0 } else { 0.0 })),
            ]));
        }
    }
    println!();
    if smoke {
        let doc = Json::obj(vec![
            ("bench", Json::Str("fleet".into())),
            ("cases", Json::Arr(cases)),
        ]);
        match std::fs::write("BENCH_fleet.json", doc.emit(2)) {
            Ok(()) => println!("wrote BENCH_fleet.json"),
            Err(e) => println!("BENCH_fleet.json not written: {e}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Replicated engine: R × workers sweep (ISSUE 8, no artifacts required)
// ---------------------------------------------------------------------------

/// Per-step micro-batch gradients live with the stack; lane buffers are
/// preallocated once so timed steps stay heap-silent on the fleet side.
struct ReplicaStack {
    opts: Vec<BenchOpt>,
    ws: Vec<Mat>,
    micros: Vec<Vec<Mat>>,
    lanes: Vec<Vec<Mat>>,
}

const REPLICA_MICRO: usize = 8;

fn build_replica_stack(layers: usize, mn: usize, r: usize,
                       seed: u64) -> ReplicaStack {
    let mut rng = Rng::new(seed);
    let mut opts = Vec::new();
    let mut ws = Vec::new();
    let mut micros = Vec::new();
    let mut lanes = Vec::new();
    for i in 0..layers {
        opts.push(BenchOpt::build(i, mn, r));
        ws.push(Mat::randn(&mut rng, mn, mn, 1.0));
        micros.push((0..REPLICA_MICRO)
            .map(|_| Mat::randn(&mut rng, mn, mn, 0.5))
            .collect());
        lanes.push((0..TREE_WIDTH).map(|_| Mat::zeros(mn, mn)).collect());
    }
    ReplicaStack { opts, ws, micros, lanes }
}

/// Frozen R = 1 baseline: sequential lane-tree fold (`reduce_ref`),
/// mean scale, serial per-layer step.
fn step_serial_replica(stack: &mut ReplicaStack, sched: &TreeSchedule,
                       eta: f32) {
    let inv = 1.0 / sched.n_items() as f32;
    for li in 0..stack.opts.len() {
        let refs: Vec<&[f32]> =
            stack.micros[li].iter().map(|g| &g.data[..]).collect();
        let mut mean = reduce::reduce_ref(sched, &refs);
        for x in &mut mean {
            *x *= inv;
        }
        let (m, n) = (stack.micros[li][0].rows, stack.micros[li][0].cols);
        let g = Mat::from_vec(m, n, mean);
        stack.opts[li].step(&mut stack.ws[li], &g, eta);
    }
}

/// The replicated path: R accumulation chains per layer + tree reduce +
/// step, all layers in ONE `run_replicated` dispatch.
fn step_replicated(fleet: &mut Fleet, stack: &mut ReplicaStack,
                   sched: &TreeSchedule, eta: f32, reps: usize,
                   workers: usize) {
    let lps: Vec<LanePtr> =
        stack.lanes.iter_mut().map(|l| LanePtr::new(l)).collect();
    let mut accs: Vec<Vec<GradAccumUnit>> = lps
        .iter()
        .zip(&stack.micros)
        .map(|(lp, items)| {
            (0..reps)
                .map(|k| GradAccumUnit::new(*lp, sched, items, k, reps))
                .collect()
        })
        .collect();
    let mut reds: Vec<TreeReduceUnit> =
        lps.iter().map(|lp| TreeReduceUnit::new(*lp, sched)).collect();
    let mut steps: Vec<MatUnit> = stack
        .opts
        .iter_mut()
        .zip(&mut stack.ws)
        .zip(&lps)
        .map(|((o, w), lp)| o.unit_reduced(w, *lp, eta))
        .collect();
    let mut acc_refs: Vec<Vec<&mut dyn FleetUnit>> = accs
        .iter_mut()
        .map(|v| v.iter_mut().map(|u| u as &mut dyn FleetUnit).collect())
        .collect();
    let step_refs = steps.iter_mut().map(|u| u as &mut dyn FleetUnit);
    let mut sets: Vec<ReplicaSet> = acc_refs
        .iter_mut()
        .zip(reds.iter_mut())
        .zip(step_refs)
        .map(|((ar, red), st)| ReplicaSet {
            accum: ar.as_mut_slice(),
            reduce: red,
            step: st,
        })
        .collect();
    fleet.run_replicated(&mut sets, workers);
}

/// Bit parity is verified per (R, workers) row before that row is
/// timed — `bit_identical` in `BENCH_replica.json` is gathered evidence,
/// never an assumption.
fn verify_replica_case(layers: usize, mn: usize, r: usize,
                       sched: &TreeSchedule, reps: usize,
                       workers: usize) -> bool {
    let mut serial = build_replica_stack(layers, mn, r, 5);
    let mut repl = build_replica_stack(layers, mn, r, 5);
    let mut fleet = Fleet::new();
    for _ in 0..2 {
        step_serial_replica(&mut serial, sched, 1e-3);
        step_replicated(&mut fleet, &mut repl, sched, 1e-3, reps, workers);
    }
    serial.ws.iter().zip(&repl.ws).all(|(a, b)| a.data == b.data)
}

fn replica_section(smoke: bool) {
    println!("== replicated engine: R x workers sweep ==\n");
    let (layers, mn, r) = if smoke { (8, 192, 8) } else { (12, 384, 8) };
    let sched = TreeSchedule::new(REPLICA_MICRO, TREE_WIDTH);
    let (wu, iu) = if smoke { (1, 2) } else { (1, 4) };
    let mut cases = Vec::new();
    for reps in [1usize, 2, 4] {
        for w in [1usize, 2, 8] {
            fusion::set_workers(w);
            let bit_identical =
                verify_replica_case(layers, mn, r, &sched, reps, w);
            assert!(
                bit_identical,
                "replica-vs-serial diverged at R={reps} w={w}"
            );
            let mut s_stack = build_replica_stack(layers, mn, r, 9);
            step_serial_replica(&mut s_stack, &sched, 1e-3);
            let serial_ms = time_it(wu, iu, || {
                step_serial_replica(&mut s_stack, &sched, 1e-3);
            }) * 1e3;
            let mut r_stack = build_replica_stack(layers, mn, r, 9);
            let mut fleet = Fleet::new();
            step_replicated(&mut fleet, &mut r_stack, &sched, 1e-3, reps, w);
            let replica_ms = time_it(wu, iu, || {
                step_replicated(&mut fleet, &mut r_stack, &sched, 1e-3,
                                reps, w);
            }) * 1e3;
            fusion::set_workers(0);
            let speedup = serial_ms / replica_ms.max(1e-9);
            println!(
                "replica {layers} layers {mn}x{mn} micro={REPLICA_MICRO} \
                 R={reps} w={w}   serial {serial_ms:9.2} ms   replicated \
                 {replica_ms:9.2} ms   speedup {speedup:5.2}x"
            );
            cases.push(Json::obj(vec![
                ("layers", Json::Num(layers as f64)),
                ("mn", Json::Num(mn as f64)),
                ("rank", Json::Num(r as f64)),
                ("micro", Json::Num(REPLICA_MICRO as f64)),
                ("replicas", Json::Num(reps as f64)),
                ("workers", Json::Num(w as f64)),
                ("serial_ms", Json::Num(serial_ms)),
                ("replica_ms", Json::Num(replica_ms)),
                ("speedup", Json::Num(speedup)),
                ("bit_identical",
                 Json::Num(if bit_identical { 1.0 } else { 0.0 })),
            ]));
        }
    }
    println!();
    if smoke {
        let doc = Json::obj(vec![
            ("bench", Json::Str("replica".into())),
            ("cases", Json::Arr(cases)),
        ]);
        match std::fs::write("BENCH_replica.json", doc.emit(2)) {
            Ok(()) => println!("wrote BENCH_replica.json"),
            Err(e) => println!("BENCH_replica.json not written: {e}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Artifact-path sections (skipped when `make artifacts` has not run)
// ---------------------------------------------------------------------------

fn bench_opt(reg: &Registry, opt: &str, fused: bool, accum: usize) {
    let choice = OptimizerChoice::parse(opt).unwrap();
    let mut trainer = Trainer::new(reg, TrainerOptions {
        config: "gpt_tiny".into(),
        choice,
        hyper: Hyper {
            lr: 1e-3,
            emb_lr: 1e-3,
            accum,
            fused,
            schedule: Schedule::Constant,
            ..Hyper::default()
        },
        seed: 1,
        run_name: format!("bench-{opt}"),
    })
    .unwrap();
    let cfg = trainer.cfg.clone();
    let mut data = LmDataset::new(cfg.vocab, cfg.batch, cfg.seq, 1);
    let micro: Vec<_> = (0..accum).map(|_| data.next_train()).collect();
    // warmup compiles artifacts
    trainer.step_lm(&micro).unwrap();
    let secs = time_it(1, 3, || {
        trainer.step_lm(&micro).unwrap();
    });
    let tokens = (accum * cfg.batch * cfg.seq) as f64;
    let label = format!(
        "step {opt} accum={accum} fused={fused}"
    );
    report(&label, secs, Some((tokens, "tok/s")));
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    println!("\n== bench_e2e: optimizer step throughput ==\n");
    fleet_section(smoke);
    replica_section(smoke);
    if smoke {
        // Smoke mode exists to seed BENCH_fleet.json and
        // BENCH_replica.json quickly; skip the artifact-path sweeps.
        return;
    }
    let Ok(reg) = Registry::open(Registry::default_dir()) else {
        println!("artifacts not built; run `make artifacts` for the \
                  gpt_tiny table");
        return;
    };
    println!("\n-- gpt_tiny step throughput (Table 1 shape) --\n");
    for opt in [
        "mofasgd:r=8,beta=0.9",
        "mofasgd:r=4,beta=0.9",
        "galore:r=8,tau=150",
        "adamw",
        "muon:beta=0.9",
        "lora:r=8",
        "signsgd",
    ] {
        bench_opt(&reg, opt, true, 1);
    }
    println!("\n-- §5.5 ablation: fused vs dense accumulation (accum=4) --\n");
    for (opt, fused) in [
        ("mofasgd:r=8,beta=0.9", true),
        ("mofasgd:r=8,beta=0.9", false),
        ("galore:r=8,tau=150", true),
        ("galore:r=8,tau=150", false),
    ] {
        bench_opt(&reg, opt, fused, 4);
    }
}

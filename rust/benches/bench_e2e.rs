//! End-to-end step throughput per optimizer (the Table 1 throughput
//! column) + the fused-vs-dense accumulation ablation (§5.5) on gpt_tiny.

mod common;

use common::{report, time_it};
use mofasgd::coordinator::{Hyper, OptimizerChoice, Schedule, Trainer,
                           TrainerOptions};
use mofasgd::data::corpus::LmDataset;
use mofasgd::runtime::Registry;

fn bench_opt(reg: &Registry, opt: &str, fused: bool, accum: usize) {
    let choice = OptimizerChoice::parse(opt).unwrap();
    let mut trainer = Trainer::new(reg, TrainerOptions {
        config: "gpt_tiny".into(),
        choice,
        hyper: Hyper {
            lr: 1e-3,
            emb_lr: 1e-3,
            accum,
            fused,
            schedule: Schedule::Constant,
            ..Hyper::default()
        },
        seed: 1,
        run_name: format!("bench-{opt}"),
    })
    .unwrap();
    let cfg = trainer.cfg.clone();
    let mut data = LmDataset::new(cfg.vocab, cfg.batch, cfg.seq, 1);
    let micro: Vec<_> = (0..accum).map(|_| data.next_train()).collect();
    // warmup compiles artifacts
    trainer.step_lm(&micro).unwrap();
    let secs = time_it(1, 3, || {
        trainer.step_lm(&micro).unwrap();
    });
    let tokens = (accum * cfg.batch * cfg.seq) as f64;
    let label = format!(
        "step {opt} accum={accum} fused={fused}"
    );
    report(&label, secs, Some((tokens, "tok/s")));
}

fn main() {
    println!("\n== bench_e2e: gpt_tiny step throughput (Table 1 shape) ==\n");
    let Ok(reg) = Registry::open(Registry::default_dir()) else {
        println!("artifacts not built; run `make artifacts`");
        return;
    };
    for opt in [
        "mofasgd:r=8,beta=0.9",
        "mofasgd:r=4,beta=0.9",
        "galore:r=8,tau=150",
        "adamw",
        "muon:beta=0.9",
        "lora:r=8",
        "signsgd",
    ] {
        bench_opt(&reg, opt, true, 1);
    }
    println!("\n-- §5.5 ablation: fused vs dense accumulation (accum=4) --\n");
    for (opt, fused) in [
        ("mofasgd:r=8,beta=0.9", true),
        ("mofasgd:r=8,beta=0.9", false),
        ("galore:r=8,tau=150", true),
        ("galore:r=8,tau=150", false),
    ] {
        bench_opt(&reg, opt, fused, 4);
    }
}

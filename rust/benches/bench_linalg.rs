//! Substrate roofline: matmul / QR / Jacobi-SVD throughput.
//!
//! Establishes the native-linalg baseline the §Perf analysis quotes: the
//! UMF step cost should be dominated by its O(mnr) projections, i.e. sit
//! within a small factor of three matmul passes at the same shapes.

mod common;

use common::{report, time_it};
use mofasgd::fusion::{self, MatKind};
use mofasgd::linalg::{
    householder_qr, householder_qr_unblocked, jacobi_svd, jacobi_svd_seq,
    Mat,
};
use mofasgd::util::rng::Rng;

fn main() {
    println!("\n== bench_linalg: native substrate roofline ==\n");
    let workers = fusion::workers();
    let mut rng = Rng::new(1);
    for (m, k, n) in [(256, 256, 256), (256, 1024, 256), (512, 512, 512)] {
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let b = Mat::randn(&mut rng, k, n, 1.0);
        let flops = 2.0 * (m * k * n) as f64 / 1e9;
        let secs = time_it(2, 5, || {
            let _ = a.matmul(&b);
        });
        report(&format!("matmul {m}x{k}x{n}"), secs, Some((flops, "GFLOP/s")));
        let mut out = Mat::zeros(m, n);
        let secs = time_it(2, 5, || {
            fusion::gemm_into(MatKind::NN, &a, &b, &mut out, 1.0, 0.0);
        });
        report(&format!("fused gemm NN {m}x{k}x{n} w={workers}"), secs,
               Some((flops, "GFLOP/s")));
        let secs = time_it(2, 5, || {
            let _ = a.t_matmul(&b.t());
        });
        report(&format!("t_matmul {m}x{k}x{n}"), secs,
               Some((flops, "GFLOP/s")));
        let at = a.t();
        let secs = time_it(2, 5, || {
            fusion::gemm_into(MatKind::TN, &at, &b, &mut out, 1.0, 0.0);
        });
        report(&format!("fused gemm TN {m}x{k}x{n} w={workers}"), secs,
               Some((flops, "GFLOP/s")));
        let bt = b.t();
        let secs = time_it(2, 5, || {
            fusion::gemm_into(MatKind::NT, &a, &bt, &mut out, 1.0, 0.0);
        });
        report(&format!("fused gemm NT {m}x{k}x{n} w={workers}"), secs,
               Some((flops, "GFLOP/s")));
    }
    println!();
    for (m, k) in [(256, 16), (256, 64), (1024, 64), (256, 256)] {
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let secs = time_it(2, 5, || {
            let _ = householder_qr(&a);
        });
        report(&format!("householder_qr {m}x{k}"), secs,
               Some((2.0 * (m * k * k) as f64 / 1e9, "GFLOP/s")));
    }
    println!();
    for (m, k) in [(16, 16), (64, 64), (256, 64), (256, 256)] {
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let secs = time_it(1, 3, || {
            let _ = jacobi_svd(&a);
        });
        report(&format!("jacobi_svd {m}x{k}"), secs, None);
    }
    // Blocked/parallel paths vs their frozen sequential baselines (the
    // full sweep with JSON output lives in bench_umf's svd_qr_section).
    println!();
    for (m, k) in [(256, 64), (256, 128)] {
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let secs = time_it(1, 2, || {
            let _ = jacobi_svd_seq(&a);
        });
        report(&format!("jacobi_svd_seq {m}x{k}"), secs, None);
        let secs = time_it(1, 2, || {
            let _ = householder_qr_unblocked(&a);
        });
        report(&format!("householder_qr_unblocked {m}x{k}"), secs,
               Some((2.0 * (m * k * k) as f64 / 1e9, "GFLOP/s")));
        let secs = time_it(1, 2, || {
            let _ = householder_qr(&a);
        });
        report(&format!("householder_qr_blocked {m}x{k}"), secs,
               Some((2.0 * (m * k * k) as f64 / 1e9, "GFLOP/s")));
    }
}

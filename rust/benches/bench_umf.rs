//! UMF-vs-alternatives kernel bench (the Table 1 runtime story).
//!
//! Compares, per (m, n, r):
//!   * MoFaSGD UMF step (Alg. 1: O(mnr + (m+n)r²))
//!   * the naive update SVD_r(β·M̂ + Ĝ) it replaces (randomized SVD of the
//!     densified momentum)
//!   * GaLore's offline subspace resample (randomized; the paper's exact
//!     variant is a full O(m²n) SVD)
//!   * Muon's full-rank Newton-Schulz step
//! on both the native Rust path and the PJRT artifact path when available.

mod common;

use common::{report, time_it};
use mofasgd::fusion;
use mofasgd::linalg::{
    householder_qr, householder_qr_unblocked, jacobi_svd, jacobi_svd_seq,
    Mat,
};
use mofasgd::optim::{muon::newton_schulz, MatrixOptimizer, MoFaSgd};
use mofasgd::runtime::{lit_f32, lit_scalar, Registry};
use mofasgd::util::json::Json;
use mofasgd::util::rng::Rng;

fn native(m: usize, n: usize, r: usize) {
    let mut rng = Rng::new(1);
    let g = Mat::randn(&mut rng, m, n, 1.0);
    let mut w = Mat::randn(&mut rng, m, n, 1.0);

    let mut umf = MoFaSgd::new(m, n, r, 0.9);
    umf.step(&mut w, &g, 0.0); // init outside the timed region
    let (wu, iu) = if r >= 128 { (0, 1) } else { (2, 5) };
    let secs = time_it(wu, iu, || {
        umf.step(&mut w, &g, 1e-4);
    });
    report(&format!("native umf_step {m}x{n} r={r}"), secs,
           Some((2.0 * (m * n * r) as f64 * 3.0 / 1e9, "GFLOP/s")));

    // Naive: densify momentum, randomized SVD_r. No longer skipped above
    // r = 32: svd_lowrank's inner Jacobi now runs the parallel
    // round-robin ordering (a sweep is k−1 parallel rounds instead of
    // k(k−1)/2 sequential rotations), so the r = 64 / 128 configs that
    // used to take minutes per call are bench-able.
    {
        let mut rng2 = Rng::new(2);
        let (wu, iu) = if r >= 64 { (0, 1) } else { (1, 3) };
        let secs = time_it(wu, iu, || {
            let dense = umf.momentum_dense().scale(0.9).add(&g);
            let _ = mofasgd::linalg::svd_lowrank(&dense, r, 2, &mut rng2);
        });
        report(&format!("native naive_densify_svd {m}x{n} r={r}"), secs,
               None);
    }

    // GaLore resample (randomized range finder).
    let mut rng3 = Rng::new(3);
    let secs = time_it(1, 3, || {
        let _ = mofasgd::linalg::rand_range(&g, r, 2, &mut rng3);
    });
    report(&format!("native galore_resample {m}x{n} r={r}"), secs, None);

    // Muon full-rank Newton-Schulz (rank-independent cost).
    let secs = time_it(1, 3, || {
        let _ = newton_schulz(&g, 5);
    });
    report(&format!("native muon_ns5 {m}x{n}"), secs, None);
}

fn artifact(reg: &Registry, m: usize, n: usize, r: usize) {
    let mut rng = Rng::new(4);
    let name = Registry::opt_name("mofasgd_step", m, n, Some(r));
    let Ok(exec) = reg.load(&name) else {
        println!("(skip {name}: not built)");
        return;
    };
    let w = lit_f32(&[m, n], &rng.normal_vec(m * n, 1.0)).unwrap();
    let u = lit_f32(&[m, r], &rng.normal_vec(m * r, 1.0)).unwrap();
    let s = lit_f32(&[r], &rng.normal_vec(r, 1.0)).unwrap();
    let v = lit_f32(&[n, r], &rng.normal_vec(n * r, 1.0)).unwrap();
    let g = lit_f32(&[m, n], &rng.normal_vec(m * n, 1.0)).unwrap();
    let secs = time_it(3, 10, || {
        let _ = exec
            .run(&[&w, &u, &s, &v, &g, &lit_scalar(1e-4), &lit_scalar(0.9)])
            .unwrap();
    });
    report(&format!("artifact mofasgd_step {m}x{n} r={r}"), secs, None);

    if let Ok(naive) = reg.load(&Registry::opt_name(
        "mofasgd_step_naive", m, n, Some(r))) {
        let omega = lit_f32(&[n, r], &rng.normal_vec(n * r, 1.0)).unwrap();
        let secs = time_it(2, 5, || {
            let _ = naive
                .run(&[&w, &u, &s, &v, &g, &lit_scalar(1e-4),
                       &lit_scalar(0.9), &omega])
                .unwrap();
        });
        report(&format!("artifact mofasgd_step_naive {m}x{n} r={r}"), secs,
               None);
    }
    if let Ok(rs) = reg.load(&Registry::opt_name(
        "galore_resample", m, n, Some(r))) {
        let omega = lit_f32(&[n, r], &rng.normal_vec(n * r, 1.0)).unwrap();
        let secs = time_it(2, 5, || {
            let _ = rs.run(&[&g, &omega]).unwrap();
        });
        report(&format!("artifact galore_resample {m}x{n} r={r}"), secs,
               None);
    }
    if let Ok(mu) = reg.load(&Registry::opt_name("muon_step", m, n, None)) {
        let mom = lit_f32(&[m, n], &vec![0.0; m * n]).unwrap();
        let secs = time_it(2, 5, || {
            let _ = mu
                .run(&[&w, &mom, &g, &lit_scalar(1e-4), &lit_scalar(0.9)])
                .unwrap();
        });
        report(&format!("artifact muon_step {m}x{n}"), secs, None);
    }
}

/// Fused executor vs the frozen pre-refactor sequential reference, same
/// UMF step at the same state. Returns (reference_ms, fused_ms).
fn fused_vs_reference(m: usize, n: usize, r: usize, smoke: bool)
                      -> (f64, f64) {
    let mut rng = Rng::new(7);
    let g = Mat::randn(&mut rng, m, n, 1.0);
    let mut w_ref = Mat::randn(&mut rng, m, n, 1.0);
    let mut w_fus = w_ref.clone();
    let mut opt_ref = MoFaSgd::new(m, n, r, 0.9);
    let mut opt_fus = MoFaSgd::new(m, n, r, 0.9);
    opt_ref.step_reference(&mut w_ref, &g, 0.0);
    opt_fus.step(&mut w_fus, &g, 0.0);
    let (wu, iu) = if smoke { (0, 1) } else { (1, 3) };
    let ref_s = time_it(wu, iu, || {
        opt_ref.step_reference(&mut w_ref, &g, 1e-4);
    });
    let fus_s = time_it(wu, iu, || {
        opt_fus.step(&mut w_fus, &g, 1e-4);
    });
    (ref_s * 1e3, fus_s * 1e3)
}

/// Register-tiled NT kernel vs the frozen per-element unrolled path, at
/// one worker so the comparison is pure kernel (no fork-join). Shapes:
/// the Eq. 9 spectral-update rank-r outer product and the Newton–Schulz
/// Gram contractions. Returns the cases for `BENCH_fusion.json`.
fn nt_section(smoke: bool) -> Vec<Json> {
    println!("== NT kernel: 4x4 register tile vs unrolled dots ==\n");
    let shapes: &[(usize, usize, usize)] = if smoke {
        &[(512, 512, 32), (256, 256, 512)]
    } else {
        &[(1024, 1024, 32), (256, 256, 1024), (512, 512, 512)]
    };
    let mut rng = Rng::new(11);
    let mut cases = Vec::new();
    for &(m, n, k) in shapes {
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let b = Mat::randn(&mut rng, n, k, 1.0);
        let mut out = Mat::zeros(m, n);
        let (wu, iu) = if smoke { (1, 2) } else { (1, 4) };
        let old_ms = time_it(wu, iu, || {
            fusion::kernels::gemm_nt_unrolled(m, n, k, &a.data, &b.data,
                                              1.0, 0.0, &mut out.data);
        }) * 1e3;
        let tiled_ms = time_it(wu, iu, || {
            fusion::kernels::gemm(fusion::MatKind::NT, m, n, k, &a.data,
                                  &b.data, 1.0, 0.0, &mut out.data, &[], 1);
        }) * 1e3;
        let speedup = old_ms / tiled_ms.max(1e-9);
        println!(
            "nt {m}x{n} k={k:<5} unrolled {old_ms:9.2} ms   tiled \
             {tiled_ms:9.2} ms   speedup {speedup:5.2}x"
        );
        cases.push(Json::obj(vec![
            ("m", Json::Num(m as f64)),
            ("n", Json::Num(n as f64)),
            ("k", Json::Num(k as f64)),
            ("nt_unrolled_ms", Json::Num(old_ms)),
            ("nt_tiled_ms", Json::Num(tiled_ms)),
            ("nt_speedup", Json::Num(speedup)),
        ]));
    }
    println!();
    cases
}

fn fused_section(smoke: bool, nt_cases: Vec<Json>) {
    let workers = fusion::workers();
    println!(
        "== fused executor vs sequential reference ({workers} workers) ==\n"
    );
    let shapes: &[(usize, usize, usize)] = if smoke {
        &[(256, 256, 16), (1024, 1024, 32)]
    } else {
        &[(256, 1024, 32), (1024, 1024, 32), (2048, 2048, 32)]
    };
    let mut cases = Vec::new();
    for &(m, n, r) in shapes {
        let (ref_ms, fus_ms) = fused_vs_reference(m, n, r, smoke);
        let speedup = ref_ms / fus_ms.max(1e-9);
        println!(
            "umf_step {m}x{n} r={r:<4} reference {ref_ms:9.2} ms   fused \
             {fus_ms:9.2} ms   speedup {speedup:5.2}x"
        );
        cases.push(Json::obj(vec![
            ("m", Json::Num(m as f64)),
            ("n", Json::Num(n as f64)),
            ("r", Json::Num(r as f64)),
            ("reference_ms", Json::Num(ref_ms)),
            ("fused_ms", Json::Num(fus_ms)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    println!();
    if smoke {
        let doc = Json::obj(vec![
            ("bench", Json::Str("fusion".into())),
            ("workers", Json::Num(workers as f64)),
            ("cases", Json::Arr(cases)),
            ("nt_cases", Json::Arr(nt_cases)),
        ]);
        match std::fs::write("BENCH_fusion.json", doc.emit(2)) {
            Ok(()) => println!("wrote BENCH_fusion.json"),
            Err(e) => println!("BENCH_fusion.json not written: {e}"),
        }
    }
}

/// Sequential vs parallel round-robin Jacobi at the 2r×2r UMF-core
/// shapes, and unblocked vs blocked compact-WY QR at the augmented-panel
/// shapes. Smoke mode persists the numbers to `BENCH_svd.json` (checked
/// for completeness by `rust/run_checks.sh --bench-smoke`).
fn svd_qr_section(smoke: bool) {
    let workers = fusion::workers();
    println!(
        "== parallel Jacobi / blocked QR vs sequential baselines \
         ({workers} workers) ==\n"
    );
    let mut cases = Vec::new();
    for r in [16usize, 64, 128] {
        let k = 2 * r; // the 2r×2r core SVD shape of Alg. 1
        let m = 2 * r;
        let mut rng = Rng::new(9);
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let (wu, iu) = if r >= 64 { (0, 1) } else { (1, 3) };
        let seq_ms = time_it(wu, iu, || {
            let _ = jacobi_svd_seq(&a);
        }) * 1e3;
        let par_ms = time_it(wu, iu, || {
            let _ = jacobi_svd(&a);
        }) * 1e3;
        let svd_speedup = seq_ms / par_ms.max(1e-9);
        // QR at the m×2r augmented-panel shape QR([U  GV]).
        let qm = 1024.max(2 * k);
        let qa = Mat::randn(&mut rng, qm, k, 1.0);
        let old_ms = time_it(wu, iu, || {
            let _ = householder_qr_unblocked(&qa);
        }) * 1e3;
        let blk_ms = time_it(wu, iu, || {
            let _ = householder_qr(&qa);
        }) * 1e3;
        let qr_speedup = old_ms / blk_ms.max(1e-9);
        println!(
            "jacobi {m}x{k}   seq {seq_ms:9.2} ms   par {par_ms:9.2} ms   \
             speedup {svd_speedup:5.2}x"
        );
        println!(
            "qr     {qm}x{k}  old {old_ms:9.2} ms   blk {blk_ms:9.2} ms   \
             speedup {qr_speedup:5.2}x"
        );
        cases.push(Json::obj(vec![
            ("r", Json::Num(r as f64)),
            ("k", Json::Num(k as f64)),
            ("m", Json::Num(m as f64)),
            ("seq_svd_ms", Json::Num(seq_ms)),
            ("par_svd_ms", Json::Num(par_ms)),
            ("svd_speedup", Json::Num(svd_speedup)),
            ("qr_m", Json::Num(qm as f64)),
            ("qr_old_ms", Json::Num(old_ms)),
            ("qr_blocked_ms", Json::Num(blk_ms)),
            ("qr_speedup", Json::Num(qr_speedup)),
        ]));
    }
    println!();
    if smoke {
        let doc = Json::obj(vec![
            ("bench", Json::Str("svd".into())),
            ("workers", Json::Num(workers as f64)),
            ("cases", Json::Arr(cases)),
        ]);
        match std::fs::write("BENCH_svd.json", doc.emit(2)) {
            Ok(()) => println!("wrote BENCH_svd.json"),
            Err(e) => println!("BENCH_svd.json not written: {e}"),
        }
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    println!("\n== bench_umf: per-step optimizer cost (Table 1 runtime) ==\n");
    let nt_cases = nt_section(smoke);
    fused_section(smoke, nt_cases);
    svd_qr_section(smoke);
    if smoke {
        // Smoke mode exists to seed BENCH_fusion.json / BENCH_svd.json
        // quickly; skip the long Table 1 sweep.
        return;
    }
    for (m, n) in [(256, 1024), (256, 256)] {
        for r in [8, 32, 64, 128] {
            if 2 * r <= m.min(n) {
                native(m, n, r);
            }
        }
        println!();
    }
    match Registry::open(Registry::default_dir()) {
        Ok(reg) => {
            for r in [8, 32] {
                artifact(&reg, 256, 1024, r);
            }
            artifact(&reg, 256, 1024, 128);
        }
        Err(_) => println!("(artifacts not built; native-only run)"),
    }
}

//! Tangent-projection + spectral-update hot-path bench (L1 kernels).
//!
//! Measures the two O(mnr) operations of Algorithm 1 — native Rust vs the
//! Pallas-lowered artifacts (`mofasgd_accum` wraps `tangent_project`;
//! `rank_r_update` is embedded in the step artifacts). Interpret-mode
//! Pallas wallclock is NOT a TPU proxy (DESIGN.md §7); the artifact
//! numbers here measure the CPU request path the coordinator actually runs.

mod common;

use common::{report, time_it};
use mofasgd::linalg::Mat;
use mofasgd::optim::mofasgd::{LowRankBuffers, MoFaSgd};
use mofasgd::optim::MatrixOptimizer;
use mofasgd::runtime::{lit_f32, Registry};
use mofasgd::util::rng::Rng;

fn main() {
    println!("\n== bench_projection: tangent projections + rank-r update ==\n");
    let mut rng = Rng::new(1);
    for (m, n, r) in [(256, 1024, 8), (256, 1024, 32), (1024, 256, 32),
                      (256, 256, 128)] {
        let g = Mat::randn(&mut rng, m, n, 1.0);
        let mut opt = MoFaSgd::new(m, n, r, 0.9);
        let mut w = Mat::randn(&mut rng, m, n, 1.0);
        opt.step(&mut w, &g, 0.0); // init
        let flops = 2.0 * (m * n * r) as f64 * 3.0 / 1e9;
        let secs = time_it(2, 8, || {
            let _ = opt.project(&g);
        });
        report(&format!("native tangent_project {m}x{n} r={r}"), secs,
               Some((flops, "GFLOP/s")));
        let mut buf = LowRankBuffers::zeros(m, n, r);
        let secs = time_it(2, 8, || {
            opt.accumulate(&g, &mut buf);
        });
        report(&format!("native lowrank_accum {m}x{n} r={r}"), secs,
               Some((flops, "GFLOP/s")));
        // rank-r spectral apply: W -= eta U Vᵀ
        let u = opt.u.clone();
        let v = opt.v.clone();
        let secs = time_it(2, 8, || {
            let uvt = u.matmul_t(&v);
            w.axpy_inplace(1.0, -1e-4, &uvt);
        });
        report(&format!("native rank_r_update {m}x{n} r={r}"), secs,
               Some((2.0 * (m * n * r) as f64 / 1e9, "GFLOP/s")));
    }
    println!();
    let Ok(reg) = Registry::open(Registry::default_dir()) else {
        println!("(artifacts not built; native-only run)");
        return;
    };
    for (m, n, r) in [(256, 1024, 8), (256, 1024, 32)] {
        let Ok(exec) = reg.load(&Registry::opt_name(
            "mofasgd_accum", m, n, Some(r))) else { continue };
        let g = lit_f32(&[m, n], &rng.normal_vec(m * n, 1.0)).unwrap();
        let u = lit_f32(&[m, r], &rng.normal_vec(m * r, 1.0)).unwrap();
        let v = lit_f32(&[n, r], &rng.normal_vec(n * r, 1.0)).unwrap();
        let b1 = lit_f32(&[m, r], &vec![0.0; m * r]).unwrap();
        let b2 = lit_f32(&[r, n], &vec![0.0; r * n]).unwrap();
        let b3 = lit_f32(&[r, r], &vec![0.0; r * r]).unwrap();
        let secs = time_it(3, 10, || {
            let _ = exec.run(&[&g, &u, &v, &b1, &b2, &b3]).unwrap();
        });
        report(&format!("artifact mofasgd_accum(pallas) {m}x{n} r={r}"),
               secs, Some((2.0 * (m * n * r) as f64 * 3.0 / 1e9,
                           "GFLOP/s")));
    }
}

//! Tracing overhead self-benchmark (`BENCH_obs.json`).
//!
//! Times the same multi-layer fleet step with the recorder disabled and
//! enabled; the enabled run is drained between measurements so the rings
//! never wrap mid-timing. Smoke mode writes `BENCH_obs.json` and FAILS
//! (exit 1) if the enabled/disabled overhead exceeds the ≤2% gate —
//! with a small absolute floor so sub-millisecond steps aren't gated on
//! timer noise.

mod common;

use common::time_it;
use mofasgd::fusion::{Fleet, FleetUnit};
use mofasgd::linalg::Mat;
use mofasgd::obs;
use mofasgd::optim::{AdamW, GaLore, MatOpt, MatUnit, MoFaSgd};
use mofasgd::util::json::Json;
use mofasgd::util::rng::Rng;

const GATE_PCT: f64 = 2.0;
/// Don't fail the gate on absolute deltas below this — at smoke sizes a
/// step is a few ms and scheduler jitter alone exceeds 2%.
const FLOOR_US: f64 = 100.0;

enum BenchOpt {
    Mofa(MoFaSgd),
    Gal(GaLore),
    Adam(AdamW),
}

impl BenchOpt {
    fn build(i: usize, mn: usize, r: usize) -> BenchOpt {
        match i % 4 {
            0 | 1 => BenchOpt::Mofa(MoFaSgd::new(mn, mn, r, 0.9)),
            2 => BenchOpt::Gal(GaLore::new(mn, mn, r, 1_000_000, 0.9,
                                           0.999, 17 + i as u64)),
            _ => BenchOpt::Adam(AdamW::new(mn, mn, 0.9, 0.999, 0.0)),
        }
    }

    fn unit<'a>(&'a mut self, w: &'a mut Mat, g: &'a Mat, eta: f32)
                -> MatUnit<'a> {
        let opt = match self {
            BenchOpt::Mofa(o) => MatOpt::MoFaSgd(o),
            BenchOpt::Gal(o) => MatOpt::GaLore(o),
            BenchOpt::Adam(o) => MatOpt::AdamW(o),
        };
        MatUnit::new(opt, w, g, eta)
    }
}

struct BenchStack {
    opts: Vec<BenchOpt>,
    ws: Vec<Mat>,
    gs: Vec<Mat>,
}

fn build_stack(layers: usize, mn: usize, r: usize, seed: u64) -> BenchStack {
    let mut rng = Rng::new(seed);
    let mut opts = Vec::new();
    let mut ws = Vec::new();
    let mut gs = Vec::new();
    for i in 0..layers {
        opts.push(BenchOpt::build(i, mn, r));
        ws.push(Mat::randn(&mut rng, mn, mn, 1.0));
        gs.push(Mat::randn(&mut rng, mn, mn, 1.0));
    }
    BenchStack { opts, ws, gs }
}

fn step_fleet(fleet: &mut Fleet, stack: &mut BenchStack, workers: usize) {
    let mut units: Vec<MatUnit> = stack
        .opts
        .iter_mut()
        .zip(&mut stack.ws)
        .zip(&stack.gs)
        .map(|((opt, w), g)| opt.unit(w, g, 1e-3))
        .collect();
    let mut refs: Vec<&mut dyn FleetUnit> = units
        .iter_mut()
        .map(|u| u as &mut dyn FleetUnit)
        .collect();
    fleet.run(&mut refs, workers);
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    println!("\n== bench_obs: tracing overhead (gate ≤{GATE_PCT}%) ==\n");

    let (layers, mn, r) = (8usize, 256usize, 32usize);
    let workers = 2usize;
    let (wu, iu) = if smoke { (2, 5) } else { (5, 20) };

    // -- disabled baseline ---------------------------------------------------
    obs::set_enabled(false);
    let mut stack = build_stack(layers, mn, r, 9);
    let mut fleet = Fleet::new();
    step_fleet(&mut fleet, &mut stack, workers); // init (SVD_r, subspaces)
    step_fleet(&mut fleet, &mut stack, workers); // steady shape
    let disabled_ms = time_it(wu, iu, || {
        step_fleet(&mut fleet, &mut stack, workers);
    }) * 1e3;

    // -- enabled -------------------------------------------------------------
    // Same stack (sizes are steady; the math does not affect timing) —
    // drain first so rings start empty, and warm one traced step so the
    // worker threads claim their rings outside the timed window.
    obs::set_enabled(true);
    let _ = obs::drain();
    step_fleet(&mut fleet, &mut stack, workers);
    let enabled_ms = time_it(wu, iu, || {
        step_fleet(&mut fleet, &mut stack, workers);
    }) * 1e3;
    let trace = obs::drain();
    let spans = trace.spans.len();

    // Drain cost (not part of the hot path, reported for context).
    step_fleet(&mut fleet, &mut stack, workers);
    let drain_ms = {
        let t0 = std::time::Instant::now();
        let _ = obs::drain();
        t0.elapsed().as_secs_f64() * 1e3
    };
    obs::set_enabled(false);

    let overhead_pct =
        100.0 * (enabled_ms - disabled_ms) / disabled_ms.max(1e-9);
    let abs_us = (enabled_ms - disabled_ms) * 1e3;
    let pass = overhead_pct <= GATE_PCT || abs_us <= FLOOR_US;

    println!(
        "fleet {layers} layers {mn}x{mn} r={r} w={workers}   disabled \
         {disabled_ms:9.3} ms   enabled {enabled_ms:9.3} ms   overhead \
         {overhead_pct:6.2}% ({abs_us:8.1} us)   {spans} spans   drain \
         {drain_ms:.3} ms   {}",
        if pass { "PASS" } else { "FAIL" }
    );

    if smoke {
        let doc = Json::obj(vec![
            ("bench", Json::Str("obs".into())),
            ("workers", Json::Num(workers as f64)),
            ("gate_pct", Json::Num(GATE_PCT)),
            ("floor_us", Json::Num(FLOOR_US)),
            ("pass", Json::Bool(pass)),
            ("cases", Json::Arr(vec![Json::obj(vec![
                ("layers", Json::Num(layers as f64)),
                ("mn", Json::Num(mn as f64)),
                ("rank", Json::Num(r as f64)),
                ("disabled_ms", Json::Num(disabled_ms)),
                ("enabled_ms", Json::Num(enabled_ms)),
                ("overhead_pct", Json::Num(overhead_pct)),
                ("abs_us", Json::Num(abs_us)),
                ("spans", Json::Num(spans as f64)),
                ("drain_ms", Json::Num(drain_ms)),
            ])])),
        ]);
        match std::fs::write("BENCH_obs.json", doc.emit(2)) {
            Ok(()) => println!("wrote BENCH_obs.json"),
            Err(e) => println!("BENCH_obs.json not written: {e}"),
        }
        if !pass {
            eprintln!(
                "bench_obs: tracing overhead {overhead_pct:.2}% exceeds \
                 the {GATE_PCT}% gate (delta {abs_us:.1} us > floor \
                 {FLOOR_US} us)"
            );
            std::process::exit(1);
        }
    }
}

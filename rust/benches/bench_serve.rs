//! Multi-tenant serve tick throughput: how one lockstep tick scales
//! with the number of admitted sessions and fleet workers.
//!
//! Each session is the serve workload at bench scale (4 matrix layers —
//! MoFaSGD/Muon/AdamW/SGD-M — plus one vec layer, accum 3, inline
//! noise), so a tick covers noise generation, fused lane accumulation,
//! tree reduce, and the staged optimizer steps for every tenant. The
//! interesting read is the workers column: sessions × layers chains are
//! independent, so added workers should cut tick latency until chains
//! run out.
//!
//! Smoke mode (`--smoke` / `BENCH_SMOKE=1`) writes `BENCH_serve.json`
//! with a per-case breakdown and a `"pass"` verdict (every tick's loss
//! stayed finite — a correctness floor, not a performance claim),
//! consumed by `rust/run_checks.sh --bench-smoke`.

mod common;

use common::{report, time_it};
use mofasgd::serve::{LayerKind, LayerSpec, SessionManager, SessionSpec,
                     TickEvent, VecSpec};
use mofasgd::util::json::Json;

fn bench_spec(name: &str, seed: u64) -> SessionSpec {
    let layer = |kind, m, n| LayerSpec { kind, m, n, rank: 8, beta: 0.9 };
    SessionSpec {
        name: name.to_string(),
        seed,
        steps: 1_000_000,
        accum: 3,
        eta: 0.01,
        noise: 0.5,
        prefetch: 0,
        layers: vec![
            layer(LayerKind::MoFaSgd, 192, 160),
            layer(LayerKind::Muon, 96, 96),
            layer(LayerKind::AdamW, 128, 80),
            layer(LayerKind::SgdM, 80, 144),
        ],
        vecs: vec![VecSpec { len: 1024 }],
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    println!("\n== bench_serve: multi-tenant lockstep tick ==\n");

    let (session_counts, worker_counts, wu, iu): (&[usize], &[usize], _, _) =
        if smoke {
            (&[1, 4], &[1, 2], 1, 3)
        } else {
            (&[1, 2, 4, 8], &[1, 2, 8], 2, 8)
        };

    let mut cases = Vec::new();
    let mut all_pass = true;
    for &n_sessions in session_counts {
        for &workers in worker_counts {
            let mut mgr = SessionManager::new();
            for i in 0..n_sessions {
                mgr.admit(&bench_spec(&format!("t{i}"), 1 + i as u64))
                    .unwrap();
            }
            let mut events: Vec<TickEvent> =
                Vec::with_capacity(2 * n_sessions);
            // Warm-up inside time_it covers MoFaSGD SVD_r init.
            let mut finite = true;
            let secs = time_it(wu, iu, || {
                events.clear();
                mgr.tick(workers, &mut events);
                for e in &events {
                    if let TickEvent::Metrics { loss, .. } = e {
                        finite &= loss.is_finite();
                    }
                }
            });
            let n_layers = n_sessions * 5;
            let pass = finite;
            all_pass &= pass;
            report(
                &format!(
                    "tick s={n_sessions} ({n_layers} chains) w={workers}\
                     {}",
                    if pass { "" } else { "  NON-FINITE" }
                ),
                secs,
                Some((1.0, "ticks/s")),
            );
            cases.push(Json::obj(vec![
                ("sessions", Json::Num(n_sessions as f64)),
                ("layers", Json::Num(n_layers as f64)),
                ("workers", Json::Num(workers as f64)),
                ("tick_ms", Json::Num(secs * 1e3)),
                ("ticks_per_s", Json::Num(1.0 / secs.max(1e-12))),
                ("pass", Json::Bool(pass)),
            ]));
        }
    }
    println!();
    if smoke {
        let doc = Json::obj(vec![
            ("bench", Json::Str("serve".into())),
            ("cases", Json::Arr(cases)),
            ("pass", Json::Bool(all_pass)),
        ]);
        match std::fs::write("BENCH_serve.json", doc.emit(2)) {
            Ok(()) => println!("wrote BENCH_serve.json (pass={all_pass})"),
            Err(e) => println!("BENCH_serve.json not written: {e}"),
        }
    } else if !all_pass {
        println!("NOTE: a tick produced a non-finite loss — investigate \
                  before trusting the numbers");
    }
}

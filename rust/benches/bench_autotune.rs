//! Static-vs-autotuned GEMM dispatch per UMF shape family.
//!
//! For each recurring shape class of the MoFaSGD step (thin m×r
//! projections, square r×r cores, rank-r NT outer products, Gram
//! squares) this bench tunes the class, then times the static default
//! variant and the tuned winner back to back through `gemm_v` at one
//! worker — pure kernel comparison, no fork-join. The acceptance bar is
//! that the tuned path is never slower than the static one (a tuner
//! that picks the static variant passes by construction: the measured
//! ratio is then noise around 1.0, and `pass` allows 5% of it).
//!
//! Smoke mode (`--smoke` / `BENCH_SMOKE=1`) writes `BENCH_autotune.json`
//! with a per-case breakdown and a global `"pass"` verdict, consumed by
//! `rust/run_checks.sh --bench-smoke`.

mod common;

use common::time_it;
use mofasgd::fusion::autotune::{self, Mode};
use mofasgd::fusion::kernels::{gemm_v, static_variant};
use mofasgd::fusion::MatKind;
use mofasgd::linalg::Mat;
use mofasgd::util::json::Json;
use mofasgd::util::rng::Rng;

struct Family {
    label: &'static str,
    kind: MatKind,
    m: usize,
    n: usize,
    k: usize,
}

/// The UMF shape families (DESIGN.md §8/§12) at bench scale.
const FAMILIES: [Family; 5] = [
    Family { label: "thin_gv (G·V)", kind: MatKind::NN,
             m: 1024, n: 32, k: 1024 },
    Family { label: "thin_utg (Uᵀ·G)", kind: MatKind::TN,
             m: 32, n: 1024, k: 1024 },
    Family { label: "core_rr (r×r)", kind: MatKind::NN,
             m: 64, n: 64, k: 64 },
    Family { label: "outer_uvt (U·Vᵀ)", kind: MatKind::NT,
             m: 1024, n: 1024, k: 32 },
    Family { label: "gram_ns (X·Xᵀ)", kind: MatKind::NT,
             m: 256, n: 256, k: 256 },
];

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("BENCH_SMOKE").is_ok();
    println!("\n== bench_autotune: static vs tuned dispatch per shape \
              family ==\n");

    // Tune into a scratch cache so bench runs never pollute (or get
    // skewed by) the per-host table, unless the caller already pointed
    // MOFA_AUTOTUNE_CACHE somewhere.
    if std::env::var_os("MOFA_AUTOTUNE_CACHE").is_none() {
        let scratch = std::env::temp_dir().join(format!(
            "mofa_bench_autotune_{}.json", std::process::id()));
        std::env::set_var("MOFA_AUTOTUNE_CACHE", &scratch);
    }
    autotune::set_mode(Mode::Refresh);

    let mut rng = Rng::new(21);
    let (wu, iu) = if smoke { (1, 3) } else { (2, 8) };
    let mut cases = Vec::new();
    let mut all_pass = true;
    for f in &FAMILIES {
        let (m, n, k) = (f.m, f.n, f.k);
        let (sa, sb) = match f.kind {
            MatKind::NN => ((m, k), (k, n)),
            MatKind::TN => ((k, m), (k, n)),
            MatKind::NT => ((m, k), (n, k)),
        };
        let a = Mat::randn(&mut rng, sa.0, sa.1, 1.0);
        let b = Mat::randn(&mut rng, sb.0, sb.1, 1.0);
        let mut out = Mat::zeros(m, n);

        let tuned = autotune::chosen(f.kind, m, n, k);
        let stat = static_variant(f.kind);
        let static_ms = time_it(wu, iu, || {
            gemm_v(stat, m, n, k, &a.data, &b.data, 1.0, 0.0,
                   &mut out.data, &[], 1);
        }) * 1e3;
        let tuned_ms = time_it(wu, iu, || {
            gemm_v(tuned, m, n, k, &a.data, &b.data, 1.0, 0.0,
                   &mut out.data, &[], 1);
        }) * 1e3;
        let speedup = static_ms / tuned_ms.max(1e-9);
        // The tuner must never lose to the static default; 5% headroom
        // absorbs timer noise when it picks the static variant itself.
        let pass = tuned_ms <= static_ms * 1.05;
        all_pass &= pass;
        println!(
            "{:<18} {} {m}x{n}x{k:<5} static[{:<15}] {static_ms:8.3} ms   \
             tuned[{:<15}] {tuned_ms:8.3} ms   speedup {speedup:5.2}x   \
             {}",
            f.label,
            match f.kind {
                MatKind::NN => "nn",
                MatKind::TN => "tn",
                MatKind::NT => "nt",
            },
            stat.name(), tuned.name(),
            if pass { "ok" } else { "SLOWER" },
        );
        cases.push(Json::obj(vec![
            ("family", Json::Str(f.label.into())),
            ("class", Json::Str(autotune::key_string(f.kind, m, n, k))),
            ("m", Json::Num(m as f64)),
            ("n", Json::Num(n as f64)),
            ("k", Json::Num(k as f64)),
            ("static_variant", Json::Str(stat.name().into())),
            ("tuned_variant", Json::Str(tuned.name().into())),
            ("static_ms", Json::Num(static_ms)),
            ("tuned_ms", Json::Num(tuned_ms)),
            ("speedup", Json::Num(speedup)),
            ("pass", Json::Bool(pass)),
        ]));
    }
    println!();
    if smoke {
        let doc = Json::obj(vec![
            ("bench", Json::Str("autotune".into())),
            ("tuned_classes", Json::Num(autotune::table_len() as f64)),
            ("cases", Json::Arr(cases)),
            ("pass", Json::Bool(all_pass)),
        ]);
        match std::fs::write("BENCH_autotune.json", doc.emit(2)) {
            Ok(()) => println!("wrote BENCH_autotune.json (pass={all_pass})"),
            Err(e) => println!("BENCH_autotune.json not written: {e}"),
        }
    } else if !all_pass {
        println!("NOTE: at least one family regressed vs static — \
                  rerun on a quiet machine before trusting the table");
    }
}

//! Shared timing harness for the benches (criterion is not vendored).

use std::time::Instant;

/// Time `f` over `iters` iterations after `warmup` runs; returns seconds
/// per iteration (median of 5 repetitions of the timed block).
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut reps: Vec<f64> = (0..5)
        .map(|_| {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            t0.elapsed().as_secs_f64() / iters as f64
        })
        .collect();
    reps.sort_by(|a, b| a.partial_cmp(b).unwrap());
    reps[2]
}

pub fn report(name: &str, secs: f64, work: Option<(f64, &str)>) {
    match work {
        Some((units, label)) => println!(
            "{name:44} {:>10.3} ms   {:>10.2} {label}",
            secs * 1e3,
            units / secs
        ),
        None => println!("{name:44} {:>10.3} ms", secs * 1e3),
    }
}

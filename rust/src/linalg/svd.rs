//! One-sided Jacobi SVD + randomized low-rank SVD.
//!
//! Jacobi iterates plane rotations until columns are mutually orthogonal —
//! dependency-free and exact, and since the round-robin parallel ordering
//! landed, fast enough for the 2r×2r UMF cores well past r = 128.
//!
//! Two paths:
//! * [`jacobi_svd_into`] — the parallel-ordering formulation ported from
//!   `python/compile/linalg_jnp.py::jacobi_svd`: each round-robin round
//!   rotates k/2 *disjoint* column pairs concurrently over `util::pool`
//!   (a sweep is k−1 parallel rounds instead of k(k−1)/2 sequential
//!   rotations), on a precomputed static schedule, with odd-k zero-column
//!   padding and a NaN-safe `total_cmp` descending sort. The working
//!   matrix is stored transposed so every rotation streams contiguous
//!   rows. Results are bit-identical across worker counts: pairs within
//!   a round touch disjoint columns, so the update order cannot matter.
//! * [`jacobi_svd_seq`] — the frozen pre-refactor sequential sweep,
//!   retained as the parity baseline (`rust/tests/linalg_parity.rs`).

use super::{householder_qr_into, LinalgWorkspace, Mat};
use crate::obs;
use crate::util::pool::{self, RowsPtr};
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};

pub struct Svd {
    /// m×k left singular vectors.
    pub u: Mat,
    /// k singular values, descending, non-negative.
    pub s: Vec<f32>,
    /// k×k right singular vectors (A = U diag(s) Vᵀ).
    pub v: Mat,
}

const MAX_SWEEPS: usize = 30;
const PAIR_TOL: f64 = 1e-10;
const SWEEP_TOL: f64 = 1e-9;

/// One-sided Jacobi SVD of a (m×k), m ≥ k — parallel round-robin path,
/// allocating convenience wrapper over [`jacobi_svd_into`].
pub fn jacobi_svd(a: &Mat) -> Svd {
    let mut ws = LinalgWorkspace::new();
    let mut u = Mat::zeros(0, 0);
    let mut v = Mat::zeros(0, 0);
    let mut s = Vec::new();
    jacobi_svd_into(a, &mut u, &mut s, &mut v, &mut ws);
    Svd { u, s, v }
}

/// Parallel round-robin Jacobi SVD of a (m×k), m ≥ k, into caller-owned
/// outputs and workspace. Allocation-free once `ws` (including its
/// memoized schedule for this k) and the outputs are warm.
pub fn jacobi_svd_into(a: &Mat, u: &mut Mat, s_out: &mut Vec<f32>,
                       v: &mut Mat, ws: &mut LinalgWorkspace) {
    let (m, k0) = (a.rows, a.cols);
    assert!(m >= k0, "jacobi_svd expects tall input, got {m}x{k0}");
    assert!(k0 >= 1, "jacobi_svd needs at least one column");
    let _sp = obs::span_args(obs::Category::Linalg, "jacobi_svd",
                             [m as u32, k0 as u32, 0]);
    if k0 == 1 {
        let nrm = (0..m)
            .map(|i| (a[(i, 0)] as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        s_out.clear();
        s_out.push(nrm as f32);
        u.reset(m, 1);
        if nrm > 1e-12 {
            for i in 0..m {
                u[(i, 0)] = (a[(i, 0)] as f64 / nrm) as f32;
            }
        }
        v.reset(1, 1);
        v[(0, 0)] = 1.0;
        return;
    }
    // Pad to an even column count (zero column ⇒ zero singular value,
    // sorted last and trimmed below — it never rotates: γ = 0 exactly).
    let k = k0 + (k0 % 2);
    let half = k / 2;
    // Small-problem cutoff, same policy as the GEMM kernels: a round's
    // work is ~half·(10m + 4k) flops (three m-dots, two m-rotations, two
    // k-rotations per pair); below the fork-join threshold the 2r×2r
    // cores MoFaSgd actually steps stay on the calling thread. Safe at
    // any gate value — results are bit-identical at every worker count.
    let round_flops = half * (10 * m + 4 * k);
    let workers = crate::fusion::workers()
        .min(half)
        .min(1 + round_flops / crate::fusion::kernels::MIN_PAR_FLOPS);
    let pos = ws.schedule_pos(k);
    let LinalgWorkspace { bt, vt, snorm, order, scheds, .. } = ws;
    let sched: &[(u32, u32)] = &scheds[pos].1;
    // Work transposed: rows of `bt`/`vt` are columns of B/V, so the dot
    // products and rotations below stream contiguous memory.
    bt.reset(k, m);
    for j in 0..k0 {
        for i in 0..m {
            bt[(j, i)] = a[(i, j)];
        }
    }
    vt.reset(k, k);
    for j in 0..k {
        vt[(j, j)] = 1.0;
    }
    for sweep in 0..MAX_SWEEPS {
        let _sw = obs::span_args(obs::Category::Linalg, "jacobi_sweep",
                                 [m as u32, k as u32, sweep as u32]);
        // Sweep-wide max of |γ|/√(αβ); bit-encoded (values ≥ 0, so the
        // IEEE bit pattern is monotone and fetch_max works).
        let off_bits = AtomicU64::new(0);
        for round in 0..k - 1 {
            let pairs = &sched[round * half..(round + 1) * half];
            let btp = RowsPtr::new(&mut bt.data, m);
            let vtp = RowsPtr::new(&mut vt.data, k);
            let off = &off_bits;
            let rotate = move |&(p, q): &(u32, u32)| {
                let (p, q) = (p as usize, q as usize);
                // SAFETY: pairs within a round are disjoint, and each
                // pair is processed by exactly one worker, so rows p and
                // q are exclusively ours for the duration.
                let bp = unsafe { btp.row_mut(p) };
                let bq = unsafe { btp.row_mut(q) };
                let mut alpha = 0.0f64;
                let mut beta = 0.0f64;
                let mut gamma = 0.0f64;
                for t in 0..m {
                    let bi = bp[t] as f64;
                    let bj = bq[t] as f64;
                    alpha += bi * bi;
                    beta += bj * bj;
                    gamma += bi * bj;
                }
                let scale = (alpha * beta).sqrt();
                let rel = gamma.abs() / scale.max(1e-30);
                // NaN bits would exceed every finite pattern and wedge the
                // convergence check at 30 sweeps; drop NaN like the
                // sequential path's f64::max does.
                if !rel.is_nan() {
                    off.fetch_max(rel.to_bits(), Ordering::Relaxed);
                }
                if gamma.abs() <= PAIR_TOL * scale {
                    return;
                }
                let zeta = (beta - alpha) / (2.0 * gamma);
                let sgn = if zeta >= 0.0 { 1.0 } else { -1.0 };
                let t_rot = sgn / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t_rot * t_rot).sqrt();
                let s = (c * t_rot) as f32;
                let c = c as f32;
                for t in 0..m {
                    let bi = bp[t];
                    let bj = bq[t];
                    bp[t] = c * bi - s * bj;
                    bq[t] = s * bi + c * bj;
                }
                let vp = unsafe { vtp.row_mut(p) };
                let vq = unsafe { vtp.row_mut(q) };
                for t in 0..k {
                    let vi = vp[t];
                    let vj = vq[t];
                    vp[t] = c * vi - s * vj;
                    vq[t] = s * vi + c * vj;
                }
            };
            if workers <= 1 {
                for pr in pairs {
                    rotate(pr);
                }
            } else {
                pool::scope_chunks(half, workers, |_, s0, e0| {
                    for pr in &pairs[s0..e0] {
                        rotate(pr);
                    }
                });
            }
        }
        if f64::from_bits(off_bits.load(Ordering::Relaxed)) < SWEEP_TOL {
            break;
        }
    }
    // Singular values = column norms; NaN-safe descending sort
    // (`total_cmp`; `sort_unstable` keeps the steady state alloc-free).
    snorm.clear();
    for j in 0..k {
        let nrm = bt.row(j)
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt();
        snorm.push(nrm);
    }
    order.clear();
    order.extend(0..k);
    // Descending by norm, ties broken by ascending index: keeps the sort
    // fully deterministic and ensures the odd-k padding column (index k0,
    // norm exactly 0) can never displace a real zero column — whose V
    // column is a unit vector — from the top k0.
    order.sort_unstable_by(|&x, &y| {
        snorm[y].total_cmp(&snorm[x]).then(x.cmp(&y))
    });
    s_out.clear();
    u.reset(m, k0);
    v.reset(k0, k0);
    for (new_j, &old_j) in order.iter().take(k0).enumerate() {
        let sv = snorm[old_j];
        s_out.push(sv as f32);
        if sv > 1e-12 {
            let inv = 1.0 / sv;
            let row = bt.row(old_j);
            for i in 0..m {
                u[(i, new_j)] = (row[i] as f64 * inv) as f32;
            }
        }
        let vrow = vt.row(old_j);
        for i in 0..k0 {
            v[(i, new_j)] = vrow[i];
        }
    }
}

/// Frozen pre-refactor sequential one-sided Jacobi: cyclic pair order,
/// strided column access, allocation per call. Parity baseline for the
/// parallel path and the `BENCH_svd.json` SVD speedup measurement.
pub fn jacobi_svd_seq(a: &Mat) -> Svd {
    let (m, k) = (a.rows, a.cols);
    assert!(m >= k, "jacobi_svd expects tall input, got {m}x{k}");
    let mut b = a.clone();
    let mut v = Mat::eye(k);
    for _ in 0..MAX_SWEEPS {
        let mut off = 0.0f64;
        for i in 0..k {
            for j in (i + 1)..k {
                let mut alpha = 0.0f64;
                let mut beta = 0.0f64;
                let mut gamma = 0.0f64;
                for t in 0..m {
                    let bi = b[(t, i)] as f64;
                    let bj = b[(t, j)] as f64;
                    alpha += bi * bi;
                    beta += bj * bj;
                    gamma += bi * bj;
                }
                off = off.max(gamma.abs() / (alpha * beta).sqrt().max(1e-30));
                if gamma.abs() <= PAIR_TOL * (alpha * beta).sqrt() {
                    continue;
                }
                let zeta = (beta - alpha) / (2.0 * gamma);
                let sgn = if zeta >= 0.0 { 1.0 } else { -1.0 };
                let t_rot = sgn / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t_rot * t_rot).sqrt();
                let s = (c * t_rot) as f32;
                let c = c as f32;
                for t in 0..m {
                    let bi = b[(t, i)];
                    let bj = b[(t, j)];
                    b[(t, i)] = c * bi - s * bj;
                    b[(t, j)] = s * bi + c * bj;
                }
                for t in 0..k {
                    let vi = v[(t, i)];
                    let vj = v[(t, j)];
                    v[(t, i)] = c * vi - s * vj;
                    v[(t, j)] = s * vi + c * vj;
                }
            }
        }
        if off < SWEEP_TOL {
            break;
        }
    }
    // Singular values = column norms; sort descending. `total_cmp` keeps
    // NaN singular values (NaN/Inf inputs) from aborting the sort — the
    // old `partial_cmp(..).unwrap()` panicked here.
    let mut s: Vec<f32> = (0..k)
        .map(|j| {
            (0..m).map(|i| (b[(i, j)] as f64).powi(2)).sum::<f64>().sqrt()
                as f32
        })
        .collect();
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_unstable_by(|&x, &y| s[y].total_cmp(&s[x]));
    let mut u = Mat::zeros(m, k);
    let mut v_sorted = Mat::zeros(k, k);
    let s_sorted: Vec<f32> = order.iter().map(|&j| s[j]).collect();
    for (new_j, &old_j) in order.iter().enumerate() {
        let nrm = s[old_j].max(1e-30);
        for i in 0..m {
            u[(i, new_j)] = if s[old_j] > 1e-12 {
                b[(i, old_j)] / nrm
            } else {
                0.0
            };
        }
        for i in 0..k {
            v_sorted[(i, new_j)] = v[(i, old_j)];
        }
    }
    s = s_sorted;
    Svd { u, s, v: v_sorted }
}

/// Randomized range finder: orthonormal Q (m×r) ≈ top-r range of A, with
/// `iters` power iterations (mirrors `linalg_jnp.rand_range`). QR panels
/// run through the blocked path staged in `ws`.
pub fn rand_range_ws(a: &Mat, r: usize, iters: usize, rng: &mut Rng,
                     ws: &mut LinalgWorkspace) -> Mat {
    let omega = Mat::randn(rng, a.cols, r, 1.0);
    let mut q = Mat::zeros(0, 0);
    let mut z = Mat::zeros(0, 0);
    let mut rr = Mat::zeros(0, 0);
    householder_qr_into(&a.matmul(&omega), &mut q, &mut rr, ws);
    for _ in 0..iters {
        householder_qr_into(&a.t_matmul(&q), &mut z, &mut rr, ws);
        householder_qr_into(&a.matmul(&z), &mut q, &mut rr, ws);
    }
    q
}

/// Allocating convenience wrapper over [`rand_range_ws`].
pub fn rand_range(a: &Mat, r: usize, iters: usize, rng: &mut Rng) -> Mat {
    let mut ws = LinalgWorkspace::new();
    rand_range_ws(a, r, iters, rng, &mut ws)
}

/// Rank-r randomized SVD: A ≈ U diag(s) Vᵀ with U m×r, V n×r, staged in
/// the caller's workspace (QR + inner Jacobi both reuse it).
pub fn svd_lowrank_ws(a: &Mat, r: usize, iters: usize, rng: &mut Rng,
                      ws: &mut LinalgWorkspace) -> Svd {
    let q = rand_range_ws(a, r, iters, rng, ws);   // m×r
    let b = q.t_matmul(a);                          // r×n
    let bt = b.t();                                 // n×r
    let mut iu = Mat::zeros(0, 0);
    let mut iv = Mat::zeros(0, 0);
    let mut is_ = Vec::new();
    jacobi_svd_into(&bt, &mut iu, &mut is_, &mut iv, ws);
    // bᵀ = U₁ s V₁ᵀ ⇒ b = V₁ s U₁ᵀ
    Svd { u: q.matmul(&iv), s: is_, v: iu }
}

/// Allocating convenience wrapper over [`svd_lowrank_ws`].
pub fn svd_lowrank(a: &Mat, r: usize, iters: usize, rng: &mut Rng) -> Svd {
    let mut ws = LinalgWorkspace::new();
    svd_lowrank_ws(a, r, iters, rng, &mut ws)
}

/// Energy ratio captured by the top-r singular values:
/// Σ_{i<r} σ_i² / ‖A‖_F² (paper Fig. 6a metric).
pub fn energy_ratio(s: &[f32], frob: f32, r: usize) -> f64 {
    let top: f64 = s.iter().take(r).map(|x| (*x as f64).powi(2)).sum();
    top / ((frob as f64).powi(2)).max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{dim, Prop};

    fn reconstruct(svd: &Svd) -> Mat {
        let k = svd.s.len();
        let mut us = svd.u.clone();
        for j in 0..k {
            for i in 0..us.rows {
                us[(i, j)] *= svd.s[j];
            }
        }
        us.matmul_t(&svd.v)
    }

    #[test]
    fn svd_reconstructs_fixed() {
        let mut rng = Rng::new(1);
        for (m, k) in [(8, 8), (40, 16), (64, 64), (33, 5)] {
            let a = Mat::randn(&mut rng, m, k, 1.0);
            let svd = jacobi_svd(&a);
            assert!(reconstruct(&svd).rel_err(&a) < 1e-4, "{m}x{k}");
            assert!(svd.u.t_matmul(&svd.u).rel_err(&Mat::eye(k)) < 1e-4);
            assert!(svd.v.t_matmul(&svd.v).rel_err(&Mat::eye(k)) < 1e-4);
            for w in svd.s.windows(2) {
                assert!(w[0] >= w[1] - 1e-5, "not sorted");
            }
        }
    }

    #[test]
    fn svd_property() {
        Prop::new(24).check("jacobi-svd", |rng| {
            let k = dim(rng, 20);
            let m = k + dim(rng, 30);
            let a = Mat::randn(rng, m, k, 1.0);
            let svd = jacobi_svd(&a);
            assert!(reconstruct(&svd).rel_err(&a) < 1e-3);
        });
    }

    #[test]
    fn singular_values_of_diagonal() {
        let a = Mat::from_fn(4, 4, |i, j| if i == j {
            [3.0, 1.0, 4.0, 2.0][i]
        } else {
            0.0
        });
        let svd = jacobi_svd(&a);
        let want = [4.0, 3.0, 2.0, 1.0];
        for (got, want) in svd.s.iter().zip(want) {
            assert!((got - want).abs() < 1e-5);
        }
    }

    #[test]
    fn odd_column_count_pads_cleanly() {
        let mut rng = Rng::new(6);
        for (m, k) in [(9, 3), (21, 7), (13, 13)] {
            let a = Mat::randn(&mut rng, m, k, 1.0);
            let svd = jacobi_svd(&a);
            assert_eq!(svd.s.len(), k);
            assert_eq!((svd.u.rows, svd.u.cols), (m, k));
            assert_eq!((svd.v.rows, svd.v.cols), (k, k));
            assert!(reconstruct(&svd).rel_err(&a) < 1e-4, "{m}x{k}");
            assert!(svd.u.t_matmul(&svd.u).rel_err(&Mat::eye(k)) < 1e-4);
        }
    }

    #[test]
    fn nan_input_does_not_panic() {
        // Regression: the sort previously aborted on NaN singular values
        // via `partial_cmp(..).unwrap()` (mirrors the Mat zero-skip NaN
        // fix: poisoned inputs must propagate, not crash).
        let mut a = Mat::zeros(6, 4);
        a[(0, 0)] = f32::NAN;
        a[(1, 1)] = f32::INFINITY;
        a[(2, 2)] = 1.0;
        for svd in [jacobi_svd(&a), jacobi_svd_seq(&a)] {
            assert_eq!(svd.s.len(), 4);
            assert!(svd.s.iter().any(|x| !x.is_finite()),
                    "NaN/Inf must propagate into the spectrum");
        }
    }

    #[test]
    fn lowrank_svd_exact_on_lowrank() {
        let mut rng = Rng::new(3);
        let (m, n, r) = (80, 60, 6);
        let a = Mat::randn(&mut rng, m, r, 1.0)
            .matmul(&Mat::randn(&mut rng, r, n, 1.0));
        let svd = svd_lowrank(&a, r, 2, &mut rng);
        let approx = {
            let mut us = svd.u.clone();
            for j in 0..r {
                for i in 0..m {
                    us[(i, j)] *= svd.s[j];
                }
            }
            us.matmul_t(&svd.v)
        };
        assert!(approx.rel_err(&a) < 1e-3);
    }

    #[test]
    fn energy_ratio_bounds() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(&mut rng, 30, 20, 1.0);
        let svd = jacobi_svd(&a);
        let frob = a.frob_norm();
        let r_full = energy_ratio(&svd.s, frob, 20);
        assert!((r_full - 1.0).abs() < 1e-3, "{r_full}");
        let r_half = energy_ratio(&svd.s, frob, 5);
        assert!(r_half > 0.0 && r_half < 1.0);
    }

    #[test]
    fn rand_range_orthogonal() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(&mut rng, 50, 40, 1.0);
        let q = rand_range(&a, 8, 2, &mut rng);
        assert_eq!((q.rows, q.cols), (50, 8));
        assert!(q.t_matmul(&q).rel_err(&Mat::eye(8)) < 1e-4);
    }
}

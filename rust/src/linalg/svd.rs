//! One-sided Jacobi SVD + randomized low-rank SVD.
//!
//! Jacobi iterates plane rotations until columns are mutually orthogonal —
//! slow for huge matrices but exact, dependency-free, and more than fast
//! enough for the 2r×2r cores and moment-spectrum analyses this repo runs.

use super::{householder_qr, Mat};
use crate::util::rng::Rng;

pub struct Svd {
    /// m×k left singular vectors.
    pub u: Mat,
    /// k singular values, descending, non-negative.
    pub s: Vec<f32>,
    /// k×k right singular vectors (A = U diag(s) Vᵀ).
    pub v: Mat,
}

/// One-sided Jacobi SVD of a (m×k), m ≥ k. Sweeps until convergence or
/// `max_sweeps`.
pub fn jacobi_svd(a: &Mat) -> Svd {
    let (m, k) = (a.rows, a.cols);
    assert!(m >= k, "jacobi_svd expects tall input, got {m}x{k}");
    let mut b = a.clone();
    let mut v = Mat::eye(k);
    let max_sweeps = 30;
    let tol = 1e-10f64;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for i in 0..k {
            for j in (i + 1)..k {
                let mut alpha = 0.0f64;
                let mut beta = 0.0f64;
                let mut gamma = 0.0f64;
                for t in 0..m {
                    let bi = b[(t, i)] as f64;
                    let bj = b[(t, j)] as f64;
                    alpha += bi * bi;
                    beta += bj * bj;
                    gamma += bi * bj;
                }
                off = off.max(gamma.abs() / (alpha * beta).sqrt().max(1e-30));
                if gamma.abs() <= tol * (alpha * beta).sqrt() {
                    continue;
                }
                let zeta = (beta - alpha) / (2.0 * gamma);
                let sgn = if zeta >= 0.0 { 1.0 } else { -1.0 };
                let t_rot = sgn / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t_rot * t_rot).sqrt();
                let s = (c * t_rot) as f32;
                let c = c as f32;
                for t in 0..m {
                    let bi = b[(t, i)];
                    let bj = b[(t, j)];
                    b[(t, i)] = c * bi - s * bj;
                    b[(t, j)] = s * bi + c * bj;
                }
                for t in 0..k {
                    let vi = v[(t, i)];
                    let vj = v[(t, j)];
                    v[(t, i)] = c * vi - s * vj;
                    v[(t, j)] = s * vi + c * vj;
                }
            }
        }
        if off < 1e-9 {
            break;
        }
    }
    // Singular values = column norms; sort descending.
    let mut s: Vec<f32> = (0..k)
        .map(|j| {
            (0..m).map(|i| (b[(i, j)] as f64).powi(2)).sum::<f64>().sqrt()
                as f32
        })
        .collect();
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&x, &y| s[y].partial_cmp(&s[x]).unwrap());
    let mut u = Mat::zeros(m, k);
    let mut v_sorted = Mat::zeros(k, k);
    let s_sorted: Vec<f32> = order.iter().map(|&j| s[j]).collect();
    for (new_j, &old_j) in order.iter().enumerate() {
        let nrm = s[old_j].max(1e-30);
        for i in 0..m {
            u[(i, new_j)] = if s[old_j] > 1e-12 {
                b[(i, old_j)] / nrm
            } else {
                0.0
            };
        }
        for i in 0..k {
            v_sorted[(i, new_j)] = v[(i, old_j)];
        }
    }
    s = s_sorted;
    Svd { u, s, v: v_sorted }
}

/// Randomized range finder: orthonormal Q (m×r) ≈ top-r range of A, with
/// `iters` power iterations (mirrors `linalg_jnp.rand_range`).
pub fn rand_range(a: &Mat, r: usize, iters: usize, rng: &mut Rng) -> Mat {
    let omega = Mat::randn(rng, a.cols, r, 1.0);
    let mut q = householder_qr(&a.matmul(&omega)).q;
    for _ in 0..iters {
        let z = householder_qr(&a.t_matmul(&q)).q;
        q = householder_qr(&a.matmul(&z)).q;
    }
    q
}

/// Rank-r randomized SVD: A ≈ U diag(s) Vᵀ with U m×r, V n×r.
pub fn svd_lowrank(a: &Mat, r: usize, iters: usize, rng: &mut Rng) -> Svd {
    let q = rand_range(a, r, iters, rng);          // m×r
    let b = q.t_matmul(a);                          // r×n
    let bt = b.t();                                 // n×r
    let inner = jacobi_svd(&bt);                    // bᵀ = U₁ s V₁ᵀ ⇒ b = V₁ s U₁ᵀ
    Svd { u: q.matmul(&inner.v), s: inner.s, v: inner.u }
}

/// Energy ratio captured by the top-r singular values:
/// Σ_{i<r} σ_i² / ‖A‖_F² (paper Fig. 6a metric).
pub fn energy_ratio(s: &[f32], frob: f32, r: usize) -> f64 {
    let top: f64 = s.iter().take(r).map(|x| (*x as f64).powi(2)).sum();
    top / ((frob as f64).powi(2)).max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{dim, Prop};

    fn reconstruct(svd: &Svd) -> Mat {
        let k = svd.s.len();
        let mut us = svd.u.clone();
        for j in 0..k {
            for i in 0..us.rows {
                us[(i, j)] *= svd.s[j];
            }
        }
        us.matmul_t(&svd.v)
    }

    #[test]
    fn svd_reconstructs_fixed() {
        let mut rng = Rng::new(1);
        for (m, k) in [(8, 8), (40, 16), (64, 64), (33, 5)] {
            let a = Mat::randn(&mut rng, m, k, 1.0);
            let svd = jacobi_svd(&a);
            assert!(reconstruct(&svd).rel_err(&a) < 1e-4, "{m}x{k}");
            assert!(svd.u.t_matmul(&svd.u).rel_err(&Mat::eye(k)) < 1e-4);
            assert!(svd.v.t_matmul(&svd.v).rel_err(&Mat::eye(k)) < 1e-4);
            for w in svd.s.windows(2) {
                assert!(w[0] >= w[1] - 1e-5, "not sorted");
            }
        }
    }

    #[test]
    fn svd_property() {
        Prop::new(24).check("jacobi-svd", |rng| {
            let k = dim(rng, 20);
            let m = k + dim(rng, 30);
            let a = Mat::randn(rng, m, k, 1.0);
            let svd = jacobi_svd(&a);
            assert!(reconstruct(&svd).rel_err(&a) < 1e-3);
        });
    }

    #[test]
    fn singular_values_of_diagonal() {
        let a = Mat::from_fn(4, 4, |i, j| if i == j {
            [3.0, 1.0, 4.0, 2.0][i]
        } else {
            0.0
        });
        let svd = jacobi_svd(&a);
        let want = [4.0, 3.0, 2.0, 1.0];
        for (got, want) in svd.s.iter().zip(want) {
            assert!((got - want).abs() < 1e-5);
        }
    }

    #[test]
    fn lowrank_svd_exact_on_lowrank() {
        let mut rng = Rng::new(3);
        let (m, n, r) = (80, 60, 6);
        let a = Mat::randn(&mut rng, m, r, 1.0)
            .matmul(&Mat::randn(&mut rng, r, n, 1.0));
        let svd = svd_lowrank(&a, r, 2, &mut rng);
        let approx = {
            let mut us = svd.u.clone();
            for j in 0..r {
                for i in 0..m {
                    us[(i, j)] *= svd.s[j];
                }
            }
            us.matmul_t(&svd.v)
        };
        assert!(approx.rel_err(&a) < 1e-3);
    }

    #[test]
    fn energy_ratio_bounds() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(&mut rng, 30, 20, 1.0);
        let svd = jacobi_svd(&a);
        let frob = a.frob_norm();
        let r_full = energy_ratio(&svd.s, frob, 20);
        assert!((r_full - 1.0).abs() < 1e-3, "{r_full}");
        let r_half = energy_ratio(&svd.s, frob, 5);
        assert!(r_half > 0.0 && r_half < 1.0);
    }

    #[test]
    fn rand_range_orthogonal() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(&mut rng, 50, 40, 1.0);
        let q = rand_range(&a, 8, 2, &mut rng);
        assert_eq!((q.rows, q.cols), (50, 8));
        assert!(q.t_matmul(&q).rel_err(&Mat::eye(8)) < 1e-4);
    }
}

//! Native dense linear algebra substrate.
//!
//! Mirrors `python/compile/linalg_jnp.py` on the Rust side: the native
//! optimizer implementations (`optim::*`), the property tests, the
//! momentum spectral analysis (Fig. 6a), and the memory-model validation
//! all run on these routines — no BLAS/LAPACK available offline.
//!
//! The hot entry points come in two forms: allocating wrappers
//! (`householder_qr`, `jacobi_svd`, `svd_lowrank`) and `_into`/`_ws`
//! variants that stage every intermediate in a reusable
//! [`LinalgWorkspace`] so a whole optimizer step can run without heap
//! traffic. The frozen sequential baselines (`householder_qr_unblocked`,
//! `jacobi_svd_seq`) back the parity suite and `BENCH_svd.json`.

pub mod mat;
pub mod qr;
pub mod svd;
pub mod workspace;

pub use mat::Mat;
pub use qr::{
    householder_qr, householder_qr_into, householder_qr_unblocked,
    QrFactors, QR_PANEL,
};
pub use svd::{
    jacobi_svd, jacobi_svd_into, jacobi_svd_seq, rand_range, rand_range_ws,
    svd_lowrank, svd_lowrank_ws, Svd,
};
pub use workspace::{round_robin_schedule, LinalgWorkspace};

//! Native dense linear algebra substrate.
//!
//! Mirrors `python/compile/linalg_jnp.py` on the Rust side: the native
//! optimizer implementations (`optim::*`), the property tests, the
//! momentum spectral analysis (Fig. 6a), and the memory-model validation
//! all run on these routines — no BLAS/LAPACK available offline.

pub mod mat;
pub mod qr;
pub mod svd;

pub use mat::Mat;
pub use qr::{householder_qr, QrFactors};
pub use svd::{jacobi_svd, rand_range, svd_lowrank, Svd};

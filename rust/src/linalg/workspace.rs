//! Reusable scratch for the alloc-free linalg entry points.
//!
//! `householder_qr_into` and `jacobi_svd_into` stage every intermediate in
//! a caller-owned [`LinalgWorkspace`]. All buffers are (re)shaped with
//! [`Mat::reset`], which reuses capacity: after one warm-up call at a
//! given shape the steady state never touches the allocator — the same
//! contract the fusion plan arena provides for GEMMs, extended here to
//! the QR / core-SVD control flow a static graph cannot express. The
//! counting-allocator proof over a full `MoFaSgd::step` lives in
//! `rust/tests/fusion_alloc.rs`.

use super::Mat;

/// Grow-once scratch shared by the blocked QR and the parallel Jacobi SVD.
/// One workspace serves both (they never run concurrently within a step),
/// with disjoint field groups so a QR inside an SVD caller is still fine.
pub struct LinalgWorkspace {
    // -- blocked Householder QR --
    /// m×k working copy: R above the diagonal, unit-lower reflector
    /// columns below it (LAPACK `geqrf` storage).
    pub(crate) fac: Mat,
    /// (m−j0)×nb explicit unit-lower panel V for the block reflector.
    pub(crate) vpanel: Mat,
    /// nb×nb compact-WY T factor (H_{j0}···H_{j0+nb−1} = I − V·T·Vᵀ).
    pub(crate) tmat: Mat,
    /// nb×n staging for Vᵀ·C.
    pub(crate) w1: Mat,
    /// nb×n staging for T·(Vᵀ·C).
    pub(crate) w2: Mat,
    /// Contiguous copy of the trailing block C.
    pub(crate) cpanel: Mat,
    pub(crate) tau: Vec<f32>,
    // -- parallel round-robin Jacobi SVD --
    /// k_pad×m working transpose: rows are columns of the input, so the
    /// rotation inner loops stream contiguous memory.
    pub(crate) bt: Mat,
    /// k_pad×k_pad accumulated rotations, stored transposed like `bt`.
    pub(crate) vt: Mat,
    pub(crate) snorm: Vec<f64>,
    pub(crate) order: Vec<usize>,
    /// Round-robin schedules memoized per padded column count, flattened
    /// as (k−1)·(k/2) pairs. Never evicted — distinct k's per workspace
    /// are few (2r for the UMF core, r for the randomized-SVD inner SVD).
    pub(crate) scheds: Vec<(usize, Vec<(u32, u32)>)>,
}

impl LinalgWorkspace {
    pub fn new() -> LinalgWorkspace {
        LinalgWorkspace {
            fac: Mat::zeros(0, 0),
            vpanel: Mat::zeros(0, 0),
            tmat: Mat::zeros(0, 0),
            w1: Mat::zeros(0, 0),
            w2: Mat::zeros(0, 0),
            cpanel: Mat::zeros(0, 0),
            tau: Vec::new(),
            bt: Mat::zeros(0, 0),
            vt: Mat::zeros(0, 0),
            snorm: Vec::new(),
            order: Vec::new(),
            scheds: Vec::new(),
        }
    }

    /// Index into `scheds` for column count `k`, computing and memoizing
    /// the schedule on first request (the only allocating path — warm-up).
    pub(crate) fn schedule_pos(&mut self, k: usize) -> usize {
        if let Some(pos) = self.scheds.iter().position(|(kk, _)| *kk == k) {
            crate::obs::counter_add(crate::obs::Counter::SchedCacheHits, 1);
            return pos;
        }
        self.scheds.push((k, round_robin_schedule(k)));
        self.scheds.len() - 1
    }
}

impl Default for LinalgWorkspace {
    fn default() -> Self {
        LinalgWorkspace::new()
    }
}

/// Tournament pairings (circle method, element 0 fixed): k−1 rounds of
/// k/2 *disjoint* pairs covering every (i, j) pair exactly once per
/// sweep. Mirrors `python/compile/linalg_jnp._round_robin_schedule`;
/// returned flattened round-major, `k/2` pairs per round.
pub fn round_robin_schedule(k: usize) -> Vec<(u32, u32)> {
    assert!(k >= 2 && k % 2 == 0, "round-robin needs even k ≥ 2, got {k}");
    let half = k / 2;
    let mut players: Vec<u32> = (0..k as u32).collect();
    let mut pairs = Vec::with_capacity((k - 1) * half);
    for _ in 0..k - 1 {
        for i in 0..half {
            // left[i] = players[i], right[i] = players[k−1−i]
            pairs.push((players[i], players[k - 1 - i]));
        }
        // Rotate everyone but players[0] one slot clockwise.
        let last = players[k - 1];
        for idx in (2..k).rev() {
            players[idx] = players[idx - 1];
        }
        players[1] = last;
    }
    pairs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_covers_all_pairs_once_with_disjoint_rounds() {
        for k in [2usize, 4, 6, 8, 16, 34] {
            let half = k / 2;
            let sched = round_robin_schedule(k);
            assert_eq!(sched.len(), (k - 1) * half);
            let mut seen = vec![false; k * k];
            for round in 0..k - 1 {
                let mut used = vec![false; k];
                for &(p, q) in &sched[round * half..(round + 1) * half] {
                    let (p, q) = (p as usize, q as usize);
                    assert!(p != q && p < k && q < k);
                    // disjoint within the round
                    assert!(!used[p] && !used[q], "round {round} reuses");
                    used[p] = true;
                    used[q] = true;
                    let key = p.min(q) * k + p.max(q);
                    assert!(!seen[key], "pair ({p},{q}) repeated");
                    seen[key] = true;
                }
            }
            let covered = seen.iter().filter(|x| **x).count();
            assert_eq!(covered, k * (k - 1) / 2, "k={k} coverage");
        }
    }

    #[test]
    fn workspace_memoizes_schedules() {
        let mut ws = LinalgWorkspace::new();
        let a = ws.schedule_pos(8);
        let b = ws.schedule_pos(4);
        assert_eq!(ws.schedule_pos(8), a);
        assert_eq!(ws.schedule_pos(4), b);
        assert_eq!(ws.scheds.len(), 2);
    }
}

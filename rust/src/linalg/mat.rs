//! Row-major f32 matrix with cache-blocked matmul.

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize,
                   f: impl Fn(usize, usize) -> f32) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn randn(rng: &mut Rng, rows: usize, cols: usize, std: f32) -> Mat {
        Mat { rows, cols, data: rng.normal_vec(rows * cols, std) }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// C = A · B, ikj loop order (streaming over B rows — vectorizes well).
    ///
    /// Plain accumulation, no zero-skip: skipping `aik == 0.0` silently
    /// dropped NaN/Inf propagation (a zero row times a NaN column yielded
    /// 0, not NaN) and the unpredictable branch hurt dense throughput.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        let mut c = Mat::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            let arow = self.row(i);
            let crow = c.row_mut(i);
            for (k, &aik) in arow.iter().enumerate() {
                let brow = b.row(k);
                for (j, &bkj) in brow.iter().enumerate() {
                    crow[j] += aik * bkj;
                }
            }
        }
        c
    }

    /// C = Aᵀ · B without materializing Aᵀ. Plain accumulation (see
    /// [`Mat::matmul`] on why there is no zero-skip).
    pub fn t_matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows, "t_matmul shape mismatch");
        let mut c = Mat::zeros(self.cols, b.cols);
        for k in 0..self.rows {
            let arow = self.row(k);
            let brow = b.row(k);
            for (i, &aki) in arow.iter().enumerate() {
                let crow = c.row_mut(i);
                for (j, &bkj) in brow.iter().enumerate() {
                    crow[j] += aki * bkj;
                }
            }
        }
        c
    }

    /// C = A · Bᵀ without materializing Bᵀ.
    pub fn matmul_t(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "matmul_t shape mismatch");
        let mut c = Mat::zeros(self.rows, b.rows);
        for i in 0..self.rows {
            let arow = self.row(i);
            for jb in 0..b.rows {
                let brow = b.row(jb);
                let mut acc = 0.0f32;
                for k in 0..self.cols {
                    acc += arow[k] * brow[k];
                }
                c[(i, jb)] = acc;
            }
        }
        c
    }

    pub fn add(&self, b: &Mat) -> Mat {
        self.zip(b, |x, y| x + y)
    }

    pub fn sub(&self, b: &Mat) -> Mat {
        self.zip(b, |x, y| x - y)
    }

    pub fn scale(&self, a: f32) -> Mat {
        self.map(|x| x * a)
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip(&self, b: &Mat, f: impl Fn(f32, f32) -> f32) -> Mat {
        assert_eq!((self.rows, self.cols), (b.rows, b.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&b.data)
                .map(|(&x, &y)| f(x, y))
                .collect(),
        }
    }

    /// In-place a·self + b·other (hot-loop accumulation without allocs).
    pub fn axpy_inplace(&mut self, a: f32, b: f32, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, &y) in self.data.iter_mut().zip(&other.data) {
            *x = a * *x + b * y;
        }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>()
            .sqrt() as f32
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    }

    /// ‖A − B‖_F / max(‖B‖_F, eps) — relative error for tests.
    pub fn rel_err(&self, b: &Mat) -> f32 {
        self.sub(b).frob_norm() / b.frob_norm().max(1e-12)
    }

    /// Horizontal concatenation [self  b].
    pub fn hcat(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows);
        let mut out = Mat::zeros(self.rows, self.cols + b.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(b.row(i));
        }
        out
    }

    /// Columns [j0, j1) as a new matrix.
    pub fn slice_cols(&self, j0: usize, j1: usize) -> Mat {
        assert!(j0 <= j1 && j1 <= self.cols);
        let mut out = Mat::zeros(self.rows, j1 - j0);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[j0..j1]);
        }
        out
    }

    /// Reshape in place to a zero-filled rows×cols, reusing the existing
    /// buffer: once capacity covers the shape, this never touches the
    /// allocator — the contract the alloc-free linalg workspace rests on.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// `self = src[:, j0..j1]`, reusing self's storage (alloc-free once
    /// warm; the in-place counterpart of [`Mat::slice_cols`]).
    pub fn copy_cols_from(&mut self, src: &Mat, j0: usize, j1: usize) {
        assert!(j0 <= j1 && j1 <= src.cols);
        self.reset(src.rows, j1 - j0);
        for i in 0..src.rows {
            self.row_mut(i).copy_from_slice(&src.row(i)[j0..j1]);
        }
    }

    /// `self = [a  b]`, reusing self's storage (in-place [`Mat::hcat`]).
    pub fn hcat_into(&mut self, a: &Mat, b: &Mat) {
        assert_eq!(a.rows, b.rows, "hcat_into row mismatch");
        self.reset(a.rows, a.cols + b.cols);
        let ac = a.cols;
        for i in 0..a.rows {
            let row = self.row_mut(i);
            row[..ac].copy_from_slice(a.row(i));
            row[ac..].copy_from_slice(b.row(i));
        }
    }

    /// `self = [a  bᵀ]` without materializing the transpose — the
    /// augmented-panel form QR([V  (UᵀG)ᵀ]) consumes.
    pub fn hcat_t_into(&mut self, a: &Mat, b: &Mat) {
        assert_eq!(a.rows, b.cols, "hcat_t_into shape mismatch");
        self.reset(a.rows, a.cols + b.rows);
        let ac = a.cols;
        for i in 0..a.rows {
            for j in 0..b.rows {
                self[(i, ac + j)] = b[(j, i)];
            }
            self.row_mut(i)[..ac].copy_from_slice(a.row(i));
        }
    }

    /// `self = [a  s·b]` in place — lets the §5.5 buffered step fold the
    /// gradient-mean `1/count` into panel assembly instead of allocating
    /// a scaled copy. `s == 1.0` takes the exact [`Mat::hcat_into`] copy
    /// path, so the unscaled callers are bit-identical.
    pub fn hcat_into_scaled(&mut self, a: &Mat, b: &Mat, s: f32) {
        if s == 1.0 {
            self.hcat_into(a, b);
            return;
        }
        assert_eq!(a.rows, b.rows, "hcat_into row mismatch");
        self.reset(a.rows, a.cols + b.cols);
        let ac = a.cols;
        for i in 0..a.rows {
            let row = self.row_mut(i);
            row[..ac].copy_from_slice(a.row(i));
            for (d, &x) in row[ac..].iter_mut().zip(b.row(i)) {
                *d = s * x;
            }
        }
    }

    /// `self = [a  s·bᵀ]` in place (scaled [`Mat::hcat_t_into`]).
    pub fn hcat_t_into_scaled(&mut self, a: &Mat, b: &Mat, s: f32) {
        if s == 1.0 {
            self.hcat_t_into(a, b);
            return;
        }
        assert_eq!(a.rows, b.cols, "hcat_t_into shape mismatch");
        self.reset(a.rows, a.cols + b.rows);
        let ac = a.cols;
        for i in 0..a.rows {
            for j in 0..b.rows {
                self[(i, ac + j)] = s * b[(j, i)];
            }
            self.row_mut(i)[..ac].copy_from_slice(a.row(i));
        }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{dim, Prop};

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(&mut rng, 7, 5, 1.0);
        assert!(a.matmul(&Mat::eye(5)).rel_err(&a) < 1e-6);
        assert!(Mat::eye(7).matmul(&a).rel_err(&a) < 1e-6);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_variants_agree() {
        Prop::new(24).check("t-matmul-agree", |rng| {
            let (m, k, n) = (dim(rng, 20), dim(rng, 20), dim(rng, 20));
            let a = Mat::randn(rng, k, m, 1.0);
            let b = Mat::randn(rng, k, n, 1.0);
            let fast = a.t_matmul(&b);
            let slow = a.t().matmul(&b);
            assert!(fast.rel_err(&slow) < 1e-5);
            let c = Mat::randn(rng, m, k, 1.0);
            let d = Mat::randn(rng, n, k, 1.0);
            assert!(c.matmul_t(&d).rel_err(&c.matmul(&d.t())) < 1e-5);
        });
    }

    #[test]
    fn matmul_associativity() {
        Prop::new(16).check("assoc", |rng| {
            let (m, k, l, n) =
                (dim(rng, 12), dim(rng, 12), dim(rng, 12), dim(rng, 12));
            let a = Mat::randn(rng, m, k, 1.0);
            let b = Mat::randn(rng, k, l, 1.0);
            let c = Mat::randn(rng, l, n, 1.0);
            let left = a.matmul(&b).matmul(&c);
            let right = a.matmul(&b.matmul(&c));
            assert!(left.rel_err(&right) < 1e-4);
        });
    }

    #[test]
    fn matmul_propagates_nan_through_zero_rows() {
        // Regression: the old `aik == 0.0` skip turned 0·NaN into 0.
        let a = Mat::from_vec(1, 2, vec![0.0, 0.0]);
        let b = Mat::from_vec(2, 1, vec![f32::NAN, 1.0]);
        assert!(a.matmul(&b).data[0].is_nan());
        let at = Mat::from_vec(2, 1, vec![0.0, 0.0]);
        assert!(at.t_matmul(&b).data[0].is_nan());
    }

    #[test]
    fn hcat_slice_roundtrip() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(&mut rng, 6, 3, 1.0);
        let b = Mat::randn(&mut rng, 6, 4, 1.0);
        let c = a.hcat(&b);
        assert_eq!(c.slice_cols(0, 3), a);
        assert_eq!(c.slice_cols(3, 7), b);
    }

    #[test]
    fn inplace_helpers_match_allocating_forms() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(&mut rng, 6, 3, 1.0);
        let b = Mat::randn(&mut rng, 6, 4, 1.0);
        let mut out = Mat::zeros(1, 1);
        out.hcat_into(&a, &b);
        assert_eq!(out, a.hcat(&b));
        out.hcat_t_into(&a, &b.t());
        assert_eq!(out, a.hcat(&b));
        out.copy_cols_from(&b, 1, 3);
        assert_eq!(out, b.slice_cols(1, 3));
        // scaled variants: s = 1 is the exact copy path, s ≠ 1 scales
        // only the second operand
        out.hcat_into_scaled(&a, &b, 1.0);
        assert_eq!(out, a.hcat(&b));
        out.hcat_into_scaled(&a, &b, 0.5);
        assert_eq!(out, a.hcat(&b.scale(0.5)));
        out.hcat_t_into_scaled(&a, &b.t(), 0.5);
        assert_eq!(out, a.hcat(&b.scale(0.5)));
        // reset reuses capacity and zero-fills
        let cap = out.data.capacity();
        out.reset(2, 2);
        assert_eq!(out, Mat::zeros(2, 2));
        assert!(out.data.capacity() >= cap.min(4));
    }

    #[test]
    fn axpy_matches_functional() {
        let mut rng = Rng::new(3);
        let mut a = Mat::randn(&mut rng, 5, 5, 1.0);
        let b = Mat::randn(&mut rng, 5, 5, 1.0);
        let want = a.scale(0.9).add(&b.scale(0.1));
        a.axpy_inplace(0.9, 0.1, &b);
        assert!(a.rel_err(&want) < 1e-6);
    }

    #[test]
    fn frob_norm_known() {
        let a = Mat::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-6);
    }
}

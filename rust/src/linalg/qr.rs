//! Householder QR (thin) — numerically robust panel factorization.
//!
//! Used by the native MoFaSGD implementation for QR([U  GV]) / QR([V  GᵀU])
//! (paper Alg. 1) and by the randomized range finder.
//!
//! Two paths:
//! * [`householder_qr_into`] — blocked compact-WY factorization writing
//!   into caller-provided outputs and a reusable [`LinalgWorkspace`]:
//!   panels of [`QR_PANEL`] columns are factored sequentially, then the
//!   trailing block and the Q backsolve run as three GEMMs per panel
//!   through the parallel `fusion::kernels`. Zero steady-state heap
//!   allocations once the workspace is warm.
//! * [`householder_qr_unblocked`] — the frozen pre-refactor sequential
//!   reflector-at-a-time loop, retained as the parity / benchmark
//!   baseline (`rust/tests/linalg_parity.rs`, `BENCH_svd.json`).

use super::{LinalgWorkspace, Mat};
use crate::fusion::kernels;
use crate::fusion::MatKind;
use crate::obs;

/// Panel width for the blocked factorization (LAPACK-style nb).
pub const QR_PANEL: usize = 32;

pub struct QrFactors {
    /// m×k with orthonormal columns.
    pub q: Mat,
    /// k×k upper triangular.
    pub r: Mat,
}

/// Thin QR of a (m×k), m ≥ k — blocked path, allocating convenience
/// wrapper over [`householder_qr_into`].
pub fn householder_qr(a: &Mat) -> QrFactors {
    let mut ws = LinalgWorkspace::new();
    let mut q = Mat::zeros(0, 0);
    let mut r = Mat::zeros(0, 0);
    householder_qr_into(a, &mut q, &mut r, &mut ws);
    QrFactors { q, r }
}

/// Rebuild the explicit unit-lower panel V and its compact-WY T factor
/// for panel [j0, j0+jb) from the packed reflectors in `fac` (standard
/// `larft` forward/columnwise recurrence). Recomputed in the backward Q
/// pass instead of stored — O(m·nb²) per panel, cheaper than a k×nb
/// side buffer and still alloc-free.
fn build_panel(fac: &Mat, tau: &[f32], j0: usize, jb: usize, m: usize,
               vpanel: &mut Mat, tmat: &mut Mat) {
    let mp = m - j0;
    vpanel.reset(mp, jb);
    for jj in 0..jb {
        vpanel[(jj, jj)] = 1.0;
        for i in (jj + 1)..mp {
            vpanel[(i, jj)] = fac[(j0 + i, j0 + jj)];
        }
    }
    tmat.reset(jb, jb);
    let mut z = [0.0f64; QR_PANEL];
    for jj in 0..jb {
        let t_jj = tau[j0 + jj];
        tmat[(jj, jj)] = t_jj;
        if t_jj == 0.0 || jj == 0 {
            continue;
        }
        // z = V[:, 0..jj]ᵀ · v_jj (v_jj is zero above its unit entry).
        for i in 0..jj {
            let mut acc = 0.0f64;
            for t in jj..mp {
                acc += vpanel[(t, i)] as f64 * vpanel[(t, jj)] as f64;
            }
            z[i] = acc;
        }
        // T[0..jj, jj] = −τ_jj · T[0..jj, 0..jj] · z
        for i in 0..jj {
            let mut acc = 0.0f64;
            for l in i..jj {
                acc += tmat[(i, l)] as f64 * z[l];
            }
            tmat[(i, jj)] = (-(t_jj as f64) * acc) as f32;
        }
    }
}

/// Thin QR of a (m×k), m ≥ k, blocked Householder with compact-WY panel
/// updates. Writes Q (m×k) and R (k×k) into the caller's matrices and
/// stages everything else in `ws` — allocation-free once `ws` and the
/// outputs have seen the shape.
pub fn householder_qr_into(a: &Mat, q: &mut Mat, r: &mut Mat,
                           ws: &mut LinalgWorkspace) {
    let (m, k) = (a.rows, a.cols);
    assert!(m >= k, "householder_qr expects tall input, got {m}x{k}");
    let _sp = obs::span_args(obs::Category::Linalg, "householder_qr",
                             [m as u32, k as u32, 0]);
    let nb = QR_PANEL.min(k).max(1);
    let wk = crate::fusion::workers();
    let LinalgWorkspace { fac, vpanel, tmat, w1, w2, cpanel, tau, .. } = ws;
    fac.reset(m, k);
    fac.data.copy_from_slice(&a.data);
    tau.clear();
    tau.resize(k, 0.0);

    // Forward pass: factor each panel, then block-update the trailing
    // columns C ← (I − V·Tᵀ·Vᵀ)·C (creation order applies the transposed
    // block reflector).
    let n_panels = k.div_ceil(nb);
    for p in 0..n_panels {
        let _pp = obs::span_args(obs::Category::Linalg, "qr_panel",
                                 [m as u32, k as u32, p as u32]);
        let j0 = p * nb;
        let jb = nb.min(k - j0);
        let mp = m - j0;
        // 1. Householder-factor the panel columns (sequential, f64 dots).
        for jj in 0..jb {
            let j = j0 + jj;
            let mut nrm2 = 0.0f64;
            for i in j..m {
                nrm2 += (fac[(i, j)] as f64).powi(2);
            }
            let normx = nrm2.sqrt();
            if normx < 1e-20 {
                // Numerically zero column below the diagonal: identity
                // reflector (τ = 0 ⇒ T column is zero, block skips it).
                tau[j] = 0.0;
                for i in (j + 1)..m {
                    fac[(i, j)] = 0.0;
                }
                continue;
            }
            let x0 = fac[(j, j)] as f64;
            let alpha = if x0 >= 0.0 { -normx } else { normx };
            let v0 = x0 - alpha;
            // H = I − τ·wwᵀ with w = v/v₀ (unit first entry), τ = −v₀/α.
            tau[j] = (-v0 / alpha) as f32;
            let inv_v0 = 1.0 / v0;
            for i in (j + 1)..m {
                fac[(i, j)] = (fac[(i, j)] as f64 * inv_v0) as f32;
            }
            fac[(j, j)] = alpha as f32;
            // Apply H to the rest of the panel.
            for c in (j + 1)..(j0 + jb) {
                let mut dot = fac[(j, c)] as f64;
                for i in (j + 1)..m {
                    dot += fac[(i, j)] as f64 * fac[(i, c)] as f64;
                }
                let coeff = tau[j] as f64 * dot;
                fac[(j, c)] = (fac[(j, c)] as f64 - coeff) as f32;
                for i in (j + 1)..m {
                    let w = fac[(i, j)] as f64;
                    fac[(i, c)] = (fac[(i, c)] as f64 - coeff * w) as f32;
                }
            }
        }
        // 2. Blocked trailing update through the parallel GEMM kernels.
        let nc = k - j0 - jb;
        if nc > 0 {
            build_panel(fac, tau, j0, jb, m, vpanel, tmat);
            cpanel.reset(mp, nc);
            for i in 0..mp {
                cpanel.row_mut(i)
                      .copy_from_slice(&fac.row(j0 + i)[j0 + jb..k]);
            }
            w1.reset(jb, nc);
            kernels::gemm(MatKind::TN, jb, nc, mp, &vpanel.data,
                          &cpanel.data, 1.0, 0.0, &mut w1.data, &[], wk);
            w2.reset(jb, nc);
            kernels::gemm(MatKind::TN, jb, nc, jb, &tmat.data, &w1.data,
                          1.0, 0.0, &mut w2.data, &[], wk);
            kernels::gemm(MatKind::NN, mp, nc, jb, &vpanel.data, &w2.data,
                          -1.0, 1.0, &mut cpanel.data, &[], wk);
            for i in 0..mp {
                fac.row_mut(j0 + i)[j0 + jb..k]
                   .copy_from_slice(cpanel.row(i));
            }
        }
    }

    // R = top k×k upper triangle of the reduced matrix.
    r.reset(k, k);
    for i in 0..k {
        for j in i..k {
            r[(i, j)] = fac[(i, j)];
        }
    }

    // Q = (I − V₀T₀V₀ᵀ)(I − V₁T₁V₁ᵀ)···[I_k; 0], applied backward so each
    // panel is one Vᵀ·Q / T·X / Q −= V·X GEMM triple on the live rows.
    q.reset(m, k);
    for i in 0..k {
        q[(i, i)] = 1.0;
    }
    for p in (0..n_panels).rev() {
        let _pp = obs::span_args(obs::Category::Linalg, "qr_q_panel",
                                 [m as u32, k as u32, p as u32]);
        let j0 = p * nb;
        let jb = nb.min(k - j0);
        let mp = m - j0;
        build_panel(fac, tau, j0, jb, m, vpanel, tmat);
        w1.reset(jb, k);
        kernels::gemm(MatKind::TN, jb, k, mp, &vpanel.data,
                      &q.data[j0 * k..], 1.0, 0.0, &mut w1.data, &[], wk);
        w2.reset(jb, k);
        kernels::gemm(MatKind::NN, jb, k, jb, &tmat.data, &w1.data, 1.0,
                      0.0, &mut w2.data, &[], wk);
        kernels::gemm(MatKind::NN, mp, k, jb, &vpanel.data, &w2.data, -1.0,
                      1.0, &mut q.data[j0 * k..], &[], wk);
    }

    // Sign-fix: make R's diagonal non-negative (canonical form).
    for j in 0..k {
        if r[(j, j)] < 0.0 {
            for c in j..k {
                r[(j, c)] = -r[(j, c)];
            }
            for i in 0..m {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }
}

/// Frozen pre-refactor sequential path: one reflector at a time, applied
/// with f64 dots, allocation per call. Baseline for the blocked-vs-old
/// parity tests and the `BENCH_svd.json` QR speedup measurement.
pub fn householder_qr_unblocked(a: &Mat) -> QrFactors {
    let (m, k) = (a.rows, a.cols);
    assert!(m >= k, "householder_qr expects tall input, got {m}x{k}");
    let mut r_full = a.clone(); // will be reduced in place
    // Store reflectors v_j in the lower part plus separate betas.
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(k);
    for j in 0..k {
        // Build the reflector for column j below the diagonal.
        let mut v: Vec<f32> = (j..m).map(|i| r_full[(i, j)]).collect();
        let alpha = {
            let norm = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>()
                .sqrt() as f32;
            if v[0] >= 0.0 { -norm } else { norm }
        };
        if alpha.abs() < 1e-20 {
            // Zero column below diagonal — identity reflector.
            vs.push(vec![0.0; m - j]);
            continue;
        }
        v[0] -= alpha;
        let vnorm2 = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>() as f32;
        if vnorm2 < 1e-30 {
            vs.push(vec![0.0; m - j]);
            continue;
        }
        // Apply H = I − 2vvᵀ/(vᵀv) to the trailing block of R.
        for col in j..k {
            let mut dot = 0.0f64;
            for (t, &vt) in v.iter().enumerate() {
                dot += vt as f64 * r_full[(j + t, col)] as f64;
            }
            let coeff = (2.0 * dot / vnorm2 as f64) as f32;
            for (t, &vt) in v.iter().enumerate() {
                r_full[(j + t, col)] -= coeff * vt;
            }
        }
        vs.push(v);
    }
    // R = top k×k of the reduced matrix.
    let mut r = Mat::zeros(k, k);
    for i in 0..k {
        for j in i..k {
            r[(i, j)] = r_full[(i, j)];
        }
    }
    // Q = H_0 H_1 … H_{k-1} · [I_k; 0] — apply reflectors in reverse to the
    // identity embedding.
    let mut q = Mat::zeros(m, k);
    for i in 0..k {
        q[(i, i)] = 1.0;
    }
    for j in (0..k).rev() {
        let v = &vs[j];
        let vnorm2 = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>() as f32;
        if vnorm2 < 1e-30 {
            continue;
        }
        for col in 0..k {
            let mut dot = 0.0f64;
            for (t, &vt) in v.iter().enumerate() {
                dot += vt as f64 * q[(j + t, col)] as f64;
            }
            let coeff = (2.0 * dot / vnorm2 as f64) as f32;
            for (t, &vt) in v.iter().enumerate() {
                q[(j + t, col)] -= coeff * vt;
            }
        }
    }
    // Sign-fix: make R's diagonal non-negative (canonical form).
    for j in 0..k {
        if r[(j, j)] < 0.0 {
            for c in j..k {
                r[(j, c)] = -r[(j, c)];
            }
            for i in 0..m {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }
    QrFactors { q, r }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{dim, Prop};
    use crate::util::rng::Rng;

    fn check_qr(a: &Mat, tol: f32) {
        let QrFactors { q, r } = householder_qr(a);
        assert!(q.matmul(&r).rel_err(a) < tol, "reconstruction");
        let qtq = q.t_matmul(&q);
        assert!(qtq.rel_err(&Mat::eye(a.cols)) < tol, "orthogonality");
        for i in 0..a.cols {
            for j in 0..i {
                assert!(r[(i, j)].abs() < 1e-5, "R not triangular");
            }
            assert!(r[(i, i)] >= 0.0, "R diagonal sign");
        }
    }

    #[test]
    fn qr_fixed_shapes() {
        let mut rng = Rng::new(1);
        for (m, k) in [(8, 8), (64, 16), (256, 64), (33, 5), (4, 1)] {
            check_qr(&Mat::randn(&mut rng, m, k, 1.0), 1e-4);
        }
    }

    #[test]
    fn qr_property_random_shapes() {
        Prop::new(32).check("qr", |rng| {
            let k = dim(rng, 24);
            let m = k + dim(rng, 40);
            check_qr(&Mat::randn(rng, m, k, 1.0), 1e-4);
        });
    }

    #[test]
    fn qr_crosses_panel_boundaries() {
        // Shapes straddling QR_PANEL exercise the block trailing update
        // and the multi-panel Q backsolve.
        let mut rng = Rng::new(7);
        for (m, k) in [(96, 48), (130, 65), (64, 33), (256, 96)] {
            check_qr(&Mat::randn(&mut rng, m, k, 1.0), 1e-4);
        }
    }

    #[test]
    fn qr_into_reuses_workspace_and_outputs() {
        let mut rng = Rng::new(8);
        let mut ws = LinalgWorkspace::new();
        let mut q = Mat::zeros(0, 0);
        let mut r = Mat::zeros(0, 0);
        for _ in 0..3 {
            let a = Mat::randn(&mut rng, 80, 40, 1.0);
            householder_qr_into(&a, &mut q, &mut r, &mut ws);
            assert!(q.matmul(&r).rel_err(&a) < 1e-4);
            assert!(q.t_matmul(&q).rel_err(&Mat::eye(40)) < 1e-4);
        }
    }

    #[test]
    fn qr_rank_deficient_reconstructs() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(&mut rng, 40, 3, 1.0);
        let dup = a.hcat(&a.slice_cols(0, 2)); // duplicated columns
        let QrFactors { q, r } = householder_qr(&dup);
        assert!(q.matmul(&r).rel_err(&dup) < 1e-4);
        assert!(!q.data.iter().any(|x| x.is_nan()));
    }

    #[test]
    fn qr_already_orthogonal() {
        let e = Mat::eye(10);
        let QrFactors { q, r } = householder_qr(&e.slice_cols(0, 4));
        assert!(q.rel_err(&e.slice_cols(0, 4)) < 1e-5);
        assert!(r.rel_err(&Mat::eye(4)) < 1e-5);
    }
}

//! Householder QR (thin) — numerically robust panel factorization.
//!
//! Used by the native MoFaSGD implementation for QR([U  GV]) / QR([V  GᵀU])
//! (paper Alg. 1) and by the randomized range finder.

use super::Mat;

pub struct QrFactors {
    /// m×k with orthonormal columns.
    pub q: Mat,
    /// k×k upper triangular.
    pub r: Mat,
}

/// Thin QR of a (m×k), m ≥ k, via Householder reflections.
pub fn householder_qr(a: &Mat) -> QrFactors {
    let (m, k) = (a.rows, a.cols);
    assert!(m >= k, "householder_qr expects tall input, got {m}x{k}");
    let mut r_full = a.clone(); // will be reduced in place
    // Store reflectors v_j in the lower part plus separate betas.
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(k);
    for j in 0..k {
        // Build the reflector for column j below the diagonal.
        let mut v: Vec<f32> = (j..m).map(|i| r_full[(i, j)]).collect();
        let alpha = {
            let norm = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>()
                .sqrt() as f32;
            if v[0] >= 0.0 { -norm } else { norm }
        };
        if alpha.abs() < 1e-20 {
            // Zero column below diagonal — identity reflector.
            vs.push(vec![0.0; m - j]);
            continue;
        }
        v[0] -= alpha;
        let vnorm2 = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>() as f32;
        if vnorm2 < 1e-30 {
            vs.push(vec![0.0; m - j]);
            continue;
        }
        // Apply H = I − 2vvᵀ/(vᵀv) to the trailing block of R.
        for col in j..k {
            let mut dot = 0.0f64;
            for (t, &vt) in v.iter().enumerate() {
                dot += vt as f64 * r_full[(j + t, col)] as f64;
            }
            let coeff = (2.0 * dot / vnorm2 as f64) as f32;
            for (t, &vt) in v.iter().enumerate() {
                r_full[(j + t, col)] -= coeff * vt;
            }
        }
        vs.push(v);
    }
    // R = top k×k of the reduced matrix.
    let mut r = Mat::zeros(k, k);
    for i in 0..k {
        for j in i..k {
            r[(i, j)] = r_full[(i, j)];
        }
    }
    // Q = H_0 H_1 … H_{k-1} · [I_k; 0] — apply reflectors in reverse to the
    // identity embedding.
    let mut q = Mat::zeros(m, k);
    for i in 0..k {
        q[(i, i)] = 1.0;
    }
    for j in (0..k).rev() {
        let v = &vs[j];
        let vnorm2 = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>() as f32;
        if vnorm2 < 1e-30 {
            continue;
        }
        for col in 0..k {
            let mut dot = 0.0f64;
            for (t, &vt) in v.iter().enumerate() {
                dot += vt as f64 * q[(j + t, col)] as f64;
            }
            let coeff = (2.0 * dot / vnorm2 as f64) as f32;
            for (t, &vt) in v.iter().enumerate() {
                q[(j + t, col)] -= coeff * vt;
            }
        }
    }
    // Sign-fix: make R's diagonal non-negative (canonical form).
    for j in 0..k {
        if r[(j, j)] < 0.0 {
            for c in j..k {
                r[(j, c)] = -r[(j, c)];
            }
            for i in 0..m {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }
    QrFactors { q, r }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{dim, Prop};
    use crate::util::rng::Rng;

    fn check_qr(a: &Mat, tol: f32) {
        let QrFactors { q, r } = householder_qr(a);
        assert!(q.matmul(&r).rel_err(a) < tol, "reconstruction");
        let qtq = q.t_matmul(&q);
        assert!(qtq.rel_err(&Mat::eye(a.cols)) < tol, "orthogonality");
        for i in 0..a.cols {
            for j in 0..i {
                assert!(r[(i, j)].abs() < 1e-5, "R not triangular");
            }
            assert!(r[(i, i)] >= 0.0, "R diagonal sign");
        }
    }

    #[test]
    fn qr_fixed_shapes() {
        let mut rng = Rng::new(1);
        for (m, k) in [(8, 8), (64, 16), (256, 64), (33, 5), (4, 1)] {
            check_qr(&Mat::randn(&mut rng, m, k, 1.0), 1e-4);
        }
    }

    #[test]
    fn qr_property_random_shapes() {
        Prop::new(32).check("qr", |rng| {
            let k = dim(rng, 24);
            let m = k + dim(rng, 40);
            check_qr(&Mat::randn(rng, m, k, 1.0), 1e-4);
        });
    }

    #[test]
    fn qr_rank_deficient_reconstructs() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(&mut rng, 40, 3, 1.0);
        let dup = a.hcat(&a.slice_cols(0, 2)); // duplicated columns
        let QrFactors { q, r } = householder_qr(&dup);
        assert!(q.matmul(&r).rel_err(&dup) < 1e-4);
        assert!(!q.data.iter().any(|x| x.is_nan()));
    }

    #[test]
    fn qr_already_orthogonal() {
        let e = Mat::eye(10);
        let QrFactors { q, r } = householder_qr(&e.slice_cols(0, 4));
        assert!(q.rel_err(&e.slice_cols(0, 4)) < 1e-5);
        assert!(r.rel_err(&Mat::eye(4)) < 1e-5);
    }
}

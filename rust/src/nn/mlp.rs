//! Two-layer tanh MLP with hand-derived gradients.
//!
//! Small enough to train on one core in milliseconds, matrix-shaped enough
//! to exercise every `MatrixOptimizer` exactly like a transformer linear.
//! Used by closed-loop optimizer tests and by `spectral::run_analysis`
//! (AdamW first-moment snapshots, paper Fig. 6a).

use crate::linalg::Mat;
use crate::util::rng::Rng;

pub struct Mlp {
    /// Input→hidden (d_in × d_hidden).
    pub w1: Mat,
    /// Hidden→output (d_hidden × d_out).
    pub w2: Mat,
}

pub struct MlpGrads {
    pub g1: Mat,
    pub g2: Mat,
}

impl Mlp {
    pub fn new(d_in: usize, d_hidden: usize, d_out: usize,
               rng: &mut Rng) -> Mlp {
        Mlp {
            w1: Mat::randn(rng, d_in, d_hidden,
                           1.0 / (d_in as f32).sqrt()),
            w2: Mat::randn(rng, d_hidden, d_out,
                           1.0 / (d_hidden as f32).sqrt()),
        }
    }

    pub fn forward(&self, x: &Mat) -> Mat {
        let h = x.matmul(&self.w1).map(|z| z.tanh());
        h.matmul(&self.w2)
    }

    /// MSE loss ½‖ŷ − y‖²/B and gradients w.r.t. both weight matrices.
    pub fn loss_and_grads(&self, x: &Mat, y: &Mat) -> (f32, MlpGrads) {
        let b = x.rows as f32;
        let pre = x.matmul(&self.w1);
        let h = pre.map(|z| z.tanh());
        let yhat = h.matmul(&self.w2);
        let err = yhat.sub(y);
        let loss = 0.5 * (err.frob_norm().powi(2)) / b;
        // dL/dyhat = err / B
        let dy = err.scale(1.0 / b);
        let g2 = h.t_matmul(&dy);
        let dh = dy.matmul_t(&self.w2);
        let dpre = dh.zip(&h, |d, hv| d * (1.0 - hv * hv));
        let g1 = x.t_matmul(&dpre);
        (loss, MlpGrads { g1, g2 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{AdamW, MatrixOptimizer, MoFaSgd};

    #[test]
    fn gradients_match_finite_difference() {
        let mut rng = Rng::new(1);
        let mut net = Mlp::new(5, 7, 3, &mut rng);
        let x = Mat::randn(&mut rng, 4, 5, 1.0);
        let y = Mat::randn(&mut rng, 4, 3, 1.0);
        let (_, grads) = net.loss_and_grads(&x, &y);
        let eps = 1e-3f32;
        for _ in 0..6 {
            let (i, j) = (rng.below(5), rng.below(7));
            let base = net.loss_and_grads(&x, &y).0 as f64;
            net.w1[(i, j)] += eps;
            let plus = net.loss_and_grads(&x, &y).0 as f64;
            net.w1[(i, j)] -= eps;
            let fd = (plus - base) / eps as f64;
            let an = grads.g1[(i, j)] as f64;
            assert!((fd - an).abs() < 0.02 * an.abs().max(0.05),
                    "w1[{i},{j}] fd {fd} vs {an}");
        }
    }

    #[test]
    fn trains_to_low_loss_with_adamw() {
        let mut rng = Rng::new(2);
        let mut net = Mlp::new(8, 16, 2, &mut rng);
        let teacher = Mlp::new(8, 16, 2, &mut rng);
        let x = Mat::randn(&mut rng, 64, 8, 1.0);
        let y = teacher.forward(&x);
        let mut o1 = AdamW::new(8, 16, 0.9, 0.999, 0.0);
        let mut o2 = AdamW::new(16, 2, 0.9, 0.999, 0.0);
        let first = net.loss_and_grads(&x, &y).0;
        let mut last = first;
        for _ in 0..300 {
            let (l, g) = net.loss_and_grads(&x, &y);
            o1.step(&mut net.w1, &g.g1, 0.01);
            o2.step(&mut net.w2, &g.g2, 0.01);
            last = l;
        }
        assert!(last < 0.05 * first, "{first} -> {last}");
    }

    #[test]
    fn trains_with_native_mofasgd() {
        let mut rng = Rng::new(3);
        let mut net = Mlp::new(16, 24, 8, &mut rng);
        let teacher = Mlp::new(16, 24, 8, &mut rng);
        let x = Mat::randn(&mut rng, 64, 16, 1.0);
        let y = teacher.forward(&x);
        let mut o1 = MoFaSgd::new(16, 24, 4, 0.9);
        let mut o2 = MoFaSgd::new(24, 8, 4, 0.9);
        let first = net.loss_and_grads(&x, &y).0;
        let mut last = first;
        for _ in 0..300 {
            let (l, g) = net.loss_and_grads(&x, &y);
            o1.step(&mut net.w1, &g.g1, 0.005);
            o2.step(&mut net.w2, &g.g2, 0.005);
            last = l;
        }
        assert!(last < 0.5 * first, "{first} -> {last}");
    }
}

//! Native neural nets with manual backprop — closed-loop optimizer tests
//! and the spectral analysis (Fig. 6a) run here without PJRT.

pub mod mlp;

pub use mlp::Mlp;

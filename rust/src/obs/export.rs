//! Trace exporters: Chrome trace-event JSON (loadable in Perfetto /
//! chrome://tracing) and per-kernel summary tables.
//!
//! Everything here runs at drain time, outside the steady-state window,
//! so it allocates freely and goes through the repo's own `util::json`
//! and `util::table` rather than anything external.

use crate::util::json::Json;
use crate::util::table::{fmt_f, Table};

use super::recorder::{Category, Trace, TraceSpan};

/// Per-label names for the three numeric span args, so the Chrome trace
/// shows `"m": 256` instead of `"arg0": 256`. Empty names are skipped.
fn arg_names(cat: Category, label: &str) -> [&'static str; 3] {
    match (cat, label) {
        (Category::Plan, "elem_chain") => ["len", "steps", ""],
        (Category::Plan, _) => ["m", "n", "k"],
        (Category::Linalg, "jacobi_sweep") => ["m", "k", "sweep"],
        (Category::Linalg, "jacobi_svd") => ["m", "k", ""],
        (Category::Linalg, "householder_qr") => ["m", "k", ""],
        (Category::Linalg, _) => ["m", "k", "panel"],
        (Category::Fleet, "fleet_run") => ["layers", "tasks", "workers"],
        (Category::Fleet, _) => ["layer", "stage", ""],
        (Category::Task, _) => ["task", "", ""],
        (Category::Engine, _) => ["", "", ""],
    }
}

fn event(sp: &TraceSpan) -> Json {
    let names = arg_names(sp.cat, sp.label);
    let mut args = Vec::new();
    for (name, &v) in names.iter().zip(sp.args.iter()) {
        if !name.is_empty() {
            args.push((*name, Json::Num(v as f64)));
        }
    }
    Json::obj(vec![
        ("name", Json::Str(sp.label.to_string())),
        ("cat", Json::Str(sp.cat.name().to_string())),
        ("ph", Json::Str("X".to_string())),
        ("ts", Json::Num(sp.start_ns as f64 / 1e3)),
        ("dur", Json::Num(sp.end_ns.saturating_sub(sp.start_ns) as f64
                          / 1e3)),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(sp.worker as f64)),
        ("args", Json::obj(args)),
    ])
}

/// Build the Chrome trace-event document: `traceEvents` holds one
/// complete (`ph:"X"`) event per span, timestamps in microseconds since
/// the trace epoch, `tid` = worker ordinal; counters ride along in
/// `otherData`.
pub fn chrome_trace(trace: &Trace) -> Json {
    let events: Vec<Json> = trace.spans.iter().map(event).collect();
    let mut other: Vec<(&str, Json)> = trace
        .counters
        .iter()
        .map(|&(k, v)| (k, Json::Num(v as f64)))
        .collect();
    other.push(("spans_dropped", Json::Num(trace.dropped as f64)));
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("otherData", Json::obj(other)),
    ])
}

/// Write the Chrome trace to `path` (pretty-printed; Perfetto accepts
/// either form).
pub fn write_chrome_trace(trace: &Trace, path: &str)
                          -> std::io::Result<()> {
    std::fs::write(path, chrome_trace(trace).emit(1))
}

/// Per-(category, label) aggregate: count, total/mean/max duration,
/// sorted by total time descending — the "which kernel is the
/// bottleneck" table.
pub fn summary_table(trace: &Trace) -> Table {
    struct Agg {
        cat: Category,
        label: &'static str,
        count: u64,
        total_ns: u64,
        max_ns: u64,
    }
    let mut aggs: Vec<Agg> = Vec::new();
    for sp in &trace.spans {
        let dur = sp.end_ns.saturating_sub(sp.start_ns);
        match aggs
            .iter_mut()
            .find(|a| a.cat == sp.cat && a.label == sp.label)
        {
            Some(a) => {
                a.count += 1;
                a.total_ns += dur;
                a.max_ns = a.max_ns.max(dur);
            }
            None => aggs.push(Agg {
                cat: sp.cat,
                label: sp.label,
                count: 1,
                total_ns: dur,
                max_ns: dur,
            }),
        }
    }
    aggs.sort_by(|a, b| b.total_ns.cmp(&a.total_ns));
    let mut t = Table::new(
        "span summary",
        &["category", "label", "count", "total ms", "mean us", "max us"],
    );
    for a in &aggs {
        t.row(vec![
            a.cat.name().to_string(),
            a.label.to_string(),
            a.count.to_string(),
            fmt_f(a.total_ns as f64 / 1e6, 3),
            fmt_f(a.total_ns as f64 / 1e3 / a.count as f64, 1),
            fmt_f(a.max_ns as f64 / 1e3, 1),
        ]);
    }
    t
}

/// Counter snapshot as a table (skips zero counters unless all are zero).
pub fn counter_table(trace: &Trace) -> Table {
    let mut t = Table::new("counters", &["counter", "value"]);
    let any_nonzero = trace.counters.iter().any(|&(_, v)| v > 0);
    for &(name, v) in &trace.counters {
        if v > 0 || !any_nonzero {
            t.row(vec![name.to_string(), v.to_string()]);
        }
    }
    if trace.dropped > 0 {
        t.row(vec!["spans_dropped".to_string(), trace.dropped.to_string()]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        Trace {
            spans: vec![
                TraceSpan {
                    worker: 0,
                    cat: Category::Plan,
                    label: "gemm_nn",
                    start_ns: 1_000,
                    end_ns: 5_000,
                    args: [64, 32, 16],
                },
                TraceSpan {
                    worker: 1,
                    cat: Category::Plan,
                    label: "gemm_nn",
                    start_ns: 2_000,
                    end_ns: 4_000,
                    args: [64, 32, 16],
                },
                TraceSpan {
                    worker: 0,
                    cat: Category::Engine,
                    label: "step",
                    start_ns: 0,
                    end_ns: 9_000,
                    args: [0; 3],
                },
            ],
            counters: vec![("flops", 1234), ("bytes_moved", 0)],
            dropped: 0,
        }
    }

    #[test]
    fn chrome_trace_is_valid_json_with_named_args() {
        let doc = chrome_trace(&sample_trace());
        let parsed = Json::parse(&doc.emit(1)).unwrap();
        let events = parsed.req("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3);
        let e0 = &events[0];
        assert_eq!(e0.req("name").unwrap().as_str().unwrap(), "gemm_nn");
        assert_eq!(e0.req("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(e0.req("ts").unwrap().as_f64().unwrap(), 1.0); // µs
        assert_eq!(e0.req("dur").unwrap().as_f64().unwrap(), 4.0);
        let args = e0.req("args").unwrap();
        assert_eq!(args.req("m").unwrap().as_f64().unwrap(), 64.0);
        assert_eq!(args.req("k").unwrap().as_f64().unwrap(), 16.0);
        // Engine spans carry no named args.
        assert!(events[2].req("args").unwrap().as_obj().unwrap().is_empty());
        assert_eq!(
            parsed
                .req("otherData").unwrap()
                .req("flops").unwrap()
                .as_f64().unwrap(),
            1234.0
        );
    }

    #[test]
    fn summary_aggregates_and_sorts_by_total() {
        let t = summary_table(&sample_trace());
        assert_eq!(t.rows.len(), 2, "two (cat,label) groups");
        // engine step (9µs total) outranks the two gemms (6µs total)
        assert_eq!(t.rows[0][1], "step");
        assert_eq!(t.rows[1][1], "gemm_nn");
        assert_eq!(t.rows[1][2], "2", "gemm count aggregated");
        assert_eq!(t.rows[0][3], fmt_f(0.009, 3), "9µs total in ms");
    }

    #[test]
    fn counter_table_skips_zeros() {
        let t = counter_table(&sample_trace());
        let md = t.to_markdown();
        assert!(md.contains("flops"));
        assert!(!md.contains("bytes_moved"), "{md}");
    }
}

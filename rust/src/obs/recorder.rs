//! Span recorder: per-thread fixed-capacity ring buffers + a global
//! counter registry, near-zero cost when disabled and allocation-free in
//! steady state when enabled.
//!
//! Hot path discipline (same counting-allocator contract as the fusion
//! executor, proven in `rust/tests/obs_alloc.rs`):
//!
//! * [`enabled`] is one relaxed atomic load; every recording entry point
//!   checks it first, so a disabled build pays a branch and nothing else.
//! * A recording thread owns exactly one [`Ring`] — claimed from a global
//!   freelist on its first span (the only allocating event, the warm-up)
//!   and returned at thread exit, so short-lived pool workers reuse rings
//!   instead of leaking one per dispatch. Pushing a span is two `Instant`
//!   reads, a slot write, and a head bump: no locks, no allocation.
//! * Labels are `&'static str` literals: the compiler interns them, the
//!   ring stores the reference, and exporters dedup by value at drain
//!   time — no runtime intern table on the hot path.
//! * Counters are relaxed `AtomicU64`s indexed by [`Counter`].
//!
//! [`drain`] snapshots and resets every ring and counter. It must be
//! called while no instrumented work is in flight (end of a run, between
//! steps, after a fleet dispatch joined) — the rings are single-writer
//! and the drainer reads them unsynchronized beyond the head
//! acquire/release pair.

use std::cell::{Cell, UnsafeCell};
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Spans per ring; power of two so the slot index is a mask.
pub const RING_CAP: usize = 1 << 14;

/// Span taxonomy — one category per instrumented layer of the stack
/// (DESIGN.md §11 maps each to its label table).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Category {
    /// Coordinator step phases (`coordinator::engine` / `metrics`).
    Engine,
    /// Fleet dispatches and per-unit stages (`fusion::fleet`).
    Fleet,
    /// Fused plan kernel nodes (`fusion::exec`).
    Plan,
    /// QR panels and Jacobi sweeps (`linalg::qr` / `svd`).
    Linalg,
    /// Task-graph queue waits and executions (`util::pool`).
    Task,
}

impl Category {
    pub const ALL: [Category; 5] = [
        Category::Engine,
        Category::Fleet,
        Category::Plan,
        Category::Linalg,
        Category::Task,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Category::Engine => "engine",
            Category::Fleet => "fleet",
            Category::Plan => "plan",
            Category::Linalg => "linalg",
            Category::Task => "task",
        }
    }
}

/// Aggregated counters, reset on [`drain`]. `QueueDepthHw` is a
/// high-water mark (`counter_max`); the rest accumulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Kernel FLOPs through fused plan nodes (2mnk per GEMM).
    Flops,
    /// Estimated bytes moved by fused plan nodes (f32 operands).
    Bytes,
    /// Plan nodes executed.
    PlanNodes,
    /// Fleet stages executed.
    FleetStages,
    /// Task-graph tasks executed.
    TasksRun,
    /// Task-graph ready-queue high-water mark.
    QueueDepthHw,
    /// Memoized schedule/table reuses: Jacobi round-robin schedules and
    /// autotune shape-class lookups served from the cached table.
    SchedCacheHits,
    /// Gradient payload bytes folded through tree-reduce edges
    /// (`fusion::reduce::fold_lane` counts its source operand).
    BytesReduced,
    /// Serve daemon: concurrent-session high-water mark (`counter_max`
    /// per tick, like `QueueDepthHw`).
    SessionsActive,
    /// Serve daemon: lockstep ticks executed.
    Ticks,
}

impl Counter {
    pub const ALL: [Counter; 10] = [
        Counter::Flops,
        Counter::Bytes,
        Counter::PlanNodes,
        Counter::FleetStages,
        Counter::TasksRun,
        Counter::QueueDepthHw,
        Counter::SchedCacheHits,
        Counter::BytesReduced,
        Counter::SessionsActive,
        Counter::Ticks,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::Flops => "flops",
            Counter::Bytes => "bytes_moved",
            Counter::PlanNodes => "plan_nodes",
            Counter::FleetStages => "fleet_stages",
            Counter::TasksRun => "tasks_run",
            Counter::QueueDepthHw => "queue_depth_hw",
            Counter::SchedCacheHits => "sched_cache_hits",
            Counter::BytesReduced => "bytes_reduced",
            Counter::SessionsActive => "sessions_active",
            Counter::Ticks => "ticks",
        }
    }
}

static COUNTERS: [AtomicU64; 10] = [
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
    AtomicU64::new(0),
];

// -- enable toggle -----------------------------------------------------------

const STATE_UNSET: u8 = 0;
const STATE_OFF: u8 = 1;
const STATE_ON: u8 = 2;

static STATE: AtomicU8 = AtomicU8::new(STATE_UNSET);

/// Is recording on? One relaxed load on the hot path; the first call
/// resolves the `MOFA_TRACE` environment toggle.
#[inline]
pub fn enabled() -> bool {
    match STATE.load(Ordering::Relaxed) {
        STATE_ON => true,
        STATE_OFF => false,
        _ => init_from_env(),
    }
}

#[cold]
fn init_from_env() -> bool {
    let on = std::env::var_os("MOFA_TRACE").is_some_and(|v| !v.is_empty());
    set_enabled(on);
    on
}

/// Turn recording on or off. Overrides the `MOFA_TRACE` environment
/// default; spans opened before a disable are dropped at close.
pub fn set_enabled(on: bool) {
    let _ = epoch(); // pin the trace epoch before any span reads it
    STATE.store(if on { STATE_ON } else { STATE_OFF }, Ordering::Relaxed);
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the trace epoch — for callers that must timestamp
/// an event before the span closes (e.g. queue-wait starts).
#[inline]
pub fn now_ns() -> u64 {
    Instant::now().saturating_duration_since(epoch()).as_nanos() as u64
}

// -- rings -------------------------------------------------------------------

#[derive(Clone, Copy)]
struct Slot {
    cat: Category,
    label: &'static str,
    start_ns: u64,
    end_ns: u64,
    args: [u32; 3],
}

const EMPTY_SLOT: Slot = Slot {
    cat: Category::Engine,
    label: "",
    start_ns: 0,
    end_ns: 0,
    args: [0; 3],
};

/// Single-writer span ring. The owning thread (tracked through
/// [`TL_RING`]) is the only writer; [`drain`] reads under the module's
/// quiescence contract.
struct Ring {
    slots: UnsafeCell<Vec<Slot>>,
    /// Total spans ever pushed; slot index = head & (RING_CAP − 1).
    head: AtomicUsize,
    /// Stable worker ordinal (registration order), the trace `tid`.
    worker: u32,
}

// SAFETY: slot writes come only from the claiming thread (exclusive via
// the freelist); drain reads while instrumented work is quiescent.
unsafe impl Sync for Ring {}

impl Ring {
    #[inline]
    fn push(&self, sp: Slot) {
        let h = self.head.load(Ordering::Relaxed);
        // SAFETY: see the `Sync` contract above.
        unsafe {
            (*self.slots.get())[h & (RING_CAP - 1)] = sp;
        }
        self.head.store(h + 1, Ordering::Release);
    }
}

/// Every ring ever created (leaked: rings outlive their claiming
/// threads and are recycled through `FREE`).
static REGISTRY: Mutex<Vec<&'static Ring>> = Mutex::new(Vec::new());
/// Rings whose claiming thread has exited, ready for reuse.
static FREE: Mutex<Vec<&'static Ring>> = Mutex::new(Vec::new());

fn lock<'m, T>(m: &'m Mutex<T>) -> std::sync::MutexGuard<'m, T> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

fn claim_ring() -> &'static Ring {
    if let Some(r) = lock(&FREE).pop() {
        return r;
    }
    let mut reg = lock(&REGISTRY);
    let ring: &'static Ring = Box::leak(Box::new(Ring {
        slots: UnsafeCell::new(vec![EMPTY_SLOT; RING_CAP]),
        head: AtomicUsize::new(0),
        worker: reg.len() as u32,
    }));
    reg.push(ring);
    ring
}

/// Thread-local ring handle; returns the ring to the freelist when the
/// thread exits so scoped pool workers recycle instead of leak.
struct TlRing {
    ring: Cell<Option<&'static Ring>>,
}

impl Drop for TlRing {
    fn drop(&mut self) {
        if let Some(r) = self.ring.take() {
            lock(&FREE).push(r);
        }
    }
}

thread_local! {
    static TL_RING: TlRing = TlRing { ring: Cell::new(None) };
}

#[inline]
fn push_span(cat: Category, label: &'static str, args: [u32; 3],
             start_ns: u64, end_ns: u64) {
    TL_RING.with(|tl| {
        let ring = match tl.ring.get() {
            Some(r) => r,
            None => {
                let r = claim_ring();
                tl.ring.set(Some(r));
                r
            }
        };
        ring.push(Slot { cat, label, start_ns, end_ns, args });
    });
}

// -- recording API -----------------------------------------------------------

/// RAII span: records `[creation, drop]` into the thread's ring when
/// tracing is enabled, a no-op otherwise.
pub struct SpanGuard {
    active: Option<(Category, &'static str, [u32; 3], Instant)>,
}

impl SpanGuard {
    /// An inert guard — for callers that branch on [`enabled`] themselves.
    pub const fn off() -> SpanGuard {
        SpanGuard { active: None }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((cat, label, args, start)) = self.active.take() {
            if !enabled() {
                return; // disabled mid-span: drop it
            }
            let e = epoch();
            push_span(
                cat,
                label,
                args,
                start.saturating_duration_since(e).as_nanos() as u64,
                Instant::now().saturating_duration_since(e).as_nanos()
                    as u64,
            );
        }
    }
}

/// Open a span. `label` must be a `'static` literal (the interning).
#[inline]
pub fn span(cat: Category, label: &'static str) -> SpanGuard {
    span_args(cat, label, [0; 3])
}

/// Open a span carrying up to three numeric args (shape, ids — the
/// exporter names them per label).
#[inline]
pub fn span_args(cat: Category, label: &'static str, args: [u32; 3])
                 -> SpanGuard {
    if !enabled() {
        return SpanGuard::off();
    }
    SpanGuard { active: Some((cat, label, args, Instant::now())) }
}

/// Record a span whose start predates the call (queue waits): both
/// endpoints are [`now_ns`]-style epoch offsets.
#[inline]
pub fn record_raw(cat: Category, label: &'static str, start_ns: u64,
                  end_ns: u64, args: [u32; 3]) {
    if !enabled() {
        return;
    }
    push_span(cat, label, args, start_ns, end_ns);
}

/// Add to a counter (no-op when disabled).
#[inline]
pub fn counter_add(c: Counter, v: u64) {
    if enabled() {
        COUNTERS[c as usize].fetch_add(v, Ordering::Relaxed);
    }
}

/// Raise a high-water-mark counter (no-op when disabled).
#[inline]
pub fn counter_max(c: Counter, v: u64) {
    if enabled() {
        COUNTERS[c as usize].fetch_max(v, Ordering::Relaxed);
    }
}

// -- drain -------------------------------------------------------------------

/// One drained span, tagged with its worker ordinal.
#[derive(Clone, Copy, Debug)]
pub struct TraceSpan {
    pub worker: u32,
    pub cat: Category,
    pub label: &'static str,
    pub start_ns: u64,
    pub end_ns: u64,
    pub args: [u32; 3],
}

/// Everything [`drain`] collected: spans sorted by start time, counter
/// snapshot, and how many spans the rings overwrote.
pub struct Trace {
    pub spans: Vec<TraceSpan>,
    pub counters: Vec<(&'static str, u64)>,
    pub dropped: u64,
}

impl Trace {
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(k, _)| *k == name)
            .map_or(0, |&(_, v)| v)
    }
}

/// Spans with `label` recorded by the *calling thread* since `mark_ns`
/// (a [`now_ns`] timestamp), oldest first.
///
/// Unlike [`drain`] this needs no quiescence: the calling thread is its
/// ring's only writer, so reading its own slots races nothing. Other
/// threads' rings are not consulted and nothing is reset — the spans
/// stay visible to a later `drain`. This is the autotuner's timing
/// readback: it runs candidate kernels sequentially under per-variant
/// `tune_*` spans, then reads its own ring back instead of adding a
/// separate measurement path.
pub fn local_spans_since(mark_ns: u64, label: &str) -> Vec<TraceSpan> {
    TL_RING.with(|tl| {
        let ring = match tl.ring.get() {
            Some(r) => r,
            None => return Vec::new(),
        };
        let h = ring.head.load(Ordering::Acquire);
        let n = h.min(RING_CAP);
        // SAFETY: single-writer ring, and the writer is this thread.
        let slots = unsafe { &*ring.slots.get() };
        let mut out = Vec::new();
        for i in (h - n)..h {
            let s = slots[i & (RING_CAP - 1)];
            if s.label == label && s.start_ns >= mark_ns {
                out.push(TraceSpan {
                    worker: ring.worker,
                    cat: s.cat,
                    label: s.label,
                    start_ns: s.start_ns,
                    end_ns: s.end_ns,
                    args: s.args,
                });
            }
        }
        out
    })
}

/// Snapshot and reset every ring and counter. Allocates freely — it runs
/// outside the steady-state window — and must only be called while no
/// instrumented work is in flight (see module docs).
pub fn drain() -> Trace {
    let reg = lock(&REGISTRY);
    let mut spans = Vec::new();
    let mut dropped = 0u64;
    for ring in reg.iter() {
        let h = ring.head.load(Ordering::Acquire);
        let n = h.min(RING_CAP);
        dropped += (h - n) as u64;
        // SAFETY: quiescence contract — the owning thread is not pushing.
        let slots = unsafe { &*ring.slots.get() };
        for i in (h - n)..h {
            let s = slots[i & (RING_CAP - 1)];
            spans.push(TraceSpan {
                worker: ring.worker,
                cat: s.cat,
                label: s.label,
                start_ns: s.start_ns,
                end_ns: s.end_ns,
                args: s.args,
            });
        }
        ring.head.store(0, Ordering::Release);
    }
    drop(reg);
    spans.sort_by(|a, b| {
        a.start_ns.cmp(&b.start_ns).then(a.end_ns.cmp(&b.end_ns))
    });
    let counters = Counter::ALL
        .iter()
        .map(|&c| (c.name(), COUNTERS[c as usize].swap(0, Ordering::Relaxed)))
        .collect();
    Trace { spans, counters, dropped }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Single test: the recorder is process-global state (enable flag,
    // rings, counters) — sibling tests would race each other. Foreign
    // spans from concurrently running lib tests are tolerated by
    // filtering on this test's unique labels.
    #[test]
    fn recorder_roundtrip() {
        // Disabled: guards are inert and the drain that follows must not
        // see our label.
        set_enabled(false);
        {
            let _g = span(Category::Task, "obs_selftest_disabled");
        }
        counter_add(Counter::TasksRun, 7);

        set_enabled(true);
        let before = drain();
        assert!(before
            .spans
            .iter()
            .all(|s| s.label != "obs_selftest_disabled"));

        {
            let _a = span_args(Category::Linalg, "obs_selftest_a",
                               [3, 4, 5]);
            let _b = span(Category::Engine, "obs_selftest_b");
        }
        record_raw(Category::Task, "obs_selftest_raw", 10, 20, [1, 0, 0]);
        counter_add(Counter::Flops, 100);
        counter_max(Counter::QueueDepthHw, 9);
        counter_max(Counter::QueueDepthHw, 4);

        let trace = drain();
        set_enabled(false);

        let a = trace
            .spans
            .iter()
            .find(|s| s.label == "obs_selftest_a")
            .expect("span a recorded");
        assert_eq!(a.cat, Category::Linalg);
        assert_eq!(a.args, [3, 4, 5]);
        assert!(a.end_ns >= a.start_ns);
        assert!(trace.spans.iter().any(|s| s.label == "obs_selftest_b"));
        let raw = trace
            .spans
            .iter()
            .find(|s| s.label == "obs_selftest_raw")
            .expect("raw span recorded");
        assert_eq!((raw.start_ns, raw.end_ns), (10, 20));
        assert!(trace.counter("flops") >= 100);
        assert!(trace.counter("queue_depth_hw") >= 9);
        // sorted by start
        for w in trace.spans.windows(2) {
            assert!(w[0].start_ns <= w[1].start_ns);
        }
        // drained rings are empty now (modulo concurrent lib tests, which
        // never use our labels)
        let again = drain();
        assert!(again.spans.iter().all(|s| !s.label.starts_with("obs_self")));
    }
}

//! Always-on observability: per-kernel spans, task-graph timelines, and
//! counters, with Chrome-trace / summary-table export (DESIGN.md §11).
//!
//! The subsystem is always compiled and runtime-toggled: `MOFA_TRACE`
//! (or `--trace <path>` on the CLI, or [`set_enabled`]) turns recording
//! on. Disabled cost is one relaxed atomic load per instrumentation
//! site; enabled recording is lock- and allocation-free in steady state
//! (`rust/tests/obs_alloc.rs`), and tracing never changes scheduling or
//! math — traced runs are bit-identical to untraced ones
//! (`rust/tests/obs_trace.rs`).
//!
//! Typical use:
//!
//! ```text
//! MOFA_TRACE=trace.json mofasgd train ...   # then open trace.json in
//!                                           # ui.perfetto.dev
//! ```

pub mod export;
pub mod recorder;

pub use recorder::{counter_add, counter_max, drain, enabled,
                   local_spans_since, now_ns, record_raw, set_enabled,
                   span, span_args, Category, Counter, SpanGuard, Trace,
                   TraceSpan};

/// The trace output path from `MOFA_TRACE`, if set and non-empty.
pub fn trace_path_from_env() -> Option<String> {
    std::env::var("MOFA_TRACE").ok().filter(|p| !p.is_empty())
}

//! Analytic memory model, calibrated against the paper's LLaMA-3.1-8B
//! measurements (Appendix C.6).
//!
//! Conventions follow the paper's profiling setup: bf16 weights/grads/
//! states (2 bytes), batch 1 × seq 4096, gradient accumulation 8, no
//! activation checkpointing. The only fitted constant is
//! `ACT_BYTES_PER_TOKEN_LAYER` (activations per token per layer),
//! calibrated once so the AdamW row reproduces the paper's 7.5 GB; every
//! other cell is then a prediction compared against C.6 in EXPERIMENTS.md.

/// One matrix-shaped (trainable, 2-D) parameter group.
#[derive(Debug, Clone)]
pub struct MatGroup {
    pub name: &'static str,
    pub m: usize,
    pub n: usize,
    pub count: usize,
}

/// Architecture description for memory accounting.
#[derive(Debug, Clone)]
pub struct Arch {
    pub name: String,
    pub matrices: Vec<MatGroup>,
    /// Parameters routed to AdamW regardless of the matrix optimizer
    /// (embeddings, norms, heads — paper §5.5).
    pub nonmatrix_params: usize,
    pub layers: usize,
    pub d_model: usize,
    pub seq: usize,
    pub micro_batch: usize,
}

impl Arch {
    pub fn matrix_params(&self) -> usize {
        self.matrices.iter().map(|g| g.m * g.n * g.count).sum()
    }

    pub fn total_params(&self) -> usize {
        self.matrix_params() + self.nonmatrix_params
    }
}

/// LLaMA-3.1-8B shapes (d=4096, 32 layers, GQA kv=1024, MLP 14336,
/// untied 128256-token embedding + head) — the paper's profiling subject.
pub fn llama31_8b() -> Arch {
    let l = 32;
    Arch {
        name: "LLaMA-3.1-8B".into(),
        matrices: vec![
            MatGroup { name: "q_proj", m: 4096, n: 4096, count: l },
            MatGroup { name: "k_proj", m: 4096, n: 1024, count: l },
            MatGroup { name: "v_proj", m: 4096, n: 1024, count: l },
            MatGroup { name: "o_proj", m: 4096, n: 4096, count: l },
            MatGroup { name: "gate_proj", m: 4096, n: 14336, count: l },
            MatGroup { name: "up_proj", m: 4096, n: 14336, count: l },
            MatGroup { name: "down_proj", m: 14336, n: 4096, count: l },
        ],
        // embedding + lm_head (untied) + norms
        nonmatrix_params: 2 * 128_256 * 4096 + (2 * l + 1) * 4096,
        layers: l,
        d_model: 4096,
        seq: 4096,
        micro_batch: 1,
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemOptimizer {
    MoFaSgd { rank: usize },
    GaLore { rank: usize },
    Lora { rank: usize },
    AdamW,
    Muon,
    /// Stateless spectral (SWAN proxy, profiled exactly as the paper does).
    Swan,
    Adafactor,
    Lion,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradMode {
    /// §5.5 fused low-rank accumulation (backward-hook projection).
    Fused,
    /// Persistent full-rank gradient buffers across micro-batches.
    Dense,
}

/// Memory breakdown in bytes, by the paper's five categories.
#[derive(Debug, Clone, Copy, Default)]
pub struct Breakdown {
    pub params: u64,
    pub opt_states: u64,
    pub gradients: u64,
    pub activations: u64,
    pub adapters: u64,
}

impl Breakdown {
    pub fn total(&self) -> u64 {
        self.params + self.opt_states + self.gradients + self.activations
            + self.adapters
    }

    pub fn gb(x: u64) -> f64 {
        x as f64 / 1e9
    }
}

pub const BF16: u64 = 2;

/// Activation bytes per token per layer, no checkpointing. Calibrated so
/// AdamW × LLaMA-8B × (batch 1, seq 4096) reproduces the paper's 7.5 GB
/// activations row; includes attention scores, MLP intermediates, and
/// framework slack.
pub const ACT_BYTES_PER_TOKEN_LAYER: f64 = 57_200.0;

pub fn breakdown(arch: &Arch, opt: MemOptimizer, grad: GradMode) -> Breakdown {
    let p_total = arch.total_params() as u64;
    let p_matrix = arch.matrix_params() as u64;
    let p_nonmat = p_total - p_matrix;

    let params = p_total * BF16;

    // Optimizer states: matrix route by optimizer; non-matrix always AdamW
    // (2 moments), per paper §5.5 ("optimizer states ... approximately
    // 4.2 GB" for the AdamW-on-embeddings share).
    let lowrank_state = |r: usize, per_shape: fn(usize, usize, usize) -> u64| {
        arch.matrices
            .iter()
            .map(|g| g.count as u64 * per_shape(g.m, g.n, r))
            .sum::<u64>()
    };
    let mat_state: u64 = match opt {
        MemOptimizer::MoFaSgd { rank } => {
            lowrank_state(rank, |m, n, r| ((m + n + 1) * r) as u64)
        }
        MemOptimizer::GaLore { rank } => {
            lowrank_state(rank, |m, n, r| ((m + 2 * n) * r) as u64)
        }
        MemOptimizer::Lora { rank } => {
            // base matrices frozen: no state; adapters counted below
            let _ = rank;
            0
        }
        MemOptimizer::AdamW => 2 * p_matrix,
        MemOptimizer::Muon | MemOptimizer::Lion => p_matrix,
        MemOptimizer::Swan => 0,
        MemOptimizer::Adafactor => arch
            .matrices
            .iter()
            .map(|g| (g.count * (g.m + g.n)) as u64)
            .sum(),
    };
    let opt_states = (mat_state + 2 * p_nonmat) * BF16;

    // Gradients. Fused low-rank accumulation removes the matrix gradient
    // buffers; the non-matrix (embedding) gradients always persist — that
    // is exactly the paper's 2.1 GB floor for MoFaSGD/fused-GaLore/LoRA.
    let grad_lowrank: u64 = match opt {
        MemOptimizer::MoFaSgd { rank } => {
            lowrank_state(rank, |m, n, r| ((m + n + r) * r) as u64)
        }
        MemOptimizer::GaLore { rank } => {
            lowrank_state(rank, |_m, n, r| (n * r) as u64)
        }
        MemOptimizer::Lora { rank } => {
            // adapter grads only
            arch.matrices
                .iter()
                .map(|g| (g.count * rank * (g.m + g.n)) as u64)
                .sum()
        }
        _ => p_matrix, // no fused path: full matrix grads
    };
    let matrix_grads = match (opt, grad) {
        (MemOptimizer::Lora { .. }, _) => grad_lowrank,
        (_, GradMode::Fused) => grad_lowrank,
        (_, GradMode::Dense) => p_matrix,
    };
    let gradients = (matrix_grads + p_nonmat) * BF16;

    // Activations: per-token-per-layer constant (calibrated once).
    let tokens = (arch.micro_batch * arch.seq) as f64;
    let activations =
        (tokens * arch.layers as f64 * ACT_BYTES_PER_TOKEN_LAYER) as u64;

    // Adapters (LoRA only): A/B params + AdamW moments on them.
    let adapters: u64 = match opt {
        MemOptimizer::Lora { rank } => {
            let ab: u64 = arch
                .matrices
                .iter()
                .map(|g| (g.count * rank * (g.m + g.n)) as u64)
                .sum();
            3 * ab * BF16 // params + 2 moments
        }
        _ => 0,
    };

    Breakdown { params, opt_states, gradients, activations, adapters }
}

/// Paper C.6 reference rows (GB) for EXPERIMENTS.md comparison.
pub fn paper_c6_rows() -> Vec<(&'static str, [f64; 5])> {
    vec![
        ("MoFaSGD (r=8)", [15.5, 4.2, 2.1, 7.6, 0.0]),
        ("LoRA (r=8)", [15.5, 4.2, 2.1, 9.8, 2.0]),
        ("SWAN", [15.5, 4.2, 16.0, 8.2, 0.0]),
        ("AdamW (BF16)", [15.5, 31.8, 16.0, 7.5, 0.0]),
        ("GaLore Fused (r=8)", [15.5, 4.2, 2.1, 8.2, 0.0]),
        ("GaLore Non-Fused (r=8)", [15.5, 4.2, 16.0, 8.8, 0.0]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gb(x: u64) -> f64 {
        Breakdown::gb(x)
    }

    #[test]
    fn llama_param_count_matches() {
        let a = llama31_8b();
        let total = a.total_params() as f64;
        assert!((total - 8.03e9).abs() < 0.1e9, "{total}");
    }

    #[test]
    fn adamw_row_matches_paper_within_tolerance() {
        let a = llama31_8b();
        let b = breakdown(&a, MemOptimizer::AdamW, GradMode::Dense);
        assert!((gb(b.params) - 15.5).abs() < 1.1, "{}", gb(b.params));
        assert!((gb(b.opt_states) - 31.8).abs() < 1.0, "{}",
                gb(b.opt_states));
        assert!((gb(b.gradients) - 16.0).abs() < 0.5, "{}", gb(b.gradients));
        assert!((gb(b.activations) - 7.5).abs() < 0.3, "{}",
                gb(b.activations));
    }

    #[test]
    fn mofasgd_row_matches_paper_shape() {
        let a = llama31_8b();
        let b = breakdown(&a, MemOptimizer::MoFaSgd { rank: 8 },
                          GradMode::Fused);
        // opt states dominated by the AdamW-on-embeddings share (~4.2 GB)
        assert!((gb(b.opt_states) - 4.2) < 0.6, "{}", gb(b.opt_states));
        // gradients ≈ embedding grads only (~2.1 GB)
        assert!((gb(b.gradients) - 2.1).abs() < 0.3, "{}", gb(b.gradients));
        // MoFaSGD total far below AdamW total (paper: 29.4 vs 70.8)
        let adamw = breakdown(&a, MemOptimizer::AdamW, GradMode::Dense);
        assert!(b.total() * 2 < adamw.total());
    }

    #[test]
    fn fused_vs_dense_galore_gap_matches_paper() {
        // Paper: fused 2.1 GB vs non-fused 16.0 GB gradient buffers.
        let a = llama31_8b();
        let f = breakdown(&a, MemOptimizer::GaLore { rank: 8 },
                          GradMode::Fused);
        let d = breakdown(&a, MemOptimizer::GaLore { rank: 8 },
                          GradMode::Dense);
        assert!(gb(d.gradients) - gb(f.gradients) > 12.0);
    }

    #[test]
    fn lowrank_state_is_table2_formula() {
        // Single 100×60 matrix, r=4: MoFaSGD state = (m+n+1)r floats.
        let a = Arch {
            name: "unit".into(),
            matrices: vec![MatGroup { name: "w", m: 100, n: 60, count: 1 }],
            nonmatrix_params: 0,
            layers: 1,
            d_model: 60,
            seq: 8,
            micro_batch: 1,
        };
        let b = breakdown(&a, MemOptimizer::MoFaSgd { rank: 4 },
                          GradMode::Fused);
        assert_eq!(b.opt_states, (100 + 60 + 1) * 4 * BF16);
        let g = breakdown(&a, MemOptimizer::GaLore { rank: 4 },
                          GradMode::Fused);
        assert_eq!(g.opt_states, (100 + 2 * 60) * 4 * BF16);
    }

    #[test]
    fn ordering_matches_figure4() {
        // Paper Fig. 4 totals: MoFaSGD < GaLore-fused < LoRA < SWAN <
        // GaLore-non-fused < AdamW.
        let a = llama31_8b();
        let t = |o, g| breakdown(&a, o, g).total();
        let mofa = t(MemOptimizer::MoFaSgd { rank: 8 }, GradMode::Fused);
        let gf = t(MemOptimizer::GaLore { rank: 8 }, GradMode::Fused);
        let lora = t(MemOptimizer::Lora { rank: 8 }, GradMode::Fused);
        let swan = t(MemOptimizer::Swan, GradMode::Dense);
        let gnf = t(MemOptimizer::GaLore { rank: 8 }, GradMode::Dense);
        let adamw = t(MemOptimizer::AdamW, GradMode::Dense);
        assert!(mofa <= gf && gf <= lora && lora < swan,
                "{mofa} {gf} {lora} {swan}");
        assert!(swan < gnf && gnf < adamw, "{swan} {gnf} {adamw}");
    }
}

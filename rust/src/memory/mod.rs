//! GPU-memory accounting model (paper Table 2, Fig. 4, Fig. 7/9–14, C.6).
//!
//! `model` computes the per-category breakdown (params / optimizer states /
//! gradients / activations / adapters) for any architecture × optimizer ×
//! accumulation-mode combination; `trace` simulates the step-phase memory
//! timeline the paper's torch.cuda snapshots show.

pub mod model;
pub mod trace;

pub use model::{llama31_8b, Arch, Breakdown, GradMode, MemOptimizer};
pub use trace::simulate_trace;

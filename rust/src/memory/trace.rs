//! Step-phase memory-trace simulation (paper Fig. 7 and Figs. 9–14).
//!
//! Reproduces the qualitative timeline of the paper's torch.cuda memory
//! snapshots: per training step, activations ramp up through the forward
//! pass, convert into gradient buffers through the backward pass, and a
//! transient optimizer-step working set appears at the boundary. The fused
//! §5.5 path shows gradient memory collapsing after every micro-batch;
//! the dense path shows it persisting across the accumulation window.

use super::model::{breakdown, Arch, Breakdown, GradMode, MemOptimizer, BF16};

#[derive(Debug, Clone, Copy)]
pub struct TracePoint {
    /// Simulation time in phase units.
    pub t: f64,
    pub params_gb: f64,
    pub opt_gb: f64,
    pub grad_gb: f64,
    pub act_gb: f64,
    pub total_gb: f64,
}

/// Simulate `steps` optimizer steps with `accum` micro-batches each,
/// sampling `res` points per phase.
pub fn simulate_trace(arch: &Arch, opt: MemOptimizer, grad: GradMode,
                      steps: usize, accum: usize) -> Vec<TracePoint> {
    let b = breakdown(arch, opt, grad);
    let gb = Breakdown::gb;
    let params = gb(b.params) + gb(b.adapters);
    let opt_gb = gb(b.opt_states);
    let act_peak = gb(b.activations);
    // Peak per-micro-batch transient gradient (one matrix at a time is
    // materialized even in the fused path, then immediately projected).
    let largest_matrix = arch
        .matrices
        .iter()
        .map(|g| (g.m * g.n) as u64 * BF16)
        .max()
        .unwrap_or(0);
    let transient = gb(largest_matrix);
    let grad_steady = gb(b.gradients);

    let res = 4usize;
    let mut out = Vec::new();
    let mut t = 0.0f64;
    let push = |t: f64, grad_now: f64, act_now: f64| TracePoint {
        t,
        params_gb: params,
        opt_gb,
        grad_gb: grad_now,
        act_gb: act_now,
        total_gb: params + opt_gb + grad_now + act_now,
    };
    for _ in 0..steps {
        for micro in 0..accum {
            // forward: activations ramp 0 → peak
            for k in 0..res {
                let act = act_peak * (k + 1) as f64 / res as f64;
                let g_now = match grad {
                    GradMode::Fused => grad_steady,
                    GradMode::Dense => {
                        // dense buffers persist once the first micro-batch
                        // has completed its backward
                        if micro == 0 { grad_steady.min(transient) }
                        else { grad_steady }
                    }
                };
                out.push(push(t, g_now, act));
                t += 1.0 / res as f64;
            }
            // backward: activations release, gradients materialize
            for k in 0..res {
                let act = act_peak * (res - k - 1) as f64 / res as f64;
                let g_now = match grad {
                    GradMode::Fused => grad_steady + transient,
                    GradMode::Dense => grad_steady + transient,
                };
                out.push(push(t, g_now, act));
                t += 1.0 / res as f64;
            }
            // after the §5.5 hook, fused gradients collapse to the
            // low-rank buffers immediately
            out.push(push(t, grad_steady, 0.0));
            t += 0.25;
        }
        // optimizer step transient (factor update working set)
        out.push(push(t, grad_steady + transient * 0.5, 0.0));
        t += 0.5;
        out.push(push(t, match grad {
            GradMode::Fused => grad_steady,
            GradMode::Dense => grad_steady,
        }, 0.0));
        t += 0.5;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::model::llama31_8b;

    #[test]
    fn fused_peak_below_dense_peak() {
        let arch = llama31_8b();
        let fused = simulate_trace(&arch, MemOptimizer::MoFaSgd { rank: 8 },
                                   GradMode::Fused, 2, 4);
        let dense = simulate_trace(&arch, MemOptimizer::AdamW,
                                   GradMode::Dense, 2, 4);
        let peak = |tr: &[TracePoint]| {
            tr.iter().map(|p| p.total_gb).fold(0.0f64, f64::max)
        };
        // Paper: 29.4 GB vs 70.8 GB.
        assert!(peak(&fused) * 1.8 < peak(&dense),
                "{} vs {}", peak(&fused), peak(&dense));
    }

    #[test]
    fn trace_is_time_ordered_and_positive() {
        let arch = llama31_8b();
        let tr = simulate_trace(&arch, MemOptimizer::GaLore { rank: 8 },
                                GradMode::Fused, 1, 2);
        for w in tr.windows(2) {
            assert!(w[1].t > w[0].t);
        }
        assert!(tr.iter().all(|p| p.total_gb > 0.0));
    }

    #[test]
    fn params_band_is_constant() {
        let arch = llama31_8b();
        let tr = simulate_trace(&arch, MemOptimizer::MoFaSgd { rank: 8 },
                                GradMode::Fused, 1, 3);
        let first = tr[0].params_gb;
        assert!(tr.iter().all(|p| (p.params_gb - first).abs() < 1e-9));
    }
}

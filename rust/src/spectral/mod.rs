//! Momentum spectral analysis (paper §5.3, Fig. 6a).
//!
//! The paper's empirical justification for MoFaSGD: the AdamW first-moment
//! EMA concentrates its energy in a low-rank subspace throughout training.
//! We reproduce the measurement natively: train with AdamW, snapshot the
//! first-moment buffers of every matrix layer, SVD them, and report the
//! average energy ratio Σ_{i≤r} σ_i² / ‖M‖_F² for r ∈ {16, 32}.

use crate::linalg::{jacobi_svd, svd::energy_ratio, Mat};
use crate::nn::Mlp;
use crate::optim::{AdamW, MatrixOptimizer};
use crate::util::rng::Rng;

/// Energy ratios of one momentum matrix at several ranks.
pub fn moment_energy_ratios(m: &Mat, ranks: &[usize]) -> Vec<f64> {
    // SVD expects tall input.
    let tall = if m.rows >= m.cols { m.clone() } else { m.t() };
    let svd = jacobi_svd(&tall);
    let frob = m.frob_norm();
    ranks.iter().map(|&r| energy_ratio(&svd.s, frob, r)).collect()
}

/// Average the per-matrix ratios (the paper averages over all 2-D weights).
pub fn average_ratios(moments: &[Mat], ranks: &[usize]) -> Vec<f64> {
    let mut acc = vec![0.0f64; ranks.len()];
    for m in moments {
        for (a, r) in acc.iter_mut().zip(moment_energy_ratios(m, ranks)) {
            *a += r;
        }
    }
    for a in &mut acc {
        *a /= moments.len().max(1) as f64;
    }
    acc
}

/// One sampled point of the Fig. 6a curve.
pub struct SpectralPoint {
    pub step: usize,
    /// ratios aligned with the requested ranks.
    pub ratios: Vec<f64>,
}

/// Native AdamW teacher-student run that snapshots first-moment energy
/// ratios every `every` steps — the Fig. 6a harness. The teacher-student
/// MLP regression plays the Tulu3 run's role: what matters is that
/// gradients (and hence their EMA) come from real training dynamics.
pub fn run_analysis(d_in: usize, d_hidden: usize, d_out: usize,
                    steps: usize, every: usize, ranks: &[usize],
                    seed: u64) -> Vec<SpectralPoint> {
    let mut rng = Rng::new(seed);
    let mut net = Mlp::new(d_in, d_hidden, d_out, &mut rng);
    let teacher = Mlp::new(d_in, d_hidden, d_out, &mut rng);
    let mut o1 = AdamW::new(d_in, d_hidden, 0.9, 0.999, 0.0);
    let mut o2 = AdamW::new(d_hidden, d_out, 0.9, 0.999, 0.0);
    let mut out = Vec::new();
    for step in 0..steps {
        let x = Mat::randn(&mut rng, 32, d_in, 1.0);
        let y = teacher.forward(&x);
        let (_, g) = net.loss_and_grads(&x, &y);
        o1.step(&mut net.w1, &g.g1, 3e-3);
        o2.step(&mut net.w2, &g.g2, 3e-3);
        if step % every == 0 || step + 1 == steps {
            let ratios =
                average_ratios(&[o1.m.clone(), o2.m.clone()], ranks);
            out.push(SpectralPoint { step, ratios });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_are_monotone_in_rank() {
        let mut rng = Rng::new(1);
        let m = Mat::randn(&mut rng, 60, 40, 1.0);
        let r = moment_energy_ratios(&m, &[4, 8, 16, 40]);
        for w in r.windows(2) {
            assert!(w[1] >= w[0] - 1e-9);
        }
        assert!((r[3] - 1.0).abs() < 1e-3, "full rank captures everything");
    }

    #[test]
    fn lowrank_matrix_saturates_early() {
        let mut rng = Rng::new(2);
        let m = Mat::randn(&mut rng, 80, 4, 1.0)
            .matmul(&Mat::randn(&mut rng, 4, 50, 1.0));
        let r = moment_energy_ratios(&m, &[4, 16]);
        assert!(r[0] > 0.999, "{}", r[0]);
    }

    #[test]
    fn training_momentum_concentrates_energy() {
        // The Fig. 6a phenomenon in miniature: AdamW first moments during
        // training are far more concentrated than white noise.
        let points = run_analysis(48, 64, 32, 60, 20, &[8], 3);
        let last = points.last().unwrap().ratios[0];
        let mut rng = Rng::new(9);
        let noise = Mat::randn(&mut rng, 48, 64, 1.0);
        let noise_ratio = moment_energy_ratios(&noise, &[8])[0];
        assert!(last > noise_ratio + 0.1,
                "momentum {last} vs noise {noise_ratio}");
    }
}

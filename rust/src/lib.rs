//! MoFaSGD: Low-rank Momentum Factorization for Memory Efficient Training.
//!
//! Rust Layer-3 coordinator of the three-layer reproduction (see DESIGN.md):
//! the Python/JAX/Pallas layers are build-time only; this crate loads their
//! AOT-lowered HLO artifacts through the PJRT C API and owns everything on
//! the request path — data pipeline, per-layer optimizer routing, fused
//! low-rank gradient accumulation (paper §5.5), schedules, metrics,
//! checkpoints — plus native-Rust reference implementations of the paper's
//! optimizer (Algorithm 1) and every baseline it is evaluated against.

pub mod coordinator;
pub mod data;
pub mod fusion;
pub mod linalg;
pub mod memory;
pub mod nn;
pub mod obs;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod spectral;
pub mod util;

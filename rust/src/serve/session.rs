//! One tenant's fine-tuning session: model state, owned optimizer
//! fleet, and the seeded synthetic data stream.
//!
//! The workload is the repo's standard descent task (a noisy matrix
//! quadratic, ½‖W − W*‖²_F per layer — the same closed loop
//! `optim::descent_tests` runs): per micro-batch the gradient is
//! `(W − W*) + noise·Z` with `Z` a fresh standard-normal draw. Every
//! byte a tick consumes is a pure function of `(seed, layer, step,
//! micro)` via `Rng::shard_stream`, so a session's trajectory is
//! bit-identical whether its noise is generated inline on the tick
//! thread or by a prefetcher thread, and no matter how many tenants
//! share the dispatch ([`rust/tests/serve_parity.rs`]).
//!
//! Each layer is a [`crate::fusion::FleetUnit`] whose chain covers the
//! whole step: `accum` micro-gradient accumulation stages (fused — the
//! gradient expression writes straight into the tree-reduce lane, no
//! gradient scratch matrix), the fixed-topology tree-reduce stages of
//! `fusion::reduce::TreeSchedule`, a mean-scale stage, then the
//! optimizer stages via [`MatStager`] — literally the staging code the
//! trainer path runs, so serve inherits the fleet's parity surface.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::coordinator::checkpoint::Checkpoint;
use crate::data::loader::Prefetcher;
use crate::fusion::reduce::{self, TreeSchedule};
use crate::fusion::FleetUnit;
use crate::linalg::Mat;
use crate::optim::adamw::AdamWVec;
use crate::optim::{AdamW, MatOpt, MatStager, MoFaSgd, Muon, SgdM, SignSgd,
                   VecOptimizer};
use crate::util::faultinject;
use crate::util::rng::Rng;

use super::protocol::{LayerKind, LayerSpec, SessionSpec};

/// Deterministic per-layer stream: `tag` keys the layer and the stream
/// role (weights / target / noise), so every consumer — session build,
/// inline tick fill, prefetcher producer thread — derives identical
/// bytes from the session seed alone.
fn layer_rng(seed: u64, tag: u64) -> Rng {
    Rng::new(seed).split(tag)
}

/// Stream-role tags: matrix layer `li` uses `4*li + role`, vec layer
/// `vi` uses `(1<<32) + 4*vi + role`, with role 0 = init weights,
/// 1 = target, 2 = noise.
fn mat_tag(li: usize, role: u64) -> u64 {
    4 * li as u64 + role
}

fn vec_tag(vi: usize, role: u64) -> u64 {
    (1u64 << 32) + 4 * vi as u64 + role
}

/// A session owns its optimizers (the trainer's `MatUnit` borrows them);
/// this wraps the owned value so stage dispatch can still hand
/// [`MatStager`] the borrowed [`MatOpt`] view it shares with the trainer.
enum OwnedOpt {
    MoFaSgd(MoFaSgd),
    Muon(Muon),
    AdamW(AdamW),
    SgdM(SgdM),
    SignSgd(SignSgd),
}

impl OwnedOpt {
    fn build(l: &LayerSpec) -> OwnedOpt {
        match l.kind {
            LayerKind::MoFaSgd => {
                OwnedOpt::MoFaSgd(MoFaSgd::new(l.m, l.n, l.rank, l.beta))
            }
            LayerKind::Muon => OwnedOpt::Muon(Muon::new(l.m, l.n, l.beta)),
            LayerKind::AdamW => {
                OwnedOpt::AdamW(AdamW::new(l.m, l.n, l.beta, 0.999, 0.0))
            }
            LayerKind::SgdM => OwnedOpt::SgdM(SgdM::new(l.m, l.n, l.beta)),
            LayerKind::SignSgd => OwnedOpt::SignSgd(SignSgd::new()),
        }
    }

    fn as_mat_opt(&mut self) -> MatOpt<'_> {
        match self {
            OwnedOpt::MoFaSgd(o) => MatOpt::MoFaSgd(o),
            OwnedOpt::Muon(o) => MatOpt::Muon(o),
            OwnedOpt::AdamW(o) => MatOpt::AdamW(o),
            OwnedOpt::SgdM(o) => MatOpt::SgdM(o),
            OwnedOpt::SignSgd(o) => MatOpt::SignSgd(o),
        }
    }
}

/// One matrix layer of a session, as a fleet unit covering the full
/// step: accumulate → tree-reduce → mean-scale → optimizer stages.
pub struct SessLayer {
    session: u32,
    w: Mat,
    target: Mat,
    opt: OwnedOpt,
    stager: MatStager,
    sched: TreeSchedule,
    /// Tree-reduce lane set (the replicated engine's layout, R = 1).
    lanes: Vec<Mat>,
    /// Per-micro standard-normal noise, filled each tick (inline or
    /// copied from the prefetched [`TickNoise`]).
    micros: Vec<Vec<f32>>,
    rng_noise: Rng,
    noise: f32,
    eta: f32,
    inv_micro: f32,
    accum: usize,
    /// Optimizer stage count, cached from [`MatStager::n_stages`].
    n_step: usize,
    /// Lanes written this step (bitmask; reset at stage 0).
    written: u64,
}

impl SessLayer {
    fn new(session: u32, li: usize, l: &LayerSpec, spec: &SessionSpec)
           -> SessLayer {
        let mut rw = layer_rng(spec.seed, mat_tag(li, 0));
        let w = Mat::randn(&mut rw, l.m, l.n, 1.0);
        let mut rt = layer_rng(spec.seed, mat_tag(li, 1));
        let target = Mat::randn(&mut rt, l.m, l.n, 1.0);
        let mut opt = OwnedOpt::build(l);
        let n_step = MatStager::n_stages(&opt.as_mat_opt());
        let sched = TreeSchedule::new(spec.accum, reduce::TREE_WIDTH);
        assert!(sched.width() <= 64, "written bitmask width");
        let lanes = (0..sched.width()).map(|_| Mat::zeros(l.m, l.n))
            .collect();
        let micros = (0..spec.accum).map(|_| vec![0.0f32; l.m * l.n])
            .collect();
        SessLayer {
            session,
            w,
            target,
            opt,
            stager: MatStager::new(),
            sched,
            lanes,
            micros,
            rng_noise: layer_rng(spec.seed, mat_tag(li, 2)),
            noise: spec.noise,
            eta: spec.eta,
            inv_micro: 1.0 / spec.accum as f32,
            accum: spec.accum,
            n_step,
            written: 0,
        }
    }

    /// Generate this tick's noise inline (the prefetch = 0 path). Same
    /// bytes as the producer thread: both shard the layer's noise rng by
    /// the global micro index.
    fn fill_micros(&mut self, step: usize) {
        for (k, buf) in self.micros.iter_mut().enumerate() {
            let mut r = self
                .rng_noise
                .shard_stream((step * self.accum + k) as u64);
            for x in buf.iter_mut() {
                *x = r.normal_f32();
            }
        }
    }

    /// Install this tick's noise from a prefetched [`TickNoise`] slice
    /// (one buffer per micro). Shape mismatches are the stream's failure.
    fn copy_micros(&mut self, src: &[Vec<f32>]) -> std::result::Result<(), String> {
        if src.len() != self.accum {
            return Err("noise stream micro count mismatch".to_string());
        }
        for (buf, s) in self.micros.iter_mut().zip(src) {
            if s.len() != buf.len() {
                return Err("noise stream buffer size mismatch".to_string());
            }
            buf.copy_from_slice(s);
        }
        Ok(())
    }

    /// ½‖W − W*‖²_F in f64 (metrics stream).
    fn loss(&self) -> f64 {
        let mut acc = 0.0f64;
        for (w, t) in self.w.data.iter().zip(&self.target.data) {
            let d = (w - t) as f64;
            acc += d * d;
        }
        0.5 * acc
    }

    fn save_into(&self, li: usize, ck: &mut Checkpoint) {
        let dims = vec![self.w.rows, self.w.cols];
        ck.tensors
            .push((format!("w{li}"), dims.clone(), self.w.data.clone()));
        match &self.opt {
            OwnedOpt::MoFaSgd(o) => {
                ck.tensors.push((format!("u{li}"),
                                 vec![o.u.rows, o.u.cols],
                                 o.u.data.clone()));
                ck.tensors.push((format!("s{li}"), vec![o.s.len()],
                                 o.s.clone()));
                ck.tensors.push((format!("v{li}"),
                                 vec![o.v.rows, o.v.cols],
                                 o.v.data.clone()));
            }
            OwnedOpt::Muon(o) => {
                ck.tensors.push((format!("m{li}"), dims, o.m.data.clone()));
            }
            OwnedOpt::SgdM(o) => {
                ck.tensors.push((format!("m{li}"), dims, o.m.data.clone()));
            }
            // AdamW moments stream for inspection; the layer is still
            // not restorable (private step counter).
            OwnedOpt::AdamW(o) => {
                ck.tensors.push((format!("am{li}"), dims.clone(),
                                 o.m.data.clone()));
                ck.tensors.push((format!("av{li}"), dims, o.v.data.clone()));
            }
            OwnedOpt::SignSgd(_) => {}
        }
    }

    /// Restore weight + optimizer state from checkpoint tensors. Dims
    /// are validated *before* any `Mat::from_vec`/`restore_factors` call
    /// — those assert, and this runs on daemon-received bytes.
    fn restore_from(
        &mut self,
        li: usize,
        map: &mut BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    ) -> Result<()> {
        let (m, n) = (self.w.rows, self.w.cols);
        let take = |map: &mut BTreeMap<String, (Vec<usize>, Vec<f32>)>,
                    name: String,
                    want: &[usize]|
         -> Result<Vec<f32>> {
            let (dims, data) = map
                .remove(&name)
                .ok_or_else(|| anyhow::anyhow!("missing tensor {name}"))?;
            if dims != want {
                bail!("{name}: dims {dims:?}, want {want:?}");
            }
            Ok(data)
        };
        let wd = take(map, format!("w{li}"), &[m, n])?;
        self.w = Mat::from_vec(m, n, wd);
        match &mut self.opt {
            OwnedOpt::MoFaSgd(o) => {
                let r = o.s.len();
                let u = take(map, format!("u{li}"), &[m, r])?;
                let s = take(map, format!("s{li}"), &[r])?;
                let v = take(map, format!("v{li}"), &[n, r])?;
                o.restore_factors(Mat::from_vec(m, r, u), s,
                                  Mat::from_vec(n, r, v));
            }
            OwnedOpt::Muon(o) => {
                o.m = Mat::from_vec(m, n, take(map, format!("m{li}"),
                                               &[m, n])?);
            }
            OwnedOpt::SgdM(o) => {
                o.m = Mat::from_vec(m, n, take(map, format!("m{li}"),
                                               &[m, n])?);
            }
            OwnedOpt::SignSgd(_) => {}
            OwnedOpt::AdamW(_) => {
                bail!("layer {li}: adamw is not restorable (private step \
                       counter)");
            }
        }
        Ok(())
    }
}

impl SessLayer {
    /// Free the mid-tick scratch (lanes, micro-noise) after the owning
    /// session failed: the buffers are indeterminate and must never be
    /// read again. Weights/targets are kept so `loss()` still answers.
    pub(crate) fn quarantine(&mut self) {
        self.lanes = Vec::new();
        self.micros = Vec::new();
        self.written = 0;
    }
}

impl FleetUnit for SessLayer {
    fn n_stages(&self) -> usize {
        self.accum + self.sched.pairs().len() + 1 + self.n_step
    }

    fn run_stage(&mut self, stage: usize) {
        faultinject::stage_point(&[("session", self.session as u64),
                                   ("stage", stage as u64)]);
        let accum = self.accum;
        let n_red = self.sched.pairs().len();
        if stage < accum {
            // Fused micro-gradient accumulation: the gradient expression
            // `(w − w*) + noise·z` writes straight into the lane — per
            // element the same f32 value, in the same fold order, as
            // materializing the gradient and running `GradAccumUnit`
            // (the replicated engine's accumulation contract, R = 1).
            if stage == 0 {
                self.written = 0;
            }
            let lane = self.sched.lane_of_item(stage);
            let nz = &self.micros[stage];
            let noise = self.noise;
            let dst = &mut self.lanes[lane];
            if self.written & (1u64 << lane) == 0 {
                dst.reset(self.w.rows, self.w.cols);
                for i in 0..self.w.data.len() {
                    dst.data[i] = (self.w.data[i] - self.target.data[i])
                        + noise * nz[i];
                }
                self.written |= 1u64 << lane;
            } else {
                for i in 0..self.w.data.len() {
                    dst.data[i] += (self.w.data[i] - self.target.data[i])
                        + noise * nz[i];
                }
            }
        } else if stage < accum + n_red {
            let (d, s) = self.sched.pairs()[stage - accum];
            // TreeSchedule pairs always fold a higher lane into a lower
            // one — split there for two disjoint &mut lanes.
            assert!(d < s, "tree pair order");
            let (head, tail) = self.lanes.split_at_mut(s);
            reduce::fold_lane(&mut head[d].data, &tail[0].data,
                              crate::fusion::workers());
        } else if stage == accum + n_red {
            reduce::scale_lane(&mut self.lanes[0].data, self.inv_micro);
        } else {
            let ss = stage - (accum + n_red + 1);
            let mut mo = self.opt.as_mat_opt();
            self.stager.run_stage(&mut mo, &mut self.w,
                                  &self.lanes[0], self.eta, ss);
        }
    }

    fn session(&self) -> u32 {
        self.session
    }
}

/// One flat (vec-routed) layer: same chain shape as [`SessLayer`] with
/// lanes stored as 1×len Mats and a single AdamW step stage.
pub struct SessVecLayer {
    session: u32,
    w: Vec<f32>,
    target: Vec<f32>,
    opt: AdamWVec,
    sched: TreeSchedule,
    lanes: Vec<Mat>,
    micros: Vec<Vec<f32>>,
    rng_noise: Rng,
    noise: f32,
    eta: f32,
    inv_micro: f32,
    accum: usize,
    written: u64,
}

impl SessVecLayer {
    fn new(session: u32, vi: usize, len: usize, spec: &SessionSpec)
           -> SessVecLayer {
        let mut rw = layer_rng(spec.seed, vec_tag(vi, 0));
        let w = rw.normal_vec(len, 1.0);
        let mut rt = layer_rng(spec.seed, vec_tag(vi, 1));
        let target = rt.normal_vec(len, 1.0);
        let sched = TreeSchedule::new(spec.accum, reduce::TREE_WIDTH);
        assert!(sched.width() <= 64, "written bitmask width");
        let lanes = (0..sched.width()).map(|_| Mat::zeros(1, len)).collect();
        let micros = (0..spec.accum).map(|_| vec![0.0f32; len]).collect();
        SessVecLayer {
            session,
            w,
            target,
            opt: AdamWVec::new(len, 0.9, 0.999, 0.0),
            sched,
            lanes,
            micros,
            rng_noise: layer_rng(spec.seed, vec_tag(vi, 2)),
            noise: spec.noise,
            eta: spec.eta,
            inv_micro: 1.0 / spec.accum as f32,
            accum: spec.accum,
            written: 0,
        }
    }

    fn fill_micros(&mut self, step: usize) {
        for (k, buf) in self.micros.iter_mut().enumerate() {
            let mut r = self
                .rng_noise
                .shard_stream((step * self.accum + k) as u64);
            for x in buf.iter_mut() {
                *x = r.normal_f32();
            }
        }
    }

    fn copy_micros(&mut self, src: &[Vec<f32>]) -> std::result::Result<(), String> {
        if src.len() != self.accum {
            return Err("noise stream micro count mismatch".to_string());
        }
        for (buf, s) in self.micros.iter_mut().zip(src) {
            if s.len() != buf.len() {
                return Err("noise stream buffer size mismatch".to_string());
            }
            buf.copy_from_slice(s);
        }
        Ok(())
    }

    fn loss(&self) -> f64 {
        let mut acc = 0.0f64;
        for (w, t) in self.w.iter().zip(&self.target) {
            let d = (w - t) as f64;
            acc += d * d;
        }
        0.5 * acc
    }

    fn save_into(&self, vi: usize, ck: &mut Checkpoint) {
        let dims = vec![self.w.len()];
        ck.tensors.push((format!("vw{vi}"), dims.clone(), self.w.clone()));
        ck.tensors
            .push((format!("vm{vi}"), dims.clone(), self.opt.m.clone()));
        ck.tensors.push((format!("vv{vi}"), dims, self.opt.v.clone()));
    }
}

impl SessVecLayer {
    /// See [`SessLayer::quarantine`].
    pub(crate) fn quarantine(&mut self) {
        self.lanes = Vec::new();
        self.micros = Vec::new();
        self.written = 0;
    }
}

impl FleetUnit for SessVecLayer {
    fn n_stages(&self) -> usize {
        self.accum + self.sched.pairs().len() + 1 + 1
    }

    fn run_stage(&mut self, stage: usize) {
        faultinject::stage_point(&[("session", self.session as u64),
                                   ("stage", stage as u64)]);
        let accum = self.accum;
        let n_red = self.sched.pairs().len();
        if stage < accum {
            if stage == 0 {
                self.written = 0;
            }
            let lane = self.sched.lane_of_item(stage);
            let nz = &self.micros[stage];
            let noise = self.noise;
            let dst = &mut self.lanes[lane];
            if self.written & (1u64 << lane) == 0 {
                dst.reset(1, self.w.len());
                for i in 0..self.w.len() {
                    dst.data[i] =
                        (self.w[i] - self.target[i]) + noise * nz[i];
                }
                self.written |= 1u64 << lane;
            } else {
                for i in 0..self.w.len() {
                    dst.data[i] +=
                        (self.w[i] - self.target[i]) + noise * nz[i];
                }
            }
        } else if stage < accum + n_red {
            let (d, s) = self.sched.pairs()[stage - accum];
            assert!(d < s, "tree pair order");
            let (head, tail) = self.lanes.split_at_mut(s);
            reduce::fold_lane(&mut head[d].data, &tail[0].data,
                              crate::fusion::workers());
        } else if stage == accum + n_red {
            reduce::scale_lane(&mut self.lanes[0].data, self.inv_micro);
        } else {
            self.opt.step(&mut self.w, &self.lanes[0].data, self.eta);
        }
    }

    fn session(&self) -> u32 {
        self.session
    }
}

/// One tick's noise for every layer of a session: `data[li*accum + k]`
/// is layer `li`'s micro-`k` buffer (matrix layers first, then vec
/// layers). Carries its step so a desynchronized stream is detected,
/// not silently consumed.
pub struct TickNoise {
    pub step: usize,
    pub data: Vec<Vec<f32>>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionState {
    Running,
    Paused,
    Done,
    Failed,
}

impl SessionState {
    pub fn name(self) -> &'static str {
        match self {
            SessionState::Running => "running",
            SessionState::Paused => "paused",
            SessionState::Done => "done",
            SessionState::Failed => "failed",
        }
    }
}

/// A live session: per-layer fleet units plus the optional prefetched
/// noise source and the admit-time spec.
pub struct Session {
    pub id: u32,
    pub name: String,
    pub state: SessionState,
    pub step: usize,
    pub steps: usize,
    accum: usize,
    pub(crate) layers: Vec<SessLayer>,
    pub(crate) vlayers: Vec<SessVecLayer>,
    source: Option<Prefetcher<TickNoise>>,
    pub(crate) spec: SessionSpec,
    /// Why the session is [`SessionState::Failed`] (None otherwise).
    fail_reason: Option<String>,
}

impl Session {
    /// Build a session at `start_step` (0 on admit; the saved step on
    /// restore, so the noise stream resumes at the right global index).
    pub fn build(id: u32, spec: &SessionSpec, start_step: usize) -> Session {
        let layers = spec
            .layers
            .iter()
            .enumerate()
            .map(|(li, l)| SessLayer::new(id, li, l, spec))
            .collect();
        let vlayers = spec
            .vecs
            .iter()
            .enumerate()
            .map(|(vi, v)| SessVecLayer::new(id, vi, v.len, spec))
            .collect();
        let source =
            (spec.prefetch > 0).then(|| spawn_noise_stream(spec, start_step));
        Session {
            id,
            name: spec.name.clone(),
            state: SessionState::Running,
            step: start_step,
            steps: spec.steps,
            accum: spec.accum,
            layers,
            vlayers,
            source,
            spec: spec.clone(),
            fail_reason: None,
        }
    }

    /// Stage this tick's noise into every layer. An exhausted or
    /// desynchronized prefetch stream is this session's failure — the
    /// caller moves it to [`SessionState::Failed`]; the daemon ticks on.
    pub fn begin_tick(&mut self) -> std::result::Result<(), String> {
        let step = self.step;
        if let Some(src) = &self.source {
            let tn = src
                .next()
                .ok_or_else(|| "noise stream ended early".to_string())?;
            if tn.step != step {
                return Err(format!(
                    "noise stream out of sync: got step {}, want {step}",
                    tn.step
                ));
            }
            let n_bufs = (self.layers.len() + self.vlayers.len()) * self.accum;
            if tn.data.len() != n_bufs {
                return Err("noise stream layer count mismatch".to_string());
            }
            let accum = self.accum;
            for (li, l) in self.layers.iter_mut().enumerate() {
                l.copy_micros(&tn.data[li * accum..(li + 1) * accum])?;
            }
            let off = self.layers.len();
            for (vi, v) in self.vlayers.iter_mut().enumerate() {
                v.copy_micros(
                    &tn.data[(off + vi) * accum..(off + vi + 1) * accum])?;
            }
        } else {
            for l in &mut self.layers {
                l.fill_micros(step);
            }
            for v in &mut self.vlayers {
                v.fill_micros(step);
            }
        }
        Ok(())
    }

    /// Advance the step counter after the dispatch ran this session's
    /// chains; returns `(completed_step, loss)` for the metrics stream.
    pub fn end_tick(&mut self) -> (usize, f64) {
        self.step += 1;
        let loss = self.loss();
        if self.step >= self.steps {
            self.state = SessionState::Done;
            self.source = None;
        }
        (self.step, loss)
    }

    /// Total loss across all layers, in f64 so the metrics stream is a
    /// bit-stable parity signal.
    pub fn loss(&self) -> f64 {
        self.layers.iter().map(|l| l.loss()).sum::<f64>()
            + self.vlayers.iter().map(|v| v.loss()).sum::<f64>()
    }

    /// Move the session to [`SessionState::Failed`], recording `reason`,
    /// dropping the noise source, and quarantining the per-layer scratch
    /// buffers: after a mid-tick panic the lanes/micros are
    /// indeterminate, so they are freed rather than ever read again
    /// (weights and targets are kept — `loss()` and status stay
    /// answerable). A failed session is never ticked again; `evict` is
    /// the remedy.
    pub(crate) fn fail_with(&mut self, reason: String) {
        self.state = SessionState::Failed;
        self.fail_reason = Some(reason);
        self.source = None;
        for l in &mut self.layers {
            l.quarantine();
        }
        for v in &mut self.vlayers {
            v.quarantine();
        }
    }

    /// The recorded failure reason, if the session is Failed.
    pub fn fail_reason(&self) -> Option<&str> {
        self.fail_reason.as_deref()
    }

    /// Snapshot weights + optimizer state. Any session can be
    /// checkpointed (AdamW moments included, for inspection); only
    /// all-restorable specs can be restored.
    pub fn checkpoint(&self) -> Checkpoint {
        let mut ck = Checkpoint { tensors: Vec::new() };
        for (li, l) in self.layers.iter().enumerate() {
            l.save_into(li, &mut ck);
        }
        for (vi, v) in self.vlayers.iter().enumerate() {
            v.save_into(vi, &mut ck);
        }
        ck
    }

    /// Restore state from a checkpoint of the same spec. Requires every
    /// layer kind to be externally restorable and consumes every tensor
    /// — leftovers mean the checkpoint doesn't match the spec.
    pub fn restore_state(&mut self, ck: &Checkpoint) -> Result<()> {
        for (li, l) in self.spec.layers.iter().enumerate() {
            if !l.kind.restorable() {
                bail!("layer {li} ({}) is not restorable", l.kind.name());
            }
        }
        if !self.spec.vecs.is_empty() {
            bail!("vec layers (adamw) are not restorable");
        }
        let mut map: BTreeMap<String, (Vec<usize>, Vec<f32>)> =
            BTreeMap::new();
        for (name, dims, data) in &ck.tensors {
            map.insert(name.clone(), (dims.clone(), data.clone()));
        }
        for (li, l) in self.layers.iter_mut().enumerate() {
            l.restore_from(li, &mut map)?;
        }
        if !map.is_empty() {
            let names: Vec<&str> =
                map.keys().map(|s| s.as_str()).collect();
            bail!("unconsumed checkpoint tensors: {names:?}");
        }
        Ok(())
    }
}

/// Producer for the bounded prefetch pipeline: regenerates each layer's
/// noise rng from the session seed (so its bytes match the inline path
/// bit for bit) and ends the stream cleanly at `steps` — the
/// `data::loader` end-of-stream contract, not a panic.
fn spawn_noise_stream(spec: &SessionSpec, start_step: usize)
                      -> Prefetcher<TickNoise> {
    let accum = spec.accum;
    let steps = spec.steps;
    let seed = spec.seed;
    let shapes: Vec<(u64, usize)> = spec
        .layers
        .iter()
        .enumerate()
        .map(|(li, l)| (mat_tag(li, 2), l.m * l.n))
        .chain(spec.vecs.iter().enumerate()
            .map(|(vi, v)| (vec_tag(vi, 2), v.len)))
        .collect();
    let mut step = start_step;
    Prefetcher::spawn_with(spec.prefetch, move || {
        if step >= steps {
            return None;
        }
        let mut data = Vec::with_capacity(shapes.len() * accum);
        for &(tag, numel) in &shapes {
            let base = layer_rng(seed, tag);
            for k in 0..accum {
                let mut r = base.shard_stream((step * accum + k) as u64);
                let mut buf = vec![0.0f32; numel];
                for x in buf.iter_mut() {
                    *x = r.normal_f32();
                }
                data.push(buf);
            }
        }
        let tn = TickNoise { step, data };
        step += 1;
        Some(tn)
    })
}

//! Crash-safe per-session checkpoint store for the serve daemon.
//!
//! Layout: one subdirectory per session name under the store root
//! (`{sanitized-name}-{crc32(name):08x}` — the hash disambiguates names
//! that sanitize to the same string), holding snapshot files
//! `step-{step:08}.mofs`. Each snapshot is written through
//! `fsio::atomic_write_crc` (write-to-temp + `sync_all` + atomic rename
//! + CRC32 footer), so a crash mid-save can tear at most a file that
//! never replaced the previous good one — and a torn file that somehow
//! reaches the final path (legacy writes, injected faults) fails its
//! CRC on load and is skipped, never fatal.
//!
//! Retention: the newest two snapshots are kept after every save, so a
//! torn newest still leaves a last-good predecessor to recover from.
//! Sessions are keyed by *name*: re-admitting the same name appends to
//! the same directory, and recovery yields that name's newest valid
//! snapshot.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::coordinator::checkpoint::Checkpoint;
use crate::util::json::Json;
use crate::util::{fsio, logging};

use super::protocol::SessionSpec;

/// Snapshot container magic ("MOFA serve"); the payload after the meta
/// block is a `Checkpoint::to_bytes` body.
const SNAP_MAGIC: &[u8; 4] = b"MOFS";
const SNAP_VERSION: u32 = 1;
/// Snapshots retained per session after each save (newest first). Two,
/// so the invariant "a torn newest leaves a good previous" holds.
const RETAIN: usize = 2;

/// What one recovered snapshot re-admits: the admit-time spec, the step
/// the checkpoint was taken at, and the state itself.
pub struct RecoveredSession {
    pub spec: SessionSpec,
    pub step: usize,
    pub ck: Checkpoint,
}

pub struct CheckpointStore {
    root: PathBuf,
}

/// Filesystem-safe session directory stem: keep `[A-Za-z0-9._-]`,
/// replace the rest with `_`, never start with a dot. Session names are
/// only length-validated at the wire (`SessionSpec::validate`), so they
/// may contain `/`, `..`, or arbitrary bytes.
fn safe_name(name: &str) -> String {
    let mut out: String = name
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') {
                c
            } else {
                '_'
            }
        })
        .collect();
    if out.starts_with('.') {
        out.replace_range(0..1, "_");
    }
    out
}

impl CheckpointStore {
    pub fn new(root: impl Into<PathBuf>) -> CheckpointStore {
        CheckpointStore { root: root.into() }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Directory holding one session name's snapshots.
    pub fn session_dir(&self, name: &str) -> PathBuf {
        self.root.join(format!("{}-{:08x}", safe_name(name),
                               fsio::crc32(name.as_bytes())))
    }

    /// Persist one snapshot; prunes the session's directory down to the
    /// newest [`RETAIN`] snapshots afterwards. Returns the written path.
    pub fn save(&self, spec: &SessionSpec, step: usize, ck: &Checkpoint)
                -> Result<PathBuf> {
        let meta = Json::obj(vec![
            ("version", Json::Num(SNAP_VERSION as f64)),
            ("step", Json::Num(step as f64)),
            ("spec", spec.to_json()),
        ])
        .emit(0);
        let body = ck.to_bytes()?;
        let mut payload =
            Vec::with_capacity(12 + meta.len() + body.len());
        payload.extend_from_slice(SNAP_MAGIC);
        payload.extend_from_slice(&SNAP_VERSION.to_le_bytes());
        payload.extend_from_slice(&(meta.len() as u32).to_le_bytes());
        payload.extend_from_slice(meta.as_bytes());
        payload.extend_from_slice(&body);
        let dir = self.session_dir(&spec.name);
        let path = dir.join(format!("step-{step:08}.mofs"));
        fsio::atomic_write_crc(&path, &payload)
            .with_context(|| format!("write {}", path.display()))?;
        self.prune(&dir);
        Ok(path)
    }

    /// Keep only the newest [`RETAIN`] snapshots (zero-padded step in
    /// the filename makes lexicographic order chronological).
    fn prune(&self, dir: &Path) {
        let mut snaps = list_snapshots(dir);
        while snaps.len() > RETAIN {
            let victim = snaps.remove(0); // oldest first in the sorted list
            if let Err(e) = std::fs::remove_file(&victim) {
                logging::warn(format!(
                    "store: prune {} failed: {e}", victim.display()));
            }
        }
    }

    /// Parse one snapshot file, CRC-verified. Every malformation is an
    /// `Err` — recovery warn-skips them.
    pub fn load_snapshot(path: &Path) -> Result<RecoveredSession> {
        let payload = fsio::read_crc(path)
            .with_context(|| format!("read {}", path.display()))?;
        if payload.len() < 12 || &payload[..4] != SNAP_MAGIC {
            bail!("{}: not a serve snapshot", path.display());
        }
        let version = u32::from_le_bytes([
            payload[4], payload[5], payload[6], payload[7],
        ]);
        if version != SNAP_VERSION {
            bail!("{}: unsupported snapshot version {version}",
                  path.display());
        }
        let meta_len = u32::from_le_bytes([
            payload[8], payload[9], payload[10], payload[11],
        ]) as usize;
        let body_at = 12usize.checked_add(meta_len)
            .filter(|&end| end <= payload.len())
            .ok_or_else(|| anyhow::anyhow!(
                "{}: meta length out of bounds", path.display()))?;
        let meta = std::str::from_utf8(&payload[12..body_at])
            .with_context(|| format!("{}: meta utf8", path.display()))?;
        let meta = Json::parse(meta)
            .map_err(|e| anyhow::anyhow!(
                "{}: meta json: {e}", path.display()))?;
        let step = meta.req("step")?.as_usize()?;
        let spec = SessionSpec::from_json(meta.req("spec")?)?;
        let ck = Checkpoint::from_bytes(&payload[body_at..])
            .with_context(|| format!("parse {}", path.display()))?;
        Ok(RecoveredSession { spec, step, ck })
    }

    /// Scan the store and yield the newest valid snapshot of every
    /// session directory, in deterministic (sorted) directory order.
    /// Torn, CRC-failing, or unparsable snapshots are warn-skipped —
    /// recovery NEVER fails on bad files; a session with no valid
    /// snapshot is simply not recovered.
    pub fn recover_all(&self) -> Vec<RecoveredSession> {
        let mut out = Vec::new();
        let entries = match std::fs::read_dir(&self.root) {
            Ok(e) => e,
            Err(_) => return out, // no store directory yet
        };
        let mut dirs: Vec<PathBuf> = entries
            .flatten()
            .map(|e| e.path())
            .filter(|p| p.is_dir())
            .collect();
        dirs.sort();
        for dir in dirs {
            let mut snaps = list_snapshots(&dir);
            snaps.reverse(); // newest first
            let mut recovered = false;
            for snap in &snaps {
                match CheckpointStore::load_snapshot(snap) {
                    Ok(r) => {
                        out.push(r);
                        recovered = true;
                        break;
                    }
                    Err(e) => {
                        logging::warn(format!(
                            "store: skipping snapshot {}: {e:#}",
                            snap.display()));
                    }
                }
            }
            if !recovered && !snaps.is_empty() {
                logging::warn(format!(
                    "store: no valid snapshot in {}; session not \
                     recovered", dir.display()));
            }
        }
        out
    }
}

/// Snapshot files of `dir`, sorted oldest → newest.
fn list_snapshots(dir: &Path) -> Vec<PathBuf> {
    let mut snaps: Vec<PathBuf> = match std::fs::read_dir(dir) {
        Ok(entries) => entries
            .flatten()
            .map(|e| e.path())
            .filter(|p| {
                p.extension().map(|x| x == "mofs").unwrap_or(false)
            })
            .collect(),
        Err(_) => Vec::new(),
    };
    snaps.sort();
    snaps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::protocol::{LayerKind, LayerSpec};

    fn spec(name: &str) -> SessionSpec {
        SessionSpec {
            name: name.to_string(),
            seed: 7,
            steps: 4,
            accum: 1,
            eta: 0.01,
            noise: 0.0,
            prefetch: 0,
            layers: vec![LayerSpec {
                kind: LayerKind::SgdM,
                m: 4,
                n: 3,
                rank: 2,
                beta: 0.9,
            }],
            vecs: vec![],
        }
    }

    fn ck() -> Checkpoint {
        Checkpoint {
            tensors: vec![("w0".into(), vec![2, 2],
                           vec![1.0, 2.0, 3.0, 4.0])],
        }
    }

    fn tmp_store(tag: &str) -> CheckpointStore {
        let d = std::env::temp_dir()
            .join(format!("mofa-store-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        CheckpointStore::new(d)
    }

    #[test]
    fn save_recover_roundtrip_and_retention() {
        let store = tmp_store("rt");
        let sp = spec("alpha");
        for step in 1..=4 {
            store.save(&sp, step, &ck()).unwrap();
        }
        // Retention: only the newest two snapshots remain.
        let snaps = list_snapshots(&store.session_dir("alpha"));
        assert_eq!(snaps.len(), 2);
        let rec = store.recover_all();
        assert_eq!(rec.len(), 1);
        assert_eq!(rec[0].spec.name, "alpha");
        assert_eq!(rec[0].step, 4);
        assert_eq!(rec[0].ck.tensors[0].2, vec![1.0, 2.0, 3.0, 4.0]);
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn corrupt_newest_falls_back_to_last_good() {
        let store = tmp_store("fall");
        let sp = spec("beta");
        store.save(&sp, 1, &ck()).unwrap();
        let newest = store.save(&sp, 2, &ck()).unwrap();
        // Tear the newest snapshot (simulated crash mid-write of a
        // legacy in-place writer): recovery must fall back to step 1.
        let raw = std::fs::read(&newest).unwrap();
        std::fs::write(&newest, &raw[..raw.len() / 2]).unwrap();
        let rec = store.recover_all();
        assert_eq!(rec.len(), 1);
        assert_eq!(rec[0].step, 1);
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn hostile_session_names_stay_inside_the_store() {
        let store = tmp_store("names");
        for name in ["../escape", "a/b/c", ".hidden", "ok-name_1"] {
            let dir = store.session_dir(name);
            assert!(dir.starts_with(store.root()), "{name}");
            assert_eq!(dir.components().count(),
                       store.root().components().count() + 1, "{name}");
            let stem = dir.file_name().unwrap().to_str().unwrap();
            assert!(!stem.starts_with('.'), "{name}");
        }
        // Distinct hostile names that sanitize identically still get
        // distinct directories (name hash).
        assert_ne!(store.session_dir("a/b"), store.session_dir("a_b"));
        let _ = std::fs::remove_dir_all(store.root());
    }

    #[test]
    fn recover_on_missing_or_empty_store_is_empty() {
        let store = tmp_store("empty");
        assert!(store.recover_all().is_empty());
        std::fs::create_dir_all(store.root()).unwrap();
        assert!(store.recover_all().is_empty());
        let _ = std::fs::remove_dir_all(store.root());
    }
}

//! Multi-tenant session scheduler: admits sessions, runs them in
//! lockstep ticks, and flattens every active session's per-layer stage
//! chains into ONE shared fleet dispatch per tick
//! ([`crate::fusion::fleet::Fleet::run_fair`] — fair-share round-robin
//! across session groups, so a tenant with many layers cannot starve
//! one with few).
//!
//! **Parity.** Sessions are independent (each layer touches only its
//! own state) and every layer's chain runs strictly in stage order, so
//! a multiplexed tick is bit-identical to running each session alone —
//! at every worker count (`rust/tests/serve_parity.rs`).
//!
//! **Allocation.** With `workers <= 1` the tick runs every chain inline
//! without building a dispatch table: a warm tick is zero-alloc
//! (extend of the counting-allocator proof in
//! `rust/tests/fusion_alloc.rs`), provided sessions use inline noise
//! (`prefetch = 0`) and no Muon layers (Newton–Schulz allocates its
//! iterates per call).

use anyhow::{bail, Result};

use crate::coordinator::checkpoint::Checkpoint;
use crate::fusion::fleet::{Fleet, FleetUnit};
use crate::obs;
use crate::util::faultinject;
use crate::util::logging;
use crate::util::pool;

use super::protocol::SessionSpec;
use super::session::{Session, SessionState};

/// Most sessions a daemon will hold at once (any state); a hostile
/// client looping `admit` hits an error, not an OOM.
pub const MAX_SESSIONS: usize = 64;

/// What one tick produced, for the daemon to route to owning clients.
/// `Metrics`/`Done` are allocation-free; `Failed` carries its reason.
#[derive(Debug)]
pub enum TickEvent {
    Metrics { session: u32, step: usize, loss: f64 },
    Done { session: u32, step: usize },
    Failed { session: u32, msg: String },
}

pub struct SessionManager {
    sessions: Vec<Session>,
    fleet: Fleet,
    next_id: u32,
    ticks: u64,
}

impl Default for SessionManager {
    fn default() -> SessionManager {
        SessionManager::new()
    }
}

impl SessionManager {
    pub fn new() -> SessionManager {
        SessionManager {
            sessions: Vec::new(),
            fleet: Fleet::new(),
            next_id: 1,
            ticks: 0,
        }
    }

    /// Admit a new session (starts Running at step 0). Session ids are
    /// monotonic from 1 — id 0 is the fleet's "no session" tag.
    pub fn admit(&mut self, spec: &SessionSpec) -> Result<u32> {
        spec.validate()?;
        if self.sessions.len() >= MAX_SESSIONS {
            bail!("session limit {MAX_SESSIONS} reached");
        }
        let id = self.next_id;
        self.next_id += 1;
        self.sessions.push(Session::build(id, spec, 0));
        Ok(id)
    }

    /// Admit a session resumed from a checkpoint at `step`: requires an
    /// all-restorable spec (no AdamW matrix layers, no vec layers) and
    /// a checkpoint that exactly matches it.
    pub fn restore(&mut self, spec: &SessionSpec, step: usize,
                   ck: &Checkpoint) -> Result<u32> {
        spec.validate()?;
        if step > spec.steps {
            bail!("restore step {step} beyond spec steps {}", spec.steps);
        }
        if self.sessions.len() >= MAX_SESSIONS {
            bail!("session limit {MAX_SESSIONS} reached");
        }
        let id = self.next_id;
        let mut sess = Session::build(id, spec, step);
        sess.restore_state(ck)?;
        if step >= spec.steps {
            sess.state = SessionState::Done;
        }
        self.next_id += 1;
        self.sessions.push(sess);
        Ok(id)
    }

    fn find_mut(&mut self, id: u32) -> Result<&mut Session> {
        self.sessions
            .iter_mut()
            .find(|s| s.id == id)
            .ok_or_else(|| anyhow::anyhow!("no session {id}"))
    }

    pub fn get(&self, id: u32) -> Option<&Session> {
        self.sessions.iter().find(|s| s.id == id)
    }

    pub fn sessions(&self) -> &[Session] {
        &self.sessions
    }

    pub fn n_running(&self) -> usize {
        self.sessions
            .iter()
            .filter(|s| s.state == SessionState::Running)
            .count()
    }

    pub fn pause(&mut self, id: u32) -> Result<()> {
        let s = self.find_mut(id)?;
        if s.state != SessionState::Running {
            bail!("session {id} is {}, not running", s.state.name());
        }
        s.state = SessionState::Paused;
        Ok(())
    }

    pub fn resume(&mut self, id: u32) -> Result<()> {
        let s = self.find_mut(id)?;
        if s.state != SessionState::Paused {
            bail!("session {id} is {}, not paused", s.state.name());
        }
        s.state = SessionState::Running;
        Ok(())
    }

    /// Remove a session in any state, dropping its prefetcher.
    pub fn evict(&mut self, id: u32) -> Result<()> {
        let n = self.sessions.len();
        self.sessions.retain(|s| s.id != id);
        if self.sessions.len() == n {
            bail!("no session {id}");
        }
        Ok(())
    }

    /// Snapshot a session's state; returns its current step too, so the
    /// pair can later seed a `restore`. Refused for Failed sessions:
    /// their mid-tick buffers were quarantined and the surviving weights
    /// are from an indeterminate point of the failed tick.
    pub fn checkpoint(&self, id: u32) -> Result<(usize, Checkpoint)> {
        let s = self
            .get(id)
            .ok_or_else(|| anyhow::anyhow!("no session {id}"))?;
        if s.state == SessionState::Failed {
            bail!("session {id} is failed; its buffers are quarantined \
                   (evict to remove)");
        }
        Ok((s.step, s.checkpoint()))
    }

    /// Lockstep ticks completed so far.
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Run one lockstep tick over every Running session: stage this
    /// tick's noise, flatten all sessions' layer chains into one
    /// fair-share fleet dispatch, then advance steps and emit events
    /// into `events` (not cleared here — the caller owns the buffer so
    /// a warm tick stays allocation-free).
    pub fn tick(&mut self, workers: usize, events: &mut Vec<TickEvent>) {
        let n_running = self.n_running();
        if n_running == 0 {
            return;
        }
        self.ticks += 1;
        faultinject::set_tick(self.ticks);
        obs::counter_add(obs::Counter::Ticks, 1);
        obs::counter_max(obs::Counter::SessionsActive, n_running as u64);
        let _sp = obs::span_args(
            obs::Category::Engine, "serve_tick",
            [self.ticks as u32, n_running as u32, workers as u32]);
        for s in &mut self.sessions {
            if s.state != SessionState::Running {
                continue;
            }
            if let Err(msg) = s.begin_tick() {
                events.push(TickEvent::Failed {
                    session: s.id,
                    msg: msg.clone(),
                });
                s.fail_with(msg);
            }
        }
        // A begin failure may have emptied the running set.
        if self.sessions.iter().all(|s| s.state != SessionState::Running) {
            return;
        }
        if workers <= 1 {
            // Inline drain in dispatch order, without building the unit
            // table — the same per-chain stage order `run_fair` produces
            // at any worker count, and zero-alloc when warm. A stage
            // panic is contained to its session, mirroring the
            // dispatched path: the session's remaining stages are
            // skipped and it moves to Failed while survivors tick on.
            //
            // Runs one unit's whole chain inline; `Some(msg)` if a
            // stage panicked.
            fn run_unit_inline(u: &mut dyn FleetUnit, li: u32, sess: u32)
                               -> Option<String> {
                for st in 0..u.n_stages() {
                    let run = {
                        let _st = obs::span_args(
                            obs::Category::Fleet, "stage",
                            [li, st as u32, sess]);
                        std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(
                                || u.run_stage(st)))
                    };
                    if let Err(payload) = run {
                        let msg =
                            pool::panic_payload_msg(payload.as_ref());
                        return Some(format!(
                            "fleet unit {li} stage {st}: {msg}"));
                    }
                    obs::counter_add(obs::Counter::FleetStages, 1);
                }
                None
            }
            let sessions = &mut self.sessions;
            crate::fusion::with_workers(1, || {
                let mut li = 0u32;
                for s in sessions.iter_mut() {
                    if s.state != SessionState::Running {
                        continue;
                    }
                    let sess = s.id;
                    let mut failure: Option<String> = None;
                    for l in &mut s.layers {
                        if failure.is_none() {
                            failure = run_unit_inline(l, li, sess);
                        }
                        li += 1;
                    }
                    for v in &mut s.vlayers {
                        if failure.is_none() {
                            failure = run_unit_inline(v, li, sess);
                        }
                        li += 1;
                    }
                    if let Some(msg) = failure {
                        logging::warn(format!(
                            "serve: session {sess} failed mid-tick \
                             ({msg}); quarantined, survivors continue"));
                        events.push(TickEvent::Failed {
                            session: sess,
                            msg: msg.clone(),
                        });
                        s.fail_with(msg);
                    }
                }
            });
        } else {
            let SessionManager { sessions, fleet, .. } = self;
            let mut refs: Vec<&mut dyn FleetUnit> = Vec::new();
            for s in sessions.iter_mut() {
                if s.state != SessionState::Running {
                    continue;
                }
                for l in &mut s.layers {
                    refs.push(l);
                }
                for v in &mut s.vlayers {
                    refs.push(v);
                }
            }
            let outcomes = fleet.run_fair(&mut refs, workers);
            for oc in outcomes {
                let Some(msg) = &oc.failed else { continue };
                logging::warn(format!(
                    "serve: session {} failed mid-tick ({msg}); \
                     quarantined, survivors continue", oc.session));
                events.push(TickEvent::Failed {
                    session: oc.session,
                    msg: msg.clone(),
                });
                if let Some(s) =
                    sessions.iter_mut().find(|s| s.id == oc.session)
                {
                    s.fail_with(msg.clone());
                }
            }
        }
        for s in &mut self.sessions {
            if s.state != SessionState::Running {
                continue;
            }
            let (step, loss) = s.end_tick();
            events.push(TickEvent::Metrics { session: s.id, step, loss });
            if s.state == SessionState::Done {
                events.push(TickEvent::Done { session: s.id, step });
            }
        }
    }
}

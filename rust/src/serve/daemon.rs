//! The `mofasgd serve` daemon: newline-delimited JSON over a local TCP
//! or Unix socket, multiplexing concurrent fine-tuning sessions over
//! one [`SessionManager`].
//!
//! Threading model: one detached accept thread; per connection, one
//! detached reader thread (line reads capped at [`MAX_LINE_BYTES`])
//! funneling [`Inbound`] messages into an mpsc channel the single tick
//! loop owns, and one writer thread draining a bounded outbound line
//! queue onto the socket under a per-write timeout. The tick loop
//! blocks on the channel while no session is Running (idle daemon burns
//! no CPU), otherwise drains pending requests non-blockingly and runs
//! one lockstep tick. The tick loop never touches a socket: responses
//! and events are enqueued with a non-blocking `try_send` — a slow or
//! dead client only ever loses its own stream: its queue fills (or its
//! write times out), its writer is dropped, and its sessions keep
//! running detached (reconnection/ownership transfer is out of scope;
//! `evict` is the remedy).
//!
//! Durability: with [`ServeOpts::store_dir`] set, the tick loop
//! snapshots sessions into a crash-safe [`CheckpointStore`] (every
//! session on its finishing tick; every running session each
//! `auto_checkpoint` ticks), and [`ServeOpts::recover`] re-admits the
//! newest valid snapshot of every stored session at startup —
//! torn/CRC-failing files are warn-skipped, never fatal. Contract
//! details in DESIGN.md §15.
//!
//! Robustness contract: any byte sequence a client sends is answered
//! with `{"ok":false,...}` at worst — `protocol::parse_request` and
//! `Checkpoint::from_json` are panic-free on arbitrary input (including
//! deeply nested JSON, which `util::json` depth-caps), every
//! admit/restore spec passes `SessionSpec::validate` ceilings, and a
//! line longer than [`MAX_LINE_BYTES`] drops only that connection
//! (`rust/tests/serve_parity.rs` fuzzes this path).

use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender,
                      TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::faultinject;
use crate::util::json::Json;
use crate::util::logging;

use super::manager::{SessionManager, TickEvent};
use super::protocol::{self, Request, SessionSpec};
use super::store::CheckpointStore;

/// Hard cap on one request line. Generous — a restore line carries a
/// whole checkpoint as JSON — but finite: a client streaming an endless
/// unterminated line must not grow a buffer without bound (the
/// `SessionSpec`/`Checkpoint` ceilings only apply *after* a line
/// parses).
pub const MAX_LINE_BYTES: usize = 1 << 28; // 256 MiB

/// Outbound queue depth per connection (lines). Metrics events are one
/// line per session per tick; 256 of backlog means the client has
/// stopped reading for a long time — it is dropped, not waited on.
const WRITE_QUEUE: usize = 256;

/// Per-write socket timeout for connection writer threads, so a peer
/// that stops reading cannot pin a writer thread (and its queued
/// lines) forever once its TCP buffer fills.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn try_clone(&self) -> std::io::Result<Stream> {
        match self {
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
            #[cfg(unix)]
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
        }
    }

    fn set_write_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.set_write_timeout(d),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_write_timeout(d),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

enum Inbound {
    Line { conn: u64, line: String },
    Closed { conn: u64 },
}

/// Handle to one connection's writer thread: the bounded line queue it
/// drains, plus its join handle (joined only at daemon shutdown, to
/// flush final acks before the process exits).
struct ConnWriter {
    tx: SyncSender<String>,
    handle: std::thread::JoinHandle<()>,
}

type Writers = Mutex<BTreeMap<u64, ConnWriter>>;

/// Options for [`Daemon::run_opts`]. `Default` is the bare daemon PR 9
/// shipped: no persistence, no recovery, one worker.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Fleet worker threads per lockstep tick (>= 1).
    pub workers: usize,
    /// Auto-checkpoint every N completed ticks (0 = off; requires
    /// `store_dir`). Independently of the cadence, a session is always
    /// snapshotted on the tick it finishes when a store is configured.
    pub auto_checkpoint: u64,
    /// Root directory of the crash-safe [`CheckpointStore`]; `None`
    /// disables persistence entirely.
    pub store_dir: Option<String>,
    /// Before serving, re-admit every session that has a valid
    /// last-good snapshot under `store_dir`. Torn, CRC-failing, or
    /// non-restorable snapshots are warn-skipped — recovery is never
    /// fatal.
    pub recover: bool,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            workers: 1,
            auto_checkpoint: 0,
            store_dir: None,
            recover: false,
        }
    }
}

pub struct Daemon {
    listener: Listener,
    local_addr: String,
}

impl Daemon {
    /// Bind the serving socket. `unix:/path/to.sock` binds a Unix
    /// socket (removing a stale file first); anything else is a TCP
    /// `host:port` — port 0 picks an ephemeral port, readable back via
    /// [`Daemon::local_addr`].
    pub fn bind(addr: &str) -> Result<Daemon> {
        if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .with_context(|| format!("bind {addr}"))?;
                return Ok(Daemon {
                    listener: Listener::Unix(l),
                    local_addr: addr.to_string(),
                });
            }
            #[cfg(not(unix))]
            anyhow::bail!("unix sockets unsupported on this platform");
        }
        let l = TcpListener::bind(addr)
            .with_context(|| format!("bind {addr}"))?;
        let local_addr = l.local_addr()?.to_string();
        Ok(Daemon { listener: Listener::Tcp(l), local_addr })
    }

    /// The bound address (with the real port when bound to port 0).
    pub fn local_addr(&self) -> &str {
        &self.local_addr
    }

    /// Serve with default options (no checkpoint store, no recovery) —
    /// see [`Daemon::run_opts`].
    pub fn run(self, workers: usize) -> Result<()> {
        self.run_opts(ServeOpts { workers, ..ServeOpts::default() })
    }

    /// Serve until a `shutdown` request arrives. The accept and reader
    /// threads are detached; they die with the process. Writer threads
    /// are joined on the way out so queued final responses (the
    /// shutdown ack in particular) reach their sockets before this
    /// returns.
    pub fn run_opts(self, opts: ServeOpts) -> Result<()> {
        if opts.auto_checkpoint > 0 && opts.store_dir.is_none() {
            anyhow::bail!("auto-checkpoint requires a store directory");
        }
        let store = opts.store_dir.as_deref().map(CheckpointStore::new);
        let (tx, rx) = channel::<Inbound>();
        let writers: Arc<Writers> = Arc::new(Mutex::new(BTreeMap::new()));
        spawn_acceptor(self.listener, tx, writers.clone());
        serve_loop(rx, &writers, &opts, store.as_ref());
        let conns = std::mem::take(&mut *lock_writers(&writers));
        for (_, w) in conns {
            drop(w.tx); // writer drains its backlog, then exits
            let _ = w.handle.join();
        }
        Ok(())
    }
}

fn spawn_acceptor(listener: Listener, tx: Sender<Inbound>,
                  writers: Arc<Writers>) {
    std::thread::spawn(move || {
        let mut next_conn = 0u64;
        loop {
            let stream = match &listener {
                Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
                #[cfg(unix)]
                Listener::Unix(l) => {
                    l.accept().map(|(s, _)| Stream::Unix(s))
                }
            };
            let stream = match stream {
                Ok(s) => s,
                Err(_) => break, // listener gone
            };
            let conn = next_conn;
            next_conn += 1;
            let write_half = match stream.try_clone() {
                Ok(w) => w,
                Err(_) => continue,
            };
            let _ = write_half.set_write_timeout(Some(WRITE_TIMEOUT));
            let (wtx, wrx) = sync_channel::<String>(WRITE_QUEUE);
            let handle = spawn_conn_writer(write_half, wrx);
            lock_writers(&writers)
                .insert(conn, ConnWriter { tx: wtx, handle });
            let tx = tx.clone();
            std::thread::spawn(move || {
                let mut reader = BufReader::new(stream);
                let mut buf: Vec<u8> = Vec::new();
                loop {
                    buf.clear();
                    match read_line_capped(&mut reader, &mut buf,
                                           MAX_LINE_BYTES) {
                        Ok(true) => {}
                        Ok(false) | Err(_) => break,
                    }
                    // Lossy: a non-UTF-8 line becomes a parse error and
                    // an `ok:false` reply, not a dropped connection.
                    let line = String::from_utf8_lossy(&buf).into_owned();
                    if tx.send(Inbound::Line { conn, line }).is_err() {
                        return; // daemon shut down
                    }
                }
                let _ = tx.send(Inbound::Closed { conn });
            });
        }
    });
}

/// Per-connection writer thread: drains the bounded outbound queue onto
/// the socket, one flushed line per message. Exits when every sender is
/// dropped (queue drained) or a write fails/times out — the socket
/// blocking is confined here, never on the tick loop.
fn spawn_conn_writer(mut w: Stream, rx: Receiver<String>)
                     -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        while let Ok(line) = rx.recv() {
            if w.write_all(line.as_bytes()).is_err() || w.flush().is_err() {
                break;
            }
        }
    })
}

/// Read one `\n`-terminated line into `buf` (terminator consumed and
/// excluded; a preceding `\r` is stripped), enforcing `max` bytes.
/// `Ok(true)` delivers a line (including a final unterminated line at
/// EOF), `Ok(false)` is clean EOF, `Err` is an I/O error or an
/// over-long line — the caller drops the connection either way.
fn read_line_capped(r: &mut impl BufRead, buf: &mut Vec<u8>, max: usize)
                    -> std::io::Result<bool> {
    loop {
        let (done, used) = {
            let chunk = r.fill_buf()?;
            if chunk.is_empty() {
                return Ok(!buf.is_empty()); // EOF
            }
            match chunk.iter().position(|&b| b == b'\n') {
                Some(p) => {
                    if buf.len() + p > max {
                        return Err(line_too_long());
                    }
                    buf.extend_from_slice(&chunk[..p]);
                    (true, p + 1)
                }
                None => {
                    if buf.len() + chunk.len() > max {
                        return Err(line_too_long());
                    }
                    buf.extend_from_slice(chunk);
                    (false, chunk.len())
                }
            }
        };
        r.consume(used);
        if done {
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
            return Ok(true);
        }
    }
}

fn line_too_long() -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData,
                        "request line exceeds MAX_LINE_BYTES")
}

fn lock_writers(writers: &Writers)
                -> std::sync::MutexGuard<'_, BTreeMap<u64, ConnWriter>> {
    match writers.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// Best-effort line enqueue; never blocks the caller (the tick loop).
/// A full queue or dropped writer means the client is slow or gone —
/// its writer is removed (its sessions keep running detached).
fn send_line(writers: &Writers, conn: u64, line: &str) {
    let mut msg = String::with_capacity(line.len() + 1);
    msg.push_str(line);
    msg.push('\n');
    let mut map = lock_writers(writers);
    let ok = match map.get(&conn) {
        Some(w) => w.tx.try_send(msg).is_ok(),
        None => return,
    };
    if !ok {
        map.remove(&conn);
    }
}

fn serve_loop(rx: Receiver<Inbound>, writers: &Writers, opts: &ServeOpts,
              store: Option<&CheckpointStore>) {
    let mut mgr = SessionManager::new();
    // session id -> connection that admitted it (event routing).
    let mut owner: BTreeMap<u32, u64> = BTreeMap::new();
    // session id -> admit-time spec (auto-checkpoint snapshots carry
    // the spec so `--recover` can re-admit without the original client).
    let mut specs: BTreeMap<u32, SessionSpec> = BTreeMap::new();
    if opts.recover {
        if let Some(store) = store {
            for r in store.recover_all() {
                let name = r.spec.name.clone();
                match mgr.restore(&r.spec, r.step, &r.ck) {
                    Ok(id) => {
                        logging::info(format!(
                            "serve: recovered session '{name}' at step \
                             {} as id {id}", r.step));
                        specs.insert(id, r.spec);
                    }
                    Err(e) => logging::warn(format!(
                        "serve: snapshot of '{name}' not re-admitted: \
                         {e:#}")),
                }
            }
        }
    }
    let mut events: Vec<TickEvent> = Vec::with_capacity(64);
    'serve: loop {
        if mgr.n_running() == 0 {
            // Idle: block until a client says something.
            match rx.recv() {
                Ok(m) => {
                    if handle(m, &mut mgr, &mut owner, &mut specs,
                              writers) {
                        break 'serve;
                    }
                }
                Err(_) => break 'serve, // acceptor died
            }
        }
        loop {
            match rx.try_recv() {
                Ok(m) => {
                    if handle(m, &mut mgr, &mut owner, &mut specs,
                              writers) {
                        break 'serve;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => break 'serve,
            }
        }
        events.clear();
        mgr.tick(opts.workers, &mut events);
        for ev in &events {
            let (session, line) = match ev {
                TickEvent::Metrics { session, step, loss } => {
                    (*session,
                     protocol::event_metrics(*session, *step, *loss))
                }
                TickEvent::Done { session, step } => {
                    (*session, protocol::event_done(*session, *step))
                }
                TickEvent::Failed { session, msg } => {
                    logging::warn(&format!(
                        "serve: session {session} failed: {msg}"));
                    (*session, protocol::event_failed(*session, msg))
                }
            };
            if let Some(&conn) = owner.get(&session) {
                send_line(writers, conn, &line);
            }
        }
        if let Some(store) = store {
            auto_checkpoint(store, &mgr, &specs, &events,
                            opts.auto_checkpoint);
        }
        // Deterministic chaos hook: `panic@daemon_tick:N` kills the
        // daemon itself after tick N's snapshots land. A daemon-level
        // fault is fatal by design — `--recover` is the remedy.
        faultinject::panic_point(&[("daemon_tick", mgr.ticks())]);
    }
}

/// Snapshot sessions into the store: every session that finished this
/// tick, plus — when the periodic cadence hits — every session that
/// produced metrics. Failed sessions never snapshot (their buffers are
/// quarantined). Store errors are warned, never fatal: the daemon
/// outlives a full disk.
fn auto_checkpoint(store: &CheckpointStore, mgr: &SessionManager,
                   specs: &BTreeMap<u32, SessionSpec>,
                   events: &[TickEvent], every: u64) {
    let periodic = every > 0 && mgr.ticks() % every == 0;
    let mut snap: BTreeSet<u32> = BTreeSet::new();
    for ev in events {
        match ev {
            TickEvent::Done { session, .. } => {
                snap.insert(*session);
            }
            TickEvent::Metrics { session, .. } if periodic => {
                snap.insert(*session);
            }
            _ => {}
        }
    }
    for id in snap {
        let Some(spec) = specs.get(&id) else { continue };
        let res = mgr
            .checkpoint(id)
            .and_then(|(step, ck)| store.save(spec, step, &ck));
        if let Err(e) = res {
            logging::warn(format!(
                "serve: auto-checkpoint of session {id} failed: {e:#}"));
        }
    }
}

/// Process one inbound message; returns true on shutdown.
fn handle(m: Inbound, mgr: &mut SessionManager,
          owner: &mut BTreeMap<u32, u64>,
          specs: &mut BTreeMap<u32, SessionSpec>,
          writers: &Writers) -> bool {
    let (conn, line) = match m {
        Inbound::Line { conn, line } => (conn, line),
        Inbound::Closed { conn } => {
            lock_writers(writers).remove(&conn);
            return false;
        }
    };
    let mut shutdown = false;
    let reply = match protocol::parse_request(&line) {
        Err(e) => protocol::resp_err(&e.to_string()),
        Ok(req) => match req {
            Request::Admit(spec) => match mgr.admit(&spec) {
                Ok(id) => {
                    owner.insert(id, conn);
                    specs.insert(id, spec);
                    protocol::resp_ok(vec![
                        ("session", Json::Num(id as f64)),
                    ])
                }
                Err(e) => protocol::resp_err(&e.to_string()),
            },
            Request::Restore { spec, step, checkpoint } => {
                match mgr.restore(&spec, step, &checkpoint) {
                    Ok(id) => {
                        owner.insert(id, conn);
                        specs.insert(id, spec);
                        protocol::resp_ok(vec![
                            ("session", Json::Num(id as f64)),
                        ])
                    }
                    Err(e) => protocol::resp_err(&e.to_string()),
                }
            }
            Request::Pause(id) => ack(mgr.pause(id)),
            Request::Resume(id) => ack(mgr.resume(id)),
            Request::Evict(id) => {
                let r = mgr.evict(id);
                if r.is_ok() {
                    owner.remove(&id);
                    specs.remove(&id);
                }
                ack(r)
            }
            Request::Checkpoint(id) => match mgr.checkpoint(id) {
                Ok((step, ck)) => protocol::resp_ok(vec![
                    ("step", Json::Num(step as f64)),
                    ("checkpoint", ck.to_json()),
                ]),
                Err(e) => protocol::resp_err(&e.to_string()),
            },
            Request::Status => {
                let sessions: Vec<Json> = mgr
                    .sessions()
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("session", Json::Num(s.id as f64)),
                            ("name", Json::Str(s.name.clone())),
                            ("state",
                             Json::Str(s.state.name().to_string())),
                            ("step", Json::Num(s.step as f64)),
                            ("steps", Json::Num(s.steps as f64)),
                            ("loss", Json::Num(s.loss())),
                        ])
                    })
                    .collect();
                protocol::resp_ok(vec![("sessions", Json::Arr(sessions))])
            }
            Request::Shutdown => {
                shutdown = true;
                protocol::resp_ok(vec![])
            }
        },
    };
    send_line(writers, conn, &reply);
    shutdown
}

fn ack(r: Result<()>) -> String {
    match r {
        Ok(()) => protocol::resp_ok(vec![]),
        Err(e) => protocol::resp_err(&e.to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn lines_with_cap(input: &[u8], max: usize)
                      -> (Vec<String>, bool) {
        let mut r = BufReader::with_capacity(4, Cursor::new(input.to_vec()));
        let mut out = Vec::new();
        let mut buf = Vec::new();
        loop {
            buf.clear();
            match read_line_capped(&mut r, &mut buf, max) {
                Ok(true) => {
                    out.push(String::from_utf8(buf.clone()).unwrap());
                }
                Ok(false) => return (out, true),
                Err(_) => return (out, false),
            }
        }
    }

    #[test]
    fn capped_reader_splits_lines_like_lines() {
        let (got, clean) =
            lines_with_cap(b"alpha\nbeta\r\n\ngamma", 1024);
        assert!(clean);
        assert_eq!(got, vec!["alpha", "beta", "", "gamma"]);
    }

    #[test]
    fn capped_reader_rejects_oversized_line() {
        // An unterminated line past the cap must be an Err (drop the
        // connection), not unbounded buffer growth — and the check
        // fires mid-stream, long before any terminator arrives.
        let (got, clean) = lines_with_cap(b"0123456789abcdef", 8);
        assert!(!clean);
        assert!(got.is_empty());
        // Terminated-but-too-long is rejected the same way.
        let (got, clean) = lines_with_cap(b"ok\n0123456789\n", 8);
        assert!(!clean);
        assert_eq!(got, vec!["ok"]);
    }

    #[test]
    fn capped_reader_accepts_line_at_exact_cap() {
        let (got, clean) = lines_with_cap(b"12345678\nxx\n", 8);
        assert!(clean);
        assert_eq!(got, vec!["12345678", "xx"]);
    }
}

//! The `mofasgd serve` daemon: newline-delimited JSON over a local TCP
//! or Unix socket, multiplexing concurrent fine-tuning sessions over
//! one [`SessionManager`].
//!
//! Threading model: one detached accept thread, one detached reader
//! thread per connection, all funneling [`Inbound`] messages into an
//! mpsc channel the single tick loop owns. The tick loop blocks on the
//! channel while no session is Running (idle daemon burns no CPU),
//! otherwise drains pending requests non-blockingly and runs one
//! lockstep tick. Responses and events are written through per
//! connection writer handles (`try_clone` of the accepted stream) —
//! a slow or dead client only ever loses its own stream: writes to it
//! fail, its writer is dropped, and its sessions keep running detached
//! (reconnection/ownership transfer is out of scope; `evict` is the
//! remedy).
//!
//! Robustness contract: any byte sequence a client sends is answered
//! with `{"ok":false,...}` at worst — `protocol::parse_request` and
//! `Checkpoint::from_json` are panic-free on arbitrary input, and every
//! admit/restore spec passes `SessionSpec::validate` ceilings
//! (`rust/tests/serve_parity.rs` fuzzes this path).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::logging;

use super::manager::{SessionManager, TickEvent};
use super::protocol::{self, Request};

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    fn try_clone(&self) -> std::io::Result<Stream> {
        match self {
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
            #[cfg(unix)]
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

enum Inbound {
    Line { conn: u64, line: String },
    Closed { conn: u64 },
}

pub struct Daemon {
    listener: Listener,
    local_addr: String,
}

impl Daemon {
    /// Bind the serving socket. `unix:/path/to.sock` binds a Unix
    /// socket (removing a stale file first); anything else is a TCP
    /// `host:port` — port 0 picks an ephemeral port, readable back via
    /// [`Daemon::local_addr`].
    pub fn bind(addr: &str) -> Result<Daemon> {
        if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .with_context(|| format!("bind {addr}"))?;
                return Ok(Daemon {
                    listener: Listener::Unix(l),
                    local_addr: addr.to_string(),
                });
            }
            #[cfg(not(unix))]
            anyhow::bail!("unix sockets unsupported on this platform");
        }
        let l = TcpListener::bind(addr)
            .with_context(|| format!("bind {addr}"))?;
        let local_addr = l.local_addr()?.to_string();
        Ok(Daemon { listener: Listener::Tcp(l), local_addr })
    }

    /// The bound address (with the real port when bound to port 0).
    pub fn local_addr(&self) -> &str {
        &self.local_addr
    }

    /// Serve until a `shutdown` request arrives. The accept and reader
    /// threads are detached; they die with the process.
    pub fn run(self, workers: usize) -> Result<()> {
        let (tx, rx) = channel::<Inbound>();
        let writers: Arc<Mutex<BTreeMap<u64, Stream>>> =
            Arc::new(Mutex::new(BTreeMap::new()));
        spawn_acceptor(self.listener, tx, writers.clone());
        serve_loop(rx, &writers, workers);
        Ok(())
    }
}

fn spawn_acceptor(listener: Listener, tx: Sender<Inbound>,
                  writers: Arc<Mutex<BTreeMap<u64, Stream>>>) {
    std::thread::spawn(move || {
        let mut next_conn = 0u64;
        loop {
            let stream = match &listener {
                Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
                #[cfg(unix)]
                Listener::Unix(l) => {
                    l.accept().map(|(s, _)| Stream::Unix(s))
                }
            };
            let stream = match stream {
                Ok(s) => s,
                Err(_) => break, // listener gone
            };
            let conn = next_conn;
            next_conn += 1;
            match stream.try_clone() {
                Ok(w) => {
                    lock_writers(&writers).insert(conn, w);
                }
                Err(_) => continue,
            }
            let tx = tx.clone();
            std::thread::spawn(move || {
                let reader = BufReader::new(stream);
                for line in reader.lines() {
                    let line = match line {
                        Ok(l) => l,
                        Err(_) => break,
                    };
                    if tx.send(Inbound::Line { conn, line }).is_err() {
                        return; // daemon shut down
                    }
                }
                let _ = tx.send(Inbound::Closed { conn });
            });
        }
    });
}

fn lock_writers(
    writers: &Mutex<BTreeMap<u64, Stream>>,
) -> std::sync::MutexGuard<'_, BTreeMap<u64, Stream>> {
    match writers.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// Best-effort line write; a failed write drops the connection's writer
/// (the client is gone — its sessions keep running detached).
fn send_line(writers: &Mutex<BTreeMap<u64, Stream>>, conn: u64,
             line: &str) {
    let mut map = lock_writers(writers);
    let ok = match map.get_mut(&conn) {
        Some(w) => {
            w.write_all(line.as_bytes()).is_ok()
                && w.write_all(b"\n").is_ok()
                && w.flush().is_ok()
        }
        None => return,
    };
    if !ok {
        map.remove(&conn);
    }
}

fn serve_loop(rx: Receiver<Inbound>,
              writers: &Mutex<BTreeMap<u64, Stream>>, workers: usize) {
    let mut mgr = SessionManager::new();
    // session id -> connection that admitted it (event routing).
    let mut owner: BTreeMap<u32, u64> = BTreeMap::new();
    let mut events: Vec<TickEvent> = Vec::with_capacity(64);
    'serve: loop {
        if mgr.n_running() == 0 {
            // Idle: block until a client says something.
            match rx.recv() {
                Ok(m) => {
                    if handle(m, &mut mgr, &mut owner, writers) {
                        break 'serve;
                    }
                }
                Err(_) => break 'serve, // acceptor died
            }
        }
        loop {
            match rx.try_recv() {
                Ok(m) => {
                    if handle(m, &mut mgr, &mut owner, writers) {
                        break 'serve;
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => break 'serve,
            }
        }
        events.clear();
        mgr.tick(workers, &mut events);
        for ev in &events {
            let (session, line) = match ev {
                TickEvent::Metrics { session, step, loss } => {
                    (*session,
                     protocol::event_metrics(*session, *step, *loss))
                }
                TickEvent::Done { session, step } => {
                    (*session, protocol::event_done(*session, *step))
                }
                TickEvent::Failed { session, msg } => {
                    logging::warn(&format!(
                        "serve: session {session} failed: {msg}"));
                    (*session, protocol::event_failed(*session, msg))
                }
            };
            if let Some(&conn) = owner.get(&session) {
                send_line(writers, conn, &line);
            }
        }
    }
}

/// Process one inbound message; returns true on shutdown.
fn handle(m: Inbound, mgr: &mut SessionManager,
          owner: &mut BTreeMap<u32, u64>,
          writers: &Mutex<BTreeMap<u64, Stream>>) -> bool {
    let (conn, line) = match m {
        Inbound::Line { conn, line } => (conn, line),
        Inbound::Closed { conn } => {
            lock_writers(writers).remove(&conn);
            return false;
        }
    };
    let mut shutdown = false;
    let reply = match protocol::parse_request(&line) {
        Err(e) => protocol::resp_err(&e.to_string()),
        Ok(req) => match req {
            Request::Admit(spec) => match mgr.admit(&spec) {
                Ok(id) => {
                    owner.insert(id, conn);
                    protocol::resp_ok(vec![
                        ("session", Json::Num(id as f64)),
                    ])
                }
                Err(e) => protocol::resp_err(&e.to_string()),
            },
            Request::Restore { spec, step, checkpoint } => {
                match mgr.restore(&spec, step, &checkpoint) {
                    Ok(id) => {
                        owner.insert(id, conn);
                        protocol::resp_ok(vec![
                            ("session", Json::Num(id as f64)),
                        ])
                    }
                    Err(e) => protocol::resp_err(&e.to_string()),
                }
            }
            Request::Pause(id) => ack(mgr.pause(id)),
            Request::Resume(id) => ack(mgr.resume(id)),
            Request::Evict(id) => {
                let r = mgr.evict(id);
                if r.is_ok() {
                    owner.remove(&id);
                }
                ack(r)
            }
            Request::Checkpoint(id) => match mgr.checkpoint(id) {
                Ok((step, ck)) => protocol::resp_ok(vec![
                    ("step", Json::Num(step as f64)),
                    ("checkpoint", ck.to_json()),
                ]),
                Err(e) => protocol::resp_err(&e.to_string()),
            },
            Request::Status => {
                let sessions: Vec<Json> = mgr
                    .sessions()
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("session", Json::Num(s.id as f64)),
                            ("name", Json::Str(s.name.clone())),
                            ("state",
                             Json::Str(s.state.name().to_string())),
                            ("step", Json::Num(s.step as f64)),
                            ("steps", Json::Num(s.steps as f64)),
                            ("loss", Json::Num(s.loss())),
                        ])
                    })
                    .collect();
                protocol::resp_ok(vec![("sessions", Json::Arr(sessions))])
            }
            Request::Shutdown => {
                shutdown = true;
                protocol::resp_ok(vec![])
            }
        },
    };
    send_line(writers, conn, &reply);
    shutdown
}

fn ack(r: Result<()>) -> String {
    match r {
        Ok(()) => protocol::resp_ok(vec![]),
        Err(e) => protocol::resp_err(&e.to_string()),
    }
}

//! Serve wire protocol: newline-delimited JSON over a local socket.
//!
//! Every request is one line, every response/event one line back. The
//! daemon parses client bytes with [`parse_request`], which returns
//! `Err` — never panics — on any malformation: `util::json::Json::parse`
//! is panic-free on arbitrary `&str` input, and every field access below
//! goes through the fallible `req`/`as_*` accessors plus explicit range
//! validation (`rust/tests/serve_parity.rs` fuzzes this with
//! `util::prop`).
//!
//! Requests (`cmd` selects):
//!   {"cmd":"admit","spec":{…}}                      → {"ok":true,"session":N}
//!   {"cmd":"pause"|"resume"|"evict","session":N}    → {"ok":true,…}
//!   {"cmd":"checkpoint","session":N}                → {"ok":true,"step":S,
//!                                                      "checkpoint":{…}}
//!   {"cmd":"restore","spec":{…},"step":S,
//!    "checkpoint":{…}}                              → {"ok":true,"session":N}
//!   {"cmd":"status"}                                → {"ok":true,"sessions":[…]}
//!   {"cmd":"shutdown"}                              → {"ok":true}
//!
//! Unsolicited events (streamed to the admitting connection):
//!   {"event":"metrics","session":N,"step":S,"loss":L}
//!   {"event":"done","session":N,"step":S}
//!   {"event":"failed","session":N,"error":"…"}
//!
//! The checkpoint payload is `coordinator::checkpoint::Checkpoint`'s
//! JSON wire form (`to_json`/`from_json`) — tensor data as `f32::to_bits`
//! integers, so streaming a checkpoint out and restoring it is bit-exact.

use anyhow::{bail, Result};

use crate::coordinator::checkpoint::Checkpoint;
use crate::util::json::Json;

/// Validation ceilings: a hostile spec must not be able to OOM or wedge
/// the daemon. Generous for the native coordinator's scale, tiny for an
/// attacker.
pub const MAX_NAME: usize = 64;
pub const MAX_DIM: usize = 4096;
pub const MAX_RANK: usize = 256;
pub const MAX_LAYERS: usize = 256;
pub const MAX_VEC_LEN: usize = 1 << 20;
pub const MAX_ACCUM: usize = 64;
pub const MAX_STEPS: usize = 1_000_000;
pub const MAX_PREFETCH: usize = 16;

/// Optimizer routed to one matrix layer of a session. GaLore is
/// deliberately absent: its offline resample allocates mid-run, which
/// would break the serve tick's steady-state zero-allocation contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    MoFaSgd,
    Muon,
    AdamW,
    SgdM,
    SignSgd,
}

impl LayerKind {
    pub fn name(self) -> &'static str {
        match self {
            LayerKind::MoFaSgd => "mofasgd",
            LayerKind::Muon => "muon",
            LayerKind::AdamW => "adamw",
            LayerKind::SgdM => "sgdm",
            LayerKind::SignSgd => "signsgd",
        }
    }

    pub fn from_name(s: &str) -> Option<LayerKind> {
        Some(match s {
            "mofasgd" => LayerKind::MoFaSgd,
            "muon" => LayerKind::Muon,
            "adamw" => LayerKind::AdamW,
            "sgdm" => LayerKind::SgdM,
            "signsgd" => LayerKind::SignSgd,
            _ => return None,
        })
    }

    /// Whether the optimizer's full state is externally restorable from
    /// checkpoint tensors (AdamW keeps a private step counter, so a
    /// restored instance could not be bit-exact — same restriction as
    /// `rust/tests/replica_parity.rs`).
    pub fn restorable(self) -> bool {
        !matches!(self, LayerKind::AdamW)
    }
}

/// One matrix layer of a session's synthetic fine-tuning workload.
#[derive(Clone, Debug)]
pub struct LayerSpec {
    pub kind: LayerKind,
    pub m: usize,
    pub n: usize,
    /// MoFaSGD momentum-factorization rank (ignored by other kinds).
    pub rank: usize,
    /// Momentum coefficient (ignored by SignSGD).
    pub beta: f32,
}

/// One flat (vector) layer, stepped by AdamW — embeddings/norms analogue.
#[derive(Clone, Debug)]
pub struct VecSpec {
    pub len: usize,
}

/// A fine-tuning session: model shape, optimizer fleet, and the seeded
/// synthetic data stream (noisy quadratic pull toward a hidden target —
/// the repo's descent-test workload). Everything a tick consumes is a
/// pure function of `(seed, step, micro)`, so a session's trajectory is
/// identical no matter how many tenants share the fleet dispatch or
/// whether its noise is generated inline or prefetched.
#[derive(Clone, Debug)]
pub struct SessionSpec {
    pub name: String,
    pub seed: u64,
    /// Total optimizer steps (ticks) the session runs.
    pub steps: usize,
    /// Micro-batches accumulated per step.
    pub accum: usize,
    pub eta: f32,
    /// Gradient noise std (0 = exact quadratic descent).
    pub noise: f32,
    /// Bounded-channel prefetch depth for the noise stream; 0 generates
    /// inline on the tick thread (the zero-allocation path).
    pub prefetch: usize,
    pub layers: Vec<LayerSpec>,
    pub vecs: Vec<VecSpec>,
}

impl SessionSpec {
    /// Enforce the validation ceilings; every admit/restore goes through
    /// this whether the spec arrived over the wire or in-process.
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() || self.name.len() > MAX_NAME {
            bail!("session name must be 1..={MAX_NAME} bytes");
        }
        if self.steps == 0 || self.steps > MAX_STEPS {
            bail!("steps must be 1..={MAX_STEPS}, got {}", self.steps);
        }
        if self.accum == 0 || self.accum > MAX_ACCUM {
            bail!("accum must be 1..={MAX_ACCUM}, got {}", self.accum);
        }
        if !self.eta.is_finite() {
            bail!("eta must be finite");
        }
        if !self.noise.is_finite() || self.noise < 0.0 {
            bail!("noise must be finite and >= 0");
        }
        if self.prefetch > MAX_PREFETCH {
            bail!("prefetch must be <= {MAX_PREFETCH}, got {}",
                  self.prefetch);
        }
        let n_layers = self.layers.len() + self.vecs.len();
        if n_layers == 0 || n_layers > MAX_LAYERS {
            bail!("need 1..={MAX_LAYERS} layers, got {n_layers}");
        }
        for (li, l) in self.layers.iter().enumerate() {
            if l.m == 0 || l.m > MAX_DIM || l.n == 0 || l.n > MAX_DIM {
                bail!("layer {li}: dims {}x{} out of 1..={MAX_DIM}",
                      l.m, l.n);
            }
            // `MoFaSgd::new` asserts 2*rank <= min(m, n); reject here so
            // a hostile spec gets an Err, not a daemon panic.
            if l.kind == LayerKind::MoFaSgd
                && (l.rank == 0
                    || 2 * l.rank > l.m.min(l.n)
                    || l.rank > MAX_RANK)
            {
                bail!("layer {li}: rank {} out of 1..=min({}/2, {}/2, \
                       {MAX_RANK})", l.rank, l.m, l.n);
            }
            if !l.beta.is_finite() || !(0.0..1.0).contains(&l.beta) {
                bail!("layer {li}: beta {} out of [0, 1)", l.beta);
            }
        }
        for (vi, v) in self.vecs.iter().enumerate() {
            if v.len == 0 || v.len > MAX_VEC_LEN {
                bail!("vec layer {vi}: len {} out of 1..={MAX_VEC_LEN}",
                      v.len);
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let layers: Vec<Json> = self
            .layers
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("kind", Json::Str(l.kind.name().to_string())),
                    ("m", Json::Num(l.m as f64)),
                    ("n", Json::Num(l.n as f64)),
                    ("rank", Json::Num(l.rank as f64)),
                    ("beta", Json::Num(l.beta as f64)),
                ])
            })
            .collect();
        let vecs: Vec<Json> = self
            .vecs
            .iter()
            .map(|v| Json::obj(vec![("len", Json::Num(v.len as f64))]))
            .collect();
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("accum", Json::Num(self.accum as f64)),
            ("eta", Json::Num(self.eta as f64)),
            ("noise", Json::Num(self.noise as f64)),
            ("prefetch", Json::Num(self.prefetch as f64)),
            ("layers", Json::Arr(layers)),
            ("vecs", Json::Arr(vecs)),
        ])
    }

    /// Parse and validate a wire spec. Optional fields default: accum 1,
    /// eta 0.01, noise 0.0, prefetch 0, vecs [].
    pub fn from_json(v: &Json) -> Result<SessionSpec> {
        let name = v.req("name")?.as_str()?.to_string();
        let seed = parse_u64(v.req("seed")?)?;
        let steps = v.req("steps")?.as_usize()?;
        let accum = opt_usize(v, "accum", 1)?;
        let eta = opt_f32(v, "eta", 0.01)?;
        let noise = opt_f32(v, "noise", 0.0)?;
        let prefetch = opt_usize(v, "prefetch", 0)?;
        let mut layers = Vec::new();
        for (li, l) in v.req("layers")?.as_arr()?.iter().enumerate() {
            let kind_name = l.req("kind")?.as_str()?;
            let kind = LayerKind::from_name(kind_name).ok_or_else(|| {
                anyhow::anyhow!("layer {li}: unknown kind `{kind_name}`")
            })?;
            layers.push(LayerSpec {
                kind,
                m: l.req("m")?.as_usize()?,
                n: l.req("n")?.as_usize()?,
                rank: opt_usize(l, "rank", 4)?,
                beta: opt_f32(l, "beta", 0.9)?,
            });
        }
        let mut vecs = Vec::new();
        if let Some(arr) = v.get("vecs") {
            for e in arr.as_arr()? {
                vecs.push(VecSpec { len: e.req("len")?.as_usize()? });
            }
        }
        let spec = SessionSpec {
            name, seed, steps, accum, eta, noise, prefetch, layers, vecs,
        };
        spec.validate()?;
        Ok(spec)
    }
}

fn parse_u64(v: &Json) -> Result<u64> {
    let x = v.as_f64()?;
    if x < 0.0 || x.fract() != 0.0 || x >= (1u64 << 53) as f64 {
        bail!("expected integer in [0, 2^53), got {x}");
    }
    Ok(x as u64)
}

fn opt_usize(v: &Json, key: &str, default: usize) -> Result<usize> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => x.as_usize(),
    }
}

fn opt_f32(v: &Json, key: &str, default: f32) -> Result<f32> {
    match v.get(key) {
        None => Ok(default),
        Some(x) => Ok(x.as_f64()? as f32),
    }
}

/// A parsed client request.
#[derive(Debug)]
pub enum Request {
    Admit(SessionSpec),
    Pause(u32),
    Resume(u32),
    Evict(u32),
    Checkpoint(u32),
    Restore {
        spec: SessionSpec,
        step: usize,
        checkpoint: Checkpoint,
    },
    Status,
    Shutdown,
}

/// Parse one request line. Every malformation — bad JSON, wrong types,
/// out-of-range values, unknown commands — is an `Err`; this function
/// must never panic on client bytes.
pub fn parse_request(line: &str) -> Result<Request> {
    let v = Json::parse(line)?;
    let cmd = v.req("cmd")?.as_str()?;
    Ok(match cmd {
        "admit" => Request::Admit(SessionSpec::from_json(v.req("spec")?)?),
        "pause" => Request::Pause(session_id(&v)?),
        "resume" => Request::Resume(session_id(&v)?),
        "evict" => Request::Evict(session_id(&v)?),
        "checkpoint" => Request::Checkpoint(session_id(&v)?),
        "restore" => {
            let spec = SessionSpec::from_json(v.req("spec")?)?;
            let step = v.req("step")?.as_usize()?;
            if step > spec.steps {
                bail!("restore step {step} beyond spec steps {}",
                      spec.steps);
            }
            let checkpoint = Checkpoint::from_json(v.req("checkpoint")?)?;
            Request::Restore { spec, step, checkpoint }
        }
        "status" => Request::Status,
        "shutdown" => Request::Shutdown,
        other => bail!("unknown cmd `{other}`"),
    })
}

fn session_id(v: &Json) -> Result<u32> {
    let id = v.req("session")?.as_usize()?;
    if id > u32::MAX as usize {
        bail!("session id {id} out of range");
    }
    Ok(id as u32)
}

// ---- response / event emitters ------------------------------------------

pub fn resp_ok(fields: Vec<(&str, Json)>) -> String {
    let mut pairs = vec![("ok", Json::Bool(true))];
    pairs.extend(fields);
    Json::obj(pairs).emit(0)
}

pub fn resp_err(msg: &str) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ])
    .emit(0)
}

pub fn event_metrics(session: u32, step: usize, loss: f64) -> String {
    Json::obj(vec![
        ("event", Json::Str("metrics".to_string())),
        ("session", Json::Num(session as f64)),
        ("step", Json::Num(step as f64)),
        ("loss", Json::Num(loss)),
    ])
    .emit(0)
}

pub fn event_done(session: u32, step: usize) -> String {
    Json::obj(vec![
        ("event", Json::Str("done".to_string())),
        ("session", Json::Num(session as f64)),
        ("step", Json::Num(step as f64)),
    ])
    .emit(0)
}

pub fn event_failed(session: u32, msg: &str) -> String {
    Json::obj(vec![
        ("event", Json::Str("failed".to_string())),
        ("session", Json::Num(session as f64)),
        ("error", Json::Str(msg.to_string())),
    ])
    .emit(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_spec() -> SessionSpec {
        SessionSpec {
            name: "demo".into(),
            seed: 7,
            steps: 20,
            accum: 3,
            eta: 0.01,
            noise: 0.5,
            prefetch: 2,
            layers: vec![
                LayerSpec { kind: LayerKind::MoFaSgd, m: 48, n: 40,
                            rank: 4, beta: 0.9 },
                LayerSpec { kind: LayerKind::SgdM, m: 32, n: 64,
                            rank: 4, beta: 0.9 },
            ],
            vecs: vec![VecSpec { len: 128 }],
        }
    }

    #[test]
    fn spec_roundtrips_through_wire_form() {
        let spec = demo_spec();
        let wire = spec.to_json().emit(0);
        let back =
            SessionSpec::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.name, spec.name);
        assert_eq!(back.seed, spec.seed);
        assert_eq!(back.steps, spec.steps);
        assert_eq!(back.accum, spec.accum);
        assert_eq!(back.eta.to_bits(), spec.eta.to_bits());
        assert_eq!(back.noise.to_bits(), spec.noise.to_bits());
        assert_eq!(back.prefetch, spec.prefetch);
        assert_eq!(back.layers.len(), 2);
        assert_eq!(back.layers[0].kind, LayerKind::MoFaSgd);
        assert_eq!(back.layers[1].m, 32);
        assert_eq!(back.vecs.len(), 1);
        assert_eq!(back.vecs[0].len, 128);
    }

    #[test]
    fn parses_control_requests() {
        assert!(matches!(
            parse_request(r#"{"cmd":"pause","session":3}"#).unwrap(),
            Request::Pause(3)
        ));
        assert!(matches!(
            parse_request(r#"{"cmd":"status"}"#).unwrap(),
            Request::Status
        ));
        assert!(matches!(
            parse_request(r#"{"cmd":"shutdown"}"#).unwrap(),
            Request::Shutdown
        ));
        let admit = format!(
            r#"{{"cmd":"admit","spec":{}}}"#,
            demo_spec().to_json().emit(0)
        );
        assert!(matches!(parse_request(&admit).unwrap(),
                         Request::Admit(_)));
    }

    #[test]
    fn rejects_malformed_requests() {
        for bad in [
            "",
            "not json",
            "{}",
            r#"{"cmd":"frobnicate"}"#,
            r#"{"cmd":"pause"}"#,
            r#"{"cmd":"pause","session":-1}"#,
            r#"{"cmd":"pause","session":99999999999}"#,
            r#"{"cmd":"admit"}"#,
            r#"{"cmd":"admit","spec":{"name":"x","seed":0,"steps":0,
                "layers":[]}}"#,
            // Hostile dims / counts must be range-rejected.
            r#"{"cmd":"admit","spec":{"name":"x","seed":0,"steps":5,
                "layers":[{"kind":"sgdm","m":99999,"n":4}]}}"#,
            r#"{"cmd":"admit","spec":{"name":"x","seed":0,"steps":5,
                "accum":4096,"layers":[{"kind":"sgdm","m":4,"n":4}]}}"#,
            r#"{"cmd":"admit","spec":{"name":"x","seed":0,"steps":5,
                "layers":[{"kind":"galore","m":4,"n":4}]}}"#,
            r#"{"cmd":"restore","spec":{"name":"x","seed":0,"steps":5,
                "layers":[{"kind":"sgdm","m":4,"n":4}]},"step":9,
                "checkpoint":{"version":1,"tensors":[]}}"#,
        ] {
            assert!(parse_request(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn validate_enforces_rank_and_beta() {
        let mut s = demo_spec();
        s.layers[0].rank = 4096;
        assert!(s.validate().is_err());
        let mut s = demo_spec();
        s.layers[0].beta = 1.0;
        assert!(s.validate().is_err());
        let mut s = demo_spec();
        s.noise = f32::NAN;
        assert!(s.validate().is_err());
        assert!(demo_spec().validate().is_ok());
    }
}

//! Training-as-a-service: a long-running daemon (`mofasgd serve`)
//! accepting concurrent fine-tuning sessions over a local socket and
//! multiplexing them through one shared fleet dispatch per lockstep
//! tick. Architecture notes in DESIGN.md §14.
//!
//! - [`protocol`] — newline-delimited JSON wire protocol (requests,
//!   responses, streamed metric/checkpoint events), panic-free on
//!   arbitrary client bytes.
//! - [`session`] — per-tenant model + optimizer state as fleet units,
//!   with a seeded noise stream (inline or prefetched, bit-identical).
//! - [`manager`] — admit/pause/resume/checkpoint/evict state machine
//!   and the lockstep tick over `Fleet::run_fair`, with per-session
//!   fault isolation (a panicking session fails alone; survivors tick
//!   on bit-identically).
//! - [`daemon`] — the socket front end (TCP or Unix), with optional
//!   auto-checkpointing and crash recovery ([`store`]).
//! - [`store`] — crash-safe per-session checkpoint store (atomic CRC32
//!   snapshots, last-good retention, warn-skip recovery).
//!
//! Failure model and durability contract in DESIGN.md §15.

pub mod daemon;
pub mod manager;
pub mod protocol;
pub mod session;
pub mod store;

pub use daemon::{Daemon, ServeOpts};
pub use manager::{SessionManager, TickEvent, MAX_SESSIONS};
pub use protocol::{parse_request, LayerKind, LayerSpec, Request,
                   SessionSpec, VecSpec};
pub use session::{Session, SessionState, TickNoise};
pub use store::CheckpointStore;

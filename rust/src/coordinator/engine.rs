//! The training engine: fwd/bwd artifact execution, per-layer optimizer
//! routing, §5.5 fused low-rank gradient accumulation, eval suites.

use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::hp::{Hyper, OptimizerChoice};
use crate::coordinator::metrics::{Phase, PhaseTimer, TrainMetrics};
use crate::coordinator::optstate::{MatLayer, MatState, VecLayer};
use crate::data::instruct::Example;
use crate::data::{ClsBatch, LmBatch};
use crate::fusion::reduce::{self, TreeSchedule, TREE_WIDTH};
use crate::obs;
use crate::runtime::{lit_f32, lit_i32, scalar_f32, to_f32_vec, Exec,
                     ModelConfig, Registry};
use crate::util::pool;
use crate::util::rng::Rng;

pub struct TrainerOptions {
    pub config: String,
    pub choice: OptimizerChoice,
    pub hyper: Hyper,
    pub seed: u64,
    pub run_name: String,
}

/// LoRA adapter state: adapters live host-side (they are tiny) with a
/// native AdamW; the base model is frozen literals.
struct LoraState {
    rank: usize,
    spec: Vec<(String, Vec<usize>)>,
    adapters: Vec<Vec<f32>>,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    t: usize,
    fwd: Rc<Exec>,
    eval: Rc<Exec>,
}

pub struct Trainer<'r> {
    reg: &'r Registry,
    pub cfg: ModelConfig,
    pub choice: OptimizerChoice,
    pub hyper: Hyper,
    /// Flat parameters in manifest order, resident as literals.
    params: Vec<xla::Literal>,
    fwd: Rc<Exec>,
    eval_exec: Rc<Exec>,
    /// Matrix layers (paper §5.5: transformer linears).
    mat_layers: Vec<MatLayer>,
    /// Everything else → AdamW.
    vec_layers: Vec<VecLayer>,
    /// Host-side full-rank gradient accumulators, lane-indexed by the
    /// step's tree-reduce schedule: `dense_acc[lane][param]`
    /// (DESIGN.md §13). The outer vector is fixed at [`TREE_WIDTH`];
    /// inner vectors are allocated lazily per *used* lane, and within a
    /// lane only for params that need dense folds (non-fused matrices +
    /// all non-matrix params) — the §5.5 memory story depends on this.
    /// Replica `k` owns the contiguous lane group
    /// `sched.replica_lanes(k, R)`, so lanes double as per-replica
    /// partial sums.
    dense_acc: Vec<Vec<Option<Vec<f32>>>>,
    dense_count: usize,
    /// Tree-reduce schedule for the current accumulation depth; rebuilt
    /// only when the micro-batch count changes.
    sched: Option<TreeSchedule>,
    /// Retained last micro-batch gradient per matrix layer, only when a
    /// GaLore resample is due this step.
    resample_grads: Vec<Option<xla::Literal>>,
    rng: Rng,
    pub metrics: TrainMetrics,
    pub step_idx: usize,
    lora: Option<LoraState>,
}

impl<'r> Trainer<'r> {
    pub fn new(reg: &'r Registry, opts: TrainerOptions) -> Result<Trainer<'r>> {
        let cfg = reg.config(&opts.config)?.clone();
        let r = opts.hyper.replicas;
        if r == 0 || !r.is_power_of_two() || TREE_WIDTH % r != 0 {
            bail!("replicas must be a power of two dividing the tree \
                   width {TREE_WIDTH}, got {r}");
        }
        let mut rng = Rng::new(opts.seed);
        let params = init_params(&cfg, &mut rng)?;
        let fwd = reg.load(&format!("{}_loss_and_grads", cfg.name))?;
        let eval_exec = reg.load(&format!("{}_eval_loss", cfg.name))?;

        let mut mat_layers = Vec::new();
        let mut vec_layers = Vec::new();
        let mut lora = None;
        match opts.choice {
            OptimizerChoice::Lora { rank, alpha: _ } => {
                let lfwd = reg.load(&format!(
                    "{}_lora_r{}_loss_and_grads", cfg.name, rank))?;
                let leval = reg.load(&format!(
                    "{}_lora_r{}_eval_loss", cfg.name, rank))?;
                let mut spec = Vec::new();
                let mut adapters = Vec::new();
                for (name, (m, n)) in cfg.matrix_params() {
                    spec.push((format!("{name}.A"), vec![m, rank]));
                    adapters.push(rng.normal_vec(m * rank, 0.02));
                    spec.push((format!("{name}.B"), vec![rank, n]));
                    adapters.push(vec![0.0; rank * n]);
                }
                let m = adapters.iter().map(|a| vec![0.0; a.len()]).collect();
                let v = adapters.iter().map(|a| vec![0.0; a.len()]).collect();
                lora = Some(LoraState {
                    rank,
                    spec,
                    adapters,
                    m,
                    v,
                    t: 0,
                    fwd: lfwd,
                    eval: leval,
                });
            }
            choice => {
                for (name, (m, n)) in cfg.matrix_params() {
                    let idx = cfg.param_index(&name).unwrap();
                    mat_layers.push(MatLayer::new(&name, m, n, idx, choice)?);
                }
                for (i, (name, dims)) in cfg.params.iter().enumerate() {
                    let is_matrix =
                        dims.len() == 2 && name.starts_with('l');
                    if !is_matrix {
                        vec_layers.push(VecLayer::new(name, dims, i)?);
                    }
                }
            }
        }
        let n_mat = mat_layers.len();
        Ok(Trainer {
            reg,
            cfg,
            choice: opts.choice,
            hyper: opts.hyper,
            params,
            fwd,
            eval_exec,
            mat_layers,
            vec_layers,
            dense_acc: (0..TREE_WIDTH).map(|_| Vec::new()).collect(),
            dense_count: 0,
            sched: None,
            resample_grads: (0..n_mat).map(|_| None).collect(),
            rng,
            metrics: TrainMetrics::new(&opts.run_name),
            step_idx: 0,
            lora,
        })
    }

    // -- batch marshaling ---------------------------------------------------

    fn lm_literals(&self, b: &LmBatch) -> Result<(xla::Literal, xla::Literal)> {
        if b.batch != self.cfg.batch || b.seq != self.cfg.seq {
            bail!("batch shape {}x{} != config {}x{}", b.batch, b.seq,
                  self.cfg.batch, self.cfg.seq);
        }
        Ok((
            lit_i32(&[b.batch, b.seq], &b.tokens)?,
            lit_i32(&[b.batch, b.seq], &b.targets)?,
        ))
    }

    fn cls_literals(&self, b: &ClsBatch) -> Result<(xla::Literal, xla::Literal)> {
        Ok((
            lit_i32(&[b.batch, b.seq], &b.tokens)?,
            lit_i32(&[b.batch], &b.labels)?,
        ))
    }

    // -- forward/backward ---------------------------------------------------

    /// Run fwd+bwd on one micro-batch; returns (loss, grads aligned with
    /// the flat parameter order).
    fn fwd_bwd(&self, tokens: &xla::Literal,
               labels: &xla::Literal) -> Result<(f32, Vec<xla::Literal>)> {
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(tokens);
        inputs.push(labels);
        let mut outs = self.fwd.run(&inputs)?;
        let grads = outs.split_off(1);
        let loss = scalar_f32(&outs[0])?;
        Ok((loss, grads))
    }

    /// The tree-reduce schedule for a step of `total` micro-batches
    /// (cached across steps; rebuilt only when the count changes).
    fn schedule_for(&mut self, total: usize) -> &TreeSchedule {
        if self.sched.as_ref().map(|s| s.n_items()) != Some(total) {
            self.sched = Some(TreeSchedule::new(total, TREE_WIDTH));
        }
        self.sched.as_ref().unwrap()
    }

    /// Micro-batch accumulation: fused low-rank for capable optimizers,
    /// host-side dense for the rest (and for all non-matrix params).
    ///
    /// Every fold lands in the micro-batch's *lane* — the schedule's
    /// partial sum owned by exactly one replica (DESIGN.md §13) —
    /// rather than one global accumulator; [`Trainer::apply_step`]
    /// folds the lanes through the fixed tree. The PJRT dispatches stay
    /// serial (the client is single-threaded); the host-side dense
    /// folds are batched fleet-style — the long tail of small gradients
    /// folds into its lane accumulators in ONE pool dispatch
    /// (`fold_dense_batch`) instead of paying a fork-join per layer.
    /// Gradients at or above [`FOLD_BIG`] elements (the embedding
    /// class) are marshaled, chunk-parallel folded, and dropped one at
    /// a time, preserving the §5.5 one-large-gradient-at-a-time peak
    /// memory story.
    fn accumulate_micro(&mut self, loss_grads: Vec<xla::Literal>,
                        micro_index: usize, total_micro: usize) -> Result<()> {
        let lane = self.schedule_for(total_micro).lane_of_item(micro_index);
        let _sp = obs::span_args(obs::Category::Engine, "accum_micro",
                                 [lane as u32, micro_index as u32,
                                  total_micro as u32]);
        let n_params = self.params.len();
        if self.dense_acc[lane].is_empty() {
            self.dense_acc[lane] = (0..n_params).map(|_| None).collect();
        }
        let fused = self.hyper.fused;
        let workers = crate::fusion::workers();
        let mut small: Vec<(usize, Vec<f32>)> = Vec::with_capacity(
            self.mat_layers.len() + self.vec_layers.len());
        for li in 0..self.mat_layers.len() {
            let pidx = self.mat_layers[li].param_idx;
            let g = &loss_grads[pidx];
            let resample_due = self.galore_resample_due(li);
            if fused && self.mat_layers[li].supports_fused() {
                let layer = &mut self.mat_layers[li];
                layer.accumulate(self.reg, g, &mut self.rng, lane,
                                 TREE_WIDTH)?;
                // Retain the final micro-batch's gradient only when the
                // GaLore subspace refresh fires at this step boundary.
                if resample_due && micro_index + 1 == total_micro {
                    self.resample_grads[li] = Some(clone_lit(g)?);
                }
            } else {
                fold_or_defer(&mut self.dense_acc[lane], &mut small, pidx,
                              to_f32_vec(g)?, workers);
            }
        }
        for vl in &self.vec_layers {
            fold_or_defer(&mut self.dense_acc[lane], &mut small,
                          vl.param_idx,
                          to_f32_vec(&loss_grads[vl.param_idx])?, workers);
        }
        fold_dense_batch(&mut self.dense_acc[lane], small, workers);
        self.dense_count += 1;
        Ok(())
    }

    fn galore_resample_due(&self, layer_idx: usize) -> bool {
        match &self.mat_layers[layer_idx].state {
            MatState::GaLore { tau, t, .. } => (*t + 1) % *tau == 0,
            _ => false,
        }
    }

    /// Fold the dense lane accumulators down to lane 0 through the
    /// schedule's fixed pair order. Each fold edge moves or adds whole
    /// param slots; the adds run through [`reduce::fold_lane`] so they
    /// are per-element worker-invariant and accounted to
    /// `bytes_reduced`. Lanes the schedule never populated are empty
    /// and skipped.
    fn tree_reduce_dense(&mut self) {
        let Some(sched) = &self.sched else { return };
        let workers = crate::fusion::workers();
        let _sp = obs::span(obs::Category::Engine, "tree_reduce");
        for &(d, s) in sched.pairs() {
            debug_assert!(d < s, "schedule pairs fold right into left");
            let (lo, hi) = self.dense_acc.split_at_mut(s);
            let (dst_lane, src_lane) = (&mut lo[d], &mut hi[0]);
            if src_lane.is_empty() {
                continue;
            }
            if dst_lane.is_empty() {
                *dst_lane = std::mem::take(src_lane);
                continue;
            }
            for (dslot, sslot) in
                dst_lane.iter_mut().zip(src_lane.iter_mut())
            {
                let Some(b) = sslot.take() else { continue };
                match dslot {
                    Some(a) => reduce::fold_lane(a, &b, workers),
                    slot => *slot = Some(b),
                }
            }
        }
    }

    /// Apply the optimizer step from whatever was accumulated.
    ///
    /// First folds the per-replica lane partial sums into lane 0 with
    /// the fixed-topology tree (dense accumulators via
    /// [`Trainer::tree_reduce_dense`], fused low-rank buffers via
    /// [`MatLayer::reduce_lanes`]) — the association depends only on
    /// the micro-batch count, so every `(replicas, workers)` setting
    /// produces the same bits (DESIGN.md §13).
    ///
    /// Host-side work then runs fleet-style: the gradient-mean
    /// `1/count` scale folds into every pending lane-0 accumulator in
    /// ONE pool dispatch, in place. (Multiplying by the reciprocal
    /// matches the fused `*_step_from_buf` artifacts, which take the
    /// same `scale` scalar.) The per-layer artifact dispatches
    /// themselves stay serial — the PJRT client is single-threaded
    /// (see the ROADMAP open item).
    ///
    /// An `Err` from a per-layer dispatch leaves the step partially
    /// applied (earlier layers stepped, remaining accumulators already
    /// mean-scaled) — step errors are fatal to the run, not retryable,
    /// which was equally true of the old divide-at-consumption path
    /// (earlier layers had stepped and `dense_count` was not reset).
    fn apply_step(&mut self) -> Result<()> {
        let scale = self.hyper.schedule.scale(self.step_idx);
        let eta = (self.hyper.lr * scale) as f32;
        let emb_eta = (self.hyper.emb_lr * scale) as f32;
        self.tree_reduce_dense();
        let fused = self.hyper.fused;
        if let Some(sched) = self.sched.as_ref() {
            for layer in &mut self.mat_layers {
                if fused && layer.supports_fused() {
                    layer.reduce_lanes(sched)?;
                }
            }
        }
        let count = self.dense_count.max(1) as f32;
        if count > 1.0 {
            // Every `Some` slot is a pending accumulator consumed below.
            let inv = 1.0 / count;
            pool::par_for_each_mut(
                &mut self.dense_acc[0],
                crate::fusion::workers(),
                |slot| {
                    if let Some(acc) = slot {
                        for x in acc.iter_mut() {
                            *x *= inv;
                        }
                    }
                },
            );
        }
        for li in 0..self.mat_layers.len() {
            let pidx = self.mat_layers[li].param_idx;
            let fused = self.hyper.fused
                && self.mat_layers[li].supports_fused();
            let new_w = if fused {
                let rg = self.resample_grads[li].take();
                let layer = &mut self.mat_layers[li];
                layer.step_fused(self.reg, &self.params[pidx], eta,
                                 rg.as_ref(), &mut self.rng)?
            } else {
                let acc = self.dense_acc[0][pidx]
                    .take()
                    .ok_or_else(|| anyhow!("no dense grad for {}",
                                           self.mat_layers[li].name))?;
                let layer = &mut self.mat_layers[li];
                let g = lit_f32(&[layer.m, layer.n], &acc)?;
                layer.step_dense(self.reg, &self.params[pidx], &g, eta,
                                 &mut self.rng)?
            };
            self.params[pidx] = new_w;
        }
        for vi in 0..self.vec_layers.len() {
            let pidx = self.vec_layers[vi].param_idx;
            let acc = self.dense_acc[0][pidx]
                .take()
                .ok_or_else(|| anyhow!("no dense grad for {}",
                                       self.vec_layers[vi].name))?;
            let vl = &mut self.vec_layers[vi];
            let g = lit_f32(&vl.dims, &acc)?;
            let new_w = vl.step(self.reg, &self.params[pidx], &g, emb_eta,
                                self.hyper.weight_decay)?;
            self.params[pidx] = new_w;
        }
        self.dense_count = 0;
        self.step_idx += 1;
        Ok(())
    }

    /// One-shot step from a single micro-batch's gradient literals:
    /// per-layer step artifacts consume the gradients directly. There is
    /// no host-side math to batch here — the whole step is per-layer
    /// PJRT dispatch, which the single-threaded client serializes; when
    /// that constraint lifts (ROADMAP: per-layer clients / multi-stream
    /// executor) this loop becomes a fleet of artifact-dispatch units
    /// exactly like the native path's `optim::fleet`.
    fn apply_step_single(&mut self, grads: Vec<xla::Literal>) -> Result<()> {
        let scale = self.hyper.schedule.scale(self.step_idx);
        let eta = (self.hyper.lr * scale) as f32;
        let emb_eta = (self.hyper.emb_lr * scale) as f32;
        for li in 0..self.mat_layers.len() {
            let pidx = self.mat_layers[li].param_idx;
            let layer = &mut self.mat_layers[li];
            let new_w = layer.step_dense(self.reg, &self.params[pidx],
                                         &grads[pidx], eta, &mut self.rng)?;
            self.params[pidx] = new_w;
        }
        for vi in 0..self.vec_layers.len() {
            let pidx = self.vec_layers[vi].param_idx;
            let vl = &mut self.vec_layers[vi];
            let new_w = vl.step(self.reg, &self.params[pidx], &grads[pidx],
                                emb_eta, self.hyper.weight_decay)?;
            self.params[pidx] = new_w;
        }
        self.step_idx += 1;
        Ok(())
    }

    /// One optimizer step over `hyper.accum` LM micro-batches.
    pub fn step_lm(&mut self, micro: &[LmBatch]) -> Result<f32> {
        assert_eq!(micro.len(), self.hyper.accum, "micro-batch count");
        if self.lora.is_some() {
            return self.step_lora(micro);
        }
        let _step = obs::span(obs::Category::Engine, "step");
        let mut mean_loss = 0.0f32;
        let total = micro.len();
        if total == 1 {
            // §Perf fast path: a single micro-batch needs no accumulation
            // buffers — dispatch the one-shot step artifact per layer
            // (one PJRT execute instead of accum + step_from_buf).
            let t = PhaseTimer::begin(Phase::Marshal);
            let (tokens, targets) = self.lm_literals(&micro[0])?;
            self.metrics.end_phase(t);
            let t = PhaseTimer::begin(Phase::Fwd);
            let (loss, grads) = self.fwd_bwd(&tokens, &targets)?;
            self.metrics.end_phase(t);
            let t = PhaseTimer::begin(Phase::Opt);
            self.apply_step_single(grads)?;
            self.metrics.end_phase(t);
            let tokens = self.cfg.batch * self.cfg.seq;
            self.metrics.log_train(self.step_idx, loss, tokens);
            return Ok(loss);
        }
        for (i, mb) in micro.iter().enumerate() {
            let t = PhaseTimer::begin(Phase::Marshal);
            let (tokens, targets) = self.lm_literals(mb)?;
            self.metrics.end_phase(t);
            let t = PhaseTimer::begin(Phase::Fwd);
            let (loss, grads) = self.fwd_bwd(&tokens, &targets)?;
            self.metrics.end_phase(t);
            mean_loss += loss / total as f32;
            let t = PhaseTimer::begin(Phase::Opt);
            self.accumulate_micro(grads, i, total)?;
            self.metrics.end_phase(t);
        }
        let t = PhaseTimer::begin(Phase::Opt);
        self.apply_step()?;
        self.metrics.end_phase(t);
        let tokens = total * self.cfg.batch * self.cfg.seq;
        self.metrics.log_train(self.step_idx, mean_loss, tokens);
        Ok(mean_loss)
    }

    /// One optimizer step over classification micro-batches.
    pub fn step_cls(&mut self, micro: &[ClsBatch]) -> Result<f32> {
        assert_eq!(micro.len(), self.hyper.accum);
        if self.lora.is_some() {
            return self.step_lora_cls(micro);
        }
        let _step = obs::span(obs::Category::Engine, "step");
        let mut mean_loss = 0.0f32;
        let total = micro.len();
        for (i, mb) in micro.iter().enumerate() {
            let t = PhaseTimer::begin(Phase::Marshal);
            let (tokens, labels) = self.cls_literals(mb)?;
            self.metrics.end_phase(t);
            let t = PhaseTimer::begin(Phase::Fwd);
            let (loss, grads) = self.fwd_bwd(&tokens, &labels)?;
            self.metrics.end_phase(t);
            mean_loss += loss / total as f32;
            let t = PhaseTimer::begin(Phase::Opt);
            self.accumulate_micro(grads, i, total)?;
            self.metrics.end_phase(t);
        }
        let t = PhaseTimer::begin(Phase::Opt);
        self.apply_step()?;
        self.metrics.end_phase(t);
        let tokens = total * self.cfg.batch * self.cfg.seq;
        self.metrics.log_train(self.step_idx, mean_loss, tokens);
        Ok(mean_loss)
    }

    // -- LoRA path -----------------------------------------------------------

    fn lora_fwd_bwd(&mut self, tokens: &xla::Literal, labels: &xla::Literal)
                    -> Result<(f32, Vec<Vec<f32>>)> {
        let lora = self.lora.as_ref().unwrap();
        let ad_lits: Vec<xla::Literal> = lora
            .adapters
            .iter()
            .zip(&lora.spec)
            .map(|(a, (_, dims))| lit_f32(dims, a))
            .collect::<Result<Vec<_>>>()?;
        let mut inputs: Vec<&xla::Literal> = ad_lits.iter().collect();
        inputs.extend(self.params.iter());
        inputs.push(tokens);
        inputs.push(labels);
        let mut outs = lora.fwd.run(&inputs)?;
        let grads = outs
            .split_off(1)
            .iter()
            .map(to_f32_vec)
            .collect::<Result<Vec<_>>>()?;
        Ok((scalar_f32(&outs[0])?, grads))
    }

    fn step_lora(&mut self, micro: &[LmBatch]) -> Result<f32> {
        let total = micro.len();
        let mut mean_loss = 0.0f32;
        let mut acc: Option<Vec<Vec<f32>>> = None;
        for mb in micro {
            let (tokens, targets) = self.lm_literals(mb)?;
            let (loss, grads) = self.lora_fwd_bwd(&tokens, &targets)?;
            mean_loss += loss / total as f32;
            match &mut acc {
                None => acc = Some(grads),
                Some(a) => {
                    for (dst, src) in a.iter_mut().zip(&grads) {
                        for (d, s) in dst.iter_mut().zip(src) {
                            *d += s;
                        }
                    }
                }
            }
        }
        self.lora_adamw_step(acc.unwrap(), total)?;
        let tokens = total * self.cfg.batch * self.cfg.seq;
        self.metrics.log_train(self.step_idx, mean_loss, tokens);
        Ok(mean_loss)
    }

    pub fn step_lora_cls(&mut self, micro: &[ClsBatch]) -> Result<f32> {
        let total = micro.len();
        let mut mean_loss = 0.0f32;
        let mut acc: Option<Vec<Vec<f32>>> = None;
        for mb in micro {
            let (tokens, labels) = self.cls_literals(mb)?;
            let (loss, grads) = self.lora_fwd_bwd(&tokens, &labels)?;
            mean_loss += loss / total as f32;
            match &mut acc {
                None => acc = Some(grads),
                Some(a) => {
                    for (dst, src) in a.iter_mut().zip(&grads) {
                        for (d, s) in dst.iter_mut().zip(src) {
                            *d += s;
                        }
                    }
                }
            }
        }
        self.lora_adamw_step(acc.unwrap(), total)?;
        let tokens = total * self.cfg.batch * self.cfg.seq;
        self.metrics.log_train(self.step_idx, mean_loss, tokens);
        Ok(mean_loss)
    }

    fn lora_adamw_step(&mut self, acc: Vec<Vec<f32>>, count: usize) -> Result<()> {
        let scale = self.hyper.schedule.scale(self.step_idx);
        let eta = (self.hyper.lr * scale) as f32;
        let (b1, b2) = (self.hyper.b1, self.hyper.b2);
        let lora = self.lora.as_mut().unwrap();
        lora.t += 1;
        let t = lora.t as f32;
        let (bc1, bc2) = (1.0 - b1.powf(t), 1.0 - b2.powf(t));
        for (k, grads) in acc.iter().enumerate() {
            let inv = 1.0 / count as f32;
            for i in 0..grads.len() {
                let g = grads[i] * inv;
                lora.m[k][i] = b1 * lora.m[k][i] + (1.0 - b1) * g;
                lora.v[k][i] = b2 * lora.v[k][i] + (1.0 - b2) * g * g;
                let mh = lora.m[k][i] / bc1;
                let vh = lora.v[k][i] / bc2;
                lora.adapters[k][i] -= eta * mh / (vh.max(0.0).sqrt() + 1e-8);
            }
        }
        self.step_idx += 1;
        Ok(())
    }

    // -- evaluation ------------------------------------------------------------

    pub fn eval_lm(&mut self, batches: &[LmBatch]) -> Result<f32> {
        let mut total = 0.0f32;
        for b in batches {
            let (tokens, targets) = self.lm_literals(b)?;
            total += self.eval_loss(&tokens, &targets)?;
        }
        let loss = total / batches.len().max(1) as f32;
        self.metrics.log_val(self.step_idx, loss);
        Ok(loss)
    }

    pub fn eval_cls_loss(&mut self, batches: &[ClsBatch]) -> Result<f32> {
        let mut total = 0.0f32;
        for b in batches {
            let (tokens, labels) = self.cls_literals(b)?;
            total += self.eval_loss(&tokens, &labels)?;
        }
        let loss = total / batches.len().max(1) as f32;
        self.metrics.log_val(self.step_idx, loss);
        Ok(loss)
    }

    fn eval_loss(&self, tokens: &xla::Literal,
                 labels: &xla::Literal) -> Result<f32> {
        if let Some(lora) = &self.lora {
            let ad_lits: Vec<xla::Literal> = lora
                .adapters
                .iter()
                .zip(&lora.spec)
                .map(|(a, (_, dims))| lit_f32(dims, a))
                .collect::<Result<Vec<_>>>()?;
            let mut inputs: Vec<&xla::Literal> = ad_lits.iter().collect();
            inputs.extend(self.params.iter());
            inputs.push(tokens);
            inputs.push(labels);
            return scalar_f32(&lora.eval.run(&inputs)?[0]);
        }
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(tokens);
        inputs.push(labels);
        scalar_f32(&self.eval_exec.run(&inputs)?[0])
    }

    /// Classification accuracy over batches (Table 3 metric).
    pub fn eval_cls_accuracy(&self, batches: &[ClsBatch]) -> Result<f64> {
        if self.lora.is_some() {
            return self.eval_cls_accuracy_lora(batches);
        }
        let exec = self.reg.load(&format!("{}_cls_logits", self.cfg.name))?;
        let ncls = self.cfg.ncls;
        let mut correct = 0usize;
        let mut total = 0usize;
        for b in batches {
            let (tokens, _) = self.cls_literals(b)?;
            let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
            inputs.push(&tokens);
            let logits = to_f32_vec(&exec.run(&inputs)?[0])?;
            for (row, &label) in b.labels.iter().enumerate() {
                let sl = &logits[row * ncls..(row + 1) * ncls];
                let pred = argmax_logits(sl);
                correct += usize::from(pred as i32 == label);
                total += 1;
            }
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    fn eval_cls_accuracy_lora(&self, batches: &[ClsBatch]) -> Result<f64> {
        // Merge adapters into a copy of the base weights, then reuse the
        // plain cls_logits artifact.
        let lora = self.lora.as_ref().unwrap();
        let exec = self.reg.load(&format!("{}_cls_logits", self.cfg.name))?;
        let ncls = self.cfg.ncls;
        let merged = self.merged_lora_params(lora)?;
        let mut correct = 0usize;
        let mut total = 0usize;
        for b in batches {
            let (tokens, _) = self.cls_literals(b)?;
            let mut inputs: Vec<&xla::Literal> = merged.iter().collect();
            inputs.push(&tokens);
            let logits = to_f32_vec(&exec.run(&inputs)?[0])?;
            for (row, &label) in b.labels.iter().enumerate() {
                let sl = &logits[row * ncls..(row + 1) * ncls];
                let pred = argmax_logits(sl);
                correct += usize::from(pred as i32 == label);
                total += 1;
            }
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    fn merged_lora_params(&self, lora: &LoraState) -> Result<Vec<xla::Literal>> {
        use crate::linalg::Mat;
        let mut merged = Vec::with_capacity(self.params.len());
        let alpha = match self.choice {
            OptimizerChoice::Lora { alpha, .. } => alpha,
            _ => 2.0 * lora.rank as f32,
        };
        let ad: std::collections::BTreeMap<&str, (&Vec<usize>, &Vec<f32>)> =
            lora.spec.iter().zip(&lora.adapters)
                .map(|((n, d), a)| (n.as_str(), (d, a)))
                .collect();
        for (i, (name, dims)) in self.cfg.params.iter().enumerate() {
            let is_matrix = dims.len() == 2 && name.starts_with('l');
            if !is_matrix {
                merged.push(clone_lit(&self.params[i])?);
                continue;
            }
            let a_key = format!("{name}.A");
            let b_key = format!("{name}.B");
            let (ad_dims, a_data) = ad[a_key.as_str()];
            let (_, b_data) = ad[b_key.as_str()];
            let (m, n) = (dims[0], dims[1]);
            let r = ad_dims[1];
            let a_mat = Mat::from_vec(m, r, a_data.clone());
            let b_mat = Mat::from_vec(r, n, b_data.clone());
            let w = Mat::from_vec(m, n, to_f32_vec(&self.params[i])?);
            let w_eff = w.add(&a_mat.matmul(&b_mat).scale(alpha / r as f32));
            merged.push(lit_f32(&[m, n], &w_eff.data)?);
        }
        Ok(merged)
    }

    /// Teacher-forced answer exact-match over instruction examples
    /// (Table 4 metric; see `model.token_correct`).
    pub fn answer_exact_match(&self, examples: &[Example]) -> Result<SuiteScore> {
        let exec =
            self.reg.load(&format!("{}_token_correct", self.cfg.name))?;
        let (bsz, seq) = (self.cfg.batch, self.cfg.seq);
        // LoRA: evaluate the merged effective weights, not the frozen base.
        let merged = match &self.lora {
            Some(l) => Some(self.merged_lora_params(l)?),
            None => None,
        };
        let eval_params: &[xla::Literal] = match &merged {
            Some(m) => m,
            None => &self.params,
        };
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut token_hits = 0usize;
        let mut token_total = 0usize;
        for chunk in examples.chunks(bsz) {
            let mut tokens = Vec::with_capacity(bsz * seq);
            let mut targets = Vec::with_capacity(bsz * seq);
            for ex in chunk {
                tokens.extend_from_slice(&ex.tokens);
                let mut y = ex.tokens[1..].to_vec();
                y.push(*ex.tokens.last().unwrap());
                targets.extend_from_slice(&y);
            }
            // pad the final partial chunk by repeating the last example
            while tokens.len() < bsz * seq {
                let start = tokens.len() - seq;
                let (t_prev, y_prev) = (
                    tokens[start..].to_vec(),
                    targets[start..].to_vec(),
                );
                tokens.extend_from_slice(&t_prev);
                targets.extend_from_slice(&y_prev);
            }
            let t_lit = lit_i32(&[bsz, seq], &tokens)?;
            let y_lit = lit_i32(&[bsz, seq], &targets)?;
            let mut inputs: Vec<&xla::Literal> = eval_params.iter().collect();
            inputs.push(&t_lit);
            inputs.push(&y_lit);
            let corr = to_f32_vec(&exec.run(&inputs)?[0])?;
            for (row, ex) in chunk.iter().enumerate() {
                // predict every answer token plus the EOS terminator:
                // positions [answer_start-1, answer_start+len(answer)].
                let lo = ex.answer_start - 1;
                let hi = (ex.answer_start + ex.answer.len()).min(seq - 1);
                let all = (lo..=hi)
                    .all(|t| corr[row * seq + t] > 0.5);
                correct += usize::from(all);
                token_hits += (lo..=hi)
                    .filter(|&t| corr[row * seq + t] > 0.5).count();
                token_total += hi - lo + 1;
                total += 1;
            }
        }
        Ok(SuiteScore {
            exact: correct as f64 / total.max(1) as f64,
            token: token_hits as f64 / token_total.max(1) as f64,
        })
    }

    /// Borrow the resident parameter literals (probing / external eval).
    pub fn params_literals(&self) -> impl Iterator<Item = &xla::Literal> {
        self.params.iter()
    }

    // -- state I/O ---------------------------------------------------------

    pub fn save_checkpoint(&self, path: &str) -> Result<()> {
        let tensors = self
            .cfg
            .params
            .iter()
            .zip(&self.params)
            .map(|((name, dims), lit)| {
                Ok((name.clone(), dims.clone(), to_f32_vec(lit)?))
            })
            .collect::<Result<Vec<_>>>()?;
        Checkpoint { tensors }.save(path)
    }

    pub fn load_checkpoint(&mut self, path: &str) -> Result<()> {
        let ck = Checkpoint::load(path)?;
        if ck.tensors.len() != self.params.len() {
            bail!("checkpoint has {} tensors, model needs {}",
                  ck.tensors.len(), self.params.len());
        }
        for (i, ((name, dims), (ck_name, ck_dims, data))) in
            self.cfg.params.iter().zip(&ck.tensors).enumerate()
        {
            if name != ck_name || dims != ck_dims {
                bail!("checkpoint tensor {i}: {ck_name}{ck_dims:?} vs \
                       expected {name}{dims:?}");
            }
            self.params[i] = lit_f32(dims, data)?;
        }
        Ok(())
    }

    /// Measured optimizer-state footprint in f32s (Table 2 validation).
    pub fn optimizer_state_floats(&self) -> usize {
        let mat: usize =
            self.mat_layers.iter().map(|l| l.state_floats()).sum();
        let vec: usize =
            self.vec_layers.iter().map(|l| l.state_floats()).sum();
        let lora: usize = self.lora.as_ref().map(|l| {
            l.adapters.iter().map(|a| 3 * a.len()).sum() // A/B + m + v
        }).unwrap_or(0);
        mat + vec + lora
    }

    /// Peak gradient-buffer footprint in f32s under the current
    /// accumulation mode (§5.5 fused vs non-fused comparison). Each
    /// lane the tree-reduce schedule populates at the configured
    /// accumulation depth owns its own accumulator set (DESIGN.md §13),
    /// so the per-layer figures scale by the used-lane count — 1 at
    /// `accum = 1`, up to [`TREE_WIDTH`].
    pub fn gradient_buffer_floats(&self) -> usize {
        let lanes = TreeSchedule::new(self.hyper.accum.max(1), TREE_WIDTH)
            .ranges()
            .iter()
            .filter(|r| r.1 > r.0)
            .count()
            .max(1);
        let mut total = 0usize;
        for l in &self.mat_layers {
            if self.hyper.fused && l.supports_fused() {
                total += match &l.state {
                    MatState::MoFaSgd { rank, .. } =>
                        l.m * rank + rank * l.n + rank * rank,
                    MatState::GaLore { rank, .. } => rank * l.n,
                    _ => 0,
                };
            } else {
                total += l.m * l.n;
            }
        }
        for v in &self.vec_layers {
            total += v.dims.iter().product::<usize>().max(1);
        }
        total * lanes
    }
}

/// Element-count threshold above which a gradient folds immediately
/// (chunk-parallel, then dropped — §5.5 peak memory) rather than being
/// deferred into the layer-parallel small batch.
const FOLD_BIG: usize = 1 << 18;

/// Route one marshaled gradient: large ones fold into their accumulator
/// right away, chunk-parallel across the whole pool, and are dropped —
/// at most one large f32 copy is ever alive; small ones are deferred
/// into `small` for a single layer-parallel dispatch at the end of the
/// micro-batch ([`fold_dense_batch`]).
fn fold_or_defer(acc: &mut [Option<Vec<f32>>],
                 small: &mut Vec<(usize, Vec<f32>)>, idx: usize,
                 v: Vec<f32>, workers: usize) {
    if v.len() >= FOLD_BIG {
        fold_par(&mut acc[idx], v, workers);
    } else {
        small.push((idx, v));
    }
}

fn fold_par(slot: &mut Option<Vec<f32>>, v: Vec<f32>, workers: usize) {
    match slot {
        None => *slot = Some(v),
        Some(acc) => pool::par_add_assign(acc, &v, workers),
    }
}

/// Fold the micro-batch's deferred small gradients into their
/// accumulator slots in one layer-parallel pool dispatch — one spawn
/// set for the whole tail, versus the per-layer fork-join of the old
/// `fold_dense` loop.
fn fold_dense_batch(acc: &mut [Option<Vec<f32>>],
                    mut grads: Vec<(usize, Vec<f32>)>, workers: usize) {
    if grads.is_empty() {
        return;
    }
    grads.sort_by_key(|(i, _)| *i);
    // Tied parameters could route two gradients to one slot in a single
    // micro-batch; merge duplicates up front so the disjoint-slot walk
    // below stays valid (today indices are unique — this is defensive).
    let mut merged: Vec<(usize, Vec<f32>)> = Vec::with_capacity(grads.len());
    for (idx, v) in grads {
        match merged.last_mut() {
            Some((last, sum)) if *last == idx => {
                assert_eq!(sum.len(), v.len(),
                           "gradient fold length mismatch");
                for (a, b) in sum.iter_mut().zip(&v) {
                    *a += *b;
                }
            }
            _ => merged.push((idx, v)),
        }
    }
    // Walk the accumulator slots once to materialize disjoint `&mut`
    // borrows for exactly the indices this batch touches.
    let mut jobs: Vec<(&mut Option<Vec<f32>>, Vec<f32>)> =
        Vec::with_capacity(merged.len());
    let mut slots = acc.iter_mut().enumerate();
    for (idx, v) in merged {
        let slot = loop {
            let (i, s) = slots.next().expect("gradient index out of range");
            if i == idx {
                break s;
            }
        };
        jobs.push((slot, v));
    }
    pool::par_for_each_mut(&mut jobs, workers, |(slot, v)| {
        fold_one(slot, std::mem::take(v));
    });
}

fn fold_one(slot: &mut Option<Vec<f32>>, v: Vec<f32>) {
    match slot {
        None => *slot = Some(v),
        Some(acc) => {
            assert_eq!(acc.len(), v.len(), "gradient fold length mismatch");
            for (a, b) in acc.iter_mut().zip(&v) {
                *a += *b;
            }
        }
    }
}

fn clone_lit(l: &xla::Literal) -> Result<xla::Literal> {
    let shape = l
        .array_shape()
        .map_err(|e| anyhow!("shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    lit_f32(&dims, &to_f32_vec(l)?)
}

fn init_params(cfg: &ModelConfig, rng: &mut Rng) -> Result<Vec<xla::Literal>> {
    let mut out = Vec::with_capacity(cfg.params.len());
    for (name, dims) in &cfg.params {
        let numel: usize = dims.iter().product::<usize>().max(1);
        let data = if dims.len() == 1 {
            vec![1.0f32; numel]
        } else {
            let std = if name.contains("emb") {
                0.02
            } else {
                1.0 / (dims[0] as f32).sqrt()
            };
            rng.normal_vec(numel, std)
        };
        out.push(lit_f32(dims, &data)?);
    }
    Ok(out)
}

/// Answer-span score: `exact` = whole-answer teacher-forced exact match;
/// `token` = per-token answer accuracy (the discriminative metric at the
/// scaled-down model sizes; exact match saturates at ~0 for tiny models).
#[derive(Debug, Clone, Copy)]
pub struct SuiteScore {
    pub exact: f64,
    pub token: f64,
}

/// Named bundle of instruction-task scores (Table 4 row).
pub struct EvalSuite {
    pub scores: Vec<(String, f64)>,
}

impl EvalSuite {
    pub fn average(&self) -> f64 {
        let s: f64 = self.scores.iter().map(|(_, v)| v).sum();
        s / self.scores.len().max(1) as f64
    }
}

/// Index of the largest logit in one row, NaN-tolerant.
///
/// `total_cmp` gives NaN a defined order (positive NaN sorts above every
/// finite value), so a degenerate logits row — a diverged model emitting
/// NaN — yields a deterministic prediction instead of the
/// `partial_cmp().unwrap()` panic this replaced (same fix class as the
/// Jacobi sort in `linalg::svd`). Empty rows return 0.
pub(crate) fn argmax_logits(sl: &[f32]) -> usize {
    (0..sl.len())
        .max_by(|&a, &b| sl[a].total_cmp(&sl[b]))
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::argmax_logits;

    #[test]
    fn argmax_logits_picks_largest() {
        assert_eq!(argmax_logits(&[0.1, 2.0, -3.0, 1.9]), 1);
        assert_eq!(argmax_logits(&[-5.0]), 0);
        // Ties resolve to the last maximal index (max_by keeps later
        // elements on Equal) — any fixed rule is fine, it must just be
        // deterministic.
        assert_eq!(argmax_logits(&[7.0, 7.0, 1.0]), 1);
    }

    #[test]
    fn argmax_logits_survives_nan_rows() {
        // Regression: the old `partial_cmp().unwrap()` panicked on the
        // first NaN comparison. total_cmp orders +NaN above +inf, so a
        // NaN logit wins deterministically and accuracy evaluation keeps
        // going instead of aborting the run.
        let pnan = f32::from_bits(0x7fc0_0000); // +quiet NaN
        let nnan = f32::from_bits(0xffc0_0000); // -quiet NaN
        assert_eq!(argmax_logits(&[1.0, pnan, 0.5]), 1);
        assert_eq!(argmax_logits(&[f32::NAN, f32::NAN]), 1);
        // -NaN sorts below every finite value; finite entries still win.
        assert_eq!(argmax_logits(&[nnan, 3.0, 2.0]), 1);
        assert_eq!(argmax_logits(&[]), 0);
    }
}


//! Per-matrix optimizer state over PJRT literals + artifact dispatch.
//!
//! Each 2-D transformer linear owns one `MatState`; the engine routes its
//! gradient here and the state machine calls the right per-shape artifact
//! (`mofasgd_step_256x768_r8`, …). MoFaSGD and GaLore additionally expose
//! the §5.5 fused accumulation path where only low-rank projections of the
//! gradient survive across micro-batches.

use anyhow::{anyhow, Result};

use crate::coordinator::hp::OptimizerChoice;
use crate::runtime::{lit_f32, lit_scalar, Registry};
use crate::util::rng::Rng;

pub struct MatLayer {
    pub name: String,
    pub m: usize,
    pub n: usize,
    /// Index into the flat parameter list.
    pub param_idx: usize,
    pub state: MatState,
}

pub enum MatState {
    MoFaSgd {
        rank: usize,
        beta: f32,
        /// (U, s, V) literals once initialized from the first gradient.
        factors: Option<(xla::Literal, xla::Literal, xla::Literal)>,
        /// Fused low-rank accumulation buffers (GV, UᵀG, UᵀGV).
        bufs: Option<(xla::Literal, xla::Literal, xla::Literal)>,
        count: usize,
    },
    GaLore {
        rank: usize,
        tau: usize,
        q: Option<xla::Literal>,
        m1: xla::Literal,
        m2: xla::Literal,
        t: usize,
        /// Fused buffer: accumulated QᵀG.
        buf: Option<xla::Literal>,
        count: usize,
    },
    Muon { beta: f32, m: xla::Literal },
    AdamW { m: xla::Literal, v: xla::Literal, t: usize },
    Lion { m: xla::Literal },
    SgdM { beta: f32, m: xla::Literal },
    SignSgd,
    Adafactor { r_acc: xla::Literal, c_acc: xla::Literal },
}

fn zeros(dims: &[usize]) -> Result<xla::Literal> {
    lit_f32(dims, &vec![0.0; dims.iter().product::<usize>().max(1)])
}

impl MatLayer {
    pub fn new(name: &str, m: usize, n: usize, param_idx: usize,
               choice: OptimizerChoice) -> Result<MatLayer> {
        let state = match choice {
            OptimizerChoice::MoFaSgd { rank, beta } => MatState::MoFaSgd {
                rank,
                beta,
                factors: None,
                bufs: None,
                count: 0,
            },
            OptimizerChoice::GaLore { rank, tau } => MatState::GaLore {
                rank,
                tau,
                q: None,
                m1: zeros(&[rank, n])?,
                m2: zeros(&[rank, n])?,
                t: 0,
                buf: None,
                count: 0,
            },
            OptimizerChoice::Muon { beta } =>
                MatState::Muon { beta, m: zeros(&[m, n])? },
            OptimizerChoice::AdamW => MatState::AdamW {
                m: zeros(&[m, n])?,
                v: zeros(&[m, n])?,
                t: 0,
            },
            OptimizerChoice::Lion => MatState::Lion { m: zeros(&[m, n])? },
            OptimizerChoice::SgdM { beta } =>
                MatState::SgdM { beta, m: zeros(&[m, n])? },
            OptimizerChoice::SignSgd => MatState::SignSgd,
            OptimizerChoice::Adafactor => MatState::Adafactor {
                r_acc: zeros(&[m])?,
                c_acc: zeros(&[n])?,
            },
            OptimizerChoice::Lora { .. } => {
                return Err(anyhow!(
                    "LoRA is handled by the adapter engine, not MatLayer"
                ))
            }
        };
        Ok(MatLayer { name: name.to_string(), m, n, param_idx, state })
    }

    /// Whether this state supports the §5.5 fused low-rank accumulation.
    pub fn supports_fused(&self) -> bool {
        matches!(self.state,
                 MatState::MoFaSgd { .. } | MatState::GaLore { .. })
    }

    /// Persistent optimizer state in f32s (memory accounting).
    pub fn state_floats(&self) -> usize {
        let (m, n) = (self.m, self.n);
        match &self.state {
            MatState::MoFaSgd { rank, .. } => m * rank + n * rank + rank,
            MatState::GaLore { rank, .. } => m * rank + 2 * n * rank,
            MatState::Muon { .. } | MatState::Lion { .. }
            | MatState::SgdM { .. } => m * n,
            MatState::AdamW { .. } => 2 * m * n,
            MatState::SignSgd => 0,
            MatState::Adafactor { .. } => m + n,
        }
    }

    /// Fold one micro-batch gradient into the fused low-rank buffers.
    /// Initializes factor/subspace state from the first gradient seen.
    pub fn accumulate(&mut self, reg: &Registry, grad: &xla::Literal,
                      rng: &mut Rng) -> Result<()> {
        let (m, n) = (self.m, self.n);
        match &mut self.state {
            MatState::MoFaSgd { rank, factors, bufs, count, .. } => {
                let rank = *rank;
                if factors.is_none() {
                    let omega = lit_f32(
                        &[n, rank], &rng.normal_vec(n * rank, 1.0))?;
                    let init = reg.load(&Registry::opt_name(
                        "mofasgd_init", m, n, Some(rank)))?;
                    let mut outs = init.run(&[grad, &omega])?;
                    let v = outs.pop().unwrap();
                    let s = outs.pop().unwrap();
                    let u = outs.pop().unwrap();
                    *factors = Some((u, s, v));
                }
                if bufs.is_none() {
                    *bufs = Some((
                        zeros(&[m, rank])?,
                        zeros(&[rank, n])?,
                        zeros(&[rank, rank])?,
                    ));
                }
                let (u, _, v) = factors.as_ref().unwrap();
                let (b_gv, b_utg, b_utgv) = bufs.as_ref().unwrap();
                let accum = reg.load(&Registry::opt_name(
                    "mofasgd_accum", m, n, Some(rank)))?;
                let mut outs =
                    accum.run(&[grad, u, v, b_gv, b_utg, b_utgv])?;
                let nb3 = outs.pop().unwrap();
                let nb2 = outs.pop().unwrap();
                let nb1 = outs.pop().unwrap();
                *bufs = Some((nb1, nb2, nb3));
                *count += 1;
            }
            MatState::GaLore { rank, q, buf, count, .. } => {
                let rank = *rank;
                if q.is_none() {
                    let omega = lit_f32(
                        &[n, rank], &rng.normal_vec(n * rank, 1.0))?;
                    let rs = reg.load(&Registry::opt_name(
                        "galore_resample", m, n, Some(rank)))?;
                    *q = Some(rs.run(&[grad, &omega])?.pop().unwrap());
                }
                if buf.is_none() {
                    *buf = Some(zeros(&[rank, n])?);
                }
                let accum = reg.load(&Registry::opt_name(
                    "galore_accum", m, n, Some(rank)))?;
                let outs = accum.run(&[
                    grad,
                    q.as_ref().unwrap(),
                    buf.as_ref().unwrap(),
                ])?;
                *buf = outs.into_iter().next();
                *count += 1;
            }
            _ => return Err(anyhow!(
                "{}: fused accumulation unsupported for this optimizer",
                self.name
            )),
        }
        Ok(())
    }

    /// Optimizer step from the fused buffers; returns the new weight.
    /// `last_grad` (any recent full-rank gradient) powers GaLore's periodic
    /// subspace resampling, mirroring the paper's fused implementation.
    pub fn step_fused(&mut self, reg: &Registry, w: &xla::Literal,
                      eta: f32, last_grad: Option<&xla::Literal>,
                      rng: &mut Rng) -> Result<xla::Literal> {
        let (m, n) = (self.m, self.n);
        match &mut self.state {
            MatState::MoFaSgd { rank, beta, factors, bufs, count } => {
                let rank = *rank;
                let (u, s, v) = factors
                    .take()
                    .ok_or_else(|| anyhow!("{}: no factors", self.name))?;
                let (b1, b2, b3) = bufs
                    .take()
                    .ok_or_else(|| anyhow!("{}: no buffers", self.name))?;
                let scale = 1.0 / (*count).max(1) as f32;
                let step = reg.load(&Registry::opt_name(
                    "mofasgd_step_from_buf", m, n, Some(rank)))?;
                let mut outs = step.run(&[
                    w, &u, &s, &v, &b1, &b2, &b3,
                    &lit_scalar(eta), &lit_scalar(*beta),
                    &lit_scalar(scale),
                ])?;
                let nv = outs.pop().unwrap();
                let ns = outs.pop().unwrap();
                let nu = outs.pop().unwrap();
                let nw = outs.pop().unwrap();
                *factors = Some((nu, ns, nv));
                *count = 0;
                *bufs = Some((
                    zeros(&[m, rank])?,
                    zeros(&[rank, n])?,
                    zeros(&[rank, rank])?,
                ));
                Ok(nw)
            }
            MatState::GaLore { rank, tau, q, m1, m2, t, buf, count } => {
                let rank = *rank;
                *t += 1;
                let buf_lit = buf
                    .take()
                    .ok_or_else(|| anyhow!("{}: no buffer", self.name))?;
                let scale = 1.0 / (*count).max(1) as f32;
                let step = reg.load(&Registry::opt_name(
                    "galore_step_from_buf", m, n, Some(rank)))?;
                let mut outs = step.run(&[
                    w, q.as_ref().unwrap(), m1, m2, &buf_lit,
                    &lit_scalar(eta), &lit_scalar(*t as f32),
                    &lit_scalar(0.9), &lit_scalar(0.999),
                    &lit_scalar(scale),
                ])?;
                *m2 = outs.pop().unwrap();
                *m1 = outs.pop().unwrap();
                let nw = outs.pop().unwrap();
                // Offline subspace refresh every τ steps (paper Fig. 6b).
                if *t % *tau == 0 {
                    if let Some(g) = last_grad {
                        let omega = lit_f32(
                            &[n, rank], &rng.normal_vec(n * rank, 1.0))?;
                        let rs = reg.load(&Registry::opt_name(
                            "galore_resample", m, n, Some(rank)))?;
                        *q = Some(rs.run(&[g, &omega])?.pop().unwrap());
                    }
                }
                *count = 0;
                *buf = Some(zeros(&[rank, n])?);
                Ok(nw)
            }
            _ => Err(anyhow!("{}: step_fused on non-fused state", self.name)),
        }
    }

    /// Plain (non-fused) optimizer step from a full-rank mean gradient.
    pub fn step_dense(&mut self, reg: &Registry, w: &xla::Literal,
                      grad: &xla::Literal, eta: f32,
                      rng: &mut Rng) -> Result<xla::Literal> {
        let (m, n) = (self.m, self.n);
        match &mut self.state {
            MatState::MoFaSgd { rank, beta, factors, .. } => {
                let rank = *rank;
                if factors.is_none() {
                    let omega = lit_f32(
                        &[n, rank], &rng.normal_vec(n * rank, 1.0))?;
                    let init = reg.load(&Registry::opt_name(
                        "mofasgd_init", m, n, Some(rank)))?;
                    let mut outs = init.run(&[grad, &omega])?;
                    let v = outs.pop().unwrap();
                    let s = outs.pop().unwrap();
                    let u = outs.pop().unwrap();
                    // Spectral update from the init factors (Alg. 1: the
                    // first gradient *is* the momentum). Running the UMF
                    // step with β = 0 reproduces exactly that: the tangent
                    // projection of G0 onto its own factors is G0, so the
                    // re-factorization returns the init factors and the
                    // update is −η·U₀V₀ᵀ.
                    let upd = reg.load(&Registry::opt_name(
                        "mofasgd_step", m, n, Some(rank)))?;
                    let mut outs = upd.run(&[
                        w, &u, &s, &v, grad,
                        &lit_scalar(eta), &lit_scalar(0.0),
                    ])?;
                    let nv = outs.pop().unwrap();
                    let ns = outs.pop().unwrap();
                    let nu = outs.pop().unwrap();
                    let nw = outs.pop().unwrap();
                    *factors = Some((nu, ns, nv));
                    return Ok(nw);
                }
                let (u, s, v) = factors.take().unwrap();
                let step = reg.load(&Registry::opt_name(
                    "mofasgd_step", m, n, Some(rank)))?;
                let mut outs = step.run(&[
                    w, &u, &s, &v, grad,
                    &lit_scalar(eta), &lit_scalar(*beta),
                ])?;
                let nv = outs.pop().unwrap();
                let ns = outs.pop().unwrap();
                let nu = outs.pop().unwrap();
                let nw = outs.pop().unwrap();
                *factors = Some((nu, ns, nv));
                Ok(nw)
            }
            MatState::GaLore { rank, tau, q, m1, m2, t, .. } => {
                let rank = *rank;
                if q.is_none() || (*t > 0 && *t % *tau == 0) {
                    let omega = lit_f32(
                        &[n, rank], &rng.normal_vec(n * rank, 1.0))?;
                    let rs = reg.load(&Registry::opt_name(
                        "galore_resample", m, n, Some(rank)))?;
                    *q = Some(rs.run(&[grad, &omega])?.pop().unwrap());
                }
                *t += 1;
                let step = reg.load(&Registry::opt_name(
                    "galore_step", m, n, Some(rank)))?;
                let mut outs = step.run(&[
                    w, q.as_ref().unwrap(), m1, m2, grad,
                    &lit_scalar(eta), &lit_scalar(*t as f32),
                    &lit_scalar(0.9), &lit_scalar(0.999),
                ])?;
                *m2 = outs.pop().unwrap();
                *m1 = outs.pop().unwrap();
                Ok(outs.pop().unwrap())
            }
            MatState::Muon { beta, m: mom } => {
                let step = reg.load(&Registry::opt_name(
                    "muon_step", m, n, None))?;
                let mut outs = step.run(&[
                    w, mom, grad, &lit_scalar(eta), &lit_scalar(*beta),
                ])?;
                *mom = outs.pop().unwrap();
                Ok(outs.pop().unwrap())
            }
            MatState::AdamW { m: mm, v: vv, t } => {
                *t += 1;
                let step = reg.load(&Registry::adamw_name(&[m, n]))?;
                let mut outs = step.run(&[
                    w, mm, vv, grad,
                    &lit_scalar(eta), &lit_scalar(*t as f32),
                    &lit_scalar(0.9), &lit_scalar(0.999), &lit_scalar(0.0),
                ])?;
                *vv = outs.pop().unwrap();
                *mm = outs.pop().unwrap();
                Ok(outs.pop().unwrap())
            }
            MatState::Lion { m: mm } => {
                let step = reg.load(&Registry::opt_name(
                    "lion_step", m, n, None))?;
                let mut outs = step.run(&[
                    w, mm, grad, &lit_scalar(eta),
                    &lit_scalar(0.9), &lit_scalar(0.99), &lit_scalar(0.0),
                ])?;
                *mm = outs.pop().unwrap();
                Ok(outs.pop().unwrap())
            }
            MatState::SgdM { beta, m: mm } => {
                let step = reg.load(&Registry::opt_name(
                    "sgdm_step", m, n, None))?;
                let mut outs = step.run(&[
                    w, mm, grad, &lit_scalar(eta), &lit_scalar(*beta),
                ])?;
                *mm = outs.pop().unwrap();
                Ok(outs.pop().unwrap())
            }
            MatState::SignSgd => {
                let step = reg.load(&Registry::opt_name(
                    "signsgd_step", m, n, None))?;
                let mut outs = step.run(&[w, grad, &lit_scalar(eta)])?;
                Ok(outs.pop().unwrap())
            }
            MatState::Adafactor { r_acc, c_acc } => {
                let step = reg.load(&Registry::opt_name(
                    "adafactor_step", m, n, None))?;
                let mut outs = step.run(&[
                    w, r_acc, c_acc, grad,
                    &lit_scalar(eta), &lit_scalar(0.999),
                ])?;
                *c_acc = outs.pop().unwrap();
                *r_acc = outs.pop().unwrap();
                Ok(outs.pop().unwrap())
            }
        }
    }
}

/// AdamW state over a flat (non-matrix) parameter — embeddings, norms,
/// heads (paper §5.5 routing). Runs through the shape-keyed adamw artifact.
pub struct VecLayer {
    pub name: String,
    pub dims: Vec<usize>,
    pub param_idx: usize,
    m: xla::Literal,
    v: xla::Literal,
    t: usize,
}

impl VecLayer {
    pub fn new(name: &str, dims: &[usize], param_idx: usize) -> Result<VecLayer> {
        Ok(VecLayer {
            name: name.to_string(),
            dims: dims.to_vec(),
            param_idx,
            m: zeros(dims)?,
            v: zeros(dims)?,
            t: 0,
        })
    }

    pub fn step(&mut self, reg: &Registry, w: &xla::Literal,
                grad: &xla::Literal, eta: f32, wd: f32) -> Result<xla::Literal> {
        self.t += 1;
        let step = reg.load(&Registry::adamw_name(&self.dims))?;
        let mut outs = step.run(&[
            w, &self.m, &self.v, grad,
            &lit_scalar(eta), &lit_scalar(self.t as f32),
            &lit_scalar(0.9), &lit_scalar(0.999), &lit_scalar(wd),
        ])?;
        self.v = outs.pop().unwrap();
        self.m = outs.pop().unwrap();
        Ok(outs.pop().unwrap())
    }

    pub fn state_floats(&self) -> usize {
        2 * self.dims.iter().product::<usize>().max(1)
    }
}

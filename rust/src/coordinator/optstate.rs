//! Per-matrix optimizer state over PJRT literals + artifact dispatch.
//!
//! Each 2-D transformer linear owns one `MatState`; the engine routes its
//! gradient here and the state machine calls the right per-shape artifact
//! (`mofasgd_step_256x768_r8`, …). MoFaSGD and GaLore additionally expose
//! the §5.5 fused accumulation path where only low-rank projections of the
//! gradient survive across micro-batches.

use anyhow::{anyhow, ensure, Result};

use crate::coordinator::hp::OptimizerChoice;
use crate::fusion::reduce::{self, TreeSchedule};
use crate::runtime::{lit_f32, lit_scalar, to_f32_vec, Registry};
use crate::util::rng::Rng;

pub struct MatLayer {
    pub name: String,
    pub m: usize,
    pub n: usize,
    /// Index into the flat parameter list.
    pub param_idx: usize,
    pub state: MatState,
}

pub enum MatState {
    MoFaSgd {
        rank: usize,
        beta: f32,
        /// (U, s, V) literals once initialized from the first gradient.
        factors: Option<(xla::Literal, xla::Literal, xla::Literal)>,
        /// Fused low-rank accumulation buffers (GV, UᵀG, UᵀGV),
        /// lane-indexed by the engine's tree-reduce schedule
        /// (DESIGN.md §13); `reduce_lanes` folds them into lane 0.
        bufs: Vec<Option<(xla::Literal, xla::Literal, xla::Literal)>>,
        count: usize,
    },
    GaLore {
        rank: usize,
        tau: usize,
        q: Option<xla::Literal>,
        m1: xla::Literal,
        m2: xla::Literal,
        t: usize,
        /// Fused buffer: accumulated QᵀG, lane-indexed like
        /// `MoFaSgd::bufs`.
        buf: Vec<Option<xla::Literal>>,
        count: usize,
    },
    Muon { beta: f32, m: xla::Literal },
    AdamW { m: xla::Literal, v: xla::Literal, t: usize },
    Lion { m: xla::Literal },
    SgdM { beta: f32, m: xla::Literal },
    SignSgd,
    Adafactor { r_acc: xla::Literal, c_acc: xla::Literal },
}

fn zeros(dims: &[usize]) -> Result<xla::Literal> {
    lit_f32(dims, &vec![0.0; dims.iter().product::<usize>().max(1)])
}

/// Elementwise literal add for the host-side lane fold — routed through
/// [`reduce::fold_lane`] so the traffic lands on the `bytes_reduced`
/// counter and the chunking stays per-element worker-invariant.
fn add_lits(dst: &xla::Literal, src: &xla::Literal,
            dims: &[usize]) -> Result<xla::Literal> {
    let mut a = to_f32_vec(dst)?;
    let b = to_f32_vec(src)?;
    ensure!(a.len() == b.len(), "lane buffer length mismatch");
    reduce::fold_lane(&mut a, &b, crate::fusion::workers());
    lit_f32(dims, &a)
}

impl MatLayer {
    pub fn new(name: &str, m: usize, n: usize, param_idx: usize,
               choice: OptimizerChoice) -> Result<MatLayer> {
        let state = match choice {
            OptimizerChoice::MoFaSgd { rank, beta } => MatState::MoFaSgd {
                rank,
                beta,
                factors: None,
                bufs: Vec::new(),
                count: 0,
            },
            OptimizerChoice::GaLore { rank, tau } => MatState::GaLore {
                rank,
                tau,
                q: None,
                m1: zeros(&[rank, n])?,
                m2: zeros(&[rank, n])?,
                t: 0,
                buf: Vec::new(),
                count: 0,
            },
            OptimizerChoice::Muon { beta } =>
                MatState::Muon { beta, m: zeros(&[m, n])? },
            OptimizerChoice::AdamW => MatState::AdamW {
                m: zeros(&[m, n])?,
                v: zeros(&[m, n])?,
                t: 0,
            },
            OptimizerChoice::Lion => MatState::Lion { m: zeros(&[m, n])? },
            OptimizerChoice::SgdM { beta } =>
                MatState::SgdM { beta, m: zeros(&[m, n])? },
            OptimizerChoice::SignSgd => MatState::SignSgd,
            OptimizerChoice::Adafactor => MatState::Adafactor {
                r_acc: zeros(&[m])?,
                c_acc: zeros(&[n])?,
            },
            OptimizerChoice::Lora { .. } => {
                return Err(anyhow!(
                    "LoRA is handled by the adapter engine, not MatLayer"
                ))
            }
        };
        Ok(MatLayer { name: name.to_string(), m, n, param_idx, state })
    }

    /// Whether this state supports the §5.5 fused low-rank accumulation.
    pub fn supports_fused(&self) -> bool {
        matches!(self.state,
                 MatState::MoFaSgd { .. } | MatState::GaLore { .. })
    }

    /// Persistent optimizer state in f32s (memory accounting).
    pub fn state_floats(&self) -> usize {
        let (m, n) = (self.m, self.n);
        match &self.state {
            MatState::MoFaSgd { rank, .. } => m * rank + n * rank + rank,
            MatState::GaLore { rank, .. } => m * rank + 2 * n * rank,
            MatState::Muon { .. } | MatState::Lion { .. }
            | MatState::SgdM { .. } => m * n,
            MatState::AdamW { .. } => 2 * m * n,
            MatState::SignSgd => 0,
            MatState::Adafactor { .. } => m + n,
        }
    }

    /// Fold one micro-batch gradient into lane `lane` of the fused
    /// low-rank buffers (`width` lanes total — the engine's tree-reduce
    /// width, DESIGN.md §13). Initializes factor/subspace state from
    /// the first gradient seen; lane buffers are allocated lazily so
    /// only lanes the schedule actually populates cost memory.
    pub fn accumulate(&mut self, reg: &Registry, grad: &xla::Literal,
                      rng: &mut Rng, lane: usize, width: usize)
                      -> Result<()> {
        let (m, n) = (self.m, self.n);
        ensure!(lane < width, "{}: lane {lane} out of {width}", self.name);
        match &mut self.state {
            MatState::MoFaSgd { rank, factors, bufs, count, .. } => {
                let rank = *rank;
                if factors.is_none() {
                    let omega = lit_f32(
                        &[n, rank], &rng.normal_vec(n * rank, 1.0))?;
                    let init = reg.load(&Registry::opt_name(
                        "mofasgd_init", m, n, Some(rank)))?;
                    let mut outs = init.run(&[grad, &omega])?;
                    let v = outs.pop().unwrap();
                    let s = outs.pop().unwrap();
                    let u = outs.pop().unwrap();
                    *factors = Some((u, s, v));
                }
                if bufs.len() < width {
                    bufs.resize_with(width, || None);
                }
                if bufs[lane].is_none() {
                    bufs[lane] = Some((
                        zeros(&[m, rank])?,
                        zeros(&[rank, n])?,
                        zeros(&[rank, rank])?,
                    ));
                }
                let (u, _, v) = factors.as_ref().unwrap();
                let (b_gv, b_utg, b_utgv) = bufs[lane].as_ref().unwrap();
                let accum = reg.load(&Registry::opt_name(
                    "mofasgd_accum", m, n, Some(rank)))?;
                let mut outs =
                    accum.run(&[grad, u, v, b_gv, b_utg, b_utgv])?;
                let nb3 = outs.pop().unwrap();
                let nb2 = outs.pop().unwrap();
                let nb1 = outs.pop().unwrap();
                bufs[lane] = Some((nb1, nb2, nb3));
                *count += 1;
            }
            MatState::GaLore { rank, q, buf, count, .. } => {
                let rank = *rank;
                if q.is_none() {
                    let omega = lit_f32(
                        &[n, rank], &rng.normal_vec(n * rank, 1.0))?;
                    let rs = reg.load(&Registry::opt_name(
                        "galore_resample", m, n, Some(rank)))?;
                    *q = Some(rs.run(&[grad, &omega])?.pop().unwrap());
                }
                if buf.len() < width {
                    buf.resize_with(width, || None);
                }
                if buf[lane].is_none() {
                    buf[lane] = Some(zeros(&[rank, n])?);
                }
                let accum = reg.load(&Registry::opt_name(
                    "galore_accum", m, n, Some(rank)))?;
                let outs = accum.run(&[
                    grad,
                    q.as_ref().unwrap(),
                    buf[lane].as_ref().unwrap(),
                ])?;
                buf[lane] = outs.into_iter().next();
                *count += 1;
            }
            _ => return Err(anyhow!(
                "{}: fused accumulation unsupported for this optimizer",
                self.name
            )),
        }
        Ok(())
    }

    /// Fold the lane buffers into lane 0 through the schedule's fixed
    /// pair order (DESIGN.md §13). The fused accumulation artifacts are
    /// linear in the gradient, so tree-folding *buffers* equals
    /// tree-folding *gradients*: lane 0 afterwards holds exactly what a
    /// single lane fed every micro-batch would hold, in the same float
    /// association — which is why every replica count is bit-identical.
    /// No-op for non-fused states and for untouched lanes.
    pub fn reduce_lanes(&mut self, sched: &TreeSchedule) -> Result<()> {
        let (m, n) = (self.m, self.n);
        match &mut self.state {
            MatState::MoFaSgd { rank, bufs, .. } => {
                let rank = *rank;
                for &(d, s) in sched.pairs() {
                    if s >= bufs.len() {
                        continue;
                    }
                    let Some((s1, s2, s3)) = bufs[s].take() else {
                        continue;
                    };
                    match &mut bufs[d] {
                        Some((d1, d2, d3)) => {
                            *d1 = add_lits(d1, &s1, &[m, rank])?;
                            *d2 = add_lits(d2, &s2, &[rank, n])?;
                            *d3 = add_lits(d3, &s3, &[rank, rank])?;
                        }
                        slot => *slot = Some((s1, s2, s3)),
                    }
                }
                Ok(())
            }
            MatState::GaLore { rank, buf, .. } => {
                let rank = *rank;
                for &(d, s) in sched.pairs() {
                    if s >= buf.len() {
                        continue;
                    }
                    let Some(sb) = buf[s].take() else { continue };
                    match &mut buf[d] {
                        Some(db) => *db = add_lits(db, &sb, &[rank, n])?,
                        slot => *slot = Some(sb),
                    }
                }
                Ok(())
            }
            _ => Ok(()),
        }
    }

    /// Optimizer step from the fused buffers; returns the new weight.
    /// Call [`MatLayer::reduce_lanes`] first — this consumes lane 0.
    /// `last_grad` (any recent full-rank gradient) powers GaLore's periodic
    /// subspace resampling, mirroring the paper's fused implementation.
    pub fn step_fused(&mut self, reg: &Registry, w: &xla::Literal,
                      eta: f32, last_grad: Option<&xla::Literal>,
                      rng: &mut Rng) -> Result<xla::Literal> {
        let (m, n) = (self.m, self.n);
        match &mut self.state {
            MatState::MoFaSgd { rank, beta, factors, bufs, count } => {
                let rank = *rank;
                let (u, s, v) = factors
                    .take()
                    .ok_or_else(|| anyhow!("{}: no factors", self.name))?;
                let (b1, b2, b3) = bufs
                    .first_mut()
                    .and_then(Option::take)
                    .ok_or_else(|| anyhow!("{}: no buffers", self.name))?;
                let scale = 1.0 / (*count).max(1) as f32;
                let step = reg.load(&Registry::opt_name(
                    "mofasgd_step_from_buf", m, n, Some(rank)))?;
                let mut outs = step.run(&[
                    w, &u, &s, &v, &b1, &b2, &b3,
                    &lit_scalar(eta), &lit_scalar(*beta),
                    &lit_scalar(scale),
                ])?;
                let nv = outs.pop().unwrap();
                let ns = outs.pop().unwrap();
                let nu = outs.pop().unwrap();
                let nw = outs.pop().unwrap();
                *factors = Some((nu, ns, nv));
                *count = 0;
                // Lanes re-zero lazily on the next accumulate; dropping
                // them here keeps only the lanes a schedule uses alive.
                bufs.iter_mut().for_each(|b| *b = None);
                Ok(nw)
            }
            MatState::GaLore { rank, tau, q, m1, m2, t, buf, count } => {
                let rank = *rank;
                *t += 1;
                let buf_lit = buf
                    .first_mut()
                    .and_then(Option::take)
                    .ok_or_else(|| anyhow!("{}: no buffer", self.name))?;
                let scale = 1.0 / (*count).max(1) as f32;
                let step = reg.load(&Registry::opt_name(
                    "galore_step_from_buf", m, n, Some(rank)))?;
                let mut outs = step.run(&[
                    w, q.as_ref().unwrap(), m1, m2, &buf_lit,
                    &lit_scalar(eta), &lit_scalar(*t as f32),
                    &lit_scalar(0.9), &lit_scalar(0.999),
                    &lit_scalar(scale),
                ])?;
                *m2 = outs.pop().unwrap();
                *m1 = outs.pop().unwrap();
                let nw = outs.pop().unwrap();
                // Offline subspace refresh every τ steps (paper Fig. 6b).
                if *t % *tau == 0 {
                    if let Some(g) = last_grad {
                        let omega = lit_f32(
                            &[n, rank], &rng.normal_vec(n * rank, 1.0))?;
                        let rs = reg.load(&Registry::opt_name(
                            "galore_resample", m, n, Some(rank)))?;
                        *q = Some(rs.run(&[g, &omega])?.pop().unwrap());
                    }
                }
                *count = 0;
                buf.iter_mut().for_each(|b| *b = None);
                Ok(nw)
            }
            _ => Err(anyhow!("{}: step_fused on non-fused state", self.name)),
        }
    }

    /// Plain (non-fused) optimizer step from a full-rank mean gradient.
    pub fn step_dense(&mut self, reg: &Registry, w: &xla::Literal,
                      grad: &xla::Literal, eta: f32,
                      rng: &mut Rng) -> Result<xla::Literal> {
        let (m, n) = (self.m, self.n);
        match &mut self.state {
            MatState::MoFaSgd { rank, beta, factors, .. } => {
                let rank = *rank;
                if factors.is_none() {
                    let omega = lit_f32(
                        &[n, rank], &rng.normal_vec(n * rank, 1.0))?;
                    let init = reg.load(&Registry::opt_name(
                        "mofasgd_init", m, n, Some(rank)))?;
                    let mut outs = init.run(&[grad, &omega])?;
                    let v = outs.pop().unwrap();
                    let s = outs.pop().unwrap();
                    let u = outs.pop().unwrap();
                    // Spectral update from the init factors (Alg. 1: the
                    // first gradient *is* the momentum). Running the UMF
                    // step with β = 0 reproduces exactly that: the tangent
                    // projection of G0 onto its own factors is G0, so the
                    // re-factorization returns the init factors and the
                    // update is −η·U₀V₀ᵀ.
                    let upd = reg.load(&Registry::opt_name(
                        "mofasgd_step", m, n, Some(rank)))?;
                    let mut outs = upd.run(&[
                        w, &u, &s, &v, grad,
                        &lit_scalar(eta), &lit_scalar(0.0),
                    ])?;
                    let nv = outs.pop().unwrap();
                    let ns = outs.pop().unwrap();
                    let nu = outs.pop().unwrap();
                    let nw = outs.pop().unwrap();
                    *factors = Some((nu, ns, nv));
                    return Ok(nw);
                }
                let (u, s, v) = factors.take().unwrap();
                let step = reg.load(&Registry::opt_name(
                    "mofasgd_step", m, n, Some(rank)))?;
                let mut outs = step.run(&[
                    w, &u, &s, &v, grad,
                    &lit_scalar(eta), &lit_scalar(*beta),
                ])?;
                let nv = outs.pop().unwrap();
                let ns = outs.pop().unwrap();
                let nu = outs.pop().unwrap();
                let nw = outs.pop().unwrap();
                *factors = Some((nu, ns, nv));
                Ok(nw)
            }
            MatState::GaLore { rank, tau, q, m1, m2, t, .. } => {
                let rank = *rank;
                if q.is_none() || (*t > 0 && *t % *tau == 0) {
                    let omega = lit_f32(
                        &[n, rank], &rng.normal_vec(n * rank, 1.0))?;
                    let rs = reg.load(&Registry::opt_name(
                        "galore_resample", m, n, Some(rank)))?;
                    *q = Some(rs.run(&[grad, &omega])?.pop().unwrap());
                }
                *t += 1;
                let step = reg.load(&Registry::opt_name(
                    "galore_step", m, n, Some(rank)))?;
                let mut outs = step.run(&[
                    w, q.as_ref().unwrap(), m1, m2, grad,
                    &lit_scalar(eta), &lit_scalar(*t as f32),
                    &lit_scalar(0.9), &lit_scalar(0.999),
                ])?;
                *m2 = outs.pop().unwrap();
                *m1 = outs.pop().unwrap();
                Ok(outs.pop().unwrap())
            }
            MatState::Muon { beta, m: mom } => {
                let step = reg.load(&Registry::opt_name(
                    "muon_step", m, n, None))?;
                let mut outs = step.run(&[
                    w, mom, grad, &lit_scalar(eta), &lit_scalar(*beta),
                ])?;
                *mom = outs.pop().unwrap();
                Ok(outs.pop().unwrap())
            }
            MatState::AdamW { m: mm, v: vv, t } => {
                *t += 1;
                let step = reg.load(&Registry::adamw_name(&[m, n]))?;
                let mut outs = step.run(&[
                    w, mm, vv, grad,
                    &lit_scalar(eta), &lit_scalar(*t as f32),
                    &lit_scalar(0.9), &lit_scalar(0.999), &lit_scalar(0.0),
                ])?;
                *vv = outs.pop().unwrap();
                *mm = outs.pop().unwrap();
                Ok(outs.pop().unwrap())
            }
            MatState::Lion { m: mm } => {
                let step = reg.load(&Registry::opt_name(
                    "lion_step", m, n, None))?;
                let mut outs = step.run(&[
                    w, mm, grad, &lit_scalar(eta),
                    &lit_scalar(0.9), &lit_scalar(0.99), &lit_scalar(0.0),
                ])?;
                *mm = outs.pop().unwrap();
                Ok(outs.pop().unwrap())
            }
            MatState::SgdM { beta, m: mm } => {
                let step = reg.load(&Registry::opt_name(
                    "sgdm_step", m, n, None))?;
                let mut outs = step.run(&[
                    w, mm, grad, &lit_scalar(eta), &lit_scalar(*beta),
                ])?;
                *mm = outs.pop().unwrap();
                Ok(outs.pop().unwrap())
            }
            MatState::SignSgd => {
                let step = reg.load(&Registry::opt_name(
                    "signsgd_step", m, n, None))?;
                let mut outs = step.run(&[w, grad, &lit_scalar(eta)])?;
                Ok(outs.pop().unwrap())
            }
            MatState::Adafactor { r_acc, c_acc } => {
                let step = reg.load(&Registry::opt_name(
                    "adafactor_step", m, n, None))?;
                let mut outs = step.run(&[
                    w, r_acc, c_acc, grad,
                    &lit_scalar(eta), &lit_scalar(0.999),
                ])?;
                *c_acc = outs.pop().unwrap();
                *r_acc = outs.pop().unwrap();
                Ok(outs.pop().unwrap())
            }
        }
    }
}

/// AdamW state over a flat (non-matrix) parameter — embeddings, norms,
/// heads (paper §5.5 routing). Runs through the shape-keyed adamw artifact.
pub struct VecLayer {
    pub name: String,
    pub dims: Vec<usize>,
    pub param_idx: usize,
    m: xla::Literal,
    v: xla::Literal,
    t: usize,
}

impl VecLayer {
    pub fn new(name: &str, dims: &[usize], param_idx: usize) -> Result<VecLayer> {
        Ok(VecLayer {
            name: name.to_string(),
            dims: dims.to_vec(),
            param_idx,
            m: zeros(dims)?,
            v: zeros(dims)?,
            t: 0,
        })
    }

    pub fn step(&mut self, reg: &Registry, w: &xla::Literal,
                grad: &xla::Literal, eta: f32, wd: f32) -> Result<xla::Literal> {
        self.t += 1;
        let step = reg.load(&Registry::adamw_name(&self.dims))?;
        let mut outs = step.run(&[
            w, &self.m, &self.v, grad,
            &lit_scalar(eta), &lit_scalar(self.t as f32),
            &lit_scalar(0.9), &lit_scalar(0.999), &lit_scalar(wd),
        ])?;
        self.v = outs.pop().unwrap();
        self.m = outs.pop().unwrap();
        Ok(outs.pop().unwrap())
    }

    pub fn state_floats(&self) -> usize {
        2 * self.dims.iter().product::<usize>().max(1)
    }
}

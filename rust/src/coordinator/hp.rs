//! Hyperparameters, optimizer routing choices, and LR schedules.

use anyhow::{bail, Result};

/// Which optimizer drives the 2-D transformer linears (paper §5.5 routes
/// embeddings/1-D params to AdamW regardless).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptimizerChoice {
    MoFaSgd { rank: usize, beta: f32 },
    GaLore { rank: usize, tau: usize },
    Muon { beta: f32 },
    AdamW,
    Lion,
    SgdM { beta: f32 },
    SignSgd,
    Adafactor,
    /// LoRA adapters trained with AdamW; base weights frozen.
    Lora { rank: usize, alpha: f32 },
}

impl OptimizerChoice {
    pub fn name(&self) -> &'static str {
        match self {
            OptimizerChoice::MoFaSgd { .. } => "mofasgd",
            OptimizerChoice::GaLore { .. } => "galore",
            OptimizerChoice::Muon { .. } => "muon",
            OptimizerChoice::AdamW => "adamw",
            OptimizerChoice::Lion => "lion",
            OptimizerChoice::SgdM { .. } => "sgdm",
            OptimizerChoice::SignSgd => "signsgd",
            OptimizerChoice::Adafactor => "adafactor",
            OptimizerChoice::Lora { .. } => "lora",
        }
    }

    pub fn rank(&self) -> Option<usize> {
        match self {
            OptimizerChoice::MoFaSgd { rank, .. }
            | OptimizerChoice::GaLore { rank, .. }
            | OptimizerChoice::Lora { rank, .. } => Some(*rank),
            _ => None,
        }
    }

    /// Parse "mofasgd:r=8,beta=0.95" style CLI specs.
    pub fn parse(spec: &str) -> Result<OptimizerChoice> {
        let (name, rest) = match spec.split_once(':') {
            Some((n, r)) => (n, r),
            None => (spec, ""),
        };
        let mut rank = 8usize;
        let mut beta = 0.95f32;
        let mut tau = 150usize;
        let mut alpha = 16.0f32;
        for kv in rest.split(',').filter(|s| !s.is_empty()) {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad opt spec `{kv}`"))?;
            match k {
                "r" | "rank" => rank = v.parse()?,
                "beta" => beta = v.parse()?,
                "tau" => tau = v.parse()?,
                "alpha" => alpha = v.parse()?,
                _ => bail!("unknown opt key `{k}` in `{spec}`"),
            }
        }
        Ok(match name {
            "mofasgd" => OptimizerChoice::MoFaSgd { rank, beta },
            "galore" => OptimizerChoice::GaLore { rank, tau },
            "muon" => OptimizerChoice::Muon { beta },
            "adamw" => OptimizerChoice::AdamW,
            "lion" => OptimizerChoice::Lion,
            "sgdm" => OptimizerChoice::SgdM { beta },
            "signsgd" => OptimizerChoice::SignSgd,
            "adafactor" => OptimizerChoice::Adafactor,
            "lora" => OptimizerChoice::Lora { rank, alpha },
            _ => bail!("unknown optimizer `{name}`"),
        })
    }
}

/// LR schedule: constant, or the NanoGPT-speedrun "stable then linear
/// cool-down" the paper tunes against (Table 5: cool-down fraction 0.4).
#[derive(Debug, Clone, Copy)]
pub enum Schedule {
    Constant,
    StableDecay { total_steps: usize, cooldown_frac: f64 },
}

impl Schedule {
    pub fn scale(&self, step: usize) -> f64 {
        match *self {
            Schedule::Constant => 1.0,
            Schedule::StableDecay { total_steps, cooldown_frac } => {
                let total = total_steps.max(1) as f64;
                let start = total * (1.0 - cooldown_frac);
                let s = step as f64;
                if s <= start {
                    1.0
                } else {
                    // linear decay from 1 at `start` to ~0.1 at `total`
                    let t = ((s - start) / (total - start).max(1.0)).min(1.0);
                    1.0 - 0.9 * t
                }
            }
        }
    }
}

/// Full hyperparameter bundle for one run.
#[derive(Debug, Clone, Copy)]
pub struct Hyper {
    pub lr: f64,
    /// AdamW betas for the embedding/1-D route and GaLore subspace moments.
    pub b1: f32,
    pub b2: f32,
    pub weight_decay: f32,
    /// AdamW LR for the embedding/1-D route (paper uses a separately tuned
    /// AdamW for those layers; default ties it to `lr`).
    pub emb_lr: f64,
    pub schedule: Schedule,
    /// Gradient-accumulation micro-batches per optimizer step.
    pub accum: usize,
    /// In-process data-parallel replicas sharding the micro-batches of
    /// one step (DESIGN.md §13). Must be a power of two dividing
    /// `fusion::reduce::TREE_WIDTH`; gradients fold through the fixed
    /// lane tree, so every replica count is bit-identical to `1`.
    pub replicas: usize,
    /// Use the fused low-rank accumulation path (§5.5) when available.
    pub fused: bool,
}

impl Default for Hyper {
    fn default() -> Hyper {
        Hyper {
            lr: 1e-3,
            b1: 0.9,
            b2: 0.999,
            weight_decay: 0.0,
            emb_lr: 1e-3,
            schedule: Schedule::Constant,
            accum: 1,
            replicas: 1,
            fused: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(
            OptimizerChoice::parse("mofasgd:r=16,beta=0.85").unwrap(),
            OptimizerChoice::MoFaSgd { rank: 16, beta: 0.85 }
        );
        assert_eq!(
            OptimizerChoice::parse("galore:r=32,tau=75").unwrap(),
            OptimizerChoice::GaLore { rank: 32, tau: 75 }
        );
        assert_eq!(OptimizerChoice::parse("adamw").unwrap(),
                   OptimizerChoice::AdamW);
        assert!(OptimizerChoice::parse("nope").is_err());
        assert!(OptimizerChoice::parse("mofasgd:bogus=1").is_err());
    }

    #[test]
    fn stable_decay_shape() {
        let s = Schedule::StableDecay { total_steps: 100, cooldown_frac: 0.4 };
        assert!((s.scale(0) - 1.0).abs() < 1e-12);
        assert!((s.scale(60) - 1.0).abs() < 1e-12);
        assert!(s.scale(80) < 1.0 && s.scale(80) > s.scale(99));
        assert!(s.scale(100) >= 0.099);
    }

    #[test]
    fn constant_is_flat() {
        assert_eq!(Schedule::Constant.scale(0), Schedule::Constant.scale(999));
    }
}

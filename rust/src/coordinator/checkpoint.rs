//! Parameter checkpointing — simple self-describing binary format.
//!
//! Layout: magic "MOFA" u32 version | u32 count | per tensor:
//! u32 name_len, name bytes, u32 ndims, u64 dims…, f32 data…
//! Little-endian throughout, followed on disk by a 4-byte CRC32 footer
//! (`util::fsio`). Used to hand a pre-trained base model from the
//! pretraining example to the instruction-tuning / LoRA examples, and as
//! the payload of the serve daemon's crash-safe checkpoint store.
//!
//! Durability: `save` goes through `fsio::atomic_write_crc`
//! (write-to-temp + `sync_all` + atomic rename), so a crash mid-save
//! leaves the previous file intact; `load` verifies the CRC32 footer
//! before parsing, so torn or bit-rotted files are a clean `Err`.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::fsio;
use crate::util::json::Json;

pub struct Checkpoint {
    pub tensors: Vec<(String, Vec<usize>, Vec<f32>)>,
}

const MAGIC: &[u8; 4] = b"MOFA";
/// JSON wire-form version (serve socket). Unchanged by the on-disk CRC
/// footer — the wire layer has its own integrity story (length-capped
/// lines, full-message parse).
const WIRE_VERSION: u32 = 1;
/// On-disk binary version. v2 = v1 layout + mandatory CRC32 footer
/// (v1 files without a footer fail the CRC check and are rejected).
const FILE_VERSION: u32 = 2;

impl Checkpoint {
    /// JSON wire form, for streaming a checkpoint over the serve socket:
    /// `{"version":1,"tensors":[{"name","dims":[…],"bits":[…]},…]}`.
    /// Tensor data travels as `f32::to_bits` u32s — every u32 is exact
    /// in an f64 JSON number, so the round trip is bit-exact for *all*
    /// f32 payloads (±0.0, subnormals, NaN, ±inf included).
    pub fn to_json(&self) -> Json {
        let tensors: Vec<Json> = self
            .tensors
            .iter()
            .map(|(name, dims, data)| {
                Json::obj(vec![
                    ("name", Json::Str(name.clone())),
                    ("dims",
                     Json::Arr(dims.iter()
                         .map(|&d| Json::Num(d as f64)).collect())),
                    ("bits",
                     Json::Arr(data.iter()
                         .map(|x| Json::Num(x.to_bits() as f64))
                         .collect())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::Num(WIRE_VERSION as f64)),
            ("tensors", Json::Arr(tensors)),
        ])
    }

    /// Parse the [`Checkpoint::to_json`] wire form. Every malformation
    /// is an `Err`, never a panic — this runs on daemon-received bytes.
    pub fn from_json(v: &Json) -> Result<Checkpoint> {
        let version = v.req("version")?.as_usize()?;
        if version != WIRE_VERSION as usize {
            bail!("unsupported checkpoint version {version}");
        }
        let mut tensors = Vec::new();
        for t in v.req("tensors")?.as_arr()? {
            let name = t.req("name")?.as_str()?.to_string();
            let mut dims = Vec::new();
            for d in t.req("dims")?.as_arr()? {
                dims.push(d.as_usize()?);
            }
            let bits = t.req("bits")?.as_arr()?;
            // Checked fold: hostile dims like [2^32, 2^32] must be an
            // `Err`, not a debug-build overflow panic (this runs on
            // daemon-received bytes).
            let numel = checked_numel(&name, &dims)?;
            if bits.len() != numel {
                bail!("{name}: dims {dims:?} vs {} values", bits.len());
            }
            let mut data = Vec::with_capacity(bits.len());
            for b in bits {
                let x = b.as_f64()?;
                if x < 0.0 || x.fract() != 0.0 || x > u32::MAX as f64 {
                    bail!("{name}: bad f32 bit pattern {x}");
                }
                data.push(f32::from_bits(x as u32));
            }
            tensors.push((name, dims, data));
        }
        Ok(Checkpoint { tensors })
    }
    /// Serialize to the on-disk binary layout (without the CRC footer —
    /// `fsio::atomic_write_crc` appends that). Dims-vs-data mismatches
    /// are validated here, *before* any bytes reach a file.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut out: Vec<u8> = Vec::new();
        let f = &mut out;
        f.write_all(MAGIC)?;
        f.write_all(&FILE_VERSION.to_le_bytes())?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, dims, data) in &self.tensors {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u32).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(dims.len() as u32).to_le_bytes())?;
            for d in dims {
                f.write_all(&(*d as u64).to_le_bytes())?;
            }
            let expect = checked_numel(name, dims)?;
            if expect != data.len() {
                bail!("{name}: dims {:?} vs {} floats", dims, data.len());
            }
            for x in data {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(out)
    }

    /// Parse the [`Checkpoint::to_bytes`] layout (CRC footer already
    /// stripped by `fsio::read_crc`). Every malformation is an `Err`.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        let mut f = std::io::Cursor::new(bytes);
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a MOFA checkpoint");
        }
        let version = read_u32(&mut f)?;
        if version != FILE_VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let count = read_u32(&mut f)? as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = read_u32(&mut f)? as usize;
            let mut nb = vec![0u8; name_len];
            f.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            let ndims = read_u32(&mut f)? as usize;
            let mut dims = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                let mut b = [0u8; 8];
                f.read_exact(&mut b)?;
                dims.push(u64::from_le_bytes(b) as usize);
            }
            let numel = checked_numel(&name, &dims)?;
            let nbytes = numel.checked_mul(4)
                .ok_or_else(|| anyhow!("{name}: dims {dims:?} overflow"))?;
            let mut bytes = vec![0u8; nbytes];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.push((name, dims, data));
        }
        Ok(Checkpoint { tensors })
    }

    /// Crash-safe save: serialize, then write-to-temp + `sync_all` +
    /// atomic rename with a CRC32 footer. A crash at any point leaves
    /// either the previous file intact or the new one complete.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let bytes = self.to_bytes()?;
        fsio::atomic_write_crc(path, &bytes)
            .with_context(|| format!("write {}", path.display()))?;
        Ok(())
    }

    /// Load a [`Checkpoint::save`] file, verifying the CRC32 footer
    /// before parsing — torn or corrupted files are a clean `Err`.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let bytes = fsio::read_crc(path)
            .with_context(|| format!("read {}", path.display()))?;
        Checkpoint::from_bytes(&bytes)
            .with_context(|| format!("parse {}", path.display()))
    }
}

/// Element count of `dims` (scalar = 1), overflow-checked: untrusted
/// dims must yield an `Err`, never a debug overflow panic or a release
/// wrap that would mask a size mismatch.
fn checked_numel(name: &str, dims: &[usize]) -> Result<usize> {
    dims.iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .map(|n| n.max(1))
        .ok_or_else(|| anyhow!("{name}: dims {dims:?} overflow"))
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ck = Checkpoint {
            tensors: vec![
                ("tok_emb".into(), vec![4, 3], (0..12).map(|i| i as f32)
                    .collect()),
                ("lnf".into(), vec![5], vec![1.0; 5]),
            ],
        };
        let path = std::env::temp_dir().join("mofa_ckpt_test.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.tensors[0].0, "tok_emb");
        assert_eq!(back.tensors[0].1, vec![4, 3]);
        assert_eq!(back.tensors[0].2[5], 5.0);
        assert_eq!(back.tensors[1].1, vec![5]);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("mofa_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn json_roundtrip_is_bit_exact() {
        let tricky = vec![
            0.0f32, -0.0, 1.5, -3.25e-20, f32::MIN_POSITIVE / 2.0,
            f32::INFINITY, f32::NEG_INFINITY, f32::NAN, 16777217.0,
        ];
        let ck = Checkpoint {
            tensors: vec![
                ("w0".into(), vec![3, 3], tricky.clone()),
                ("b".into(), vec![2], vec![1.0, -2.0]),
            ],
        };
        let wire = ck.to_json().emit(0);
        let back =
            Checkpoint::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.tensors[0].0, "w0");
        assert_eq!(back.tensors[0].1, vec![3, 3]);
        let bits = |v: &[f32]| -> Vec<u32> {
            v.iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&back.tensors[0].2), bits(&tricky));
        assert_eq!(back.tensors[1].2, vec![1.0, -2.0]);
    }

    #[test]
    fn json_rejects_malformed() {
        for bad in [
            r#"{"tensors":[]}"#,                                  // no version
            r#"{"version":9,"tensors":[]}"#,                      // bad version
            r#"{"version":1,"tensors":[{"name":"x","dims":[2],"bits":[1]}]}"#,
            r#"{"version":1,"tensors":[{"name":"x","dims":[1],"bits":[-1]}]}"#,
            r#"{"version":1,"tensors":[{"dims":[1],"bits":[0]}]}"#,
            // Hostile dims whose product overflows usize: must be a
            // clean Err, not a debug-build multiply-overflow panic.
            r#"{"version":1,"tensors":[{"name":"x",
                "dims":[4294967296,4294967296],"bits":[0]}]}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(Checkpoint::from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn detects_corruption_and_truncation() {
        let ck = Checkpoint {
            tensors: vec![("w".into(), vec![2, 2],
                           vec![1.0, 2.0, 3.0, 4.0])],
        };
        let path = std::env::temp_dir().join("mofa_ckpt_crc.bin");
        ck.save(&path).unwrap();
        assert!(Checkpoint::load(&path).is_ok());
        // Flip one payload bit: CRC must catch it.
        let mut raw = std::fs::read(&path).unwrap();
        let mid = raw.len() / 2;
        raw[mid] ^= 0x01;
        std::fs::write(&path, &raw).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        // Torn write (prefix only, no footer): also a clean Err.
        ck.save(&path).unwrap();
        let raw = std::fs::read(&path).unwrap();
        std::fs::write(&path, &raw[..raw.len() / 2]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_replaces_existing_file_atomically() {
        let path = std::env::temp_dir().join("mofa_ckpt_replace.bin");
        let a = Checkpoint {
            tensors: vec![("x".into(), vec![2], vec![1.0, 2.0])],
        };
        let b = Checkpoint {
            tensors: vec![("x".into(), vec![3], vec![7.0, 8.0, 9.0])],
        };
        a.save(&path).unwrap();
        b.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.tensors[0].1, vec![3]);
        assert_eq!(back.tensors[0].2, vec![7.0, 8.0, 9.0]);
        let mut tmp = path.clone().into_os_string();
        tmp.push(".tmp");
        assert!(!std::path::Path::new(&tmp).exists());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_mismatched_dims() {
        let ck = Checkpoint {
            tensors: vec![("x".into(), vec![2, 2], vec![0.0; 3])],
        };
        let path = std::env::temp_dir().join("mofa_ckpt_bad.bin");
        assert!(ck.save(&path).is_err());
    }
}

//! Parameter checkpointing — simple self-describing binary format.
//!
//! Layout: magic "MOFA" u32 version | u32 count | per tensor:
//! u32 name_len, name bytes, u32 ndims, u64 dims…, f32 data…
//! Little-endian throughout. Used to hand a pre-trained base model from the
//! pretraining example to the instruction-tuning / LoRA examples.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

pub struct Checkpoint {
    pub tensors: Vec<(String, Vec<usize>, Vec<f32>)>,
}

const MAGIC: &[u8; 4] = b"MOFA";
const VERSION: u32 = 1;

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, dims, data) in &self.tensors {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u32).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(dims.len() as u32).to_le_bytes())?;
            for d in dims {
                f.write_all(&(*d as u64).to_le_bytes())?;
            }
            let expect: usize = dims.iter().product::<usize>().max(1);
            if expect != data.len() {
                bail!("{name}: dims {:?} vs {} floats", dims, data.len());
            }
            for x in data {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path)
                .with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: not a MOFA checkpoint", path.display());
        }
        let version = read_u32(&mut f)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let count = read_u32(&mut f)? as usize;
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = read_u32(&mut f)? as usize;
            let mut nb = vec![0u8; name_len];
            f.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            let ndims = read_u32(&mut f)? as usize;
            let mut dims = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                let mut b = [0u8; 8];
                f.read_exact(&mut b)?;
                dims.push(u64::from_le_bytes(b) as usize);
            }
            let numel: usize = dims.iter().product::<usize>().max(1);
            let mut bytes = vec![0u8; numel * 4];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.push((name, dims, data));
        }
        Ok(Checkpoint { tensors })
    }
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ck = Checkpoint {
            tensors: vec![
                ("tok_emb".into(), vec![4, 3], (0..12).map(|i| i as f32)
                    .collect()),
                ("lnf".into(), vec![5], vec![1.0; 5]),
            ],
        };
        let path = std::env::temp_dir().join("mofa_ckpt_test.bin");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.tensors[0].0, "tok_emb");
        assert_eq!(back.tensors[0].1, vec![4, 3]);
        assert_eq!(back.tensors[0].2[5], 5.0);
        assert_eq!(back.tensors[1].1, vec![5]);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("mofa_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn rejects_mismatched_dims() {
        let ck = Checkpoint {
            tensors: vec![("x".into(), vec![2, 2], vec![0.0; 3])],
        };
        let path = std::env::temp_dir().join("mofa_ckpt_bad.bin");
        assert!(ck.save(&path).is_err());
    }
}

//! Run metrics: loss curves, throughput, wall-clock — the raw series every
//! paper figure is rebuilt from.
//!
//! Step-phase attribution goes through [`PhaseTimer`]: one guard times a
//! phase for the cumulative `fwd_s`/`opt_s`/`marshal_s` fields *and*
//! opens a matching `obs` engine span, so the coarse phase report and the
//! Chrome trace always agree on what counted as forward, optimizer, or
//! marshaling time.

use std::time::Instant;

use crate::obs;
use crate::util::table::Series;

/// The engine's step phases. Labels double as the `obs` span names.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Forward + backward through the model (PJRT execute or native nn).
    Fwd,
    /// Optimizer-step dispatch (fleet / fused plans / PJRT).
    Opt,
    /// Host-side batch/gradient marshaling.
    Marshal,
}

impl Phase {
    pub fn label(self) -> &'static str {
        match self {
            Phase::Fwd => "fwd_bwd",
            Phase::Opt => "opt",
            Phase::Marshal => "marshal",
        }
    }
}

/// In-flight phase measurement: carries the wall-clock start for the
/// metrics rollup and an `obs` engine span for the trace. If the phase
/// unwinds (a `?` error path), dropping the timer still closes the span;
/// the metrics fields are only updated through
/// [`TrainMetrics::end_phase`], exactly like the old manual
/// `Instant::now()` accumulation.
pub struct PhaseTimer {
    pub(crate) phase: Phase,
    pub(crate) start: Instant,
    _span: obs::SpanGuard,
}

impl PhaseTimer {
    pub fn begin(phase: Phase) -> PhaseTimer {
        PhaseTimer {
            phase,
            start: Instant::now(),
            _span: obs::span(obs::Category::Engine, phase.label()),
        }
    }
}

pub struct TrainMetrics {
    pub run_name: String,
    pub train_loss: Series,
    pub val_loss: Series,
    /// (step, seconds since start) for wall-clock figures (Fig. 2 / 5b).
    pub wall: Series,
    pub tokens_seen: usize,
    /// Cumulative seconds in the fwd+bwd artifact (PJRT execute).
    pub fwd_s: f64,
    /// Cumulative seconds in optimizer-step dispatch (incl. PJRT).
    pub opt_s: f64,
    /// Cumulative seconds marshaling batches/gradients host-side.
    pub marshal_s: f64,
    start: Instant,
}

impl TrainMetrics {
    pub fn new(run_name: &str) -> TrainMetrics {
        TrainMetrics {
            run_name: run_name.to_string(),
            train_loss: Series::new(format!("{run_name}/train")),
            val_loss: Series::new(format!("{run_name}/val")),
            wall: Series::new(format!("{run_name}/wall_s")),
            tokens_seen: 0,
            fwd_s: 0.0,
            opt_s: 0.0,
            marshal_s: 0.0,
            start: Instant::now(),
        }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Fold `secs` into the matching cumulative phase field — the single
    /// rollup point shared by [`end_phase`][Self::end_phase] and any
    /// manual accumulation.
    pub fn add_phase_s(&mut self, phase: Phase, secs: f64) {
        match phase {
            Phase::Fwd => self.fwd_s += secs,
            Phase::Opt => self.opt_s += secs,
            Phase::Marshal => self.marshal_s += secs,
        }
    }

    /// Close a [`PhaseTimer`], rolling its elapsed time into the phase
    /// fields (and, through the timer's drop, closing the engine span).
    pub fn end_phase(&mut self, t: PhaseTimer) {
        self.add_phase_s(t.phase, t.start.elapsed().as_secs_f64());
    }

    pub fn log_train(&mut self, step: usize, loss: f32, tokens: usize) {
        self.tokens_seen += tokens;
        self.train_loss.push(step as f64, loss as f64);
        self.wall.push(step as f64, self.elapsed_s());
    }

    pub fn log_val(&mut self, step: usize, loss: f32) {
        self.val_loss.push(step as f64, loss as f64);
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_seen as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn final_val_loss(&self) -> Option<f64> {
        self.val_loss.last()
    }

    /// Validation perplexity (the NanoGPT speedrun metric, Fig. 3).
    pub fn final_val_ppl(&self) -> Option<f64> {
        self.final_val_loss().map(f64::exp)
    }

    /// `[fwd, opt, marshal, other]` as fractions of elapsed wall clock;
    /// the four always sum to exactly 1 (other is the residual).
    pub fn phase_fractions(&self) -> [f64; 4] {
        let total = self.elapsed_s().max(1e-9);
        let f = self.fwd_s / total;
        let o = self.opt_s / total;
        let ma = self.marshal_s / total;
        [f, o, ma, 1.0 - f - o - ma]
    }

    /// Phase breakdown string for the §Perf analysis.
    pub fn phase_report(&self) -> String {
        let [f, o, ma, rest] = self.phase_fractions();
        format!(
            "fwd+bwd {:.1}% | opt {:.1}% | marshal {:.1}% | other {:.1}%",
            100.0 * f,
            100.0 * o,
            100.0 * ma,
            100.0 * rest
        )
    }

    pub fn all_series(&self) -> Vec<Series> {
        vec![self.train_loss.clone(), self.val_loss.clone(),
             self.wall.clone()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut m = TrainMetrics::new("run");
        m.log_train(0, 2.0, 100);
        m.log_train(1, 1.5, 100);
        m.log_val(1, 1.7);
        assert_eq!(m.tokens_seen, 200);
        assert_eq!(m.train_loss.points.len(), 2);
        assert!((m.final_val_loss().unwrap() - 1.7).abs() < 1e-6);
        assert!((m.final_val_ppl().unwrap() - (1.7f32 as f64).exp()).abs() < 1e-6);
        assert!(m.tokens_per_sec() > 0.0);
    }

    #[test]
    fn add_phase_s_matches_manual_accumulation() {
        // The timer rollup and the old hand-written `fwd_s += dt` style
        // must land bitwise-identically in the same fields.
        let seq = [
            (Phase::Marshal, 0.001),
            (Phase::Fwd, 0.25),
            (Phase::Opt, 0.125),
            (Phase::Fwd, 0.0625),
            (Phase::Marshal, 0.5),
            (Phase::Opt, 0.03125),
        ];
        let mut via_timer = TrainMetrics::new("a");
        let mut manual = TrainMetrics::new("b");
        for &(p, dt) in &seq {
            via_timer.add_phase_s(p, dt);
            match p {
                Phase::Fwd => manual.fwd_s += dt,
                Phase::Opt => manual.opt_s += dt,
                Phase::Marshal => manual.marshal_s += dt,
            }
        }
        assert_eq!(via_timer.fwd_s.to_bits(), manual.fwd_s.to_bits());
        assert_eq!(via_timer.opt_s.to_bits(), manual.opt_s.to_bits());
        assert_eq!(via_timer.marshal_s.to_bits(),
                   manual.marshal_s.to_bits());
    }

    #[test]
    fn end_phase_routes_to_matching_field_only() {
        let mut m = TrainMetrics::new("run");
        let t = PhaseTimer::begin(Phase::Opt);
        // Guarantee a nonzero elapsed reading on coarse clocks.
        while t.start.elapsed().as_nanos() == 0 {
            std::hint::spin_loop();
        }
        m.end_phase(t);
        assert!(m.opt_s > 0.0);
        assert_eq!(m.fwd_s, 0.0);
        assert_eq!(m.marshal_s, 0.0);
    }

    #[test]
    fn phase_percentages_sum_to_at_most_100() {
        let mut m = TrainMetrics::new("run");
        // Let some wall clock pass, then attribute strictly less of it.
        while m.elapsed_s() < 1e-4 {
            std::hint::spin_loop();
        }
        let snap = m.elapsed_s();
        m.add_phase_s(Phase::Fwd, 0.5 * snap);
        m.add_phase_s(Phase::Opt, 0.3 * snap);
        m.add_phase_s(Phase::Marshal, 0.1 * snap);
        let [f, o, ma, rest] = m.phase_fractions();
        assert!(f + o + ma <= 1.0 + 1e-12,
                "attributed {f}+{o}+{ma} exceeds elapsed");
        assert!((f + o + ma + rest - 1.0).abs() < 1e-12);
        assert!(rest >= -1e-12, "negative residual");
        assert!(m.phase_report().contains('%'));
    }

    #[test]
    fn wall_series_is_monotone() {
        let mut m = TrainMetrics::new("run");
        for step in 0..50 {
            m.log_train(step, 1.0, 10);
        }
        for w in m.wall.points.windows(2) {
            assert!(w[0].0 < w[1].0, "step strictly increasing");
            assert!(w[0].1 <= w[1].1, "wall clock went backwards");
        }
    }
}

//! Run metrics: loss curves, throughput, wall-clock — the raw series every
//! paper figure is rebuilt from.

use std::time::Instant;

use crate::util::table::Series;

pub struct TrainMetrics {
    pub run_name: String,
    pub train_loss: Series,
    pub val_loss: Series,
    /// (step, seconds since start) for wall-clock figures (Fig. 2 / 5b).
    pub wall: Series,
    pub tokens_seen: usize,
    /// Cumulative seconds in the fwd+bwd artifact (PJRT execute).
    pub fwd_s: f64,
    /// Cumulative seconds in optimizer-step dispatch (incl. PJRT).
    pub opt_s: f64,
    /// Cumulative seconds marshaling batches/gradients host-side.
    pub marshal_s: f64,
    start: Instant,
}

impl TrainMetrics {
    pub fn new(run_name: &str) -> TrainMetrics {
        TrainMetrics {
            run_name: run_name.to_string(),
            train_loss: Series::new(format!("{run_name}/train")),
            val_loss: Series::new(format!("{run_name}/val")),
            wall: Series::new(format!("{run_name}/wall_s")),
            tokens_seen: 0,
            fwd_s: 0.0,
            opt_s: 0.0,
            marshal_s: 0.0,
            start: Instant::now(),
        }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn log_train(&mut self, step: usize, loss: f32, tokens: usize) {
        self.tokens_seen += tokens;
        self.train_loss.push(step as f64, loss as f64);
        self.wall.push(step as f64, self.elapsed_s());
    }

    pub fn log_val(&mut self, step: usize, loss: f32) {
        self.val_loss.push(step as f64, loss as f64);
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_seen as f64 / self.elapsed_s().max(1e-9)
    }

    pub fn final_val_loss(&self) -> Option<f64> {
        self.val_loss.last()
    }

    /// Validation perplexity (the NanoGPT speedrun metric, Fig. 3).
    pub fn final_val_ppl(&self) -> Option<f64> {
        self.final_val_loss().map(f64::exp)
    }

    /// Phase breakdown string for the §Perf analysis.
    pub fn phase_report(&self) -> String {
        let total = self.elapsed_s().max(1e-9);
        format!(
            "fwd+bwd {:.1}% | opt {:.1}% | marshal {:.1}% | other {:.1}%",
            100.0 * self.fwd_s / total,
            100.0 * self.opt_s / total,
            100.0 * self.marshal_s / total,
            100.0 * (total - self.fwd_s - self.opt_s - self.marshal_s)
                / total
        )
    }

    pub fn all_series(&self) -> Vec<Series> {
        vec![self.train_loss.clone(), self.val_loss.clone(),
             self.wall.clone()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut m = TrainMetrics::new("run");
        m.log_train(0, 2.0, 100);
        m.log_train(1, 1.5, 100);
        m.log_val(1, 1.7);
        assert_eq!(m.tokens_seen, 200);
        assert_eq!(m.train_loss.points.len(), 2);
        assert!((m.final_val_loss().unwrap() - 1.7).abs() < 1e-6);
        assert!((m.final_val_ppl().unwrap() - (1.7f32 as f64).exp()).abs() < 1e-6);
        assert!(m.tokens_per_sec() > 0.0);
    }
}

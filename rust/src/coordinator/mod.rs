//! Layer-3 training coordinator.
//!
//! Owns the request path end-to-end: batch pipeline → fwd/bwd artifact →
//! per-layer optimizer routing (2-D transformer linears → MoFaSGD / GaLore
//! / Muon / …, embeddings + 1-D params → AdamW, following paper §5.5) →
//! fused low-rank gradient accumulation across micro-batches (§5.5) →
//! LR schedule → metrics/checkpoints. Python never runs here.

pub mod checkpoint;
pub mod engine;
pub mod hp;
pub mod metrics;
pub mod optstate;

pub use engine::{EvalSuite, Trainer, TrainerOptions};
pub use hp::{Hyper, OptimizerChoice, Schedule};
pub use metrics::TrainMetrics;

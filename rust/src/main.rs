//! `mofasgd` — Layer-3 coordinator CLI.
//!
//! Subcommands:
//!   train   --config gpt_tiny --opt mofasgd:r=8,beta=0.95 --steps 50 …
//!   serve   --addr 127.0.0.1:7070 --workers 4   multi-tenant training
//!           daemon: newline-delimited JSON requests over TCP (or
//!           `--addr unix:/tmp/mofa.sock`); `--ckpt-dir D`,
//!           `--auto-checkpoint N`, and `--recover D` add crash-safe
//!           persistence (DESIGN.md §15), e.g.
//!           {"cmd":"admit","spec":{"name":"a","seed":7,"steps":100,
//!            "layers":[{"kind":"mofasgd","m":64,"n":48,"rank":4}]}}
//!           (protocol in rust/src/serve/protocol.rs, DESIGN.md §14)
//!   table2  analytic memory/resampling complexity (paper Table 2)
//!   info    registry + config summary
//!
//! The paper-figure harnesses live under examples/ (see DESIGN.md §3).

use anyhow::{bail, Result};

use mofasgd::coordinator::{Hyper, OptimizerChoice, Schedule, Trainer,
                           TrainerOptions};
use mofasgd::data::corpus::LmDataset;
use mofasgd::fusion::autotune;
use mofasgd::memory::model::{breakdown, GradMode, MemOptimizer};
use mofasgd::memory::{llama31_8b, Breakdown};
use mofasgd::obs;
use mofasgd::runtime::Registry;
use mofasgd::util::cli::Args;
use mofasgd::util::logging;
use mofasgd::util::table::{fmt_f, sparkline, Table};

fn main() -> Result<()> {
    let args = Args::from_env();
    if args.flag("debug") {
        logging::set_level(logging::DEBUG);
    }
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("table2") => cmd_table2(&args),
        Some("info") => cmd_info(&args),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown command `{cmd}`\n");
            }
            eprintln!(
                "usage: mofasgd <train|serve|table2|info> [--options]\n\
                 examples/ contains the per-figure harnesses \
                 (see DESIGN.md §3)."
            );
            if other.is_some() {
                bail!("unknown command");
            }
            Ok(())
        }
    }
}

/// Warn (don't fail) about `--options` a subcommand doesn't accept, so
/// a typo like `--replica` for `--replicas` can't silently no-op into a
/// differently-configured run.
fn warn_unknown(args: &Args, known: &[&str]) {
    for opt in args.unknown_options(known) {
        logging::warn(format!("ignoring unknown option --{opt}"));
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    warn_unknown(args, &["debug", "trace", "autotune", "config", "opt",
                         "steps", "accum", "replicas", "lr", "seed",
                         "eval-every", "artifacts", "emb-lr", "no-fused",
                         "save"]);
    // `--trace <path>` / `MOFA_TRACE=<path>` turns on span recording and
    // writes a Chrome trace-event file at the end of the run.
    let trace_path =
        args.get("trace").map(str::to_string).or_else(obs::trace_path_from_env);
    if trace_path.is_some() {
        obs::set_enabled(true);
    }
    // `--autotune off|on|refresh` selects GEMM micro-kernel variants per
    // shape class; default is the MOFA_AUTOTUNE environment mode (off
    // when unset), which `autotune::mode()` resolves on first call.
    let at = args.choice_or("autotune", autotune::mode().name(),
                            &["off", "on", "refresh"])?;
    autotune::set_mode(autotune::Mode::from_name(&at).unwrap());
    let config = args.str_or("config", "gpt_tiny");
    let opt = OptimizerChoice::parse(&args.str_or("opt", "mofasgd:r=8"))?;
    let steps = args.usize_or("steps", 30)?;
    let accum = args.usize_or("accum", 1)?;
    let replicas = args.usize_or("replicas", 1)?;
    let lr = args.f64_or("lr", 1e-3)?;
    let seed = args.u64_or("seed", 0)?;
    let eval_every = args.usize_or("eval-every", 10)?;
    let reg = Registry::open(args.str_or(
        "artifacts", Registry::default_dir().to_str().unwrap()))?;
    let hyper = Hyper {
        lr,
        emb_lr: args.f64_or("emb-lr", lr)?,
        accum,
        replicas,
        fused: !args.flag("no-fused"),
        schedule: Schedule::StableDecay {
            total_steps: steps,
            cooldown_frac: 0.4,
        },
        ..Hyper::default()
    };
    let mut trainer = Trainer::new(&reg, TrainerOptions {
        config: config.clone(),
        choice: opt,
        hyper,
        seed,
        run_name: format!("{}-{}", config, opt.name()),
    })?;
    let cfg = trainer.cfg.clone();
    let mut data = LmDataset::new(cfg.vocab, cfg.batch, cfg.seq, seed);
    let val = data.val_batches(2);
    logging::info(format!(
        "train {config} with {} (fused={}), {} params, {steps} steps",
        opt.name(), hyper.fused, cfg.n_params
    ));
    for step in 0..steps {
        let micro: Vec<_> = (0..accum).map(|_| data.next_train()).collect();
        let loss = trainer.step_lm(&micro)?;
        if step % eval_every == 0 || step + 1 == steps {
            let vl = trainer.eval_lm(&val)?;
            logging::info(format!(
                "step {step:4} train {loss:.4} val {vl:.4} \
                 ({:.0} tok/s)",
                trainer.metrics.tokens_per_sec()
            ));
        }
    }
    let curve: Vec<f64> = trainer.metrics.train_loss.points.iter()
        .map(|(_, y)| *y).collect();
    println!("loss {}", sparkline(&curve));
    println!(
        "final: train={:.4} val={:.4} ppl={:.3} tokens/s={:.0} \
         opt_state_floats={} grad_buffer_floats={}",
        curve.last().copied().unwrap_or(f64::NAN),
        trainer.metrics.final_val_loss().unwrap_or(f64::NAN),
        trainer.metrics.final_val_ppl().unwrap_or(f64::NAN),
        trainer.metrics.tokens_per_sec(),
        trainer.optimizer_state_floats(),
        trainer.gradient_buffer_floats(),
    );
    println!("phases: {}", trainer.metrics.phase_report());
    if let Some(path) = &trace_path {
        let trace = obs::drain();
        obs::export::write_chrome_trace(&trace, path)?;
        obs::export::summary_table(&trace).print();
        obs::export::counter_table(&trace).print();
        logging::info(format!(
            "chrome trace ({} spans) written to {path} — open in \
             ui.perfetto.dev or chrome://tracing",
            trace.spans.len()
        ));
    }
    if let Some(path) = args.get("save") {
        trainer.save_checkpoint(path)?;
        logging::info(format!("checkpoint saved to {path}"));
    }
    Ok(())
}

/// `mofasgd serve`: run the multi-tenant training daemon until a client
/// sends `{"cmd":"shutdown"}`. `--workers 0` (the default) uses the
/// fusion worker count (`MOFA_WORKERS` / available parallelism).
/// `--ckpt-dir <dir>` enables the crash-safe checkpoint store,
/// `--auto-checkpoint <n>` snapshots every running session each n ticks
/// (requires a store directory), and `--recover <dir>` re-admits every
/// session with a valid last-good snapshot before serving (and implies
/// `--ckpt-dir <dir>` unless one is given explicitly).
fn cmd_serve(args: &Args) -> Result<()> {
    warn_unknown(args, &["debug", "addr", "workers", "auto-checkpoint",
                         "ckpt-dir", "recover"]);
    let addr = args.str_or("addr", "127.0.0.1:7070");
    let workers = match args.usize_or("workers", 0)? {
        0 => mofasgd::fusion::workers(),
        w => w,
    };
    let recover_dir = args.get("recover").map(str::to_string);
    let store_dir = args
        .get("ckpt-dir")
        .map(str::to_string)
        .or_else(|| recover_dir.clone());
    let auto_checkpoint = args.u64_or("auto-checkpoint", 0)?;
    if auto_checkpoint > 0 && store_dir.is_none() {
        bail!("--auto-checkpoint requires --ckpt-dir (or --recover)");
    }
    let daemon = mofasgd::serve::Daemon::bind(&addr)?;
    logging::info(format!(
        "serving on {} ({workers} workers, up to {} sessions)",
        daemon.local_addr(),
        mofasgd::serve::MAX_SESSIONS
    ));
    if let Some(dir) = &store_dir {
        logging::info(format!(
            "checkpoint store at {dir} (auto-checkpoint: {})",
            if auto_checkpoint > 0 {
                format!("every {auto_checkpoint} ticks")
            } else {
                "on session completion only".to_string()
            }
        ));
    }
    daemon.run_opts(mofasgd::serve::ServeOpts {
        workers,
        auto_checkpoint,
        store_dir,
        recover: recover_dir.is_some(),
    })
}

fn cmd_table2(args: &Args) -> Result<()> {
    // Paper Table 2: memory complexity (params + optimizer state) and
    // subspace-resampling complexity per optimizer, evaluated analytically
    // on a single m×n matrix, plus whole-model state on LLaMA-3.1-8B.
    warn_unknown(args, &["debug", "m", "n", "rank"]);
    let m = args.usize_or("m", 4096)?;
    let n = args.usize_or("n", 4096)?;
    let r = args.usize_or("rank", 8)?;
    let mut t = Table::new(
        "Table 2 — memory & subspace resampling complexity",
        &["Optimizer", "Memory (floats)", "formula", "Resampling"],
    );
    let rows: Vec<(&str, usize, &str, &str)> = vec![
        ("GaLore", m * n + m * r + 2 * n * r, "mn + mr + 2nr",
         "O(m^2 n) offline (SVD)"),
        ("LoRA", m * n + 3 * (m * r + n * r), "mn + 3mr + 3nr", "-"),
        ("MoFaSGD", m * n + m * r + n * r + r, "mn + mr + nr + r",
         "O((m+n) r^2) online"),
        ("Muon", 2 * m * n, "2mn", "-"),
        ("AdamW", 3 * m * n, "3mn", "-"),
        ("Adafactor", m * n + m + n, "mn + m + n", "-"),
    ];
    for (name, floats, formula, res) in rows {
        t.row(vec![
            name.into(),
            format!("{floats}"),
            formula.into(),
            res.into(),
        ]);
    }
    t.print();
    // Whole-model optimizer state on LLaMA-3.1-8B for context.
    let arch = llama31_8b();
    let mut t2 = Table::new(
        "Optimizer state on LLaMA-3.1-8B (GB, bf16, incl. AdamW-on-embeddings)",
        &["Optimizer", "opt state GB"],
    );
    let opts = [
        ("MoFaSGD (r)", MemOptimizer::MoFaSgd { rank: r }, GradMode::Fused),
        ("GaLore (r)", MemOptimizer::GaLore { rank: r }, GradMode::Fused),
        ("LoRA (r)", MemOptimizer::Lora { rank: r }, GradMode::Fused),
        ("AdamW", MemOptimizer::AdamW, GradMode::Dense),
        ("Muon", MemOptimizer::Muon, GradMode::Dense),
        ("SWAN", MemOptimizer::Swan, GradMode::Dense),
        ("Adafactor", MemOptimizer::Adafactor, GradMode::Dense),
    ];
    for (name, o, g) in opts {
        let b = breakdown(&arch, o, g);
        t2.row(vec![name.into(), fmt_f(Breakdown::gb(b.opt_states), 2)]);
    }
    t2.print();
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    warn_unknown(args, &["debug", "artifacts"]);
    let reg = Registry::open(args.str_or(
        "artifacts", Registry::default_dir().to_str().unwrap()))?;
    println!("artifacts: {}", reg.artifact_names().len());
    for (name, cfg) in &reg.configs {
        println!(
            "config {name}: kind={} d={} layers={} seq={} batch={} \
             vocab={} params={} ranks={:?}",
            cfg.kind, cfg.d, cfg.layers, cfg.seq, cfg.batch, cfg.vocab,
            cfg.n_params, cfg.ranks
        );
    }
    Ok(())
}

//! PJRT runtime: loads AOT artifacts (HLO text) and executes them.
//!
//! The contract with the Python build step is `artifacts/manifest.json`
//! (see `python/compile/aot.py`): every artifact lists ordered input/output
//! tensor descriptors plus semantic tags. This module wraps the `xla`
//! crate (PJRT C API): `HloModuleProto::from_text_file` → `compile` →
//! `execute`, with an executable cache so each artifact is compiled once
//! per process.
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;
use crate::util::logging;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unknown dtype {s}"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub dims: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub tags: Json,
}

/// One model configuration as recorded by the manifest (mirrors
/// `python/compile/configs.py`).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub kind: String,
    pub vocab: usize,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub seq: usize,
    pub batch: usize,
    pub ncls: usize,
    pub n_params: usize,
    pub ranks: Vec<usize>,
    pub lora_ranks: Vec<usize>,
    /// Canonical flat parameter order: (name, shape).
    pub params: Vec<(String, Vec<usize>)>,
}

impl ModelConfig {
    /// 2-D transformer-block linears — the parameters routed to the
    /// low-rank / spectral optimizers (paper §5.5).
    pub fn matrix_params(&self) -> Vec<(String, (usize, usize))> {
        self.params
            .iter()
            .filter(|(n, s)| s.len() == 2 && n.starts_with('l'))
            .map(|(n, s)| (n.clone(), (s[0], s[1])))
            .collect()
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|(n, _)| n == name)
    }
}

fn parse_spec(j: &Json) -> Result<TensorSpec> {
    Ok(TensorSpec {
        name: j.req("name")?.as_str()?.to_string(),
        dims: j
            .req("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>>>()?,
        dtype: DType::parse(j.req("dtype")?.as_str()?)?,
    })
}

/// A compiled artifact ready to execute.
pub struct Exec {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Exec {
    /// Execute with borrowed input literals; returns decomposed outputs.
    pub fn run(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        let res = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("execute {}", self.meta.name))?;
        let mut tuple = res[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch {}", self.meta.name))?;
        let outs = tuple.decompose_tuple()?;
        if outs.len() != self.meta.outputs.len() {
            bail!(
                "{}: manifest promises {} outputs, artifact returned {}",
                self.meta.name,
                self.meta.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }
}

/// Artifact registry: manifest index + lazy compile cache.
pub struct Registry {
    pub dir: PathBuf,
    pub client: xla::PjRtClient,
    metas: BTreeMap<String, ArtifactMeta>,
    pub configs: BTreeMap<String, ModelConfig>,
    cache: RefCell<HashMap<String, Rc<Exec>>>,
}

impl Registry {
    pub fn open(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(
            || format!("read {} (run `make artifacts`)",
                       manifest_path.display()),
        )?;
        let root = Json::parse(&text)?;
        let mut metas = BTreeMap::new();
        for a in root.req("artifacts")?.as_arr()? {
            let meta = ArtifactMeta {
                name: a.req("name")?.as_str()?.to_string(),
                file: a.req("file")?.as_str()?.to_string(),
                inputs: a
                    .req("inputs")?
                    .as_arr()?
                    .iter()
                    .map(parse_spec)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .req("outputs")?
                    .as_arr()?
                    .iter()
                    .map(parse_spec)
                    .collect::<Result<Vec<_>>>()?,
                tags: a.req("tags")?.clone(),
            };
            metas.insert(meta.name.clone(), meta);
        }
        let mut configs = BTreeMap::new();
        for (name, c) in root.req("configs")?.as_obj()? {
            let params = c
                .req("params")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok((
                        p.req("name")?.as_str()?.to_string(),
                        p.req("shape")?
                            .as_arr()?
                            .iter()
                            .map(|d| d.as_usize())
                            .collect::<Result<Vec<_>>>()?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            let get_usize = |k: &str| -> usize {
                c.get(k).and_then(|v| v.as_usize().ok()).unwrap_or(0)
            };
            configs.insert(
                name.clone(),
                ModelConfig {
                    name: name.clone(),
                    kind: c.req("kind")?.as_str()?.to_string(),
                    vocab: get_usize("vocab"),
                    d: get_usize("d"),
                    layers: get_usize("layers"),
                    heads: get_usize("heads"),
                    seq: get_usize("seq"),
                    batch: get_usize("batch"),
                    ncls: get_usize("ncls"),
                    n_params: get_usize("n_params"),
                    ranks: c
                        .req("ranks")?
                        .as_arr()?
                        .iter()
                        .map(|r| r.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                    lora_ranks: c
                        .req("lora_ranks")?
                        .as_arr()?
                        .iter()
                        .map(|r| r.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                    params,
                },
            );
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        logging::debug(format!(
            "registry: {} artifacts, {} configs, platform {}",
            metas.len(),
            configs.len(),
            client.platform_name()
        ));
        Ok(Registry { dir, client, metas, configs, cache: RefCell::default() })
    }

    /// Default artifacts directory (repo-root/artifacts).
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.metas
            .get(name)
            .ok_or_else(|| anyhow!("artifact `{name}` not in manifest"))
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.metas.keys().cloned().collect()
    }

    pub fn config(&self, name: &str) -> Result<&ModelConfig> {
        self.configs
            .get(name)
            .ok_or_else(|| anyhow!("config `{name}` not in manifest"))
    }

    /// Compile (or fetch cached) an artifact.
    pub fn load(&self, name: &str) -> Result<Rc<Exec>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let meta = self.meta(name)?.clone();
        let path = self.dir.join(&meta.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e}"))?;
        logging::debug(format!(
            "compiled {name} in {:.2}s",
            t0.elapsed().as_secs_f64()
        ));
        let exec = Rc::new(Exec { meta, exe });
        self.cache.borrow_mut().insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Name of a per-shape optimizer artifact, e.g.
    /// `opt_name("mofasgd_step", 256, 768, Some(8))`.
    pub fn opt_name(kind: &str, m: usize, n: usize, r: Option<usize>) -> String {
        match r {
            Some(r) => format!("{kind}_{m}x{n}_r{r}"),
            None => format!("{kind}_{m}x{n}"),
        }
    }

    /// AdamW artifact for an arbitrary-shape parameter.
    pub fn adamw_name(dims: &[usize]) -> String {
        let key: Vec<String> = dims.iter().map(|d| d.to_string()).collect();
        format!("adamw_step_{}", key.join("x"))
    }
}

// ---------------------------------------------------------------------------
// Literal marshaling helpers
// ---------------------------------------------------------------------------

pub fn lit_f32(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
    debug_assert_eq!(dims.iter().product::<usize>().max(1), data.len());
    if dims.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let flat = xla::Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|d| *d as i64).collect();
    flat.reshape(&dims_i64).map_err(|e| anyhow!("reshape: {e}"))
}

pub fn lit_i32(dims: &[usize], data: &[i32]) -> Result<xla::Literal> {
    debug_assert_eq!(dims.iter().product::<usize>().max(1), data.len());
    if dims.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let flat = xla::Literal::vec1(data);
    let dims_i64: Vec<i64> = dims.iter().map(|d| *d as i64).collect();
    flat.reshape(&dims_i64).map_err(|e| anyhow!("reshape: {e}"))
}

pub fn lit_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn to_f32_vec(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))
}

pub fn scalar_f32(l: &xla::Literal) -> Result<f32> {
    Ok(to_f32_vec(l)?[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> Option<Registry> {
        let dir = Registry::default_dir();
        if dir.join("manifest.json").exists() {
            Some(Registry::open(dir).unwrap())
        } else {
            None // `make artifacts` not run — skip
        }
    }

    #[test]
    fn manifest_parses_and_has_configs() {
        let Some(reg) = registry() else { return };
        assert!(reg.configs.contains_key("gpt_tiny"));
        let cfg = reg.config("gpt_tiny").unwrap();
        assert_eq!(cfg.kind, "lm");
        assert!(cfg.n_params > 100_000);
        assert_eq!(cfg.matrix_params().len(), 4 * cfg.layers);
    }

    #[test]
    fn adamw_roundtrip_executes() {
        let Some(reg) = registry() else { return };
        let exec = reg.load("adamw_step_128").unwrap();
        let n = 128;
        let w = lit_f32(&[n], &vec![1.0; n]).unwrap();
        let m = lit_f32(&[n], &vec![0.0; n]).unwrap();
        let v = lit_f32(&[n], &vec![0.0; n]).unwrap();
        let g = lit_f32(&[n], &vec![0.5; n]).unwrap();
        let outs = exec
            .run(&[
                &w, &m, &v, &g,
                &lit_scalar(0.1), &lit_scalar(1.0),
                &lit_scalar(0.9), &lit_scalar(0.999), &lit_scalar(0.0),
            ])
            .unwrap();
        assert_eq!(outs.len(), 3);
        let w2 = to_f32_vec(&outs[0]).unwrap();
        // first Adam step ≈ w − η·sign(g)
        assert!((w2[0] - (1.0 - 0.1)).abs() < 1e-4, "{}", w2[0]);
    }

    #[test]
    fn mofasgd_step_artifact_matches_native() {
        let Some(reg) = registry() else { return };
        use crate::linalg::Mat;
        use crate::optim::{MatrixOptimizer, MoFaSgd};
        use crate::util::rng::Rng;
        let (m, n, r) = (128, 384, 4);
        let exec = reg
            .load(&Registry::opt_name("mofasgd_step", m, n, Some(r)))
            .unwrap();
        let mut rng = Rng::new(1);
        // Start both from identical factor state.
        let mut native = MoFaSgd::new(m, n, r, 0.9);
        let mut w_nat = Mat::randn(&mut rng, m, n, 1.0);
        let g0 = Mat::randn(&mut rng, m, r, 1.0)
            .matmul(&Mat::randn(&mut rng, r, n, 1.0));
        native.step(&mut w_nat, &g0, 0.01); // init
        let g1 = Mat::randn(&mut rng, m, n, 1.0);

        let w_lit = lit_f32(&[m, n], &w_nat.data).unwrap();
        let u_lit = lit_f32(&[m, r], &native.u.data).unwrap();
        let s_lit = lit_f32(&[r], &native.s).unwrap();
        let v_lit = lit_f32(&[n, r], &native.v.data).unwrap();
        let g_lit = lit_f32(&[m, n], &g1.data).unwrap();
        let outs = exec
            .run(&[
                &w_lit, &u_lit, &s_lit, &v_lit, &g_lit,
                &lit_scalar(0.01), &lit_scalar(0.9),
            ])
            .unwrap();
        native.step(&mut w_nat, &g1, 0.01);
        let w_art = Mat::from_vec(m, n, to_f32_vec(&outs[0]).unwrap());
        assert!(
            w_art.rel_err(&w_nat) < 1e-3,
            "artifact vs native weight divergence: {}",
            w_art.rel_err(&w_nat)
        );
        // Singular values agree too (basis may differ by rotation/sign).
        let s_art = to_f32_vec(&outs[2]).unwrap();
        for (a, b) in s_art.iter().zip(&native.s) {
            assert!((a - b).abs() < 1e-2 * b.abs().max(1.0), "{a} {b}");
        }
    }

    #[test]
    fn opt_name_formats() {
        assert_eq!(
            Registry::opt_name("mofasgd_step", 256, 768, Some(8)),
            "mofasgd_step_256x768_r8"
        );
        assert_eq!(Registry::opt_name("muon_step", 128, 128, None),
                   "muon_step_128x128");
        assert_eq!(Registry::adamw_name(&[256, 128]), "adamw_step_256x128");
    }

    #[test]
    fn missing_artifact_is_clean_error() {
        let Some(reg) = registry() else { return };
        assert!(reg.load("no_such_artifact").is_err());
    }

    #[test]
    fn lit_roundtrip() {
        let l = lit_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(to_f32_vec(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let s = lit_scalar(3.5);
        assert!((scalar_f32(&s).unwrap() - 3.5).abs() < 1e-6);
    }
}

//! Greedy op-graph fuser, modeled on burn's `OptimizationBuilder`: walk
//! the op list keeping at most one *open* fused node; each matmul anchors
//! a GEMM node and trailing elementwise ops fuse into its epilogue (or
//! into the GEMM's alpha/beta when they are pure scale/accumulate);
//! elementwise producer→consumer runs collapse into single-pass chains.
//! An op that cannot fuse *closes* the open node and starts a new one.
//!
//! Fusion is only performed when it provably preserves semantics:
//! * the fused-away intermediate is a temp whose last read is the fusing
//!   op (liveness is precomputed);
//! * a retargeted output never aliases a buffer the open node still
//!   reads (the node writes progressively);
//! * scalar folding only happens when the product stays representable
//!   ([`SVal::fold_mul`]).
//!
//! Eliminated temps are never materialized: they get no arena slot.

use super::ir::{BufId, BufKind, Graph, MatKind, Op, SVal};
use super::plan::{ElemNode, EpiOp, GemmNode, Loc, Node, Plan, Src, Step,
                  MAX_EPI, MAX_STEPS};

/// Open node under construction (buffer ids not yet resolved to Locs).
enum Pending {
    Gemm {
        kind: MatKind,
        a: BufId,
        b: BufId,
        out: BufId,
        alpha: SVal,
        beta: SVal,
        epi: Vec<(EpiKindB, SVal)>,
    },
    Elem {
        out: BufId,
        steps: Vec<StepB>,
    },
}

/// Builder-stage epilogue op over BufIds.
#[derive(Clone, Copy)]
enum EpiKindB {
    Scale,
    Add(BufId),
    Map(fn(f32) -> f32),
}

/// Builder-stage chain step over BufIds (`None` src ⇒ the chain's own
/// output buffer, i.e. `Src::Own` after resolution).
#[derive(Clone, Copy)]
enum StepB {
    Ld(Option<BufId>, SVal),
    Add(Option<BufId>, SVal),
    MulB(Option<BufId>),
    MulS(SVal),
    Map1(fn(f32) -> f32),
    Zip2(fn(f32, f32) -> f32, Option<BufId>),
    Zip2Rev(fn(f32, f32) -> f32, Option<BufId>),
    ZipSelf(fn(f32, f32) -> f32),
}

/// Copied-out summary of the open node, for fusion checks without holding
/// a borrow.
#[derive(Clone, Copy)]
enum Peek {
    None,
    Gemm { a: BufId, b: BufId, out: BufId, beta: SVal, epi_len: usize,
           reads_hit: bool },
    Elem { out: BufId, steps_len: usize, reads_hit: bool },
}

fn peek(pending: &Option<Pending>, probe: BufId) -> Peek {
    match pending {
        None => Peek::None,
        Some(Pending::Gemm { a, b, out, beta, epi, .. }) => Peek::Gemm {
            a: *a,
            b: *b,
            out: *out,
            beta: *beta,
            epi_len: epi.len(),
            reads_hit: *a == probe
                || *b == probe
                || epi.iter().any(|(k, _)| {
                    matches!(k, EpiKindB::Add(s) if *s == probe)
                }),
        },
        Some(Pending::Elem { out, steps }) => Peek::Elem {
            out: *out,
            steps_len: steps.len(),
            reads_hit: steps.iter().any(|s| {
                matches!(s,
                    StepB::Ld(Some(b), _) | StepB::Add(Some(b), _)
                    | StepB::MulB(Some(b)) | StepB::Zip2(_, Some(b))
                    | StepB::Zip2Rev(_, Some(b)) if *b == probe)
            }),
        },
    }
}

fn mul2(a: f32, b: f32) -> f32 {
    a * b
}

/// Rebind `Own` (None) sources before retargeting a chain away from
/// `old`: those steps were recorded as "read the chain's own output",
/// which at the time meant `old` — after the output moves they must stay
/// bound to `old` (which an earlier node wrote; ir.rs rejects graphs
/// where it was never written).
fn rebind_own(steps: &mut [StepB], old: BufId) {
    for s in steps.iter_mut() {
        match s {
            StepB::Ld(src @ None, _)
            | StepB::Add(src @ None, _)
            | StepB::MulB(src @ None)
            | StepB::Zip2(_, src @ None)
            | StepB::Zip2Rev(_, src @ None) => *src = Some(old),
            _ => {}
        }
    }
}

/// Compile a graph into a fused [`Plan`].
pub fn compile(g: &Graph) -> Plan {
    // Liveness: last op index reading each temp (Ext/In are live forever /
    // never fusable away, so only temps matter).
    let mut last_read = vec![0usize; g.bufs.len()];
    for (idx, op) in g.ops.iter().enumerate() {
        let mut mark = |b: BufId| {
            last_read[b.0] = last_read[b.0].max(idx);
        };
        match *op {
            Op::MatMul { a, b, out, beta, .. } => {
                mark(a);
                mark(b);
                if !beta.is_lit(0.0) {
                    mark(out);
                }
            }
            Op::Axpy { x, y, .. } => {
                mark(x);
                mark(y);
            }
            Op::Scale { x, .. } | Op::Map { x, .. } => mark(x),
            Op::Mul { x, y, .. } | Op::Zip { x, y, .. } => {
                mark(x);
                mark(y);
            }
        }
    }
    // `b` is a temp whose last read is at or before `idx` — safe to fuse
    // away at `idx`.
    let dead_after = |b: BufId, idx: usize| -> bool {
        g.kind(b) == BufKind::Temp && last_read[b.0] <= idx
    };

    let mut nodes_b: Vec<Pending> = Vec::new();
    let mut pending: Option<Pending> = None;

    macro_rules! close {
        () => {
            if let Some(p) = pending.take() {
                nodes_b.push(p);
            }
        };
    }

    for (idx, op) in g.ops.iter().enumerate() {
        match *op {
            Op::MatMul { kind, a, b, out, alpha, beta } => {
                close!();
                pending = Some(Pending::Gemm {
                    kind,
                    a,
                    b,
                    out,
                    alpha,
                    beta,
                    epi: Vec::new(),
                });
            }

            // -- scale / map: single-input elementwise ---------------------
            Op::Scale { .. } | Op::Map { .. } => {
                let (out, x, sv, f) = match *op {
                    Op::Scale { out, a, x } => (out, x, a, None),
                    Op::Map { out, x, f } => (out, x, SVal::Lit(1.0), Some(f)),
                    _ => unreachable!(),
                };
                let mut fused = false;
                match peek(&pending, out) {
                    Peek::Gemm { out: g_out, beta: g_beta, epi_len,
                                 reads_hit, .. }
                        if x == g_out
                            && epi_len < MAX_EPI
                            && (out == x
                                || (dead_after(x, idx)
                                    && g_beta.is_lit(0.0)
                                    && !reads_hit)) =>
                    {
                        if let Some(Pending::Gemm { out: po, epi, .. }) =
                            pending.as_mut()
                        {
                            *po = out;
                            epi.push(match f {
                                Some(f) => (EpiKindB::Map(f), SVal::Lit(1.0)),
                                None => (EpiKindB::Scale, sv),
                            });
                        }
                        fused = true;
                    }
                    Peek::Elem { out: e_out, steps_len, reads_hit }
                        if x == e_out
                            && steps_len < MAX_STEPS
                            && (out == x
                                || (dead_after(x, idx) && !reads_hit)) =>
                    {
                        if let Some(Pending::Elem { out: po, steps }) =
                            pending.as_mut()
                        {
                            if *po != out {
                                rebind_own(steps, *po);
                            }
                            *po = out;
                            steps.push(match f {
                                Some(f) => StepB::Map1(f),
                                None => StepB::MulS(sv),
                            });
                        }
                        fused = true;
                    }
                    _ => {}
                }
                if !fused {
                    close!();
                    let src = if x == out { None } else { Some(x) };
                    let steps = match f {
                        Some(f) => vec![StepB::Ld(src, SVal::Lit(1.0)),
                                        StepB::Map1(f)],
                        None => vec![StepB::Ld(src, sv)],
                    };
                    pending = Some(Pending::Elem { out, steps });
                }
            }

            // -- axpy: out = a·x + b·y ------------------------------------
            Op::Axpy { out, a, x, b, y } => {
                let mut fused = false;
                match peek(&pending, out) {
                    Peek::Gemm { a: g_a, b: g_b, out: g_out, beta: g_beta,
                                 epi_len, reads_hit } => {
                        // Exactly one side must be the open product.
                        let side = if y == g_out && x != g_out {
                            Some((x, a, b)) // (other, s_other, s_prod)
                        } else if x == g_out && y != g_out {
                            Some((y, b, a))
                        } else {
                            None
                        };
                        if let Some((other, s_other, s_prod)) = side {
                            if dead_after(g_out, idx) && g_beta.is_lit(0.0) {
                                if out == other && epi_len == 0 {
                                    // out = s_other·out + s_prod·(A·B):
                                    // fold into beta/alpha. `out`'s old
                                    // value flows through beta, so it must
                                    // not alias the gemm operands.
                                    if out != g_a && out != g_b {
                                        if let Some(Pending::Gemm {
                                            out: po,
                                            alpha,
                                            beta,
                                            ..
                                        }) = pending.as_mut()
                                        {
                                            if let Some(na) =
                                                alpha.fold_mul(s_prod)
                                            {
                                                *po = out;
                                                *alpha = na;
                                                *beta = s_other;
                                                fused = true;
                                            }
                                        }
                                    }
                                } else if out != other
                                    && epi_len + 2 <= MAX_EPI
                                    && !reads_hit
                                    && other != g_out
                                {
                                    // out = s_prod·(gemm) + s_other·other
                                    // via epilogue Scale + Add.
                                    if let Some(Pending::Gemm {
                                        out: po, epi, ..
                                    }) = pending.as_mut()
                                    {
                                        *po = out;
                                        epi.push((EpiKindB::Scale, s_prod));
                                        epi.push((
                                            EpiKindB::Add(other),
                                            s_other,
                                        ));
                                        fused = true;
                                    }
                                }
                            }
                        }
                    }
                    Peek::Elem { out: e_out, steps_len, reads_hit } => {
                        let one_side = (x == e_out) != (y == e_out);
                        if one_side
                            && steps_len + 2 <= MAX_STEPS
                            && dead_after(e_out, idx)
                        {
                            let (other, s_other, s_reg) = if x == e_out {
                                (y, b, a)
                            } else {
                                (x, a, b)
                            };
                            if !reads_hit || other == out {
                                if let Some(Pending::Elem {
                                    out: po, steps,
                                }) = pending.as_mut()
                                {
                                    if *po != out {
                                        rebind_own(steps, *po);
                                    }
                                    *po = out;
                                    steps.push(StepB::MulS(s_reg));
                                    let src = if other == out {
                                        None
                                    } else {
                                        Some(other)
                                    };
                                    steps.push(StepB::Add(src, s_other));
                                    fused = true;
                                }
                            }
                        } else if x == e_out
                            && y == e_out
                            && steps_len < MAX_STEPS
                            && dead_after(e_out, idx)
                            && !reads_hit
                        {
                            // (a+b)·reg — foldable for literals only.
                            if let (SVal::Lit(av), SVal::Lit(bv)) = (a, b) {
                                if let Some(Pending::Elem {
                                    out: po, steps,
                                }) = pending.as_mut()
                                {
                                    if *po != out {
                                        rebind_own(steps, *po);
                                    }
                                    *po = out;
                                    steps.push(StepB::MulS(SVal::Lit(
                                        av + bv,
                                    )));
                                    fused = true;
                                }
                            }
                        }
                    }
                    Peek::None => {}
                }
                if !fused {
                    close!();
                    let sx = if x == out { None } else { Some(x) };
                    let sy = if y == out { None } else { Some(y) };
                    pending = Some(Pending::Elem {
                        out,
                        steps: vec![StepB::Ld(sx, a), StepB::Add(sy, b)],
                    });
                }
            }

            // -- mul / zip: two-input elementwise --------------------------
            Op::Mul { out, x, y } | Op::Zip { out, x, y, .. } => {
                let (is_mul, f) = match *op {
                    Op::Zip { f, .. } => (false, f),
                    _ => (true, mul2),
                };
                let mut fused = false;
                if let Peek::Elem { out: e_out, steps_len, reads_hit } =
                    peek(&pending, out)
                {
                    if steps_len < MAX_STEPS && dead_after(e_out, idx) {
                        if x == e_out && y == e_out && !reads_hit {
                            if let Some(Pending::Elem { out: po, steps }) =
                                pending.as_mut()
                            {
                                if *po != out {
                                    rebind_own(steps, *po);
                                }
                                *po = out;
                                steps.push(StepB::ZipSelf(f));
                                fused = true;
                            }
                        } else if (x == e_out) != (y == e_out) {
                            let (other, rev) = if x == e_out {
                                (y, false)
                            } else {
                                (x, true)
                            };
                            if !reads_hit || other == out {
                                if let Some(Pending::Elem {
                                    out: po, steps,
                                }) = pending.as_mut()
                                {
                                    if *po != out {
                                        rebind_own(steps, *po);
                                    }
                                    *po = out;
                                    let src = if other == out {
                                        None
                                    } else {
                                        Some(other)
                                    };
                                    steps.push(if is_mul {
                                        // Hadamard is commutative — the
                                        // dedicated step skips the fn
                                        // pointer call.
                                        StepB::MulB(src)
                                    } else if rev {
                                        StepB::Zip2Rev(f, src)
                                    } else {
                                        StepB::Zip2(f, src)
                                    });
                                    fused = true;
                                }
                            }
                        }
                    }
                }
                if !fused {
                    close!();
                    let sx = if x == out { None } else { Some(x) };
                    let sy = if y == out { None } else { Some(y) };
                    pending = Some(Pending::Elem {
                        out,
                        steps: vec![StepB::Ld(sx, SVal::Lit(1.0)),
                                    if is_mul {
                                        StepB::MulB(sy)
                                    } else {
                                        StepB::Zip2(f, sy)
                                    }],
                    });
                }
            }
        }
    }
    close!();

    resolve(g, nodes_b)
}

/// Assign Locs: compact surviving temps into arena slots, map bound
/// buffers to their binding indices, and materialize the final nodes.
fn resolve(g: &Graph, nodes_b: Vec<Pending>) -> Plan {
    // Collect temps still referenced by any node, in first-use order.
    let mut temp_slot: Vec<Option<usize>> = vec![None; g.bufs.len()];
    let mut temp_sizes: Vec<usize> = Vec::new();
    {
        let mut touch = |b: BufId| {
            if g.kind(b) == BufKind::Temp && temp_slot[b.0].is_none() {
                temp_slot[b.0] = Some(temp_sizes.len());
                temp_sizes.push(g.shape(b).numel());
            }
        };
        for p in &nodes_b {
            match p {
                Pending::Gemm { a, b, out, epi, .. } => {
                    touch(*a);
                    touch(*b);
                    touch(*out);
                    for (k, _) in epi {
                        if let EpiKindB::Add(s) = k {
                            touch(*s);
                        }
                    }
                }
                Pending::Elem { out, steps } => {
                    touch(*out);
                    for s in steps {
                        if let StepB::Ld(Some(b), _)
                        | StepB::Add(Some(b), _)
                        | StepB::MulB(Some(b))
                        | StepB::Zip2(_, Some(b))
                        | StepB::Zip2Rev(_, Some(b)) = s
                        {
                            touch(*b);
                        }
                    }
                }
            }
        }
    }
    let loc = |b: BufId| -> Loc {
        match g.kind(b) {
            BufKind::In => Loc::In(g.in_index(b)),
            BufKind::Ext => Loc::Ext(g.ext_index(b)),
            BufKind::Temp => Loc::Temp(temp_slot[b.0].expect("live temp")),
        }
    };

    let mut nodes = Vec::with_capacity(nodes_b.len());
    for p in nodes_b {
        match p {
            Pending::Gemm { kind, a, b, out, alpha, beta, epi } => {
                let sh = g.matmul_shape(kind, a, b);
                let k = match kind {
                    MatKind::NN | MatKind::NT => g.shape(a).cols,
                    MatKind::TN => g.shape(a).rows,
                };
                assert!(a != out && b != out, "gemm out aliases operand");
                let epi_r = epi
                    .into_iter()
                    .map(|(kb, s)| match kb {
                        EpiKindB::Scale => EpiOp::Scale { s },
                        EpiKindB::Add(src) => {
                            // The out slot is extracted during execution;
                            // a node must not read it through the epilogue.
                            assert!(src != out, "epilogue reads gemm out");
                            EpiOp::Add { s, src: loc(src) }
                        }
                        EpiKindB::Map(f) => EpiOp::Map { f },
                    })
                    .collect();
                nodes.push(Node::Gemm(GemmNode {
                    kind,
                    m: sh.rows,
                    n: sh.cols,
                    k,
                    a: loc(a),
                    b: loc(b),
                    out: loc(out),
                    variant: super::autotune::compile_choice(
                        kind, sh.rows, sh.cols, k),
                    alpha,
                    beta,
                    epi: epi_r,
                }));
            }
            Pending::Elem { out, steps } => {
                let to_src = |sb: Option<BufId>| -> Src {
                    match sb {
                        None => Src::Own,
                        Some(b) if b == out => Src::Own,
                        Some(b) => Src::L(loc(b)),
                    }
                };
                let steps_r = steps
                    .into_iter()
                    .map(|s| match s {
                        StepB::Ld(b, sv) => Step::Ld { src: to_src(b), s: sv },
                        StepB::Add(b, sv) => {
                            Step::Add { src: to_src(b), s: sv }
                        }
                        StepB::MulB(b) => Step::MulB { src: to_src(b) },
                        StepB::MulS(sv) => Step::MulS { s: sv },
                        StepB::Map1(f) => Step::Map1 { f },
                        StepB::Zip2(f, b) => Step::Zip2 { f, src: to_src(b) },
                        StepB::Zip2Rev(f, b) => {
                            Step::Zip2Rev { f, src: to_src(b) }
                        }
                        StepB::ZipSelf(f) => Step::ZipSelf { f },
                    })
                    .collect();
                nodes.push(Node::Elem(ElemNode {
                    len: g.shape(out).numel(),
                    out: loc(out),
                    steps: steps_r,
                }));
            }
        }
    }
    let mut in_sizes = Vec::new();
    let mut ext_sizes = Vec::new();
    for d in &g.bufs {
        match d.kind {
            BufKind::In => in_sizes.push(d.shape.numel()),
            BufKind::Ext => ext_sizes.push(d.shape.numel()),
            BufKind::Temp => {}
        }
    }
    Plan { nodes, temp_sizes, in_sizes, ext_sizes, n_params: g.n_params }
}

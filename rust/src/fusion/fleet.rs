//! Fleet executor: one pool dispatch for a whole multi-layer optimizer
//! step (see DESIGN.md §10).
//!
//! The coordinator used to execute layers one at a time, paying a full
//! fork-join per kernel per layer: a MoFaSGD step alone issues dozens of
//! GEMMs (projections, blocked-QR panels, Jacobi rounds, the spectral
//! update), and every one of them spawned and joined its own worker set.
//! The fleet inverts that: each layer contributes its step as a short
//! chain of *stages* (a [`FleetUnit`]), the chains of all layers are
//! flattened into one task graph, and [`Fleet::run`] drains the graph
//! through `util::pool::run_task_graph` — `workers` threads spawned
//! once, cross-layer readiness tracked by per-task atomic dependency
//! counters, small layers filling the idle time left by stragglers.
//!
//! Every stage executes with the thread-local kernel worker cap pinned
//! to 1 ([`crate::fusion::with_workers`]): parallelism comes from
//! running many layers' stages concurrently, not from nesting a
//! fork-join inside each kernel.
//!
//! **Bit parity.** Per-layer state is touched only by that layer's
//! stages, which the chain dependencies run in order — so the schedule
//! can never reorder math within a layer, and layers are independent by
//! the caller's contract. Combined with the kernels' guarantee that per
//! element results are worker-count- and chunking-invariant, a fleet
//! step is bit-identical to the serial per-layer loop at every worker
//! count (`rust/tests/fleet_parity.rs`).
//!
//! **Allocation.** With `workers <= 1` the graph runs inline with no
//! queue and no threads: a warm fleet step performs zero heap
//! allocations (counting-allocator proof in `rust/tests/fusion_alloc.rs`).
//! With more workers the scheduler allocates only its per-run task
//! table and the OS threads of the single dispatch.
//!
//! Buffer arenas stay *per layer*: a [`PlanUnit`] carries its own plan
//! workspace, and the native optimizers keep their persistent
//! projection/core scratch — the fleet owns scheduling state only.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Mutex;

use super::plan::{Plan, Workspace};
use crate::obs;
use crate::util::logging;
use crate::util::pool;

/// One layer's contribution to a fleet step: a fixed-length chain of
/// stages. Stages of a unit are invoked strictly in order (0, 1, …) and
/// never concurrently, so stage 0 may compute per-step state (scalar
/// schedules, subspace refreshes) that later stages consume. Different
/// units must not share mutable state — that is the caller's
/// disjointness contract, same as `pool::scope_chunks`.
pub trait FleetUnit: Send {
    /// Number of sequential stages this unit contributes.
    fn n_stages(&self) -> usize;

    /// Run stage `stage` (`0 <= stage < n_stages()`).
    fn run_stage(&mut self, stage: usize);

    /// Data-parallel replica this unit belongs to (0 for unreplicated
    /// units). Purely attributive: trace spans and panic labels carry
    /// it so replica-stage failures and costs are attributable.
    fn replica(&self) -> u32 {
        0
    }

    /// Serving session (tenant) this unit belongs to (0 outside the
    /// serve daemon). Unlike [`FleetUnit::replica`] it is not only
    /// attributive: [`Fleet::run_fair`] keys its round-robin ready
    /// ordering on it, so no session's stages can starve another's.
    fn session(&self) -> u32 {
        0
    }
}

/// Multi-layer single-dispatch executor. Owns only reusable scheduling
/// storage; per-layer buffers live in the units.
pub struct Fleet {
    /// Flattened task table: task id → owning layer.
    task_layer: Vec<u32>,
    /// Per-layer task id range: layer `l` owns `offsets[l]..offsets[l+1]`.
    offsets: Vec<usize>,
    /// Per-task pending-dependency counters (chain edges today: stage s
    /// waits on stage s−1; the counters generalize to richer graphs).
    pending: Vec<AtomicU32>,
    /// Initially-ready task ids (stage 0 of every non-empty layer).
    seeds: Vec<usize>,
    /// Task id → *dense* group index, for fair-share dispatch
    /// ([`Fleet::run_fair`]). Session ids are monotonic and never
    /// reused, so they are compacted to `0..n_groups` per dispatch —
    /// the scheduler's group table is sized by the max group id, and a
    /// long-lived daemon must not grow it with every admit/evict cycle.
    task_group: Vec<u32>,
    /// Dense group index → real session id (first-appearance order over
    /// the units), for span attribution and panic descriptions.
    group_ids: Vec<u32>,
    /// Scratch: per-dense-group outcomes from the isolated dispatch.
    outcomes: Vec<pool::GroupOutcome>,
    /// Reused per-session outcome storage returned by [`Fleet::run_fair`].
    sess_outcomes: Vec<SessionOutcome>,
}

/// Per-session result of a fair-share fleet dispatch
/// ([`Fleet::run_fair`]).
#[derive(Clone, Debug, PartialEq)]
pub struct SessionOutcome {
    /// Real session id ([`FleetUnit::session`]).
    pub session: u32,
    /// `None` when every stage of the session's units completed;
    /// otherwise the first failing unit/stage label plus the panic
    /// payload. A failed session's remaining stages were cancelled; its
    /// units' buffers must be treated as indeterminate by the caller.
    pub failed: Option<String>,
}

impl Default for Fleet {
    fn default() -> Fleet {
        Fleet::new()
    }
}

impl Fleet {
    pub fn new() -> Fleet {
        Fleet {
            task_layer: Vec::new(),
            offsets: Vec::new(),
            pending: Vec::new(),
            seeds: Vec::new(),
            task_group: Vec::new(),
            group_ids: Vec::new(),
            outcomes: Vec::new(),
            sess_outcomes: Vec::new(),
        }
    }

    /// Execute one step of every unit as a single pool dispatch.
    ///
    /// `workers <= 1` runs the whole fleet inline (layer by layer, stage
    /// by stage — the same task order the scheduler's one-worker drain
    /// produces) with zero allocations; results are identical either way.
    pub fn run(&mut self, units: &mut [&mut dyn FleetUnit], workers: usize) {
        if units.is_empty() {
            return;
        }
        if workers <= 1 {
            let _run = obs::span_args(obs::Category::Fleet, "fleet_run",
                                      [units.len() as u32, 0, 1]);
            super::with_workers(1, || {
                for (li, u) in units.iter_mut().enumerate() {
                    for s in 0..u.n_stages() {
                        {
                            let _sp = obs::span_args(
                                obs::Category::Fleet, "stage",
                                [li as u32, s as u32, 0]);
                            u.run_stage(s);
                        }
                        obs::counter_add(obs::Counter::FleetStages, 1);
                    }
                }
            });
            return;
        }
        // Flatten the per-layer stage chains into the task table.
        let n_layers = units.len();
        self.task_layer.clear();
        self.offsets.clear();
        self.seeds.clear();
        self.offsets.push(0);
        for (li, u) in units.iter().enumerate() {
            let n = u.n_stages();
            if n > 0 {
                self.seeds.push(self.task_layer.len());
            }
            for _ in 0..n {
                self.task_layer.push(li as u32);
            }
            self.offsets.push(self.task_layer.len());
        }
        let total = self.task_layer.len();
        if total == 0 {
            return;
        }
        self.pending.clear();
        self.pending.extend((0..total).map(|_| AtomicU32::new(1)));
        for li in 0..n_layers {
            if self.offsets[li] < self.offsets[li + 1] {
                self.pending[self.offsets[li]].store(0, Ordering::Relaxed);
            }
        }
        // A unit's stages form a chain, so at most one of its tasks is
        // ever ready: the per-layer lock is never contended — it only
        // turns the shared slot borrow into exclusive stage access.
        let slots: Vec<Mutex<&mut dyn FleetUnit>> =
            units.iter_mut().map(|u| Mutex::new(&mut **u)).collect();
        let task_layer = &self.task_layer;
        let offsets = &self.offsets;
        let pending = &self.pending;
        let _run = obs::span_args(
            obs::Category::Fleet, "fleet_run",
            [n_layers as u32, total as u32, workers as u32]);
        pool::run_task_graph_described(
            total,
            &self.seeds,
            workers,
            |t, ready| {
                let li = task_layer[t] as usize;
                let stage = t - offsets[li];
                {
                    let mut unit = match slots[li].lock() {
                        Ok(g) => g,
                        Err(p) => {
                            logging::warn(
                                "fleet: unit lock poisoned by a panicked \
                                 stage");
                            p.into_inner()
                        }
                    };
                    let _sp = obs::span_args(obs::Category::Fleet, "stage",
                                             [li as u32, stage as u32, 0]);
                    super::with_workers(1, || unit.run_stage(stage));
                }
                obs::counter_add(obs::Counter::FleetStages, 1);
                let next = t + 1;
                if next < offsets[li + 1]
                    && pending[next].fetch_sub(1, Ordering::AcqRel) == 1
                {
                    ready(next);
                }
            },
            |t| {
                let li = task_layer[t] as usize;
                format!("fleet unit {li} stage {}", t - offsets[li])
            },
        );
    }

    /// [`Fleet::run`] with **fair-share ready ordering across sessions**
    /// ([`FleetUnit::session`]): the flattened task graph drains through
    /// `pool::run_task_graph_fair`, which round-robins ready stages
    /// across session groups so a tenant contributing many layers cannot
    /// starve one contributing few (the serve daemon's multiplexing
    /// contract, DESIGN.md §14).
    ///
    /// Scheduling order is the only difference from [`Fleet::run`] on
    /// the happy path: units stay independent and each unit's chain
    /// still runs strictly in stage order, so results are bit-identical
    /// to `run` — and to the inline `workers <= 1` loop (fairness is
    /// moot on one thread; every session's tick completes within the
    /// dispatch either way). Stage spans carry the owning session in
    /// their third argument slot.
    ///
    /// Unlike [`Fleet::run`], a stage panic is *contained to its
    /// session*: the session's remaining stages are cancelled, every
    /// other session drains to completion bit-identically to a dispatch
    /// where the failed session's units were never present, and the
    /// returned per-session outcomes (one entry per distinct session,
    /// first-appearance order; storage reused across calls) report
    /// which sessions failed and why instead of resuming the unwind.
    pub fn run_fair<'a>(&'a mut self,
                        units: &mut [&mut dyn FleetUnit],
                        workers: usize) -> &'a [SessionOutcome] {
        if units.is_empty() {
            self.sess_outcomes.clear();
            return &self.sess_outcomes;
        }
        if workers <= 1 {
            let _run = obs::span_args(obs::Category::Fleet, "fleet_run",
                                      [units.len() as u32, 0, 1]);
            self.sess_outcomes.clear();
            let sess_outcomes = &mut self.sess_outcomes;
            super::with_workers(1, || {
                for (li, u) in units.iter_mut().enumerate() {
                    let sess = u.session();
                    let oi = match sess_outcomes.iter()
                        .position(|o| o.session == sess)
                    {
                        Some(i) => i,
                        None => {
                            sess_outcomes.push(SessionOutcome {
                                session: sess,
                                failed: None,
                            });
                            sess_outcomes.len() - 1
                        }
                    };
                    if sess_outcomes[oi].failed.is_some() {
                        // An earlier unit of this session panicked:
                        // cancel the session's remaining units, exactly
                        // like the dispatched path cancels its
                        // not-yet-started tasks.
                        continue;
                    }
                    for s in 0..u.n_stages() {
                        let run = {
                            let _sp = obs::span_args(
                                obs::Category::Fleet, "stage",
                                [li as u32, s as u32, sess]);
                            std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(
                                    || u.run_stage(s)))
                        };
                        if let Err(payload) = run {
                            let msg = pool::panic_payload_msg(
                                payload.as_ref());
                            logging::warn(format!(
                                "fleet: session {sess} unit {li} stage \
                                 {s} panicked ({msg}); cancelling \
                                 session, others continue"));
                            sess_outcomes[oi].failed = Some(format!(
                                "fleet unit {li} stage {s}: {msg}"));
                            break;
                        }
                        obs::counter_add(obs::Counter::FleetStages, 1);
                    }
                }
            });
            return &self.sess_outcomes;
        }
        // Flatten the per-layer stage chains, tagging each task with its
        // unit's session group — compacted to dense indices by first
        // appearance (serve session ids grow forever; sizing the fair
        // scheduler's group table by the raw max id would degrade a
        // long-lived daemon without bound). Determinism is preserved:
        // the mapping is a pure function of the unit order.
        let n_layers = units.len();
        self.task_layer.clear();
        self.task_group.clear();
        self.group_ids.clear();
        self.offsets.clear();
        self.seeds.clear();
        self.offsets.push(0);
        for (li, u) in units.iter().enumerate() {
            let n = u.n_stages();
            let sess = u.session();
            let dense = match self.group_ids.iter()
                .position(|&g| g == sess)
            {
                Some(d) => d as u32,
                None => {
                    self.group_ids.push(sess);
                    (self.group_ids.len() - 1) as u32
                }
            };
            if n > 0 {
                self.seeds.push(self.task_layer.len());
            }
            for _ in 0..n {
                self.task_layer.push(li as u32);
                self.task_group.push(dense);
            }
            self.offsets.push(self.task_layer.len());
        }
        let total = self.task_layer.len();
        if total == 0 {
            // Every unit was empty: report each distinct session as Ok.
            self.sess_outcomes.clear();
            for &sess in &self.group_ids {
                self.sess_outcomes.push(SessionOutcome {
                    session: sess,
                    failed: None,
                });
            }
            return &self.sess_outcomes;
        }
        self.pending.clear();
        self.pending.extend((0..total).map(|_| AtomicU32::new(1)));
        for li in 0..n_layers {
            if self.offsets[li] < self.offsets[li + 1] {
                self.pending[self.offsets[li]].store(0, Ordering::Relaxed);
            }
        }
        let slots: Vec<Mutex<&mut dyn FleetUnit>> =
            units.iter_mut().map(|u| Mutex::new(&mut **u)).collect();
        let task_layer = &self.task_layer;
        let task_group = &self.task_group;
        let group_ids = &self.group_ids;
        let offsets = &self.offsets;
        let pending = &self.pending;
        let _run = obs::span_args(
            obs::Category::Fleet, "fleet_run",
            [n_layers as u32, total as u32, workers as u32]);
        pool::run_task_graph_fair_isolated(
            total,
            &self.seeds,
            workers,
            task_group,
            |t, ready| {
                let li = task_layer[t] as usize;
                let stage = t - offsets[li];
                {
                    let mut unit = match slots[li].lock() {
                        Ok(g) => g,
                        Err(p) => {
                            logging::warn(
                                "fleet: unit lock poisoned by a panicked \
                                 stage");
                            p.into_inner()
                        }
                    };
                    let _sp = obs::span_args(
                        obs::Category::Fleet, "stage",
                        [li as u32, stage as u32,
                         group_ids[task_group[t] as usize]]);
                    super::with_workers(1, || unit.run_stage(stage));
                }
                obs::counter_add(obs::Counter::FleetStages, 1);
                let next = t + 1;
                if next < offsets[li + 1]
                    && pending[next].fetch_sub(1, Ordering::AcqRel) == 1
                {
                    ready(next);
                }
            },
            |t| {
                let li = task_layer[t] as usize;
                format!("session {} fleet unit {li} stage {}",
                        group_ids[task_group[t] as usize], t - offsets[li])
            },
            &mut self.outcomes,
        );
        // Map dense group outcomes back to real session ids; move the
        // failure strings out of the scratch vector instead of cloning.
        self.sess_outcomes.clear();
        for (dense, oc) in self.outcomes.iter_mut().enumerate() {
            let failed = match std::mem::replace(oc, pool::GroupOutcome::Ok)
            {
                pool::GroupOutcome::Ok => None,
                pool::GroupOutcome::Failed { task, msg } => {
                    let li = self.task_layer[task] as usize;
                    let stage = task - self.offsets[li];
                    Some(format!("fleet unit {li} stage {stage}: {msg}"))
                }
            };
            self.sess_outcomes.push(SessionOutcome {
                session: self.group_ids[dense],
                failed,
            });
        }
        &self.sess_outcomes
    }

    /// Execute one *replicated* step — R per-replica gradient
    /// accumulation chains per layer, that layer's tree-reduce chain,
    /// then its optimizer step chain — as a single pool dispatch.
    ///
    /// The reduce stages are first-class task-graph nodes: a layer's
    /// reduce chain head carries one pending edge per non-empty
    /// accumulation chain, and its tail feeds the step chain head, so
    /// accumulation chains of *all* replicas and layers interleave
    /// freely while every layer's math keeps the fixed order
    /// accum → reduce → step. With `workers <= 1` the whole graph runs
    /// inline (replicas in index order — bit-identical by lane
    /// disjointness, see `fusion::reduce`) with zero allocations.
    pub fn run_replicated(&mut self, sets: &mut [ReplicaSet],
                          workers: usize) {
        if sets.is_empty() {
            return;
        }
        if workers <= 1 {
            let _run = obs::span_args(obs::Category::Fleet, "fleet_run",
                                      [sets.len() as u32, 0, 1]);
            super::with_workers(1, || {
                for (li, set) in sets.iter_mut().enumerate() {
                    for u in set.accum.iter_mut() {
                        let rep = u.replica();
                        for s in 0..u.n_stages() {
                            {
                                let _sp = obs::span_args(
                                    obs::Category::Fleet, "stage",
                                    [li as u32, s as u32, rep]);
                                u.run_stage(s);
                            }
                            obs::counter_add(obs::Counter::FleetStages, 1);
                        }
                    }
                    for s in 0..set.reduce.n_stages() {
                        {
                            let _sp = obs::span_args(
                                obs::Category::Fleet, "reduce_stage",
                                [li as u32, s as u32, 0]);
                            set.reduce.run_stage(s);
                        }
                        obs::counter_add(obs::Counter::FleetStages, 1);
                    }
                    for s in 0..set.step.n_stages() {
                        {
                            let _sp = obs::span_args(
                                obs::Category::Fleet, "stage",
                                [li as u32, s as u32, 0]);
                            set.step.run_stage(s);
                        }
                        obs::counter_add(obs::Counter::FleetStages, 1);
                    }
                }
            });
            return;
        }
        // Flatten every chain into one task table. Per task:
        // owning unit slot, stage, kind (accum/reduce/step), layer,
        // replica, single successor (u32::MAX = none) and fan-in.
        let mut slots: Vec<Mutex<&mut dyn FleetUnit>> = Vec::new();
        let mut t_slot: Vec<u32> = Vec::new();
        let mut t_stage: Vec<u32> = Vec::new();
        let mut t_succ: Vec<u32> = Vec::new();
        let mut t_kind: Vec<u8> = Vec::new();
        let mut t_set: Vec<u32> = Vec::new();
        let mut t_rep: Vec<u32> = Vec::new();
        let mut fanin: Vec<u32> = Vec::new();
        self.seeds.clear();
        for (si, set) in sets.iter_mut().enumerate() {
            let mut accum_tails: Vec<usize> = Vec::new();
            for u in set.accum.iter_mut() {
                let n = u.n_stages();
                let rep = u.replica();
                let slot = slots.len() as u32;
                slots.push(Mutex::new(&mut **u));
                if n == 0 {
                    continue;
                }
                self.seeds.push(t_slot.len());
                for s in 0..n {
                    t_slot.push(slot);
                    t_stage.push(s as u32);
                    t_kind.push(0);
                    t_set.push(si as u32);
                    t_rep.push(rep);
                    fanin.push(if s == 0 { 0 } else { 1 });
                    t_succ.push(t_slot.len() as u32); // provisional: next
                }
                accum_tails.push(t_slot.len() - 1);
            }
            let nr = set.reduce.n_stages();
            assert!(nr > 0, "reduce unit needs at least one stage");
            let r_slot = slots.len() as u32;
            slots.push(Mutex::new(&mut *set.reduce));
            let r_base = t_slot.len();
            for &tail in &accum_tails {
                t_succ[tail] = r_base as u32;
            }
            if accum_tails.is_empty() {
                self.seeds.push(r_base);
            }
            for s in 0..nr {
                t_slot.push(r_slot);
                t_stage.push(s as u32);
                t_kind.push(1);
                t_set.push(si as u32);
                t_rep.push(0);
                fanin.push(if s == 0 {
                    accum_tails.len() as u32
                } else {
                    1
                });
                t_succ.push(t_slot.len() as u32);
            }
            let ns = set.step.n_stages();
            assert!(ns > 0, "step unit needs at least one stage");
            let s_slot = slots.len() as u32;
            slots.push(Mutex::new(&mut *set.step));
            // The reduce tail's provisional successor already points at
            // the step chain head (tasks are pushed contiguously).
            for s in 0..ns {
                t_slot.push(s_slot);
                t_stage.push(s as u32);
                t_kind.push(2);
                t_set.push(si as u32);
                t_rep.push(0);
                fanin.push(1);
                t_succ.push(t_slot.len() as u32);
            }
            let tail = t_slot.len() - 1;
            t_succ[tail] = u32::MAX;
        }
        let total = t_slot.len();
        self.pending.clear();
        self.pending.extend(fanin.iter().map(|&c| AtomicU32::new(c)));
        let pending = &self.pending;
        let _run = obs::span_args(
            obs::Category::Fleet, "fleet_run",
            [sets.len() as u32, total as u32, workers as u32]);
        pool::run_task_graph_described(
            total,
            &self.seeds,
            workers,
            |t, ready| {
                let slot = t_slot[t] as usize;
                let stage = t_stage[t] as usize;
                {
                    let mut unit = match slots[slot].lock() {
                        Ok(g) => g,
                        Err(p) => {
                            logging::warn(
                                "fleet: unit lock poisoned by a panicked \
                                 stage");
                            p.into_inner()
                        }
                    };
                    let label = if t_kind[t] == 1 {
                        "reduce_stage"
                    } else {
                        "stage"
                    };
                    let _sp = obs::span_args(
                        obs::Category::Fleet, label,
                        [t_set[t], stage as u32, t_rep[t]]);
                    super::with_workers(1, || unit.run_stage(stage));
                }
                obs::counter_add(obs::Counter::FleetStages, 1);
                let succ = t_succ[t];
                if succ != u32::MAX
                    && pending[succ as usize]
                        .fetch_sub(1, Ordering::AcqRel) == 1
                {
                    ready(succ as usize);
                }
            },
            |t| {
                let kind = match t_kind[t] {
                    0 => "accum",
                    1 => "reduce",
                    _ => "step",
                };
                format!("layer {} {kind} replica {} stage {}",
                        t_set[t], t_rep[t], t_stage[t])
            },
        );
    }
}

/// One layer of a replicated fleet step: the per-replica gradient
/// accumulation chains, the fixed-topology tree-reduce chain that folds
/// their lanes, and the optimizer step chain consuming the reduced
/// gradient. All three act on the layer's lane set via
/// `fusion::reduce::LanePtr`; the task-graph edges built by
/// [`Fleet::run_replicated`] are what make the derived lane references
/// disjoint in time.
pub struct ReplicaSet<'a, 'b> {
    pub accum: &'a mut [&'b mut dyn FleetUnit],
    pub reduce: &'a mut dyn FleetUnit,
    pub step: &'a mut dyn FleetUnit,
}

/// Convenience: run a fleet once without keeping scheduler storage.
pub fn run_once(units: &mut [&mut dyn FleetUnit], workers: usize) {
    Fleet::new().run(units, workers);
}

/// [`FleetUnit`] over a compiled [`Plan`]: flattens the plan's fused
/// nodes into fleet stages, one node per stage, against caller bindings
/// and the unit's own workspace arena. Bindings are validated once, in
/// stage 0.
pub struct PlanUnit<'a, 'b> {
    plan: &'a Plan,
    ws: &'a mut Workspace,
    ins: &'a [&'b [f32]],
    exts: &'a mut [&'b mut [f32]],
    params: &'a [f32],
}

impl<'a, 'b> PlanUnit<'a, 'b> {
    pub fn new(plan: &'a Plan, ws: &'a mut Workspace, ins: &'a [&'b [f32]],
               exts: &'a mut [&'b mut [f32]], params: &'a [f32])
               -> PlanUnit<'a, 'b> {
        PlanUnit { plan, ws, ins, exts, params }
    }
}

impl FleetUnit for PlanUnit<'_, '_> {
    fn n_stages(&self) -> usize {
        self.plan.n_nodes()
    }

    fn run_stage(&mut self, stage: usize) {
        if stage == 0 {
            self.plan.check_bindings(self.ws, self.ins, self.exts,
                                     self.params);
        }
        self.plan.execute_node(stage, self.ws, self.ins, self.exts,
                               self.params, super::workers());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::{self, Graph, MatKind, SVal};
    use crate::linalg::Mat;
    use crate::util::rng::Rng;

    /// Records the order its stages ran in.
    struct LogUnit {
        stages: usize,
        log: Vec<usize>,
    }

    impl FleetUnit for LogUnit {
        fn n_stages(&self) -> usize {
            self.stages
        }

        fn run_stage(&mut self, stage: usize) {
            self.log.push(stage);
        }
    }

    #[test]
    fn all_stages_run_in_chain_order() {
        for workers in [1usize, 4] {
            let mut units: Vec<LogUnit> = (0..6)
                .map(|i| LogUnit { stages: 1 + i % 4, log: Vec::new() })
                .collect();
            {
                let mut refs: Vec<&mut dyn FleetUnit> = units
                    .iter_mut()
                    .map(|u| u as &mut dyn FleetUnit)
                    .collect();
                let mut fleet = Fleet::new();
                fleet.run(&mut refs, workers);
                // A second run through the same Fleet reuses storage.
                fleet.run(&mut refs, workers);
            }
            for (i, u) in units.iter().enumerate() {
                let want: Vec<usize> =
                    (0..u.stages).chain(0..u.stages).collect();
                assert_eq!(u.log, want, "w={workers} unit {i}");
            }
        }
    }

    /// Stamps a global clock at every stage — lets tests assert
    /// cross-unit ordering (accum → reduce → step) under the
    /// replicated scheduler.
    struct ClockUnit<'c> {
        stages: usize,
        rep: u32,
        clock: &'c AtomicU32,
        stamps: Vec<u32>,
    }

    impl FleetUnit for ClockUnit<'_> {
        fn n_stages(&self) -> usize {
            self.stages
        }

        fn run_stage(&mut self, stage: usize) {
            assert_eq!(stage, self.stamps.len(), "stage order violated");
            self.stamps.push(self.clock.fetch_add(1, Ordering::SeqCst));
        }

        fn replica(&self) -> u32 {
            self.rep
        }
    }

    #[test]
    fn replicated_graph_orders_accum_reduce_step() {
        for workers in [1usize, 4] {
            let clock = AtomicU32::new(0);
            let mk = |stages, rep| ClockUnit {
                stages,
                rep,
                clock: &clock,
                stamps: Vec::new(),
            };
            let mut a00 = mk(2, 0);
            let mut a01 = mk(3, 1);
            let mut r0 = mk(2, 0);
            let mut s0 = mk(2, 0);
            let mut a10 = mk(1, 0);
            let mut r1 = mk(1, 0);
            let mut s1 = mk(3, 0);
            {
                let mut acc0: [&mut dyn FleetUnit; 2] =
                    [&mut a00, &mut a01];
                let mut acc1: [&mut dyn FleetUnit; 1] = [&mut a10];
                let mut sets = [
                    ReplicaSet {
                        accum: &mut acc0,
                        reduce: &mut r0,
                        step: &mut s0,
                    },
                    ReplicaSet {
                        accum: &mut acc1,
                        reduce: &mut r1,
                        step: &mut s1,
                    },
                ];
                Fleet::new().run_replicated(&mut sets, workers);
            }
            for (accs, red, st) in
                [(vec![&a00, &a01], &r0, &s0), (vec![&a10], &r1, &s1)]
            {
                let acc_max = accs
                    .iter()
                    .flat_map(|u| u.stamps.iter())
                    .max()
                    .copied()
                    .unwrap();
                assert_eq!(red.stamps.len(), red.stages);
                assert_eq!(st.stamps.len(), st.stages);
                assert!(acc_max < red.stamps[0],
                        "w={workers}: reduce ran before accum finished");
                assert!(red.stamps[red.stamps.len() - 1] < st.stamps[0],
                        "w={workers}: step ran before reduce finished");
            }
            for u in [&a00, &a01, &a10] {
                assert_eq!(u.stamps.len(), u.stages, "w={workers}");
            }
        }
    }

    #[test]
    fn replicated_graph_with_empty_accum_chains() {
        // A layer whose replicas had no micro-batches this step: reduce
        // becomes the seed and the chain still runs reduce → step.
        for workers in [1usize, 4] {
            let clock = AtomicU32::new(0);
            let mut a = ClockUnit {
                stages: 0,
                rep: 0,
                clock: &clock,
                stamps: Vec::new(),
            };
            let mut r = ClockUnit {
                stages: 1,
                rep: 0,
                clock: &clock,
                stamps: Vec::new(),
            };
            let mut s = ClockUnit {
                stages: 2,
                rep: 0,
                clock: &clock,
                stamps: Vec::new(),
            };
            {
                let mut acc: [&mut dyn FleetUnit; 1] = [&mut a];
                let mut sets = [ReplicaSet {
                    accum: &mut acc,
                    reduce: &mut r,
                    step: &mut s,
                }];
                Fleet::new().run_replicated(&mut sets, workers);
            }
            assert!(a.stamps.is_empty());
            assert_eq!(r.stamps.len(), 1, "w={workers}");
            assert_eq!(s.stamps.len(), 2, "w={workers}");
            assert!(r.stamps[0] < s.stamps[0]);
        }
    }

    /// [`LogUnit`] with a session tag — exercises fair-share grouping.
    struct SessLogUnit {
        stages: usize,
        sess: u32,
        log: Vec<usize>,
    }

    impl FleetUnit for SessLogUnit {
        fn n_stages(&self) -> usize {
            self.stages
        }

        fn run_stage(&mut self, stage: usize) {
            self.log.push(stage);
        }

        fn session(&self) -> u32 {
            self.sess
        }
    }

    #[test]
    fn fair_run_executes_every_chain_in_order() {
        // Three sessions with unequal layer counts; every unit's chain
        // must still run strictly in stage order, twice (storage reuse),
        // at both dispatch modes.
        for workers in [1usize, 4] {
            let mut units: Vec<SessLogUnit> = (0..7)
                .map(|i| SessLogUnit {
                    stages: 1 + i % 3,
                    sess: (i % 3) as u32,
                    log: Vec::new(),
                })
                .collect();
            {
                let mut refs: Vec<&mut dyn FleetUnit> = units
                    .iter_mut()
                    .map(|u| u as &mut dyn FleetUnit)
                    .collect();
                let mut fleet = Fleet::new();
                fleet.run_fair(&mut refs, workers);
                fleet.run_fair(&mut refs, workers);
            }
            for (i, u) in units.iter().enumerate() {
                let want: Vec<usize> =
                    (0..u.stages).chain(0..u.stages).collect();
                assert_eq!(u.log, want, "w={workers} unit {i}");
            }
        }
    }

    /// [`SessLogUnit`] that panics at one stage — fault-isolation probe.
    struct FaultySessUnit {
        stages: usize,
        sess: u32,
        panic_at: Option<usize>,
        log: Vec<usize>,
    }

    impl FleetUnit for FaultySessUnit {
        fn n_stages(&self) -> usize {
            self.stages
        }

        fn run_stage(&mut self, stage: usize) {
            self.log.push(stage);
            if self.panic_at == Some(stage) {
                panic!("unit for session {} exploded", self.sess);
            }
        }

        fn session(&self) -> u32 {
            self.sess
        }
    }

    #[test]
    fn fair_run_isolates_a_panicking_session() {
        // Session 1's second unit panics at stage 1; sessions 0 and 2
        // must run every stage of every unit, session 1's remaining
        // stages are cancelled, and the outcome names the failure. Both
        // dispatch modes.
        for workers in [1usize, 4] {
            let mut units: Vec<FaultySessUnit> = (0..6)
                .map(|i| FaultySessUnit {
                    stages: 3,
                    sess: (i % 3) as u32,
                    panic_at: if i == 4 { Some(1) } else { None },
                    log: Vec::new(),
                })
                .collect();
            let mut fleet = Fleet::new();
            let outcomes: Vec<SessionOutcome> = {
                let mut refs: Vec<&mut dyn FleetUnit> = units
                    .iter_mut()
                    .map(|u| u as &mut dyn FleetUnit)
                    .collect();
                fleet.run_fair(&mut refs, workers).to_vec()
            };
            assert_eq!(outcomes.len(), 3, "w={workers}");
            for oc in &outcomes {
                if oc.session == 1 {
                    let msg = oc.failed.as_ref().unwrap_or_else(|| {
                        panic!("w={workers}: session 1 should fail")
                    });
                    assert!(msg.contains("unit 4 stage 1"),
                            "w={workers}: {msg}");
                    assert!(msg.contains("exploded"), "w={workers}");
                } else {
                    assert!(oc.failed.is_none(),
                            "w={workers} session {}", oc.session);
                }
            }
            for (i, u) in units.iter().enumerate() {
                if u.sess != 1 {
                    assert_eq!(u.log, vec![0, 1, 2], "w={workers} unit {i}");
                } else if i == 4 {
                    // Ran up to and including the panicking stage.
                    assert_eq!(u.log, vec![0, 1], "w={workers}");
                }
                // Unit 1 (session 1, before the faulty unit) may or may
                // not have completed depending on dispatch interleaving;
                // its stages that did run are in order by construction.
            }
            // A subsequent dispatch with only the survivors still works
            // (scratch state fully reset).
            let mut survivors: Vec<FaultySessUnit> = (0..2)
                .map(|i| FaultySessUnit {
                    stages: 2,
                    sess: i as u32,
                    panic_at: None,
                    log: Vec::new(),
                })
                .collect();
            let mut refs: Vec<&mut dyn FleetUnit> = survivors
                .iter_mut()
                .map(|u| u as &mut dyn FleetUnit)
                .collect();
            let ok = fleet.run_fair(&mut refs, workers);
            assert!(ok.iter().all(|o| o.failed.is_none()), "w={workers}");
        }
    }

    #[test]
    fn fair_run_outcomes_cover_all_sessions_when_healthy() {
        for workers in [1usize, 4] {
            let mut units: Vec<SessLogUnit> = (0..5)
                .map(|i| SessLogUnit {
                    stages: 1 + i % 2,
                    sess: (i % 2) as u32,
                    log: Vec::new(),
                })
                .collect();
            let mut refs: Vec<&mut dyn FleetUnit> = units
                .iter_mut()
                .map(|u| u as &mut dyn FleetUnit)
                .collect();
            let mut fleet = Fleet::new();
            let outcomes = fleet.run_fair(&mut refs, workers);
            assert_eq!(outcomes.len(), 2, "w={workers}");
            assert!(outcomes.iter().all(|o| o.failed.is_none()));
            assert_eq!(outcomes[0].session, 0);
            assert_eq!(outcomes[1].session, 1);
        }
    }

    #[test]
    fn fair_run_compacts_sparse_session_ids() {
        // A long-lived daemon hands out monotonic session ids; after many
        // admit/evict cycles the live ids are huge and sparse. The fair
        // dispatch must compact them to dense group indices — sizing the
        // scheduler's group table by the raw max id (here ~3 billion)
        // would OOM. The chains must still run in order.
        for workers in [1usize, 4] {
            let mut units: Vec<SessLogUnit> = [
                3_000_000_000u32, 7, 3_000_000_000, 1_999_999, 7,
            ]
            .iter()
            .enumerate()
            .map(|(i, &sess)| SessLogUnit {
                stages: 1 + i % 3,
                sess,
                log: Vec::new(),
            })
            .collect();
            {
                let mut refs: Vec<&mut dyn FleetUnit> = units
                    .iter_mut()
                    .map(|u| u as &mut dyn FleetUnit)
                    .collect();
                Fleet::new().run_fair(&mut refs, workers);
            }
            for (i, u) in units.iter().enumerate() {
                let want: Vec<usize> = (0..u.stages).collect();
                assert_eq!(u.log, want, "w={workers} unit {i}");
            }
        }
    }

    #[test]
    fn empty_and_zero_stage_units_are_fine() {
        let mut fleet = Fleet::new();
        fleet.run(&mut [], 4);
        let mut a = LogUnit { stages: 0, log: Vec::new() };
        let mut b = LogUnit { stages: 2, log: Vec::new() };
        let mut refs: Vec<&mut dyn FleetUnit> = vec![&mut a, &mut b];
        fleet.run(&mut refs, 4);
        assert!(a.log.is_empty());
        assert_eq!(b.log, vec![0, 1]);
    }

    fn tiny_step_graph(m: usize, n: usize, r: usize) -> Graph {
        // W ← W − η·Q·gr with a momentum fold — a GaLore-shaped layer.
        let mut g = Graph::new();
        let gr = g.input(r, n);
        let q = g.input(m, r);
        let m1 = g.ext(r, n);
        let w = g.ext(m, n);
        let p_eta = g.param();
        let t_full = g.temp(m, n);
        g.axpy(m1, SVal::Lit(0.9), m1, SVal::Lit(0.1), gr);
        g.matmul(MatKind::NN, q, m1, t_full, SVal::Lit(1.0), SVal::Lit(0.0));
        g.axpy(w, SVal::Lit(1.0), w, p_eta, t_full);
        g
    }

    #[test]
    fn plan_units_match_serial_execute_bitwise() {
        let mut rng = Rng::new(5);
        let shapes = [(24usize, 18usize, 4usize), (40, 12, 6), (16, 30, 2)];
        let graphs: Vec<Graph> =
            shapes.iter().map(|&(m, n, r)| tiny_step_graph(m, n, r)).collect();
        let plans: Vec<_> = graphs.iter().map(fusion::compile).collect();
        // Layer buffers, duplicated for the serial baseline.
        let mk = |rng: &mut Rng| {
            shapes
                .iter()
                .map(|&(m, n, r)| {
                    (
                        Mat::randn(rng, r, n, 1.0), // gr
                        Mat::randn(rng, m, r, 1.0), // q
                        Mat::zeros(r, n),           // m1
                        Mat::randn(rng, m, n, 1.0), // w
                    )
                })
                .collect::<Vec<_>>()
        };
        let mut rng2 = Rng::new(5);
        let mut fleet_bufs = mk(&mut rng);
        let mut serial_bufs = mk(&mut rng2);
        let params = [-0.01f32];
        // Serial baseline: one plan at a time.
        for (plan, (gr, q, m1, w)) in plans.iter().zip(&mut serial_bufs) {
            let mut ws = plan.workspace();
            let ins = [&gr.data[..], &q.data[..]];
            let mut exts = [&mut m1.data[..], &mut w.data[..]];
            for _ in 0..3 {
                plan.execute(&mut ws, &ins, &mut exts, &params, 2);
            }
        }
        // Fleet: all layers in one dispatch per step. Binding tables and
        // units persist across steps — only the layer state evolves.
        let mut wss: Vec<_> = plans.iter().map(|p| p.workspace()).collect();
        {
            let mut tables: Vec<(Vec<&[f32]>, Vec<&mut [f32]>)> = fleet_bufs
                .iter_mut()
                .map(|(gr, q, m1, w)| {
                    let ins: Vec<&[f32]> = vec![&gr.data, &q.data];
                    let exts: Vec<&mut [f32]> =
                        vec![&mut m1.data, &mut w.data];
                    (ins, exts)
                })
                .collect();
            let mut units: Vec<PlanUnit> = plans
                .iter()
                .zip(&mut wss)
                .zip(&mut tables)
                .map(|((plan, ws), (ins, exts))| {
                    PlanUnit::new(plan, ws, ins, exts, &params)
                })
                .collect();
            let mut fleet = Fleet::new();
            for _ in 0..3 {
                let mut refs: Vec<&mut dyn FleetUnit> = units
                    .iter_mut()
                    .map(|u| u as &mut dyn FleetUnit)
                    .collect();
                fleet.run(&mut refs, 4);
            }
        }
        for ((_, _, m1_f, w_f), (_, _, m1_s, w_s)) in
            fleet_bufs.iter().zip(&serial_bufs)
        {
            assert_eq!(m1_f.data, m1_s.data);
            assert_eq!(w_f.data, w_s.data);
        }
    }
}

//! Fused low-rank update executor — op-graph fusion + parallel blocked
//! kernels for the native optimizer path (see DESIGN.md §8).
//!
//! The UMF hot loop (tangent projections → QR → 2r×2r core → spectral
//! update) and its baseline cousins (GaLore's projected moment update,
//! Muon's Newton–Schulz iteration) all reduce to the same kernel shapes:
//! G·V, Uᵀ·G, A·Bᵀ, rank-r weight updates, and short elementwise chains.
//! This subsystem provides one fast path for all of them:
//!
//! * [`ir`] — a tiny op IR over buffer ids (matmul anchors in all three
//!   transpose variants + elementwise axpy/scale/mul/map/zip), with a
//!   naive `Mat` reference interpreter for property testing;
//! * [`builder`] — an `OptimizationBuilder`-style greedy fuser that closes
//!   a plan at each matmul anchor and fuses trailing elementwise ops into
//!   the matmul epilogue (or its alpha/beta), collapsing elementwise runs
//!   into single-pass chains;
//! * [`kernels`] — cache-blocked, multi-threaded GEMM kernels (NN/TN/NT)
//!   with fused epilogues, safe row-chunk parallelism, and sequential
//!   fallback below a flop threshold;
//! * [`autotune`] / [`simd`] — per-shape-class micro-kernel selection
//!   over a registry of scalar and explicit 8-wide variants
//!   (`kernels::KernelVariant`), winners cached in a persistent
//!   per-host table;
//! * [`plan`] / [`exec`] — compiled plans executing against a workspace
//!   arena: steady-state optimizer steps perform zero heap allocations.
//!
//! Direct kernel entry points ([`gemm_into`], [`gemm_add_into`]) serve hot
//! paths whose surrounding control flow (QR, Jacobi sweeps) cannot live in
//! a static graph; full graphs + plans serve straight-line steps like
//! GaLore's (see `optim::galore`).

pub mod autotune;
pub mod builder;
pub mod exec;
pub mod fleet;
pub mod ir;
pub mod kernels;
pub mod plan;
pub mod reduce;
pub mod simd;

pub use builder::compile;
pub use fleet::{Fleet, FleetUnit, ReplicaSet, SessionOutcome};
pub use ir::{BufId, Graph, MatKind, SVal};
pub use plan::{Plan, Workspace};

use crate::linalg::Mat;
use crate::obs;
use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

static WORKERS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Per-thread worker override (0 = none). The fleet executor pins
    /// every stage it runs to one thread this way: stages already execute
    /// concurrently across layers, and nested per-kernel fork-join would
    /// oversubscribe the machine.
    static TL_WORKERS: Cell<usize> = Cell::new(0);
}

/// Override the worker-thread cap for all fused kernels (0 = auto).
pub fn set_workers(n: usize) {
    WORKERS.store(n, Ordering::SeqCst);
}

/// Run `f` with this thread's kernel worker cap pinned to `n` (restored
/// on exit, panic-safe). Takes precedence over [`set_workers`] and the
/// environment for every [`workers`] call made from inside `f` on this
/// thread.
pub fn with_workers<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            TL_WORKERS.with(|c| c.set(self.0));
        }
    }
    let _guard = Restore(TL_WORKERS.with(|c| {
        let prev = c.get();
        c.set(n);
        prev
    }));
    f()
}

/// Worker threads used by the fused kernels: thread-local override
/// ([`with_workers`]), else explicit global override, else
/// `MOFA_WORKERS`, else available parallelism.
pub fn workers() -> usize {
    let tl = TL_WORKERS.with(|c| c.get());
    if tl != 0 {
        return tl;
    }
    let w = WORKERS.load(Ordering::SeqCst);
    if w != 0 {
        return w;
    }
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("MOFA_WORKERS")
            .ok()
            .and_then(|s| s.parse().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(crate::util::pool::default_workers)
    })
}

fn gemm_dims(kind: MatKind, a: &Mat, b: &Mat, out: &Mat)
             -> (usize, usize, usize) {
    let (m, n, k) = match kind {
        MatKind::NN => {
            assert_eq!(a.cols, b.rows, "NN shape mismatch");
            (a.rows, b.cols, a.cols)
        }
        MatKind::TN => {
            assert_eq!(a.rows, b.rows, "TN shape mismatch");
            (a.cols, b.cols, a.rows)
        }
        MatKind::NT => {
            assert_eq!(a.cols, b.cols, "NT shape mismatch");
            (a.rows, b.rows, a.cols)
        }
    };
    assert_eq!((out.rows, out.cols), (m, n), "gemm out shape mismatch");
    (m, n, k)
}

/// Kernel-level GEMM span + FLOP/byte counters, shared by the direct
/// entry points below and the plan executor's GEMM nodes — every GEMM
/// in the system is attributed the same way in a trace.
pub(crate) fn gemm_obs_span(kind: MatKind, m: usize, n: usize, k: usize)
                            -> obs::SpanGuard {
    if !obs::enabled() {
        return obs::SpanGuard::off();
    }
    obs::counter_add(obs::Counter::Flops, (2 * m * n * k) as u64);
    obs::counter_add(obs::Counter::Bytes,
                     (4 * (m * k + k * n + m * n)) as u64);
    let label = match kind {
        MatKind::NN => "gemm_nn",
        MatKind::TN => "gemm_tn",
        MatKind::NT => "gemm_nt",
    };
    obs::span_args(obs::Category::Plan, label,
                   [m as u32, n as u32, k as u32])
}

/// `out = alpha·op(a)·op(b) + beta·out` through the parallel blocked
/// kernels (worker count from [`workers`]). Allocation-free.
pub fn gemm_into(kind: MatKind, a: &Mat, b: &Mat, out: &mut Mat,
                 alpha: f32, beta: f32) {
    let (m, n, k) = gemm_dims(kind, a, b, out);
    let _sp = gemm_obs_span(kind, m, n, k);
    kernels::gemm(kind, m, n, k, &a.data, &b.data, alpha, beta,
                  &mut out.data, &[], workers());
}

/// `out = alpha·op(a)·op(b) + beta·out + s·src` with the extra addend
/// fused into the GEMM epilogue (no temporary). Allocation-free.
pub fn gemm_add_into(kind: MatKind, a: &Mat, b: &Mat, out: &mut Mat,
                     alpha: f32, beta: f32, s: f32, src: &Mat) {
    let (m, n, k) = gemm_dims(kind, a, b, out);
    assert_eq!(src.data.len(), out.data.len(), "epilogue src numel");
    let _sp = gemm_obs_span(kind, m, n, k);
    kernels::gemm(kind, m, n, k, &a.data, &b.data, alpha, beta,
                  &mut out.data, &[kernels::Epi::Add(s, &src.data)],
                  workers());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gemm_into_matches_mat() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(&mut rng, 17, 9, 1.0);
        let b = Mat::randn(&mut rng, 9, 13, 1.0);
        let mut out = Mat::zeros(17, 13);
        gemm_into(MatKind::NN, &a, &b, &mut out, 1.0, 0.0);
        assert!(out.rel_err(&a.matmul(&b)) < 1e-5);
    }

    #[test]
    fn gemm_add_into_fuses_update() {
        let mut rng = Rng::new(2);
        let u = Mat::randn(&mut rng, 20, 4, 1.0);
        let v = Mat::randn(&mut rng, 15, 4, 1.0);
        let w0 = Mat::randn(&mut rng, 20, 15, 1.0);
        // W ← W − η·U·Vᵀ, the Eq. 9 spectral update, no UVᵀ temporary.
        let mut w = w0.clone();
        gemm_into(MatKind::NT, &u, &v, &mut w, -0.1, 1.0);
        let want = w0.sub(&u.matmul_t(&v).scale(0.1));
        assert!(w.rel_err(&want) < 1e-5);
        // and the explicit-epilogue variant
        let mut w2 = Mat::zeros(20, 15);
        gemm_add_into(MatKind::NT, &u, &v, &mut w2, -0.1, 0.0, 1.0, &w0);
        assert!(w2.rel_err(&want) < 1e-5);
    }

    #[test]
    fn worker_resolution_positive() {
        assert!(workers() >= 1);
    }

    #[test]
    fn with_workers_overrides_then_restores() {
        let base = workers();
        let inner = with_workers(3, workers);
        assert_eq!(inner, 3);
        let nested = with_workers(2, || with_workers(5, workers));
        assert_eq!(nested, 5);
        assert_eq!(workers(), base);
    }

    #[test]
    fn end_to_end_plan_umf_accumulate_shape() {
        // The §5.5 accumulate pattern as a graph: three projections folded
        // into persistent buffers with beta = 1 — all three GEMMs keep
        // their accumulate form, no temps survive except the utg
        // staging buffer.
        let (m, n, r) = (24, 18, 4);
        let mut g = Graph::new();
        let grad = g.input(m, n);
        let u = g.input(m, r);
        let v = g.input(n, r);
        let gv = g.ext(m, r);
        let utg = g.ext(r, n);
        let utgv = g.ext(r, r);
        let t_utg = g.temp(r, n);
        g.matmul(MatKind::NN, grad, v, gv, SVal::Lit(1.0), SVal::Lit(1.0));
        g.matmul(MatKind::TN, u, grad, t_utg, SVal::Lit(1.0), SVal::Lit(0.0));
        g.axpy(utg, SVal::Lit(1.0), utg, SVal::Lit(1.0), t_utg);
        g.matmul(MatKind::NN, t_utg, v, utgv, SVal::Lit(1.0), SVal::Lit(1.0));

        let plan = compile(&g);
        let mut ws = plan.workspace();

        let mut rng = Rng::new(3);
        let gm = Mat::randn(&mut rng, m, n, 1.0);
        let um = Mat::randn(&mut rng, m, r, 1.0);
        let vm = Mat::randn(&mut rng, n, r, 1.0);
        let mut e_gv = Mat::randn(&mut rng, m, r, 0.5);
        let mut e_utg = Mat::randn(&mut rng, r, n, 0.5);
        let mut e_utgv = Mat::randn(&mut rng, r, r, 0.5);

        let mut want = [e_gv.clone(), e_utg.clone(), e_utgv.clone()];
        g.eval_naive(&[&gm, &um, &vm], &mut want, &[]);

        {
            let ins = [&gm.data[..], &um.data[..], &vm.data[..]];
            let mut exts = [&mut e_gv.data[..], &mut e_utg.data[..],
                            &mut e_utgv.data[..]];
            plan.execute(&mut ws, &ins, &mut exts, &[], 2);
        }
        assert!(e_gv.rel_err(&want[0]) < 1e-5);
        assert!(e_utg.rel_err(&want[1]) < 1e-5);
        assert!(e_utgv.rel_err(&want[2]) < 1e-5);
        // arena stays put across executions
        let sz = ws.floats();
        {
            let ins = [&gm.data[..], &um.data[..], &vm.data[..]];
            let mut exts = [&mut e_gv.data[..], &mut e_utg.data[..],
                            &mut e_utgv.data[..]];
            plan.execute(&mut ws, &ins, &mut exts, &[], 2);
        }
        assert_eq!(ws.floats(), sz);
    }
}

//! Shape-class GEMM autotuner: picks a micro-kernel variant per
//! (transpose anchor, bucketed m×n×k) class and caches the winners
//! (DESIGN.md §12).
//!
//! The UMF step hits a handful of recurring GEMM shape families — thin
//! m×r projections, square r×r core products, Gram/Newton–Schulz
//! squares — and no single blocking wins all of them. The tuner keeps a
//! registry of candidate kernels per anchor ([`KernelVariant`]), times
//! the candidates once per shape class, and serves every later dispatch
//! from a table:
//!
//! * **Shape classes.** Dims are bucketed to their pow2 ceiling, so
//!   `nn:64x8x512` covers every NN GEMM with m ∈ (32,64], n ∈ (4,8],
//!   k ∈ (256,512] — close enough in blocking behavior to share a
//!   winner, and coarse enough that a training run tunes a few classes,
//!   not thousands.
//! * **Measurement reuses the obs recorder.** Candidates run
//!   sequentially on the calling thread under per-variant `tune_*`
//!   spans, and the timings are read back with
//!   [`obs::local_spans_since`] — the same span machinery every traced
//!   GEMM already goes through, not a separate stopwatch path. Running
//!   on one thread keeps every tuning span on this thread's ring (the
//!   readback needs no cross-thread quiescence) and measures the
//!   kernel, not the fork-join.
//! * **Persistence.** Winners are written to a per-host JSON table
//!   (`$MOFA_AUTOTUNE_CACHE`, else `~/.cache/mofasgd/autotune.json`)
//!   via `util::json`; the next process loads it and skips measurement
//!   entirely. Stale or corrupt files are dropped with a warning, never
//!   an error: entries must name a variant that still exists in the
//!   registry *and* matches the key's anchor.
//! * **Steady state.** [`chosen`] is one atomic mode load; with tuning
//!   off it returns [`static_variant`] untouched (the historical
//!   kernel, bit-for-bit), and with tuning on a warm class is an
//!   RwLock read + BTreeMap lookup — no allocation — counted in
//!   `sched_cache_hits`. Plan-compiled graphs resolve their variant
//!   once at compile time ([`compile_choice`]) so executing a node
//!   doesn't even pay the lookup.
//!
//! Determinism is scoped per-variant (DESIGN.md §12): any fixed choice
//! is bit-identical across `MOFA_WORKERS`, so a tuned table changes
//! *which* rounding a class gets, never makes it worker-dependent. With
//! tuning off nothing changes at all.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock, RwLock};

use super::ir::MatKind;
use super::kernels::{self, static_variant, KernelVariant};
use crate::obs;
use crate::util::json::Json;
use crate::util::logging;

/// Autotuner mode, resolved once from `MOFA_AUTOTUNE` / `--autotune`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Static variants only — the pre-autotuner dispatch, bit-for-bit.
    Off,
    /// Tune on first touch per shape class; load + extend the
    /// persistent cache.
    On,
    /// Tune every class fresh this process, ignoring (and then
    /// overwriting) the persistent cache.
    Refresh,
}

impl Mode {
    pub fn name(self) -> &'static str {
        match self {
            Mode::Off => "off",
            Mode::On => "on",
            Mode::Refresh => "refresh",
        }
    }

    pub fn from_name(s: &str) -> Option<Mode> {
        match s {
            "off" => Some(Mode::Off),
            "on" | "1" => Some(Mode::On),
            "refresh" => Some(Mode::Refresh),
            _ => None,
        }
    }
}

const MODE_UNSET: u8 = 0;
const MODE_OFF: u8 = 1;
const MODE_ON: u8 = 2;
const MODE_REFRESH: u8 = 3;

static MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);

/// Current mode; the first call resolves `MOFA_AUTOTUNE` (unset/empty ⇒
/// off). One relaxed load afterwards — the only cost `Off` dispatch pays.
#[inline]
pub fn mode() -> Mode {
    match MODE.load(Ordering::Relaxed) {
        MODE_OFF => Mode::Off,
        MODE_ON => Mode::On,
        MODE_REFRESH => Mode::Refresh,
        _ => init_mode_from_env(),
    }
}

#[cold]
fn init_mode_from_env() -> Mode {
    let m = match std::env::var("MOFA_AUTOTUNE") {
        Ok(v) if !v.is_empty() => Mode::from_name(&v).unwrap_or_else(|| {
            logging::warn(format!(
                "autotune: unknown MOFA_AUTOTUNE value `{v}` — using off"
            ));
            Mode::Off
        }),
        _ => Mode::Off,
    };
    set_mode(m);
    m
}

/// Set the mode (CLI `--autotune` overrides the environment default).
pub fn set_mode(m: Mode) {
    let v = match m {
        Mode::Off => MODE_OFF,
        Mode::On => MODE_ON,
        Mode::Refresh => MODE_REFRESH,
    };
    MODE.store(v, Ordering::Relaxed);
}

// -- shape-class keys --------------------------------------------------------

fn clog2(x: usize) -> u64 {
    x.max(1).next_power_of_two().trailing_zeros() as u64
}

fn kind_tag(kind: MatKind) -> u64 {
    match kind {
        MatKind::NN => 0,
        MatKind::TN => 1,
        MatKind::NT => 2,
    }
}

fn kind_name(kind: MatKind) -> &'static str {
    match kind {
        MatKind::NN => "nn",
        MatKind::TN => "tn",
        MatKind::NT => "nt",
    }
}

fn kind_from_name(s: &str) -> Option<MatKind> {
    match s {
        "nn" => Some(MatKind::NN),
        "tn" => Some(MatKind::TN),
        "nt" => Some(MatKind::NT),
        _ => None,
    }
}

/// Shape-class key: anchor tag plus the ceil-log2 of each dim, packed.
pub fn shape_key(kind: MatKind, m: usize, n: usize, k: usize) -> u64 {
    (kind_tag(kind) << 48) | (clog2(m) << 32) | (clog2(n) << 16) | clog2(k)
}

/// Human-readable key for the persistent table: `"nn:64x8x512"`, dims
/// rounded up to their pow2 class ceiling.
pub fn key_string(kind: MatKind, m: usize, n: usize, k: usize) -> String {
    format!("{}:{}x{}x{}", kind_name(kind),
            m.max(1).next_power_of_two(),
            n.max(1).next_power_of_two(),
            k.max(1).next_power_of_two())
}

/// Parse a [`key_string`] back to `(key, kind)`; `None` on any mismatch
/// (the cache loader drops such entries).
fn key_from_string(s: &str) -> Option<(u64, MatKind)> {
    let (kname, dims) = s.split_once(':')?;
    let kind = kind_from_name(kname)?;
    let mut it = dims.split('x');
    let m: usize = it.next()?.parse().ok()?;
    let n: usize = it.next()?.parse().ok()?;
    let k: usize = it.next()?.parse().ok()?;
    if it.next().is_some() || m == 0 || n == 0 || k == 0 {
        return None;
    }
    Some((shape_key(kind, m, n, k), kind))
}

// -- winner table ------------------------------------------------------------

fn table() -> &'static RwLock<BTreeMap<u64, KernelVariant>> {
    static TABLE: OnceLock<RwLock<BTreeMap<u64, KernelVariant>>> =
        OnceLock::new();
    TABLE.get_or_init(|| RwLock::new(BTreeMap::new()))
}

/// Serializes tuning (and the one-shot cache load): concurrent first
/// touches of the same class must measure once, not race.
static TUNE: Mutex<()> = Mutex::new(());
static CACHE_LOADED: AtomicBool = AtomicBool::new(false);

fn read_lock<T>(l: &RwLock<T>) -> std::sync::RwLockReadGuard<'_, T> {
    match l.read() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

fn write_lock<T>(l: &RwLock<T>) -> std::sync::RwLockWriteGuard<'_, T> {
    match l.write() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// The tuned winner for a class if one is already tabled (no tuning,
/// no counter bump) — introspection for tests and the bench.
pub fn lookup(kind: MatKind, m: usize, n: usize, k: usize)
              -> Option<KernelVariant> {
    read_lock(table()).get(&shape_key(kind, m, n, k)).copied()
}

/// Number of tuned shape classes currently tabled.
pub fn table_len() -> usize {
    read_lock(table()).len()
}

/// Drop every tabled winner and forget the cache-load. Test support —
/// the table is process-global, so tests that exercise tuning reset it
/// between scenarios.
pub fn reset() {
    let _t = lock_tune();
    write_lock(table()).clear();
    CACHE_LOADED.store(false, Ordering::Relaxed);
}

fn lock_tune() -> std::sync::MutexGuard<'static, ()> {
    match TUNE.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

// -- dispatch ----------------------------------------------------------------

/// The variant [`kernels::gemm`] should run for this GEMM.
///
/// `Off` ⇒ [`static_variant`], one atomic load. Otherwise a table read
/// (counted in `sched_cache_hits`); a first-touch miss tunes the class
/// — the warm-up, the only point this module allocates or measures.
pub fn chosen(kind: MatKind, m: usize, n: usize, k: usize)
              -> KernelVariant {
    if mode() == Mode::Off {
        return static_variant(kind);
    }
    let key = shape_key(kind, m, n, k);
    if let Some(&v) = read_lock(table()).get(&key) {
        obs::counter_add(obs::Counter::SchedCacheHits, 1);
        return v;
    }
    ensure(kind, m, n, k)
}

/// Plan-compile-time variant resolution for a GEMM node: `None` with
/// tuning off (the node dispatches through [`kernels::gemm`] as
/// always), the tuned winner otherwise — tuned here, at compile time,
/// so executing the plan never pays a first-touch measurement.
pub fn compile_choice(kind: MatKind, m: usize, n: usize, k: usize)
                      -> Option<KernelVariant> {
    if mode() == Mode::Off || m == 0 || n == 0 {
        return None;
    }
    Some(chosen(kind, m, n, k))
}

// -- tuning ------------------------------------------------------------------

/// Timed repetitions per candidate; large problems get one rep — the
/// signal is strong there and reruns are what would actually hurt.
fn reps_for(flops: usize) -> usize {
    if flops > 1 << 28 {
        1
    } else {
        3
    }
}

/// Tune the class containing (m, n, k) and table the winner. Serialized
/// by [`TUNE`]; double-checks the table so racing first touches measure
/// once.
#[cold]
fn ensure(kind: MatKind, m: usize, n: usize, k: usize) -> KernelVariant {
    if m == 0 || n == 0 || k == 0 {
        return static_variant(kind);
    }
    let key = shape_key(kind, m, n, k);
    let _t = lock_tune();
    if let Some(&v) = read_lock(table()).get(&key) {
        return v;
    }
    if mode() == Mode::On && !CACHE_LOADED.swap(true, Ordering::Relaxed) {
        load_cache();
        if let Some(&v) = read_lock(table()).get(&key) {
            return v;
        }
    }
    let winner = measure(kind, m, n, k);
    write_lock(table()).insert(key, winner);
    save_cache();
    winner
}

/// Deterministic non-trivial operand fill (no RNG dependency; values in
/// [-1, 1] with no denormals).
fn fill(buf: &mut [f32]) {
    for (i, v) in buf.iter_mut().enumerate() {
        *v = ((i as u32).wrapping_mul(2654435761) >> 16) as f32 / 32768.0
            - 1.0;
    }
}

/// Run every registered candidate for `kind` on a representative
/// problem and return the fastest, timed through obs spans.
fn measure(kind: MatKind, m: usize, n: usize, k: usize) -> KernelVariant {
    let (sa, sb) = match kind {
        MatKind::NN => (m * k, k * n),
        MatKind::TN => (k * m, k * n),
        MatKind::NT => (m * k, n * k),
    };
    let mut a = vec![0.0f32; sa];
    let mut b = vec![0.0f32; sb];
    let mut out = vec![0.0f32; m * n];
    fill(&mut a);
    fill(&mut b);
    let reps = reps_for(2 * m * n * k);

    // Timing goes through the obs recorder (the ISSUE's "no second
    // measurement path"): enable it for the duration if the run isn't
    // traced, and restore after. The candidates run sequentially on
    // this thread, so the spans land on this thread's ring and
    // `local_spans_since` reads them back without quiescing anyone.
    let was_enabled = obs::enabled();
    if !was_enabled {
        obs::set_enabled(true);
    }
    let mark = obs::now_ns();
    let mut winner = static_variant(kind);
    let mut best_ns = u64::MAX;
    for v in KernelVariant::ALL {
        if v.kind() != kind {
            continue;
        }
        // Warm-up rep: page in the buffers, settle the caches.
        kernels::gemm_v(v, m, n, k, &a, &b, 1.0, 0.0, &mut out, &[], 1);
        for _ in 0..reps {
            let _sp = obs::span_args(obs::Category::Plan, v.tune_label(),
                                     [m as u32, n as u32, k as u32]);
            kernels::gemm_v(v, m, n, k, &a, &b, 1.0, 0.0, &mut out, &[],
                            1);
        }
        let best = obs::local_spans_since(mark, v.tune_label())
            .iter()
            .map(|s| s.end_ns.saturating_sub(s.start_ns))
            .min()
            .unwrap_or(u64::MAX);
        // Strict `<` keeps the registry-order earlier variant on ties —
        // the static default is listed first per anchor, so a tie never
        // moves dispatch off the historical kernel.
        if best < best_ns {
            best_ns = best;
            winner = v;
        }
    }
    if !was_enabled {
        obs::set_enabled(false);
    }
    winner
}

// -- persistence -------------------------------------------------------------

/// Cache-file format version; bump on any key/name scheme change.
const CACHE_VERSION: f64 = 1.0;

/// Resolved cache path: `$MOFA_AUTOTUNE_CACHE`, else
/// `$HOME/.cache/mofasgd/autotune.json`, else `None` (no persistence).
pub fn cache_path() -> Option<std::path::PathBuf> {
    if let Some(p) = std::env::var_os("MOFA_AUTOTUNE_CACHE") {
        if p.is_empty() {
            return None;
        }
        return Some(p.into());
    }
    std::env::var_os("HOME").map(|h| {
        std::path::PathBuf::from(h)
            .join(".cache")
            .join("mofasgd")
            .join("autotune.json")
    })
}

/// Load the persistent table into the in-memory one. Every failure mode
/// — unreadable file, bad JSON, wrong version, unparsable key, unknown
/// variant name, anchor mismatch — degrades to a warning and skips the
/// offending part: a stale cache must never break dispatch.
fn load_cache() {
    let Some(path) = cache_path() else { return };
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(_) => return, // cold cache: normal first run
    };
    let parsed = match Json::parse(&text) {
        Ok(v) => v,
        Err(e) => {
            logging::warn(format!(
                "autotune: corrupt cache {} ({e}) — retuning from scratch",
                path.display()
            ));
            return;
        }
    };
    let version = parsed.get("version").and_then(|v| v.as_f64().ok());
    if version != Some(CACHE_VERSION) {
        logging::warn(format!(
            "autotune: cache {} has version {version:?}, want \
             {CACHE_VERSION} — retuning from scratch",
            path.display()
        ));
        return;
    }
    let Some(Ok(entries)) = parsed.get("entries").map(|e| e.as_obj())
    else {
        logging::warn(format!(
            "autotune: cache {} has no entries object — retuning",
            path.display()
        ));
        return;
    };
    let mut tab = write_lock(table());
    let mut dropped = 0usize;
    for (ks, vs) in entries {
        let parsed_key = key_from_string(ks);
        let variant = vs.as_str().ok().and_then(KernelVariant::from_name);
        match (parsed_key, variant) {
            (Some((key, kind)), Some(v)) if v.kind() == kind => {
                tab.entry(key).or_insert(v);
            }
            _ => dropped += 1,
        }
    }
    if dropped > 0 {
        logging::warn(format!(
            "autotune: dropped {dropped} stale entries from {} (unknown \
             variant or malformed key) — those classes retune",
            path.display()
        ));
    }
}

/// Rewrite the persistent table from the in-memory one (it is small —
/// one line per tuned shape class). Failures warn and move on.
fn save_cache() {
    let Some(path) = cache_path() else { return };
    let tab = read_lock(table());
    let entries: BTreeMap<String, Json> = tab
        .iter()
        .map(|(&key, v)| {
            (key_to_cache_string(key), Json::Str(v.name().to_string()))
        })
        .collect();
    drop(tab);
    let doc = Json::obj(vec![
        ("version", Json::Num(CACHE_VERSION)),
        ("entries", Json::Obj(entries)),
    ]);
    if let Some(dir) = path.parent() {
        if let Err(e) = std::fs::create_dir_all(dir) {
            logging::warn(format!(
                "autotune: cannot create {} ({e}) — winners not persisted",
                dir.display()
            ));
            return;
        }
    }
    if let Err(e) = std::fs::write(&path, doc.emit(1)) {
        logging::warn(format!(
            "autotune: cannot write {} ({e}) — winners not persisted",
            path.display()
        ));
    }
}

/// Unpack a [`shape_key`] back into its cache string.
fn key_to_cache_string(key: u64) -> String {
    let kind = match key >> 48 {
        0 => MatKind::NN,
        1 => MatKind::TN,
        _ => MatKind::NT,
    };
    let m = 1usize << ((key >> 32) & 0xffff);
    let n = 1usize << ((key >> 16) & 0xffff);
    let k = 1usize << (key & 0xffff);
    key_string(kind, m, n, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Pure-function tests only: everything touching the global mode,
    // table, or cache file lives in `rust/tests/autotune.rs` as one
    // serialized scenario test (same discipline as the obs recorder).

    #[test]
    fn shape_keys_bucket_by_pow2_ceiling() {
        let base = shape_key(MatKind::NN, 64, 8, 512);
        // Anything in (32,64] × (4,8] × (256,512] shares the class.
        assert_eq!(shape_key(MatKind::NN, 33, 5, 257), base);
        assert_eq!(shape_key(MatKind::NN, 64, 8, 512), base);
        assert_ne!(shape_key(MatKind::NN, 65, 8, 512), base);
        assert_ne!(shape_key(MatKind::TN, 64, 8, 512), base);
        assert_ne!(shape_key(MatKind::NN, 64, 8, 513), base);
    }

    #[test]
    fn key_strings_round_trip() {
        for (kind, m, n, k) in [(MatKind::NN, 48, 7, 300),
                                (MatKind::TN, 1, 1, 1),
                                (MatKind::NT, 4096, 16, 4096)] {
            let s = key_string(kind, m, n, k);
            let (key, parsed_kind) = key_from_string(&s).expect("parses");
            assert_eq!(key, shape_key(kind, m, n, k), "{s}");
            assert_eq!(parsed_kind, kind);
            assert_eq!(key_to_cache_string(key), s);
        }
        assert!(key_from_string("nn:64x8").is_none());
        assert!(key_from_string("xx:1x1x1").is_none());
        assert!(key_from_string("nn:0x8x8").is_none());
        assert!(key_from_string("nn:axbxc").is_none());
    }

    #[test]
    fn mode_names_round_trip() {
        for m in [Mode::Off, Mode::On, Mode::Refresh] {
            assert_eq!(Mode::from_name(m.name()), Some(m));
        }
        assert_eq!(Mode::from_name("1"), Some(Mode::On));
        assert_eq!(Mode::from_name("bogus"), None);
    }

    #[test]
    fn fill_is_deterministic_and_bounded() {
        let mut a = vec![0.0f32; 64];
        let mut b = vec![0.0f32; 64];
        fill(&mut a);
        fill(&mut b);
        assert_eq!(a, b);
        assert!(a.iter().all(|v| v.is_finite() && v.abs() <= 1.0));
        assert!(a.iter().any(|&v| v != 0.0));
    }
}

//! Plan executor: runs fused nodes against caller bindings + the
//! workspace arena, allocation-free in steady state.
//!
//! The borrow discipline is the whole trick: before running a node, its
//! output buffer is *extracted* from wherever it lives (`std::mem::take`
//! on the `&mut` binding slot or on the arena `Vec` — both O(1) pointer
//! swaps, no allocation), every other buffer is then readable through
//! shared reborrows, and the output is swapped back afterwards. Plan
//! construction guarantees a node never reads its own output slot except
//! through GEMM `beta` / chain `Own`, both of which operate on the
//! extracted buffer itself — so the empty placeholder left behind is
//! never observed. No `unsafe` anywhere.
//!
//! Per-node operand resolution uses fixed-size stack arrays (capacities
//! [`MAX_EPI`] / [`MAX_STEPS`][crate::fusion::plan::MAX_STEPS]); with
//! `workers <= 1` an execution therefore performs **zero** heap
//! allocations — asserted by `rust/tests/fusion_alloc.rs` with a counting
//! global allocator. With more workers the only allocations are the OS
//! thread spawns inside `std::thread::scope`.

use super::kernels::{self, Epi, RSrc, RStep};
use super::plan::{EpiOp, Loc, Node, Plan, Src, Step, Workspace, MAX_EPI,
                  MAX_STEPS};
use crate::obs;

impl Plan {
    /// Validate caller bindings against the plan's declared buffer
    /// shapes. Undersized bindings would silently truncate elementwise
    /// nodes (or corrupt ext state mid-plan) — every slice length is
    /// checked. Called once per [`Plan::execute`]; callers driving nodes
    /// individually (the fleet executor) call it once per step.
    pub fn check_bindings(&self, ws: &Workspace, ins: &[&[f32]],
                          exts: &[&mut [f32]], params: &[f32]) {
        assert_eq!(ins.len(), self.in_sizes.len(),
                   "execute: input binding count");
        assert_eq!(exts.len(), self.ext_sizes.len(),
                   "execute: ext binding count");
        assert_eq!(params.len(), self.n_params, "execute: param count");
        assert_eq!(ws.temps.len(), self.temp_sizes.len(),
                   "execute: workspace mismatch");
        for (i, (s, want)) in ins.iter().zip(&self.in_sizes).enumerate() {
            assert_eq!(s.len(), *want, "execute: input binding {i} size");
        }
        for (j, (s, want)) in exts.iter().zip(&self.ext_sizes).enumerate() {
            assert_eq!(s.len(), *want, "execute: ext binding {j} size");
        }
        for (t, (s, want)) in
            ws.temps.iter().zip(&self.temp_sizes).enumerate()
        {
            assert_eq!(s.len(), *want, "execute: workspace temp {t} size");
        }
    }

    /// Execute one fused node against already-validated bindings — the
    /// per-task entry point of the fleet executor, which interleaves
    /// nodes of many layers' plans but always runs one plan's nodes in
    /// declaration order (plan semantics assume exactly that).
    pub fn execute_node(&self, idx: usize, ws: &mut Workspace,
                        ins: &[&[f32]], exts: &mut [&mut [f32]],
                        params: &[f32], workers: usize) {
        let node = &self.nodes[idx];
        let _sp = node_span(node);
        match node.out() {
            Loc::Temp(t) => {
                let mut own = std::mem::take(&mut ws.temps[t]);
                run_node(node, &mut own, ins, exts, &ws.temps, params,
                         workers);
                ws.temps[t] = own;
            }
            Loc::Ext(j) => {
                let own = std::mem::take(&mut exts[j]);
                run_node(node, own, ins, exts, &ws.temps, params,
                         workers);
                exts[j] = own;
            }
            Loc::In(_) => unreachable!("plan writes to an input"),
        }
    }

    /// Execute the plan.
    ///
    /// * `ins`  — read-only bindings, in `Graph::input` declaration order.
    /// * `exts` — read/write bindings, in `Graph::ext` declaration order.
    /// * `params` — runtime scalar values, in `Graph::param` order.
    /// * `workers` — row-parallelism cap (1 ⇒ fully sequential and
    ///   allocation-free).
    pub fn execute(&self, ws: &mut Workspace, ins: &[&[f32]],
                   exts: &mut [&mut [f32]], params: &[f32], workers: usize) {
        self.check_bindings(ws, ins, exts, params);
        for idx in 0..self.nodes.len() {
            self.execute_node(idx, ws, ins, exts, params, workers);
        }
    }
}

/// Per-node kernel span + derived FLOP/byte counters. Pure observation:
/// never touches the bindings or the math.
fn node_span(node: &Node) -> obs::SpanGuard {
    if !obs::enabled() {
        return obs::SpanGuard::off();
    }
    obs::counter_add(obs::Counter::PlanNodes, 1);
    match node {
        Node::Gemm(g) => super::gemm_obs_span(g.kind, g.m, g.n, g.k),
        Node::Elem(e) => {
            obs::counter_add(obs::Counter::Flops,
                             (e.len * e.steps.len()) as u64);
            obs::counter_add(obs::Counter::Bytes, (8 * e.len) as u64);
            obs::span_args(obs::Category::Plan, "elem_chain",
                           [e.len as u32, e.steps.len() as u32, 0])
        }
    }
}

fn read_loc<'s>(loc: Loc, ins: &'s [&[f32]], exts: &'s [&mut [f32]],
                temps: &'s [Vec<f32>]) -> &'s [f32] {
    match loc {
        Loc::In(i) => ins[i],
        Loc::Ext(j) => &exts[j][..],
        Loc::Temp(t) => &temps[t][..],
    }
}

fn run_node(node: &Node, own: &mut [f32], ins: &[&[f32]],
            exts: &[&mut [f32]], temps: &[Vec<f32>], params: &[f32],
            workers: usize) {
    match node {
        Node::Gemm(g) => {
            let a = read_loc(g.a, ins, exts, temps);
            let b = read_loc(g.b, ins, exts, temps);
            let mut epi_buf = [Epi::None; MAX_EPI];
            for (slot, e) in epi_buf.iter_mut().zip(&g.epi) {
                *slot = match *e {
                    EpiOp::Scale { s } => Epi::Scale(s.resolve(params)),
                    EpiOp::Add { s, src } => Epi::Add(
                        s.resolve(params),
                        read_loc(src, ins, exts, temps),
                    ),
                    EpiOp::Map { f } => Epi::Map(f),
                };
            }
            match g.variant {
                Some(v) => {
                    // Variant resolved at plan-compile time: steady-state
                    // dispatch doesn't even pay the table read. Count it
                    // as a cache hit so tuned dispatch shows in traces.
                    obs::counter_add(obs::Counter::SchedCacheHits, 1);
                    kernels::gemm_v(v, g.m, g.n, g.k, a, b,
                                    g.alpha.resolve(params),
                                    g.beta.resolve(params), own,
                                    &epi_buf[..g.epi.len()], workers);
                }
                None => kernels::gemm(g.kind, g.m, g.n, g.k, a, b,
                                      g.alpha.resolve(params),
                                      g.beta.resolve(params), own,
                                      &epi_buf[..g.epi.len()], workers),
            }
        }
        Node::Elem(e) => {
            debug_assert_eq!(own.len(), e.len);
            let mut step_buf = [RStep::Nop; MAX_STEPS];
            let rsrc = |s: Src| match s {
                Src::Own => RSrc::Own,
                Src::L(l) => RSrc::Slice(read_loc(l, ins, exts, temps)),
            };
            for (slot, st) in step_buf.iter_mut().zip(&e.steps) {
                *slot = match *st {
                    Step::Ld { src, s } => {
                        RStep::Ld(rsrc(src), s.resolve(params))
                    }
                    Step::Add { src, s } => {
                        RStep::Add(rsrc(src), s.resolve(params))
                    }
                    Step::MulB { src } => RStep::MulB(rsrc(src)),
                    Step::MulS { s } => RStep::MulS(s.resolve(params)),
                    Step::Map1 { f } => RStep::Map1(f),
                    Step::Zip2 { f, src } => RStep::Zip2(f, rsrc(src)),
                    Step::Zip2Rev { f, src } => RStep::Zip2Rev(f, rsrc(src)),
                    Step::ZipSelf { f } => RStep::ZipSelf(f),
                };
            }
            kernels::elem_chain(own, &step_buf[..e.steps.len()], workers);
        }
    }
}

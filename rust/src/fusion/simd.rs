//! Explicit 8-lane f32 vector for the wide GEMM micro-kernel variants.
//!
//! [`F32x8`] is a fixed-width value type with lane-wise add/mul — the
//! operations the wide kernels in [`super::kernels`] are written
//! against. Two backends share one contract:
//!
//! * **portable** (default): plain `[f32; 8]` lane loops. LLVM
//!   vectorizes these on any target; the type mostly serves to force an
//!   8-wide computation *shape* the autovectorizer can't miss.
//! * **AVX** (`target_feature = "avx"` on x86_64, i.e. builds with
//!   `RUSTFLAGS="-C target-feature=+avx"` or `-C target-cpu=native`):
//!   `std::arch` intrinsics, one 256-bit op per call.
//!
//! Both backends perform the identical lane-wise IEEE-754 single
//! operations (separate mul then add — **no FMA**, which would change
//! rounding), so results are bit-identical across backends and the
//! per-variant determinism contract (DESIGN.md §12) is backend
//! independent.

/// Eight f32 lanes.
#[derive(Clone, Copy, Debug)]
pub struct F32x8(pub [f32; 8]);

/// Lane count, for callers stepping a loop by vector width.
pub const LANES: usize = 8;

impl F32x8 {
    pub const ZERO: F32x8 = F32x8([0.0; 8]);

    /// Load lanes from the first 8 elements of `s` (panics if shorter).
    #[inline(always)]
    pub fn load(s: &[f32]) -> F32x8 {
        let mut v = [0.0f32; 8];
        v.copy_from_slice(&s[..8]);
        F32x8(v)
    }

    /// Broadcast one value to all lanes.
    #[inline(always)]
    pub fn splat(x: f32) -> F32x8 {
        F32x8([x; 8])
    }

    /// Store lanes into the first 8 elements of `d` (panics if shorter).
    #[inline(always)]
    pub fn store(self, d: &mut [f32]) {
        d[..8].copy_from_slice(&self.0);
    }

    #[cfg(all(target_arch = "x86_64", target_feature = "avx"))]
    #[inline(always)]
    pub fn add(self, o: F32x8) -> F32x8 {
        // SAFETY: the `target_feature = "avx"` cfg guarantees AVX is
        // statically enabled for this compilation, and loadu/storeu
        // have no alignment requirements.
        unsafe {
            use std::arch::x86_64::{_mm256_add_ps, _mm256_loadu_ps,
                                    _mm256_storeu_ps};
            let r = _mm256_add_ps(_mm256_loadu_ps(self.0.as_ptr()),
                                  _mm256_loadu_ps(o.0.as_ptr()));
            let mut out = [0.0f32; 8];
            _mm256_storeu_ps(out.as_mut_ptr(), r);
            F32x8(out)
        }
    }

    #[cfg(not(all(target_arch = "x86_64", target_feature = "avx")))]
    #[inline(always)]
    pub fn add(self, o: F32x8) -> F32x8 {
        let mut v = self.0;
        for (a, b) in v.iter_mut().zip(&o.0) {
            *a += b;
        }
        F32x8(v)
    }

    #[cfg(all(target_arch = "x86_64", target_feature = "avx"))]
    #[inline(always)]
    pub fn mul(self, o: F32x8) -> F32x8 {
        // SAFETY: as in `add` — AVX statically enabled, unaligned ops.
        unsafe {
            use std::arch::x86_64::{_mm256_loadu_ps, _mm256_mul_ps,
                                    _mm256_storeu_ps};
            let r = _mm256_mul_ps(_mm256_loadu_ps(self.0.as_ptr()),
                                  _mm256_loadu_ps(o.0.as_ptr()));
            let mut out = [0.0f32; 8];
            _mm256_storeu_ps(out.as_mut_ptr(), r);
            F32x8(out)
        }
    }

    #[cfg(not(all(target_arch = "x86_64", target_feature = "avx")))]
    #[inline(always)]
    pub fn mul(self, o: F32x8) -> F32x8 {
        let mut v = self.0;
        for (a, b) in v.iter_mut().zip(&o.0) {
            *a *= b;
        }
        F32x8(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanewise_ops_match_scalar() {
        let a = [1.0f32, -2.0, 0.5, 3.25, -0.125, 7.0, 1e-8, 1e8];
        let b = [0.5f32, 4.0, -1.5, 0.75, 2.0, -3.0, 1e8, 1e-8];
        let va = F32x8::load(&a);
        let vb = F32x8::load(&b);
        let mut sum = [0.0f32; 8];
        va.add(vb).store(&mut sum);
        let mut prod = [0.0f32; 8];
        va.mul(vb).store(&mut prod);
        for i in 0..8 {
            // Bit-exact: the vector ops are the same IEEE single ops.
            assert_eq!(sum[i].to_bits(), (a[i] + b[i]).to_bits(), "add {i}");
            assert_eq!(prod[i].to_bits(), (a[i] * b[i]).to_bits(),
                       "mul {i}");
        }
    }

    #[test]
    fn splat_and_nan_propagation() {
        let v = F32x8::splat(0.0).mul(F32x8::splat(f32::NAN));
        assert!(v.0.iter().all(|x| x.is_nan()), "0 · NaN must stay NaN");
    }
}

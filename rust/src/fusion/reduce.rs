//! Deterministic fixed-topology tree all-reduce for the replicated
//! engine (see DESIGN.md §13).
//!
//! Data-parallel replication folds R partial gradient sums into one —
//! and float addition is not associative, so *which* partials meet in
//! which order decides the bits of the result. This module pins that
//! order down with a **virtual-lane tree** that depends only on the
//! micro-batch count, never on the replica count or the worker count:
//!
//! * the step's `n` micro-batches are assigned to [`TREE_WIDTH`]
//!   contiguous *lanes* by recursive halving ([`TreeSchedule::new`]);
//! * each micro-batch is folded into its lane accumulator in arrival
//!   order (a left fold *within* the lane);
//! * lanes are then combined by a fixed binary tree — level ℓ folds
//!   lane `i + 2^ℓ` into lane `i` for every `i ≡ 0 (mod 2^{ℓ+1})` —
//!   skipping lanes that received no items.
//!
//! Replica `k` of `R` owns lanes `[k·W/R, (k+1)·W/R)` (a contiguous
//! shard of micro-batches, because lane ranges are hierarchical), so
//! the same additions happen in the same association whether one
//! replica runs all lanes or R replicas run them concurrently: every
//! `(R, workers)` combination is bit-identical to the `R = 1` serial
//! run. [`reduce_ref`] is the frozen sequential baseline the parity
//! suites compare against (`rust/tests/replica_parity.rs`).
//!
//! The fold kernels ([`fold_lane`], [`scale_lane`]) are built on the
//! pool's per-element worker-invariant primitives and account their
//! traffic to the `bytes_reduced` counter under `reduce_*` spans.

use crate::linalg::Mat;
use crate::obs;
use crate::util::pool;

/// Number of virtual lanes every reduction is scheduled over. Fixing
/// this constant (rather than deriving it from R) is what makes the
/// reduction order replica-count-invariant; it also caps the supported
/// in-process replica counts at R ∈ {1, 2, 4}.
pub const TREE_WIDTH: usize = 4;

/// The fixed-topology reduction schedule for one step: lane ranges over
/// the micro-batch index space plus the ordered list of lane fold
/// pairs. Depends only on `(n_items, width)` — never on replica or
/// worker counts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TreeSchedule {
    n_items: usize,
    width: usize,
    /// Lane `l` accumulates micro-batches `ranges[l].0 .. ranges[l].1`
    /// (contiguous, ascending, possibly empty).
    ranges: Vec<(usize, usize)>,
    /// Tree folds `(dst, src)` in execution order: level ℓ before level
    /// ℓ+1, ascending `dst` within a level. Pairs whose source subtree
    /// received no items are omitted. After all pairs, lane 0 holds the
    /// full sum.
    pairs: Vec<(usize, usize)>,
}

impl TreeSchedule {
    /// Build the schedule for `n_items` micro-batches over `width`
    /// lanes (`width` must be a power of two ≥ 1).
    pub fn new(n_items: usize, width: usize) -> TreeSchedule {
        assert!(width >= 1 && width.is_power_of_two(),
                "tree width must be a power of two, got {width}");
        let mut ranges = Vec::with_capacity(width);
        split_range((0, n_items), width, &mut ranges);
        let group = |i: usize, span: usize| -> usize {
            ranges[i + span - 1].1 - ranges[i].0
        };
        let mut pairs = Vec::new();
        let mut half = 1;
        while half < width {
            let step = half * 2;
            let mut i = 0;
            while i + half < width {
                if group(i + half, half) > 0 {
                    // Left-heavy splits guarantee the destination
                    // subtree is populated whenever the source is.
                    assert!(group(i, half) > 0,
                            "empty dst lane group with non-empty src");
                    pairs.push((i, i + half));
                }
                i += step;
            }
            half = step;
        }
        TreeSchedule { n_items, width, ranges, pairs }
    }

    pub fn n_items(&self) -> usize {
        self.n_items
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Per-lane micro-batch ranges (length [`Self::width`]).
    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.ranges
    }

    /// Ordered `(dst, src)` lane folds.
    pub fn pairs(&self) -> &[(usize, usize)] {
        &self.pairs
    }

    /// Lane owning micro-batch `item`.
    pub fn lane_of_item(&self, item: usize) -> usize {
        assert!(item < self.n_items,
                "item {item} out of {}", self.n_items);
        self.ranges
            .iter()
            .position(|&(a, b)| item >= a && item < b)
            .expect("contiguous lane ranges cover every item")
    }

    /// Lane range `[start, end)` owned by `replica` of `n_replicas`.
    /// `n_replicas` must be a power of two dividing the tree width —
    /// that makes every replica's lane group a complete subtree, so its
    /// micro-batch shard is contiguous.
    pub fn replica_lanes(&self, replica: usize, n_replicas: usize)
                         -> (usize, usize) {
        assert!(n_replicas >= 1 && n_replicas.is_power_of_two()
                    && self.width % n_replicas == 0,
                "replica count {n_replicas} must be a power of two \
                 dividing tree width {}", self.width);
        assert!(replica < n_replicas,
                "replica {replica} out of {n_replicas}");
        let per = self.width / n_replicas;
        (replica * per, (replica + 1) * per)
    }

    /// Contiguous micro-batch shard `[start, end)` owned by `replica`.
    pub fn replica_items(&self, replica: usize, n_replicas: usize)
                         -> (usize, usize) {
        let (lo, hi) = self.replica_lanes(replica, n_replicas);
        (self.ranges[lo].0, self.ranges[hi - 1].1)
    }
}

/// Assign a contiguous item range to `lanes` lanes by recursive
/// halving, left half taking the ceiling — so the left subtree count ≥
/// the right at every node, and lane ranges are hierarchical (any
/// pow2-aligned lane group covers one contiguous item range).
fn split_range(items: (usize, usize), lanes: usize,
               out: &mut Vec<(usize, usize)>) {
    if lanes == 1 {
        out.push(items);
        return;
    }
    let (lo, hi) = items;
    let left = (hi - lo).div_ceil(2);
    split_range((lo, lo + left), lanes / 2, out);
    split_range((lo + left, hi), lanes / 2, out);
}

/// One tree edge: `dst[i] += src[i]`, chunk-parallel and per-element
/// worker-invariant (each element sees exactly one add regardless of
/// chunking). Accounts `src` bytes to [`obs::Counter::BytesReduced`].
/// Allocation-free.
pub fn fold_lane(dst: &mut [f32], src: &[f32], workers: usize) {
    assert_eq!(dst.len(), src.len(), "fold_lane length mismatch");
    let _sp = if obs::enabled() {
        obs::counter_add(obs::Counter::BytesReduced,
                         (4 * src.len()) as u64);
        obs::span_args(obs::Category::Fleet, "reduce_fold",
                       [src.len() as u32, 0, 0])
    } else {
        obs::SpanGuard::off()
    };
    pool::par_add_assign(dst, src, workers);
}

/// Mean scaling after the tree: `dst[i] *= s`. `s == 1.0` is a no-op
/// (exact bit preservation for the single-micro-batch case).
/// Allocation-free.
pub fn scale_lane(dst: &mut [f32], s: f32) {
    if s == 1.0 {
        return;
    }
    let _sp = obs::span_args(obs::Category::Fleet, "reduce_scale",
                             [dst.len() as u32, 0, 0]);
    for x in dst.iter_mut() {
        *x *= s;
    }
}

/// Frozen sequential baseline: fold `items` through the exact schedule
/// — left fold within each lane in item order, then the tree pairs —
/// in plain single-threaded loops. Returns the (unscaled) sum. The
/// kernel path must match this bit for bit at every worker and replica
/// count; do not "optimize" it.
pub fn reduce_ref(sched: &TreeSchedule, items: &[&[f32]]) -> Vec<f32> {
    assert_eq!(items.len(), sched.n_items, "reduce_ref item count");
    assert!(!items.is_empty(), "reduce_ref needs at least one item");
    let len = items[0].len();
    let mut lanes: Vec<Option<Vec<f32>>> = vec![None; sched.width];
    for (i, it) in items.iter().enumerate() {
        assert_eq!(it.len(), len, "reduce_ref item length mismatch");
        let lane = sched.lane_of_item(i);
        match &mut lanes[lane] {
            Some(acc) => {
                for (a, b) in acc.iter_mut().zip(*it) {
                    *a += *b;
                }
            }
            slot => *slot = Some(it.to_vec()),
        }
    }
    for &(d, s) in &sched.pairs {
        let src = lanes[s].take().expect("pair src lane never written");
        let dst = lanes[d].as_mut().expect("pair dst lane never written");
        for (a, b) in dst.iter_mut().zip(&src) {
            *a += *b;
        }
    }
    lanes[0].take().expect("lane 0 never written")
}

/// Capability to derive lane `&mut Mat` references across fleet units —
/// `pool::RowsPtr`'s contract one level up. Accumulation units derive
/// only their own replica's lanes (spatially disjoint from siblings);
/// the reduce and step units derive lanes only *after* every
/// accumulation chain completed, which the replicated task graph's
/// dependency edges guarantee (temporal disjointness).
#[derive(Clone, Copy)]
pub struct LanePtr {
    ptr: *mut Mat,
    len: usize,
}

// SAFETY: LanePtr only derives lane references; callers promise (see
// `lane_mut`) that concurrently derived lanes never overlap.
unsafe impl Send for LanePtr {}
unsafe impl Sync for LanePtr {}

impl LanePtr {
    pub fn new(lanes: &mut [Mat]) -> LanePtr {
        LanePtr { ptr: lanes.as_mut_ptr(), len: lanes.len() }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Exclusive view of lane `i`.
    ///
    /// # Safety
    /// No other live reference — on any thread — may overlap lane `i`
    /// while the returned reference is alive.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn lane_mut(&self, i: usize) -> &mut Mat {
        assert!(i < self.len, "LanePtr lane {i} out of {}", self.len);
        &mut *self.ptr.add(i)
    }

    /// Shared view of lane `i`.
    ///
    /// # Safety
    /// No live *mutable* reference — on any thread — may overlap lane
    /// `i` while the returned reference is alive.
    pub unsafe fn lane(&self, i: usize) -> &Mat {
        assert!(i < self.len, "LanePtr lane {i} out of {}", self.len);
        &*self.ptr.add(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn schedule_fixtures() {
        // 5 items over 4 lanes: 5 → 3|2 → (2|1)(1|1).
        let s = TreeSchedule::new(5, 4);
        assert_eq!(s.ranges(), &[(0, 2), (2, 3), (3, 4), (4, 5)]);
        assert_eq!(s.pairs(), &[(0, 1), (2, 3), (0, 2)]);
        // 1 item: only lane 0 populated, no folds at all.
        let s = TreeSchedule::new(1, 4);
        assert_eq!(s.ranges(), &[(0, 1), (1, 1), (1, 1), (1, 1)]);
        assert!(s.pairs().is_empty());
        // 2 items land in lanes 0 and 2 (halving splits items before
        // lanes), folded by the single level-1 pair.
        let s = TreeSchedule::new(2, 4);
        assert_eq!(s.ranges(), &[(0, 1), (1, 1), (1, 2), (2, 2)]);
        assert_eq!(s.pairs(), &[(0, 2)]);
        // Full balance at n = width.
        let s = TreeSchedule::new(8, 4);
        assert_eq!(s.ranges(), &[(0, 2), (2, 4), (4, 6), (6, 8)]);
        assert_eq!(s.pairs(), &[(0, 1), (2, 3), (0, 2)]);
        // Width 1 degenerates to the plain left fold.
        let s = TreeSchedule::new(7, 1);
        assert_eq!(s.ranges(), &[(0, 7)]);
        assert!(s.pairs().is_empty());
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn width_must_be_pow2() {
        TreeSchedule::new(4, 3);
    }

    #[test]
    fn replica_shards_are_contiguous_and_cover() {
        for n in [1usize, 2, 3, 5, 8, 13] {
            let s = TreeSchedule::new(n, TREE_WIDTH);
            for r in [1usize, 2, 4] {
                let mut next = 0;
                for k in 0..r {
                    let (a, b) = s.replica_items(k, r);
                    assert_eq!(a, next, "n={n} r={r} k={k}");
                    assert!(b >= a);
                    next = b;
                }
                assert_eq!(next, n, "n={n} r={r} shards must cover");
            }
        }
    }

    #[test]
    fn lane_of_item_matches_ranges() {
        let s = TreeSchedule::new(9, 4);
        for item in 0..9 {
            let l = s.lane_of_item(item);
            let (a, b) = s.ranges()[l];
            assert!(item >= a && item < b);
        }
    }

    #[test]
    fn kernel_fold_matches_reference_at_every_worker_count() {
        let mut rng = Rng::new(42);
        for n in [1usize, 2, 3, 5, 7, 12] {
            let sched = TreeSchedule::new(n, TREE_WIDTH);
            let items: Vec<Vec<f32>> = (0..n)
                .map(|_| rng.normal_vec(257, 1.0))
                .collect();
            let refs: Vec<&[f32]> =
                items.iter().map(|v| v.as_slice()).collect();
            let want = reduce_ref(&sched, &refs);
            for workers in [1usize, 2, 8] {
                // Kernel path: per-lane left folds, then fold_lane over
                // the schedule pairs.
                let mut lanes: Vec<Option<Vec<f32>>> =
                    vec![None; TREE_WIDTH];
                for (i, it) in items.iter().enumerate() {
                    let l = sched.lane_of_item(i);
                    match &mut lanes[l] {
                        Some(acc) => fold_lane(acc, it, workers),
                        slot => *slot = Some(it.clone()),
                    }
                }
                for &(d, s) in sched.pairs() {
                    let src = lanes[s].take().unwrap();
                    fold_lane(lanes[d].as_mut().unwrap(), &src, workers);
                }
                let got = lanes[0].take().unwrap();
                assert_eq!(got, want, "n={n} workers={workers}");
            }
        }
    }

    #[test]
    fn lane_processing_order_is_immaterial() {
        // Processing lanes in any order (as concurrent replicas do)
        // cannot change bits: lanes are independent accumulators and
        // the tree folds run after all of them.
        let mut rng = Rng::new(7);
        let n = 10;
        let sched = TreeSchedule::new(n, TREE_WIDTH);
        let items: Vec<Vec<f32>> =
            (0..n).map(|_| rng.normal_vec(64, 1.0)).collect();
        let refs: Vec<&[f32]> = items.iter().map(|v| v.as_slice()).collect();
        let want = reduce_ref(&sched, &refs);
        // Reverse lane-major order: replica 1's lanes first.
        let mut lanes: Vec<Option<Vec<f32>>> = vec![None; TREE_WIDTH];
        for l in (0..TREE_WIDTH).rev() {
            let (a, b) = sched.ranges()[l];
            for i in a..b {
                match &mut lanes[l] {
                    Some(acc) => fold_lane(acc, &items[i], 1),
                    slot => *slot = Some(items[i].clone()),
                }
            }
        }
        for &(d, s) in sched.pairs() {
            let src = lanes[s].take().unwrap();
            fold_lane(lanes[d].as_mut().unwrap(), &src, 1);
        }
        assert_eq!(lanes[0].take().unwrap(), want);
    }

    #[test]
    fn scale_lane_identity_is_exact() {
        let mut v = vec![1.5f32, -0.0, f32::MIN_POSITIVE, 3.25e7];
        let orig = v.clone();
        scale_lane(&mut v, 1.0);
        assert!(v.iter().zip(&orig).all(|(a, b)| a.to_bits() == b.to_bits()));
        scale_lane(&mut v, 0.5);
        assert_eq!(v[0], 0.75);
    }

    #[test]
    fn lane_ptr_derives_disjoint_lanes() {
        let mut lanes = vec![Mat::zeros(2, 2), Mat::zeros(2, 2)];
        let lp = LanePtr::new(&mut lanes);
        assert_eq!(lp.len(), 2);
        // SAFETY: lanes 0 and 1 are distinct elements.
        unsafe {
            lp.lane_mut(0).data[0] = 1.0;
            lp.lane_mut(1).data[0] = 2.0;
        }
        assert_eq!(lanes[0].data[0], 1.0);
        assert_eq!(lanes[1].data[0], 2.0);
    }
}

//! Cache-blocked, multi-threaded matmul kernels with fused epilogues, plus
//! the fused elementwise-chain kernel.
//!
//! All three transpose variants share one contract:
//!
//!   `out = alpha · op(A)·op(B) + beta · out`, then the epilogue ops are
//!   applied elementwise to the freshly computed rows, in order.
//!
//! Threading splits `out` into contiguous row chunks (disjoint `&mut`
//! subslices via `chunks_mut` + `std::thread::scope` — no unsafe, no
//! locks). Small problems stay sequential: below [`MIN_PAR_FLOPS`] the
//! fork-join overhead exceeds the work, and with one worker the kernels
//! allocate nothing, which is what the steady-state zero-allocation
//! guarantee of the plan executor rests on.
//!
//! NN/TN stream KC×NC panels of B across a chunk's rows; NT runs 4×4
//! register tiles over a packed, k-major B panel (see [`nt_tiled`] — the
//! pre-tiling per-element path survives as [`gemm_nt_unrolled`] for
//! parity and benches).
//!
//! Accumulation order over k is ascending everywhere, matching the naive
//! `Mat` kernels — the property suite compares the two paths at 1e-5
//! relative error. Per output element that order depends only on the
//! problem shape, never on worker count or row chunking, which is what
//! the fleet executor's bit-parity guarantee rests on.

use super::ir::MatKind;
use super::simd::{F32x8, LANES};

/// Below this many flops (2mnk) a GEMM runs on the calling thread.
pub const MIN_PAR_FLOPS: usize = 1 << 17;
/// Below this many elements an elementwise chain runs on the calling thread.
pub const MIN_PAR_ELEMS: usize = 1 << 14;

/// k-dimension block: keeps the streamed B panel resident in cache while a
/// thread sweeps its rows.
const KC: usize = 128;
/// j-dimension block: bounds the panel width so KC×NC f32 ≈ 256 KB.
const NC: usize = 512;
/// Thin-family k block: the m×r / r×n UMF projections have n (or k) ≤ r,
/// so a deeper k panel amortizes the per-block row sweep instead of the
/// panel width doing it.
const KC_THIN: usize = 512;
/// Thin-family j block: bounds KC_THIN×NC_THIN at the same ≈128 KB.
const NC_THIN: usize = 64;

/// One registered micro-kernel implementation. Every variant computes the
/// identical `out = alpha·op(A)·op(B) + beta·out` contract for its
/// transpose anchor ([`KernelVariant::kind`]); they differ in blocking,
/// tile shape, and vector width. The autotuner (`fusion::autotune`) picks
/// one per shape class; [`static_variant`] is the untuned default — the
/// exact pre-autotuner kernel for each anchor.
///
/// Determinism is scoped per-variant: each variant's per-element
/// accumulation order depends only on the problem shape, never on worker
/// count or row chunking, so any *fixed* choice is bit-identical across
/// `MOFA_WORKERS`. The NN/TN variants accumulate straight into the output
/// element in ascending-k order and are additionally bit-identical to
/// *each other*; the NT variants fold per-KC-block register accumulators
/// and differ from `NtUnrolled`'s 4-way split sums (see DESIGN.md §12).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelVariant {
    /// Cache-blocked scalar NN, KC×NC panels (static default).
    NnBlocked,
    /// Cache-blocked scalar NN, deep-k thin panels (KC_THIN×NC_THIN).
    NnBlockedThin,
    /// Cache-blocked NN with an explicit 8-wide f32x8 j loop.
    NnWide8,
    /// Cache-blocked scalar TN, KC×NC panels (static default).
    TnBlocked,
    /// Cache-blocked scalar TN, deep-k thin panels.
    TnBlockedThin,
    /// Cache-blocked TN with an explicit 8-wide f32x8 j loop.
    TnWide8,
    /// NT through 4×4 register tiles over a packed B panel (static
    /// default).
    NtTiled4,
    /// Frozen pre-tiling NT path: per-element 4-way unrolled dots.
    NtUnrolled,
    /// NT through 4×8 register tiles, f32x8 accumulators.
    NtWide8,
}

impl KernelVariant {
    pub const ALL: [KernelVariant; 9] = [
        KernelVariant::NnBlocked,
        KernelVariant::NnBlockedThin,
        KernelVariant::NnWide8,
        KernelVariant::TnBlocked,
        KernelVariant::TnBlockedThin,
        KernelVariant::TnWide8,
        KernelVariant::NtTiled4,
        KernelVariant::NtUnrolled,
        KernelVariant::NtWide8,
    ];

    /// The transpose anchor this variant implements.
    pub fn kind(self) -> MatKind {
        match self {
            KernelVariant::NnBlocked
            | KernelVariant::NnBlockedThin
            | KernelVariant::NnWide8 => MatKind::NN,
            KernelVariant::TnBlocked
            | KernelVariant::TnBlockedThin
            | KernelVariant::TnWide8 => MatKind::TN,
            KernelVariant::NtTiled4
            | KernelVariant::NtUnrolled
            | KernelVariant::NtWide8 => MatKind::NT,
        }
    }

    /// Stable name — the persistent autotune table stores these, so
    /// renaming a variant invalidates cached winners (by design: the
    /// loader drops entries whose name no longer resolves).
    pub fn name(self) -> &'static str {
        match self {
            KernelVariant::NnBlocked => "nn_blocked",
            KernelVariant::NnBlockedThin => "nn_blocked_thin",
            KernelVariant::NnWide8 => "nn_wide8",
            KernelVariant::TnBlocked => "tn_blocked",
            KernelVariant::TnBlockedThin => "tn_blocked_thin",
            KernelVariant::TnWide8 => "tn_wide8",
            KernelVariant::NtTiled4 => "nt_tiled4",
            KernelVariant::NtUnrolled => "nt_unrolled",
            KernelVariant::NtWide8 => "nt_wide8",
        }
    }

    pub fn from_name(s: &str) -> Option<KernelVariant> {
        KernelVariant::ALL.iter().copied().find(|v| v.name() == s)
    }

    /// Obs span label used while the autotuner times this variant.
    pub fn tune_label(self) -> &'static str {
        match self {
            KernelVariant::NnBlocked => "tune_nn_blocked",
            KernelVariant::NnBlockedThin => "tune_nn_blocked_thin",
            KernelVariant::NnWide8 => "tune_nn_wide8",
            KernelVariant::TnBlocked => "tune_tn_blocked",
            KernelVariant::TnBlockedThin => "tune_tn_blocked_thin",
            KernelVariant::TnWide8 => "tune_tn_wide8",
            KernelVariant::NtTiled4 => "tune_nt_tiled4",
            KernelVariant::NtUnrolled => "tune_nt_unrolled",
            KernelVariant::NtWide8 => "tune_nt_wide8",
        }
    }
}

/// The untuned default per anchor — exactly the kernel [`gemm`] ran
/// before the autotuner existed (and still runs with autotuning off).
pub fn static_variant(kind: MatKind) -> KernelVariant {
    match kind {
        MatKind::NN => KernelVariant::NnBlocked,
        MatKind::TN => KernelVariant::TnBlocked,
        MatKind::NT => KernelVariant::NtTiled4,
    }
}

/// Resolved epilogue op (scalars resolved, sources bound to slices).
#[derive(Clone, Copy)]
pub enum Epi<'a> {
    None,
    /// `out *= s`
    Scale(f32),
    /// `out += s · src` (src indexed with out's global element index)
    Add(f32, &'a [f32]),
    /// `out = f(out)`
    Map(fn(f32) -> f32),
}

/// Resolved elementwise-chain step. The chain evaluates, per element `i`,
/// a register `reg` through the steps in order and stores it to the owned
/// buffer; `RSrc::Own` reads the owned buffer's pre-store value.
#[derive(Clone, Copy)]
pub enum RStep<'a> {
    Nop,
    /// `reg = s · src[i]`
    Ld(RSrc<'a>, f32),
    /// `reg += s · src[i]`
    Add(RSrc<'a>, f32),
    /// `reg *= src[i]`
    MulB(RSrc<'a>),
    /// `reg *= s`
    MulS(f32),
    /// `reg = f(reg)`
    Map1(fn(f32) -> f32),
    /// `reg = f(reg, src[i])`
    Zip2(fn(f32, f32) -> f32, RSrc<'a>),
    /// `reg = f(src[i], reg)`
    Zip2Rev(fn(f32, f32) -> f32, RSrc<'a>),
    /// `reg = f(reg, reg)`
    ZipSelf(fn(f32, f32) -> f32),
}

#[derive(Clone, Copy)]
pub enum RSrc<'a> {
    Own,
    Slice(&'a [f32]),
}

#[inline]
fn fetch(src: RSrc, own: &[f32], li: usize, i: usize) -> f32 {
    match src {
        RSrc::Own => own[li],
        RSrc::Slice(s) => s[i],
    }
}

/// `out[m×n] = alpha·op(A)·op(B) + beta·out`, then `epi`, row-parallel.
///
/// Operand dims by `kind` (all row-major, row stride = cols):
/// * `NN`: a is m×k, b is k×n
/// * `TN`: a is k×m, b is k×n (out = Aᵀ·B)
/// * `NT`: a is m×k, b is n×k (out = A·Bᵀ)
///
/// Dispatches to the micro-kernel variant the autotuner selected for
/// this shape class ([`crate::fusion::autotune::chosen`]) — with
/// autotuning off that is [`static_variant`], i.e. the historical
/// kernel choice, bit-for-bit.
pub fn gemm(kind: MatKind, m: usize, n: usize, k: usize, a: &[f32],
            b: &[f32], alpha: f32, beta: f32, out: &mut [f32],
            epi: &[Epi], workers: usize) {
    if m == 0 || n == 0 {
        // Degenerate output: nothing to compute (and the row kernels
        // divide by n). Mat permits zero dims, so match Mat::matmul here
        // — and never hand a zero shape to the autotuner.
        assert_eq!(out.len(), m * n, "gemm out size");
        return;
    }
    let v = super::autotune::chosen(kind, m, n, k);
    gemm_v(v, m, n, k, a, b, alpha, beta, out, epi, workers);
}

/// [`gemm`] with the micro-kernel variant chosen by the caller — the
/// autotuner's measurement entry point and the plan executor's dispatch
/// for nodes whose variant was resolved at plan-compile time. The
/// transpose anchor is implied by the variant.
pub fn gemm_v(v: KernelVariant, m: usize, n: usize, k: usize, a: &[f32],
              b: &[f32], alpha: f32, beta: f32, out: &mut [f32],
              epi: &[Epi], workers: usize) {
    match v.kind() {
        MatKind::NN => {
            debug_assert_eq!(a.len(), m * k);
            debug_assert_eq!(b.len(), k * n);
        }
        MatKind::TN => {
            debug_assert_eq!(a.len(), k * m);
            debug_assert_eq!(b.len(), k * n);
        }
        MatKind::NT => {
            debug_assert_eq!(a.len(), m * k);
            debug_assert_eq!(b.len(), n * k);
        }
    }
    assert_eq!(out.len(), m * n, "gemm out size");
    if m == 0 || n == 0 {
        return;
    }
    let flops = 2 * m * n * k;
    let w = workers
        .max(1)
        .min(m.max(1))
        .min(1 + flops / MIN_PAR_FLOPS);
    if w <= 1 {
        gemm_rows(v, 0, n, k, a, b, alpha, beta, out, epi);
        return;
    }
    let rows_per = m.div_ceil(w);
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(rows_per * n).enumerate() {
            s.spawn(move || {
                gemm_rows(v, ci * rows_per, n, k, a, b, alpha, beta,
                          chunk, epi);
            });
        }
    });
}

/// Compute rows `[r0, r0 + chunk.len()/n)` of the output into `chunk`
/// with variant `v`; beta init and the epilogue pass are shared across
/// variants (identical element order for all of them).
fn gemm_rows(v: KernelVariant, r0: usize, n: usize, k: usize, a: &[f32],
             b: &[f32], alpha: f32, beta: f32, chunk: &mut [f32],
             epi: &[Epi]) {
    let rows = chunk.len() / n;
    // Init pass: scale prior contents by beta (0 ⇒ plain overwrite).
    if beta == 0.0 {
        chunk.fill(0.0);
    } else if beta != 1.0 {
        for v in chunk.iter_mut() {
            *v *= beta;
        }
    }
    match v {
        KernelVariant::NnBlocked => {
            nn_panels(false, r0, n, k, a, b, alpha, chunk, KC, NC)
        }
        KernelVariant::NnBlockedThin => {
            nn_panels(false, r0, n, k, a, b, alpha, chunk, KC_THIN, NC_THIN)
        }
        KernelVariant::NnWide8 => {
            nn_panels_wide8(false, r0, n, k, a, b, alpha, chunk, KC, NC)
        }
        KernelVariant::TnBlocked => {
            nn_panels(true, r0, n, k, a, b, alpha, chunk, KC, NC)
        }
        KernelVariant::TnBlockedThin => {
            nn_panels(true, r0, n, k, a, b, alpha, chunk, KC_THIN, NC_THIN)
        }
        KernelVariant::TnWide8 => {
            nn_panels_wide8(true, r0, n, k, a, b, alpha, chunk, KC, NC)
        }
        KernelVariant::NtTiled4 => nt_tiled(r0, n, k, a, b, alpha, chunk),
        KernelVariant::NtUnrolled => {
            nt_unrolled_rows(r0, n, k, a, b, alpha, chunk)
        }
        KernelVariant::NtWide8 => {
            nt_tiled_wide8(r0, n, k, a, b, alpha, chunk)
        }
    }
    // Epilogue pass over the chunk's rows.
    if !epi.is_empty() {
        for li in 0..rows {
            let i = r0 + li;
            let crow = &mut chunk[li * n..(li + 1) * n];
            for e in epi {
                match *e {
                    Epi::None => {}
                    Epi::Scale(s) => {
                        for v in crow.iter_mut() {
                            *v *= s;
                        }
                    }
                    Epi::Add(s, src) => {
                        let srow = &src[i * n..(i + 1) * n];
                        for (v, &x) in crow.iter_mut().zip(srow) {
                            *v += s * x;
                        }
                    }
                    Epi::Map(f) => {
                        for v in crow.iter_mut() {
                            *v = f(*v);
                        }
                    }
                }
            }
        }
    }
}

/// Blocked ikj NN/TN panel walk (`ta` selects the TN column-wise A
/// indexing): the kc×nc panel of B stays hot across the chunk's rows.
///
/// Per output element the accumulation order is ascending k regardless
/// of (kc, nc) — products add straight into the output element, blocks
/// iterate k0 ascending — so every (kc, nc) instantiation is
/// bit-identical to every other *and* to the naive kernel.
fn nn_panels(ta: bool, r0: usize, n: usize, k: usize, a: &[f32],
             b: &[f32], alpha: f32, chunk: &mut [f32], kc: usize,
             nc: usize) {
    let rows = chunk.len() / n;
    // TN: out row i is column i of A; a's row length is the full output
    // height.
    let a_cols = if ta { a.len() / k } else { 0 };
    for j0 in (0..n).step_by(nc) {
        let jend = (j0 + nc).min(n);
        for k0 in (0..k).step_by(kc) {
            let kend = (k0 + kc).min(k);
            for li in 0..rows {
                let i = r0 + li;
                let crow = &mut chunk[li * n + j0..li * n + jend];
                for kk in k0..kend {
                    let aik = if ta { a[kk * a_cols + i] } else { a[i * k + kk] }
                        * alpha;
                    let brow = &b[kk * n + j0..kk * n + jend];
                    for (c, &bv) in crow.iter_mut().zip(brow) {
                        *c += aik * bv;
                    }
                }
            }
        }
    }
}

/// [`nn_panels`] with the inner j loop done in explicit [`F32x8`] lanes.
///
/// Lane j computes `c[j] += aik · b[j]` — the same single mul and add,
/// in the same k order, as the scalar walk — so this variant is
/// bit-identical to [`nn_panels`]; the explicit width just guarantees
/// the 8-wide shape instead of hoping the autovectorizer finds it.
fn nn_panels_wide8(ta: bool, r0: usize, n: usize, k: usize, a: &[f32],
                   b: &[f32], alpha: f32, chunk: &mut [f32], kc: usize,
                   nc: usize) {
    let rows = chunk.len() / n;
    let a_cols = if ta { a.len() / k } else { 0 };
    for j0 in (0..n).step_by(nc) {
        let jend = (j0 + nc).min(n);
        let w = jend - j0;
        for k0 in (0..k).step_by(kc) {
            let kend = (k0 + kc).min(k);
            for li in 0..rows {
                let i = r0 + li;
                let crow = &mut chunk[li * n + j0..li * n + jend];
                for kk in k0..kend {
                    let aik = if ta { a[kk * a_cols + i] } else { a[i * k + kk] }
                        * alpha;
                    let brow = &b[kk * n + j0..kk * n + jend];
                    let va = F32x8::splat(aik);
                    let mut j = 0;
                    while j + LANES <= w {
                        let prod = va.mul(F32x8::load(&brow[j..]));
                        let cur = F32x8::load(&crow[j..]);
                        cur.add(prod).store(&mut crow[j..]);
                        j += LANES;
                    }
                    while j < w {
                        crow[j] += aik * brow[j];
                        j += 1;
                    }
                }
            }
        }
    }
}

/// Register-tile extents of the NT micro-kernel: NT_MR output rows ×
/// NT_NR output columns (= B rows) per tile.
const NT_MR: usize = 4;
const NT_NR: usize = 4;

/// NT (out = A·Bᵀ) through 4×4 register tiles over a packed B panel.
///
/// For each group of NT_NR B rows, a KC-long panel is packed k-major
/// (`panel[kk·4 + jj]`) so the micro-kernel streams one contiguous
/// buffer, and 16 independent accumulators carry an (i, j) tile: each
/// packed B value feeds 4 output rows per load instead of 1, cutting B
/// traffic ~4× on the Gram / Newton–Schulz shapes that dominate the UMF
/// step. The panel is a fixed-size stack array — no allocation, which
/// the plan executor's zero-alloc guarantee depends on.
///
/// Per output element the accumulation order — k ascending within each
/// KC block, one accumulator per element, blocks folded into `chunk` in
/// ascending k0 order — is a function of (n, k) only: results are
/// bit-identical at every worker count and row chunking, and identical
/// whether a row lands in the 4×4 quad loop or the row tail.
fn nt_tiled(r0: usize, n: usize, k: usize, a: &[f32], b: &[f32],
            alpha: f32, chunk: &mut [f32]) {
    let rows = chunk.len() / n;
    let mut panel = [0.0f32; KC * NT_NR];
    for j0 in (0..n).step_by(NT_NR) {
        let jw = (n - j0).min(NT_NR);
        for k0 in (0..k).step_by(KC) {
            let kw = (k - k0).min(KC);
            // Pack B[j0..j0+jw][k0..k0+kw] k-major; unused j lanes are
            // zeroed so full-width tile math never reads stale values.
            for kk in 0..kw {
                let dst = &mut panel[kk * NT_NR..(kk + 1) * NT_NR];
                for (jj, d) in dst.iter_mut().enumerate() {
                    *d = if jj < jw {
                        b[(j0 + jj) * k + k0 + kk]
                    } else {
                        0.0
                    };
                }
            }
            let mut li = 0;
            while li + NT_MR <= rows {
                let base = (r0 + li) * k + k0;
                let a0 = &a[base..base + kw];
                let a1 = &a[base + k..base + k + kw];
                let a2 = &a[base + 2 * k..base + 2 * k + kw];
                let a3 = &a[base + 3 * k..base + 3 * k + kw];
                let mut acc = [[0.0f32; NT_NR]; NT_MR];
                for kk in 0..kw {
                    let p = &panel[kk * NT_NR..(kk + 1) * NT_NR];
                    let av = [a0[kk], a1[kk], a2[kk], a3[kk]];
                    for ii in 0..NT_MR {
                        for jj in 0..NT_NR {
                            acc[ii][jj] += av[ii] * p[jj];
                        }
                    }
                }
                for (ii, accrow) in acc.iter().enumerate() {
                    let c0 = (li + ii) * n + j0;
                    let crow = &mut chunk[c0..c0 + jw];
                    for (c, &v) in crow.iter_mut().zip(accrow) {
                        *c += alpha * v;
                    }
                }
                li += NT_MR;
            }
            // Row tail: 1×4 micro-kernel, same per-element op sequence.
            while li < rows {
                let base = (r0 + li) * k + k0;
                let ar = &a[base..base + kw];
                let mut acc = [0.0f32; NT_NR];
                for kk in 0..kw {
                    let p = &panel[kk * NT_NR..(kk + 1) * NT_NR];
                    for jj in 0..NT_NR {
                        acc[jj] += ar[kk] * p[jj];
                    }
                }
                let c0 = li * n + j0;
                let crow = &mut chunk[c0..c0 + jw];
                for (c, &v) in crow.iter_mut().zip(&acc) {
                    *c += alpha * v;
                }
                li += 1;
            }
        }
    }
}

/// Packed-B lane count of the wide NT tile (one [`F32x8`] row).
const NT_NR8: usize = 8;

/// NT (out = A·Bᵀ) through 4×8 register tiles: the [`nt_tiled`] packing
/// scheme widened to [`NT_NR8`] packed B lanes held in [`F32x8`]
/// accumulators — one vector op updates 8 output columns per A value.
///
/// The wider tile halves panel repacks per output column versus the 4×4
/// tile, at the cost of 4 live F32x8 accumulators; the autotuner decides
/// per shape class whether that trades well. Same determinism shape as
/// [`nt_tiled`]: one accumulator per output element, k ascending within
/// each KC block, blocks folded ascending — and since the lanes are
/// plain IEEE mul/add (no FMA), the result is bit-identical to
/// [`nt_tiled`] too.
fn nt_tiled_wide8(r0: usize, n: usize, k: usize, a: &[f32], b: &[f32],
                  alpha: f32, chunk: &mut [f32]) {
    let rows = chunk.len() / n;
    let mut panel = [0.0f32; KC * NT_NR8];
    for j0 in (0..n).step_by(NT_NR8) {
        let jw = (n - j0).min(NT_NR8);
        for k0 in (0..k).step_by(KC) {
            let kw = (k - k0).min(KC);
            // Pack B[j0..j0+jw][k0..k0+kw] k-major; unused j lanes are
            // zeroed so full-width lane math never reads stale values.
            for kk in 0..kw {
                let dst = &mut panel[kk * NT_NR8..(kk + 1) * NT_NR8];
                for (jj, d) in dst.iter_mut().enumerate() {
                    *d = if jj < jw {
                        b[(j0 + jj) * k + k0 + kk]
                    } else {
                        0.0
                    };
                }
            }
            let mut li = 0;
            while li + NT_MR <= rows {
                let base = (r0 + li) * k + k0;
                let a0 = &a[base..base + kw];
                let a1 = &a[base + k..base + k + kw];
                let a2 = &a[base + 2 * k..base + 2 * k + kw];
                let a3 = &a[base + 3 * k..base + 3 * k + kw];
                let mut acc = [F32x8::ZERO; NT_MR];
                for kk in 0..kw {
                    let p = F32x8::load(&panel[kk * NT_NR8..]);
                    let av = [a0[kk], a1[kk], a2[kk], a3[kk]];
                    for (ii, accv) in acc.iter_mut().enumerate() {
                        *accv = accv.add(F32x8::splat(av[ii]).mul(p));
                    }
                }
                for (ii, accv) in acc.iter().enumerate() {
                    let c0 = (li + ii) * n + j0;
                    let crow = &mut chunk[c0..c0 + jw];
                    for (c, &v) in crow.iter_mut().zip(&accv.0) {
                        *c += alpha * v;
                    }
                }
                li += NT_MR;
            }
            // Row tail: 1×8 micro-kernel, same per-element op sequence.
            while li < rows {
                let base = (r0 + li) * k + k0;
                let ar = &a[base..base + kw];
                let mut accv = F32x8::ZERO;
                for kk in 0..kw {
                    let p = F32x8::load(&panel[kk * NT_NR8..]);
                    accv = accv.add(F32x8::splat(ar[kk]).mul(p));
                }
                let c0 = li * n + j0;
                let crow = &mut chunk[c0..c0 + jw];
                for (c, &v) in crow.iter_mut().zip(&accv.0) {
                    *c += alpha * v;
                }
                li += 1;
            }
        }
    }
}

/// Pre-tiling NT body for rows `[r0, r0 + chunk.len()/n)`: per-element
/// dot products with 4-way unrolled partial sums.
fn nt_unrolled_rows(r0: usize, n: usize, k: usize, a: &[f32], b: &[f32],
                    alpha: f32, chunk: &mut [f32]) {
    let rows = chunk.len() / n;
    for li in 0..rows {
        let i = r0 + li;
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut chunk[li * n..(li + 1) * n];
        for (j, c) in crow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            *c += alpha * dot4(arow, brow);
        }
    }
}

/// Frozen pre-tiling NT path: per-element dot products with 4-way
/// unrolled partial sums, sequential. Kept as the parity / `bench_umf`
/// baseline for [`nt_tiled`]; reachable from [`gemm`] only when the
/// autotuner picks [`KernelVariant::NtUnrolled`] for a shape class.
pub fn gemm_nt_unrolled(m: usize, n: usize, k: usize, a: &[f32],
                        b: &[f32], alpha: f32, beta: f32,
                        out: &mut [f32]) {
    assert_eq!(out.len(), m * n, "gemm_nt_unrolled out size");
    if m == 0 || n == 0 {
        return;
    }
    if beta == 0.0 {
        out.fill(0.0);
    } else if beta != 1.0 {
        for v in out.iter_mut() {
            *v *= beta;
        }
    }
    nt_unrolled_rows(0, n, k, a, b, alpha, out);
}

/// Dot product with four independent accumulators (ILP-friendly).
#[inline]
fn dot4(x: &[f32], y: &[f32]) -> f32 {
    let k = x.len().min(y.len());
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let k4 = k - k % 4;
    let mut t = 0;
    while t < k4 {
        s0 += x[t] * y[t];
        s1 += x[t + 1] * y[t + 1];
        s2 += x[t + 2] * y[t + 2];
        s3 += x[t + 3] * y[t + 3];
        t += 4;
    }
    let mut tail = 0.0f32;
    for u in k4..k {
        tail += x[u] * y[u];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Run a fused elementwise chain over `own`, parallel over element chunks.
pub fn elem_chain(own: &mut [f32], steps: &[RStep], workers: usize) {
    let len = own.len();
    let w = workers
        .max(1)
        .min(len.max(1))
        .min(1 + len / MIN_PAR_ELEMS);
    if w <= 1 {
        chain_range(own, 0, steps);
        return;
    }
    let per = len.div_ceil(w);
    std::thread::scope(|s| {
        for (ci, chunk) in own.chunks_mut(per).enumerate() {
            s.spawn(move || chain_range(chunk, ci * per, steps));
        }
    });
}

fn chain_range(own: &mut [f32], base: usize, steps: &[RStep]) {
    for li in 0..own.len() {
        let i = base + li;
        let mut reg = 0.0f32;
        for st in steps {
            reg = match *st {
                RStep::Nop => reg,
                RStep::Ld(src, s) => s * fetch(src, own, li, i),
                RStep::Add(src, s) => reg + s * fetch(src, own, li, i),
                RStep::MulB(src) => reg * fetch(src, own, li, i),
                RStep::MulS(s) => reg * s,
                RStep::Map1(f) => f(reg),
                RStep::Zip2(f, src) => f(reg, fetch(src, own, li, i)),
                RStep::Zip2Rev(f, src) => f(fetch(src, own, li, i), reg),
                RStep::ZipSelf(f) => f(reg, reg),
            };
        }
        own[li] = reg;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::rng::Rng;

    fn gemm_ref(kind: MatKind, a: &Mat, b: &Mat, alpha: f32, beta: f32,
                out: &Mat) -> Mat {
        let prod = match kind {
            MatKind::NN => a.matmul(b),
            MatKind::TN => a.t_matmul(b),
            MatKind::NT => a.matmul_t(b),
        };
        out.scale(beta).add(&prod.scale(alpha))
    }

    #[test]
    fn variant_registry_names_round_trip() {
        for v in KernelVariant::ALL {
            assert_eq!(KernelVariant::from_name(v.name()), Some(v));
            assert_eq!(v.tune_label(), format!("tune_{}", v.name()),
                       "{v:?}: tune label must be the name prefixed");
        }
        assert_eq!(KernelVariant::from_name("no_such_variant"), None);
        for kind in [MatKind::NN, MatKind::TN, MatKind::NT] {
            assert_eq!(static_variant(kind).kind(), kind);
            // Every anchor offers real alternatives to tune over.
            let n = KernelVariant::ALL.iter()
                .filter(|v| v.kind() == kind).count();
            assert!(n >= 2, "{kind:?} has {n} variants");
        }
    }

    #[test]
    fn gemm_matches_reference_all_kinds() {
        let mut rng = Rng::new(1);
        for workers in [1, 2, 3] {
            for (m, k, n) in [(7, 5, 9), (33, 17, 21), (64, 64, 64)] {
                for (kind, sa, sb) in [
                    (MatKind::NN, (m, k), (k, n)),
                    (MatKind::TN, (k, m), (k, n)),
                    (MatKind::NT, (m, k), (n, k)),
                ] {
                    let a = Mat::randn(&mut rng, sa.0, sa.1, 1.0);
                    let b = Mat::randn(&mut rng, sb.0, sb.1, 1.0);
                    let prior = Mat::randn(&mut rng, m, n, 1.0);
                    let want = gemm_ref(kind, &a, &b, 0.7, 0.3, &prior);
                    let mut out = prior.clone();
                    gemm(kind, m, n, k, &a.data, &b.data, 0.7, 0.3,
                         &mut out.data, &[], workers);
                    assert!(out.rel_err(&want) < 1e-5,
                            "{kind:?} w={workers} err {}", out.rel_err(&want));
                }
            }
        }
    }

    #[test]
    fn gemm_epilogue_add_scale_map() {
        let mut rng = Rng::new(2);
        let (m, k, n) = (12, 8, 10);
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let b = Mat::randn(&mut rng, k, n, 1.0);
        let src = Mat::randn(&mut rng, m, n, 1.0);
        let mut out = Mat::zeros(m, n);
        // out = tanh(2·(A·B) + 0.5·src)
        gemm(MatKind::NN, m, n, k, &a.data, &b.data, 1.0, 0.0,
             &mut out.data,
             &[Epi::Scale(2.0), Epi::Add(0.5, &src.data),
               Epi::Map(|x| x.tanh())],
             2);
        let want = a.matmul(&b).scale(2.0).add(&src.scale(0.5))
            .map(|x| x.tanh());
        assert!(out.rel_err(&want) < 1e-5);
    }

    #[test]
    fn nt_tiled_matches_unrolled_baseline() {
        // Register-tiled NT vs the frozen per-element dot-product path,
        // across quad/tail row counts, 4-lane j tails, and multi-KC k.
        let mut rng = Rng::new(7);
        for (m, n, k) in [(4, 4, 8), (5, 7, 9), (13, 10, 300), (1, 3, 130),
                          (8, 17, 64), (33, 4, 257)] {
            let a = Mat::randn(&mut rng, m, k, 1.0);
            let b = Mat::randn(&mut rng, n, k, 1.0);
            let prior = Mat::randn(&mut rng, m, n, 1.0);
            let mut want = prior.clone();
            gemm_nt_unrolled(m, n, k, &a.data, &b.data, 0.7, 0.3,
                             &mut want.data);
            for workers in [1, 3] {
                let mut out = prior.clone();
                gemm(MatKind::NT, m, n, k, &a.data, &b.data, 0.7, 0.3,
                     &mut out.data, &[], workers);
                assert!(out.rel_err(&want) < 1e-5,
                        "{m}x{n}x{k} w={workers} err {}",
                        out.rel_err(&want));
            }
        }
    }

    #[test]
    fn nt_tiled_row_chunking_is_bit_identical() {
        // The fleet's bit-parity guarantee rests on per-element compute
        // being independent of how rows are chunked across workers.
        let mut rng = Rng::new(8);
        let (m, n, k) = (29, 11, 190);
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let b = Mat::randn(&mut rng, n, k, 1.0);
        let mut base = Mat::zeros(m, n);
        gemm(MatKind::NT, m, n, k, &a.data, &b.data, 1.0, 0.0,
             &mut base.data, &[], 1);
        for workers in [2, 3, 8] {
            let mut out = Mat::zeros(m, n);
            gemm(MatKind::NT, m, n, k, &a.data, &b.data, 1.0, 0.0,
                 &mut out.data, &[], workers);
            assert_eq!(out.data, base.data, "w={workers}");
        }
    }

    #[test]
    fn nt_tiled_propagates_nan() {
        // The tiled path must not zero-skip either: 0 · NaN = NaN.
        let a = Mat::from_vec(1, 2, vec![0.0, 0.0]);
        let b = Mat::from_vec(1, 2, vec![f32::NAN, 1.0]);
        let mut out = Mat::zeros(1, 1);
        gemm(MatKind::NT, 1, 1, 2, &a.data, &b.data, 1.0, 0.0,
             &mut out.data, &[], 1);
        assert!(out.data[0].is_nan());
    }

    #[test]
    fn gemm_propagates_nan() {
        // The dense kernels must not zero-skip: 0 · NaN = NaN.
        let a = Mat::from_vec(1, 2, vec![0.0, 0.0]);
        let b = Mat::from_vec(2, 1, vec![f32::NAN, 1.0]);
        let mut out = Mat::zeros(1, 1);
        gemm(MatKind::NN, 1, 1, 2, &a.data, &b.data, 1.0, 0.0,
             &mut out.data, &[], 1);
        assert!(out.data[0].is_nan());
    }

    #[test]
    fn elem_chain_adam_like() {
        let mut rng = Rng::new(3);
        let n = 40_000; // above MIN_PAR_ELEMS so threading kicks in
        let m1: Vec<f32> = rng.normal_vec(n, 1.0);
        let m2: Vec<f32> = rng.normal_vec(n, 1.0).iter().map(|x| x * x)
            .collect();
        let mut own = m1.clone();
        // own = (own * 1.25) / (sqrt(m2 * 2.0) + 1e-8)
        fn ratio(m: f32, v: f32) -> f32 {
            m / (v.max(0.0).sqrt() + 1e-8)
        }
        let m2s: Vec<f32> = m2.iter().map(|v| v * 2.0).collect();
        elem_chain(&mut own,
                   &[RStep::MulS(1.25), RStep::Zip2(ratio, RSrc::Slice(&m2s))],
                   3);
        for i in [0usize, 1, n / 2, n - 1] {
            let want = ratio(m1[i] * 1.25, m2s[i]);
            assert!((own[i] - want).abs() < 1e-6, "{i}");
        }
    }

    #[test]
    fn elem_chain_own_reads_pre_store() {
        // own = 0.9·own + 0.1·y, in place.
        let mut own = vec![1.0f32; 100];
        let y = vec![2.0f32; 100];
        elem_chain(&mut own,
                   &[RStep::Ld(RSrc::Own, 0.9),
                     RStep::Add(RSrc::Slice(&y), 0.1)],
                   1);
        assert!(own.iter().all(|&v| (v - 1.1).abs() < 1e-6));
    }
}

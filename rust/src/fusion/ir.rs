//! Tiny op IR over buffer ids — the input language of the fused executor.
//!
//! A [`Graph`] is a straight-line sequence of ops over 2-D f32 buffers:
//! matmul anchors in all three transpose variants plus the elementwise
//! vocabulary the optimizer hot loops need (axpy, scale, Hadamard, map,
//! zip). Scalars are [`SVal`]s — either literals baked into the plan or
//! runtime parameters, so one compiled plan serves every step of a
//! training run (η, β, bias corrections change per step; the plan does
//! not).
//!
//! Buffers come in three kinds:
//! * `In`   — caller-bound, read-only (e.g. the incoming gradient);
//! * `Ext`  — caller-bound, read/write, observable after execution
//!   (weights, moments, accumulation buffers);
//! * `Temp` — plan-internal scratch, backed by the workspace arena. Temps
//!   that the planner fuses away are never materialized at all.
//!
//! [`Graph::eval_naive`] is the reference interpreter over [`Mat`]: the
//! property suite checks the fused planner + kernels against it on random
//! graphs.

use crate::linalg::Mat;

/// Opaque buffer handle.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct BufId(pub usize);

/// Matmul transpose variant: C = A·B, C = Aᵀ·B, C = A·Bᵀ.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MatKind {
    NN,
    TN,
    NT,
}

/// A scalar: literal, runtime parameter, or literal × parameter.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SVal {
    Lit(f32),
    Param(usize),
    /// `c · params[i]` — produced when the planner folds a literal into a
    /// parameterized scale.
    ScaledParam(f32, usize),
}

impl SVal {
    #[inline]
    pub fn resolve(self, params: &[f32]) -> f32 {
        match self {
            SVal::Lit(x) => x,
            SVal::Param(i) => params[i],
            SVal::ScaledParam(c, i) => c * params[i],
        }
    }

    /// Fold a product of two scalars, when at most one is a parameter.
    pub fn fold_mul(self, other: SVal) -> Option<SVal> {
        match (self, other) {
            (SVal::Lit(a), SVal::Lit(b)) => Some(SVal::Lit(a * b)),
            (SVal::Lit(a), SVal::Param(i)) | (SVal::Param(i), SVal::Lit(a)) => {
                Some(SVal::ScaledParam(a, i))
            }
            (SVal::Lit(a), SVal::ScaledParam(c, i))
            | (SVal::ScaledParam(c, i), SVal::Lit(a)) => {
                Some(SVal::ScaledParam(a * c, i))
            }
            _ => None,
        }
    }

    pub fn is_lit(self, v: f32) -> bool {
        matches!(self, SVal::Lit(x) if x == v)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shape {
    pub rows: usize,
    pub cols: usize,
}

impl Shape {
    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BufKind {
    In,
    Ext,
    Temp,
}

#[derive(Clone, Copy, Debug)]
pub(crate) struct BufDecl {
    pub shape: Shape,
    pub kind: BufKind,
}

/// One IR op. Elementwise ops may write in place (`out` may alias an
/// operand); matmuls may not (`out` must differ from `a` and `b` — the
/// accumulating read of `out` itself is expressed through `beta`).
#[derive(Clone, Copy, Debug)]
pub enum Op {
    /// `out = alpha · op(a)·op(b) + beta · out`
    MatMul { kind: MatKind, a: BufId, b: BufId, out: BufId, alpha: SVal, beta: SVal },
    /// `out = a·x + b·y`
    Axpy { out: BufId, a: SVal, x: BufId, b: SVal, y: BufId },
    /// `out = a·x`
    Scale { out: BufId, a: SVal, x: BufId },
    /// `out = x ⊙ y`
    Mul { out: BufId, x: BufId, y: BufId },
    /// `out = f(x)` elementwise
    Map { out: BufId, x: BufId, f: fn(f32) -> f32 },
    /// `out = f(x, y)` elementwise
    Zip { out: BufId, x: BufId, y: BufId, f: fn(f32, f32) -> f32 },
}

/// A straight-line op graph, built programmatically and compiled once by
/// [`crate::fusion::builder::compile`].
pub struct Graph {
    pub(crate) bufs: Vec<BufDecl>,
    pub(crate) ops: Vec<Op>,
    pub(crate) n_params: usize,
    /// Whether each buffer has been written yet (temps start false).
    /// Workspace temps persist across executions, so a temp read before
    /// its first write would see the *previous* execution's contents —
    /// the graph builder rejects that instead of letting re-execution
    /// silently diverge from `eval_naive` (which zeroes temps).
    written: Vec<bool>,
}

impl Default for Graph {
    fn default() -> Self {
        Graph::new()
    }
}

impl Graph {
    pub fn new() -> Graph {
        Graph {
            bufs: Vec::new(),
            ops: Vec::new(),
            n_params: 0,
            written: Vec::new(),
        }
    }

    fn buf(&mut self, rows: usize, cols: usize, kind: BufKind) -> BufId {
        assert!(rows > 0 && cols > 0, "degenerate buffer {rows}x{cols}");
        self.bufs.push(BufDecl { shape: Shape { rows, cols }, kind });
        self.written.push(kind != BufKind::Temp);
        BufId(self.bufs.len() - 1)
    }

    /// A buffer is read by the op being added: temps must be written
    /// first (arena contents are only defined after a write).
    fn note_read(&self, b: BufId) {
        assert!(
            self.written[b.0],
            "temp buffer {b:?} read before its first write"
        );
    }

    fn note_write(&mut self, b: BufId) {
        self.written[b.0] = true;
    }

    /// Caller-bound read-only buffer.
    pub fn input(&mut self, rows: usize, cols: usize) -> BufId {
        self.buf(rows, cols, BufKind::In)
    }

    /// Caller-bound read/write buffer (observable output).
    pub fn ext(&mut self, rows: usize, cols: usize) -> BufId {
        self.buf(rows, cols, BufKind::Ext)
    }

    /// Plan-internal scratch buffer (arena-backed, may be fused away).
    pub fn temp(&mut self, rows: usize, cols: usize) -> BufId {
        self.buf(rows, cols, BufKind::Temp)
    }

    /// Declare the next runtime scalar parameter.
    pub fn param(&mut self) -> SVal {
        self.n_params += 1;
        SVal::Param(self.n_params - 1)
    }

    pub fn shape(&self, b: BufId) -> Shape {
        self.bufs[b.0].shape
    }

    pub(crate) fn kind(&self, b: BufId) -> BufKind {
        self.bufs[b.0].kind
    }

    fn check_writable(&self, out: BufId) {
        assert!(
            self.kind(out) != BufKind::In,
            "op writes to read-only input buffer {out:?}"
        );
    }

    /// Output shape of `alpha·op(a)op(b)` for `kind`; panics on mismatch.
    pub fn matmul_shape(&self, kind: MatKind, a: BufId, b: BufId) -> Shape {
        let (sa, sb) = (self.shape(a), self.shape(b));
        match kind {
            MatKind::NN => {
                assert_eq!(sa.cols, sb.rows, "NN shape mismatch");
                Shape { rows: sa.rows, cols: sb.cols }
            }
            MatKind::TN => {
                assert_eq!(sa.rows, sb.rows, "TN shape mismatch");
                Shape { rows: sa.cols, cols: sb.cols }
            }
            MatKind::NT => {
                assert_eq!(sa.cols, sb.cols, "NT shape mismatch");
                Shape { rows: sa.rows, cols: sb.rows }
            }
        }
    }

    pub fn matmul(&mut self, kind: MatKind, a: BufId, b: BufId, out: BufId,
                  alpha: SVal, beta: SVal) {
        self.check_writable(out);
        assert!(out != a && out != b, "matmul out aliases an operand");
        assert_eq!(self.matmul_shape(kind, a, b), self.shape(out),
                   "matmul out shape mismatch");
        self.note_read(a);
        self.note_read(b);
        if !beta.is_lit(0.0) {
            // A non-zero beta (including a runtime param) reads `out`.
            self.note_read(out);
        }
        self.note_write(out);
        self.ops.push(Op::MatMul { kind, a, b, out, alpha, beta });
    }

    fn check_elemwise(&self, out: BufId, xs: &[BufId]) {
        self.check_writable(out);
        for &x in xs {
            assert_eq!(self.shape(x).numel(), self.shape(out).numel(),
                       "elementwise numel mismatch");
        }
    }

    pub fn axpy(&mut self, out: BufId, a: SVal, x: BufId, b: SVal, y: BufId) {
        self.check_elemwise(out, &[x, y]);
        self.note_read(x);
        self.note_read(y);
        self.note_write(out);
        self.ops.push(Op::Axpy { out, a, x, b, y });
    }

    pub fn scale(&mut self, out: BufId, a: SVal, x: BufId) {
        self.check_elemwise(out, &[x]);
        self.note_read(x);
        self.note_write(out);
        self.ops.push(Op::Scale { out, a, x });
    }

    pub fn mul(&mut self, out: BufId, x: BufId, y: BufId) {
        self.check_elemwise(out, &[x, y]);
        self.note_read(x);
        self.note_read(y);
        self.note_write(out);
        self.ops.push(Op::Mul { out, x, y });
    }

    pub fn map(&mut self, out: BufId, x: BufId, f: fn(f32) -> f32) {
        self.check_elemwise(out, &[x]);
        self.note_read(x);
        self.note_write(out);
        self.ops.push(Op::Map { out, x, f });
    }

    pub fn zip(&mut self, out: BufId, x: BufId, y: BufId,
               f: fn(f32, f32) -> f32) {
        self.check_elemwise(out, &[x, y]);
        self.note_read(x);
        self.note_read(y);
        self.note_write(out);
        self.ops.push(Op::Zip { out, x, y, f });
    }

    /// Binding index of an `In` buffer (position among `In` declarations).
    pub(crate) fn in_index(&self, b: BufId) -> usize {
        self.bufs[..b.0].iter().filter(|d| d.kind == BufKind::In).count()
    }

    /// Binding index of an `Ext` buffer.
    pub(crate) fn ext_index(&self, b: BufId) -> usize {
        self.bufs[..b.0].iter().filter(|d| d.kind == BufKind::Ext).count()
    }

    pub(crate) fn n_ins(&self) -> usize {
        self.bufs.iter().filter(|d| d.kind == BufKind::In).count()
    }

    pub(crate) fn n_exts(&self) -> usize {
        self.bufs.iter().filter(|d| d.kind == BufKind::Ext).count()
    }

    // -- reference interpreter ---------------------------------------------

    /// Execute the graph with naive `Mat` operations. `ins`/`exts` are in
    /// buffer-declaration order; `exts` is updated in place. Temps start
    /// at zero (matching a fresh workspace).
    pub fn eval_naive(&self, ins: &[&Mat], exts: &mut [Mat], params: &[f32]) {
        assert_eq!(ins.len(), self.n_ins(), "eval_naive: in count");
        assert_eq!(exts.len(), self.n_exts(), "eval_naive: ext count");
        assert_eq!(params.len(), self.n_params, "eval_naive: param count");
        let mut vals: Vec<Mat> = self
            .bufs
            .iter()
            .enumerate()
            .map(|(i, d)| match d.kind {
                BufKind::In => {
                    let m = ins[self.in_index(BufId(i))];
                    assert_eq!((m.rows, m.cols), (d.shape.rows, d.shape.cols));
                    m.clone()
                }
                BufKind::Ext => {
                    let m = &exts[self.ext_index(BufId(i))];
                    assert_eq!(m.data.len(), d.shape.numel());
                    m.clone()
                }
                BufKind::Temp => Mat::zeros(d.shape.rows, d.shape.cols),
            })
            .collect();
        for op in &self.ops {
            match *op {
                Op::MatMul { kind, a, b, out, alpha, beta } => {
                    let prod = match kind {
                        MatKind::NN => vals[a.0].matmul(&vals[b.0]),
                        MatKind::TN => vals[a.0].t_matmul(&vals[b.0]),
                        MatKind::NT => vals[a.0].matmul_t(&vals[b.0]),
                    };
                    let (al, be) =
                        (alpha.resolve(params), beta.resolve(params));
                    // beta == 0 is a plain overwrite, exactly like the
                    // kernels' fill(0.0) init — 0·NaN must NOT leak prior
                    // contents into the result here when it can't there.
                    let mut new = if be == 0.0 {
                        Mat::zeros(vals[out.0].rows, vals[out.0].cols)
                    } else {
                        vals[out.0].scale(be)
                    };
                    new.axpy_inplace(1.0, al, &reshaped(&prod, &new));
                    vals[out.0] = new;
                }
                Op::Axpy { out, a, x, b, y } => {
                    let (av, bv) = (a.resolve(params), b.resolve(params));
                    let r = combine(&vals[x.0], &vals[y.0], |xv, yv| {
                        av * xv + bv * yv
                    });
                    store(&mut vals, out, r);
                }
                Op::Scale { out, a, x } => {
                    let av = a.resolve(params);
                    let r = vals[x.0].map(|v| av * v);
                    store(&mut vals, out, r);
                }
                Op::Mul { out, x, y } => {
                    let r = combine(&vals[x.0], &vals[y.0], |a, b| a * b);
                    store(&mut vals, out, r);
                }
                Op::Map { out, x, f } => {
                    let r = vals[x.0].map(f);
                    store(&mut vals, out, r);
                }
                Op::Zip { out, x, y, f } => {
                    let r = combine(&vals[x.0], &vals[y.0], f);
                    store(&mut vals, out, r);
                }
            }
        }
        for (i, d) in self.bufs.iter().enumerate() {
            if d.kind == BufKind::Ext {
                exts[self.ext_index(BufId(i))] = vals[i].clone();
            }
        }
    }
}

/// Elementwise combine tolerating equal-numel shape mismatch (the IR only
/// requires matching numel for elementwise ops).
fn combine(x: &Mat, y: &Mat, f: impl Fn(f32, f32) -> f32) -> Mat {
    assert_eq!(x.data.len(), y.data.len());
    Mat {
        rows: x.rows,
        cols: x.cols,
        data: x.data.iter().zip(&y.data).map(|(&a, &b)| f(a, b)).collect(),
    }
}

fn store(vals: &mut [Mat], out: BufId, r: Mat) {
    // Keep the destination's declared shape — elementwise ops only agree
    // on numel, and a later matmul must still see `out`'s own dims.
    assert_eq!(vals[out.0].data.len(), r.data.len());
    vals[out.0].data = r.data;
}

fn reshaped(m: &Mat, like: &Mat) -> Mat {
    assert_eq!(m.data.len(), like.data.len());
    Mat { rows: like.rows, cols: like.cols, data: m.data.clone() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn sval_folding() {
        assert_eq!(SVal::Lit(2.0).fold_mul(SVal::Lit(3.0)),
                   Some(SVal::Lit(6.0)));
        assert_eq!(SVal::Lit(2.0).fold_mul(SVal::Param(1)),
                   Some(SVal::ScaledParam(2.0, 1)));
        assert_eq!(SVal::Param(0).fold_mul(SVal::Param(1)), None);
        assert!((SVal::ScaledParam(2.0, 0).resolve(&[3.0]) - 6.0).abs()
                < 1e-6);
    }

    #[test]
    fn naive_eval_gemm_accumulate() {
        let mut rng = Rng::new(1);
        let (m, k, n) = (5, 4, 3);
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let b = Mat::randn(&mut rng, k, n, 1.0);
        let w0 = Mat::randn(&mut rng, m, n, 1.0);

        let mut g = Graph::new();
        let ia = g.input(m, k);
        let ib = g.input(k, n);
        let w = g.ext(m, n);
        let eta = g.param();
        g.matmul(MatKind::NN, ia, ib, w, eta, SVal::Lit(1.0));

        let mut exts = [w0.clone()];
        g.eval_naive(&[&a, &b], &mut exts, &[-0.1]);
        let want = w0.add(&a.matmul(&b).scale(-0.1));
        assert!(exts[0].rel_err(&want) < 1e-6);
    }

    #[test]
    #[should_panic(expected = "matmul out aliases")]
    fn matmul_aliasing_rejected() {
        let mut g = Graph::new();
        let a = g.ext(4, 4);
        let b = g.input(4, 4);
        g.matmul(MatKind::NN, a, b, a, SVal::Lit(1.0), SVal::Lit(0.0));
    }

    #[test]
    #[should_panic(expected = "read-only input")]
    fn write_to_input_rejected() {
        let mut g = Graph::new();
        let a = g.input(4, 4);
        let b = g.input(4, 4);
        g.mul(a, a, b);
    }

    #[test]
    #[should_panic(expected = "read before its first write")]
    fn temp_read_before_write_rejected() {
        // Workspace temps persist across executions; accumulating into a
        // never-written temp would read stale arena contents on the
        // second execute, so the graph builder must reject it.
        let mut g = Graph::new();
        let a = g.input(4, 4);
        let b = g.input(4, 4);
        let t = g.temp(4, 4);
        g.matmul(MatKind::NN, a, b, t, SVal::Lit(1.0), SVal::Lit(1.0));
    }
}

//! Compiled execution plans and the workspace arena.
//!
//! A [`Plan`] is the output of [`crate::fusion::builder::compile`]: a
//! sequence of fused nodes (GEMMs with epilogues, elementwise chains) over
//! resolved buffer locations. Temps that survived fusion live in a
//! [`Workspace`] arena that is allocated once and reused for every
//! execution — the steady-state optimizer step performs no heap
//! allocation (see `rust/tests/fusion_alloc.rs` for the counting-allocator
//! proof).

use super::ir::{MatKind, SVal};
use super::kernels::KernelVariant;

/// Where a buffer lives at execution time.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Loc {
    /// `ins[i]` — caller-bound read-only slice.
    In(usize),
    /// `exts[i]` — caller-bound read/write slice.
    Ext(usize),
    /// `workspace.temps[i]` — arena scratch.
    Temp(usize),
}

/// Source operand of an elementwise-chain step.
#[derive(Clone, Copy, Debug)]
pub enum Src {
    /// The node's own output buffer (pre-store value).
    Own,
    L(Loc),
}

/// Unresolved elementwise-chain step (scalars still symbolic).
#[derive(Clone, Copy, Debug)]
pub enum Step {
    Ld { src: Src, s: SVal },
    Add { src: Src, s: SVal },
    MulB { src: Src },
    MulS { s: SVal },
    Map1 { f: fn(f32) -> f32 },
    Zip2 { f: fn(f32, f32) -> f32, src: Src },
    Zip2Rev { f: fn(f32, f32) -> f32, src: Src },
    ZipSelf { f: fn(f32, f32) -> f32 },
}

/// Unresolved GEMM epilogue op.
#[derive(Clone, Copy, Debug)]
pub enum EpiOp {
    Scale { s: SVal },
    Add { s: SVal, src: Loc },
    Map { f: fn(f32) -> f32 },
}

/// Hard caps keeping per-node resolution on the stack (no allocation at
/// execution time). The builder closes a node rather than exceed them.
pub const MAX_EPI: usize = 4;
pub const MAX_STEPS: usize = 8;

#[derive(Debug)]
pub struct GemmNode {
    pub kind: MatKind,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub a: Loc,
    pub b: Loc,
    pub out: Loc,
    pub alpha: SVal,
    pub beta: SVal,
    pub epi: Vec<EpiOp>,
    /// Micro-kernel variant resolved by the autotuner at plan-compile
    /// time; `None` (tuning off) dispatches through `kernels::gemm` as
    /// before.
    pub variant: Option<KernelVariant>,
}

#[derive(Debug)]
pub struct ElemNode {
    pub len: usize,
    pub out: Loc,
    pub steps: Vec<Step>,
}

#[derive(Debug)]
pub enum Node {
    Gemm(GemmNode),
    Elem(ElemNode),
}

impl Node {
    pub fn out(&self) -> Loc {
        match self {
            Node::Gemm(g) => g.out,
            Node::Elem(e) => e.out,
        }
    }
}

/// A compiled, reusable execution plan.
pub struct Plan {
    pub(crate) nodes: Vec<Node>,
    /// Element counts of the surviving temps, by arena slot.
    pub(crate) temp_sizes: Vec<usize>,
    /// Declared element counts of the `In` bindings, in binding order —
    /// validated against the caller's slices on every execution.
    pub(crate) in_sizes: Vec<usize>,
    /// Declared element counts of the `Ext` bindings, in binding order.
    pub(crate) ext_sizes: Vec<usize>,
    pub(crate) n_params: usize,
}

impl Plan {
    /// Allocate the arena this plan needs. One workspace serves any number
    /// of executions (and stays exactly this size — see
    /// [`Workspace::floats`]).
    pub fn workspace(&self) -> Workspace {
        Workspace {
            temps: self.temp_sizes.iter().map(|&n| vec![0.0; n]).collect(),
        }
    }

    /// Number of fused nodes (for tests / introspection).
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn n_gemm_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::Gemm(_))).count()
    }

    pub fn n_temps(&self) -> usize {
        self.temp_sizes.len()
    }

    pub fn n_params(&self) -> usize {
        self.n_params
    }
}

/// Arena of plan-internal scratch buffers.
pub struct Workspace {
    pub(crate) temps: Vec<Vec<f32>>,
}

impl Workspace {
    /// Total arena size in f32s — constant across executions (the
    /// arena-reuse assertion used by the fusion tests).
    pub fn floats(&self) -> usize {
        self.temps.iter().map(|t| t.len()).sum()
    }
}

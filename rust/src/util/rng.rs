//! xoshiro256++ PRNG — deterministic, splittable, no dependencies.
//!
//! Drives every synthetic data generator and initializer in the repo so
//! experiments are exactly reproducible from a seed recorded in
//! EXPERIMENTS.md.

/// xoshiro256++ by Blackman & Vigna (public domain reference).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed, per the xoshiro authors.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Independent child stream (for per-layer / per-shard generators).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Derived stream for shard `idx` — unlike [`Rng::split`] this does
    /// NOT advance `self`, so the stream a replica receives depends only
    /// on the parent's state and its own index, never on how many
    /// sibling shards were derived: replica k's stream is identical
    /// whether R is 1, 2, or 4 (the replicated-engine determinism
    /// contract, DESIGN.md §13).
    pub fn shard_stream(&self, idx: u64) -> Rng {
        let mix = self.s[0]
            ^ self.s[1].rotate_left(17)
            ^ self.s[2].rotate_left(31)
            ^ self.s[3].rotate_left(47);
        Rng::new(mix ^ idx.wrapping_mul(0xD1B54A32D192ED03))
    }

    pub fn next_u64(&mut self) -> u64 {
        let res = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        res
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free (bias negligible for n << 2^64).
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.uniform()).max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * std).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

/// Zipf(α) sampler over ranks [0, n) via a precomputed inverse CDF —
/// the token-frequency model for the synthetic "tinyweb" corpus.
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, alpha: f64) -> ZipfSampler {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += (k as f64).powf(-alpha);
            cdf.push(acc);
        }
        let total = acc;
        for x in &mut cdf {
            *x /= total;
        }
        ZipfSampler { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|x| *x));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_head_heavier_than_tail() {
        let mut r = Rng::new(11);
        let z = ZipfSampler::new(64, 1.2);
        let mut counts = [0usize; 64];
        for _ in 0..20_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[32] * 4, "{:?}", &counts[..8]);
        assert!(counts.iter().sum::<usize>() == 20_000);
    }

    #[test]
    fn zipf_covers_support() {
        let mut r = Rng::new(12);
        let z = ZipfSampler::new(8, 1.0);
        let mut seen = [false; 8];
        for _ in 0..5_000 {
            seen[z.sample(&mut r)] = true;
        }
        assert!(seen.iter().all(|x| *x));
    }

    #[test]
    fn split_streams_independent() {
        let mut base = Rng::new(123);
        let mut a = base.split(1);
        let mut b = base.split(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shard_stream_does_not_advance_parent() {
        let base = Rng::new(77);
        let mut probe = base.clone();
        let before = probe.next_u64();
        let mut s0 = base.shard_stream(0);
        let mut s1 = base.shard_stream(1);
        assert_ne!(s0.next_u64(), s1.next_u64());
        let mut probe2 = base.clone();
        assert_eq!(probe2.next_u64(), before, "parent state must not move");
    }

    #[test]
    fn shard_stream_independent_of_sibling_count() {
        // Replica 1's stream must not depend on whether replicas 2 and 3
        // were ever derived.
        let base = Rng::new(9);
        let mut few = base.shard_stream(1);
        let _ = base.shard_stream(2);
        let _ = base.shard_stream(3);
        let mut many = base.shard_stream(1);
        for _ in 0..8 {
            assert_eq!(few.next_u64(), many.next_u64());
        }
    }
}

//! Minimal JSON parser + emitter.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) — enough to read `artifacts/manifest.json`
//! written by `python/compile/aot.py` and to emit metrics/CSV-companion
//! JSON for the experiment harnesses. No external dependencies.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Object keys are kept sorted (BTreeMap) so emission is
/// deterministic — useful for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key `{key}`"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object"),
        }
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    /// Serialize. `indent = 0` means compact single-line output.
    pub fn emit(&self, indent: usize) -> String {
        let mut out = String::new();
        self.emit_into(&mut out, indent, 0);
        out
    }

    fn emit_into(&self, out: &mut String, indent: usize, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            if indent > 0 {
                out.push('\n');
                for _ in 0..(indent * d) {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if *x == 0.0 && x.is_sign_negative() {
                    // `-0.0 as i64` is 0 — spell the sign out so the
                    // value round-trips bit-exactly (serve checkpoint
                    // streaming relies on emit∘parse being lossless).
                    out.push_str("-0.0");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => emit_string(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    x.emit_into(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    emit_string(out, k);
                    out.push(':');
                    if indent > 0 {
                        out.push(' ');
                    }
                    x.emit_into(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

fn emit_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Maximum container nesting the parser accepts. The parser recurses
/// (value → array/object → value), and it runs on daemon-received bytes
/// (`serve::protocol`), so without a cap a line of ~100k `[`s overflows
/// the stack — an abort, not an `Err`. 64 is far beyond any legitimate
/// payload (the wire forms nest ≤ 5 deep).
const MAX_DEPTH: usize = 64;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected `{}` at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' | b'[' => {
                if self.depth >= MAX_DEPTH {
                    bail!("nesting deeper than {MAX_DEPTH} at byte {}",
                          self.pos);
                }
                self.depth += 1;
                let v = if self.peek()? == b'{' {
                    self.object()
                } else {
                    self.array()
                };
                self.depth -= 1;
                v
            }
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected `,` or `}}`, got `{}`", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected `,` or `]`, got `{}`", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos..self.pos + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        c => bail!("bad escape `\\{}`", c as char),
                    }
                }
                b => {
                    // Re-borrow multi-byte UTF-8 sequences whole.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let chunk = std::str::from_utf8(
                            &self.bytes[start..start + len],
                        )?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number `{text}` at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(t).unwrap();
            assert_eq!(Json::parse(&v.emit(0)).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x\n"}],"c":null}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("a").unwrap().as_arr().unwrap()[2]
                .req("b").unwrap().as_str().unwrap(),
            "x\n"
        );
    }

    #[test]
    fn parse_unicode_escape_and_utf8() {
        let v = Json::parse("\"\\u00e9 caf\u{e9}\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "é café");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn emit_pretty_stable() {
        let v = Json::obj(vec![
            ("b", Json::Num(2.0)),
            ("a", Json::arr_f64(&[1.0, 2.5])),
        ]);
        let s = v.emit(1);
        assert_eq!(Json::parse(&s).unwrap(), v);
        assert!(s.starts_with("{\n \"a\""), "{s}");
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(3.0).emit(0), "3");
        assert_eq!(Json::Num(3.25).emit(0), "3.25");
    }

    #[test]
    fn nesting_depth_is_bounded() {
        // At the cap: parses. One past: clean Err. Way past (a ~100k
        // bracket bomb, as a hostile serve client could send): still a
        // clean Err — no stack overflow, no abort.
        let deep = |n: usize| {
            format!("{}0{}", "[".repeat(n), "]".repeat(n))
        };
        assert!(Json::parse(&deep(MAX_DEPTH)).is_ok());
        assert!(Json::parse(&deep(MAX_DEPTH + 1)).is_err());
        assert!(Json::parse(&"[".repeat(100_000)).is_err());
        let objs = format!("{}1{}",
                           "{\"k\":".repeat(100_000), "}".repeat(100_000));
        assert!(Json::parse(&objs).is_err());
        // Depth is nesting, not sibling count: wide stays fine.
        let wide = format!("[{}0]", "0,".repeat(100_000));
        assert!(Json::parse(&wide).is_ok());
    }

    #[test]
    fn negative_zero_roundtrips() {
        let s = Json::Num(-0.0).emit(0);
        let back = Json::parse(&s).unwrap().as_f64().unwrap();
        assert!(back == 0.0 && back.is_sign_negative(), "{s} -> {back}");
    }
}

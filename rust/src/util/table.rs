//! Markdown/CSV table emitters for the paper-figure harnesses.
//!
//! Every Table/Figure binary prints a markdown table (matching the paper's
//! row/column layout) and optionally writes a CSV series next to it so the
//! curves in EXPERIMENTS.md can be regenerated or re-plotted.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "ragged row");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:w$} |", c, w = widths[i]));
            }
            s
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&line(&self.headers));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// A named time series (step, value) — the unit of every loss-curve figure.
#[derive(Debug, Clone, Default)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Series {
        Series { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|(_, y)| *y)
    }
}

/// Write a bundle of series as a long-form CSV: `series,x,y`.
pub fn write_series_csv(path: impl AsRef<Path>, series: &[Series]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "series,x,y")?;
    for s in series {
        for (x, y) in &s.points {
            writeln!(f, "{},{},{}", s.name, x, y)?;
        }
    }
    Ok(())
}

/// Terminal sparkline of a series (quick visual check of loss curves).
pub fn sparkline(ys: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if ys.is_empty() {
        return String::new();
    }
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &y in ys {
        lo = lo.min(y);
        hi = hi.max(y);
    }
    let span = (hi - lo).max(1e-12);
    ys.iter()
        .map(|y| BARS[(((y - lo) / span) * 7.0).round() as usize])
        .collect()
}

pub fn fmt_f(x: f64, prec: usize) -> String {
    format!("{:.*}", prec, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_layout() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | bb |"), "{md}");
        assert!(md.contains("| 1 | 2  |"), "{md}");
    }

    #[test]
    #[should_panic]
    fn ragged_row_panics() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("mofa_table_test");
        let path = dir.join("t.csv");
        let mut t = Table::new("T", &["x", "y"]);
        t.row(vec!["1".into(), "2".into()]);
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "x,y\n1,2\n");
    }

    #[test]
    fn sparkline_monotone() {
        let s = sparkline(&[0.0, 0.5, 1.0]);
        assert_eq!(s.chars().count(), 3);
    }

    #[test]
    fn series_csv() {
        let dir = std::env::temp_dir().join("mofa_series_test");
        let mut s = Series::new("loss");
        s.push(0.0, 1.0);
        s.push(1.0, 0.5);
        write_series_csv(dir.join("s.csv"), &[s]).unwrap();
        let text = std::fs::read_to_string(dir.join("s.csv")).unwrap();
        assert!(text.contains("loss,1,0.5"));
    }
}

//! Tiny CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Typed getters with defaults keep call sites one-liners.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}={v}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}={v}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}={v}")),
        }
    }

    /// Enumerated option: the value (or `default` when absent) must be
    /// one of `allowed`, e.g. `--autotune=off|on|refresh`.
    pub fn choice_or(&self, name: &str, default: &str, allowed: &[&str])
                     -> Result<String> {
        let v = self.get(name).unwrap_or(default);
        if allowed.contains(&v) {
            Ok(v.to_string())
        } else {
            Err(anyhow!("--{name}={v}: expected one of {}",
                        allowed.join("|")))
        }
    }

    pub fn required(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required --{name}"))
    }

    /// Every `--option`/`--flag` the user passed that is not in `known`
    /// — so a typo like `--replica` (for `--replicas`) can be warned
    /// about instead of silently no-opping. Each subcommand in `main.rs`
    /// calls this with its own accepted list and warns on the result.
    pub fn unknown_options(&self, known: &[&str]) -> Vec<String> {
        self.opts
            .keys()
            .map(|k| k.as_str())
            .chain(self.flags.iter().map(|f| f.as_str()))
            .filter(|name| !known.contains(name))
            .map(|name| name.to_string())
            .collect()
    }

    /// Comma-separated list, e.g. `--ranks 8,16,32`.
    pub fn list_or(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').filter(|s| !s.is_empty())
                .map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = mk(&["train", "--steps", "100", "--fused", "--lr=0.01"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.flag("fused"));
        assert!((a.f64_or("lr", 0.0).unwrap() - 0.01).abs() < 1e-12);
    }

    #[test]
    fn defaults_apply() {
        let a = mk(&[]);
        assert_eq!(a.usize_or("steps", 7).unwrap(), 7);
        assert_eq!(a.str_or("config", "gpt_tiny"), "gpt_tiny");
        assert!(!a.flag("fused"));
    }

    #[test]
    fn negative_number_values() {
        let a = mk(&["--bias=-1.5"]);
        assert!((a.f64_or("bias", 0.0).unwrap() + 1.5).abs() < 1e-12);
    }

    #[test]
    fn list_parsing() {
        let a = mk(&["--ranks", "8,16,32"]);
        assert_eq!(a.list_or("ranks", &[]), vec!["8", "16", "32"]);
        assert_eq!(a.list_or("opts", &["x"]), vec!["x"]);
    }

    #[test]
    fn bad_type_is_error() {
        let a = mk(&["--steps", "abc"]);
        assert!(a.usize_or("steps", 0).is_err());
    }

    #[test]
    fn unknown_options_catches_typos() {
        // `--replica` (typo for --replicas) passed as a value-less flag
        // AND as a key=value must both surface.
        let a = mk(&["train", "--steps", "5", "--replica", "--lr=0.1"]);
        let known = ["steps", "lr", "replicas"];
        assert_eq!(a.unknown_options(&known), vec!["replica"]);
        let b = mk(&["--replica=2", "--steps", "5"]);
        assert_eq!(b.unknown_options(&known), vec!["replica"]);
        // Fully-known lines stay quiet; positionals never count.
        assert!(a.unknown_options(&["steps", "lr", "replica"]).is_empty());
        assert!(mk(&["train"]).unknown_options(&[]).is_empty());
    }

    #[test]
    fn choice_validates_against_allowed() {
        let modes = ["off", "on", "refresh"];
        let a = mk(&["--autotune", "refresh"]);
        assert_eq!(a.choice_or("autotune", "off", &modes).unwrap(),
                   "refresh");
        assert_eq!(mk(&[]).choice_or("autotune", "off", &modes).unwrap(),
                   "off");
        assert!(mk(&["--autotune=banana"])
            .choice_or("autotune", "off", &modes)
            .is_err());
    }
}

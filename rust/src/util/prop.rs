//! Minimal property-testing harness (proptest is not in the vendor set).
//!
//! `check` runs a property over `cases` seeded random inputs and, on
//! failure, reports the failing seed so the case can be replayed with
//! `Prop::replay(seed)`. Used by the optimizer-invariant suites in
//! `optim::*` and `linalg::*`.

use super::rng::Rng;

pub struct Prop {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        Prop { cases: 64, seed: 0xC0FFEE }
    }
}

impl Prop {
    pub fn new(cases: usize) -> Prop {
        Prop { cases, ..Prop::default() }
    }

    /// Replay a single failing case.
    pub fn replay(seed: u64) -> Prop {
        Prop { cases: 1, seed }
    }

    /// Run `property(rng)`; the property panics (assert!) on violation.
    pub fn check<F: FnMut(&mut Rng)>(&self, name: &str, mut property: F) {
        for case in 0..self.cases {
            let case_seed = self
                .seed
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(case as u64);
            let mut rng = Rng::new(case_seed);
            let result = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| property(&mut rng)),
            );
            if let Err(payload) = result {
                let msg = payload
                    .downcast_ref::<String>()
                    .map(|s| s.as_str())
                    .or_else(|| payload.downcast_ref::<&str>().copied())
                    .unwrap_or("<non-string panic>");
                panic!(
                    "property `{name}` failed at case {case} \
                     (replay seed {case_seed:#x}): {msg}"
                );
            }
        }
    }
}

/// Random dimension helper: log-uniform in [1, max] biased toward small.
pub fn dim(rng: &mut Rng, max: usize) -> usize {
    let log_max = (max as f64).ln();
    ((rng.uniform() * log_max).exp() as usize).clamp(1, max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Prop::new(16).check("sum-commutes", |rng| {
            let a = rng.uniform();
            let b = rng.uniform();
            assert!((a + b - (b + a)).abs() < 1e-15);
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_reports_seed() {
        Prop::new(4).check("always-fails", |_| panic!("boom"));
    }

    #[test]
    fn dim_in_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let d = dim(&mut rng, 64);
            assert!((1..=64).contains(&d));
        }
    }
}

//! Leveled stderr logger with wall-clock offsets.
//!
//! Levels: [`QUIET`] < [`WARN`] < [`INFO`] (default) < [`DEBUG`]. The
//! initial level comes from `MOFA_LOG` (`quiet`/`warn`/`info`/`debug` or
//! `0`–`3`), resolved lazily on first use; [`set_level`] overrides it.
//!
//! Each line is formatted in full and written with a single `write_all`
//! on a locked stderr handle, so lines from concurrent pool/fleet
//! workers never tear into each other.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

pub const QUIET: u8 = 0;
pub const WARN: u8 = 1;
pub const INFO: u8 = 2;
pub const DEBUG: u8 = 3;

const UNSET: u8 = u8::MAX;

static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: u8) {
    LEVEL.store(level.min(DEBUG), Ordering::Relaxed);
}

pub fn level() -> u8 {
    match LEVEL.load(Ordering::Relaxed) {
        UNSET => init_from_env(),
        l => l,
    }
}

#[cold]
fn init_from_env() -> u8 {
    let l = match std::env::var("MOFA_LOG").ok().as_deref() {
        Some("quiet") | Some("0") => QUIET,
        Some("warn") | Some("1") => WARN,
        Some("debug") | Some("3") => DEBUG,
        _ => INFO,
    };
    set_level(l);
    l
}

pub fn elapsed_s() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Format the full line first, then write it atomically under the
/// stderr lock — concurrent workers' lines interleave whole, never torn.
fn emit(tag: &str, msg: &str) {
    let line = format!("[{:8.1}s] {}{}\n", elapsed_s(), tag, msg);
    let stderr = std::io::stderr();
    let mut out = stderr.lock();
    let _ = out.write_all(line.as_bytes());
}

/// Error-adjacent but recoverable events (poisoned fleet locks, aborted
/// task-graph dispatches). Suppressed only by `quiet`.
pub fn warn(msg: impl AsRef<str>) {
    if level() >= WARN {
        emit("WARN ", msg.as_ref());
    }
}

pub fn info(msg: impl AsRef<str>) {
    if level() >= INFO {
        emit("", msg.as_ref());
    }
}

pub fn debug(msg: impl AsRef<str>) {
    if level() >= DEBUG {
        emit("DBG ", msg.as_ref());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // One test mutates the (process-global) level — merged so parallel
    // test threads can't observe each other's set_level.
    #[test]
    fn level_roundtrip_and_warn_gate() {
        let old = level(); // also resolves MOFA_LOG lazily
        set_level(DEBUG);
        assert_eq!(level(), DEBUG);
        set_level(WARN);
        assert!(level() >= WARN && level() < INFO);
        warn("logging self-test warn line"); // must not panic or tear
        set_level(200); // clamps
        assert_eq!(level(), DEBUG);
        set_level(old);
    }

    #[test]
    fn elapsed_monotone() {
        let a = elapsed_s();
        let b = elapsed_s();
        assert!(b >= a);
    }
}

//! Leveled stderr logger with wall-clock offsets.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(1); // 0=quiet 1=info 2=debug

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

pub fn elapsed_s() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn info(msg: impl AsRef<str>) {
    if level() >= 1 {
        eprintln!("[{:8.1}s] {}", elapsed_s(), msg.as_ref());
    }
}

pub fn debug(msg: impl AsRef<str>) {
    if level() >= 2 {
        eprintln!("[{:8.1}s] DBG {}", elapsed_s(), msg.as_ref());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        let old = level();
        set_level(2);
        assert_eq!(level(), 2);
        set_level(old);
    }

    #[test]
    fn elapsed_monotone() {
        let a = elapsed_s();
        let b = elapsed_s();
        assert!(b >= a);
    }
}

//! Crash-safe file I/O: atomic write with a CRC32 integrity footer.
//!
//! Durability contract (DESIGN.md §15): `atomic_write_crc` writes the payload
//! plus a 4-byte little-endian CRC32 footer to `<path>.tmp`, calls
//! `sync_all`, then atomically renames over `path` and best-effort fsyncs the
//! parent directory. A crash at any point leaves either the old file intact
//! or the new file complete — never a torn final file. `read_crc` verifies
//! the footer before returning the payload, so corruption that slips past
//! the rename (disk bit-rot, a torn write simulated by fault injection) is
//! detected at load time, not silently consumed.

use std::fs;
use std::io::{self, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Monotone counter of checkpoint writes, used as the `ckpt` coordinate for
/// `torn_write@ckpt:N` fault rules. Reset whenever a fault spec is installed
/// so "the Nth write" is deterministic per test.
static WRITE_SEQ: AtomicU64 = AtomicU64::new(0);

pub(crate) fn reset_write_seq() {
    WRITE_SEQ.store(0, Ordering::Release);
}

/// CRC32 (IEEE 802.3, reflected, poly 0xEDB88320). Known answer:
/// `crc32(b"123456789") == 0xCBF4_3926`.
pub fn crc32(data: &[u8]) -> u32 {
    // Table built on first use; 256 u32s, cheap enough to compute lazily.
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        let mut i = 0usize;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            t[i] = c;
            i += 1;
        }
        t
    });
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

/// Atomically write `payload` + CRC32 footer to `path`.
///
/// Sequence: write to `<path>.tmp`, `sync_all`, rename over `path`,
/// best-effort fsync of the parent directory. Honors the `torn_write` fault
/// injection point: a matching rule makes this write only the first half of
/// the payload (no footer) directly to the final path — simulating a crash
/// mid-write with the legacy in-place scheme — and still return `Ok`.
pub fn atomic_write_crc(path: &Path, payload: &[u8]) -> io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent)?;
        }
    }
    let seq = WRITE_SEQ.fetch_add(1, Ordering::AcqRel) + 1;
    if super::faultinject::torn(&[("ckpt", seq)]) {
        crate::util::logging::warn(format!(
            "fsio: injected torn write #{seq} at {}",
            path.display()
        ));
        fs::write(path, &payload[..payload.len() / 2])?;
        return Ok(());
    }
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(payload)?;
        f.write_all(&crc32(payload).to_le_bytes())?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)?;
    // Durability of the rename itself: fsync the directory. Best-effort —
    // some filesystems refuse to open directories for sync.
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            if let Ok(d) = fs::File::open(parent) {
                let _ = d.sync_all();
            }
        }
    }
    Ok(())
}

/// Read a file written by [`atomic_write_crc`], verifying the CRC32 footer.
pub fn read_crc(path: &Path) -> io::Result<Vec<u8>> {
    let mut data = fs::read(path)?;
    if data.len() < 4 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("{}: too short for CRC footer", path.display()),
        ));
    }
    let n = data.len() - 4;
    let stored = u32::from_le_bytes([data[n], data[n + 1], data[n + 2], data[n + 3]]);
    data.truncate(n);
    let actual = crc32(&data);
    if stored != actual {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!(
                "{}: CRC mismatch (stored {stored:08x}, computed {actual:08x})",
                path.display()
            ),
        ));
    }
    Ok(data)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("mofa-fsio-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn crc32_known_answer() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_and_no_tmp_left_behind() {
        let d = tmpdir("rt");
        let p = d.join("a.bin");
        atomic_write_crc(&p, b"hello world").unwrap();
        assert_eq!(read_crc(&p).unwrap(), b"hello world");
        let mut tmp = p.as_os_str().to_os_string();
        tmp.push(".tmp");
        assert!(!std::path::Path::new(&tmp).exists());
        // Overwrite is atomic too.
        atomic_write_crc(&p, b"second").unwrap();
        assert_eq!(read_crc(&p).unwrap(), b"second");
        let _ = fs::remove_dir_all(&d);
    }

    #[test]
    fn corruption_and_truncation_are_detected() {
        let d = tmpdir("bad");
        let p = d.join("a.bin");
        atomic_write_crc(&p, b"payload bytes").unwrap();
        let mut raw = fs::read(&p).unwrap();
        raw[3] ^= 0x40;
        fs::write(&p, &raw).unwrap();
        assert!(read_crc(&p).is_err());
        fs::write(&p, b"xy").unwrap();
        assert!(read_crc(&p).is_err());
        let _ = fs::remove_dir_all(&d);
    }
}

//! Scoped fork-join helper over std threads (tokio/rayon unavailable).
//!
//! `scope_chunks` runs a closure over disjoint index chunks in parallel and
//! is the building block for the blocked matmul in `linalg` and for
//! per-layer optimizer dispatch in the coordinator. `run_task_graph`
//! drains a dependency graph of tasks through one shared ready queue —
//! the single-dispatch primitive under `fusion::fleet`. On the 1-core CI
//! box both degrade gracefully to sequential execution.

use crate::obs;
use crate::util::logging;

/// Number of worker threads to use (defaults to available parallelism).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_index, start, end)` over `n` items split into `workers`
/// contiguous chunks, in parallel. `f` must be Sync; disjointness of chunks
/// is the caller's safety contract for any interior-mutable access.
pub fn scope_chunks<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n == 0 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(w, start, end));
        }
    });
}

/// Map `f` over items in parallel, preserving order. Each worker maps one
/// disjoint contiguous chunk and the chunks are stitched back in order —
/// no per-element locking, and no `Default + Clone` bound on `R`.
pub fn par_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let workers = workers.max(1).min(items.len().max(1));
    if workers <= 1 {
        return items.iter().map(&f).collect();
    }
    let chunk = items.len().div_ceil(workers);
    std::thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|ch| {
                let f = &f;
                s.spawn(move || ch.iter().map(f).collect::<Vec<R>>())
            })
            .collect();
        // Join EVERY worker before propagating a panic: bailing on the
        // first Err would leave siblings running against borrowed data,
        // and `expect` would replace the original payload with a generic
        // one. Resume the first captured payload instead.
        let mut out = Vec::with_capacity(items.len());
        let mut first_panic: Option<Box<dyn std::any::Any + Send>> = None;
        for h in handles {
            match h.join() {
                Ok(chunk) => out.extend(chunk),
                Err(payload) => {
                    if first_panic.is_none() {
                        first_panic = Some(payload);
                    }
                }
            }
        }
        if let Some(payload) = first_panic {
            std::panic::resume_unwind(payload);
        }
        out
    })
}

/// Raw-pointer handle for parallel mutation of *disjoint rows* of one
/// row-major buffer — the row-granular analogue of the `scope_chunks`
/// disjointness contract. Used by the round-robin parallel Jacobi sweep
/// in `linalg::svd`, where each round rotates k/2 disjoint column pairs
/// (stored as rows of the transposed working matrix) concurrently.
#[derive(Clone, Copy)]
pub struct RowsPtr {
    ptr: *mut f32,
    stride: usize,
    rows: usize,
}

// SAFETY: RowsPtr is only a capability to *derive* row slices; the caller
// promises (see `row_mut`) that concurrently derived rows never overlap.
unsafe impl Send for RowsPtr {}
unsafe impl Sync for RowsPtr {}

impl RowsPtr {
    pub fn new(data: &mut [f32], stride: usize) -> RowsPtr {
        assert!(stride > 0 && data.len() % stride == 0,
                "RowsPtr stride must divide the buffer");
        RowsPtr { ptr: data.as_mut_ptr(), stride, rows: data.len() / stride }
    }

    /// Exclusive view of row `i`.
    ///
    /// # Safety
    /// No other live reference — on any thread — may overlap row `i`
    /// while the returned slice is alive.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_mut(&self, i: usize) -> &mut [f32] {
        assert!(i < self.rows, "RowsPtr row {i} out of {}", self.rows);
        std::slice::from_raw_parts_mut(self.ptr.add(i * self.stride),
                                       self.stride)
    }
}

/// `dst[i] += src[i]`, chunk-parallel. Small vectors stay on the calling
/// thread (the add is memory-bandwidth-bound; fork-join only pays off on
/// large parameters).
pub fn par_add_assign(dst: &mut [f32], src: &[f32], workers: usize) {
    assert_eq!(dst.len(), src.len(), "par_add_assign length mismatch");
    const MIN_PAR: usize = 1 << 15;
    let workers = workers.max(1).min(dst.len().max(1));
    if workers <= 1 || dst.len() < MIN_PAR {
        for (a, b) in dst.iter_mut().zip(src) {
            *a += *b;
        }
        return;
    }
    let chunk = dst.len().div_ceil(workers);
    std::thread::scope(|s| {
        for (d, sr) in dst.chunks_mut(chunk).zip(src.chunks(chunk)) {
            s.spawn(move || {
                for (a, b) in d.iter_mut().zip(sr) {
                    *a += *b;
                }
            });
        }
    });
}

/// Execute a dependency graph of `n_tasks` tasks over a shared ready
/// queue with `workers` threads — ONE fork-join for the whole graph,
/// which is what the fleet executor amortizes per-kernel spawns into.
///
/// `seeds` are the initially-ready task ids. `f(task, ready)` runs one
/// task and reports, through `ready`, every task id whose dependencies
/// that completion satisfied (callers track readiness with per-task
/// dependency counters; a task must be reported exactly once, and every
/// task in `0..n_tasks` must eventually run or the dispatch deadlocks —
/// at most 8 tasks may be reported per completion). Idle workers sleep
/// on a condvar until work appears or the graph drains.
///
/// With `workers <= 1` the graph runs inline on the calling thread
/// (seeds in order, reported successors depth-first) — deterministic
/// order, no threads.
pub fn run_task_graph<F>(n_tasks: usize, seeds: &[usize], workers: usize,
                         f: F)
where
    F: Fn(usize, &mut dyn FnMut(usize)) + Sync,
{
    run_task_graph_described(n_tasks, seeds, workers, f,
                             |t| format!("task {t}"));
}

/// Best-effort human label for a panic payload (the `&str` / `String`
/// payloads `panic!` produces; anything else is opaque).
pub(crate) fn panic_payload_msg(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// [`run_task_graph`] with caller-supplied task labels: when a task
/// panics, the abort warn names the failing stage/unit via
/// `describe(task)` (plus the panic message) instead of only a generic
/// line — so a replica-stage failure is attributable from logs.
/// `describe` is called only on the panic path.
pub fn run_task_graph_described<F, D>(n_tasks: usize, seeds: &[usize],
                                      workers: usize, f: F, describe: D)
where
    F: Fn(usize, &mut dyn FnMut(usize)) + Sync,
    D: Fn(usize) -> String + Sync,
{
    if n_tasks == 0 {
        return;
    }
    let workers = workers.max(1).min(n_tasks);
    if workers <= 1 {
        let mut stack: Vec<usize> = seeds.iter().rev().copied().collect();
        let mut done = 0usize;
        while let Some(t) = stack.pop() {
            {
                let _sp = obs::span_args(obs::Category::Task, "task_exec",
                                         [t as u32, 0, 0]);
                let run = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        f(t, &mut |nt| stack.push(nt));
                    }),
                );
                if let Err(payload) = run {
                    logging::warn(format!(
                        "run_task_graph: {} panicked ({}); \
                         aborting dispatch",
                        describe(t), panic_payload_msg(payload.as_ref())));
                    std::panic::resume_unwind(payload);
                }
            }
            obs::counter_add(obs::Counter::TasksRun, 1);
            done += 1;
        }
        assert_eq!(done, n_tasks, "task graph did not drain");
        return;
    }
    struct State {
        ready: Vec<usize>,
        remaining: usize,
        /// Epoch-ns ready timestamps per task for queue-wait spans;
        /// empty when tracing is off (no allocation, no stamping).
        ready_at: Vec<u64>,
    }
    let mut ready = Vec::with_capacity(n_tasks);
    ready.extend_from_slice(seeds);
    let mut ready_at = Vec::new();
    if obs::enabled() {
        ready_at = vec![0u64; n_tasks];
        let now = obs::now_ns();
        for &t in seeds {
            ready_at[t] = now;
        }
    }
    let state =
        std::sync::Mutex::new(State { ready, remaining: n_tasks, ready_at });
    let cv = std::sync::Condvar::new();
    // Poison-tolerant lock: after a task panic the graph is being torn
    // down and the state is only used to signal "stop" — propagating the
    // poison would turn one panic into a hang or a double panic.
    let lock_state = || match state.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let (task, ready_ns) = {
                    let mut st = lock_state();
                    loop {
                        if st.remaining == 0 {
                            return;
                        }
                        if let Some(t) = st.ready.pop() {
                            let r = st.ready_at.get(t).copied().unwrap_or(0);
                            break (t, r);
                        }
                        st = match cv.wait(st) {
                            Ok(g) => g,
                            Err(p) => p.into_inner(),
                        };
                    }
                };
                if ready_ns != 0 {
                    // Queue wait: became-ready → picked-up.
                    obs::record_raw(obs::Category::Task, "task_wait",
                                    ready_ns, obs::now_ns(),
                                    [task as u32, 0, 0]);
                }
                // Run outside the lock; buffer the newly-ready ids. A
                // panicking task aborts the whole graph (remaining = 0
                // wakes and releases every sibling, so thread::scope can
                // join them and propagate the panic) instead of leaving
                // the siblings asleep forever.
                let mut buf = [0usize; 8];
                let mut nb = 0usize;
                let exec_span = obs::span_args(obs::Category::Task,
                                               "task_exec",
                                               [task as u32, 0, 0]);
                let run = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        f(task, &mut |nt| {
                            assert!(nb < buf.len(), "too many successors");
                            buf[nb] = nt;
                            nb += 1;
                        });
                    }),
                );
                drop(exec_span);
                obs::counter_add(obs::Counter::TasksRun, 1);
                if let Err(payload) = run {
                    logging::warn(format!(
                        "run_task_graph: {} panicked ({}); \
                         aborting dispatch",
                        describe(task),
                        panic_payload_msg(payload.as_ref())));
                    let mut st = lock_state();
                    st.remaining = 0;
                    drop(st);
                    cv.notify_all();
                    std::panic::resume_unwind(payload);
                }
                let mut st = lock_state();
                if st.remaining == 0 {
                    // A sibling's panic aborted the graph while this task
                    // was in flight — don't underflow the counter back to
                    // "not done" (usize wrap ⇒ permanent hang).
                    return;
                }
                st.remaining -= 1;
                if !st.ready_at.is_empty() && nb > 0 {
                    let now = obs::now_ns();
                    for &nt in &buf[..nb] {
                        st.ready_at[nt] = now;
                    }
                }
                st.ready.extend_from_slice(&buf[..nb]);
                obs::counter_max(obs::Counter::QueueDepthHw,
                                 st.ready.len() as u64);
                if st.remaining == 0 {
                    cv.notify_all();
                } else {
                    for _ in 0..nb {
                        cv.notify_one();
                    }
                }
            });
        }
    });
}

/// [`run_task_graph_described`] with **fair-share ready ordering**: every
/// task belongs to a group (`group_of[t]`, e.g. a serve session id) and
/// ready tasks drain round-robin *across groups* instead of LIFO — a
/// tenant with many ready stages cannot starve a tenant with few, which
/// is the multiplexing contract of `serve::SessionManager`.
///
/// Dependency semantics are identical to [`run_task_graph`]: `seeds` are
/// the initially-ready ids, `f(task, ready)` reports newly-ready ids
/// (each exactly once, ≤ 8 per completion), every task must eventually
/// run. Scheduling order is the ONLY difference, and per-task math must
/// not depend on it (the caller's groups are independent); with
/// `workers <= 1` the drain is fully deterministic: starting from group
/// 0, the scheduler repeatedly takes the oldest ready task of the next
/// non-empty group in cyclic group order.
///
/// Group ids must be DENSE (`0..n_groups`): the group table is sized
/// `max(group_of) + 1` and every pop scans it cyclically, so sparse ids
/// cost memory and time proportional to the max id, not the group
/// count. Callers with sparse natural ids (e.g. monotonic serve session
/// ids) compact them first — see `Fleet::run_fair`.
pub fn run_task_graph_fair<F, D>(n_tasks: usize, seeds: &[usize],
                                 workers: usize, group_of: &[u32], f: F,
                                 describe: D)
where
    F: Fn(usize, &mut dyn FnMut(usize)) + Sync,
    D: Fn(usize) -> String + Sync,
{
    use std::collections::VecDeque;

    if n_tasks == 0 {
        return;
    }
    assert_eq!(group_of.len(), n_tasks, "group_of covers every task");
    let n_groups = group_of.iter().map(|&g| g as usize + 1).max().unwrap();
    let workers = workers.max(1).min(n_tasks);

    // Oldest ready task of the next non-empty group at/after `cursor`
    // (cyclic); advances the cursor past the chosen group.
    fn pop_fair(queues: &mut [VecDeque<usize>], cursor: &mut usize)
                -> Option<usize> {
        let n = queues.len();
        for k in 0..n {
            let g = (*cursor + k) % n;
            if let Some(t) = queues[g].pop_front() {
                *cursor = (g + 1) % n;
                return Some(t);
            }
        }
        None
    }

    if workers <= 1 {
        let mut queues: Vec<VecDeque<usize>> =
            (0..n_groups).map(|_| VecDeque::new()).collect();
        for &t in seeds {
            queues[group_of[t] as usize].push_back(t);
        }
        let mut cursor = 0usize;
        let mut done = 0usize;
        while let Some(t) = pop_fair(&mut queues, &mut cursor) {
            {
                let _sp = obs::span_args(obs::Category::Task, "task_exec",
                                         [t as u32, group_of[t], 0]);
                let run = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        f(t, &mut |nt| {
                            queues[group_of[nt] as usize].push_back(nt);
                        });
                    }),
                );
                if let Err(payload) = run {
                    logging::warn(format!(
                        "run_task_graph_fair: {} panicked ({}); \
                         aborting dispatch",
                        describe(t), panic_payload_msg(payload.as_ref())));
                    std::panic::resume_unwind(payload);
                }
            }
            obs::counter_add(obs::Counter::TasksRun, 1);
            done += 1;
        }
        assert_eq!(done, n_tasks, "fair task graph did not drain");
        return;
    }

    struct FairState {
        queues: Vec<VecDeque<usize>>,
        cursor: usize,
        n_ready: usize,
        remaining: usize,
        ready_at: Vec<u64>,
    }
    let mut queues: Vec<VecDeque<usize>> =
        (0..n_groups).map(|_| VecDeque::new()).collect();
    for &t in seeds {
        queues[group_of[t] as usize].push_back(t);
    }
    let mut ready_at = Vec::new();
    if obs::enabled() {
        ready_at = vec![0u64; n_tasks];
        let now = obs::now_ns();
        for &t in seeds {
            ready_at[t] = now;
        }
    }
    let state = std::sync::Mutex::new(FairState {
        queues,
        cursor: 0,
        n_ready: seeds.len(),
        remaining: n_tasks,
        ready_at,
    });
    let cv = std::sync::Condvar::new();
    // Poison-tolerant lock, as in `run_task_graph_described`.
    let lock_state = || match state.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let (task, ready_ns) = {
                    let mut st = lock_state();
                    loop {
                        if st.remaining == 0 {
                            return;
                        }
                        let mut cursor = st.cursor;
                        if let Some(t) = pop_fair(&mut st.queues,
                                                  &mut cursor) {
                            st.cursor = cursor;
                            st.n_ready -= 1;
                            let r = st.ready_at.get(t).copied().unwrap_or(0);
                            break (t, r);
                        }
                        st = match cv.wait(st) {
                            Ok(g) => g,
                            Err(p) => p.into_inner(),
                        };
                    }
                };
                if ready_ns != 0 {
                    obs::record_raw(obs::Category::Task, "task_wait",
                                    ready_ns, obs::now_ns(),
                                    [task as u32, group_of[task], 0]);
                }
                let mut buf = [0usize; 8];
                let mut nb = 0usize;
                let exec_span = obs::span_args(obs::Category::Task,
                                               "task_exec",
                                               [task as u32,
                                                group_of[task], 0]);
                let run = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        f(task, &mut |nt| {
                            assert!(nb < buf.len(), "too many successors");
                            buf[nb] = nt;
                            nb += 1;
                        });
                    }),
                );
                drop(exec_span);
                obs::counter_add(obs::Counter::TasksRun, 1);
                if let Err(payload) = run {
                    logging::warn(format!(
                        "run_task_graph_fair: {} panicked ({}); \
                         aborting dispatch",
                        describe(task),
                        panic_payload_msg(payload.as_ref())));
                    let mut st = lock_state();
                    st.remaining = 0;
                    drop(st);
                    cv.notify_all();
                    std::panic::resume_unwind(payload);
                }
                let mut st = lock_state();
                if st.remaining == 0 {
                    return;
                }
                st.remaining -= 1;
                if !st.ready_at.is_empty() && nb > 0 {
                    let now = obs::now_ns();
                    for &nt in &buf[..nb] {
                        st.ready_at[nt] = now;
                    }
                }
                for &nt in &buf[..nb] {
                    st.queues[group_of[nt] as usize].push_back(nt);
                }
                st.n_ready += nb;
                obs::counter_max(obs::Counter::QueueDepthHw,
                                 st.n_ready as u64);
                if st.remaining == 0 {
                    cv.notify_all();
                } else {
                    for _ in 0..nb {
                        cv.notify_one();
                    }
                }
            });
        }
    });
}

/// Per-group result of a fault-isolated graph dispatch.
#[derive(Clone, Debug, PartialEq)]
pub enum GroupOutcome {
    /// Every task of the group ran to completion.
    Ok,
    /// A task of the group panicked (or the group's remaining tasks were
    /// stranded by a dependency contract violation); `task` is the first
    /// failing task id, `msg` the panic payload.
    Failed { task: usize, msg: String },
}

impl GroupOutcome {
    pub fn is_ok(&self) -> bool {
        matches!(self, GroupOutcome::Ok)
    }
}

/// Fault-isolated variant of [`run_task_graph_fair`]: a task panic no
/// longer aborts the whole dispatch. Instead the panic is contained to the
/// task's *group* — the group's not-yet-started tasks are cancelled (ready
/// tasks purged, unrevealed tasks phantom-cancelled against the static
/// per-group totals from `group_of`), in-flight siblings drain, and every
/// other group runs to completion exactly as if the failed group's
/// remaining work had never existed. Per-group outcomes land in
/// `outcomes[g]` (cleared and resized to the group count).
///
/// Extra contract on top of [`run_task_graph_fair`]: every successor a
/// task reports must belong to the *same group* as the reporting task
/// (true for the fleet's per-session chains). A violation is asserted
/// inside the task's panic scope, so it becomes that group's contained
/// failure; any tasks left unreachable by such a bug (or by a missed
/// reveal) are detected when the graph stalls and fail their groups with
/// a "stranded" outcome instead of deadlocking the dispatch.
pub fn run_task_graph_fair_isolated<F, D>(
    n_tasks: usize, seeds: &[usize], workers: usize, group_of: &[u32],
    f: F, describe: D, outcomes: &mut Vec<GroupOutcome>)
where
    F: Fn(usize, &mut dyn FnMut(usize)) + Sync,
    D: Fn(usize) -> String + Sync,
{
    use std::collections::VecDeque;

    outcomes.clear();
    if n_tasks == 0 {
        return;
    }
    assert_eq!(group_of.len(), n_tasks, "group_of covers every task");
    let n_groups = group_of.iter().map(|&g| g as usize + 1).max().unwrap();
    let workers = workers.max(1).min(n_tasks);
    outcomes.resize_with(n_groups, || GroupOutcome::Ok);
    let mut total = vec![0usize; n_groups];
    for &g in group_of {
        total[g as usize] += 1;
    }
    let first_of = |g: usize| {
        group_of.iter().position(|&gg| gg as usize == g).unwrap_or(0)
    };

    fn pop_fair(queues: &mut [VecDeque<usize>], cursor: &mut usize)
                -> Option<usize> {
        let n = queues.len();
        for k in 0..n {
            let g = (*cursor + k) % n;
            if let Some(t) = queues[g].pop_front() {
                *cursor = (g + 1) % n;
                return Some(t);
            }
        }
        None
    }

    if workers <= 1 {
        let mut queues: Vec<VecDeque<usize>> =
            (0..n_groups).map(|_| VecDeque::new()).collect();
        for &t in seeds {
            queues[group_of[t] as usize].push_back(t);
        }
        let mut seen = vec![0usize; n_groups];
        let mut cursor = 0usize;
        let mut done = 0usize;
        while let Some(t) = pop_fair(&mut queues, &mut cursor) {
            let g = group_of[t] as usize;
            let run;
            {
                let _sp = obs::span_args(obs::Category::Task, "task_exec",
                                         [t as u32, g as u32, 0]);
                run = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        f(t, &mut |nt| {
                            assert_eq!(
                                group_of[nt] as usize, g,
                                "isolated graph: task {t} reported \
                                 cross-group successor {nt}"
                            );
                            queues[g].push_back(nt);
                        });
                    }),
                );
            }
            obs::counter_add(obs::Counter::TasksRun, 1);
            seen[g] += 1;
            done += 1;
            if let Err(payload) = run {
                let msg = panic_payload_msg(payload.as_ref()).to_string();
                logging::warn(format!(
                    "run_task_graph_fair_isolated: {} panicked ({msg}); \
                     cancelling group {g}, other groups continue",
                    describe(t)));
                if outcomes[g].is_ok() {
                    outcomes[g] = GroupOutcome::Failed { task: t, msg };
                    let purged = queues[g].len();
                    queues[g].clear();
                    let phantom = total[g] - (seen[g] + purged);
                    seen[g] += purged + phantom;
                    done += purged + phantom;
                }
            }
        }
        if done < n_tasks {
            // Dependency contract breach left tasks unreachable; fail
            // their groups cleanly instead of asserting mid-drain.
            for g in 0..n_groups {
                let deficit = total[g] - seen[g];
                if deficit == 0 {
                    continue;
                }
                logging::warn(format!(
                    "run_task_graph_fair_isolated: group {g} stranded \
                     {deficit} task(s) that never became ready"));
                if outcomes[g].is_ok() {
                    outcomes[g] = GroupOutcome::Failed {
                        task: first_of(g),
                        msg: "stranded: tasks never became ready"
                            .to_string(),
                    };
                }
                seen[g] += deficit;
                done += deficit;
            }
        }
        debug_assert_eq!(done, n_tasks, "isolated fair graph accounting");
        return;
    }

    struct IsoState {
        queues: Vec<VecDeque<usize>>,
        cursor: usize,
        n_ready: usize,
        remaining: usize,
        ready_at: Vec<u64>,
        /// Per-group count of accounted tasks (ran, purged, or
        /// phantom-cancelled).
        seen: Vec<usize>,
        /// Per-group count of tasks currently executing on a worker.
        inflight: Vec<usize>,
        inflight_total: usize,
        fail: Vec<Option<(usize, String)>>,
    }
    let mut queues: Vec<VecDeque<usize>> =
        (0..n_groups).map(|_| VecDeque::new()).collect();
    for &t in seeds {
        queues[group_of[t] as usize].push_back(t);
    }
    let mut ready_at = Vec::new();
    if obs::enabled() {
        ready_at = vec![0u64; n_tasks];
        let now = obs::now_ns();
        for &t in seeds {
            ready_at[t] = now;
        }
    }
    let state = std::sync::Mutex::new(IsoState {
        queues,
        cursor: 0,
        n_ready: seeds.len(),
        remaining: n_tasks,
        ready_at,
        seen: vec![0usize; n_groups],
        inflight: vec![0usize; n_groups],
        inflight_total: 0,
        fail: vec![None; n_groups],
    });
    let cv = std::sync::Condvar::new();
    // Poison-tolerant lock, as in `run_task_graph_described`. Workers
    // never unwind while holding the lock (task panics are caught before
    // re-locking), but tolerate poison anyway.
    let lock_state = || match state.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let (task, ready_ns) = {
                    let mut st = lock_state();
                    loop {
                        if st.remaining == 0 {
                            return;
                        }
                        let mut cursor = st.cursor;
                        if let Some(t) = pop_fair(&mut st.queues,
                                                  &mut cursor) {
                            st.cursor = cursor;
                            st.n_ready -= 1;
                            st.inflight[group_of[t] as usize] += 1;
                            st.inflight_total += 1;
                            let r = st.ready_at.get(t).copied().unwrap_or(0);
                            break (t, r);
                        }
                        if st.inflight_total == 0 {
                            // Nothing ready, nothing running, work left:
                            // the remaining tasks are unreachable. Fail
                            // their groups instead of deadlocking.
                            for g in 0..st.seen.len() {
                                let deficit = total[g] - st.seen[g];
                                if deficit == 0 {
                                    continue;
                                }
                                logging::warn(format!(
                                    "run_task_graph_fair_isolated: group \
                                     {g} stranded {deficit} task(s) that \
                                     never became ready"));
                                if st.fail[g].is_none() {
                                    st.fail[g] = Some((
                                        first_of(g),
                                        "stranded: tasks never became \
                                         ready".to_string(),
                                    ));
                                }
                                st.seen[g] += deficit;
                                st.remaining -= deficit;
                            }
                            drop(st);
                            cv.notify_all();
                            return;
                        }
                        st = match cv.wait(st) {
                            Ok(g) => g,
                            Err(p) => p.into_inner(),
                        };
                    }
                };
                let g = group_of[task] as usize;
                if ready_ns != 0 {
                    obs::record_raw(obs::Category::Task, "task_wait",
                                    ready_ns, obs::now_ns(),
                                    [task as u32, g as u32, 0]);
                }
                let mut buf = [0usize; 8];
                let mut nb = 0usize;
                let exec_span = obs::span_args(obs::Category::Task,
                                               "task_exec",
                                               [task as u32, g as u32, 0]);
                let run = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        f(task, &mut |nt| {
                            assert!(nb < buf.len(), "too many successors");
                            assert_eq!(
                                group_of[nt] as usize, g,
                                "isolated graph: task {task} reported \
                                 cross-group successor {nt}"
                            );
                            buf[nb] = nt;
                            nb += 1;
                        });
                    }),
                );
                drop(exec_span);
                obs::counter_add(obs::Counter::TasksRun, 1);
                let mut st = lock_state();
                st.inflight[g] -= 1;
                st.inflight_total -= 1;
                st.seen[g] += 1;
                st.remaining -= 1;
                match run {
                    Err(payload) => {
                        let msg =
                            panic_payload_msg(payload.as_ref()).to_string();
                        logging::warn(format!(
                            "run_task_graph_fair_isolated: {} panicked \
                             ({msg}); cancelling group {g}, other groups \
                             continue",
                            describe(task)));
                        if st.fail[g].is_none() {
                            st.fail[g] = Some((task, msg));
                            // Cancel the group's ready tasks, then
                            // phantom-cancel the unrevealed remainder
                            // (everything not accounted and not still
                            // in flight on a sibling worker).
                            let purged = st.queues[g].len();
                            st.queues[g].clear();
                            st.n_ready -= purged;
                            let phantom =
                                total[g] - st.seen[g] - purged
                                - st.inflight[g];
                            st.seen[g] += purged + phantom;
                            st.remaining -= purged + phantom;
                        }
                        // Buffered successors are dropped either way —
                        // they were phantom-cancelled at first failure.
                        if st.remaining == 0 {
                            drop(st);
                            cv.notify_all();
                        }
                    }
                    Ok(()) if st.fail[g].is_some() => {
                        // In-flight sibling of a failed group: account
                        // itself (done above), drop its successors.
                        if st.remaining == 0 {
                            drop(st);
                            cv.notify_all();
                        }
                    }
                    Ok(()) => {
                        if !st.ready_at.is_empty() && nb > 0 {
                            let now = obs::now_ns();
                            for &nt in &buf[..nb] {
                                st.ready_at[nt] = now;
                            }
                        }
                        for &nt in &buf[..nb] {
                            st.queues[g].push_back(nt);
                        }
                        st.n_ready += nb;
                        obs::counter_max(obs::Counter::QueueDepthHw,
                                         st.n_ready as u64);
                        if st.remaining == 0 {
                            drop(st);
                            cv.notify_all();
                        } else {
                            for _ in 0..nb {
                                cv.notify_one();
                            }
                        }
                    }
                }
            });
        }
    });
    let st = match state.into_inner() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    for (g, fail) in st.fail.into_iter().enumerate() {
        if let Some((task, msg)) = fail {
            outcomes[g] = GroupOutcome::Failed { task, msg };
        }
    }
}

/// Run `f` over every item in parallel, mutating in place. Chunked like
/// [`par_map`]; used for per-layer / per-parameter optimizer work where
/// each item owns disjoint state.
pub fn par_for_each_mut<T, F>(items: &mut [T], workers: usize, f: F)
where
    T: Send,
    F: Fn(&mut T) + Sync,
{
    let workers = workers.max(1).min(items.len().max(1));
    if workers <= 1 {
        for it in items.iter_mut() {
            f(it);
        }
        return;
    }
    let chunk = items.len().div_ceil(workers);
    std::thread::scope(|s| {
        for ch in items.chunks_mut(chunk) {
            let f = &f;
            s.spawn(move || {
                for it in ch {
                    f(it);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_once() {
        let hits: Vec<AtomicUsize> =
            (0..1000).map(|_| AtomicUsize::new(0)).collect();
        scope_chunks(1000, 4, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_items_is_fine() {
        scope_chunks(0, 4, |_, s, e| assert_eq!(s, e));
    }

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<usize> = (0..257).collect();
        let ys = par_map(&xs, 3, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_needs_no_default_or_clone() {
        // R is neither Default nor Clone.
        struct NoDefault(usize);
        let xs: Vec<usize> = (0..100).collect();
        let ys = par_map(&xs, 4, |&x| NoDefault(x + 1));
        assert!(ys.iter().enumerate().all(|(i, r)| r.0 == i + 1));
    }

    #[test]
    fn par_add_assign_matches_serial() {
        let n = 100_000; // above the parallel threshold
        let mut dst: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let src: Vec<f32> = (0..n).map(|i| 2.0 * i as f32).collect();
        par_add_assign(&mut dst, &src, 4);
        assert!(dst.iter().enumerate().all(|(i, &v)| v == 3.0 * i as f32));
        let mut small = vec![1.0f32; 8];
        par_add_assign(&mut small, &vec![2.0f32; 8], 4);
        assert!(small.iter().all(|&v| v == 3.0));
    }

    #[test]
    fn rows_ptr_disjoint_rows_parallel() {
        // 8 rows of 16; rotate disjoint row pairs in parallel.
        let mut data: Vec<f32> = (0..128).map(|i| i as f32).collect();
        let want: Vec<f32> = data.iter().map(|x| x + 1.0).collect();
        let pairs = [(0usize, 4usize), (1, 5), (2, 6), (3, 7)];
        let rp = RowsPtr::new(&mut data, 16);
        scope_chunks(pairs.len(), 2, |_, s, e| {
            for &(p, q) in &pairs[s..e] {
                // SAFETY: pairs are disjoint, one worker per pair.
                let a = unsafe { rp.row_mut(p) };
                let b = unsafe { rp.row_mut(q) };
                for (x, y) in a.iter_mut().zip(b.iter_mut()) {
                    *x += 1.0;
                    *y += 1.0;
                }
            }
        });
        assert_eq!(data, want);
    }

    #[test]
    fn task_graph_chain_runs_in_order_per_chain() {
        // 4 chains of 25 tasks: task id = chain*25 + step. Every task must
        // run exactly once, and within a chain strictly in step order.
        for workers in [1usize, 3, 8] {
            let log: Vec<AtomicUsize> =
                (0..100).map(|_| AtomicUsize::new(usize::MAX)).collect();
            let clock = AtomicUsize::new(0);
            let seeds = [0usize, 25, 50, 75];
            run_task_graph(100, &seeds, workers, |t, ready| {
                let stamp = clock.fetch_add(1, Ordering::SeqCst);
                assert_eq!(
                    log[t].swap(stamp, Ordering::SeqCst),
                    usize::MAX,
                    "task {t} ran twice"
                );
                if (t + 1) % 25 != 0 {
                    ready(t + 1);
                }
            });
            for c in 0..4 {
                for s in 1..25 {
                    let prev = log[c * 25 + s - 1].load(Ordering::SeqCst);
                    let cur = log[c * 25 + s].load(Ordering::SeqCst);
                    assert!(prev < cur, "w={workers} chain {c} step {s}");
                }
            }
        }
    }

    #[test]
    fn task_graph_diamond_with_counters() {
        // 0 → {1, 2} → 3, readiness of 3 tracked by an atomic counter —
        // the fleet's cross-task readiness pattern.
        for workers in [1usize, 4] {
            let pending3 = AtomicUsize::new(2);
            let ran: Vec<AtomicUsize> =
                (0..4).map(|_| AtomicUsize::new(0)).collect();
            run_task_graph(4, &[0], workers, |t, ready| {
                ran[t].fetch_add(1, Ordering::SeqCst);
                match t {
                    0 => {
                        ready(1);
                        ready(2);
                    }
                    1 | 2 => {
                        if pending3.fetch_sub(1, Ordering::SeqCst) == 1 {
                            ready(3);
                        }
                    }
                    _ => {}
                }
            });
            assert!(ran.iter().all(|r| r.load(Ordering::SeqCst) == 1));
        }
    }

    #[test]
    fn task_graph_panic_propagates_with_description() {
        // The described variant must keep the abort semantics (panic
        // reaches the caller, no hang) at both dispatch modes; the warn
        // line it emits names the failing unit via `describe`.
        for workers in [1usize, 3] {
            let result = std::panic::catch_unwind(|| {
                run_task_graph_described(
                    3,
                    &[0],
                    workers,
                    |t, ready| {
                        if t == 1 {
                            panic!("boom at stage 1");
                        }
                        if t + 1 < 3 {
                            ready(t + 1);
                        }
                    },
                    |t| format!("unit X stage {t}"),
                );
            });
            assert!(result.is_err(), "w={workers}");
        }
    }

    #[test]
    fn fair_graph_runs_every_task_in_chain_order() {
        // 3 groups × chains of 20; same correctness contract as the
        // plain graph, under every dispatch mode.
        for workers in [1usize, 3, 8] {
            let log: Vec<AtomicUsize> =
                (0..60).map(|_| AtomicUsize::new(usize::MAX)).collect();
            let clock = AtomicUsize::new(0);
            let group_of: Vec<u32> =
                (0..60).map(|t| (t / 20) as u32).collect();
            let seeds = [0usize, 20, 40];
            run_task_graph_fair(
                60,
                &seeds,
                workers,
                &group_of,
                |t, ready| {
                    let stamp = clock.fetch_add(1, Ordering::SeqCst);
                    assert_eq!(
                        log[t].swap(stamp, Ordering::SeqCst),
                        usize::MAX,
                        "task {t} ran twice"
                    );
                    if (t + 1) % 20 != 0 {
                        ready(t + 1);
                    }
                },
                |t| format!("task {t}"),
            );
            for c in 0..3 {
                for s in 1..20 {
                    let prev = log[c * 20 + s - 1].load(Ordering::SeqCst);
                    let cur = log[c * 20 + s].load(Ordering::SeqCst);
                    assert!(prev < cur, "w={workers} chain {c} step {s}");
                }
            }
        }
    }

    #[test]
    fn fair_graph_inline_interleaves_groups_round_robin() {
        // Two groups: group 0 contributes a 6-stage chain, group 1 a
        // 3-stage chain. The deterministic inline drain must alternate
        // groups while both have ready work — the big tenant cannot run
        // ahead while the small one still has a ready stage.
        let order = std::sync::Mutex::new(Vec::new());
        let group_of = [0u32, 0, 0, 0, 0, 0, 1, 1, 1];
        run_task_graph_fair(
            9,
            &[0, 6],
            1,
            &group_of,
            |t, ready| {
                order.lock().unwrap().push(t);
                if t < 5 || (6 <= t && t < 8) {
                    ready(t + 1);
                }
            },
            |t| format!("task {t}"),
        );
        let order = order.into_inner().unwrap();
        assert_eq!(order, vec![0, 6, 1, 7, 2, 8, 3, 4, 5]);
    }

    #[test]
    fn fair_graph_panic_propagates() {
        for workers in [1usize, 3] {
            let group_of = [0u32, 1, 0];
            let result = std::panic::catch_unwind(|| {
                run_task_graph_fair(
                    3,
                    &[0, 1, 2],
                    workers,
                    &group_of,
                    |t, _ready| {
                        if t == 1 {
                            panic!("fair boom");
                        }
                    },
                    |t| format!("task {t}"),
                );
            });
            assert!(result.is_err(), "w={workers}");
        }
    }

    #[test]
    fn par_map_panic_resumes_original_payload() {
        // Regression: a worker panic must surface the worker's own
        // payload, not a generic "par_map worker panicked" from the
        // joining thread.
        let xs: Vec<usize> = (0..100).collect();
        let result = std::panic::catch_unwind(|| {
            par_map(&xs, 4, |&x| {
                if x == 7 {
                    panic!("original payload {x}");
                }
                x * 2
            })
        });
        let payload = result.expect_err("worker panic must propagate");
        assert_eq!(panic_payload_msg(payload.as_ref()),
                   "original payload 7");
    }

    #[test]
    fn isolated_graph_contains_failure_to_its_group() {
        // 3 groups × chains of 10 (task id = g*10 + step); task 15
        // (group 1, step 5) panics. Groups 0 and 2 must run every task
        // exactly once in chain order; group 1 runs steps 0..=5 and
        // nothing after; outcomes name the failing task and payload.
        for workers in [1usize, 3, 8] {
            let ran: Vec<AtomicUsize> =
                (0..30).map(|_| AtomicUsize::new(0)).collect();
            let clock = AtomicUsize::new(0);
            let log: Vec<AtomicUsize> =
                (0..30).map(|_| AtomicUsize::new(usize::MAX)).collect();
            let group_of: Vec<u32> =
                (0..30).map(|t| (t / 10) as u32).collect();
            let mut outcomes = Vec::new();
            run_task_graph_fair_isolated(
                30,
                &[0, 10, 20],
                workers,
                &group_of,
                |t, ready| {
                    ran[t].fetch_add(1, Ordering::SeqCst);
                    log[t].store(clock.fetch_add(1, Ordering::SeqCst),
                                 Ordering::SeqCst);
                    if t == 15 {
                        panic!("injected: stage 15 down");
                    }
                    if (t + 1) % 10 != 0 {
                        ready(t + 1);
                    }
                },
                |t| format!("task {t}"),
                &mut outcomes,
            );
            assert_eq!(outcomes.len(), 3, "w={workers}");
            assert_eq!(outcomes[0], GroupOutcome::Ok, "w={workers}");
            assert_eq!(outcomes[2], GroupOutcome::Ok, "w={workers}");
            match &outcomes[1] {
                GroupOutcome::Failed { task, msg } => {
                    assert_eq!(*task, 15, "w={workers}");
                    assert!(msg.contains("stage 15 down"), "w={workers}");
                }
                other => panic!("w={workers}: group 1 not failed: \
                                 {other:?}"),
            }
            for t in 0..30 {
                let want = if t / 10 == 1 { usize::from(t <= 15) } else { 1 };
                assert_eq!(ran[t].load(Ordering::SeqCst), want,
                           "w={workers} task {t}");
            }
            for c in [0usize, 2] {
                for s in 1..10 {
                    let prev = log[c * 10 + s - 1].load(Ordering::SeqCst);
                    let cur = log[c * 10 + s].load(Ordering::SeqCst);
                    assert!(prev < cur, "w={workers} chain {c} step {s}");
                }
            }
        }
    }

    #[test]
    fn isolated_inline_keeps_fair_order_for_survivors() {
        // Same fixture as the round-robin test, but task 7 (group 1's
        // second stage) panics: group 1's tail is cancelled and group 0
        // finishes in order, with the pre-failure interleave intact.
        let order = std::sync::Mutex::new(Vec::new());
        let group_of = [0u32, 0, 0, 0, 0, 0, 1, 1, 1];
        let mut outcomes = Vec::new();
        run_task_graph_fair_isolated(
            9,
            &[0, 6],
            1,
            &group_of,
            |t, ready| {
                order.lock().unwrap().push(t);
                if t == 7 {
                    panic!("boom");
                }
                if t < 5 || (6 <= t && t < 8) {
                    ready(t + 1);
                }
            },
            |t| format!("task {t}"),
            &mut outcomes,
        );
        assert_eq!(order.into_inner().unwrap(),
                   vec![0, 6, 1, 7, 2, 3, 4, 5]);
        assert_eq!(outcomes[0], GroupOutcome::Ok);
        assert!(matches!(outcomes[1],
                         GroupOutcome::Failed { task: 7, .. }));
    }

    #[test]
    fn isolated_graph_all_groups_failing_still_terminates() {
        for workers in [1usize, 4] {
            let group_of = [0u32, 0, 1, 1];
            let mut outcomes = Vec::new();
            run_task_graph_fair_isolated(
                4,
                &[0, 2],
                workers,
                &group_of,
                |_t, _ready| panic!("everything burns"),
                |t| format!("task {t}"),
                &mut outcomes,
            );
            assert!(outcomes.iter().all(|o| !o.is_ok()), "w={workers}");
        }
    }

    #[test]
    fn isolated_graph_inflight_sibling_successors_are_dropped() {
        // Group 0 seeds two tasks at once: task 0 panics quickly while
        // task 1 is (very likely) still running; task 1 then reports
        // successor 2, which must be dropped because the group already
        // failed. Group 1 is untouched. Holds under any interleaving:
        // if 0 panics before 1 starts, 1 is purged from the queue and 2
        // is never revealed either way.
        let ran: Vec<AtomicUsize> =
            (0..4).map(|_| AtomicUsize::new(0)).collect();
        let group_of = [0u32, 0, 0, 1];
        let mut outcomes = Vec::new();
        run_task_graph_fair_isolated(
            4,
            &[0, 1, 3],
            3,
            &group_of,
            |t, ready| {
                ran[t].fetch_add(1, Ordering::SeqCst);
                match t {
                    0 => {
                        std::thread::sleep(
                            std::time::Duration::from_millis(5));
                        panic!("first sibling down");
                    }
                    1 => {
                        std::thread::sleep(
                            std::time::Duration::from_millis(40));
                        ready(2);
                    }
                    _ => {}
                }
            },
            |t| format!("task {t}"),
            &mut outcomes,
        );
        assert_eq!(ran[2].load(Ordering::SeqCst), 0,
                   "successor of a failed group must not run");
        assert_eq!(ran[3].load(Ordering::SeqCst), 1);
        assert!(!outcomes[0].is_ok());
        assert_eq!(outcomes[1], GroupOutcome::Ok);
    }

    #[test]
    fn isolated_graph_cross_group_successor_is_contained() {
        // Task 0 (group 0) illegally reports task 1 (group 1). The
        // violation must fail group 0 (assert inside the task's panic
        // scope), and task 1 — now unreachable — must strand group 1
        // rather than deadlock the dispatch.
        for workers in [1usize, 2] {
            let group_of = [0u32, 1];
            let mut outcomes = Vec::new();
            run_task_graph_fair_isolated(
                2,
                &[0],
                workers,
                &group_of,
                |t, ready| {
                    if t == 0 {
                        ready(1);
                    }
                },
                |t| format!("task {t}"),
                &mut outcomes,
            );
            assert!(!outcomes[0].is_ok(), "w={workers}");
            assert!(!outcomes[1].is_ok(), "w={workers}");
            match &outcomes[1] {
                GroupOutcome::Failed { msg, .. } => {
                    assert!(msg.contains("stranded"), "w={workers}");
                }
                _ => unreachable!(),
            }
        }
    }

    #[test]
    fn par_for_each_mut_touches_every_item_once() {
        let mut xs: Vec<usize> = (0..1000).collect();
        par_for_each_mut(&mut xs, 4, |x| *x += 1);
        assert!(xs.iter().enumerate().all(|(i, &v)| v == i + 1));
        // degenerate cases
        let mut empty: Vec<usize> = Vec::new();
        par_for_each_mut(&mut empty, 4, |_| {});
        let mut one = vec![7usize];
        par_for_each_mut(&mut one, 0, |x| *x *= 2);
        assert_eq!(one, vec![14]);
    }
}

//! Scoped fork-join helper over std threads (tokio/rayon unavailable).
//!
//! `scope_chunks` runs a closure over disjoint index chunks in parallel and
//! is the building block for the blocked matmul in `linalg` and for
//! per-layer optimizer dispatch in the coordinator. On the 1-core CI box
//! this degrades gracefully to sequential execution.

/// Number of worker threads to use (defaults to available parallelism).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_index, start, end)` over `n` items split into `workers`
/// contiguous chunks, in parallel. `f` must be Sync; disjointness of chunks
/// is the caller's safety contract for any interior-mutable access.
pub fn scope_chunks<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n == 0 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(w, start, end));
        }
    });
}

/// Map `f` over items in parallel, preserving order. Each worker maps one
/// disjoint contiguous chunk and the chunks are stitched back in order —
/// no per-element locking, and no `Default + Clone` bound on `R`.
pub fn par_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let workers = workers.max(1).min(items.len().max(1));
    if workers <= 1 {
        return items.iter().map(&f).collect();
    }
    let chunk = items.len().div_ceil(workers);
    std::thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|ch| {
                let f = &f;
                s.spawn(move || ch.iter().map(f).collect::<Vec<R>>())
            })
            .collect();
        let mut out = Vec::with_capacity(items.len());
        for h in handles {
            out.extend(h.join().expect("par_map worker panicked"));
        }
        out
    })
}

/// Raw-pointer handle for parallel mutation of *disjoint rows* of one
/// row-major buffer — the row-granular analogue of the `scope_chunks`
/// disjointness contract. Used by the round-robin parallel Jacobi sweep
/// in `linalg::svd`, where each round rotates k/2 disjoint column pairs
/// (stored as rows of the transposed working matrix) concurrently.
#[derive(Clone, Copy)]
pub struct RowsPtr {
    ptr: *mut f32,
    stride: usize,
    rows: usize,
}

// SAFETY: RowsPtr is only a capability to *derive* row slices; the caller
// promises (see `row_mut`) that concurrently derived rows never overlap.
unsafe impl Send for RowsPtr {}
unsafe impl Sync for RowsPtr {}

impl RowsPtr {
    pub fn new(data: &mut [f32], stride: usize) -> RowsPtr {
        assert!(stride > 0 && data.len() % stride == 0,
                "RowsPtr stride must divide the buffer");
        RowsPtr { ptr: data.as_mut_ptr(), stride, rows: data.len() / stride }
    }

    /// Exclusive view of row `i`.
    ///
    /// # Safety
    /// No other live reference — on any thread — may overlap row `i`
    /// while the returned slice is alive.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_mut(&self, i: usize) -> &mut [f32] {
        assert!(i < self.rows, "RowsPtr row {i} out of {}", self.rows);
        std::slice::from_raw_parts_mut(self.ptr.add(i * self.stride),
                                       self.stride)
    }
}

/// `dst[i] += src[i]`, chunk-parallel. Small vectors stay on the calling
/// thread (the add is memory-bandwidth-bound; fork-join only pays off on
/// large parameters).
pub fn par_add_assign(dst: &mut [f32], src: &[f32], workers: usize) {
    assert_eq!(dst.len(), src.len(), "par_add_assign length mismatch");
    const MIN_PAR: usize = 1 << 15;
    let workers = workers.max(1).min(dst.len().max(1));
    if workers <= 1 || dst.len() < MIN_PAR {
        for (a, b) in dst.iter_mut().zip(src) {
            *a += *b;
        }
        return;
    }
    let chunk = dst.len().div_ceil(workers);
    std::thread::scope(|s| {
        for (d, sr) in dst.chunks_mut(chunk).zip(src.chunks(chunk)) {
            s.spawn(move || {
                for (a, b) in d.iter_mut().zip(sr) {
                    *a += *b;
                }
            });
        }
    });
}

/// Run `f` over every item in parallel, mutating in place. Chunked like
/// [`par_map`]; used for per-layer / per-parameter optimizer work where
/// each item owns disjoint state.
pub fn par_for_each_mut<T, F>(items: &mut [T], workers: usize, f: F)
where
    T: Send,
    F: Fn(&mut T) + Sync,
{
    let workers = workers.max(1).min(items.len().max(1));
    if workers <= 1 {
        for it in items.iter_mut() {
            f(it);
        }
        return;
    }
    let chunk = items.len().div_ceil(workers);
    std::thread::scope(|s| {
        for ch in items.chunks_mut(chunk) {
            let f = &f;
            s.spawn(move || {
                for it in ch {
                    f(it);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_once() {
        let hits: Vec<AtomicUsize> =
            (0..1000).map(|_| AtomicUsize::new(0)).collect();
        scope_chunks(1000, 4, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_items_is_fine() {
        scope_chunks(0, 4, |_, s, e| assert_eq!(s, e));
    }

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<usize> = (0..257).collect();
        let ys = par_map(&xs, 3, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_needs_no_default_or_clone() {
        // R is neither Default nor Clone.
        struct NoDefault(usize);
        let xs: Vec<usize> = (0..100).collect();
        let ys = par_map(&xs, 4, |&x| NoDefault(x + 1));
        assert!(ys.iter().enumerate().all(|(i, r)| r.0 == i + 1));
    }

    #[test]
    fn par_add_assign_matches_serial() {
        let n = 100_000; // above the parallel threshold
        let mut dst: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let src: Vec<f32> = (0..n).map(|i| 2.0 * i as f32).collect();
        par_add_assign(&mut dst, &src, 4);
        assert!(dst.iter().enumerate().all(|(i, &v)| v == 3.0 * i as f32));
        let mut small = vec![1.0f32; 8];
        par_add_assign(&mut small, &vec![2.0f32; 8], 4);
        assert!(small.iter().all(|&v| v == 3.0));
    }

    #[test]
    fn rows_ptr_disjoint_rows_parallel() {
        // 8 rows of 16; rotate disjoint row pairs in parallel.
        let mut data: Vec<f32> = (0..128).map(|i| i as f32).collect();
        let want: Vec<f32> = data.iter().map(|x| x + 1.0).collect();
        let pairs = [(0usize, 4usize), (1, 5), (2, 6), (3, 7)];
        let rp = RowsPtr::new(&mut data, 16);
        scope_chunks(pairs.len(), 2, |_, s, e| {
            for &(p, q) in &pairs[s..e] {
                // SAFETY: pairs are disjoint, one worker per pair.
                let a = unsafe { rp.row_mut(p) };
                let b = unsafe { rp.row_mut(q) };
                for (x, y) in a.iter_mut().zip(b.iter_mut()) {
                    *x += 1.0;
                    *y += 1.0;
                }
            }
        });
        assert_eq!(data, want);
    }

    #[test]
    fn par_for_each_mut_touches_every_item_once() {
        let mut xs: Vec<usize> = (0..1000).collect();
        par_for_each_mut(&mut xs, 4, |x| *x += 1);
        assert!(xs.iter().enumerate().all(|(i, &v)| v == i + 1));
        // degenerate cases
        let mut empty: Vec<usize> = Vec::new();
        par_for_each_mut(&mut empty, 4, |_| {});
        let mut one = vec![7usize];
        par_for_each_mut(&mut one, 0, |x| *x *= 2);
        assert_eq!(one, vec![14]);
    }
}

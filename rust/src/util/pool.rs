//! Scoped fork-join helper over std threads (tokio/rayon unavailable).
//!
//! `scope_chunks` runs a closure over disjoint index chunks in parallel and
//! is the building block for the blocked matmul in `linalg` and for
//! per-layer optimizer dispatch in the coordinator. On the 1-core CI box
//! this degrades gracefully to sequential execution.

/// Number of worker threads to use (defaults to available parallelism).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_index, start, end)` over `n` items split into `workers`
/// contiguous chunks, in parallel. `f` must be Sync; disjointness of chunks
/// is the caller's safety contract for any interior-mutable access.
pub fn scope_chunks<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n == 0 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(w, start, end));
        }
    });
}

/// Map `f` over items in parallel, preserving order.
pub fn par_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send + Default + Clone,
    F: Fn(&T) -> R + Sync,
{
    let mut out = vec![R::default(); items.len()];
    {
        let slots: Vec<std::sync::Mutex<&mut R>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        scope_chunks(items.len(), workers, |_, s, e| {
            for i in s..e {
                **slots[i].lock().unwrap() = f(&items[i]);
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_once() {
        let hits: Vec<AtomicUsize> =
            (0..1000).map(|_| AtomicUsize::new(0)).collect();
        scope_chunks(1000, 4, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn zero_items_is_fine() {
        scope_chunks(0, 4, |_, s, e| assert_eq!(s, e));
    }

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<usize> = (0..257).collect();
        let ys = par_map(&xs, 3, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }
}

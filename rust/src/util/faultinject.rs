//! Deterministic fault injection registry.
//!
//! Compiled in but default-off: with no `MOFA_FAULTS` spec installed every
//! injection point is a single relaxed atomic load. When a spec is present,
//! matching is exact-coordinate equality, so a failure reproduces bit-for-bit
//! given the same spec and the same (deterministic) execution.
//!
//! Spec grammar (comma-separated rules):
//!
//! ```text
//! spec  := rule (',' rule)*
//! rule  := kind '@' key ':' u64 ('/' key ':' u64)*
//! kind  := 'panic' | 'torn_write' | 'slow'
//! ```
//!
//! Examples:
//!
//! ```text
//! MOFA_FAULTS=panic@session:2/tick:5          # panic session 2's stage work at tick 5
//! MOFA_FAULTS=torn_write@ckpt:3               # tear the 3rd checkpoint write
//! MOFA_FAULTS=slow@stage:1/ms:10              # sleep 10ms whenever stage 1 runs
//! ```
//!
//! Matching: every key named by the rule must equal the value the injection
//! site reports for that key. The `tick` key resolves from the ambient tick
//! counter (`set_tick`) when the site does not provide it, so rules can pin a
//! fault to "session 2 at tick 5" even though session stages don't know the
//! tick. A rule naming a key the site never reports (and that is not `tick`
//! or `ms`) never matches. The `ms` key on a `slow` rule is the sleep
//! duration in milliseconds, not a matcher.
//!
//! Installing a spec (via env or [`set_spec`]) also resets the checkpoint
//! write sequence counter (see `util::fsio`), so `torn_write@ckpt:N` always
//! means "the Nth checkpoint write after the spec was installed".

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Mutex;

/// 0 = not yet initialised from env, 1 = inactive (fast path), 2 = active.
static STATE: AtomicU8 = AtomicU8::new(0);
static RULES: Mutex<Vec<Rule>> = Mutex::new(Vec::new());
/// Ambient tick counter, stamped by the session manager each tick.
static TICK: AtomicU64 = AtomicU64::new(0);

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum FaultKind {
    Panic,
    TornWrite,
    Slow,
}

#[derive(Clone, Debug)]
struct Rule {
    kind: FaultKind,
    keys: Vec<(String, u64)>,
    /// Sleep duration for `Slow` rules, milliseconds.
    ms: u64,
}

fn parse_rule(s: &str) -> Result<Rule, String> {
    let s = s.trim();
    let (kind_s, rest) = s
        .split_once('@')
        .ok_or_else(|| format!("fault rule `{s}` missing '@'"))?;
    let kind = match kind_s.trim() {
        "panic" => FaultKind::Panic,
        "torn_write" => FaultKind::TornWrite,
        "slow" => FaultKind::Slow,
        other => return Err(format!("unknown fault kind `{other}`")),
    };
    let mut keys = Vec::new();
    let mut ms = 2u64;
    for part in rest.split('/') {
        let (k, v) = part
            .split_once(':')
            .ok_or_else(|| format!("fault rule `{s}`: clause `{part}` missing ':'"))?;
        let k = k.trim();
        let v: u64 = v
            .trim()
            .parse()
            .map_err(|_| format!("fault rule `{s}`: `{part}` value is not a u64"))?;
        if kind == FaultKind::Slow && k == "ms" {
            ms = v;
        } else {
            keys.push((k.to_string(), v));
        }
    }
    if keys.is_empty() {
        return Err(format!("fault rule `{s}` has no match keys"));
    }
    Ok(Rule { kind, keys, ms })
}

fn install(spec: &str) -> Result<(), String> {
    let mut rules = Vec::new();
    for part in spec.split(',') {
        if part.trim().is_empty() {
            continue;
        }
        rules.push(parse_rule(part)?);
    }
    let active = !rules.is_empty();
    {
        let mut g = RULES.lock().unwrap_or_else(|p| p.into_inner());
        *g = rules;
    }
    super::fsio::reset_write_seq();
    STATE.store(if active { 2 } else { 1 }, Ordering::Release);
    Ok(())
}

fn init_from_env() {
    let r = match std::env::var("MOFA_FAULTS") {
        Ok(spec) => install(&spec),
        Err(_) => {
            STATE.store(1, Ordering::Release);
            Ok(())
        }
    };
    if let Err(e) = r {
        crate::util::logging::warn(format!("faultinject: ignoring MOFA_FAULTS: {e}"));
        STATE.store(1, Ordering::Release);
    }
}

#[inline]
fn active() -> bool {
    match STATE.load(Ordering::Acquire) {
        0 => {
            init_from_env();
            STATE.load(Ordering::Acquire) == 2
        }
        1 => false,
        _ => true,
    }
}

/// Install a spec programmatically (tests). Replaces any env-derived rules
/// and resets the checkpoint write sequence for deterministic `torn_write`.
pub fn set_spec(spec: &str) -> Result<(), String> {
    install(spec)
}

/// Remove all rules; injection points return to the inactive fast path.
pub fn clear() {
    let mut g = RULES.lock().unwrap_or_else(|p| p.into_inner());
    g.clear();
    drop(g);
    STATE.store(1, Ordering::Release);
}

/// Stamp the ambient tick counter; `tick:` clauses resolve against this when
/// the injection site does not report a `tick` coordinate itself.
pub fn set_tick(tick: u64) {
    TICK.store(tick, Ordering::Release);
}

fn rule_matches(rule: &Rule, coords: &[(&str, u64)]) -> bool {
    rule.keys.iter().all(|(k, want)| {
        if let Some((_, have)) = coords.iter().find(|(ck, _)| ck == k) {
            have == want
        } else if k == "tick" {
            TICK.load(Ordering::Acquire) == *want
        } else {
            false
        }
    })
}

/// Look up the first rule of `kind` matching `coords`; returns its `ms`.
fn find(kind: FaultKind, coords: &[(&str, u64)]) -> Option<u64> {
    if !active() {
        return None;
    }
    let g = RULES.lock().unwrap_or_else(|p| p.into_inner());
    g.iter()
        .find(|r| r.kind == kind && rule_matches(r, coords))
        .map(|r| r.ms)
}

fn coord_string(coords: &[(&str, u64)]) -> String {
    let mut s = String::new();
    for (i, (k, v)) in coords.iter().enumerate() {
        if i > 0 {
            s.push('/');
        }
        s.push_str(k);
        s.push(':');
        s.push_str(&v.to_string());
    }
    s
}

/// Injection point: panic if a `panic@...` rule matches. The rule lock is
/// released before unwinding so the registry is never poisoned.
pub fn panic_point(coords: &[(&str, u64)]) {
    if find(FaultKind::Panic, coords).is_some() {
        panic!("injected fault at {}", coord_string(coords));
    }
}

/// Injection point: sleep if a `slow@...` rule matches.
pub fn slow_point(coords: &[(&str, u64)]) {
    if let Some(ms) = find(FaultKind::Slow, coords) {
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
}

/// Combined stage injection point: panic rule first, then slow rule.
pub fn stage_point(coords: &[(&str, u64)]) {
    panic_point(coords);
    slow_point(coords);
}

/// Injection point for checkpoint writes: true if the write should be torn.
pub fn torn(coords: &[(&str, u64)]) -> bool {
    find(FaultKind::TornWrite, coords).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tests share the global registry; serialize them.
    static GATE: Mutex<()> = Mutex::new(());

    #[test]
    fn parses_full_grammar_and_matches_exact_coords() {
        let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
        set_spec("panic@session:2/tick:5, torn_write@ckpt:3, slow@stage:1/ms:0").unwrap();
        set_tick(4);
        assert!(find(FaultKind::Panic, &[("session", 2)]).is_none());
        set_tick(5);
        assert!(find(FaultKind::Panic, &[("session", 2)]).is_some());
        assert!(find(FaultKind::Panic, &[("session", 3)]).is_none());
        assert!(torn(&[("ckpt", 3)]));
        assert!(!torn(&[("ckpt", 4)]));
        // slow with ms:0 matches stage 1 and returns the parsed duration.
        assert_eq!(find(FaultKind::Slow, &[("stage", 1)]), Some(0));
        clear();
        set_tick(0);
    }

    #[test]
    fn site_provided_tick_overrides_ambient() {
        let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
        set_spec("panic@tick:7").unwrap();
        set_tick(0);
        assert!(find(FaultKind::Panic, &[("tick", 7)]).is_some());
        assert!(find(FaultKind::Panic, &[("tick", 6)]).is_none());
        clear();
    }

    #[test]
    fn unknown_key_never_matches_and_bad_specs_error() {
        let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
        set_spec("panic@nosuch:1").unwrap();
        assert!(find(FaultKind::Panic, &[("session", 1)]).is_none());
        clear();
        assert!(set_spec("panic@").is_err());
        assert!(set_spec("boom@x:1").is_err());
        assert!(set_spec("panic@x").is_err());
        assert!(set_spec("panic@x:abc").is_err());
        clear();
    }

    #[test]
    fn panic_point_panics_only_on_match() {
        let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
        set_spec("panic@unit:9").unwrap();
        panic_point(&[("unit", 8)]); // no match: returns
        let err = std::panic::catch_unwind(|| panic_point(&[("unit", 9)]));
        assert!(err.is_err());
        // Registry is not poisoned: clear and re-install still work.
        clear();
        set_spec("slow@stage:0/ms:1").unwrap();
        slow_point(&[("stage", 0)]);
        clear();
    }
}

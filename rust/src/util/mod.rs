//! Hand-rolled substrates.
//!
//! The offline vendor set ships only the `xla` crate closure plus `anyhow`,
//! so the conveniences a production trainer would pull from crates.io are
//! implemented here from scratch: JSON (manifest + metrics interchange),
//! a CLI argument parser, a splittable PRNG, a scoped thread pool, table
//! emitters for the paper-figure harnesses, and a small property-testing
//! harness used by the optimizer invariants suite.

pub mod cli;
pub mod faultinject;
pub mod fsio;
pub mod json;
pub mod logging;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod table;

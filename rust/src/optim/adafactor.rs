//! Adafactor-style factored second moment (Shazeer & Stern 2018):
//! O(m+n) state via a rank-1 row/column outer-product approximation.

use super::MatrixOptimizer;
use crate::linalg::Mat;

const EPS: f32 = 1e-8;

pub struct Adafactor {
    /// Row second-moment factor (m,).
    pub r_acc: Vec<f32>,
    /// Column second-moment factor (n,).
    pub c_acc: Vec<f32>,
    pub b2: f32,
}

impl Adafactor {
    pub fn new(rows: usize, cols: usize, b2: f32) -> Adafactor {
        Adafactor { r_acc: vec![0.0; rows], c_acc: vec![0.0; cols], b2 }
    }
}

impl MatrixOptimizer for Adafactor {
    fn step(&mut self, w: &mut Mat, g: &Mat, eta: f32) {
        let (m, n) = (w.rows, w.cols);
        // Update factored accumulators with mean-of-squares.
        for i in 0..m {
            let mean: f32 = g.row(i).iter().map(|x| x * x).sum::<f32>()
                / n as f32;
            self.r_acc[i] =
                self.b2 * self.r_acc[i] + (1.0 - self.b2) * (mean + 1e-30);
        }
        for j in 0..n {
            let mut mean = 0.0f32;
            for i in 0..m {
                mean += g[(i, j)] * g[(i, j)];
            }
            mean /= m as f32;
            self.c_acc[j] =
                self.b2 * self.c_acc[j] + (1.0 - self.b2) * (mean + 1e-30);
        }
        let r_mean: f32 =
            self.r_acc.iter().sum::<f32>() / m as f32 + 1e-30;
        for i in 0..m {
            for j in 0..n {
                let vhat = self.r_acc[i] * self.c_acc[j] / r_mean;
                w[(i, j)] -= eta * g[(i, j)] / (vhat.max(0.0).sqrt() + EPS);
            }
        }
    }

    fn state_floats(&self) -> usize {
        self.r_acc.len() + self.c_acc.len() // O(m + n)
    }

    fn name(&self) -> &'static str {
        "adafactor"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn factored_approximation_matches_rank1_structure() {
        // For a gradient with exact rank-1 squared structure the factored
        // second moment is exact: g² = r·cᵀ.
        let r = [1.0f32, 4.0];
        let c = [9.0f32, 1.0, 4.0];
        let g = Mat::from_fn(2, 3, |i, j| (r[i] * c[j]).sqrt());
        let mut opt = Adafactor::new(2, 3, 0.0); // b2=0 ⇒ no EMA smoothing
        let mut w = Mat::zeros(2, 3);
        opt.step(&mut w, &g, 1.0);
        // after one step the update direction is ~sign(g) (vhat == g²)
        for (wi, gi) in w.data.iter().zip(&g.data) {
            assert!((wi + gi.signum()).abs() < 1e-3, "{wi} {gi}");
        }
    }

    #[test]
    fn state_is_sublinear() {
        let opt = Adafactor::new(1024, 1024, 0.999);
        assert_eq!(opt.state_floats(), 2048);
    }

    #[test]
    fn no_nans_on_zero_gradient() {
        let mut rng = Rng::new(1);
        let mut w = Mat::randn(&mut rng, 8, 8, 1.0);
        let g = Mat::zeros(8, 8);
        let mut opt = Adafactor::new(8, 8, 0.999);
        opt.step(&mut w, &g, 0.1);
        assert!(!w.data.iter().any(|x| x.is_nan()));
    }
}

//! AdamW (Loshchilov & Hutter) — the paper's full-rank performance ceiling.

use super::{MatrixOptimizer, VecOptimizer};
use crate::linalg::Mat;

const EPS: f32 = 1e-8;

pub struct AdamW {
    pub m: Mat,
    pub v: Mat,
    pub b1: f32,
    pub b2: f32,
    pub wd: f32,
    t: usize,
}

impl AdamW {
    pub fn new(rows: usize, cols: usize, b1: f32, b2: f32, wd: f32) -> AdamW {
        AdamW {
            m: Mat::zeros(rows, cols),
            v: Mat::zeros(rows, cols),
            b1,
            b2,
            wd,
            t: 0,
        }
    }
}

impl MatrixOptimizer for AdamW {
    fn step(&mut self, w: &mut Mat, g: &Mat, eta: f32) {
        // Single fused elementwise pass: the g⊙g second-moment input is
        // formed in-register (the old `zip` temporary allocated a full
        // m×n buffer per step), with per-element math identical to the
        // separate axpy/zip passes it replaces.
        assert_eq!((self.m.rows, self.m.cols), (g.rows, g.cols));
        assert_eq!(w.data.len(), g.data.len());
        self.t += 1;
        let t = self.t as f32;
        let bc1 = 1.0 - self.b1.powf(t);
        let bc2 = 1.0 - self.b2.powf(t);
        let (b1, b2, wd) = (self.b1, self.b2, self.wd);
        for i in 0..w.data.len() {
            let gi = g.data[i];
            self.m.data[i] = b1 * self.m.data[i] + (1.0 - b1) * gi;
            self.v.data[i] = b2 * self.v.data[i] + (1.0 - b2) * (gi * gi);
            let mh = self.m.data[i] / bc1;
            let vh = self.v.data[i] / bc2;
            w.data[i] -=
                eta * (mh / (vh.max(0.0).sqrt() + EPS) + wd * w.data[i]);
        }
    }

    fn state_floats(&self) -> usize {
        self.m.data.len() + self.v.data.len()
    }

    fn name(&self) -> &'static str {
        "adamw"
    }
}

/// Flat-vector AdamW for embeddings / norm scales (paper §5.5 routing).
pub struct AdamWVec {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub b1: f32,
    pub b2: f32,
    pub wd: f32,
    t: usize,
}

impl AdamWVec {
    pub fn new(len: usize, b1: f32, b2: f32, wd: f32) -> AdamWVec {
        AdamWVec { m: vec![0.0; len], v: vec![0.0; len], b1, b2, wd, t: 0 }
    }
}

impl VecOptimizer for AdamWVec {
    fn step(&mut self, w: &mut [f32], g: &[f32], eta: f32) {
        assert_eq!(w.len(), g.len());
        assert_eq!(w.len(), self.m.len());
        self.t += 1;
        let bc1 = 1.0 - self.b1.powf(self.t as f32);
        let bc2 = 1.0 - self.b2.powf(self.t as f32);
        for i in 0..w.len() {
            self.m[i] = self.b1 * self.m[i] + (1.0 - self.b1) * g[i];
            self.v[i] = self.b2 * self.v[i] + (1.0 - self.b2) * g[i] * g[i];
            let mh = self.m[i] / bc1;
            let vh = self.v[i] / bc2;
            w[i] -= eta * (mh / (vh.max(0.0).sqrt() + EPS) + self.wd * w[i]);
        }
    }

    fn state_floats(&self) -> usize {
        self.m.len() + self.v.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn first_step_matches_closed_form() {
        let mut rng = Rng::new(1);
        let g = Mat::randn(&mut rng, 4, 3, 1.0);
        let mut w = Mat::randn(&mut rng, 4, 3, 1.0);
        let w0 = w.clone();
        let mut opt = AdamW::new(4, 3, 0.9, 0.999, 0.0);
        opt.step(&mut w, &g, 0.01);
        // After bias correction the first step is −η·g/(|g| + ε) ≈ −η·sign(g).
        for i in 0..w.data.len() {
            let want = w0.data[i]
                - 0.01 * g.data[i] / (g.data[i].abs() + 1e-8);
            assert!((w.data[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn weight_decay_is_decoupled() {
        let g = Mat::zeros(2, 2);
        let mut w = Mat::from_vec(2, 2, vec![1.0; 4]);
        let mut opt = AdamW::new(2, 2, 0.9, 0.999, 0.5);
        opt.step(&mut w, &g, 0.1);
        // zero gradient ⇒ pure decay: w ← w − η·wd·w
        for &x in &w.data {
            assert!((x - (1.0 - 0.1 * 0.5)).abs() < 1e-6);
        }
    }

    #[test]
    fn vec_variant_matches_matrix_variant() {
        let mut rng = Rng::new(2);
        let g = Mat::randn(&mut rng, 6, 5, 1.0);
        let mut w_m = Mat::randn(&mut rng, 6, 5, 1.0);
        let mut w_v = w_m.data.clone();
        let mut om = AdamW::new(6, 5, 0.9, 0.999, 0.1);
        let mut ov = AdamWVec::new(30, 0.9, 0.999, 0.1);
        for _ in 0..5 {
            om.step(&mut w_m, &g, 0.01);
            ov.step(&mut w_v, &g.data, 0.01);
        }
        for (a, b) in w_m.data.iter().zip(&w_v) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}

//! Lion (Chen et al. 2024): sign of interpolated momentum, single moment.

use super::MatrixOptimizer;
use crate::linalg::Mat;

pub struct Lion {
    pub m: Mat,
    pub b1: f32,
    pub b2: f32,
    pub wd: f32,
}

impl Lion {
    pub fn new(rows: usize, cols: usize, b1: f32, b2: f32, wd: f32) -> Lion {
        Lion { m: Mat::zeros(rows, cols), b1, b2, wd }
    }
}

impl MatrixOptimizer for Lion {
    fn step(&mut self, w: &mut Mat, g: &Mat, eta: f32) {
        for i in 0..w.data.len() {
            let interp = self.b1 * self.m.data[i] + (1.0 - self.b1) * g.data[i];
            w.data[i] -= eta * (interp.signum() * (interp != 0.0) as u8 as f32
                + self.wd * w.data[i]);
            self.m.data[i] =
                self.b2 * self.m.data[i] + (1.0 - self.b2) * g.data[i];
        }
    }

    fn state_floats(&self) -> usize {
        self.m.data.len()
    }

    fn name(&self) -> &'static str {
        "lion"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn first_step_is_sign_of_gradient() {
        let mut rng = Rng::new(1);
        let g = Mat::randn(&mut rng, 5, 4, 1.0);
        let mut w = Mat::zeros(5, 4);
        let mut opt = Lion::new(5, 4, 0.9, 0.99, 0.0);
        opt.step(&mut w, &g, 0.1);
        for (wi, gi) in w.data.iter().zip(&g.data) {
            assert!((wi + 0.1 * gi.signum()).abs() < 1e-6);
        }
    }

    #[test]
    fn momentum_updates_with_b2() {
        let g = Mat::from_vec(1, 1, vec![2.0]);
        let mut w = Mat::zeros(1, 1);
        let mut opt = Lion::new(1, 1, 0.9, 0.5, 0.0);
        opt.step(&mut w, &g, 0.0);
        assert!((opt.m.data[0] - 1.0).abs() < 1e-6); // 0.5·0 + 0.5·2
    }
}

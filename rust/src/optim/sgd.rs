//! SGD with momentum + stateless signSGD (Bernstein et al. 2018).

use super::MatrixOptimizer;
use crate::linalg::Mat;

pub struct SgdM {
    pub m: Mat,
    pub beta: f32,
}

impl SgdM {
    pub fn new(rows: usize, cols: usize, beta: f32) -> SgdM {
        SgdM { m: Mat::zeros(rows, cols), beta }
    }
}

impl MatrixOptimizer for SgdM {
    fn step(&mut self, w: &mut Mat, g: &Mat, eta: f32) {
        self.m.axpy_inplace(self.beta, 1.0, g);
        w.axpy_inplace(1.0, -eta, &self.m);
    }

    fn state_floats(&self) -> usize {
        self.m.data.len()
    }

    fn name(&self) -> &'static str {
        "sgdm"
    }
}

/// signSGD — the diagonal limit of spectral normalization (paper §3).
#[derive(Default)]
pub struct SignSgd;

impl SignSgd {
    pub fn new() -> SignSgd {
        SignSgd
    }
}

impl MatrixOptimizer for SignSgd {
    fn step(&mut self, w: &mut Mat, g: &Mat, eta: f32) {
        for (wi, gi) in w.data.iter_mut().zip(&g.data) {
            *wi -= eta * gi.signum() * (*gi != 0.0) as u8 as f32;
        }
    }

    fn state_floats(&self) -> usize {
        0
    }

    fn name(&self) -> &'static str {
        "signsgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgdm_unrolls_geometric_sum() {
        let g = Mat::from_vec(1, 1, vec![1.0]);
        let mut w = Mat::zeros(1, 1);
        let mut opt = SgdM::new(1, 1, 0.5);
        opt.step(&mut w, &g, 1.0); // m=1,   w=-1
        opt.step(&mut w, &g, 1.0); // m=1.5, w=-2.5
        assert!((w.data[0] + 2.5).abs() < 1e-6);
    }

    #[test]
    fn signsgd_ignores_magnitude() {
        let g = Mat::from_vec(1, 2, vec![100.0, -0.001]);
        let mut w = Mat::zeros(1, 2);
        SignSgd.step(&mut w, &g, 0.1);
        assert!((w.data[0] + 0.1).abs() < 1e-6);
        assert!((w.data[1] - 0.1).abs() < 1e-6);
    }

    #[test]
    fn signsgd_zero_gradient_is_noop() {
        let g = Mat::zeros(2, 2);
        let mut w = Mat::from_vec(2, 2, vec![1.0; 4]);
        SignSgd.step(&mut w, &g, 0.1);
        assert_eq!(w.data, vec![1.0; 4]);
    }
}

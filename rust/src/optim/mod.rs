//! Native-Rust optimizer implementations.
//!
//! These mirror the JAX/Pallas artifact graphs (`python/compile/optim_jnp.py`)
//! and serve three roles:
//!   1. reference implementations for property tests (orthogonality of the
//!      momentum factors, UMF ≡ dense truncated SVD, fused-accumulation
//!      linearity — the paper's Alg. 1 invariants);
//!   2. the optimizer path for the native MLP trainer (`nn::mlp`) used by
//!      closed-loop tests and the spectral analysis (Fig. 6a);
//!   3. the ground truth for the memory accounting model (Table 2 / Fig. 4):
//!      `state_floats()` reports exactly what each optimizer stores.

pub mod adafactor;
pub mod adamw;
pub mod fleet;
pub mod galore;
pub mod lion;
pub mod lora;
pub mod mofasgd;
pub mod muon;
pub mod sgd;

pub use adafactor::Adafactor;
pub use adamw::AdamW;
pub use fleet::{GradAccumUnit, MatOpt, MatStager, MatUnit, TreeReduceUnit,
                VecUnit};
pub use galore::GaLore;
pub use lion::Lion;
pub use mofasgd::MoFaSgd;
pub use muon::Muon;
pub use sgd::{SgdM, SignSgd};

use crate::linalg::Mat;

/// A per-matrix optimizer: owns its state for one weight matrix.
pub trait MatrixOptimizer {
    /// One update of `w` given gradient `g` with step size `eta`.
    fn step(&mut self, w: &mut Mat, g: &Mat, eta: f32);

    /// Number of f32s of persistent optimizer state (memory model input).
    fn state_floats(&self) -> usize;

    fn name(&self) -> &'static str;
}

/// Elementwise optimizer over a flat parameter vector (embeddings, norms —
/// the layers paper §5.5 routes to AdamW).
pub trait VecOptimizer {
    fn step(&mut self, w: &mut [f32], g: &[f32], eta: f32);
    fn state_floats(&self) -> usize;
}

#[cfg(test)]
mod descent_tests {
    //! Shared closed-loop test: every optimizer must descend on a noisy
    //! matrix quadratic ½‖W − W*‖² — the cross-implementation sanity net.
    use super::*;
    use crate::util::rng::Rng;

    fn run<O: MatrixOptimizer>(mut opt: O, eta: f32, steps: usize,
                               resample_galore: bool) -> (f32, f32) {
        let mut rng = Rng::new(99);
        let (m, n) = (48, 32);
        let w_star = Mat::randn(&mut rng, m, n, 1.0);
        let mut w = w_star.add(&Mat::randn(&mut rng, m, n, 0.3));
        let loss0 = w.sub(&w_star).frob_norm();
        for _ in 0..steps {
            let noise = Mat::randn(&mut rng, m, n, 0.01);
            let g = w.sub(&w_star).add(&noise);
            let _ = resample_galore; // resampling handled inside GaLore
            opt.step(&mut w, &g, eta);
        }
        (loss0, w.sub(&w_star).frob_norm())
    }

    fn assert_halves<O: MatrixOptimizer>(opt: O, eta: f32) {
        let name = opt.name();
        let (l0, l1) = run(opt, eta, 150, true);
        assert!(l1 < 0.5 * l0, "{name}: {l0} -> {l1}");
    }

    #[test]
    fn mofasgd_descends() {
        assert_halves(MoFaSgd::new(48, 32, 8, 0.9), 0.05);
    }

    #[test]
    fn galore_descends() {
        assert_halves(GaLore::new(48, 32, 8, 10, 0.9, 0.999, 7), 0.05);
    }

    #[test]
    fn adamw_descends() {
        assert_halves(AdamW::new(48, 32, 0.9, 0.999, 0.0), 0.05);
    }

    #[test]
    fn muon_descends() {
        assert_halves(Muon::new(48, 32, 0.9), 0.02);
    }

    #[test]
    fn lion_descends() {
        assert_halves(Lion::new(48, 32, 0.9, 0.99, 0.0), 0.01);
    }

    #[test]
    fn sgdm_descends() {
        assert_halves(SgdM::new(48, 32, 0.9), 0.02);
    }

    #[test]
    fn signsgd_descends() {
        assert_halves(SignSgd::new(), 0.01);
    }

    #[test]
    fn adafactor_descends() {
        assert_halves(Adafactor::new(48, 32, 0.999), 0.05);
    }

    #[test]
    fn state_sizes_match_table2() {
        // Paper Table 2 (state only, excluding the mn parameters):
        //   MoFaSGD: mr + nr + r     GaLore: mr + 2nr      Muon/SGD-M: mn
        //   AdamW: 2mn               Adafactor: m + n      signSGD: 0
        let (m, n, r) = (64, 48, 8);
        assert_eq!(MoFaSgd::new(m, n, r, 0.9).state_floats(),
                   m * r + n * r + r);
        assert_eq!(GaLore::new(m, n, r, 10, 0.9, 0.999, 1).state_floats(),
                   m * r + 2 * n * r);
        assert_eq!(AdamW::new(m, n, 0.9, 0.999, 0.0).state_floats(), 2 * m * n);
        assert_eq!(Muon::new(m, n, 0.9).state_floats(), m * n);
        assert_eq!(SgdM::new(m, n, 0.9).state_floats(), m * n);
        assert_eq!(SignSgd::new().state_floats(), 0);
        assert_eq!(Adafactor::new(m, n, 0.999).state_floats(), m + n);
        assert_eq!(Lion::new(m, n, 0.9, 0.99, 0.0).state_floats(), m * n);
    }
}
